//! E3–E5 — tree-shape explorer: renders the binomial trees of Fig. 2, the
//! two 2-level trees of Fig. 3, and the multilevel tree of Fig. 4, with
//! per-link-class message accounting for each strategy.
//!
//! ```sh
//! cargo run --release --example tree_explorer
//! ```

use gridcollect::coordinator::experiment;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::{Strategy, TreeShape};

fn main() -> gridcollect::error::Result<()> {
    // --- Fig. 2: binomial trees B0..B3 ---
    println!("=== Figure 2: binomial trees B0..B3 ===");
    for k in 0..=3u32 {
        let n = 1usize << k;
        let ids: Vec<usize> = (0..n).collect();
        let t = TreeShape::Binomial.build(n, &ids, 0)?;
        println!("B{k} ({n} nodes):");
        print!("{}", t.render(|r| format!("{r}")));
    }

    // --- Figs. 3a/3b/4: strategy trees on the Fig. 1 topology ---
    println!("\n=== Figures 3a, 3b, 4: strategy trees on the Fig. 1 grid ===");
    let spec = TopologySpec::paper_fig1();
    print!("{}", experiment::render_strategy_trees(&spec, 0)?);

    // --- message accounting (E4/E5): WAN/LAN crossings per strategy ---
    println!("=== per-link-class accounting for a 64 KiB broadcast ===");
    let comm = Communicator::world(&spec);
    for s in Strategy::ALL {
        println!("--- {} ---", s.name());
        print!("{}", experiment::message_accounting(&comm, s, 65536)?.to_markdown());
    }

    // --- postal-model shapes (§6): flat vs fibonacci vs binomial ---
    println!("\n=== §6: postal-optimal shapes flatten as λ grows ===");
    let ids: Vec<usize> = (0..12).collect();
    for (label, shape) in [
        ("binomial (λ=1)", TreeShape::Binomial),
        ("fibonacci λ=2", TreeShape::Fibonacci(2)),
        ("fibonacci λ=4", TreeShape::Fibonacci(4)),
        ("flat (λ→∞)", TreeShape::Flat),
    ] {
        let t = shape.build(12, &ids, 0)?;
        println!(
            "{label:<16} root fan-out {:>2}, height {}",
            t.children(0).len(),
            t.height()
        );
    }
    Ok(())
}
