//! E11 — the end-to-end driver: data-parallel training of the MLP over
//! the simulated grid, composing all three layers:
//!
//! - **L1** Pallas kernels: reduce-combine (`--xla`) and the SGD `axpy`;
//! - **L2** JAX train-step graph, AOT-compiled, executed via PJRT;
//! - **L3** Rust coordinator: topology-aware allreduce over the simulated
//!   WAN/LAN/machine hierarchy.
//!
//! Logs the loss curve and per-step communication cost for both the
//! topology-unaware and multilevel strategies.
//!
//! ```sh
//! cargo run --release --example grid_training [-- --xla] [-- --steps N]
//! ```

use gridcollect::coordinator::training::{train, TrainConfig};
use gridcollect::model::presets;
use gridcollect::netsim::Combiner;
use gridcollect::runtime::{MlpRuntime, Runtime, XlaCombiner};
use gridcollect::session::GridSession;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::fmt;
use std::sync::Arc;

fn main() -> gridcollect::error::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let use_xla = args.iter().any(|a| a == "--xla");
    let steps = args
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);

    let rt = Runtime::open_default()?;
    println!("PJRT platform: {} ({} artifacts)", rt.platform(), rt.manifest.artifacts.len());
    let mlp = MlpRuntime::open(&rt)?;
    println!(
        "MLP: {} params (padded), batch {}, {}->{}->{}",
        mlp.dims.params, mlp.dims.batch, mlp.dims.d_in, mlp.dims.d_h, mlp.dims.d_out
    );

    let combiner: Arc<dyn Combiner> = if use_xla {
        Arc::new(XlaCombiner::open_default(&rt)?)
    } else {
        Arc::new(gridcollect::netsim::NativeCombiner)
    };

    // 20 workers on the paper's Fig. 1 grid.
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    println!(
        "{} data-parallel workers on '{}', combiner: {}\n",
        comm.size(),
        comm.name(),
        combiner.name()
    );

    for strategy in [Strategy::Unaware, Strategy::Multilevel] {
        let session = GridSession::new(&comm, presets::paper_grid(), strategy)
            .with_combiner(combiner.clone());
        let cfg = TrainConfig { steps, lr: 0.2, seed: 0, ..Default::default() };
        let t0 = std::time::Instant::now();
        let logs = train(&session, &mlp, &cfg)?;
        let wall = t0.elapsed().as_secs_f64();
        let first = logs.first().unwrap();
        let last = logs.last().unwrap();
        let comm_total: f64 = logs.iter().map(|l| l.comm_us).sum();
        println!("--- strategy {} ---", strategy.name());
        for l in logs.iter().step_by((logs.len() / 8).max(1)) {
            println!(
                "  step {:>3}  loss {:.4}  comm {:>11}  WAN msgs {}",
                l.step,
                l.mean_loss,
                fmt::time_us(l.comm_us),
                l.wan_msgs
            );
        }
        println!(
            "  loss {:.4} -> {:.4} in {} steps | virtual comm total {} | wall {:.1}s\n",
            first.mean_loss,
            last.mean_loss,
            logs.len(),
            fmt::time_us(comm_total),
            wall
        );
    }
    println!("multilevel allreduce uses 2 WAN messages/step (reduce up + bcast down).");
    Ok(())
}
