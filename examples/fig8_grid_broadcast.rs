//! E1 — the paper's headline result (Figure 8): the rotating-root
//! broadcast timing application (Fig. 7) on the 48-process grid
//! (16 procs × {SDSC-SP, ANL-SP, ANL-O2K}), comparing the MPICH binomial
//! tree, MagPIe-style machine/site 2-level trees, and the multilevel
//! approach across message sizes.
//!
//! ```sh
//! cargo run --release --example fig8_grid_broadcast [-- --xla]
//! ```
//!
//! With `--xla` the MPI_Reduce-free broadcast path is unchanged, but the
//! run also verifies the PJRT combiner wiring by executing one reduce per
//! size through the AOT-compiled Pallas kernels.

use gridcollect::coordinator::experiment;
use gridcollect::coordinator::timing_app;
use gridcollect::netsim::{Combiner, ReduceOp};
use gridcollect::runtime::{Runtime, XlaCombiner};
use gridcollect::session::GridSession;
use gridcollect::tree::Strategy;
use gridcollect::util::fmt;
use std::sync::Arc;

fn main() -> gridcollect::error::Result<()> {
    let use_xla = std::env::args().any(|a| a == "--xla");
    let sizes = timing_app::default_sizes();

    let xla = if use_xla {
        let rt = Runtime::open_default()?;
        println!("PJRT platform: {}", rt.platform());
        Some(rt)
    } else {
        None
    };
    let combiner: Arc<dyn Combiner> = match &xla {
        Some(rt) => Arc::new(XlaCombiner::open_default(rt)?),
        None => experiment::native_arc(),
    };

    println!("E1 / Figure 8 — rotating-root MPI_Bcast, 48 procs, 2 sites, 3 machines\n");
    let (table, pts) = experiment::fig8_table(&sizes)?;
    print!("{}", table.to_markdown());

    // The paper's qualitative claims, checked programmatically:
    println!("\nshape checks:");
    for &bytes in &sizes {
        let at = |s: Strategy| {
            pts.iter().find(|p| p.bytes == bytes && p.strategy == s).unwrap().total_us
        };
        let ok = at(Strategy::Multilevel) <= at(Strategy::TwoLevelSite) + 1e-6
            && at(Strategy::TwoLevelSite) < at(Strategy::Unaware)
            && at(Strategy::TwoLevelMachine) < at(Strategy::Unaware);
        println!(
            "  {:>9}: multilevel {:>11} vs binomial {:>11} ({:.2}x)  [{}]",
            fmt::bytes(bytes),
            fmt::time_us(at(Strategy::Multilevel)),
            fmt::time_us(at(Strategy::Unaware)),
            at(Strategy::Unaware) / at(Strategy::Multilevel),
            if ok { "ordering OK" } else { "ORDERING VIOLATION" },
        );
    }

    // Exercise the reduce path through the selected combiner.
    let comm = experiment::paper_comm();
    let contributions: Vec<Vec<f32>> =
        (0..comm.size()).map(|r| vec![r as f32; 16384]).collect();
    let session = GridSession::new(&comm, experiment::paper_params(), Strategy::Multilevel)
        .with_combiner(combiner);
    let out = session.reduce(0, ReduceOp::Sum, &contributions)?;
    let expect = (0..comm.size()).map(|r| r as f32).sum::<f32>();
    assert!((out.data[0][0] - expect).abs() < 1e-3);
    println!(
        "\nreduce(sum) through {} combiner verified: {} elements, WAN msgs {}",
        combiner.name(),
        16384,
        out.sim.wan_messages()
    );
    Ok(())
}
