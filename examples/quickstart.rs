//! Quickstart: declare a grid topology, run one broadcast under each
//! strategy, and print the timing + WAN-message comparison.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use gridcollect::model::presets;
use gridcollect::session::GridSession;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::fmt;

fn main() -> gridcollect::error::Result<()> {
    // The paper's Fig. 1 grid: 10 procs on an SDSC SP, 5 on each of two
    // NCSA O2Ks that share a LAN.
    let spec = TopologySpec::paper_fig1();
    let comm = Communicator::world(&spec);
    println!(
        "topology '{}': {} processes, {} machines, {} levels\n",
        spec.name,
        spec.n_procs(),
        spec.machines().len(),
        spec.n_levels()
    );

    // Broadcast 256 KiB from rank 0 under every strategy.
    let data = vec![1.0f32; 65536];
    let params = presets::paper_grid();
    println!("MPI_Bcast of {} from rank 0:", fmt::bytes(data.len() * 4));
    for strategy in Strategy::ALL {
        let session = GridSession::new(&comm, params.clone(), strategy);
        let out = session.bcast(0, &data)?;
        // All ranks must have received the payload.
        assert!(out.data.iter().all(|d| d == &data));
        println!(
            "  {:<16} {:>12}   WAN msgs {}  LAN msgs {}  intra msgs {}",
            strategy.name(),
            fmt::time_us(out.sim.makespan_us),
            out.sim.wan_messages(),
            out.sim.msgs_by_sep[1],
            out.sim.msgs_by_sep[2],
        );
    }

    println!("\nmultilevel sends exactly 1 WAN + 1 LAN message (Fig. 4).");
    Ok(())
}
