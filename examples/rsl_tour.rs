//! E6 — the RSL front-end tour (Figures 5 and 6): parse the paper's own
//! job scripts, show how `GLOBUS_LAN_ID` changes the derived clustering,
//! and demonstrate the `GLOBUS_SITE_ID` 4-level extension plus
//! communicator splitting with clustering propagation (§3.1).
//!
//! ```sh
//! cargo run --release --example rsl_tour
//! ```

use gridcollect::model::presets;
use gridcollect::session::GridSession;
use gridcollect::topology::{rsl, Communicator};
use gridcollect::tree::Strategy;
use gridcollect::util::fmt;

fn main() -> gridcollect::error::Result<()> {
    // --- Figure 6: with GLOBUS_LAN_ID ---
    println!("=== Figure 6 script (GLOBUS_LAN_ID groups the NCSA O2Ks) ===");
    let fig6 = rsl::topology_from_script(rsl::FIG6_SCRIPT)?;
    describe(&fig6);

    // --- Figure 5: same script, no LAN ids ---
    println!("\n=== Figure 5 script (no GLOBUS_LAN_ID: machine-only clustering) ===");
    let fig5_src = rsl::FIG6_SCRIPT.replace("(GLOBUS_LAN_ID NCSAlan)", "");
    let fig5 = rsl::topology_from_script(&fig5_src)?;
    describe(&fig5);

    // The observable difference: broadcast cost from an SDSC root.
    let data = vec![1.0f32; 16384];
    for (name, spec) in [("fig5", &fig5), ("fig6", &fig6)] {
        let comm = Communicator::world(spec);
        let session = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel);
        let out = session.bcast(0, &data)?;
        println!(
            "{name}: multilevel bcast {} — WAN msgs {} (LAN knowledge saves a WAN message)",
            fmt::time_us(out.sim.makespan_us),
            out.sim.wan_messages()
        );
    }

    // --- 4-level extension ---
    println!("\n=== GLOBUS_SITE_ID extension: 4-level clustering ===");
    let deep = rsl::topology_from_script(
        r#"
        ( &(resourceManagerContact="sp.sdsc.edu") (count=4)
          (environment=(GLOBUS_DUROC_SUBJOB_INDEX 0)
                       (GLOBUS_LAN_ID sdsclan)(GLOBUS_SITE_ID sdsc)) )
        ( &(resourceManagerContact="sp.anl.gov") (count=4)
          (environment=(GLOBUS_DUROC_SUBJOB_INDEX 1)
                       (GLOBUS_LAN_ID mcslan)(GLOBUS_SITE_ID anl)) )
        ( &(resourceManagerContact="o2k.anl.gov") (count=4)
          (environment=(GLOBUS_DUROC_SUBJOB_INDEX 2)
                       (GLOBUS_LAN_ID mcslan)(GLOBUS_SITE_ID anl)) )
        ( &(resourceManagerContact="x.anl.gov") (count=4)
          (environment=(GLOBUS_DUROC_SUBJOB_INDEX 3)
                       (GLOBUS_LAN_ID cslan)(GLOBUS_SITE_ID anl)) )
        "#,
    )?;
    describe(&deep);

    // --- Comm split with clustering propagation (§3.1) ---
    println!("\n=== MPI_Comm_split propagates the multilevel clustering ===");
    let comm = Communicator::world(&fig6);
    let split = comm.split(|r| (Some((r % 2) as i64), r as i64))?;
    for (i, sub) in split.iter().enumerate() {
        println!(
            "  color {i}: {} ranks, {} levels, site clusters {:?}",
            sub.size(),
            sub.clustering().n_levels(),
            sub.clustering().clusters_at(1)
        );
        // Collectives work on the derived communicator directly.
        let session = GridSession::new(sub, presets::paper_grid(), Strategy::Multilevel);
        let out = session.bcast(0, &data)?;
        println!(
            "    multilevel bcast on sub-communicator: {} (WAN msgs {})",
            fmt::time_us(out.sim.makespan_us),
            out.sim.wan_messages()
        );
    }
    Ok(())
}

fn describe(spec: &gridcollect::topology::TopologySpec) {
    println!(
        "  {} machines, {} processes, {} clustering levels",
        spec.machines().len(),
        spec.n_procs(),
        spec.n_levels()
    );
    for m in spec.machines() {
        println!(
            "    ranks {:>2}..{:<2} {} (path: {})",
            m.first_rank,
            m.first_rank + m.procs,
            m.name,
            m.path.join(" / ")
        );
    }
}
