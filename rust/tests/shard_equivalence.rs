//! Acceptance test for the cluster-sharded engine (ISSUE 6): for every
//! strategy, op family, composition policy and thread count, a sharded
//! session's `SimResult` is **bitwise identical** to the sequential
//! oracle's — `finish_us`, `makespan_us`, per-separation message/byte
//! accounting, combine counts, mark times and final payloads. The shard
//! workers form a Kahn process network (blocking reads, single writer
//! per channel), so interleaving cannot perturb results; this test is
//! the end-to-end enforcement of that claim through the `GridSession`
//! front door.

use gridcollect::collectives::request;
use gridcollect::coordinator::timing_app;
use gridcollect::model::{presets, NetworkParams};
use gridcollect::netsim::{
    ExecMode, GhostPayload, NativeCombiner, ReduceOp, ShardMap, SimResult,
    DEFAULT_MIN_SHARD_RANKS,
};
use gridcollect::plan::{AlgoPolicy, AllreduceAlgo, ChunkOrder, LevelAlgo, OpKind};
use gridcollect::session::GridSession;
use gridcollect::topology::{Communicator, GroupNode, TopologySpec};
use gridcollect::tree::Strategy;
use std::sync::Arc;

fn assert_bitwise(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.finish_us.len(), b.finish_us.len(), "{ctx}: rank count");
    for (i, (x, y)) in a.finish_us.iter().zip(&b.finish_us).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: finish_us[{i}]");
    }
    assert_eq!(a.makespan_us.to_bits(), b.makespan_us.to_bits(), "{ctx}: makespan");
    assert_eq!(a.msgs_by_sep, b.msgs_by_sep, "{ctx}: msgs_by_sep");
    assert_eq!(a.bytes_by_sep, b.bytes_by_sep, "{ctx}: bytes_by_sep");
    assert_eq!(a.combines, b.combines, "{ctx}: combines");
    assert_eq!(a.mark_times_us.len(), b.mark_times_us.len(), "{ctx}: mark count");
    for ((ia, ta), (ib, tb)) in a.mark_times_us.iter().zip(&b.mark_times_us) {
        assert_eq!(ia, ib, "{ctx}: mark ids");
        assert_eq!(ta.to_bits(), tb.to_bits(), "{ctx}: mark {ia} time");
    }
    assert_eq!(a.payloads, b.payloads, "{ctx}: payloads");
}

fn session_pair(
    comm: &Communicator,
    params: NetworkParams,
    strategy: Strategy,
    threads: usize,
) -> (GridSession, GridSession) {
    let seq = GridSession::new(comm, params.clone(), strategy);
    let sharded = ExecMode::Sharded { threads };
    let sh = GridSession::new(comm, params, strategy).with_exec_mode(sharded);
    (seq, sh)
}

/// [`battery_on`] with the paper-grid network parameters.
fn battery(comm: &Communicator, strategy: Strategy, threads: usize) {
    battery_on(comm, presets::paper_grid(), strategy, threads);
}

/// Run every collective family under both engines and compare bitwise.
fn battery_on(comm: &Communicator, params: NetworkParams, strategy: Strategy, threads: usize) {
    let ctx = format!("{}/t{threads}", strategy.name());
    let (seq, sh) = session_pair(comm, params, strategy, threads);
    let n = comm.size();
    let elems = 33;
    let data: Vec<f32> = (0..elems).map(|i| i as f32 * 0.5).collect();
    let contributions: Vec<Vec<f32>> =
        (0..n).map(|r| (0..elems).map(|i| ((r * 31 + i) % 11) as f32).collect()).collect();
    let segs: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32; 5]).collect();

    let (a, b) = (seq.bcast(1 % n, &data).unwrap(), sh.bcast(1 % n, &data).unwrap());
    assert_bitwise(&a.sim, &b.sim, &format!("{ctx}/bcast"));
    assert_eq!(a.data, b.data, "{ctx}/bcast data");

    let a = seq.reduce(0, ReduceOp::Max, &contributions).unwrap();
    let b = sh.reduce(0, ReduceOp::Max, &contributions).unwrap();
    assert_bitwise(&a.sim, &b.sim, &format!("{ctx}/reduce"));
    assert_eq!(a.data, b.data, "{ctx}/reduce data");

    assert_bitwise(&seq.barrier().unwrap(), &sh.barrier().unwrap(), &format!("{ctx}/barrier"));

    let (a, b) = (seq.gather(0, &segs).unwrap(), sh.gather(0, &segs).unwrap());
    assert_bitwise(&a.sim, &b.sim, &format!("{ctx}/gather"));
    assert_eq!(a.data, b.data, "{ctx}/gather data");

    let (a, b) = (seq.scatter(0, &segs).unwrap(), sh.scatter(0, &segs).unwrap());
    assert_bitwise(&a.sim, &b.sim, &format!("{ctx}/scatter"));
    assert_eq!(a.data, b.data, "{ctx}/scatter data");

    let a = seq.bcast_segmented(0, &data, 4).unwrap();
    let b = sh.bcast_segmented(0, &data, 4).unwrap();
    assert_bitwise(&a.sim, &b.sim, &format!("{ctx}/bcast_segmented"));
    assert_eq!(a.data, b.data, "{ctx}/bcast_segmented data");

    for policy in [
        AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast),
        AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather),
        AlgoPolicy::hybrid(1),
        AlgoPolicy::uniform_level(LevelAlgo::Halving),
        AlgoPolicy::composition(&[LevelAlgo::ReduceBcast, LevelAlgo::Halving, LevelAlgo::RsAgRing])
            .unwrap(),
        AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast)
            .with_chunks(3)
            .with_chunk_order(ChunkOrder::ShortestFirst),
    ] {
        let pctx = format!("{ctx}/allreduce[{}]", policy.name());
        let a = seq.allreduce_with_policy(policy, 0, ReduceOp::Sum, &contributions).unwrap();
        let b = sh.allreduce_with_policy(policy, 0, ReduceOp::Sum, &contributions).unwrap();
        assert_bitwise(&a.sim, &b.sim, &pctx);
        assert_eq!(a.data, b.data, "{pctx} data");
        // Ghost probe: timing-only execution through the sharded engine.
        let probe = request::AllreduceProbe { root: 0, op: ReduceOp::Sum, policy, elems };
        let ga = seq.simulate_timing(&probe).unwrap();
        let gb = sh.simulate_timing(&probe).unwrap();
        assert_bitwise(&ga, &gb, &format!("{pctx} ghost"));
        assert!(gb.payloads.is_empty(), "{pctx}: ghost runs return no payloads");
        // Ghost timing equals the data path's, sharded or not.
        assert_eq!(ga.makespan_us.to_bits(), a.sim.makespan_us.to_bits(), "{pctx} ghost==full");
    }
}

#[test]
fn every_strategy_and_policy_matches_sequential_bitwise() {
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    for threads in [2usize, 4, 8] {
        for s in Strategy::ALL {
            battery(&comm, s, threads);
        }
    }
}

#[test]
fn experiment_grid_matches_at_4_threads() {
    // The paper's 48-rank experiment grid: more sites than fig1, so the
    // shard map is wider and boundary traffic heavier.
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    battery(&comm, Strategy::Multilevel, 4);
    battery(&comm, Strategy::Unaware, 4);
}

#[test]
fn fused_schedules_with_marks_match_bitwise() {
    // The Fig. 7 rotation schedule: 2n segments with a boundary marker
    // after each, exercising sharded mark accounting end to end.
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    let n = comm.size();
    for threads in [2usize, 8] {
        let (seq, sh) = session_pair(&comm, presets::paper_grid(), Strategy::Multilevel, threads);
        let sched = timing_app::rotation_schedule(&seq).unwrap();
        let mut init = vec![GhostPayload::empty(); n];
        init[0] = GhostPayload::single(0, 1024);
        let a = seq.run_schedule_timing(&sched, init.clone()).unwrap();
        let b = sh.run_schedule_timing(&sched, init).unwrap();
        assert!(!a.mark_times_us.is_empty(), "rotation schedule carries markers");
        assert_bitwise(&a, &b, &format!("rotation/t{threads}"));
    }
}

/// 24 ranks over 4 clustering levels (site / LAN / machine below the
/// world): 2 sites x 2 LANs x 2 machines x 3 procs.
fn deep_spec() -> TopologySpec {
    TopologySpec::new(
        "deep",
        GroupNode::group(
            "grid",
            (0..2)
                .map(|s| {
                    GroupNode::group(
                        format!("site{s}"),
                        (0..2)
                            .map(|l| {
                                GroupNode::group(
                                    format!("s{s}lan{l}"),
                                    (0..2)
                                        .map(|m| GroupNode::machine(format!("s{s}l{l}m{m}"), 3))
                                        .collect(),
                                )
                            })
                            .collect(),
                    )
                })
                .collect(),
        ),
    )
    .unwrap()
}

/// Same depth, one site: the top-level partition is trivial (a single
/// cluster), so only the hierarchical cut can expose parallelism.
fn single_site_spec() -> TopologySpec {
    TopologySpec::new(
        "single-site-deep",
        GroupNode::group(
            "grid",
            vec![GroupNode::group(
                "site0",
                (0..3)
                    .map(|l| {
                        GroupNode::group(
                            format!("lan{l}"),
                            (0..2).map(|m| GroupNode::machine(format!("l{l}m{m}"), 4)).collect(),
                        )
                    })
                    .collect(),
            )],
        ),
    )
    .unwrap()
}

#[test]
fn deep_clusterings_match_sequential_at_2_to_16_threads() {
    // 3-level (site/machine below the world) and 4-level grids, each at
    // every power-of-two thread count up to 16: the hierarchical shard
    // tree must stay exact however deep the recursion goes and however
    // many workers steal from each other.
    let three = Communicator::world(&TopologySpec::uniform(3, 2, 2).unwrap());
    assert_eq!(three.clustering().n_levels(), 3);
    let four = Communicator::world(&deep_spec());
    assert_eq!(four.clustering().n_levels(), 4);
    for threads in [2usize, 4, 8, 16] {
        battery(&three, Strategy::Multilevel, threads);
        battery_on(&four, presets::deep_grid(), Strategy::Multilevel, threads);
    }
}

#[test]
fn single_site_deep_topology_still_shards() {
    let comm = Communicator::world(&single_site_spec());
    let c = comm.clustering();
    assert_eq!(c.n_levels(), 4);
    assert_eq!(c.clusters_at(1).len(), 1, "one top-level cluster");
    // A top-level-only partition would collapse to 1 shard here; the
    // hierarchical cut must recurse below the trivial site level and
    // find > 1 effective worker.
    let session = GridSession::new(&comm, presets::deep_grid(), Strategy::Multilevel);
    let plan = session.plan_for(0, OpKind::Bcast, 1).unwrap();
    let map = ShardMap::build(c, &plan.channels);
    let cut = map.cut(8, DEFAULT_MIN_SHARD_RANKS);
    assert!(cut.n_shards() > 1, "deep single-site cut found {} shard(s)", cut.n_shards());
    for threads in [2usize, 4, 8, 16] {
        battery_on(&comm, presets::deep_grid(), Strategy::Multilevel, threads);
    }
}

#[test]
fn shard_cuts_are_deterministic() {
    // ShardMap::cut is a pure function of (tree, target, min_ranks):
    // two independently built maps over the same clustering must agree
    // on the digest and on every cut — the sharded engine's replay
    // stability (and its cut cache) depend on it.
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let session = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let plan = session.plan_for(0, OpKind::Bcast, 1).unwrap();
    let a = ShardMap::build(comm.clustering(), &plan.channels);
    let b = ShardMap::build(comm.clustering(), &plan.channels);
    assert_eq!(a.fingerprint(), b.fingerprint(), "map digests agree");
    for target in [1usize, 2, 3, 4, 8, 16, 64] {
        for min_ranks in [1usize, 2, 8] {
            let ca = a.cut(target, min_ranks);
            let cb = b.cut(target, min_ranks);
            let ctx = format!("target {target} / min_ranks {min_ranks}");
            assert_eq!(ca.n_shards(), cb.n_shards(), "{ctx}: shard count");
            assert_eq!(ca.rank_shards(), cb.rank_shards(), "{ctx}: rank assignment");
            assert_eq!(ca.chan_shards(), cb.chan_shards(), "{ctx}: channel assignment");
            assert!(ca.n_shards() <= target.max(1), "{ctx}: never exceeds the budget");
        }
    }
}

#[test]
fn degenerate_cases_fall_back_cleanly() {
    // Flat clustering: one shard, sharded mode must take the sequential
    // path and still agree bitwise.
    let flat = Communicator::unaware(8);
    let data = vec![1.5f32; 16];
    let seq = GridSession::new(&flat, presets::uniform_lan(1), Strategy::Unaware);
    let sh = GridSession::new(&flat, presets::uniform_lan(1), Strategy::Unaware)
        .with_exec_mode(ExecMode::Sharded { threads: 4 });
    let (a, b) = (seq.bcast(0, &data).unwrap(), sh.bcast(0, &data).unwrap());
    assert_bitwise(&a.sim, &b.sim, "flat/bcast");
    assert_eq!(a.data, b.data);

    // threads <= 1 degenerates to the sequential engine.
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    for threads in [0usize, 1] {
        battery(&comm, Strategy::Multilevel, threads);
    }

    // Two ranks, one per site: every channel crosses a shard boundary.
    let tiny = Communicator::world(&TopologySpec::uniform(2, 1, 1).unwrap());
    battery(&tiny, Strategy::Multilevel, 2);

    // A combiner not known to be Sync: sharded full-mode runs fall back
    // to the sequential engine rather than racing — still identical.
    let contributions: Vec<Vec<f32>> = (0..comm.size()).map(|r| vec![r as f32; 8]).collect();
    let seq = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel)
        .with_combiner(Arc::new(NativeCombiner));
    let sh = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel)
        .with_combiner(Arc::new(NativeCombiner))
        .with_exec_mode(ExecMode::Sharded { threads: 4 });
    let a = seq.allreduce(ReduceOp::Sum, &contributions).unwrap();
    let b = sh.allreduce(ReduceOp::Sum, &contributions).unwrap();
    assert_bitwise(&a.sim, &b.sim, "non-sync-combiner fallback");
    assert_eq!(a.data, b.data);
}
