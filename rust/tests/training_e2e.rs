//! End-to-end (E11): data-parallel training with all three layers
//! composing — PJRT train-step (L2), Pallas combine/axpy kernels (L1),
//! topology-aware allreduce over the simulated grid (L3), driven through
//! the `GridSession` front door.
//!
//! Requires `make artifacts`; marked `#[ignore]` so tier-1 (`cargo test`)
//! stays interpretable in environments without the AOT-compiled PJRT
//! kernels. Run with `cargo test -- --ignored` after building artifacts.

use gridcollect::coordinator::training::{train, TrainConfig};
use gridcollect::model::presets;
use gridcollect::runtime::{MlpRuntime, Runtime, XlaCombiner};
use gridcollect::session::GridSession;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;
use std::sync::Arc;

fn setup() -> (Runtime, Communicator) {
    let rt = Runtime::open_default().expect("run `make artifacts` before cargo test");
    // 2 sites x 2 machines x 3 procs: deliberately NOT a power-of-two
    // layout — with aligned blocks the binomial tree is accidentally
    // hierarchical and the strategies tie.
    let comm = Communicator::world(&TopologySpec::uniform(2, 2, 3).unwrap());
    (rt, comm)
}

#[test]
#[ignore = "requires `make artifacts` (AOT PJRT kernels absent in plain tier-1 runs)"]
fn loss_decreases_with_native_combiner() {
    let (rt, comm) = setup();
    let mlp = MlpRuntime::open(&rt).unwrap();
    let session = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let cfg = TrainConfig { steps: 30, lr: 0.2, seed: 1, ..Default::default() };
    let logs = train(&session, &mlp, &cfg).unwrap();
    let first = logs.first().unwrap().mean_loss;
    let last = logs.last().unwrap().mean_loss;
    assert!(last < first * 0.75, "loss {first} -> {last}");
}

#[test]
#[ignore = "requires `make artifacts` (AOT PJRT kernels absent in plain tier-1 runs)"]
fn xla_and_native_combiners_train_identically() {
    // The gradient payloads are not integer-valued, but both combiners
    // perform the same chunked fp additions in the same order, so the
    // trajectories must be bitwise identical.
    let (rt, comm) = setup();
    let mlp = MlpRuntime::open(&rt).unwrap();
    let xla = Arc::new(XlaCombiner::open_default(&rt).unwrap());
    let cfg = TrainConfig { steps: 8, lr: 0.1, seed: 2, ..Default::default() };
    let xla_session = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel)
        .with_combiner(xla.clone());
    let a = train(&xla_session, &mlp, &cfg).unwrap();
    let native_session = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let b = train(&native_session, &mlp, &cfg).unwrap();
    for (la, lb) in a.iter().zip(&b) {
        assert_eq!(la.mean_loss, lb.mean_loss, "step {}", la.step);
    }
    assert!(xla.calls.get() > 0, "XLA combiner actually used");
}

#[test]
#[ignore = "requires `make artifacts` (AOT PJRT kernels absent in plain tier-1 runs)"]
fn multilevel_strategy_cuts_communication_time() {
    let (rt, comm) = setup();
    let mlp = MlpRuntime::open(&rt).unwrap();
    let mk = |strategy| {
        let session = GridSession::new(&comm, presets::paper_grid(), strategy);
        let cfg = TrainConfig { steps: 3, lr: 0.1, seed: 3, ..Default::default() };
        train(&session, &mlp, &cfg).unwrap()
    };
    let unaware = mk(Strategy::Unaware);
    let multi = mk(Strategy::Multilevel);
    // Same losses (synchronous SGD is strategy-independent)...
    for (a, b) in unaware.iter().zip(&multi) {
        assert!((a.mean_loss - b.mean_loss).abs() < 1e-5, "step {}", a.step);
    }
    // ...but less virtual communication time and fewer WAN messages.
    assert!(multi[0].comm_us < unaware[0].comm_us);
    assert!(multi[0].wan_msgs < unaware[0].wan_msgs);
}

#[test]
#[ignore = "requires `make artifacts` (AOT PJRT kernels absent in plain tier-1 runs)"]
fn gradient_payload_spans_multiple_combiner_chunks() {
    // The padded parameter vector (19456 f32 = 76 KiB) exceeds the
    // 16384-element artifact chunk: the chunked path is exercised.
    let (rt, _comm) = setup();
    let mlp = MlpRuntime::open(&rt).unwrap();
    assert!(mlp.dims.params > XlaCombiner::DEFAULT_N);
}
