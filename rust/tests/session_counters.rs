//! Acceptance test for the tuner → workload loop through the
//! `GridSession` front door (ISSUE 5): a session carrying a persisted
//! `PolicyTable` transparently runs the tuned policy, and its **warm**
//! steps perform zero tree builds, zero program compiles, zero plan
//! rebuilds and zero scratch growth — with ghost (timing) steps
//! additionally allocating zero payload data. Data-carrying steps
//! necessarily materialize their input payloads; the counter pins that
//! cost to exactly the encode path (nothing inside the engine).
//!
//! Single `#[test]` in its own binary: the counters are process-wide
//! and exact-delta assertions must not race with other tests.

use gridcollect::model::presets;
use gridcollect::netsim::ReduceOp;
use gridcollect::session::{GridSession, PolicyTable};
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::counters;

#[test]
fn tuned_session_runs_warm_steps_without_building_or_allocating() {
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let n = comm.size();
    let sizes = [4096usize, 65536];

    // Tune and persist (round-tripping through the on-disk JSON form,
    // exactly what `tune-boundary --save` + `--policy-file` do).
    let tuner = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let (_, table) = tuner.tune_boundary(ReduceOp::Sum, &sizes).unwrap();
    let table = PolicyTable::from_json(&table.to_json()).unwrap();

    // A fresh session consuming the table: the provider must resolve to
    // the tuner's argmin for each tuned size.
    let session = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel)
        .with_policy_table(table.clone())
        .unwrap();
    for &bytes in &sizes {
        assert_eq!(
            session.resolve_policy(ReduceOp::Sum, bytes).unwrap(),
            table.best_for(ReduceOp::Sum, bytes).unwrap(),
            "{bytes}: session runs the tuned policy"
        );
    }

    let elems = 65536 / 4;
    let contributions: Vec<Vec<f32>> = (0..n).map(|r| vec![(r % 7) as f32; elems]).collect();

    // Prime: first ghost step and first data step build the tuned
    // policy's plan once and size the scratch arenas.
    let before_cold = counters::snapshot();
    session.allreduce_timing(ReduceOp::Sum, elems).unwrap();
    let reference = session.allreduce(ReduceOp::Sum, &contributions).unwrap();
    let cold = counters::snapshot().since(&before_cold);
    assert!(cold.tree_builds >= 1, "cold steps build the tuned plan");
    assert!(cold.scratch_allocs >= 1, "cold steps size the scratch arenas");

    // Warm ghost steps (the tuner/timing consumers): pure engine runs.
    let before = counters::snapshot();
    for _ in 0..5 {
        let sim = session.allreduce_timing(ReduceOp::Sum, elems).unwrap();
        assert!(sim.payloads.is_empty(), "ghost steps return no payloads");
    }
    let ghost = counters::snapshot().since(&before);
    assert_eq!(ghost.tree_builds, 0, "warm tuned ghost steps build no trees");
    assert_eq!(ghost.program_compiles, 0, "warm tuned ghost steps compile nothing");
    assert_eq!(ghost.plan_cache_misses, 0, "tuned plan served from cache");
    assert_eq!(ghost.sim_runs, 5, "one engine run per step");
    assert_eq!(ghost.payload_allocs, 0, "ghost steps allocate no payload data");
    assert_eq!(ghost.scratch_allocs, 0, "ghost steps grow no scratch storage");
    assert_eq!(ghost.schedule_builds, 0);

    // Warm data steps (the training-style hot path): the only
    // allocations are the steps' own input payloads.
    let before = counters::snapshot();
    for _ in 0..5 {
        let out = session.allreduce(ReduceOp::Sum, &contributions).unwrap();
        assert_eq!(out.data, reference.data, "warm results stay bitwise stable");
    }
    let data = counters::snapshot().since(&before);
    assert_eq!(data.tree_builds, 0, "warm tuned data steps build no trees");
    assert_eq!(data.program_compiles, 0, "warm tuned data steps compile nothing");
    assert_eq!(data.plan_cache_misses, 0, "tuned plan served from cache");
    assert_eq!(data.sim_runs, 5, "one engine run per step");
    assert_eq!(data.scratch_allocs, 0, "warm data steps grow no scratch storage");
    assert!(data.payload_allocs > 0, "data steps do materialize their inputs");

    // And the tuned result is the same answer every policy gives:
    // compare against the default (reduce+bcast) front door.
    let default_session = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let default_out = default_session.allreduce(ReduceOp::Sum, &contributions).unwrap();
    assert_eq!(default_out.data, reference.data, "tuned == default, bitwise");
}
