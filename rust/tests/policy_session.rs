//! GridSession front-door acceptance (result-local; no global stage
//! counters, safe under parallel test execution):
//!
//! 1. `PolicyTable` round-trip: save → load → **identical argmin
//!    decisions** as the in-memory table, across every op/size tuned;
//! 2. provenance mismatch (table tuned under different `NetworkParams`,
//!    topology or strategy) is a **hard error** on install;
//! 3. every collective driven through `GridSession` produces
//!    **bitwise-identical** `SimResult`s to the same call hand-wired
//!    through `CollectiveEngine` — the migration is a pure re-fronting.

use gridcollect::collectives::{request, CollectiveEngine};
use gridcollect::model::presets;
use gridcollect::netsim::{ReduceOp, SimResult};
use gridcollect::plan::AlgoPolicy;
use gridcollect::session::{GridSession, PolicyTable};
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

fn assert_sim_eq(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(bits(&a.finish_us), bits(&b.finish_us), "finish_us {ctx}");
    assert_eq!(a.makespan_us.to_bits(), b.makespan_us.to_bits(), "makespan {ctx}");
    assert_eq!(a.msgs_by_sep, b.msgs_by_sep, "msgs_by_sep {ctx}");
    assert_eq!(a.bytes_by_sep, b.bytes_by_sep, "bytes_by_sep {ctx}");
    assert_eq!(a.combines, b.combines, "combines {ctx}");
    assert_eq!(a.payloads, b.payloads, "payloads {ctx}");
}

#[test]
fn policy_table_file_round_trip_preserves_argmin_decisions() {
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let session = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let sizes = [4096usize, 65536, 1 << 20];
    let (_, in_memory) = session.tune_boundary(ReduceOp::Sum, &sizes).unwrap();
    assert_eq!(in_memory.len(), sizes.len());

    let path = std::env::temp_dir().join(format!("gridcollect_policy_{}.json", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    in_memory.save(&path).unwrap();
    let loaded = PolicyTable::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    assert_eq!(loaded.provenance(), in_memory.provenance(), "provenance survives the disk");
    assert_eq!(loaded.entries(), in_memory.entries(), "entries survive the disk");
    // Identical argmin decisions — at tuned sizes AND between them
    // (nearest-log-size resolution must agree too).
    for probe in [1024usize, 4096, 10000, 65536, 1 << 19, 1 << 20, 1 << 22] {
        assert_eq!(
            loaded.best_for(ReduceOp::Sum, probe),
            in_memory.best_for(ReduceOp::Sum, probe),
            "argmin at {probe} bytes"
        );
    }
    // Installing the loaded table resolves like the in-memory one.
    let tuned = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel)
        .with_policy_table(loaded)
        .unwrap();
    for &bytes in &sizes {
        assert_eq!(
            tuned.resolve_policy(ReduceOp::Sum, bytes).unwrap(),
            in_memory.best_for(ReduceOp::Sum, bytes).unwrap()
        );
    }
}

#[test]
fn provenance_mismatch_on_load_is_a_hard_error() {
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let session = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let (_, table) = session.tune_boundary(ReduceOp::Sum, &[65536]).unwrap();
    let json = table.to_json();

    // Different NetworkParams: hard error, not a silent accept.
    let other_params = presets::paper_grid().with_combine_us_per_byte(0.5);
    let err = GridSession::new(&comm, other_params, Strategy::Multilevel)
        .with_policy_table(PolicyTable::from_json(&json).unwrap());
    let msg = format!("{}", err.err().expect("params mismatch must error"));
    assert!(msg.contains("NetworkParams"), "names the mismatched field: {msg}");

    // Different topology: hard error.
    let fig1 = Communicator::world(&TopologySpec::paper_fig1());
    let err = GridSession::new(&fig1, presets::paper_grid(), Strategy::Multilevel)
        .with_policy_table(PolicyTable::from_json(&json).unwrap());
    assert!(err.is_err(), "topology mismatch must error");

    // Different strategy: hard error.
    let err = GridSession::new(&comm, presets::paper_grid(), Strategy::TwoLevelSite)
        .with_policy_table(PolicyTable::from_json(&json).unwrap());
    assert!(err.is_err(), "strategy mismatch must error");

    // Matching context: installs.
    assert!(GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel)
        .with_policy_table(PolicyTable::from_json(&json).unwrap())
        .is_ok());
}

#[test]
fn session_results_are_bitwise_identical_to_engine_results() {
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    let n = comm.size();
    let params = presets::paper_grid();
    let data: Vec<f32> = (0..137).map(|i| (i % 11) as f32 - 5.0).collect();
    let contributions: Vec<Vec<f32>> = (0..n)
        .map(|r| (0..137).map(|i| ((r * 13 + i) % 17) as f32).collect())
        .collect();
    for strategy in Strategy::ALL {
        let session = GridSession::new(&comm, params.clone(), strategy);
        let engine = CollectiveEngine::new(&comm, params.clone(), strategy);
        let ctx = |what: &str| format!("{} {what}", strategy.name());

        let req = request::Bcast { root: 3, data: &data };
        assert_sim_eq(
            &session.run_sim(&req).unwrap(),
            &engine.run_sim(&req).unwrap(),
            &ctx("bcast"),
        );

        let req = request::Reduce { root: 1, op: ReduceOp::Max, contributions: &contributions };
        assert_sim_eq(
            &session.run_sim(&req).unwrap(),
            &engine.run_sim(&req).unwrap(),
            &ctx("reduce"),
        );

        for policy in [AlgoPolicy::hybrid(1), AlgoPolicy::hybrid(2)] {
            let req = request::Allreduce {
                root: 0,
                op: ReduceOp::Sum,
                policy,
                contributions: &contributions,
            };
            assert_sim_eq(
                &session.run_sim(&req).unwrap(),
                &engine.run_sim(&req).unwrap(),
                &ctx(&policy.name()),
            );
        }

        // The named front-door methods agree with the engine wrappers
        // on delivered data AND simulation, end to end.
        let s_out = session.gather(2, &contributions).unwrap();
        let e_out = engine.gather(2, &contributions).unwrap();
        assert_eq!(s_out.data, e_out.data, "{}", ctx("gather data"));
        assert_sim_eq(&s_out.sim, &e_out.sim, &ctx("gather"));

        let s_out = session.allreduce(ReduceOp::Sum, &contributions).unwrap();
        let e_out = engine.allreduce(ReduceOp::Sum, &contributions).unwrap();
        assert_eq!(s_out.data, e_out.data, "{}", ctx("allreduce data"));
        assert_sim_eq(&s_out.sim, &e_out.sim, &ctx("allreduce"));
    }
}
