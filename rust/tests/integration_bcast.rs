//! Integration: MPI_Bcast across strategies, topologies, roots and sizes.

use gridcollect::collectives::CollectiveEngine;
use gridcollect::model::presets;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;

fn engines(comm: &Communicator) -> Vec<CollectiveEngine<'_>> {
    Strategy::ALL
        .iter()
        .map(|&s| CollectiveEngine::new(comm, presets::paper_grid(), s))
        .collect()
}

#[test]
fn every_strategy_delivers_identical_data_everywhere() {
    for spec in [
        TopologySpec::paper_fig1(),
        TopologySpec::paper_experiment(),
        TopologySpec::uniform(3, 2, 5).unwrap(),
        TopologySpec::uniform(1, 1, 7).unwrap(), // degenerate: single machine
    ] {
        let comm = Communicator::world(&spec);
        let data: Vec<f32> = (0..2048).map(|i| (i as f32).sin()).collect();
        for e in engines(&comm) {
            for root in [0, comm.size() / 2, comm.size() - 1] {
                let out = e.bcast(root, &data).unwrap();
                for r in 0..comm.size() {
                    assert_eq!(
                        out.data[r],
                        data,
                        "{} root {root} rank {r} ({})",
                        e.strategy().name(),
                        spec.name
                    );
                }
            }
        }
    }
}

#[test]
fn multilevel_minimizes_wan_messages_for_every_root() {
    let spec = TopologySpec::paper_experiment();
    let comm = Communicator::world(&spec);
    let n_sites = 2;
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    for root in 0..comm.size() {
        let out = e.bcast(root, &[1.0; 64]).unwrap();
        assert_eq!(
            out.sim.wan_messages(),
            (n_sites - 1) as u64,
            "root {root}: multilevel must cross the WAN exactly (sites-1) times"
        );
    }
}

#[test]
fn fig8_strategy_ordering_across_sizes() {
    // For a fixed root at tiny sizes all strategies ride one overlapped
    // WAN latency and nearly tie (visible in Fig. 8's left edge); the
    // ordering becomes strict at bandwidth-relevant sizes. Sum over all
    // roots (the Fig. 7 rotation) like the paper does.
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    for bytes in [1024usize, 16384, 262144, 1 << 20] {
        let data = vec![1.0f32; bytes / 4];
        let mk = |s: Strategy| -> f64 {
            let e = CollectiveEngine::new(&comm, presets::paper_grid(), s);
            (0..comm.size()).map(|root| e.bcast(root, &data).unwrap().sim.makespan_us).sum()
        };
        let unaware = mk(Strategy::Unaware);
        let machine = mk(Strategy::TwoLevelMachine);
        let site = mk(Strategy::TwoLevelSite);
        let multi = mk(Strategy::Multilevel);
        assert!(multi <= site + 1e-6, "{bytes}: multi {multi} vs site {site}");
        assert!(site < unaware, "{bytes}: site {site} vs unaware {unaware}");
        assert!(machine < unaware, "{bytes}: machine {machine} vs unaware {unaware}");
        if bytes >= 16384 {
            assert!(
                multi < unaware * 0.7,
                "{bytes}: expected >1.4x rotation gain, got {:.2}x",
                unaware / multi
            );
        }
    }
}

#[test]
fn makespan_monotonic_in_message_size() {
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    for e in engines(&comm) {
        let mut prev = 0.0;
        for bytes in [256usize, 1024, 8192, 65536, 262144] {
            let out = e.bcast(0, &vec![0.0f32; bytes / 4]).unwrap();
            assert!(
                out.sim.makespan_us > prev,
                "{}: {bytes} not slower than smaller size",
                e.strategy().name()
            );
            prev = out.sim.makespan_us;
        }
    }
}

#[test]
fn bcast_message_count_is_n_minus_1() {
    // Any spanning-tree broadcast sends exactly n-1 messages.
    let spec = TopologySpec::uniform(4, 2, 3).unwrap();
    let comm = Communicator::world(&spec);
    for e in engines(&comm) {
        let out = e.bcast(5, &[1.0; 32]).unwrap();
        assert_eq!(
            out.sim.msgs_by_sep.iter().sum::<u64>(),
            (comm.size() - 1) as u64,
            "{}",
            e.strategy().name()
        );
    }
}

#[test]
fn trace_is_causally_ordered() {
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel)
        .with_trace();
    let out = e.bcast(0, &[1.0f32; 256]).unwrap();
    assert_eq!(out.sim.trace.len(), 2 * (comm.size() - 1));
    // Trace is sorted by time and every recv follows its send.
    let mut t = 0.0;
    for ev in &out.sim.trace {
        assert!(ev.t_us >= t);
        t = ev.t_us;
    }
}

#[test]
fn empty_and_single_rank_communicators() {
    let comm = Communicator::unaware(1);
    let e = CollectiveEngine::new(&comm, presets::uniform_lan(1), Strategy::Multilevel);
    let out = e.bcast(0, &[42.0]).unwrap();
    assert_eq!(out.data[0], vec![42.0]);
    assert_eq!(out.sim.makespan_us, 0.0);
}

#[test]
fn zero_length_broadcast() {
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let out = e.bcast(0, &[]).unwrap();
    // Messages still flow (latency-only), data is empty everywhere.
    assert_eq!(out.sim.msgs_by_sep.iter().sum::<u64>(), (comm.size() - 1) as u64);
    assert!(out.data.iter().all(|d| d.is_empty()));
}
