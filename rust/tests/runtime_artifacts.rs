//! Integration: the PJRT runtime against the real AOT artifacts.
//!
//! These tests REQUIRE `make artifacts`. They are marked `#[ignore]` with
//! a reason so a plain tier-1 `cargo test` run stays green and
//! interpretable in environments without the artifacts; run them with
//! `cargo test -- --ignored` after building artifacts.

use gridcollect::collectives::{verify, CollectiveEngine};
use gridcollect::model::presets;
use gridcollect::netsim::{Combiner, NativeCombiner, ReduceOp};
use gridcollect::runtime::{MlpRuntime, Runtime, XlaCombiner};
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::rng::Rng;

fn runtime() -> Runtime {
    Runtime::open_default().expect("run `make artifacts` before cargo test")
}

#[test]
#[ignore = "requires `make artifacts` (AOT PJRT kernels absent in plain tier-1 runs)"]
fn manifest_lists_all_expected_artifacts() {
    let rt = runtime();
    for name in [
        "combine2_sum_16384",
        "combine2_max_16384",
        "combine2_min_16384",
        "combine2_prod_16384",
        "combine8_sum_16384",
        "mlp_train_step",
        "mlp_sgd_step",
    ] {
        rt.manifest.get(name).unwrap();
    }
    assert_eq!(rt.warm_up().unwrap(), rt.manifest.artifacts.len());
}

#[test]
#[ignore = "requires `make artifacts` (AOT PJRT kernels absent in plain tier-1 runs)"]
fn combine_k_artifact_reduces_eight_buffers() {
    let rt = runtime();
    let exe = rt.load("combine8_sum_16384").unwrap();
    let n = 16384;
    let k = 8;
    let mut xs = vec![0.0f32; k * n];
    for (i, v) in xs.iter_mut().enumerate() {
        *v = (i / n) as f32; // buffer j filled with value j
    }
    let out = exe.run_f32(&[(&xs, &[k as i64, n as i64])]).unwrap();
    assert_eq!(out[0].len(), n);
    // sum over j of j = 28
    assert!(out[0].iter().all(|&v| v == 28.0));
}

#[test]
#[ignore = "requires `make artifacts` (AOT PJRT kernels absent in plain tier-1 runs)"]
fn xla_combiner_bitwise_matches_native() {
    let rt = runtime();
    let c = XlaCombiner::open_default(&rt).unwrap();
    let mut rng = Rng::new(5);
    for op in ReduceOp::ALL {
        for len in [100usize, 16384, 20000] {
            let mut a: Vec<f32> = (0..len).map(|_| rng.f32_in(0.5, 1.5)).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.f32_in(0.5, 1.5)).collect();
            let mut expect = a.clone();
            NativeCombiner.combine(op, &mut expect, &b);
            c.combine(op, &mut a, &b);
            assert_eq!(a, expect, "{op:?} len {len}");
        }
    }
}

#[test]
#[ignore = "requires `make artifacts` (AOT PJRT kernels absent in plain tier-1 runs)"]
fn full_reduce_through_pjrt_combiner() {
    let rt = runtime();
    let c = XlaCombiner::open_default(&rt).unwrap();
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    let contributions: Vec<Vec<f32>> = (0..comm.size())
        .map(|r| (0..20000).map(|i| ((r + i) % 17) as f32).collect())
        .collect();
    let expect = verify::ref_reduce(&contributions, ReduceOp::Sum);
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel)
        .with_combiner(&c);
    let out = e.reduce(3, ReduceOp::Sum, &contributions).unwrap();
    assert_eq!(out.data[3], expect, "integer sums must be exact");
    assert!(c.calls.get() > 0, "PJRT combiner was actually used");
}

#[test]
#[ignore = "requires `make artifacts` (AOT PJRT kernels absent in plain tier-1 runs)"]
fn allreduce_through_pjrt_matches_native_path() {
    let rt = runtime();
    let c = XlaCombiner::open_default(&rt).unwrap();
    let comm = Communicator::world(&TopologySpec::uniform(2, 2, 3).unwrap());
    let contributions: Vec<Vec<f32>> = (0..comm.size())
        .map(|r| (0..5000).map(|i| ((r * 3 + i) % 11) as f32).collect())
        .collect();
    let xla_out = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel)
        .with_combiner(&c)
        .allreduce(ReduceOp::Sum, &contributions)
        .unwrap();
    let native_out = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel)
        .allreduce(ReduceOp::Sum, &contributions)
        .unwrap();
    assert_eq!(xla_out.data, native_out.data);
    // Virtual time must be identical: the combiner choice affects the
    // arithmetic backend, not the simulated clock.
    assert!((xla_out.sim.makespan_us - native_out.sim.makespan_us).abs() < 1e-9);
}

#[test]
#[ignore = "requires `make artifacts` (AOT PJRT kernels absent in plain tier-1 runs)"]
fn mlp_artifacts_run() {
    let rt = runtime();
    let mlp = MlpRuntime::open(&rt).unwrap();
    let p = mlp.init_params(42);
    let (x, y) = mlp.synth_batch(0);
    let (grads, loss) = mlp.train_step(&p, &x, &y).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    let updated = mlp.sgd_step(&p, &grads, 0.01).unwrap();
    assert_eq!(updated.len(), p.len());
    assert_ne!(updated, p);
}

#[test]
#[ignore = "requires `make artifacts` (AOT PJRT kernels absent in plain tier-1 runs)"]
fn hlo_text_files_are_parseable_modules() {
    let rt = runtime();
    for a in &rt.manifest.artifacts {
        let text = std::fs::read_to_string(&a.file).unwrap();
        assert!(text.starts_with("HloModule"), "{} not an HLO module", a.name);
        assert!(text.contains("ENTRY"), "{} lacks an entry computation", a.name);
    }
}
