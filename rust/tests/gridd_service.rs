//! Protocol-level tests for the `gridd` daemon (ISSUE 10): wire
//! behaviour over Unix sockets and TCP — request validation, library
//! bit-equivalence, the tune → resolve round trip, wire-matrix
//! discovery, and provenance-stamped policy write-back.
//!
//! Each test spawns its own daemon on a unique socket; global stage
//! counters are never asserted exactly here (that lives in the
//! single-test `gridd_singleflight` binary).

use gridcollect::collectives::request::AllreduceProbe;
use gridcollect::model::presets;
use gridcollect::netsim::ReduceOp;
use gridcollect::plan::{AlgoPolicy, AllreduceAlgo};
use gridcollect::service::{proto::JsonObj, Client, Gridd, GriddConfig, GriddHandle, Target};
use gridcollect::session::{GridSession, PolicyTable};
use gridcollect::topology::discover::{infer_clustering, synthesize_from_spec, DEFAULT_PROBE_BYTES};
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::json::Value;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};

static NEXT_SOCK: AtomicUsize = AtomicUsize::new(0);

fn sock_path() -> String {
    let n = NEXT_SOCK.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir()
        .join(format!("gridd_svc_{}_{n}.sock", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn spawn_daemon(socket: &str, policy_dir: Option<String>) -> GriddHandle {
    let cfg = GriddConfig {
        socket: Some(socket.to_string()),
        tcp: None,
        threads: 4,
        policy_dir,
    };
    Gridd::new(cfg).unwrap().spawn()
}

fn connect(socket: &str) -> Client {
    Client::connect(&Target::parse(socket)).unwrap()
}

fn shutdown(socket: &str, handle: GriddHandle) {
    let doc = connect(socket).request(&JsonObj::new().str("cmd", "shutdown").render()).unwrap();
    assert_eq!(doc.get("stopping").and_then(|v| v.as_bool()), Some(true));
    handle.join().unwrap();
}

fn str_field<'a>(doc: &'a Value, key: &str) -> &'a str {
    doc.get(key).and_then(|v| v.as_str()).unwrap_or_else(|| panic!("missing '{key}': {doc:?}"))
}

fn u64_field(doc: &Value, key: &str) -> u64 {
    doc.get(key).and_then(|v| v.as_u64()).unwrap_or_else(|| panic!("missing '{key}': {doc:?}"))
}

#[test]
fn ping_ids_and_unknown_commands() {
    let socket = sock_path();
    let handle = spawn_daemon(&socket, None);
    let mut c = connect(&socket);

    let doc = c.request(r#"{"cmd":"ping","id":41}"#).unwrap();
    assert_eq!(str_field(&doc, "service"), "gridd");
    assert_eq!(u64_field(&doc, "id"), 41, "the request id is echoed back");

    let err = c.request(r#"{"cmd":"frobnicate"}"#).unwrap_err().to_string();
    assert!(err.contains("unknown command"), "got: {err}");
    let err = c.request("this is not json").unwrap_err().to_string();
    assert!(err.contains("not valid JSON"), "got: {err}");
    let err = c.request(r#"{"id":1}"#).unwrap_err().to_string();
    assert!(err.contains("\"cmd\""), "got: {err}");

    // The connection survives failed requests.
    let doc = c.request(r#"{"cmd":"ping"}"#).unwrap();
    assert_eq!(str_field(&doc, "service"), "gridd");
    drop(c);
    shutdown(&socket, handle);
}

#[test]
fn simulate_matches_the_library_bitwise() {
    let socket = sock_path();
    let handle = spawn_daemon(&socket, None);
    let mut c = connect(&socket);

    let comm = Communicator::world(&TopologySpec::paper_fig1());
    let session = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let policy = AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast);
    let probe = AllreduceProbe { root: 1, op: ReduceOp::Max, policy, elems: 16384 / 4 };
    let sim = session.simulate_timing(&probe).unwrap();

    let req = JsonObj::new()
        .str("cmd", "simulate")
        .str("spec", "fig1")
        .str("op", "max")
        .num_usize("bytes", 16384)
        .num_usize("root", 1)
        .str("policy", "rb")
        .render();
    let doc = c.request(&req).unwrap();
    let wire_bits = doc.get("makespan_us").and_then(|v| v.as_f64()).unwrap().to_bits();
    assert_eq!(wire_bits, sim.makespan_us.to_bits(), "daemon == library, bit for bit");
    assert_eq!(u64_field(&doc, "wan_msgs"), sim.wan_messages());
    assert_eq!(str_field(&doc, "policy"), "rb");
    drop(c);
    shutdown(&socket, handle);
}

#[test]
fn tune_then_resolve_round_trip() {
    let socket = sock_path();
    let handle = spawn_daemon(&socket, None);
    let mut c = connect(&socket);

    let tune = JsonObj::new()
        .str("cmd", "tune")
        .str("spec", "fig1")
        .str("kind", "composition")
        .str("mode", "exhaustive")
        .num_usize("bytes", 65536)
        .render();
    let verdict = c.request(&tune).unwrap();
    assert_eq!(str_field(&verdict, "source"), "tuned");
    assert!(u64_field(&verdict, "probes") >= 2, "a composition sweep probes candidates");

    let resolve =
        JsonObj::new().str("cmd", "resolve").str("spec", "fig1").num_usize("bytes", 65536);
    let doc = c.request(&resolve.render()).unwrap();
    assert_eq!(str_field(&doc, "policy"), str_field(&verdict, "policy"));
    assert_eq!(doc.get("exact").and_then(|v| v.as_bool()), Some(true));

    // A size the tuner never saw resolves inexactly (nearest verdict).
    let near = JsonObj::new().str("cmd", "resolve").str("spec", "fig1").num_usize("bytes", 128);
    let doc = c.request(&near.render()).unwrap();
    assert_eq!(doc.get("exact").and_then(|v| v.as_bool()), Some(false));

    // The store's verdict also backs `allreduce` timing requests.
    let all =
        JsonObj::new().str("cmd", "allreduce").str("spec", "fig1").num_usize("bytes", 65536);
    let doc = c.request(&all.render()).unwrap();
    assert_eq!(str_field(&doc, "policy"), str_field(&verdict, "policy"));

    // Stats reflect the shared context the requests routed through.
    let stats = c.request(&JsonObj::new().str("cmd", "stats").render()).unwrap();
    assert_eq!(u64_field(&stats, "contexts"), 1);
    assert_eq!(u64_field(&stats, "policy_entries"), 1);
    assert!(u64_field(&stats, "plan_misses") >= 1);
    assert!(u64_field(&stats, "requests") >= 5);
    assert_eq!(u64_field(&stats, "threads"), 4);
    assert!(u64_field(&stats, "shards_per_cache") >= 1);
    drop(c);
    shutdown(&socket, handle);
}

#[test]
fn concurrent_tunes_for_distinct_strategies_do_not_coalesce() {
    let socket = sock_path();
    let handle = spawn_daemon(&socket, None);

    // Same topology — same fingerprint — but different strategies name
    // *distinct* contexts with distinct policy stores. A concurrent
    // burst must not coalesce across them: flight keys carry the full
    // context key, so each request leads its own flight and records the
    // verdict in its own store (a fingerprint-only key would hand one
    // strategy a verdict tuned under the other, and leave the
    // follower's store empty — a later resolve would then error).
    let strategies = ["multilevel", "machine"];
    let barrier = Arc::new(Barrier::new(strategies.len()));
    let verdicts: Vec<Value> = strategies
        .iter()
        .map(|s| {
            let socket = socket.clone();
            let barrier = Arc::clone(&barrier);
            let strategy = s.to_string();
            std::thread::spawn(move || {
                let mut c = connect(&socket);
                let req = JsonObj::new()
                    .str("cmd", "tune")
                    .str("spec", "fig1")
                    .str("strategy", &strategy)
                    .num_usize("bytes", 65536)
                    .render();
                barrier.wait();
                c.request(&req).unwrap()
            })
        })
        .collect::<Vec<_>>()
        .into_iter()
        .map(|t| t.join().unwrap())
        .collect();
    for v in &verdicts {
        assert_eq!(str_field(v, "source"), "tuned", "no cross-context coalescing: {v:?}");
    }

    // Each context holds its own verdict: resolve succeeds on both and
    // returns what that strategy's tune produced.
    let mut c = connect(&socket);
    for (s, verdict) in strategies.iter().zip(&verdicts) {
        let resolve = JsonObj::new()
            .str("cmd", "resolve")
            .str("spec", "fig1")
            .str("strategy", s)
            .num_usize("bytes", 65536)
            .render();
        let doc = c.request(&resolve).unwrap();
        assert_eq!(str_field(&doc, "policy"), str_field(verdict, "policy"));
        assert_eq!(doc.get("exact").and_then(|v| v.as_bool()), Some(true));
    }
    let stats = c.request(&JsonObj::new().str("cmd", "stats").render()).unwrap();
    assert_eq!(u64_field(&stats, "contexts"), 2, "one context per strategy");
    drop(c);
    shutdown(&socket, handle);
}

#[test]
fn request_validation_errors() {
    let socket = sock_path();
    let handle = spawn_daemon(&socket, None);
    let mut c = connect(&socket);

    let cases: &[(&str, &str)] = &[
        (r#"{"cmd":"resolve","bytes":65536}"#, "no tuned verdict"),
        (r#"{"cmd":"simulate","bytes":65536}"#, "explicit \"policy\""),
        (r#"{"cmd":"tune","bytes":0}"#, "positive multiple of 4"),
        (r#"{"cmd":"tune","bytes":6}"#, "positive multiple of 4"),
        (r#"{"cmd":"tune"}"#, "integer \"bytes\""),
        (r#"{"cmd":"tune","bytes":65536,"kind":"bogus"}"#, "unknown tune kind"),
        (r#"{"cmd":"tune","bytes":65536,"strategy":"bogus"}"#, "unknown strategy"),
        (r#"{"cmd":"tune","bytes":65536,"spec":"bogus"}"#, "fig1|experiment"),
        (r#"{"cmd":"allreduce","bytes":65536,"op":"xor"}"#, "unknown reduce op"),
        (r#"{"cmd":"allreduce","bytes":65536,"root":999}"#, "out of range"),
        (r#"{"cmd":"allreduce","bytes":65536,"policy":"bogus"}"#, "bogus"),
        (r#"{"cmd":"discover"}"#, "matrix_csv"),
    ];
    for (req, needle) in cases {
        let err = c.request(req).unwrap_err().to_string();
        assert!(err.contains(needle), "{req} -> {err}");
    }
    drop(c);
    shutdown(&socket, handle);
}

#[test]
fn tcp_transport_serves_the_same_protocol() {
    let daemon = Gridd::new(GriddConfig {
        socket: None,
        tcp: Some("127.0.0.1:0".to_string()),
        threads: 2,
        policy_dir: None,
    })
    .unwrap();
    let addr = daemon.tcp_addr().expect("bound TCP listener").to_string();
    let handle = daemon.spawn();
    let target = Target::parse(&addr);
    assert!(matches!(target, Target::Tcp(_)), "host:port parses as TCP");
    let mut c = Client::connect(&target).unwrap();
    let doc = c.request(&JsonObj::new().str("cmd", "ping").render()).unwrap();
    assert_eq!(str_field(&doc, "service"), "gridd");
    let doc = c.request(&JsonObj::new().str("cmd", "shutdown").render()).unwrap();
    assert_eq!(doc.get("stopping").and_then(|v| v.as_bool()), Some(true));
    drop(c);
    handle.join().unwrap();
}

#[test]
fn discover_and_tune_on_a_wire_matrix() {
    let socket = sock_path();
    let handle = spawn_daemon(&socket, None);
    let mut c = connect(&socket);

    let m = synthesize_from_spec(&TopologySpec::paper_fig1(), &presets::paper_grid(), 0.0, 1);
    let local = infer_clustering(&m, DEFAULT_PROBE_BYTES).unwrap();
    let csv = m.to_tacos_csv();

    let doc = c
        .request(&JsonObj::new().str("cmd", "discover").str("matrix_csv", &csv).render())
        .unwrap();
    assert_eq!(u64_field(&doc, "n_ranks") as usize, local.clustering.n_ranks());
    assert_eq!(u64_field(&doc, "n_levels") as usize, local.clustering.n_levels());
    let per_level = doc.get("clusters_per_level").and_then(|v| v.as_array()).unwrap();
    assert_eq!(per_level.len(), local.clustering.n_levels());
    for (l, v) in per_level.iter().enumerate() {
        assert_eq!(v.as_u64().unwrap() as usize, local.clustering.clusters_at(l).len());
    }

    // The same matrix then names a tuning context: tune + resolve route
    // through a `matrix:<fingerprint>` context, not a named spec.
    let tune = JsonObj::new()
        .str("cmd", "tune")
        .str("matrix_csv", &csv)
        .num_usize("bytes", 65536)
        .render();
    let verdict = c.request(&tune).unwrap();
    assert_eq!(str_field(&verdict, "source"), "tuned");
    let resolve = JsonObj::new()
        .str("cmd", "resolve")
        .str("matrix_csv", &csv)
        .num_usize("bytes", 65536)
        .render();
    let doc = c.request(&resolve).unwrap();
    assert_eq!(str_field(&doc, "policy"), str_field(&verdict, "policy"));
    assert_eq!(str_field(&doc, "fingerprint"), str_field(&verdict, "fingerprint"));
    drop(c);
    shutdown(&socket, handle);
}

#[test]
fn persisted_tables_carry_checkable_provenance() {
    let dir = std::env::temp_dir().join(format!("gridd_svc_persist_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let dir = dir.to_string_lossy().into_owned();
    let socket = sock_path();
    let handle = spawn_daemon(&socket, Some(dir.clone()));
    let mut c = connect(&socket);

    let tune = JsonObj::new()
        .str("cmd", "tune")
        .str("spec", "fig1")
        .num_usize("bytes", 4096)
        .render();
    let verdict = c.request(&tune).unwrap();
    let fp = str_field(&verdict, "fingerprint").to_string();
    drop(c);
    shutdown(&socket, handle);

    let path = format!("{dir}/policy_{fp}_multilevel.json");
    let table = PolicyTable::load(&path).expect("write-back landed");
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    let session = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    table.provenance().check_matches(&session.provenance()).unwrap();
    assert_eq!(table.len(), 1);
    let best = table.best_for(ReduceOp::Sum, 4096).expect("the tuned point is present");
    assert_eq!(
        gridcollect::session::policy_to_token(best),
        str_field(&verdict, "policy"),
        "the persisted verdict is the wire verdict"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
