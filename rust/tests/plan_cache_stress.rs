//! Contention stress for the sharded [`PlanCache`] (ISSUE 10 satellite):
//! N threads hammering overlapping keys must agree on `Arc` identity
//! (one plan instance per key, ever) and leave the cache-local counters
//! exactly consistent — `hits + misses == lookups` and `misses == len`
//! even when builders race, because the miss is counted on the actual
//! insert. Cache-local counters are asserted exactly; the *global*
//! stage counters are never asserted here (other tests share them).

use gridcollect::netsim::ReduceOp;
use gridcollect::plan::cache::DEFAULT_SHARDS;
use gridcollect::plan::{OpKind, PlanCache, PlanKey};
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::{LevelPolicy, Strategy};
use std::sync::{Arc, Barrier};

fn key(comm: &Communicator, op: OpKind, root: usize) -> PlanKey {
    PlanKey {
        comm_epoch: comm.epoch(),
        strategy: Strategy::Multilevel,
        policy: LevelPolicy::paper(),
        root,
        op,
        segments: 1,
    }
}

#[test]
fn contended_lookups_share_one_plan_per_key_with_exact_counters() {
    const THREADS: usize = 8;
    const ROUNDS: usize = 10;
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let roots = comm.size().min(4);
    let cache = Arc::new(PlanCache::new());
    assert_eq!(cache.n_shards(), DEFAULT_SHARDS);

    let barrier = Arc::new(Barrier::new(THREADS));
    let workers: Vec<_> = (0..THREADS)
        .map(|_| {
            let cache = Arc::clone(&cache);
            let comm = comm.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                let mut witness = None;
                for _ in 0..ROUNDS {
                    for root in 0..roots {
                        let plan =
                            cache.get_or_build(&comm, key(&comm, OpKind::Bcast, root)).unwrap();
                        if root == 0 {
                            witness = Some(plan);
                        }
                    }
                }
                witness.unwrap()
            })
        })
        .collect();
    let witnesses: Vec<_> = workers.into_iter().map(|w| w.join().unwrap()).collect();

    // Every thread holds the *same* allocation for the root-0 plan —
    // racing builders adopted the first insert instead of keeping their
    // own build.
    for w in &witnesses[1..] {
        assert!(Arc::ptr_eq(&witnesses[0], w), "all threads share one plan instance");
    }

    let lookups = (THREADS * ROUNDS * roots) as u64;
    assert_eq!(cache.hits() + cache.misses(), lookups, "every lookup is a hit or a miss");
    assert_eq!(cache.misses(), roots as u64, "one counted miss per distinct key");
    assert_eq!(cache.len(), roots, "one resident plan per distinct key");
    assert_eq!(cache.evictions(), 0, "unbounded caches never evict");
    assert!(cache.footprint_bytes() > 0);
}

#[test]
fn bounded_cache_keeps_the_footprint_within_budget() {
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    let ops = [
        OpKind::Bcast,
        OpKind::Barrier,
        OpKind::Gather,
        OpKind::Scatter,
        OpKind::Reduce(ReduceOp::Sum),
        OpKind::Allgather,
    ];

    // Size the budget from real plans: roomy enough for the largest two,
    // far too small for all six.
    let probe = PlanCache::new();
    let largest = ops
        .iter()
        .map(|&op| probe.get_or_build(&comm, key(&comm, op, 0)).unwrap().footprint_bytes())
        .max()
        .unwrap();
    let cap = largest * 2;

    let cache = PlanCache::with_capacity(cap);
    assert_eq!(cache.capacity(), Some(cap));
    assert_eq!(cache.n_shards(), 1, "LRU needs one recency order");
    for &op in &ops {
        cache.get_or_build(&comm, key(&comm, op, 0)).unwrap();
        assert!(
            cache.footprint_bytes() <= cap || cache.len() == 1,
            "over budget with {} plans resident",
            cache.len()
        );
    }
    assert_eq!(cache.misses(), ops.len() as u64, "every distinct key built once");
    assert_eq!(cache.len() as u64 + cache.evictions(), ops.len() as u64);
    assert!(cache.evictions() >= 1, "six plans cannot fit a two-plan budget");
    assert_eq!(cache.hits(), 0);

    // The just-inserted plan is the MRU and always survives eviction.
    cache.get_or_build(&comm, key(&comm, OpKind::Allgather, 0)).unwrap();
    assert_eq!(cache.hits(), 1, "the MRU plan is still resident");
}

#[test]
fn clear_drops_plans_but_counters_keep_running() {
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    let cache = PlanCache::new();
    cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 0)).unwrap();
    cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 0)).unwrap();
    assert_eq!((cache.hits(), cache.misses()), (1u64, 1u64));
    cache.clear();
    assert!(cache.is_empty());
    assert_eq!(cache.footprint_bytes(), 0);
    assert_eq!((cache.hits(), cache.misses()), (1u64, 1u64), "counters survive clear");
    cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 0)).unwrap();
    assert_eq!(cache.misses(), 2, "a cleared key rebuilds");
}
