//! Acceptance test for the fused Fig. 7 timing application: on a warm
//! session, one sweep point is **exactly one** ghost-mode engine run with
//! **zero** tree builds, **zero** program compiles, **zero** schedule
//! assemblies (the rotation schedule is memoized per session — the PR 3
//! ROADMAP item), **zero** payload-data allocations and **zero** scratch
//! growth (the session-held arena is recycled — the PR 5 item), asserted
//! via the global stage counters in `util::counters`.
//!
//! Like `plan_pipeline.rs`, this is deliberately a single `#[test]` in
//! its own binary: the counters are process-wide and `cargo test` runs
//! tests within a binary concurrently — one test per binary makes the
//! zero/exact-delta assertions race-free.

use gridcollect::model::presets;
use gridcollect::session::GridSession;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::counters;

#[test]
fn warm_fused_point_is_one_ghost_simulation_zero_builds() {
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let params = presets::paper_grid();
    let session = GridSession::new(&comm, params, Strategy::Multilevel);

    // Cold point: builds one bcast plan per root and assembles the
    // rotation schedule exactly once (then memoizes it on the session).
    let before_cold = counters::snapshot();
    let cold = gridcollect::coordinator::run_point_with(&session, 4096).unwrap();
    let cold_delta = counters::snapshot().since(&before_cold);
    assert_eq!(session.plan_cache().len(), comm.size(), "one bcast plan per root");
    assert_eq!(cold_delta.schedule_builds, 1, "rotation assembled exactly once");
    assert_eq!(cold_delta.sim_runs, 1, "even the cold point is ONE simulation");
    assert_eq!(
        cold_delta.payload_allocs,
        0,
        "timing points are ghost runs: no payload data even cold"
    );
    assert!(cold_delta.scratch_allocs >= 1, "the cold point sizes the scratch arena");

    // Warm sweep: three more sizes against the memoized schedule. Plans
    // are payload-size-independent, the schedule is session-resident,
    // ghost registers carry no data, and the scratch arena is recycled —
    // so the whole sweep is three timing-only engine runs and nothing
    // else.
    let before = counters::snapshot();
    let mut last = cold.total_us;
    for bytes in [8192usize, 65536, 262144] {
        let warm = gridcollect::coordinator::run_point_with(&session, bytes).unwrap();
        assert!(warm.total_us > last, "{bytes}: bigger messages take longer");
        last = warm.total_us;
        assert_eq!(warm.wan_msgs, comm.size() as u64, "multilevel: 1 WAN msg per bcast");
    }
    let delta = counters::snapshot().since(&before);
    assert_eq!(delta.tree_builds, 0, "warm fused points must not build trees");
    assert_eq!(delta.program_compiles, 0, "warm fused points must not compile");
    assert_eq!(delta.schedule_builds, 0, "memoized rotation: 1 assembly per session");
    assert_eq!(delta.sim_runs, 3, "each sweep point is ONE simulation");
    assert_eq!(delta.plan_cache_misses, 0, "no plan rebuilt on the warm path");
    assert_eq!(delta.plan_cache_hits, 0, "memoized schedule: no plan-cache lookups");
    assert_eq!(delta.payload_allocs, 0, "ghost sweep allocates no payload data");
    assert_eq!(delta.scratch_allocs, 0, "warm ghost sweep grows no scratch storage");
    assert_eq!(session.plan_cache().misses() as usize, session.plan_cache().len());

    // The fused ghost sweep still reproduces the paper's Fig. 8 ordering.
    let total = |s: Strategy| {
        let sess = GridSession::new(&comm, presets::paper_grid(), s);
        gridcollect::coordinator::run_point_with(&sess, 65536).unwrap().total_us
    };
    let unaware = total(Strategy::Unaware);
    let machine = total(Strategy::TwoLevelMachine);
    let site = total(Strategy::TwoLevelSite);
    let multi = total(Strategy::Multilevel);
    assert!(multi < site && multi < machine, "multilevel fastest");
    assert!(site < unaware && machine < unaware, "topology-aware beats binomial");
}
