//! Acceptance test for the fused Fig. 7 timing application: on a warm
//! plan cache, one sweep point is **exactly one** `netsim::run` with
//! **zero** tree builds and **zero** program compiles, asserted via the
//! global stage counters in `util::counters`.
//!
//! Like `plan_pipeline.rs`, this is deliberately a single `#[test]` in
//! its own binary: the counters are process-wide and `cargo test` runs
//! tests within a binary concurrently — one test per binary makes the
//! zero/exact-delta assertions race-free.

use gridcollect::collectives::CollectiveEngine;
use gridcollect::model::presets;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::counters;

#[test]
fn warm_fused_point_is_one_simulation_zero_builds_zero_compiles() {
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let params = presets::paper_grid();
    let engine = CollectiveEngine::new(&comm, params, Strategy::Multilevel);

    // Cold prime at a different size: plans are payload-size-independent,
    // so this warms every (root, bcast) plan the rotation needs.
    let cold = gridcollect::coordinator::run_point_with(&engine, 4096).unwrap();
    assert_eq!(engine.plan_cache().len(), comm.size(), "one bcast plan per root");

    let before = counters::snapshot();
    let warm = gridcollect::coordinator::run_point_with(&engine, 65536).unwrap();
    let delta = counters::snapshot().since(&before);

    assert_eq!(delta.tree_builds, 0, "warm fused point must not build trees");
    assert_eq!(delta.program_compiles, 0, "warm fused point must not compile");
    assert_eq!(delta.sim_runs, 1, "the whole rotation is ONE simulation");
    assert_eq!(delta.plan_cache_misses, 0, "every plan served warm");
    assert_eq!(delta.plan_cache_hits, comm.size() as u64, "one hit per root");
    assert_eq!(engine.plan_cache().misses() as usize, engine.plan_cache().len());

    // Sanity on the measurements themselves.
    assert!(warm.total_us > cold.total_us, "64 KiB rotation slower than 4 KiB");
    assert_eq!(warm.wan_msgs, comm.size() as u64, "multilevel: 1 WAN msg per bcast");

    // The fused sweep still reproduces the paper's Fig. 8 ordering.
    let total = |s: Strategy| {
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), s);
        gridcollect::coordinator::run_point_with(&e, 65536).unwrap().total_us
    };
    let unaware = total(Strategy::Unaware);
    let machine = total(Strategy::TwoLevelMachine);
    let site = total(Strategy::TwoLevelSite);
    let multi = total(Strategy::Multilevel);
    assert!(multi < site && multi < machine, "multilevel fastest");
    assert!(site < unaware && machine < unaware, "topology-aware beats binomial");
}
