//! Ghost-mode equivalence suite: timing-only execution must be
//! **bitwise identical** to full execution on every timing and
//! accounting field — `finish_us`, `makespan_us`, `msgs_by_sep`,
//! `bytes_by_sep`, `combines`, `mark_times_us` — for every strategy,
//! collective, composition policy, root and boundary swept here. The
//! cost model only reads `n_bytes()`, and the ghost register reproduces
//! the key→length shape exactly; these tests pin that contract.
//!
//! Also pins the ready-queue scheduler against the retained rescan
//! oracle (`netsim::testing::run_rescan` — test-support only since the
//! session refactor), and the boundary tuner's verdict against
//! exhaustive full-mode simulation.
//!
//! Everything here is result-local (no global stage counters), so the
//! tests are safe to run concurrently; the counter-exact contracts live
//! in `tuning_counters.rs` and `fused_timing.rs`.

use gridcollect::collectives::{request, CollectiveEngine};
use gridcollect::coordinator::{rotation_schedule_memo, tuning};
use gridcollect::model::presets;
use gridcollect::netsim::{GhostPayload, Payload, ReduceOp, SimResult};
use gridcollect::plan::{AlgoPolicy, AllreduceAlgo, ChunkOrder, LevelAlgo};
use gridcollect::session::GridSession;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::rng::Rng;

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

fn assert_timing_eq(full: &SimResult, ghost: &SimResult, ctx: &str) {
    assert_eq!(bits(&full.finish_us), bits(&ghost.finish_us), "finish_us {ctx}");
    assert_eq!(
        full.makespan_us.to_bits(),
        ghost.makespan_us.to_bits(),
        "makespan_us {ctx}"
    );
    assert_eq!(full.msgs_by_sep, ghost.msgs_by_sep, "msgs_by_sep {ctx}");
    assert_eq!(full.bytes_by_sep, ghost.bytes_by_sep, "bytes_by_sep {ctx}");
    assert_eq!(full.combines, ghost.combines, "combines {ctx}");
    let full_marks: Vec<(u64, u64)> =
        full.mark_times_us.iter().map(|&(i, t)| (i, t.to_bits())).collect();
    let ghost_marks: Vec<(u64, u64)> =
        ghost.mark_times_us.iter().map(|&(i, t)| (i, t.to_bits())).collect();
    assert_eq!(full_marks, ghost_marks, "mark_times_us {ctx}");
    assert!(ghost.payloads.is_empty(), "ghost mode returns no payloads ({ctx})");
}

fn contributions(rng: &mut Rng, n: usize, len: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| (0..len).map(|_| (rng.next_u64() % 17) as f32 - 8.0).collect())
        .collect()
}

/// The headline property: ghost == full for all 4 strategies ×
/// {bcast, reduce, allreduce under every policy} × several roots ×
/// several payload lengths (including chunk-starving short vectors).
#[test]
fn ghost_equals_full_across_strategies_ops_roots_and_policies() {
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    let n = comm.size();
    let mut rng = Rng::new(0x6b0a57);
    let policies = [
        AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast),
        AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather),
        AlgoPolicy::hybrid(1),
        AlgoPolicy::hybrid(2),
        AlgoPolicy::uniform_level(LevelAlgo::Halving),
        AlgoPolicy::composition(&[LevelAlgo::Halving, LevelAlgo::RsAgRing, LevelAlgo::ReduceBcast])
            .unwrap(),
        AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather)
            .with_chunks(4)
            .with_chunk_order(ChunkOrder::ShortestFirst),
        AlgoPolicy::uniform_level(LevelAlgo::Halving).with_chunks(2),
    ];
    for s in Strategy::ALL {
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), s);
        for &len in &[7usize, 64, 1000] {
            let data = contributions(&mut rng, n, len);
            for &root in &[0usize, 3, n - 1] {
                let ctx = |what: &str| format!("{} root {root} len {len} {what}", s.name());

                let req = request::Bcast { root, data: &data[0] };
                let full = e.run_sim(&req).unwrap();
                let ghost = e.simulate_timing(&req).unwrap();
                assert_timing_eq(&full, &ghost, &ctx("bcast"));

                let req = request::Reduce { root, op: ReduceOp::Sum, contributions: &data };
                let full = e.run_sim(&req).unwrap();
                let ghost = e.simulate_timing(&req).unwrap();
                assert_timing_eq(&full, &ghost, &ctx("reduce"));

                for policy in policies {
                    let req = request::Allreduce {
                        root,
                        op: ReduceOp::Sum,
                        policy,
                        contributions: &data,
                    };
                    let full = e.run_sim(&req).unwrap();
                    let ghost = e.simulate_timing(&req).unwrap();
                    assert_timing_eq(&full, &ghost, &ctx(&policy.name()));
                    // The data-free probe is yet another route to the
                    // same cached plan — same timing again.
                    let probe =
                        request::AllreduceProbe { root, op: ReduceOp::Sum, policy, elems: len };
                    let probed = e.simulate_timing(&probe).unwrap();
                    assert_timing_eq(&full, &probed, &ctx(&format!("probe {}", policy.name())));
                }
            }
        }
    }
}

/// Ghost == full for the fused Fig. 7 rotation schedule — the mark-time
/// (per-segment completion) equality is what the ghost-routed Fig. 8
/// sweep rests on.
#[test]
fn ghost_equals_full_on_the_fused_rotation() {
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    for s in Strategy::ALL {
        let session = GridSession::new(&comm, presets::paper_grid(), s);
        let schedule = rotation_schedule_memo(&session).unwrap();
        let elems = 16384 / 4;
        let mut full_init = vec![Payload::empty(); comm.size()];
        full_init[0] = Payload::single(0, vec![1.0f32; elems]);
        let mut ghost_init = vec![GhostPayload::empty(); comm.size()];
        ghost_init[0] = GhostPayload::single(0, elems);
        let full = session.run_schedule(&schedule, full_init).unwrap();
        let ghost = session.run_schedule_timing(&schedule, ghost_init).unwrap();
        assert_timing_eq(&full, &ghost, s.name());
        assert_eq!(full.mark_times_us.len(), 2 * comm.size());
    }
}

/// The ready-queue scheduler against the retained rescan oracle:
/// bit-identical clocks, accounting AND delivered payloads, across
/// strategies and ops (both run full mode here — this pins the
/// scheduler rewrite, not the register mode).
#[test]
fn ready_queue_scheduler_matches_rescan_oracle() {
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    let n = comm.size();
    let mut rng = Rng::new(0xfeed);
    let cfg = gridcollect::netsim::SimConfig::new(presets::paper_grid());
    let combiner = gridcollect::netsim::NativeCombiner;
    for s in Strategy::ALL {
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), s);
        let data = contributions(&mut rng, n, 50);
        let bc = request::Bcast { root: 2, data: &data[0] };
        let red = request::Reduce { root: 2, op: ReduceOp::Max, contributions: &data };
        let ar = request::Allreduce {
            root: 2,
            op: ReduceOp::Sum,
            policy: AlgoPolicy::hybrid(1),
            contributions: &data,
        };
        let cases: Vec<(&str, &dyn request::OpSpec)> =
            vec![("bcast", &bc), ("reduce", &red), ("allreduce", &ar)];
        for (what, req) in cases {
            let plan = e.plan_for(req.root(), req.op_kind(), req.segments()).unwrap();
            let init = req.encode_init(&comm).unwrap();
            let a = gridcollect::netsim::run(
                comm.clustering(),
                &plan.program,
                init.clone(),
                &cfg,
                &combiner,
            )
            .unwrap();
            let b = gridcollect::netsim::testing::run_rescan(
                comm.clustering(),
                &plan.program,
                init,
                &cfg,
                &combiner,
            )
            .unwrap();
            let ctx = format!("{} {what}", s.name());
            assert_eq!(bits(&a.finish_us), bits(&b.finish_us), "{ctx}");
            assert_eq!(a.msgs_by_sep, b.msgs_by_sep, "{ctx}");
            assert_eq!(a.bytes_by_sep, b.bytes_by_sep, "{ctx}");
            assert_eq!(a.combines, b.combines, "{ctx}");
            assert_eq!(a.payloads, b.payloads, "{ctx}");
        }
    }
}

/// The tuner's chosen boundary really minimizes the *full-mode*
/// simulated makespan on a 3-level topology — the ghost probes stand in
/// for the expensive sweep without changing its verdict.
#[test]
fn tuned_boundary_minimizes_full_mode_makespan() {
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    assert_eq!(comm.clustering().n_levels(), 3, "the paper grid is 3-level");
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let n = comm.size();
    for bytes in [4096usize, 262144] {
        let tuning = tuning::tune_allreduce_boundary(&e, ReduceOp::Sum, bytes).unwrap();
        let data: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32; bytes / 4]).collect();
        let mut best_full = f64::INFINITY;
        let mut argmin = tuning.probes[0].policy;
        for p in &tuning.probes {
            let full = e
                .run_sim(&request::Allreduce {
                    root: 0,
                    op: ReduceOp::Sum,
                    policy: p.policy,
                    contributions: &data,
                })
                .unwrap();
            assert_eq!(
                full.makespan_us.to_bits(),
                p.makespan_us.to_bits(),
                "{} probe == full makespan",
                p.policy.name()
            );
            if full.makespan_us < best_full {
                best_full = full.makespan_us;
                argmin = p.policy;
            }
        }
        assert_eq!(tuning.best, argmin, "{bytes}: tuner picked the true argmin");
        assert_eq!(tuning.best_us.to_bits(), best_full.to_bits(), "{bytes}");
    }
}
