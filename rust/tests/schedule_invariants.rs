//! Schedule-subsystem invariants, for all four strategies of Fig. 8:
//!
//! 1. the fused rotation's simulated `msgs_by_sep` equals the sum of its
//!    segments' static `PlanMeta` counts (and the schedule's aggregated
//!    meta);
//! 2. fused makespan ≤ sum of the separate per-phase makespans (fusion
//!    can only overlap, never serialize more);
//! 3. per-segment completion timestamps are monotone non-decreasing and
//!    end at the fused makespan;
//! 4. tag rebasing never collides: the fused program passes
//!    `Program::validate` (per-channel send/recv balance) and segment
//!    tag budgets are pairwise disjoint.
//!
//! All assertions are cache-local / result-local — nothing here reads
//! the process-global stage counters, so these tests are immune to
//! parallel-test interference.

use gridcollect::coordinator::{rotation_schedule, run_point_separate, run_point_with};
use gridcollect::model::presets;
use gridcollect::netsim::Payload;
use gridcollect::session::GridSession;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;

const BYTES: usize = 16384;

fn engine(comm: &Communicator, s: Strategy) -> GridSession {
    GridSession::new(comm, presets::paper_grid(), s)
}

#[test]
fn fused_message_counts_equal_segment_meta_sums() {
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    for s in Strategy::ALL {
        let e = engine(&comm, s);
        let schedule = rotation_schedule(&e).unwrap();
        let mut init = vec![Payload::empty(); comm.size()];
        init[0] = Payload::single(0, vec![1.0f32; BYTES / 4]);
        let sim = e.run_schedule(&schedule, init).unwrap();
        // aggregated meta is the exact fused accounting
        assert_eq!(sim.msgs_by_sep, schedule.meta().msgs_by_sep, "{}", s.name());
        // and it is precisely the sum over segments
        let mut summed = vec![0u64; sim.msgs_by_sep.len()];
        for seg in schedule.segments() {
            for (acc, &m) in summed.iter_mut().zip(&seg.meta.msgs_by_sep) {
                *acc += m;
            }
        }
        assert_eq!(sim.msgs_by_sep, summed, "{}", s.name());
        // byte prediction holds for the fused run too (bcast payload +
        // zero-byte ack traffic)
        assert_eq!(
            sim.bytes_by_sep,
            schedule.expected_bytes_by_sep(BYTES).unwrap(),
            "{}",
            s.name()
        );
    }
}

#[test]
fn fused_makespan_never_exceeds_sum_of_separate_makespans() {
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    for s in Strategy::ALL {
        let e = engine(&comm, s);
        let fused = run_point_with(&e, BYTES).unwrap();
        let separate = run_point_separate(&e, BYTES).unwrap();
        assert!(
            fused.total_us <= separate.total_us + 1e-6,
            "{}: fused {} > separate {}",
            s.name(),
            fused.total_us,
            separate.total_us
        );
        assert!(fused.total_us > 0.0, "{}", s.name());
        // identical static accounting either way
        assert_eq!(fused.wan_msgs, separate.wan_msgs, "{}", s.name());
        assert_eq!(fused.total_msgs, separate.total_msgs, "{}", s.name());
    }
}

#[test]
fn segment_timestamps_are_monotone_and_end_at_makespan() {
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    for s in Strategy::ALL {
        let e = engine(&comm, s);
        let schedule = rotation_schedule(&e).unwrap();
        let mut init = vec![Payload::empty(); comm.size()];
        init[0] = Payload::single(0, vec![1.0f32; BYTES / 4]);
        let sim = e.run_schedule(&schedule, init).unwrap();
        let t = schedule.segment_completions(&sim).unwrap();
        assert_eq!(t.len(), 2 * comm.size(), "{}", s.name());
        for w in t.windows(2) {
            assert!(w[0] <= w[1], "{}: timestamps regress: {w:?}", s.name());
        }
        assert!(
            (t.last().unwrap() - sim.makespan_us).abs() < 1e-9,
            "{}: last segment must end at the makespan",
            s.name()
        );
        let d = schedule.segment_durations(&sim).unwrap();
        assert!(d.iter().all(|&x| x >= -1e-9), "{}", s.name());
        assert!(
            (d.iter().sum::<f64>() - sim.makespan_us).abs() < 1e-6,
            "{}",
            s.name()
        );
    }
}

#[test]
fn tag_rebasing_never_collides() {
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    for s in Strategy::ALL {
        let e = engine(&comm, s);
        let schedule = rotation_schedule(&e).unwrap();
        // channel balance of the fused program (collisions would break it)
        schedule.program().validate().unwrap();
        // and the allocator never hands out overlapping budgets
        for w in schedule.segments().windows(2) {
            assert!(
                w[0].tags.1 <= w[1].tags.0,
                "{}: overlapping tag budgets {:?} vs {:?}",
                s.name(),
                w[0].tags,
                w[1].tags
            );
        }
    }
}
