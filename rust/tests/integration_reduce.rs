//! Integration: MPI_Reduce — numeric correctness against the serial
//! reference for every operator and strategy, exact integer payloads,
//! and combine-count/message-count invariants.

use gridcollect::collectives::{verify, CollectiveEngine};
use gridcollect::model::presets;
use gridcollect::netsim::ReduceOp;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::rng::Rng;

#[test]
fn all_ops_all_strategies_match_reference() {
    let spec = TopologySpec::paper_fig1();
    let comm = Communicator::world(&spec);
    let mut rng = Rng::new(99);
    let contributions: Vec<Vec<f32>> = (0..comm.size())
        .map(|_| (0..512).map(|_| rng.f32_in(0.5, 2.0)).collect())
        .collect();
    for op in ReduceOp::ALL {
        let expect = verify::ref_reduce(&contributions, op);
        for s in Strategy::ALL {
            let e = CollectiveEngine::new(&comm, presets::paper_grid(), s);
            let out = e.reduce(7, op, &contributions).unwrap();
            let tol = match op {
                ReduceOp::Sum => verify::sum_tolerance(comm.size(), 2.0),
                ReduceOp::Prod => 1e-3, // 20 factors in (0.5, 2.0)
                _ => 0.0, // max/min are exact under any association
            };
            assert!(
                verify::close(&out.data[7], &expect, tol, 1e-5),
                "{} {op:?}",
                s.name()
            );
        }
    }
}

#[test]
fn integer_payloads_are_exact_for_sum() {
    // Integer-valued f32 sums below 2^24 are exact regardless of tree
    // association — lets us assert bitwise equality across strategies.
    let spec = TopologySpec::paper_experiment();
    let comm = Communicator::world(&spec);
    let contributions: Vec<Vec<f32>> = (0..comm.size())
        .map(|r| (0..256).map(|i| ((r * 7 + i) % 100) as f32).collect())
        .collect();
    let expect = verify::ref_reduce(&contributions, ReduceOp::Sum);
    for s in Strategy::ALL {
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), s);
        let out = e.reduce(0, ReduceOp::Sum, &contributions).unwrap();
        assert_eq!(out.data[0], expect, "{}", s.name());
    }
}

#[test]
fn reduce_performs_exactly_n_minus_1_combines() {
    let spec = TopologySpec::uniform(2, 3, 4).unwrap();
    let comm = Communicator::world(&spec);
    let contributions: Vec<Vec<f32>> = (0..comm.size()).map(|_| vec![1.0; 64]).collect();
    for s in Strategy::ALL {
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), s);
        let out = e.reduce(3, ReduceOp::Sum, &contributions).unwrap();
        assert_eq!(out.sim.combines, (comm.size() - 1) as u64, "{}", s.name());
        assert_eq!(out.data[3], vec![comm.size() as f32; 64]);
    }
}

#[test]
fn multilevel_reduce_minimizes_wan_crossings() {
    let spec = TopologySpec::paper_experiment();
    let comm = Communicator::world(&spec);
    // bandwidth-relevant payload; rotation-summed like the Fig. 7 app
    let contributions: Vec<Vec<f32>> = (0..comm.size()).map(|_| vec![2.0; 16384]).collect();
    let multi = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let unaware = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Unaware);
    let m0 = multi.reduce(0, ReduceOp::Max, &contributions).unwrap();
    let u0 = unaware.reduce(0, ReduceOp::Max, &contributions).unwrap();
    assert_eq!(m0.sim.wan_messages(), 1);
    assert!(u0.sim.wan_messages() > 1);
    let sum = |e: &CollectiveEngine| -> f64 {
        (0..comm.size())
            .map(|root| e.reduce(root, ReduceOp::Max, &contributions).unwrap().sim.makespan_us)
            .sum()
    };
    let m = sum(&multi);
    let u = sum(&unaware);
    assert!(m < u, "rotation-summed reduce: multi {m} vs unaware {u}");
}

#[test]
fn reduce_root_rotation_all_roots_correct() {
    let spec = TopologySpec::uniform(2, 2, 3).unwrap();
    let comm = Communicator::world(&spec);
    let contributions: Vec<Vec<f32>> =
        (0..comm.size()).map(|r| vec![r as f32, -(r as f32)]).collect();
    let expect = verify::ref_reduce(&contributions, ReduceOp::Sum);
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    for root in 0..comm.size() {
        let out = e.reduce(root, ReduceOp::Sum, &contributions).unwrap();
        assert_eq!(out.data[root], expect, "root {root}");
    }
}

#[test]
fn special_values_flow_through_reduce() {
    let spec = TopologySpec::paper_fig1();
    let comm = Communicator::world(&spec);
    let mut contributions: Vec<Vec<f32>> =
        (0..comm.size()).map(|_| vec![1.0f32; 8]).collect();
    contributions[13][2] = f32::INFINITY;
    contributions[4][5] = f32::NEG_INFINITY;
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let out = e.reduce(0, ReduceOp::Max, &contributions).unwrap();
    assert!(out.data[0][2].is_infinite() && out.data[0][2] > 0.0);
    assert_eq!(out.data[0][5], 1.0); // max ignores -inf
    let out = e.reduce(0, ReduceOp::Min, &contributions).unwrap();
    assert!(out.data[0][5].is_infinite() && out.data[0][5] < 0.0);
}
