//! Integration: the §4 closed-form model against the simulator (E2) —
//! absolute agreement for the binomial critical path in the regime the
//! model covers, and the asymptotic log2(C) saving for the multilevel
//! approach in the latency-dominated regime.

use gridcollect::analytic::{counts, TwoTier};
use gridcollect::collectives::CollectiveEngine;
use gridcollect::model::presets;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;

fn sim_bcast_us(p: usize, c: usize, bytes: usize, s: Strategy) -> f64 {
    let spec = TopologySpec::uniform(c, 1, p / c).unwrap();
    let comm = Communicator::world(&spec);
    CollectiveEngine::new(&comm, presets::paper_grid(), s)
        .bcast(0, &vec![0.0f32; bytes / 4])
        .unwrap()
        .sim
        .makespan_us
}

#[test]
fn binomial_prediction_within_5_percent() {
    let params = presets::paper_grid();
    let tt = TwoTier { slow: params.per_sep[0], fast: params.per_sep[2] };
    for (p, c) in [(16usize, 2usize), (32, 4), (64, 8), (128, 16)] {
        for bytes in [1024usize, 65536] {
            let predicted = tt.binomial_bcast_us(p, c, bytes);
            let simulated = sim_bcast_us(p, c, bytes, Strategy::Unaware);
            let err = (simulated - predicted).abs() / predicted;
            assert!(
                err < 0.05,
                "P={p} C={c} {bytes}B: predicted {predicted:.0} vs sim {simulated:.0} (err {err:.3})"
            );
        }
    }
}

#[test]
fn multilevel_latency_regime_matches_model() {
    // Small messages: the multilevel prediction (one slow term) must be
    // within 30% (flat-stage overheads accumulate slightly).
    let params = presets::paper_grid();
    let tt = TwoTier { slow: params.per_sep[0], fast: params.per_sep[2] };
    for (p, c) in [(32usize, 4usize), (64, 8)] {
        let bytes = 1024;
        let predicted = tt.multilevel_bcast_us(p, c, bytes);
        let simulated = sim_bcast_us(p, c, bytes, Strategy::Multilevel);
        let err = (simulated - predicted).abs() / predicted;
        assert!(
            err < 0.3,
            "P={p} C={c}: predicted {predicted:.0} vs sim {simulated:.0}"
        );
    }
}

#[test]
fn speedup_grows_toward_log2_c() {
    let mut prev = 1.0;
    for c in [2usize, 4, 8, 16] {
        let p = c * 8;
        let b = sim_bcast_us(p, c, 1024, Strategy::Unaware);
        let m = sim_bcast_us(p, c, 1024, Strategy::Multilevel);
        let speedup = b / m;
        let bound = (c as f64).log2();
        assert!(speedup <= bound * 1.05, "C={c}: speedup {speedup} exceeds log2(C)={bound}");
        assert!(speedup >= prev - 0.05, "C={c}: speedup not monotone");
        prev = speedup;
    }
    assert!(prev > 2.0, "16 clusters should save > 2x, got {prev}");
}

#[test]
fn intercluster_message_counts_match_simulator() {
    for (p, c) in [(16usize, 4usize), (32, 8), (64, 8)] {
        let spec = TopologySpec::uniform(c, 1, p / c).unwrap();
        let comm = Communicator::world(&spec);
        let sim_unaware = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Unaware)
            .bcast(0, &[0.0f32; 16])
            .unwrap()
            .sim;
        assert_eq!(
            sim_unaware.wan_messages() as usize,
            counts::binomial_intercluster(p, c),
            "P={p} C={c} binomial"
        );
        let sim_multi =
            CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel)
                .bcast(0, &[0.0f32; 16])
                .unwrap()
                .sim;
        assert_eq!(
            sim_multi.wan_messages() as usize,
            counts::multilevel_intercluster(c),
            "P={p} C={c} multilevel"
        );
    }
}

#[test]
fn single_cluster_strategies_converge() {
    // C=1: no WAN at all; binomial == multilevel exactly (same tree).
    let spec = TopologySpec::uniform(1, 1, 16).unwrap();
    let comm = Communicator::world(&spec);
    let b = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Unaware)
        .bcast(0, &[0.0f32; 1024])
        .unwrap()
        .sim
        .makespan_us;
    let m = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel)
        .bcast(0, &[0.0f32; 1024])
        .unwrap()
        .sim
        .makespan_us;
    assert!((b - m).abs() < 1e-9);
}
