//! Acceptance test for the sharded engine's allocation discipline
//! (ISSUE 6): after a cold prime, **warm sharded ghost probes are
//! allocation-free** — zero tree builds, zero program compiles, zero
//! plan-cache misses, zero payload allocations and zero scratch growth
//! across every shard worker — and warm sharded data steps allocate
//! only their own encoded inputs. The per-shard arenas, inbox rings and
//! ownership tables all live in the session's recycled scratch pool.
//!
//! Single `#[test]` in its own binary: the counters are process-wide
//! and exact-delta assertions must not race with other tests.

use gridcollect::model::presets;
use gridcollect::netsim::{ExecMode, NativeCombiner, ReduceOp};
use gridcollect::session::GridSession;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::counters;
use std::sync::Arc;

#[test]
fn warm_sharded_runs_build_and_allocate_nothing() {
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let n = comm.size();
    let elems = 65536 / 4;
    let contributions: Vec<Vec<f32>> = (0..n).map(|r| vec![(r % 7) as f32; elems]).collect();

    let session = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel)
        .with_sync_combiner(Arc::new(NativeCombiner))
        .with_exec_mode(ExecMode::Sharded { threads: 4 });

    // Prime: the first ghost step and first data step build the plan
    // once and size both the sequential and per-shard arenas.
    let before_cold = counters::snapshot();
    session.allreduce_timing(ReduceOp::Sum, elems).unwrap();
    let reference = session.allreduce(ReduceOp::Sum, &contributions).unwrap();
    let cold = counters::snapshot().since(&before_cold);
    assert!(cold.tree_builds >= 1, "cold steps build the plan");
    assert!(cold.scratch_allocs >= 1, "cold steps size the shard arenas");

    // Warm sharded ghost probes: pure engine runs, nothing allocated in
    // any shard worker.
    let before = counters::snapshot();
    for _ in 0..5 {
        let sim = session.allreduce_timing(ReduceOp::Sum, elems).unwrap();
        assert!(sim.payloads.is_empty(), "ghost steps return no payloads");
    }
    let ghost = counters::snapshot().since(&before);
    assert_eq!(ghost.tree_builds, 0, "warm sharded ghost steps build no trees");
    assert_eq!(ghost.program_compiles, 0, "warm sharded ghost steps compile nothing");
    assert_eq!(ghost.plan_cache_misses, 0, "plan served from cache");
    assert_eq!(ghost.sim_runs, 5, "one engine run per step, not one per shard");
    assert_eq!(ghost.payload_allocs, 0, "sharded ghost steps allocate no payload data");
    assert_eq!(ghost.scratch_allocs, 0, "no shard arena grows once warm");
    assert_eq!(ghost.schedule_builds, 0);

    // Warm sharded data steps: the only allocations are the steps' own
    // encoded input payloads, pinned outside the shard workers.
    let before = counters::snapshot();
    for _ in 0..5 {
        let out = session.allreduce(ReduceOp::Sum, &contributions).unwrap();
        assert_eq!(out.data, reference.data, "warm sharded results stay bitwise stable");
    }
    let data = counters::snapshot().since(&before);
    assert_eq!(data.tree_builds, 0, "warm sharded data steps build no trees");
    assert_eq!(data.program_compiles, 0, "warm sharded data steps compile nothing");
    assert_eq!(data.plan_cache_misses, 0, "plan served from cache");
    assert_eq!(data.sim_runs, 5, "one engine run per step");
    assert_eq!(data.scratch_allocs, 0, "warm sharded data steps grow no scratch");
    assert!(data.payload_allocs > 0, "data steps do materialize their inputs");

    // The sharded session's answer is the sequential oracle's, bitwise.
    let oracle = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let seq = oracle.allreduce(ReduceOp::Sum, &contributions).unwrap();
    assert_eq!(seq.data, reference.data, "sharded == sequential, bitwise");
    assert_eq!(
        seq.sim.makespan_us.to_bits(),
        reference.sim.makespan_us.to_bits(),
        "sharded makespan == sequential makespan"
    );
}
