//! Tier-2 property tests: topology discovery end to end.
//!
//! Inference on noiseless synthetic matrices must reproduce the exact
//! ground-truth clustering (same `topology_fingerprint`) across hierarchy
//! shapes — flat, 2-level, 3-level asymmetric, 4-level deep — stay robust
//! to ±10% measurement jitter, survive the TACOS CSV round trip, and
//! close the loop: a `PolicyTable` tuned on a *discovered* communicator
//! installs on the matching *hand-specified* session without a
//! provenance mismatch.

use gridcollect::model::{presets, NetworkParams};
use gridcollect::netsim::ReduceOp;
use gridcollect::session::table::topology_fingerprint;
use gridcollect::session::GridSession;
use gridcollect::topology::discover::{
    infer_clustering, spec_from_clustering, synthesize_from_clustering, synthesize_from_spec,
    CostMatrix, DEFAULT_PROBE_BYTES,
};
use gridcollect::topology::{Clustering, Communicator, GroupNode, TopologySpec};
use gridcollect::tree::Strategy;

/// 4-level ground truth: 2 sites x 2 LANs x 2 machines x 3 procs.
fn deep_spec() -> TopologySpec {
    TopologySpec::new(
        "deep",
        GroupNode::group(
            "grid",
            (0..2)
                .map(|s| {
                    GroupNode::group(
                        format!("site{s}"),
                        (0..2)
                            .map(|l| {
                                GroupNode::group(
                                    format!("s{s}lan{l}"),
                                    (0..2)
                                        .map(|m| GroupNode::machine(format!("s{s}l{l}m{m}"), 3))
                                        .collect(),
                                )
                            })
                            .collect(),
                    )
                })
                .collect(),
        ),
    )
    .unwrap()
}

/// 2-level asymmetric ground truth: one interconnect, three SMPs of
/// different widths.
fn smp_spec() -> TopologySpec {
    TopologySpec::new(
        "smps",
        GroupNode::group(
            "interconnect",
            vec![
                GroupNode::machine("smp0", 6),
                GroupNode::machine("smp1", 4),
                GroupNode::machine("smp2", 2),
            ],
        ),
    )
    .unwrap()
}

/// Every ground truth: (tag, clustering, params it is sampled through).
/// The flat case must come from [`Clustering::flat`] directly — a spec
/// always carries the machine level, so no spec is ever 1-level.
fn ground_truths() -> Vec<(&'static str, Clustering, NetworkParams)> {
    vec![
        ("flat", Clustering::flat(12), presets::uniform_lan(1)),
        ("2-level-smps", smp_spec().clustering(), presets::cluster_of_smps()),
        ("3-level-fig1", TopologySpec::paper_fig1().clustering(), presets::paper_grid()),
        ("3-level-exp", TopologySpec::paper_experiment().clustering(), presets::paper_grid()),
        ("4-level-deep", deep_spec().clustering(), presets::deep_grid()),
    ]
}

#[test]
fn noiseless_inference_reproduces_every_ground_truth_exactly() {
    for (tag, truth, params) in ground_truths() {
        let m = synthesize_from_clustering(&truth, &params, tag, 0.0, 1);
        let d = infer_clustering(&m, DEFAULT_PROBE_BYTES).unwrap();
        assert_eq!(d.clustering, truth, "{tag}: clustering mismatch");
        assert_eq!(
            topology_fingerprint(&Communicator::discovered(d.clustering, tag)),
            topology_fingerprint(&Communicator::discovered(truth, "truth")),
            "{tag}: fingerprint mismatch"
        );
    }
}

#[test]
fn discovered_communicator_fingerprints_like_the_spec_world() {
    for spec in [TopologySpec::paper_fig1(), TopologySpec::paper_experiment(), deep_spec()] {
        let params = if spec.n_levels() == 4 {
            presets::deep_grid()
        } else {
            presets::paper_grid()
        };
        let m = synthesize_from_spec(&spec, &params, 0.0, 2);
        let disc = Communicator::from_matrix(&m).unwrap();
        let hand = Communicator::world(&spec);
        assert_eq!(
            topology_fingerprint(&disc),
            topology_fingerprint(&hand),
            "{}: discovered vs hand-specified fingerprint",
            spec.name
        );
    }
}

#[test]
fn ten_percent_jitter_still_recovers_every_hierarchy() {
    for (tag, truth, params) in ground_truths() {
        for seed in 1..=5u64 {
            let m = synthesize_from_clustering(&truth, &params, tag, 0.10, seed);
            let d = infer_clustering(&m, DEFAULT_PROBE_BYTES).unwrap();
            assert_eq!(d.clustering, truth, "{tag} seed {seed}: jitter broke recovery");
        }
    }
}

#[test]
fn tacos_csv_round_trip_preserves_inference() {
    let spec = TopologySpec::paper_experiment();
    let m = synthesize_from_spec(&spec, &presets::paper_grid(), 0.05, 9);
    let path = std::env::temp_dir().join(format!("gridcollect_matrix_{}.csv", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    m.save_tacos_csv(&path).unwrap();
    let loaded = CostMatrix::load_tacos_csv(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    let a = infer_clustering(&m, DEFAULT_PROBE_BYTES).unwrap();
    let b = infer_clustering(&loaded, DEFAULT_PROBE_BYTES).unwrap();
    assert_eq!(a.clustering, b.clustering, "CSV round trip changed the inference");
    assert_eq!(b.clustering, spec.clustering());
}

#[test]
fn emitted_spec_reproduces_the_discovered_clustering() {
    let m = synthesize_from_spec(&deep_spec(), &presets::deep_grid(), 0.0, 1);
    let d = infer_clustering(&m, DEFAULT_PROBE_BYTES).unwrap();
    let spec = spec_from_clustering("rt", &d.clustering).unwrap();
    assert_eq!(spec.clustering(), d.clustering, "--emit-spec round trip");
    assert_eq!(spec.n_procs(), 24);
}

#[test]
fn table_tuned_on_a_discovered_communicator_installs_on_the_hand_specified_one() {
    let spec = TopologySpec::paper_fig1();
    let m = synthesize_from_spec(&spec, &presets::paper_grid(), 0.0, 1);
    let disc = Communicator::from_matrix(&m).unwrap();
    let tuned = GridSession::new(&disc, presets::paper_grid(), Strategy::Multilevel);
    let sizes = [4096usize, 65536];
    let (_, table) = tuned.tune_boundary(ReduceOp::Sum, &sizes).unwrap();

    let path =
        std::env::temp_dir().join(format!("gridcollect_disc_policy_{}.json", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    table.save(&path).unwrap();

    let hand = Communicator::world(&spec);
    let installed = GridSession::new(&hand, presets::paper_grid(), Strategy::Multilevel)
        .with_policy_file(&path);
    let _ = std::fs::remove_file(&path);
    let session = installed.expect("discovered provenance must match the hand-specified session");
    for &bytes in &sizes {
        assert_eq!(
            session.resolve_policy(ReduceOp::Sum, bytes).unwrap(),
            table.best_for(ReduceOp::Sum, bytes).unwrap(),
            "argmin at {bytes} bytes"
        );
    }
}
