//! Acceptance test for the `gridd` daemon's headline mechanism (ISSUE
//! 10): K concurrent identical tune requests coalesce into exactly
//! **one** ghost sweep (singleflight), warm requests run build- and
//! allocation-free, and a restarted daemon starts warm from the
//! persisted policy table (second life answers with zero probes).
//!
//! Single `#[test]` in its own binary: the assertions compare global
//! stage-counter deltas *exactly* against the library tuner, which
//! would race with any other test in the same process.

use gridcollect::coordinator::tuning;
use gridcollect::model::presets;
use gridcollect::netsim::ReduceOp;
use gridcollect::service::{proto::JsonObj, Client, Gridd, GriddConfig, GriddHandle, Target};
use gridcollect::session::{policy_to_token, topology_fingerprint, GridSession, PolicyTable};
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::counters;
use gridcollect::util::json::Value;
use std::sync::{Arc, Barrier};

const BYTES: usize = 65536;
const K: usize = 6;

fn scratch_dir(tag: &str) -> String {
    let dir = std::env::temp_dir().join(format!("gridd_sf_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir.to_string_lossy().into_owned()
}

fn spawn(socket: &str, policy_dir: &str) -> GriddHandle {
    let cfg = GriddConfig {
        socket: Some(socket.to_string()),
        tcp: None,
        threads: 8,
        policy_dir: Some(policy_dir.to_string()),
    };
    // `Gridd::new` binds before `spawn`, so clients can connect (into
    // the listen backlog) as soon as this returns.
    Gridd::new(cfg).unwrap().spawn()
}

fn connect(socket: &str) -> Client {
    Client::connect(&Target::parse(socket)).unwrap()
}

fn tune_request() -> String {
    JsonObj::new().str("cmd", "tune").str("op", "sum").num_usize("bytes", BYTES).render()
}

fn field<'a>(doc: &'a Value, key: &str) -> &'a str {
    doc.get(key).and_then(|v| v.as_str()).unwrap_or_else(|| panic!("missing '{key}' in {doc:?}"))
}

fn shutdown(socket: &str, handle: GriddHandle) {
    let doc = connect(socket).request(&JsonObj::new().str("cmd", "shutdown").render()).unwrap();
    assert_eq!(doc.get("stopping").and_then(|v| v.as_bool()), Some(true));
    handle.join().unwrap();
}

#[test]
fn concurrent_tunes_coalesce_and_restarts_start_warm() {
    // ---- library reference: the exact cost of one boundary sweep ----
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let session = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let before = counters::snapshot();
    let reference = tuning::tune_allreduce_boundary(&session.engine(), ReduceOp::Sum, BYTES)
        .unwrap();
    let lib = counters::snapshot().since(&before);
    assert!(lib.sim_runs >= 2, "a boundary sweep probes several candidates");
    let ref_token = policy_to_token(reference.best);
    let ref_bits = reference.best_us.to_bits();
    let fp_hex = format!("{:016x}", topology_fingerprint(&comm));

    // ---- K concurrent identical tunes = exactly one sweep ----
    let dir = scratch_dir("policies");
    let socket = format!("{dir}/gridd.sock");
    let handle = spawn(&socket, &dir);
    let barrier = Arc::new(Barrier::new(K));
    let before = counters::snapshot();
    let clients: Vec<_> = (0..K)
        .map(|_| {
            let socket = socket.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut c = connect(&socket);
                barrier.wait();
                c.request(&tune_request()).unwrap()
            })
        })
        .collect();
    let docs: Vec<Value> = clients.into_iter().map(|t| t.join().unwrap()).collect();
    let flight = counters::snapshot().since(&before);

    // The counter-enforced singleflight contract: the daemon spent
    // exactly one library sweep on K identical questions.
    assert_eq!(flight.sim_runs, lib.sim_runs, "K concurrent tunes ran exactly one sweep");
    assert_eq!(flight.tree_builds, lib.tree_builds);
    assert_eq!(flight.program_compiles, lib.program_compiles);
    assert_eq!(flight.schedule_builds, lib.schedule_builds);
    assert_eq!(flight.payload_allocs, 0, "ghost sweeps allocate no payload data");

    // Exactly one response was tuned live; the rest shared the verdict
    // (in-flight followers) or read the just-written store.
    let sources: Vec<&str> = docs.iter().map(|d| field(d, "source")).collect();
    assert_eq!(sources.iter().filter(|s| **s == "tuned").count(), 1, "sources: {sources:?}");
    assert!(
        sources.iter().all(|s| matches!(*s, "tuned" | "coalesced" | "table")),
        "sources: {sources:?}"
    );
    for doc in &docs {
        assert_eq!(field(doc, "policy"), ref_token, "daemon verdict == library argmin");
        let bits = doc.get("best_us").and_then(|v| v.as_f64()).unwrap().to_bits();
        assert_eq!(bits, ref_bits, "verdict timing survives the wire bit-exactly");
        assert_eq!(field(doc, "fingerprint"), fp_hex);
        let probes = doc.get("probes").and_then(|v| v.as_u64()).unwrap() as usize;
        match field(doc, "source") {
            "table" => assert_eq!(probes, 0),
            _ => assert_eq!(probes, reference.probes_issued()),
        }
    }

    // ---- an already-tuned point never flies again ----
    let mut warm = connect(&socket);
    let before = counters::snapshot();
    let doc = warm.request(&tune_request()).unwrap();
    let repeat = counters::snapshot().since(&before);
    assert_eq!(field(&doc, "source"), "table");
    assert_eq!(doc.get("probes").and_then(|v| v.as_u64()), Some(0));
    assert_eq!(repeat.sim_runs, 0, "repeat tune runs zero probes");

    // ---- warm timing path: zero builds, zero allocations ----
    // Prime once on this connection (one connection = one pool worker =
    // one scratch arena), then the steady state must be pure engine
    // runs: the tuned plan is already in the context's shared cache.
    let all_req = JsonObj::new().str("cmd", "allreduce").num_usize("bytes", BYTES).render();
    let resolve_req = JsonObj::new().str("cmd", "resolve").num_usize("bytes", BYTES).render();
    let first = warm.request(&all_req).unwrap();
    let first_bits = first.get("makespan_us").and_then(|v| v.as_f64()).unwrap().to_bits();
    assert_eq!(field(&first, "policy"), ref_token, "allreduce resolves the tuned policy");
    let before = counters::snapshot();
    for _ in 0..5 {
        let doc = warm.request(&all_req).unwrap();
        let bits = doc.get("makespan_us").and_then(|v| v.as_f64()).unwrap().to_bits();
        assert_eq!(bits, first_bits, "warm timings stay bitwise stable");
    }
    for _ in 0..3 {
        let doc = warm.request(&resolve_req).unwrap();
        assert_eq!(field(&doc, "policy"), ref_token);
        assert_eq!(doc.get("exact").and_then(|v| v.as_bool()), Some(true));
    }
    let steady = counters::snapshot().since(&before);
    assert_eq!(steady.tree_builds, 0, "warm daemon requests build no trees");
    assert_eq!(steady.program_compiles, 0, "warm daemon requests compile nothing");
    assert_eq!(steady.plan_cache_misses, 0, "the tuned plan is served from the shared cache");
    assert_eq!(steady.payload_allocs, 0, "ghost timing allocates no payload data");
    assert_eq!(steady.scratch_allocs, 0, "the worker's scratch arena is already sized");
    assert_eq!(steady.schedule_builds, 0);
    assert_eq!(steady.sim_runs, 5, "one engine run per allreduce, zero per resolve");
    drop(warm);

    // The library path agrees bitwise with what the daemon served.
    let probe = gridcollect::collectives::request::AllreduceProbe {
        root: 0,
        op: ReduceOp::Sum,
        policy: reference.best,
        elems: BYTES / 4,
    };
    let sim = session.simulate_timing(&probe).unwrap();
    assert_eq!(sim.makespan_us.to_bits(), first_bits, "daemon == library, bit for bit");

    shutdown(&socket, handle);

    // ---- write-back landed as a loadable provenance-stamped table ----
    let persisted = format!("{dir}/policy_{fp_hex}_multilevel.json");
    let table = PolicyTable::load(&persisted).unwrap();
    table.provenance().check_matches(&session.provenance()).unwrap();
    assert_eq!(table.best_for(ReduceOp::Sum, BYTES), Some(reference.best));

    // ---- second life: a restarted daemon starts warm ----
    let socket2 = format!("{dir}/gridd2.sock");
    let handle2 = spawn(&socket2, &dir);
    let mut c = connect(&socket2);
    let before = counters::snapshot();
    let doc = c.request(&tune_request()).unwrap();
    let restarted = counters::snapshot().since(&before);
    assert_eq!(field(&doc, "source"), "table", "restarted daemon serves the persisted verdict");
    assert_eq!(doc.get("probes").and_then(|v| v.as_u64()), Some(0));
    assert_eq!(field(&doc, "policy"), ref_token);
    assert_eq!(doc.get("best_us").and_then(|v| v.as_f64()).unwrap().to_bits(), ref_bits);
    assert_eq!(restarted.sim_runs, 0, "warm restart re-runs zero probes");
    drop(c);
    shutdown(&socket2, handle2);
    let _ = std::fs::remove_dir_all(&dir);
}
