//! Integration: MPI_Barrier semantics (fan-in/fan-out synchronization)
//! and the allreduce composition used by the training driver.

use gridcollect::collectives::{verify, CollectiveEngine};
use gridcollect::model::presets;
use gridcollect::netsim::ReduceOp;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;

#[test]
fn barrier_runs_on_all_strategies_with_2n_minus_2_messages() {
    let spec = TopologySpec::paper_experiment();
    let comm = Communicator::world(&spec);
    for s in Strategy::ALL {
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), s);
        let sim = e.barrier().unwrap();
        assert_eq!(
            sim.msgs_by_sep.iter().sum::<u64>(),
            2 * (comm.size() as u64 - 1),
            "{}",
            s.name()
        );
        assert_eq!(sim.bytes_by_sep.iter().sum::<u64>(), 0);
    }
}

#[test]
fn barrier_completion_after_slowest_entrant() {
    // No rank may exit before every rank has entered: the fan-in must
    // traverse the WAN once before the root releases anyone (>= 1 WAN
    // latency for every rank), and remote-site ranks additionally wait
    // for the fan-out to come back across (>= 2 WAN latencies). The
    // root is rank 0 at SDSC; ranks 16.. are at ANL.
    let spec = TopologySpec::paper_experiment();
    let comm = Communicator::world(&spec);
    let params = presets::paper_grid();
    let wan = params.per_sep[0].latency_us;
    let e = CollectiveEngine::new(&comm, params, Strategy::Multilevel);
    let sim = e.barrier().unwrap();
    for (r, &t) in sim.finish_us.iter().enumerate() {
        assert!(t >= wan * 0.95, "rank {r} exited at {t} before the fan-in crossed the WAN");
        if r >= 16 {
            assert!(
                t >= 2.0 * wan * 0.95,
                "remote rank {r} exited at {t} before the WAN round-trip"
            );
        }
    }
}

#[test]
fn multilevel_barrier_fewer_wan_crossings() {
    // For zero-byte barriers the WAN crossings of a binomial tree overlap
    // (latency only, nothing to serialize), so the *makespan* is close;
    // the multilevel win for barriers is WAN *traffic*: exactly 2
    // crossings (fan-in + fan-out) instead of O(log n) per phase.
    let spec = TopologySpec::paper_experiment();
    let comm = Communicator::world(&spec);
    let multi = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel)
        .barrier()
        .unwrap();
    let unaware = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Unaware)
        .barrier()
        .unwrap();
    assert_eq!(multi.wan_messages(), 2, "fan-in + fan-out each cross once");
    assert!(unaware.wan_messages() > multi.wan_messages());
    assert!(
        multi.makespan_us <= unaware.makespan_us * 1.1,
        "multilevel barrier should not be meaningfully slower: {} vs {}",
        multi.makespan_us,
        unaware.makespan_us
    );
}

#[test]
fn allreduce_matches_reference_everywhere() {
    let spec = TopologySpec::paper_fig1();
    let comm = Communicator::world(&spec);
    let contributions: Vec<Vec<f32>> = (0..comm.size())
        .map(|r| (0..128).map(|i| ((r + i) % 13) as f32).collect())
        .collect();
    let expect = verify::ref_reduce(&contributions, ReduceOp::Sum);
    for s in Strategy::ALL {
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), s);
        let out = e.allreduce(ReduceOp::Sum, &contributions).unwrap();
        for r in 0..comm.size() {
            assert_eq!(out.data[r], expect, "{} rank {r}", s.name());
        }
    }
}

#[test]
fn allreduce_multilevel_uses_two_wan_messages() {
    let spec = TopologySpec::paper_experiment();
    let comm = Communicator::world(&spec);
    let contributions: Vec<Vec<f32>> = (0..comm.size()).map(|_| vec![1.0; 64]).collect();
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let out = e.allreduce(ReduceOp::Sum, &contributions).unwrap();
    assert_eq!(out.sim.wan_messages(), 2, "reduce up + bcast down");
}

#[test]
fn allreduce_is_cheaper_than_reduce_plus_separate_bcast_overheads() {
    // Sanity: composed allreduce time ~= reduce + bcast (no double
    // counting, no lost overlap beyond the sequential composition).
    let spec = TopologySpec::paper_fig1();
    let comm = Communicator::world(&spec);
    let contributions: Vec<Vec<f32>> = (0..comm.size()).map(|_| vec![1.0; 1024]).collect();
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let ar = e.allreduce(ReduceOp::Sum, &contributions).unwrap().sim.makespan_us;
    let red = e.reduce(0, ReduceOp::Sum, &contributions).unwrap().sim.makespan_us;
    let bc = e.bcast(0, &contributions[0]).unwrap().sim.makespan_us;
    assert!(ar <= red + bc + 1.0, "allreduce {ar} vs reduce {red} + bcast {bc}");
    assert!(ar >= red.max(bc), "allreduce can't be faster than either phase");
}
