//! Integration: the §6-extension collectives (allgather, reduce-scatter,
//! all-to-all) and the van de Geijn segmented broadcast, at engine level
//! across strategies and topologies.

use gridcollect::collectives::CollectiveEngine;
use gridcollect::model::presets;
use gridcollect::netsim::ReduceOp;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::rng::Rng;

fn comm() -> Communicator {
    Communicator::world(&TopologySpec::paper_fig1())
}

#[test]
fn allgather_matches_reference_all_strategies() {
    let comm = comm();
    let n = comm.size();
    let contributions: Vec<Vec<f32>> =
        (0..n).map(|r| vec![r as f32, (r * r) as f32]).collect();
    let expect: Vec<f32> = contributions.iter().flatten().copied().collect();
    for s in Strategy::ALL {
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), s);
        let out = e.allgather(&contributions).unwrap();
        for r in 0..n {
            assert_eq!(out.data[r], expect, "{} rank {r}", s.name());
        }
    }
}

#[test]
fn allgather_multilevel_two_wan_crossings() {
    let comm = comm();
    let contributions: Vec<Vec<f32>> = (0..comm.size()).map(|_| vec![1.0; 64]).collect();
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let out = e.allgather(&contributions).unwrap();
    assert_eq!(out.sim.wan_messages(), 2, "up once, down once");
}

#[test]
fn reduce_scatter_matches_reference() {
    let comm = comm();
    let n = comm.size();
    let mut rng = Rng::new(7);
    // contributions[r][q] = segment rank r contributes toward q
    let contributions: Vec<Vec<Vec<f32>>> = (0..n)
        .map(|_| (0..n).map(|_| vec![rng.usize_in(0, 10) as f32; 3]).collect())
        .collect();
    for s in Strategy::ALL {
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), s);
        let out = e.reduce_scatter(ReduceOp::Sum, &contributions).unwrap();
        for q in 0..n {
            let mut expect = vec![0.0f32; 3];
            for r in 0..n {
                for (e_i, v) in expect.iter_mut().zip(&contributions[r][q]) {
                    *e_i += v;
                }
            }
            assert_eq!(out.data[q], expect, "{} dst {q}", s.name());
        }
    }
}

#[test]
fn alltoall_personalized_exchange_all_strategies() {
    let spec = TopologySpec::uniform(2, 2, 3).unwrap();
    let comm = Communicator::world(&spec);
    let n = comm.size();
    let sends: Vec<Vec<Vec<f32>>> = (0..n)
        .map(|src| (0..n).map(|dst| vec![(src * 100 + dst) as f32]).collect())
        .collect();
    for s in Strategy::ALL {
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), s);
        let out = e.alltoall(&sends).unwrap();
        for dst in 0..n {
            let expect: Vec<f32> = (0..n).map(|src| (src * 100 + dst) as f32).collect();
            assert_eq!(out.data[dst], expect, "{} dst {dst}", s.name());
        }
    }
}

#[test]
fn alltoall_hierarchical_beats_wan_naive_count() {
    // n ranks across 2 sites: a direct exchange would cross the WAN
    // (n/2)^2 * 2 times; the tree version crosses exactly twice.
    let comm = comm();
    let n = comm.size();
    let sends: Vec<Vec<Vec<f32>>> =
        (0..n).map(|_| (0..n).map(|_| vec![0.5]).collect()).collect();
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let out = e.alltoall(&sends).unwrap();
    assert_eq!(out.sim.wan_messages(), 2);
    let naive = 2 * (n / 2) * (n / 2);
    assert!(out.sim.wan_messages() < naive as u64);
}

#[test]
fn segmented_bcast_correct_and_faster_on_large_messages() {
    let comm = comm();
    let data: Vec<f32> = (0..262144).map(|i| (i % 1000) as f32).collect(); // 1 MiB
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let plain = e.bcast(0, &data).unwrap();
    let seg = e.bcast_segmented(0, &data, 16).unwrap();
    for r in 0..comm.size() {
        assert_eq!(seg.data[r], data, "rank {r}");
    }
    assert!(
        seg.sim.makespan_us < plain.sim.makespan_us,
        "pipelined {} !< plain {}",
        seg.sim.makespan_us,
        plain.sim.makespan_us
    );
}

#[test]
fn segment_tuner_finds_interior_optimum() {
    let comm = comm();
    let data = vec![0.0f32; 262144];
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let candidates = [1usize, 2, 4, 8, 16, 32, 64, 128];
    let (best_s, best_us) = e.tune_bcast_segments(0, &data, &candidates).unwrap();
    assert!(best_s > 1, "pipelining must help at 1 MiB");
    // tuned time beats both extremes
    let one = e.bcast_segmented(0, &data, 1).unwrap().sim.makespan_us;
    let many = e.bcast_segmented(0, &data, 128).unwrap().sim.makespan_us;
    assert!(best_us <= one && best_us <= many);
}

#[test]
fn segmented_bcast_degenerate_cases() {
    let comm = comm();
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    // 1 segment == plain bcast data-wise
    let data = vec![1.0f32, 2.0, 3.0];
    let out = e.bcast_segmented(0, &data, 1).unwrap();
    assert!(out.data.iter().all(|d| d == &data));
    // more segments than elements clamps
    let out = e.bcast_segmented(0, &data, 100).unwrap();
    assert!(out.data.iter().all(|d| d == &data));
}

#[test]
fn extended_input_validation() {
    let comm = comm();
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    assert!(e.allgather(&[vec![1.0]]).is_err());
    assert!(e.reduce_scatter(ReduceOp::Sum, &[vec![vec![1.0]]]).is_err());
    assert!(e.alltoall(&[vec![vec![1.0]]]).is_err());
}
