//! Acceptance test for the composition tuner's probe economy on a deep
//! (4-level) clustering, enforced by the global stage counters:
//!
//! - a **cold beam** sweep issues exactly one ghost engine run per
//!   distinct probe, and strictly fewer probes than the exhaustive
//!   assignment space (the pruning claim, counter-asserted);
//! - a **warm** sweep at the same size performs zero tree builds, zero
//!   program compiles, zero plan-cache misses, zero payload-data
//!   allocations and zero scratch-arena growth — every probe is one
//!   ghost run on a cached plan over recycled working state;
//! - the **exhaustive oracle** run is counter-checked too, so the
//!   beam-vs-oracle probe comparison rests on observed engine runs, not
//!   on the tuner's own bookkeeping.
//!
//! Single `#[test]` in its own binary: the counters are process-wide
//! and exact-delta assertions must not race with other tests.

use gridcollect::collectives::CollectiveEngine;
use gridcollect::coordinator::tuning::{
    tune_allreduce_composition, SearchMode, DEFAULT_BEAM_WIDTH,
};
use gridcollect::model::presets;
use gridcollect::netsim::ReduceOp;
use gridcollect::topology::{Communicator, GroupNode, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::counters;

/// 24 ranks over 4 separation levels (machine / LAN / site / WAN): the
/// smallest topology where `SearchMode::Auto` resolves to beam search.
fn deep_comm() -> Communicator {
    let spec = TopologySpec::new(
        "deep",
        GroupNode::group(
            "grid",
            (0..2)
                .map(|s| {
                    GroupNode::group(
                        format!("site{s}"),
                        (0..2)
                            .map(|l| {
                                GroupNode::group(
                                    format!("s{s}lan{l}"),
                                    (0..2)
                                        .map(|m| GroupNode::machine(format!("s{s}l{l}m{m}"), 3))
                                        .collect(),
                                )
                            })
                            .collect(),
                    )
                })
                .collect(),
        ),
    )
    .unwrap();
    Communicator::world(&spec)
}

#[test]
fn beam_probes_are_counted_and_warm_sweeps_allocate_nothing() {
    let comm = deep_comm();
    assert_eq!(comm.clustering().n_levels(), 4, "beam premise: deep clustering");
    let e = CollectiveEngine::new(&comm, presets::deep_grid(), Strategy::Multilevel);

    // Cold beam sweep (Auto resolves to beam at 4 levels): one ghost
    // engine run per distinct probe, zero payload allocations even cold,
    // and strictly fewer probes than the structural space.
    let before = counters::snapshot();
    let cold = tune_allreduce_composition(&e, ReduceOp::Sum, 65536, SearchMode::Auto).unwrap();
    let cold_delta = counters::snapshot().since(&before);
    assert_eq!(cold.mode, SearchMode::Beam { width: DEFAULT_BEAM_WIDTH });
    assert_eq!(cold_delta.sim_runs as usize, cold.probes_issued, "one engine run per probe");
    assert!(
        cold.probes_issued < cold.exhaustive_space,
        "beam must prune: {} probes vs {} assignments",
        cold.probes_issued,
        cold.exhaustive_space
    );
    assert_eq!(cold_delta.payload_allocs, 0, "probes never materialize payload data");
    assert_eq!(cold_delta.schedule_builds, 0, "plans, not schedules");
    // Only the shared reduce and bcast phase plans build trees; every
    // composition rebases its delivery program onto the cached reduce
    // tree.
    assert_eq!(cold_delta.tree_builds, 2, "reduce + bcast trees only");
    assert_eq!(
        cold_delta.plan_cache_misses as usize,
        cold.probes_issued + 2,
        "one plan per probe, plus the shared reduce and bcast phases"
    );

    // Warm sweep at the same size: scores are deterministic, so the beam
    // revisits the identical candidate set — every probe is one cache
    // hit and one ghost run over recycled scratch, nothing more.
    let before = counters::snapshot();
    let warm = tune_allreduce_composition(&e, ReduceOp::Sum, 65536, SearchMode::Auto).unwrap();
    let warm_delta = counters::snapshot().since(&before);
    assert_eq!(warm.best, cold.best, "warm verdict identical");
    assert_eq!(warm.best_us.to_bits(), cold.best_us.to_bits());
    assert_eq!(warm.probes_issued, cold.probes_issued);
    assert_eq!(warm_delta.tree_builds, 0, "warm probes must not build trees");
    assert_eq!(warm_delta.program_compiles, 0, "warm probes must not compile");
    assert_eq!(warm_delta.plan_cache_misses, 0, "every candidate plan served warm");
    assert_eq!(warm_delta.plan_cache_hits as usize, warm.probes_issued, "one hit per probe");
    assert_eq!(warm_delta.sim_runs as usize, warm.probes_issued, "one engine run per probe");
    assert_eq!(warm_delta.payload_allocs, 0, "zero payload allocations per probe");
    assert_eq!(warm_delta.schedule_builds, 0);
    assert_eq!(
        warm_delta.scratch_allocs,
        0,
        "warm ghost probes must not grow mailbox/wait-vector storage"
    );

    // The exhaustive oracle, counter-checked: observed engine runs agree
    // with its probe count, and the beam's pruning claim holds against
    // observed runs, not just the tuner's bookkeeping.
    let before = counters::snapshot();
    let ex = tune_allreduce_composition(&e, ReduceOp::Sum, 65536, SearchMode::Exhaustive).unwrap();
    let ex_delta = counters::snapshot().since(&before);
    assert_eq!(ex.exhaustive_space, 81, "3^4 structural assignments");
    assert_eq!(ex_delta.sim_runs as usize, ex.probes_issued, "one engine run per probe");
    assert_eq!(ex_delta.payload_allocs, 0);
    assert!(
        (cold_delta.sim_runs as usize) < (ex_delta.sim_runs as usize),
        "beam issued fewer observed engine runs than the oracle"
    );
    // The beam explores a subset, so it can never beat the oracle.
    assert!(cold.best_us >= ex.best_us);
}
