//! Acceptance tests for per-level allreduce compositions
//! (`AlgoPolicy::hybrid` and the full `LevelAlgo` vocabulary): bitwise
//! equivalence against the serial reference for every strategy × root ×
//! boundary level and for the whole composition cross product, the WAN
//! message-count claim (reduce+bcast's 2 per WAN edge, not rs+ag's 3),
//! and warm-path plan reuse via cache-local stats. (The exact global
//! zero-build/zero-compile counter assertions live in
//! `rust/tests/plan_pipeline.rs`, the single-test race-free binary.)

use gridcollect::collectives::{verify, CollectiveEngine};
use gridcollect::model::presets;
use gridcollect::netsim::ReduceOp;
use gridcollect::plan::{
    AlgoPolicy, AllreduceAlgo, ChunkOrder, LevelAlgo, OpKind, PlanCache, PlanKey,
};
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::{LevelPolicy, Strategy};

/// Small-integer contributions keep f32 sums exact (far below 2^24), so
/// the tree fold equals the serial reference bit-for-bit regardless of
/// association.
fn int_contributions(n: usize, len: usize) -> Vec<Vec<f32>> {
    (0..n).map(|r| (0..len).map(|i| ((r * 7 + i) % 9) as f32).collect()).collect()
}

#[test]
fn hybrid_bitwise_equals_reference_for_all_strategies_roots_and_boundaries() {
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    let n = comm.size();
    let contributions = int_contributions(n, 37);
    let expect = verify::ref_reduce(&contributions, ReduceOp::Sum);
    for strategy in Strategy::ALL {
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), strategy);
        for root in [0usize, 3, 10, 19] {
            let rb = e
                .allreduce_with(AllreduceAlgo::ReduceBcast, root, ReduceOp::Sum, &contributions)
                .unwrap();
            let rsag = e
                .allreduce_with(
                    AllreduceAlgo::ReduceScatterAllgather,
                    root,
                    ReduceOp::Sum,
                    &contributions,
                )
                .unwrap();
            for boundary in [0usize, 1, 2, 3, 9] {
                let hybrid = e
                    .allreduce_with_policy(
                        AlgoPolicy::hybrid(boundary),
                        root,
                        ReduceOp::Sum,
                        &contributions,
                    )
                    .unwrap();
                for r in 0..n {
                    assert_eq!(
                        hybrid.data[r],
                        expect,
                        "{} root {root} b={boundary} rank {r} vs reference",
                        strategy.name()
                    );
                    assert_eq!(hybrid.data[r], rb.data[r], "vs reduce+bcast");
                    assert_eq!(hybrid.data[r], rsag.data[r], "vs rs+ag");
                }
            }
        }
    }
}

#[test]
fn every_level_algo_composition_bitwise_equals_the_reference() {
    // The full 5^3 vocabulary cross product on the 3-level paper grid:
    // every per-level assignment must deliver the exact uniform-reference
    // vector on every rank — plus chunked-pipelining variants under both
    // schedules, with chunk counts that do not divide the payload evenly.
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    let n = comm.size();
    let contributions = int_contributions(n, 37);
    let expect = verify::ref_reduce(&contributions, ReduceOp::Sum);
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let mut policies: Vec<AlgoPolicy> = Vec::new();
    for a in LevelAlgo::ALL {
        for b in LevelAlgo::ALL {
            for c in LevelAlgo::ALL {
                policies.push(AlgoPolicy::composition(&[a, b, c]).unwrap());
            }
        }
    }
    for algo in LevelAlgo::ALL {
        for chunks in [2usize, 3, 5] {
            for order in ChunkOrder::ALL {
                policies.push(
                    AlgoPolicy::uniform_level(algo).with_chunks(chunks).with_chunk_order(order),
                );
            }
        }
    }
    for policy in policies {
        let out = e.allreduce_with_policy(policy, 0, ReduceOp::Sum, &contributions).unwrap();
        for r in 0..n {
            assert_eq!(out.data[r], expect, "{} rank {r}", policy.name());
        }
    }
}

#[test]
fn hybrid_wan_messages_match_reduce_bcast_not_rsag() {
    // Static claim, checked on PlanMeta (payload-independent) and
    // confirmed by the simulation counts.
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let cache = PlanCache::new();
    let key = |op: OpKind| PlanKey {
        comm_epoch: comm.epoch(),
        strategy: Strategy::Multilevel,
        policy: LevelPolicy::paper(),
        root: 0,
        op,
        segments: 1,
    };
    let rb = cache
        .get_or_build(
            &comm,
            key(OpKind::Allreduce(
                ReduceOp::Sum,
                AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast),
            )),
        )
        .unwrap();
    let rsag = cache
        .get_or_build(
            &comm,
            key(OpKind::Allreduce(
                ReduceOp::Sum,
                AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather),
            )),
        )
        .unwrap();
    for boundary in [1usize, 2] {
        let hybrid = cache
            .get_or_build(
                &comm,
                key(OpKind::Allreduce(ReduceOp::Sum, AlgoPolicy::hybrid(boundary))),
            )
            .unwrap();
        assert_eq!(
            hybrid.meta.wan_messages(),
            rb.meta.wan_messages(),
            "b={boundary}: hybrid pays reduce+bcast's WAN price"
        );
        assert!(
            hybrid.meta.wan_messages() < rsag.meta.wan_messages(),
            "b={boundary}: strictly fewer WAN messages than uniform rs+ag"
        );
    }
    // Fig. 4 structure: one WAN edge, crossed once per direction.
    assert_eq!(rb.meta.wan_messages(), 2);
    assert_eq!(rsag.meta.wan_messages(), 3);

    // The simulation agrees with the static meta.
    let n = comm.size();
    let contributions = int_contributions(n, 48);
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let out = e
        .allreduce_with_policy(AlgoPolicy::hybrid(1), 0, ReduceOp::Sum, &contributions)
        .unwrap();
    assert_eq!(out.sim.wan_messages(), 2);
}

#[test]
fn hybrid_boundary_extremes_degrade_to_uniform_structures() {
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let cache = PlanCache::new();
    let key = |op: OpKind| PlanKey {
        comm_epoch: comm.epoch(),
        strategy: Strategy::Multilevel,
        policy: LevelPolicy::paper(),
        root: 0,
        op,
        segments: 1,
    };
    let rb = cache
        .get_or_build(
            &comm,
            key(OpKind::Allreduce(
                ReduceOp::Sum,
                AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast),
            )),
        )
        .unwrap();
    let rsag = cache
        .get_or_build(
            &comm,
            key(OpKind::Allreduce(
                ReduceOp::Sum,
                AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather),
            )),
        )
        .unwrap();
    let h0 = cache
        .get_or_build(&comm, key(OpKind::Allreduce(ReduceOp::Sum, AlgoPolicy::hybrid(0))))
        .unwrap();
    let h9 = cache
        .get_or_build(&comm, key(OpKind::Allreduce(ReduceOp::Sum, AlgoPolicy::hybrid(9))))
        .unwrap();
    assert_eq!(h0.meta.msgs_by_sep, rsag.meta.msgs_by_sep, "b=0 == uniform rs+ag");
    assert_eq!(h9.meta.msgs_by_sep, rb.meta.msgs_by_sep, "b>=levels == uniform rb");
}

#[test]
fn warm_hybrid_calls_are_pure_cache_hits() {
    // Cache-local stats are race-free under parallel test execution.
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let n = comm.size();
    let contributions = int_contributions(n, 64);
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    e.allreduce_with_policy(AlgoPolicy::hybrid(1), 0, ReduceOp::Sum, &contributions)
        .unwrap();
    // Cold: the hybrid plan + its composed reduce phase.
    assert_eq!(e.plan_cache().misses(), 2, "hybrid + reduce phase");
    assert_eq!(e.plan_cache().hits(), 0);
    for _ in 0..5 {
        e.allreduce_with_policy(AlgoPolicy::hybrid(1), 0, ReduceOp::Sum, &contributions)
            .unwrap();
    }
    assert_eq!(e.plan_cache().misses(), 2, "no warm rebuilds");
    assert_eq!(e.plan_cache().hits(), 5, "one hit per warm call");
}
