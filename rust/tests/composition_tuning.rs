//! Acceptance suite for the per-level composition tuner: on every
//! small (≤ 3 separation levels) topology the beam search must pick the
//! **same argmin as the exhaustive sweep** (the differential oracle),
//! the exhaustive verdict must minimize the *full-mode* simulated
//! makespan, and the composition space — a strict superset of the
//! boundary-hybrid family — must never lose to the boundary tuner.
//!
//! Everything here is result-local (no global stage counters), so the
//! tests run concurrently; the probe-economy counter contract lives in
//! `composition_counters.rs`, the single-test race-free binary.

use gridcollect::collectives::{request, CollectiveEngine};
use gridcollect::coordinator::tuning::{
    tune_allreduce_boundary, tune_allreduce_composition, CompositionTuning, SearchMode,
    DEFAULT_BEAM_WIDTH,
};
use gridcollect::model::presets;
use gridcollect::netsim::ReduceOp;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;

fn tune(e: &CollectiveEngine, bytes: usize, mode: SearchMode) -> CompositionTuning {
    tune_allreduce_composition(e, ReduceOp::Sum, bytes, mode).unwrap()
}

#[test]
fn beam_argmin_equals_exhaustive_argmin_on_small_topologies() {
    for spec in [
        TopologySpec::paper_fig1(),
        TopologySpec::paper_experiment(),
        TopologySpec::uniform(2, 2, 2).unwrap(),
    ] {
        let comm = Communicator::world(&spec);
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
        assert!(comm.clustering().n_levels() <= 3, "{}: small-topology premise", comm.name());
        for bytes in [4096usize, 65536, 1 << 20] {
            let ex = tune(&e, bytes, SearchMode::Exhaustive);
            let beam = tune(&e, bytes, SearchMode::Beam { width: DEFAULT_BEAM_WIDTH });
            let auto = tune(&e, bytes, SearchMode::Auto);
            let ctx = format!("{} {bytes}B", comm.name());
            assert_eq!(ex.best, beam.best, "{ctx}: beam argmin == exhaustive argmin");
            assert_eq!(ex.best_us.to_bits(), beam.best_us.to_bits(), "{ctx}: same makespan");
            assert_eq!(auto.mode, SearchMode::Exhaustive, "{ctx}: Auto is exhaustive at <= 3");
            assert_eq!(auto.best, ex.best, "{ctx}: Auto == exhaustive");
            // Width 9 carries every 2-level prefix, so the two sweeps
            // probe the identical candidate set — not just agree on the
            // winner.
            assert_eq!(ex.probes_issued, beam.probes_issued, "{ctx}: identical probe sets");
        }
    }
}

#[test]
fn exhaustive_verdict_minimizes_full_mode_makespan() {
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let n = comm.size();
    for bytes in [4096usize, 262144] {
        let tuning = tune(&e, bytes, SearchMode::Exhaustive);
        let data: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32; bytes / 4]).collect();
        let mut best_full = f64::INFINITY;
        let mut argmin = tuning.probes[0].policy;
        for p in &tuning.probes {
            let full = e
                .run_sim(&request::Allreduce {
                    root: 0,
                    op: ReduceOp::Sum,
                    policy: p.policy,
                    contributions: &data,
                })
                .unwrap();
            assert_eq!(
                full.makespan_us.to_bits(),
                p.makespan_us.to_bits(),
                "{} ghost probe == full makespan",
                p.policy.name()
            );
            if full.makespan_us < best_full {
                best_full = full.makespan_us;
                argmin = p.policy;
            }
        }
        assert_eq!(tuning.best, argmin, "{bytes}: tuner picked the true argmin");
        assert_eq!(tuning.best_us.to_bits(), best_full.to_bits(), "{bytes}");
    }
}

#[test]
fn composition_space_never_loses_to_the_boundary_tuner() {
    // Every boundary candidate (two uniforms + the hybrid family) is a
    // point in the structural composition space, so the exhaustive
    // composition sweep's minimum can only match or beat the boundary
    // tuner's — at every size.
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    for bytes in [4096usize, 65536, 1 << 20] {
        let boundary = tune_allreduce_boundary(&e, ReduceOp::Sum, bytes).unwrap();
        let comp = tune(&e, bytes, SearchMode::Exhaustive);
        assert!(
            comp.best_us <= boundary.best_us,
            "{bytes}: composition {} us must not lose to boundary {} us",
            comp.best_us,
            boundary.best_us
        );
    }
}

#[test]
fn tuned_composition_survives_the_policy_file_round_trip() {
    // The CLI loop in miniature: tune-composition --save, then resolve
    // through the loaded file and get the identical policy back.
    use gridcollect::session::{GridSession, PolicyTable};
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let session = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let sizes = [4096usize, 65536];
    let (_report, table) = session
        .tune_composition(ReduceOp::Sum, &sizes, SearchMode::Auto)
        .unwrap();
    let file = format!("gridcollect_comp_tuning_{}.json", std::process::id());
    let path = std::env::temp_dir().join(file);
    let path = path.to_str().unwrap().to_string();
    table.save(&path).unwrap();
    let loaded = PolicyTable::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    let tuned = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel)
        .with_policy_table(loaded)
        .unwrap();
    for &bytes in &sizes {
        assert_eq!(
            tuned.resolve_policy(ReduceOp::Sum, bytes).unwrap(),
            table.best_for(ReduceOp::Sum, bytes).unwrap(),
            "{bytes}: file round-trip preserves the tuned composition"
        );
    }
}
