//! Acceptance test for the topology → plan → execute pipeline: warm-path
//! calls for a repeated `(root, op)` perform **zero tree builds and zero
//! program compiles**, asserted via the global build/compile counters in
//! `util::counters`.
//!
//! This is deliberately a single `#[test]` in its own binary: the
//! counters are process-wide, and `cargo test` runs tests within a
//! binary concurrently — one test per binary makes the zero-delta
//! assertions race-free.

use gridcollect::model::presets;
use gridcollect::netsim::ReduceOp;
use gridcollect::plan::{AlgoPolicy, AllreduceAlgo};
use gridcollect::session::GridSession;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::counters;

#[test]
fn warm_path_performs_zero_tree_builds_and_zero_program_compiles() {
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let e = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let n = comm.size();
    let data = vec![1.0f32; 256];
    let contributions: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32; 256]).collect();

    // Cold calls: one per (root, op) — these must build.
    let before_cold = counters::snapshot();
    e.bcast(0, &data).unwrap();
    e.reduce(0, ReduceOp::Sum, &contributions).unwrap();
    e.allreduce(ReduceOp::Sum, &contributions).unwrap();
    e.allreduce_with(AllreduceAlgo::ReduceScatterAllgather, 0, ReduceOp::Sum, &contributions)
        .unwrap();
    e.barrier().unwrap();
    let cold = counters::snapshot().since(&before_cold);
    assert!(cold.tree_builds >= 1, "cold path must build trees");
    assert!(cold.program_compiles >= 1, "cold path must compile programs");
    // Both allreduces composed cached phases rather than rebuilding:
    // bcast+reduce+barrier = 3 trees. Reduce+bcast concatenates its two
    // cached plans; rs+ag rebases a freshly compiled delivery program
    // onto the cached reduce tree.
    assert_eq!(cold.tree_builds, 3, "allreduces must reuse cached phase trees");
    assert_eq!(cold.plan_cache_misses, 5, "five distinct plans");
    assert_eq!(cold.plan_cache_hits, 3, "rb served both phases warm, rs+ag its reduce phase");

    // Warm calls: identical (root, op) tuples, many times over.
    let before_warm = counters::snapshot();
    for _ in 0..10 {
        e.bcast(0, &data).unwrap();
        e.reduce(0, ReduceOp::Sum, &contributions).unwrap();
        e.allreduce(ReduceOp::Sum, &contributions).unwrap();
        e.allreduce_with(
            AllreduceAlgo::ReduceScatterAllgather,
            0,
            ReduceOp::Sum,
            &contributions,
        )
        .unwrap();
        e.barrier().unwrap();
    }
    let warm = counters::snapshot().since(&before_warm);
    assert_eq!(warm.tree_builds, 0, "warm path must never build a tree");
    assert_eq!(warm.program_compiles, 0, "warm path must never compile a program");
    assert_eq!(warm.plan_cache_misses, 0, "every warm call is a cache hit");
    assert_eq!(warm.plan_cache_hits, 50, "10 rounds x 5 ops");
    // The session recycles the engine scratch arena across its engine
    // views: the cold round sized it, the warm rounds grow nothing.
    assert_eq!(warm.scratch_allocs, 0, "warm path must never grow the scratch arena");

    // Hybrid allreduce, cold: composes the *cached* reduce phase with a
    // freshly compiled per-level delivery program — zero new tree builds,
    // exactly one compile, one plan-cache miss (the hybrid plan itself).
    let before_hybrid = counters::snapshot();
    e.allreduce_with_policy(AlgoPolicy::hybrid(1), 0, ReduceOp::Sum, &contributions)
        .unwrap();
    let cold_h = counters::snapshot().since(&before_hybrid);
    assert_eq!(cold_h.tree_builds, 0, "hybrid reuses the cached reduce tree");
    assert_eq!(cold_h.program_compiles, 1, "only the delivery phase compiles");
    assert_eq!(cold_h.plan_cache_misses, 1, "the hybrid plan itself");
    assert_eq!(cold_h.plan_cache_hits, 1, "reduce phase served warm");

    // Hybrid allreduce, warm: pure cache hits — zero builds, zero
    // compiles (the acceptance criterion for the per-level policy).
    let before_hw = counters::snapshot();
    for _ in 0..10 {
        e.allreduce_with_policy(AlgoPolicy::hybrid(1), 0, ReduceOp::Sum, &contributions)
            .unwrap();
    }
    let warm_h = counters::snapshot().since(&before_hw);
    assert_eq!(warm_h.tree_builds, 0, "warm hybrid must never build a tree");
    assert_eq!(warm_h.program_compiles, 0, "warm hybrid must never compile");
    assert_eq!(warm_h.plan_cache_misses, 0);
    assert_eq!(warm_h.plan_cache_hits, 10);

    // Results stay correct on the warm path.
    let out = e.allreduce(ReduceOp::Sum, &contributions).unwrap();
    let expect: Vec<f32> = vec![(0..n).map(|r| r as f32).sum(); 256];
    for r in 0..n {
        assert_eq!(out.data[r], expect, "rank {r}");
    }
}
