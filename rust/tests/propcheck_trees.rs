//! Property tests (via the in-repo propcheck kit) over randomly generated
//! grid topologies: tree invariants for every strategy, minimal boundary
//! crossings for the multilevel builder, and determinism.

use gridcollect::topology::{Clustering, Communicator, TopologySpec};
use gridcollect::tree::{
    build_multilevel, build_strategy_tree, LevelPolicy, Strategy, TreeShape,
};
use gridcollect::util::propcheck::{check, Config};
use gridcollect::util::rng::Rng;

/// Random topology: 1..=4 sites, 1..=3 machines each, 1..=size procs.
fn random_spec(rng: &mut Rng, size: usize) -> TopologySpec {
    let sites = rng.usize_in(1, 5);
    let spec: Vec<Vec<usize>> = (0..sites)
        .map(|_| {
            let machines = rng.usize_in(1, 4);
            (0..machines).map(|_| rng.usize_in(1, size.max(2))).collect()
        })
        .collect();
    TopologySpec::grid("random", &spec).expect("counts >= 1")
}

fn random_root(rng: &mut Rng, n: usize) -> usize {
    rng.usize_in(0, n)
}

#[test]
fn prop_all_strategies_produce_valid_spanning_trees() {
    check(
        "spanning-tree",
        Config::default().cases(150).max_size(12),
        |rng, size| {
            let spec = random_spec(rng, size);
            let root = random_root(rng, spec.n_procs());
            (spec, root)
        },
        |(spec, root)| {
            let comm = Communicator::world(spec);
            let all: Vec<usize> = (0..comm.size()).collect();
            for s in Strategy::ALL {
                let t = build_strategy_tree(&comm, *root, s, &LevelPolicy::paper())
                    .map_err(|e| format!("{s:?}: {e}"))?;
                t.validate(Some(&all)).map_err(|e| format!("{s:?}: {e}"))?;
                if t.root() != *root {
                    return Err(format!("{s:?}: root moved"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_multilevel_crosses_each_boundary_minimally() {
    check(
        "minimal-crossings",
        Config::default().cases(120).max_size(10),
        |rng, size| {
            let spec = random_spec(rng, size);
            let root = random_root(rng, spec.n_procs());
            (spec, root)
        },
        |(spec, root)| {
            let c = spec.clustering();
            let t = build_multilevel(&c, *root, &LevelPolicy::paper())
                .map_err(|e| e.to_string())?;
            // Level-1 crossings must equal (#level-1 clusters - 1);
            // within each level-1 cluster, level-2 crossings must equal
            // (#level-2 clusters inside it - 1).
            let mut by_sep = vec![0usize; c.n_levels()];
            for (p, ch) in t.edges() {
                by_sep[c.sep(p, ch) - 1] += 1;
            }
            let sites = c.clusters_at(1).len();
            if by_sep[0] != sites - 1 {
                return Err(format!("WAN crossings {} != {}", by_sep[0], sites - 1));
            }
            let mut expect_l2 = 0;
            for site in c.clusters_at(1) {
                let members = c.members(1, site);
                let machines = c.partition(&members, 2).len();
                expect_l2 += machines - 1;
            }
            if by_sep[1] != expect_l2 {
                return Err(format!("LAN crossings {} != {expect_l2}", by_sep[1]));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tree_construction_is_deterministic() {
    check(
        "deterministic-trees",
        Config::default().cases(80).max_size(10),
        |rng, size| {
            let spec = random_spec(rng, size);
            let root = random_root(rng, spec.n_procs());
            let strategy = *rng.choose(&Strategy::ALL);
            (spec, root, strategy)
        },
        |(spec, root, strategy)| {
            let comm = Communicator::world(spec);
            let a = build_strategy_tree(&comm, *root, *strategy, &LevelPolicy::paper())
                .map_err(|e| e.to_string())?;
            let b = build_strategy_tree(&comm, *root, *strategy, &LevelPolicy::paper())
                .map_err(|e| e.to_string())?;
            if a != b {
                return Err("non-deterministic construction".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_shapes_span_arbitrary_member_subsets() {
    check(
        "shape-subsets",
        Config::default().cases(150).max_size(40),
        |rng, size| {
            let cap = size.max(2) + 2;
            // random subset of 1..=cap ranks
            let mut members: Vec<usize> = (0..cap).collect();
            rng.shuffle(&mut members);
            let k = rng.usize_in(1, cap + 1);
            let mut members: Vec<usize> = members.into_iter().take(k).collect();
            members.sort_unstable();
            let root = *rng.choose(&members);
            let shape = *rng.choose(&[
                TreeShape::Binomial,
                TreeShape::Flat,
                TreeShape::Chain,
                TreeShape::Fibonacci(2),
                TreeShape::Fibonacci(5),
                TreeShape::DistanceHalving,
            ]);
            (cap, members, root, shape)
        },
        |(cap, members, root, shape)| {
            let t = shape.build(*cap, members, *root).map_err(|e| e.to_string())?;
            t.validate(Some(members)).map_err(|e| e.to_string())?;
            // every member except the root has a parent within members
            for &m in members {
                if m != *root {
                    let p = t.parent(m).ok_or(format!("member {m} has no parent"))?;
                    if !members.contains(&p) {
                        return Err(format!("parent {p} of {m} outside member set"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_clustering_restrict_preserves_separation_order() {
    // For any subset, sep in the restriction is >= a function of the
    // original: if two ranks were in the same cluster they stay together.
    check(
        "restrict-separation",
        Config::default().cases(120).max_size(10),
        |rng, size| {
            let spec = random_spec(rng, size);
            let n = spec.n_procs();
            let mut ranks: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut ranks);
            let k = rng.usize_in(1, n + 1);
            let mut subset: Vec<usize> = ranks.into_iter().take(k).collect();
            subset.sort_unstable();
            (spec, subset, rng.next_u64())
        },
        |(spec, subset, seed)| {
            let c = spec.clustering();
            let sub = c.restrict(subset).map_err(|e| e.to_string())?;
            let mut rng = Rng::new(*seed);
            for _ in 0..10.min(subset.len() * subset.len()) {
                let i = rng.usize_in(0, subset.len());
                let j = rng.usize_in(0, subset.len());
                if sub.sep(i, j) != c.sep(subset[i], subset[j]) {
                    return Err(format!(
                        "sep changed for ({}, {}): {} vs {}",
                        subset[i],
                        subset[j],
                        sub.sep(i, j),
                        c.sep(subset[i], subset[j])
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_two_level_views_are_coarsenings() {
    check(
        "two-level-view",
        Config::default().cases(100).max_size(10),
        |rng, size| random_spec(rng, size),
        |spec| {
            let c = spec.clustering();
            for l in 1..c.n_levels() {
                let v: Clustering = c.two_level_view(l).map_err(|e| e.to_string())?;
                if v.n_levels() != 2 {
                    return Err("view not 2-level".into());
                }
                // same-cluster at level l implies same-cluster in view
                for a in 0..c.n_ranks() {
                    for b in (a + 1)..c.n_ranks().min(a + 5) {
                        let same_orig = c.sep(a, b) > l;
                        let same_view = v.sep(a, b) > 1;
                        if same_orig != same_view {
                            return Err(format!("view level {l} disagrees for ({a},{b})"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}
