//! Integration: MPI_Gather and MPI_Scatter — segment placement, byte
//! accounting (scatter sends only subtree segments), ragged segments,
//! and round-trips.

use gridcollect::collectives::CollectiveEngine;
use gridcollect::model::presets;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::rng::Rng;

fn ragged_segments(n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|r| {
            let len = rng.usize_in(1, 64);
            (0..len).map(|i| (r * 1000 + i) as f32).collect()
        })
        .collect()
}

#[test]
fn gather_assembles_exact_segments_every_strategy() {
    let spec = TopologySpec::paper_experiment();
    let comm = Communicator::world(&spec);
    let segs = ragged_segments(comm.size(), 1);
    for s in Strategy::ALL {
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), s);
        for root in [0, 17, 47] {
            let out = e.gather(root, &segs).unwrap();
            assert_eq!(out.data, segs, "{} root {root}", s.name());
        }
    }
}

#[test]
fn scatter_delivers_exact_segments_every_strategy() {
    let spec = TopologySpec::paper_experiment();
    let comm = Communicator::world(&spec);
    let segs = ragged_segments(comm.size(), 2);
    for s in Strategy::ALL {
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), s);
        for root in [0, 16, 33] {
            let out = e.scatter(root, &segs).unwrap();
            assert_eq!(out.data, segs, "{} root {root}", s.name());
        }
    }
}

#[test]
fn scatter_gather_roundtrip() {
    let spec = TopologySpec::uniform(3, 2, 4).unwrap();
    let comm = Communicator::world(&spec);
    let segs = ragged_segments(comm.size(), 3);
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let scattered = e.scatter(0, &segs).unwrap();
    let gathered = e.gather(0, &scattered.data).unwrap();
    assert_eq!(gathered.data, segs);
}

#[test]
fn gather_and_scatter_byte_volumes_match() {
    // Both move each segment along the same tree path (up vs down), so
    // total bytes on the wire must be identical for the same tree.
    let spec = TopologySpec::paper_fig1();
    let comm = Communicator::world(&spec);
    let segs: Vec<Vec<f32>> = (0..comm.size()).map(|r| vec![r as f32; 16]).collect();
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let g = e.gather(0, &segs).unwrap();
    let s = e.scatter(0, &segs).unwrap();
    assert_eq!(g.sim.bytes_by_sep, s.sim.bytes_by_sep);
}

#[test]
fn multilevel_gather_crosses_wan_once_with_all_site_bytes() {
    let spec = TopologySpec::paper_experiment();
    let comm = Communicator::world(&spec);
    let per = 64usize; // elements per rank
    let segs: Vec<Vec<f32>> = (0..comm.size()).map(|_| vec![1.0; per]).collect();
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let out = e.gather(0, &segs).unwrap();
    assert_eq!(out.sim.wan_messages(), 1);
    // The single WAN message carries the whole remote site (32 ranks).
    assert_eq!(out.sim.bytes_by_sep[0], (32 * per * 4) as u64);
}

#[test]
fn scatter_wire_bytes_less_than_naive_flat() {
    // Tree scatter sends each segment once per tree edge on its path;
    // the multilevel tree keeps remote segments off the WAN except once.
    let spec = TopologySpec::paper_experiment();
    let comm = Communicator::world(&spec);
    let segs: Vec<Vec<f32>> = (0..comm.size()).map(|_| vec![1.0; 256]).collect();
    let run = |s: Strategy| -> (u64, f64) {
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), s);
        let mut wan_bytes = 0;
        let mut total_us = 0.0;
        for root in 0..comm.size() {
            let out = e.scatter(root, &segs).unwrap();
            wan_bytes += out.sim.bytes_by_sep[0];
            total_us += out.sim.makespan_us;
        }
        (wan_bytes, total_us)
    };
    let (multi_bytes, multi_us) = run(Strategy::Multilevel);
    let (unaware_bytes, unaware_us) = run(Strategy::Unaware);
    assert!(multi_bytes <= unaware_bytes);
    assert!(
        multi_us < unaware_us,
        "rotation-summed scatter: multi {multi_us} vs unaware {unaware_us}"
    );
}

#[test]
fn empty_segments_allowed() {
    let spec = TopologySpec::paper_fig1();
    let comm = Communicator::world(&spec);
    let mut segs: Vec<Vec<f32>> = (0..comm.size()).map(|_| vec![]).collect();
    segs[5] = vec![9.0];
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let g = e.gather(0, &segs).unwrap();
    assert_eq!(g.data, segs);
    let s = e.scatter(0, &segs).unwrap();
    assert_eq!(s.data, segs);
}
