//! Acceptance test for the boundary autotuner's probe cost: a **warm**
//! `tune_allreduce_boundary` sweep performs zero tree builds, zero
//! program compiles, zero schedule assemblies, zero payload-data
//! allocations — and, with the reusable engine scratch arena, **zero
//! mailbox/wait-vector allocations** — each probe is exactly one
//! ghost-mode engine run on a cached plan over recycled working state.
//! This is the "cheap probe" premise (cs/0408034) the tuner is built on,
//! enforced by the global stage counters.
//!
//! Single `#[test]` in its own binary: the counters are process-wide
//! and exact-delta assertions must not race with other tests.

use gridcollect::collectives::CollectiveEngine;
use gridcollect::coordinator::tuning;
use gridcollect::model::presets;
use gridcollect::netsim::ReduceOp;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::counters;

#[test]
fn warm_boundary_tuning_is_pure_ghost_execution() {
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let engine = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let n_candidates = tuning::boundary_candidates(comm.clustering().n_levels()).len() as u64;
    assert!(n_candidates >= 4, "3-level grid: 2 uniforms + 2 hybrids");

    // Cold sweep: builds each candidate's plan once — and nothing else.
    // Even cold, probes are ghost runs: zero payload-data allocations.
    // The engine-held scratch arena grows while the candidates' channel
    // counts ratchet up, but only on this first sweep.
    let before_cold = counters::snapshot();
    let cold = tuning::tune_allreduce_boundary(&engine, ReduceOp::Sum, 65536).unwrap();
    let cold_delta = counters::snapshot().since(&before_cold);
    assert_eq!(cold_delta.sim_runs, n_candidates, "one engine run per probe");
    assert_eq!(cold_delta.payload_allocs, 0, "probes never materialize payload data");
    assert_eq!(cold_delta.schedule_builds, 0, "plans, not schedules");
    assert!(cold_delta.tree_builds >= 1, "cold sweep builds trees");
    assert!(cold_delta.scratch_allocs >= 1, "cold sweep sizes the scratch arena");

    // Warm sweep at a different payload size: plans are size-independent,
    // so every probe is served entirely from cache — and the scratch
    // arena (mailbox channels, wait slots, ready queue, cursors) is
    // recycled, so a warm probe performs zero working-state allocations.
    let before = counters::snapshot();
    let warm = tuning::tune_allreduce_boundary(&engine, ReduceOp::Sum, 1 << 20).unwrap();
    let delta = counters::snapshot().since(&before);
    assert_eq!(delta.tree_builds, 0, "warm probes must not build trees");
    assert_eq!(delta.program_compiles, 0, "warm probes must not compile");
    assert_eq!(delta.plan_cache_misses, 0, "every candidate plan served warm");
    assert_eq!(delta.plan_cache_hits, n_candidates, "one cache hit per probe");
    assert_eq!(delta.sim_runs, n_candidates, "one engine run per probe");
    assert_eq!(delta.payload_allocs, 0, "zero payload allocations per probe");
    assert_eq!(delta.schedule_builds, 0);
    assert_eq!(
        delta.scratch_allocs,
        0,
        "warm ghost probes must not grow mailbox/wait-vector storage"
    );

    // A third sweep (another size again) stays allocation-free too —
    // reuse is steady-state, not a one-off.
    let before = counters::snapshot();
    tuning::tune_allreduce_boundary(&engine, ReduceOp::Sum, 4096).unwrap();
    assert_eq!(counters::snapshot().since(&before).scratch_allocs, 0);

    // Sanity on the verdicts themselves.
    assert_eq!(cold.probes.len(), warm.probes.len());
    assert!(warm.best_us.is_finite() && warm.best_us > 0.0);
    assert!(
        warm.best_us >= cold.best_us,
        "1 MiB allreduce cannot beat 64 KiB: {} vs {}",
        warm.best_us,
        cold.best_us
    );
}
