//! Integration: the RSL front-end (E6) — from script text to running
//! collectives, including the Fig. 5 vs Fig. 6 clustering difference and
//! communicator splitting.

use gridcollect::collectives::CollectiveEngine;
use gridcollect::model::presets;
use gridcollect::topology::{rsl, Communicator};
use gridcollect::tree::Strategy;

#[test]
fn fig6_script_end_to_end() {
    let spec = rsl::topology_from_script(rsl::FIG6_SCRIPT).unwrap();
    assert_eq!(spec.n_procs(), 20);
    let comm = Communicator::world(&spec);
    let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
    let out = e.bcast(0, &[1.0f32; 1024]).unwrap();
    assert_eq!(out.sim.wan_messages(), 1);
    assert_eq!(out.sim.msgs_by_sep[1], 1, "one LAN message between the O2Ks");
}

#[test]
fn lan_id_saves_a_wan_message() {
    // Fig. 5 (no GLOBUS_LAN_ID): the two O2Ks look WAN-separated, so the
    // multilevel broadcast must use 2 "WAN" messages; Fig. 6 needs 1.
    let fig5 = rsl::FIG6_SCRIPT.replace("(GLOBUS_LAN_ID NCSAlan)", "");
    let spec5 = rsl::topology_from_script(&fig5).unwrap();
    let spec6 = rsl::topology_from_script(rsl::FIG6_SCRIPT).unwrap();
    let wan = |spec: &gridcollect::topology::TopologySpec| {
        let comm = Communicator::world(spec);
        CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel)
            .bcast(0, &[0.0f32; 256])
            .unwrap()
            .sim
            .wan_messages()
    };
    assert_eq!(wan(&spec5), 2);
    assert_eq!(wan(&spec6), 1);
}

#[test]
fn machine_info_paths_follow_lan_groups() {
    let spec = rsl::topology_from_script(rsl::FIG6_SCRIPT).unwrap();
    let ms = spec.machines();
    assert_eq!(ms.len(), 3);
    assert_eq!(ms[0].name, "sp.npaci.edu");
    assert_eq!(ms[1].path, vec!["NCSAlan".to_string()]);
    assert_eq!(ms[2].path, vec!["NCSAlan".to_string()]);
}

#[test]
fn split_on_rsl_topology_keeps_collectives_working() {
    let spec = rsl::topology_from_script(rsl::FIG6_SCRIPT).unwrap();
    let comm = Communicator::world(&spec);
    // Split into SDSC (ranks < 10) and NCSA (>= 10).
    let subs = comm.split(|r| (Some(if r < 10 { 0 } else { 1 }), r as i64)).unwrap();
    assert_eq!(subs.len(), 2);
    // NCSA sub-communicator still knows its two machines.
    let ncsa = &subs[1];
    assert_eq!(ncsa.size(), 10);
    let e = CollectiveEngine::new(ncsa, presets::paper_grid(), Strategy::Multilevel);
    let out = e.bcast(0, &[3.0f32; 512]).unwrap();
    // No WAN crossing inside one site; exactly one LAN message between
    // the two O2Ks.
    assert_eq!(out.sim.wan_messages(), 0);
    assert_eq!(out.sim.msgs_by_sep[1], 1);
    assert!(out.data.iter().all(|d| d == &vec![3.0f32; 512]));
}

#[test]
fn four_level_script_runs_collectives() {
    let src = r#"
        ( &(resourceManagerContact="a") (count=3)
          (environment=(GLOBUS_LAN_ID l1)(GLOBUS_SITE_ID east)) )
        ( &(resourceManagerContact="b") (count=3)
          (environment=(GLOBUS_LAN_ID l2)(GLOBUS_SITE_ID east)) )
        ( &(resourceManagerContact="c") (count=3)
          (environment=(GLOBUS_LAN_ID l3)(GLOBUS_SITE_ID west)) )
    "#;
    let spec = rsl::topology_from_script(src).unwrap();
    assert_eq!(spec.n_levels(), 4);
    let comm = Communicator::world(&spec);
    let e = CollectiveEngine::new(&comm, presets::deep_grid(), Strategy::Multilevel);
    let out = e.bcast(0, &[1.0f32; 128]).unwrap();
    assert_eq!(out.sim.wan_messages(), 1, "east->west once");
    assert!(out.data.iter().all(|d| d.len() == 128));
}

#[test]
fn whitespace_and_comment_robustness() {
    let src = "# job header\n\n  ( &(resourceManagerContact=\"x\")(count=2) )\n\t( &(resourceManagerContact=\"y\")(count=2)(environment=(GLOBUS_LAN_ID z)) )";
    let spec = rsl::topology_from_script(src).unwrap();
    assert_eq!(spec.n_procs(), 4);
}
