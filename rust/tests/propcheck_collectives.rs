//! Property tests over the collective engine: random topologies, random
//! payloads, random roots — semantics must match the serial reference for
//! every strategy, and simulation must always terminate (deadlock-free).

use gridcollect::collectives::{verify, CollectiveEngine};
use gridcollect::model::presets;
use gridcollect::netsim::ReduceOp;
use gridcollect::topology::{Communicator, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::propcheck::{check, Config};
use gridcollect::util::rng::Rng;

struct Case {
    spec: TopologySpec,
    root: usize,
    strategy: Strategy,
    op: ReduceOp,
    /// integer-valued contributions (exact under any association)
    contributions: Vec<Vec<f32>>,
}

fn gen_case(rng: &mut Rng, size: usize) -> Case {
    let sites = rng.usize_in(1, 4);
    let layout: Vec<Vec<usize>> = (0..sites)
        .map(|_| {
            let machines = rng.usize_in(1, 4);
            (0..machines).map(|_| rng.usize_in(1, size.max(2))).collect()
        })
        .collect();
    let spec = TopologySpec::grid("prop", &layout).unwrap();
    let n = spec.n_procs();
    let len = rng.usize_in(1, 128);
    let contributions = (0..n)
        .map(|_| (0..len).map(|_| rng.usize_in(0, 8) as f32).collect())
        .collect();
    Case {
        root: rng.usize_in(0, n),
        strategy: *rng.choose(&Strategy::ALL),
        op: *rng.choose(&ReduceOp::ALL),
        spec,
        contributions,
    }
}

#[test]
fn prop_reduce_matches_serial_reference() {
    check(
        "reduce-vs-reference",
        Config::default().cases(120).max_size(8),
        gen_case,
        |case| {
            let comm = Communicator::world(&case.spec);
            let e = CollectiveEngine::new(&comm, presets::paper_grid(), case.strategy);
            let out = e
                .reduce(case.root, case.op, &case.contributions)
                .map_err(|e| e.to_string())?;
            let expect = verify::ref_reduce(&case.contributions, case.op);
            // products of ints in [0,8) can overflow exactness; use tolerance
            let tol = if case.op == ReduceOp::Prod { 1e-3 } else { 0.0 };
            if !verify::close(&out.data[case.root], &expect, tol, 1e-6) {
                return Err(format!(
                    "{:?}/{:?} root {}: mismatch",
                    case.strategy, case.op, case.root
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bcast_delivers_everywhere() {
    check(
        "bcast-delivery",
        Config::default().cases(120).max_size(8),
        gen_case,
        |case| {
            let comm = Communicator::world(&case.spec);
            let e = CollectiveEngine::new(&comm, presets::paper_grid(), case.strategy);
            let data = &case.contributions[0];
            let out = e.bcast(case.root, data).map_err(|e| e.to_string())?;
            for r in 0..comm.size() {
                if &out.data[r] != data {
                    return Err(format!("rank {r} got wrong data"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_allreduce_matches_reference_for_both_algorithms() {
    // Random topology, random strategy, random op, random payload length,
    // random tree root: every rank must receive the reference elementwise
    // reduction, and the two compositions must agree bitwise (identical
    // tree, identical combine association).
    use gridcollect::plan::AllreduceAlgo;
    check(
        "allreduce-vs-reference",
        Config::default().cases(100).max_size(8),
        gen_case,
        |case| {
            let comm = Communicator::world(&case.spec);
            let e = CollectiveEngine::new(&comm, presets::paper_grid(), case.strategy);
            // For Prod, remap payloads to {1, 2}: products stay exact
            // powers of two (no f32 overflow, association-free), so every
            // comparison below is bit-for-bit for every operator.
            let contribs: Vec<Vec<f32>> = if case.op == ReduceOp::Prod {
                case.contributions
                    .iter()
                    .map(|c| c.iter().map(|&v| if v >= 4.0 { 2.0 } else { 1.0 }).collect())
                    .collect()
            } else {
                case.contributions.clone()
            };
            let expect = verify::ref_reduce(&contribs, case.op);
            let rb = e
                .allreduce_with(AllreduceAlgo::ReduceBcast, case.root, case.op, &contribs)
                .map_err(|e| e.to_string())?;
            let rsag = e
                .allreduce_with(
                    AllreduceAlgo::ReduceScatterAllgather,
                    case.root,
                    case.op,
                    &contribs,
                )
                .map_err(|e| e.to_string())?;
            for r in 0..comm.size() {
                if rb.data[r] != expect {
                    return Err(format!(
                        "{:?}/{:?} root {} rank {r}: reduce+bcast mismatch",
                        case.strategy, case.op, case.root
                    ));
                }
                if rsag.data[r] != rb.data[r] {
                    return Err(format!(
                        "{:?}/{:?} root {} rank {r}: compositions disagree bitwise",
                        case.strategy, case.op, case.root
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn allreduce_matches_reference_for_every_root_fig1() {
    // Deterministic complement to the property: all 20 roots x all four
    // strategies x both compositions on the Fig. 1 grid; integer-valued
    // payloads make the comparison bit-for-bit.
    use gridcollect::plan::AllreduceAlgo;
    let comm = Communicator::world(&TopologySpec::paper_fig1());
    let contributions: Vec<Vec<f32>> = (0..comm.size())
        .map(|r| (0..47).map(|i| ((r * 5 + i) % 7) as f32).collect())
        .collect();
    let expect = verify::ref_reduce(&contributions, ReduceOp::Sum);
    for strategy in Strategy::ALL {
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), strategy);
        for root in 0..comm.size() {
            for algo in AllreduceAlgo::ALL {
                let out = e
                    .allreduce_with(algo, root, ReduceOp::Sum, &contributions)
                    .unwrap();
                for r in 0..comm.size() {
                    assert_eq!(
                        out.data[r],
                        expect,
                        "{}/{} root {root} rank {r}",
                        strategy.name(),
                        algo.name()
                    );
                }
            }
        }
    }
}

#[test]
fn prop_gather_scatter_are_inverse_permutations() {
    check(
        "gather-scatter",
        Config::default().cases(100).max_size(8),
        gen_case,
        |case| {
            let comm = Communicator::world(&case.spec);
            let e = CollectiveEngine::new(&comm, presets::paper_grid(), case.strategy);
            let segs = &case.contributions;
            let g = e.gather(case.root, segs).map_err(|e| e.to_string())?;
            if &g.data != segs {
                return Err("gather mismatch".into());
            }
            let s = e.scatter(case.root, segs).map_err(|e| e.to_string())?;
            if &s.data != segs {
                return Err("scatter mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_no_deadlocks_and_message_conservation() {
    check(
        "termination",
        Config::default().cases(150).max_size(8),
        gen_case,
        |case| {
            let comm = Communicator::world(&case.spec);
            let n = comm.size() as u64;
            let e = CollectiveEngine::new(&comm, presets::paper_grid(), case.strategy);
            // barrier: 2(n-1) messages, 0 bytes
            let sim = e.barrier().map_err(|e| format!("barrier: {e}"))?;
            if sim.msgs_by_sep.iter().sum::<u64>() != 2 * (n - 1) {
                return Err("barrier message count".into());
            }
            // bcast: n-1 messages, (n-1)*len*4 bytes
            let len = case.contributions[0].len();
            let out = e
                .bcast(case.root, &case.contributions[0])
                .map_err(|e| format!("bcast: {e}"))?;
            if out.sim.msgs_by_sep.iter().sum::<u64>() != n - 1 {
                return Err("bcast message count".into());
            }
            if out.sim.bytes_by_sep.iter().sum::<u64>() != (n - 1) * (len * 4) as u64 {
                return Err("bcast byte conservation".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_makespan_nonnegative_and_rank_finish_bounded() {
    check(
        "time-sanity",
        Config::default().cases(100).max_size(8),
        gen_case,
        |case| {
            let comm = Communicator::world(&case.spec);
            let e = CollectiveEngine::new(&comm, presets::paper_grid(), case.strategy);
            let out = e.bcast(case.root, &case.contributions[0]).map_err(|e| e.to_string())?;
            if out.sim.makespan_us < 0.0 {
                return Err("negative makespan".into());
            }
            for &f in &out.sim.finish_us {
                if f > out.sim.makespan_us + 1e-9 {
                    return Err("rank finish beyond makespan".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fault_injection_fails_cleanly() {
    // Mutate a valid broadcast program (drop one random action) and run
    // it: the engine must either complete with correct semantics (if the
    // dropped action was redundant — it never is for bcast) or return a
    // clean Deadlock/Sim error naming stuck ranks. It must never panic
    // and never deliver silently-wrong payloads.
    use gridcollect::collectives::programs;
    use gridcollect::netsim::{run, NativeCombiner, Payload, SimConfig};
    use gridcollect::tree::{build_strategy_tree, LevelPolicy};

    check(
        "fault-injection",
        Config::default().cases(120).max_size(8),
        |rng, size| {
            let mut case = gen_case(rng, size);
            case.root = 0;
            let drop_seed = rng.next_u64();
            (case, drop_seed)
        },
        |(case, drop_seed)| {
            let comm = Communicator::world(&case.spec);
            let tree = build_strategy_tree(&comm, 0, case.strategy, &LevelPolicy::paper())
                .map_err(|e| e.to_string())?;
            let mut prog = programs::bcast(&tree, 7).map_err(|e| e.to_string())?;
            // drop one action from a random non-empty rank
            let mut rng = Rng::new(*drop_seed);
            let candidates: Vec<usize> =
                (0..comm.size()).filter(|&r| !prog.actions[r].is_empty()).collect();
            if candidates.is_empty() {
                return Ok(()); // single-rank communicator: nothing to drop
            }
            let victim = *rng.choose(&candidates);
            let idx = rng.usize_in(0, prog.actions[victim].len());
            prog.actions[victim].remove(idx);

            let mut init = vec![Payload::empty(); comm.size()];
            init[0] = Payload::single(0, case.contributions[0].clone());
            let cfg = SimConfig::new(presets::paper_grid());
            match run(comm.clustering(), &prog, init, &cfg, &NativeCombiner) {
                Err(gridcollect::error::Error::Deadlock { stuck_ranks, .. }) => {
                    if stuck_ranks.is_empty() {
                        return Err("deadlock with no stuck ranks".into());
                    }
                    Ok(())
                }
                Err(gridcollect::error::Error::Sim(_)) => Ok(()), // undelivered msg
                Err(e) => Err(format!("unexpected error kind: {e}")),
                Ok(sim) => {
                    // Completing is only legal if every rank still got the
                    // data (dropping a leaf's recv makes it unreachable —
                    // then the mailbox check must have caught it, so a
                    // clean Ok means full delivery).
                    for r in 0..comm.size() {
                        match sim.payloads[r].get(&0) {
                            Some(d) if d == case.contributions[0].as_slice() => {}
                            _ => return Err(format!("silent corruption at rank {r}")),
                        }
                    }
                    Ok(())
                }
            }
        },
    );
}
