//! PLogP-style network parameter fitting (§6: "through parameterized
//! studies of the network, determine optimal packet sizes"; Kielmann et
//! al.). Measures point-to-point costs through the simulator (or any
//! observation source) at a sweep of message sizes and fits per-level
//! `LinkParams` by least squares — the calibration path a deployment
//! would run at bootstrap.

use crate::error::{Error, Result};
use crate::model::{LinkParams, NetworkParams};
use crate::netsim::{run, Merge, NativeCombiner, Payload, Program, SendPart, SimConfig};
use crate::topology::Clustering;
use crate::util::stats::linear_fit;

/// One observation: a `bytes`-sized message between a fixed pair took
/// `us` end-to-end (send start to receive completion).
#[derive(Clone, Copy, Debug)]
pub struct PingObservation {
    pub bytes: usize,
    pub us: f64,
}

/// Fit `(latency_us, bandwidth_mb_s)` from ping observations:
/// `t = (latency + overheads) + bytes / bandwidth` is linear in bytes.
/// The constant term bundles latency + send/recv overhead, exactly what a
/// real PLogP measurement sees; we report it as `latency_us` with zero
/// overheads (an equivalent parameterization).
pub fn fit_link(observations: &[PingObservation]) -> Result<LinkParams> {
    if observations.len() < 2 {
        return Err(Error::Config("fit_link: need >= 2 observations".into()));
    }
    let xs: Vec<f64> = observations.iter().map(|o| o.bytes as f64).collect();
    let ys: Vec<f64> = observations.iter().map(|o| o.us).collect();
    let (intercept, slope) = linear_fit(&xs, &ys);
    if slope <= 0.0 || intercept < 0.0 {
        return Err(Error::Config(format!(
            "fit_link: non-physical fit (intercept {intercept:.3}, slope {slope:.6})"
        )));
    }
    Ok(LinkParams::new(intercept, 1.0 / slope).with_overheads(0.0, 0.0))
}

/// Measure a ping between `src` and `dst` of `bytes` under `params`
/// through the simulation engine (end-to-end: send start at t=0 to recv
/// completion at the receiver).
pub fn measure_ping(
    clustering: &Clustering,
    params: &NetworkParams,
    src: usize,
    dst: usize,
    bytes: usize,
) -> Result<PingObservation> {
    let n = clustering.n_ranks();
    let mut p = Program::new(n);
    p.send(src, dst, 1, SendPart::All);
    p.recv(dst, src, 1, Merge::Replace);
    let mut init = vec![Payload::empty(); n];
    init[src] = Payload::single(src, vec![0.0f32; bytes / 4]);
    let cfg = SimConfig::new(params.clone());
    let sim = run(clustering, &p, init, &cfg, &NativeCombiner)?;
    Ok(PingObservation { bytes, us: sim.finish_us[dst] })
}

/// Full bootstrap calibration: for every separation level present in the
/// clustering, pick one representative pair, sweep message sizes, and fit
/// that level's parameters. Returns fitted params ordered like
/// `NetworkParams::per_sep`.
pub fn calibrate(
    clustering: &Clustering,
    true_params: &NetworkParams,
    sizes: &[usize],
) -> Result<Vec<(usize, LinkParams)>> {
    let n = clustering.n_ranks();
    let mut out = Vec::new();
    for sep in 1..=clustering.n_levels() {
        // find a pair with this separation
        let mut pair = None;
        'outer: for a in 0..n {
            for b in 0..n {
                if a != b && clustering.sep(a, b) == sep {
                    pair = Some((a, b));
                    break 'outer;
                }
            }
        }
        let Some((a, b)) = pair else { continue };
        let mut obs = Vec::with_capacity(sizes.len());
        for &bytes in sizes {
            obs.push(measure_ping(clustering, true_params, a, b, bytes)?);
        }
        out.push((sep, fit_link(&obs)?));
    }
    if out.is_empty() {
        return Err(Error::Config("calibrate: no measurable pairs".into()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::topology::TopologySpec;

    #[test]
    fn fit_recovers_synthetic_line() {
        let obs: Vec<PingObservation> = [1024usize, 4096, 65536, 262144]
            .iter()
            .map(|&b| PingObservation { bytes: b, us: 500.0 + b as f64 / 25.0 })
            .collect();
        let l = fit_link(&obs).unwrap();
        assert!((l.latency_us - 500.0).abs() < 1e-6);
        assert!((l.bandwidth_mb_s - 25.0).abs() < 1e-6);
    }

    #[test]
    fn fit_rejects_degenerate_input() {
        assert!(fit_link(&[PingObservation { bytes: 1, us: 1.0 }]).is_err());
        // negative slope: time decreasing with size
        let obs = [
            PingObservation { bytes: 1000, us: 100.0 },
            PingObservation { bytes: 2000, us: 50.0 },
        ];
        assert!(fit_link(&obs).is_err());
    }

    #[test]
    fn calibration_recovers_preset_parameters() {
        let spec = TopologySpec::paper_fig1();
        let c = spec.clustering();
        let truth = presets::paper_grid();
        let sizes = [1024usize, 8192, 65536, 524288];
        let fitted = calibrate(&c, &truth, &sizes).unwrap();
        assert_eq!(fitted.len(), 3, "three levels measurable on fig1");
        for (sep, l) in fitted {
            let t = truth.at_sep(sep);
            // bandwidth within 2%
            let bw_err = (l.bandwidth_mb_s - t.bandwidth_mb_s).abs() / t.bandwidth_mb_s;
            assert!(bw_err < 0.02, "sep {sep}: bw {} vs {}", l.bandwidth_mb_s, t.bandwidth_mb_s);
            // intercept = latency + send/recv overheads
            let expect_const = t.latency_us + t.send_overhead_us + t.recv_overhead_us;
            let lat_err = (l.latency_us - expect_const).abs() / expect_const;
            assert!(lat_err < 0.02, "sep {sep}: const {} vs {}", l.latency_us, expect_const);
        }
    }

    #[test]
    fn fitted_params_predict_unseen_size() {
        let spec = TopologySpec::paper_fig1();
        let c = spec.clustering();
        let truth = presets::paper_grid();
        let fitted = calibrate(&c, &truth, &[1024, 16384, 131072]).unwrap();
        let (sep, l) = fitted[0]; // WAN
        assert_eq!(sep, 1);
        let true_obs = measure_ping(&c, &truth, 0, 10, 32768).unwrap();
        let predicted = l.p2p_us(32768);
        let err = (predicted - true_obs.us).abs() / true_obs.us;
        assert!(err < 0.02, "predicted {predicted} vs measured {}", true_obs.us);
    }
}
