//! Point-to-point network cost models.
//!
//! The simulator prices a message by the **separation level** of its
//! endpoints (see [`crate::topology::Clustering::sep`]): `sep==1` crosses
//! the slowest boundary (WAN between level-1 clusters), deeper separations
//! use progressively faster channels, and `sep == n_levels` is
//! intra-machine. The per-link cost follows a LogGP-flavored postal model:
//!
//! ```text
//! sender busy   : o_send + bytes/bandwidth        (serialization)
//! wire          : latency
//! receiver busy : o_recv
//! arrival time  : t_send + o_send + bytes/bandwidth + latency
//! ```
//!
//! Endpoint occupancy (not shared-link contention) is modeled — the same
//! assumption the paper's §4 analysis and the postal/LogP literature make.

pub mod fit;
pub mod presets;

/// Cost parameters of one channel class. Times in microseconds; bandwidth
/// in bytes/us (== MB/s).
///
/// `sender_serializes` selects between the two classical injection
/// models: `true` (LogGP-style — the sender's NIC is busy for the whole
/// transfer, appropriate for LAN/shared-memory channels) and `false`
/// (postal-style — the sender is busy only for `o` and transfers to
/// distinct destinations proceed on independent wide-area paths, the
/// assumption the paper's §4 analysis and MagPIe make for WAN links).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkParams {
    pub latency_us: f64,
    pub bandwidth_mb_s: f64,
    pub send_overhead_us: f64,
    pub recv_overhead_us: f64,
    pub sender_serializes: bool,
}

impl LinkParams {
    pub fn new(latency_us: f64, bandwidth_mb_s: f64) -> Self {
        LinkParams {
            latency_us,
            bandwidth_mb_s,
            send_overhead_us: 1.0,
            recv_overhead_us: 1.0,
            sender_serializes: true,
        }
    }

    pub fn with_overheads(mut self, send_us: f64, recv_us: f64) -> Self {
        self.send_overhead_us = send_us;
        self.recv_overhead_us = recv_us;
        self
    }

    /// Postal-style injection: sender busy only for the overhead;
    /// transfers to distinct destinations overlap (independent paths).
    pub fn overlapped(mut self) -> Self {
        self.sender_serializes = false;
        self
    }

    /// Time the sender is occupied injecting `bytes`.
    #[inline]
    pub fn sender_busy_us(&self, bytes: usize) -> f64 {
        if self.sender_serializes {
            self.send_overhead_us + bytes as f64 / self.bandwidth_mb_s
        } else {
            self.send_overhead_us
        }
    }

    /// Delay from send start to availability at the receiver (always
    /// includes the transfer time, whichever injection model is used).
    #[inline]
    pub fn arrival_delay_us(&self, bytes: usize) -> f64 {
        self.send_overhead_us + bytes as f64 / self.bandwidth_mb_s + self.latency_us
    }

    /// One-way point-to-point cost (the `l + N/b` of §4).
    #[inline]
    pub fn p2p_us(&self, bytes: usize) -> f64 {
        self.latency_us + bytes as f64 / self.bandwidth_mb_s
    }
}

/// Per-level channel parameters plus local compute pricing.
#[derive(Clone, Debug)]
pub struct NetworkParams {
    /// `per_sep[s]` prices messages whose endpoints have separation level
    /// `s+1`: index 0 = the slowest (WAN) boundary, the last entry =
    /// intra-machine. Length must equal the clustering's `n_levels()`.
    pub per_sep: Vec<LinkParams>,
    /// Local reduction-combine cost in us per byte (calibrated from the
    /// measured PJRT combiner throughput; see `runtime::combiner`).
    pub combine_us_per_byte: f64,
}

impl NetworkParams {
    pub fn new(per_sep: Vec<LinkParams>) -> Self {
        assert!(!per_sep.is_empty(), "need at least one level");
        NetworkParams { per_sep, combine_us_per_byte: 0.0005 } // ~2 GB/s default
    }

    /// Channel parameters for endpoints at separation `sep` (1-based;
    /// values beyond the table clamp to the fastest/innermost entry, which
    /// lets a deep clustering run against a shallower parameter table).
    #[inline]
    pub fn at_sep(&self, sep: usize) -> &LinkParams {
        debug_assert!(sep >= 1);
        let idx = (sep - 1).min(self.per_sep.len() - 1);
        &self.per_sep[idx]
    }

    pub fn n_levels(&self) -> usize {
        self.per_sep.len()
    }

    /// Combine cost for a payload of `bytes`.
    #[inline]
    pub fn combine_us(&self, bytes: usize) -> f64 {
        self.combine_us_per_byte * bytes as f64
    }

    pub fn with_combine_us_per_byte(mut self, v: f64) -> Self {
        self.combine_us_per_byte = v;
        self
    }

    /// Uniform network (every level identical) — the topology-unaware
    /// modeling assumption the paper argues against.
    pub fn uniform(levels: usize, link: LinkParams) -> Self {
        NetworkParams::new(vec![link; levels])
    }
}

/// Human-readable names for the canonical 3-level grid's link classes.
pub fn sep_name(sep: usize, n_levels: usize) -> &'static str {
    if sep >= n_levels {
        "intra-machine"
    } else if sep == 1 {
        "WAN"
    } else if sep == 2 && n_levels >= 3 {
        "LAN"
    } else {
        "mid-level"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_costs() {
        let l = LinkParams::new(100.0, 10.0).with_overheads(5.0, 3.0);
        // 1000 bytes at 10 MB/s = 100 us serialization.
        assert!((l.sender_busy_us(1000) - 105.0).abs() < 1e-9);
        assert!((l.arrival_delay_us(1000) - 205.0).abs() < 1e-9);
        assert!((l.p2p_us(1000) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn sep_indexing_and_clamp() {
        let p = NetworkParams::new(vec![
            LinkParams::new(1000.0, 1.0),
            LinkParams::new(10.0, 100.0),
        ]);
        assert_eq!(p.at_sep(1).latency_us, 1000.0);
        assert_eq!(p.at_sep(2).latency_us, 10.0);
        // sep beyond table clamps to innermost.
        assert_eq!(p.at_sep(5).latency_us, 10.0);
    }

    #[test]
    fn combine_pricing() {
        let p = NetworkParams::new(vec![LinkParams::new(1.0, 1.0)]).with_combine_us_per_byte(0.01);
        assert!((p.combine_us(1000) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn sep_names() {
        assert_eq!(sep_name(1, 3), "WAN");
        assert_eq!(sep_name(2, 3), "LAN");
        assert_eq!(sep_name(3, 3), "intra-machine");
        assert_eq!(sep_name(1, 1), "intra-machine");
    }
}
