//! Calibrated parameter presets for the simulated grids.
//!
//! Absolute values are era-plausible (2002 WAN/LAN/SMP numbers informed by
//! the paper's testbed description and the MagPIe/PLogP measurements the
//! paper cites); the reproduced *shapes* — who wins and where crossovers
//! fall — are insensitive to the exact values, which `benches/` sweep.

use super::{LinkParams, NetworkParams};

/// The paper's experimental setting (§4): two sites over a transcontinental
/// WAN; machines at one site share a LAN; processes within a machine use
/// vendor MPI / shared memory. 3 levels: WAN / LAN / intra-machine.
pub fn paper_grid() -> NetworkParams {
    NetworkParams::new(vec![
        // WAN: SDSC <-> ANL. ~30 ms one-way latency, ~2 MB/s sustained TCP.
        // Overlapped injection: distinct site pairs use independent
        // wide-area paths (the §4 / MagPIe assumption).
        LinkParams::new(30_000.0, 2.0).with_overheads(60.0, 60.0),
        // LAN at ANL: ~0.5 ms latency, ~10 MB/s TCP over fast ethernet.
        LinkParams::new(500.0, 10.0).with_overheads(25.0, 25.0),
        // Intra-machine (vendor MPI on the SP / shared memory on the O2K).
        LinkParams::new(30.0, 150.0).with_overheads(2.0, 2.0),
    ])
    .with_combine_us_per_byte(0.002) // ~0.5 GB/s combine, 2002-era CPU
}

/// A modern-ish grid for ablations: faster absolute numbers, same ordering.
pub fn modern_grid() -> NetworkParams {
    NetworkParams::new(vec![
        LinkParams::new(15_000.0, 100.0).with_overheads(10.0, 10.0),
        LinkParams::new(100.0, 1_000.0).with_overheads(3.0, 3.0),
        LinkParams::new(2.0, 10_000.0).with_overheads(0.5, 0.5),
    ])
    .with_combine_us_per_byte(0.0005)
}

/// 4-level variant (world / site / LAN / machine) for the deep-hierarchy
/// experiments: campus backbone inserted between WAN and machine-room LAN.
pub fn deep_grid() -> NetworkParams {
    NetworkParams::new(vec![
        LinkParams::new(30_000.0, 2.0).with_overheads(60.0, 60.0),
        LinkParams::new(2_000.0, 5.0).with_overheads(40.0, 40.0),
        LinkParams::new(500.0, 10.0).with_overheads(25.0, 25.0),
        LinkParams::new(30.0, 150.0).with_overheads(2.0, 2.0),
    ])
    .with_combine_us_per_byte(0.002)
}

/// Cluster-of-SMPs (MPI-StarT's setting): 2 levels, interconnect + bus.
pub fn cluster_of_smps() -> NetworkParams {
    NetworkParams::new(vec![
        LinkParams::new(100.0, 40.0).with_overheads(8.0, 8.0),
        LinkParams::new(5.0, 200.0).with_overheads(1.0, 1.0),
    ])
    .with_combine_us_per_byte(0.002)
}

/// Uniform low-latency network (telephone-model assumption) — the regime
/// where plain binomial trees are actually optimal; used as a control.
pub fn uniform_lan(levels: usize) -> NetworkParams {
    NetworkParams::uniform(levels, LinkParams::new(50.0, 50.0).with_overheads(5.0, 5.0))
        .with_combine_us_per_byte(0.002)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_ordered_slow_to_fast() {
        for p in [paper_grid(), modern_grid(), deep_grid(), cluster_of_smps()] {
            for w in p.per_sep.windows(2) {
                assert!(w[0].latency_us > w[1].latency_us, "latency must decrease inward");
                assert!(
                    w[0].bandwidth_mb_s < w[1].bandwidth_mb_s,
                    "bandwidth must increase inward"
                );
            }
        }
    }

    #[test]
    fn paper_grid_is_three_level() {
        assert_eq!(paper_grid().n_levels(), 3);
        assert_eq!(deep_grid().n_levels(), 4);
    }

    #[test]
    fn wan_dominates_lan_by_an_order_of_magnitude() {
        let p = paper_grid();
        // The §1 claim: inter-level costs differ by >= 10x.
        assert!(p.at_sep(1).p2p_us(1024) / p.at_sep(2).p2p_us(1024) > 10.0);
        assert!(p.at_sep(2).p2p_us(1024) / p.at_sep(3).p2p_us(1024) > 5.0);
    }
}
