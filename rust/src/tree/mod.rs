//! Communication trees and their builders.
//!
//! A [`Tree`] spans a set of communicator ranks; builders produce the
//! shapes the paper discusses — binomial (Fig. 2, the MPICH default), flat
//! (postal-optimal at high latency), chain, and generalized Fibonacci
//! (postal-optimal at intermediate latency λ) — plus the **multilevel
//! composite** (Fig. 4) and the MagPIe-style 2-level trees (Fig. 3).

pub mod multilevel;
pub mod shapes;

pub use multilevel::{build_multilevel, build_strategy_tree, LevelPolicy, Strategy};
pub use shapes::TreeShape;

use crate::error::{Error, Result};
use crate::topology::Rank;

/// Rooted ordered tree over a subset of communicator ranks `0..n`.
///
/// `parent[r] == None` for the root and for ranks not in the tree; use
/// [`Tree::contains`] to distinguish. Children are ordered — send order
/// matters (a parent's earlier sends depart first).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tree {
    root: Rank,
    parent: Vec<Option<Rank>>,
    children: Vec<Vec<Rank>>,
    in_tree: Vec<bool>,
    n_members: usize,
}

impl Tree {
    /// A tree containing only `root` over an `n`-rank communicator.
    pub fn singleton(n: usize, root: Rank) -> Self {
        assert!(root < n);
        let mut t = Tree {
            root,
            parent: vec![None; n],
            children: vec![Vec::new(); n],
            in_tree: vec![false; n],
            n_members: 1,
        };
        t.in_tree[root] = true;
        t
    }

    pub fn root(&self) -> Rank {
        self.root
    }

    pub fn capacity(&self) -> usize {
        self.parent.len()
    }

    pub fn n_members(&self) -> usize {
        self.n_members
    }

    pub fn contains(&self, r: Rank) -> bool {
        self.in_tree[r]
    }

    pub fn parent(&self, r: Rank) -> Option<Rank> {
        self.parent[r]
    }

    /// Ordered children of `r`.
    pub fn children(&self, r: Rank) -> &[Rank] {
        &self.children[r]
    }

    /// Add edge `parent -> child`, appending to the parent's child order.
    /// `child` must not already be in the tree; `parent` must be.
    pub fn attach(&mut self, parent: Rank, child: Rank) -> Result<()> {
        if !self.in_tree[parent] {
            return Err(Error::Tree(format!("attach: parent {parent} not in tree")));
        }
        if self.in_tree[child] {
            return Err(Error::Tree(format!("attach: child {child} already in tree")));
        }
        self.parent[child] = Some(parent);
        self.children[parent].push(child);
        self.in_tree[child] = true;
        self.n_members += 1;
        Ok(())
    }

    /// Members in preorder (root, then each child subtree in order).
    pub fn preorder(&self) -> Vec<Rank> {
        let mut out = Vec::with_capacity(self.n_members);
        let mut stack = vec![self.root];
        while let Some(r) = stack.pop() {
            out.push(r);
            // reverse so the first child is popped first
            for &c in self.children[r].iter().rev() {
                stack.push(c);
            }
        }
        out
    }

    /// Ranks of the subtree rooted at `r` (preorder), including `r`.
    pub fn subtree(&self, r: Rank) -> Vec<Rank> {
        let mut out = Vec::new();
        let mut stack = vec![r];
        while let Some(x) = stack.pop() {
            out.push(x);
            for &c in self.children[x].iter().rev() {
                stack.push(c);
            }
        }
        out
    }

    /// Depth of rank `r` (root = 0).
    pub fn depth(&self, r: Rank) -> usize {
        let mut d = 0;
        let mut x = r;
        while let Some(p) = self.parent[x] {
            d += 1;
            x = p;
        }
        d
    }

    /// Height of the tree (max depth over members).
    pub fn height(&self) -> usize {
        (0..self.capacity())
            .filter(|&r| self.in_tree[r])
            .map(|r| self.depth(r))
            .max()
            .unwrap_or(0)
    }

    /// Verify structural invariants and (optionally) that the member set
    /// equals `members`.
    pub fn validate(&self, members: Option<&[Rank]>) -> Result<()> {
        if !self.in_tree[self.root] || self.parent[self.root].is_some() {
            return Err(Error::Tree("root missing or has a parent".into()));
        }
        // Every member reachable from root exactly once.
        let reach = self.preorder();
        if reach.len() != self.n_members {
            return Err(Error::Tree(format!(
                "reachable {} != members {} (cycle or orphan)",
                reach.len(),
                self.n_members
            )));
        }
        let mut seen = vec![false; self.capacity()];
        for &r in &reach {
            if seen[r] {
                return Err(Error::Tree(format!("rank {r} visited twice (cycle)")));
            }
            seen[r] = true;
            if !self.in_tree[r] {
                return Err(Error::Tree(format!("rank {r} reachable but not marked in-tree")));
            }
        }
        // parent/child coherence
        for r in 0..self.capacity() {
            for &c in &self.children[r] {
                if self.parent[c] != Some(r) {
                    return Err(Error::Tree(format!("child {c} of {r} disagrees on parent")));
                }
            }
            if let Some(p) = self.parent[r] {
                if !self.children[p].contains(&r) {
                    return Err(Error::Tree(format!("rank {r} not in parent {p}'s child list")));
                }
            }
        }
        if let Some(members) = members {
            if members.len() != self.n_members {
                return Err(Error::Tree(format!(
                    "member count {} != expected {}",
                    self.n_members,
                    members.len()
                )));
            }
            for &m in members {
                if !self.in_tree[m] {
                    return Err(Error::Tree(format!("expected member {m} missing")));
                }
            }
        }
        Ok(())
    }

    /// ASCII rendering (for `tree_explorer` and docs).
    pub fn render(&self, label: impl Fn(Rank) -> String) -> String {
        let mut out = String::new();
        fn rec(
            t: &Tree,
            r: Rank,
            prefix: &str,
            is_last: bool,
            is_root: bool,
            label: &dyn Fn(Rank) -> String,
            out: &mut String,
        ) {
            if is_root {
                out.push_str(&format!("{}\n", label(r)));
            } else {
                out.push_str(&format!("{prefix}{}{}\n", if is_last { "└─ " } else { "├─ " }, label(r)));
            }
            let kids = t.children(r);
            for (i, &c) in kids.iter().enumerate() {
                let last = i + 1 == kids.len();
                let child_prefix = if is_root {
                    String::new()
                } else {
                    format!("{prefix}{}", if is_last { "   " } else { "│  " })
                };
                rec(t, c, &child_prefix, last, false, label, out);
            }
        }
        rec(self, self.root, "", true, true, &label, &mut out);
        out
    }

    /// Edge list `(parent, child)` in preorder discovery order.
    pub fn edges(&self) -> Vec<(Rank, Rank)> {
        let mut out = Vec::with_capacity(self.n_members.saturating_sub(1));
        for r in self.preorder() {
            for &c in self.children(r) {
                out.push((r, c));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path3() -> Tree {
        let mut t = Tree::singleton(3, 0);
        t.attach(0, 1).unwrap();
        t.attach(1, 2).unwrap();
        t
    }

    #[test]
    fn attach_and_query() {
        let t = path3();
        assert_eq!(t.root(), 0);
        assert_eq!(t.parent(2), Some(1));
        assert_eq!(t.children(0), &[1]);
        assert_eq!(t.n_members(), 3);
        assert!(t.contains(2));
        assert_eq!(t.depth(2), 2);
        assert_eq!(t.height(), 2);
        t.validate(Some(&[0, 1, 2])).unwrap();
    }

    #[test]
    fn attach_rejects_duplicates_and_orphans() {
        let mut t = path3();
        assert!(t.attach(0, 1).is_err()); // already in tree
        let mut t2 = Tree::singleton(5, 0);
        assert!(t2.attach(3, 4).is_err()); // parent not in tree
    }

    #[test]
    fn preorder_and_subtree() {
        let mut t = Tree::singleton(5, 0);
        t.attach(0, 1).unwrap();
        t.attach(0, 2).unwrap();
        t.attach(1, 3).unwrap();
        t.attach(1, 4).unwrap();
        assert_eq!(t.preorder(), vec![0, 1, 3, 4, 2]);
        assert_eq!(t.subtree(1), vec![1, 3, 4]);
        assert_eq!(t.edges(), vec![(0, 1), (0, 2), (1, 3), (1, 4)]);
    }

    #[test]
    fn validate_detects_missing_member() {
        let t = path3();
        assert!(t.validate(Some(&[0, 1])).is_err());
        assert!(t.validate(Some(&[0, 1, 2])).is_ok());
    }

    #[test]
    fn partial_tree_over_larger_comm() {
        let mut t = Tree::singleton(10, 4);
        t.attach(4, 7).unwrap();
        assert!(!t.contains(0));
        assert_eq!(t.n_members(), 2);
        t.validate(Some(&[4, 7])).unwrap();
    }

    #[test]
    fn render_ascii() {
        let t = path3();
        let s = t.render(|r| format!("r{r}"));
        assert!(s.contains("r0"));
        assert!(s.contains("└─ r2"));
    }
}
