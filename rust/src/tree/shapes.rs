//! Single-level tree shapes over an ordered member list.
//!
//! Shape selection per level is the §3.2/§6 knob: Bar-Noy & Kipnis show
//! the postal-optimal broadcast tree flattens as latency λ grows — binomial
//! at λ=1 (intra-machine), flat as λ→∞ (WAN). All builders are
//! deterministic in `(members, root)`, the property §3.2 requires so that
//! every process constructs the identical tree without communication.

use crate::error::{Error, Result};
use crate::topology::Rank;
use crate::tree::Tree;

/// Tree shape selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TreeShape {
    /// MPICH's relative-rank binomial tree (Fig. 2).
    Binomial,
    /// Root sends to every other member directly (postal-optimal, λ→∞).
    Flat,
    /// Linear pipeline in member order.
    Chain,
    /// Generalized Fibonacci tree for postal latency λ >= 1 (λ=1 ≡ binomial
    /// in node count; shape follows the postal recurrence
    /// N(t) = N(t-1) + N(t-λ)).
    Fibonacci(u32),
    /// Bine/Swing-style distance-halving tree (PAPERS.md, 2508.17311):
    /// recursive halving over the rotated member ring — the root covers
    /// the whole ring and repeatedly hands the upper half of its interval
    /// to the member at its midpoint, so the first hop spans half the
    /// ring and every deeper hop spans half the previous distance.
    /// Identical to [`TreeShape::Binomial`] on power-of-two member counts;
    /// on other counts its sends stay distance-ordered (farthest first)
    /// where the bitmask construction's do not.
    DistanceHalving,
}

impl TreeShape {
    pub fn name(&self) -> String {
        match self {
            TreeShape::Binomial => "binomial".into(),
            TreeShape::Flat => "flat".into(),
            TreeShape::Chain => "chain".into(),
            TreeShape::Fibonacci(l) => format!("fibonacci(λ={l})"),
            TreeShape::DistanceHalving => "distance-halving".into(),
        }
    }

    /// Build this shape over `members` (which must contain `root`) inside a
    /// tree whose rank space has `capacity` slots.
    pub fn build(&self, capacity: usize, members: &[Rank], root: Rank) -> Result<Tree> {
        let mut t = Tree::singleton(capacity, root);
        self.graft(&mut t, members, root)?;
        Ok(t)
    }

    /// Graft this shape's edges over `members` into an existing tree.
    /// `root` must already be in `tree`; all other members must not.
    pub fn graft(&self, tree: &mut Tree, members: &[Rank], root: Rank) -> Result<()> {
        let m = members.len();
        let root_pos = members
            .iter()
            .position(|&r| r == root)
            .ok_or_else(|| Error::Tree(format!("root {root} not among members")))?;
        if m == 1 {
            return Ok(());
        }
        // Work in "relative position" space: rel i corresponds to
        // members[(root_pos + i) % m]; rel 0 is the root.
        let abs = |rel: usize| members[(root_pos + rel) % m];
        match self {
            TreeShape::Flat => {
                for rel in 1..m {
                    tree.attach(root, abs(rel))?;
                }
            }
            TreeShape::Chain => {
                for rel in 1..m {
                    tree.attach(abs(rel - 1), abs(rel))?;
                }
            }
            TreeShape::Binomial => {
                // MPICH construction: parent(rel) = rel with its lowest set
                // bit cleared; children attached in descending-mask order
                // (largest subtree first), matching the MPI_Bcast send loop
                // and the Fig. 2 child ordering.
                // Attach in an order that guarantees parents precede
                // children: increasing rel works because parent(rel) < rel.
                // But child order must be descending-subtree, so collect
                // children per parent first.
                let mut kids: Vec<Vec<usize>> = vec![Vec::new(); m];
                for rel in 1..m {
                    let parent = rel & (rel - 1);
                    kids[parent].push(rel);
                }
                // kids[p] currently ascending (mask order low->high); MPICH
                // sends high mask first.
                for k in kids.iter_mut() {
                    k.reverse();
                }
                // BFS attach from rel 0.
                let mut queue = std::collections::VecDeque::from([0usize]);
                while let Some(p) = queue.pop_front() {
                    for &c in &kids[p] {
                        tree.attach(abs(p), abs(c))?;
                        queue.push_back(c);
                    }
                }
            }
            TreeShape::DistanceHalving => {
                // Recursive halving: the owner of interval [lo, hi) sends
                // to the member at the midpoint, which takes over the
                // upper half. LIFO processing keeps the attach order
                // parent-before-child and each owner's children in
                // descending-distance order (farthest first), matching
                // the postal send discipline.
                let mut stack = vec![(0usize, m)];
                while let Some((lo, hi)) = stack.pop() {
                    if hi - lo <= 1 {
                        continue;
                    }
                    let mid = lo + (hi - lo).div_ceil(2);
                    tree.attach(abs(lo), abs(mid))?;
                    stack.push((lo, mid));
                    stack.push((mid, hi));
                }
            }
            TreeShape::Fibonacci(lambda) => {
                let lambda = (*lambda).max(1) as f64;
                // Postal-model greedy schedule: a node activated at time a
                // sends at a+1, a+2, ...; a message sent at s activates its
                // receiver at s + λ. Repeatedly give the next unassigned
                // member to the sender whose next send completes earliest;
                // ties break toward the earlier-activated (lower rel) node,
                // keeping the construction deterministic.
                // next_send[i] = absolute time of node i's next send start.
                let mut activated = vec![(0usize, 0.0f64)]; // (rel, activation)
                let mut next_send: Vec<f64> = vec![0.0]; // root can send at t=0
                let mut assigned = 1usize;
                while assigned < m {
                    // earliest (arrival = send + λ) among activated nodes
                    let (best, _) = activated
                        .iter()
                        .enumerate()
                        .map(|(i, _)| (i, next_send[i] + lambda))
                        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)))
                        .unwrap();
                    let rel = assigned;
                    let send_t = next_send[best];
                    let parent_rel = activated[best].0;
                    tree.attach(abs(parent_rel), abs(rel))?;
                    next_send[best] = send_t + 1.0; // sender free one step later
                    activated.push((rel, send_t + lambda));
                    next_send.push(send_t + lambda); // receiver sends on activation
                    assigned += 1;
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(n: usize) -> Vec<Rank> {
        (0..n).collect()
    }

    #[test]
    fn binomial_matches_fig2() {
        // B_3 over 8 ranks rooted at 0 (Fig. 2): root children are the
        // roots of B_2, B_1, B_0 => rel 4, 2, 1 in that order.
        let t = TreeShape::Binomial.build(8, &ids(8), 0).unwrap();
        t.validate(Some(&ids(8))).unwrap();
        assert_eq!(t.children(0), &[4, 2, 1]);
        assert_eq!(t.children(4), &[6, 5]);
        assert_eq!(t.children(2), &[3]);
        assert_eq!(t.children(6), &[7]);
        assert_eq!(t.height(), 3);
    }

    #[test]
    fn binomial_rotation_by_root() {
        // Root 3 over 8: rel space rotated; structure identical.
        let t = TreeShape::Binomial.build(8, &ids(8), 3).unwrap();
        t.validate(Some(&ids(8))).unwrap();
        assert_eq!(t.root(), 3);
        // rel 4,2,1 => ranks (3+4)%8=7, 5, 4
        assert_eq!(t.children(3), &[7, 5, 4]);
    }

    #[test]
    fn binomial_non_power_of_two() {
        for n in [1usize, 2, 3, 5, 6, 7, 9, 13] {
            let t = TreeShape::Binomial.build(n, &ids(n), 0).unwrap();
            t.validate(Some(&ids(n))).unwrap();
            // depth of rel r = popcount(r); height = max over members.
            let expect = (0..n).map(|r| r.count_ones() as usize).max().unwrap();
            assert_eq!(t.height(), expect, "n={n}");
        }
    }

    #[test]
    fn flat_tree() {
        let t = TreeShape::Flat.build(5, &ids(5), 2).unwrap();
        t.validate(Some(&ids(5))).unwrap();
        assert_eq!(t.children(2), &[3, 4, 0, 1]); // member order after root
        assert_eq!(t.height(), 1);
    }

    #[test]
    fn chain_tree() {
        let t = TreeShape::Chain.build(4, &ids(4), 1).unwrap();
        t.validate(Some(&ids(4))).unwrap();
        assert_eq!(t.height(), 3);
        assert_eq!(t.children(1), &[2]);
        assert_eq!(t.children(2), &[3]);
        assert_eq!(t.children(3), &[0]);
    }

    #[test]
    fn fibonacci_lambda1_is_binomial_sized() {
        // λ=1: postal tree reaches 2^t nodes by time t, like binomial.
        let t = TreeShape::Fibonacci(1).build(8, &ids(8), 0).unwrap();
        t.validate(Some(&ids(8))).unwrap();
        assert_eq!(t.height(), TreeShape::Binomial.build(8, &ids(8), 0).unwrap().height());
    }

    #[test]
    fn fibonacci_large_lambda_flattens() {
        // λ >= m-1: root sends everything before any child can forward.
        let t = TreeShape::Fibonacci(10).build(6, &ids(6), 0).unwrap();
        t.validate(Some(&ids(6))).unwrap();
        assert_eq!(t.children(0).len(), 5, "should be flat");
    }

    #[test]
    fn fibonacci_intermediate_lambda_node_counts() {
        // Postal recurrence N(t) = N(t-1) + N(t-λ) for λ=2:
        // t:      0 1 2 3 4  5
        // N(t):   1 1 2 3 5  8   (Fibonacci numbers)
        // Check the tree over 8 members has postal height 5 for λ=2:
        // height in *hops* is smaller; verify via construction determinism
        // and spanning instead, plus monotonicity vs flat/binomial.
        let t2 = TreeShape::Fibonacci(2).build(8, &ids(8), 0).unwrap();
        t2.validate(Some(&ids(8))).unwrap();
        let tb = TreeShape::Binomial.build(8, &ids(8), 0).unwrap();
        // λ=2 tree is flatter than binomial at the root.
        assert!(t2.children(0).len() >= tb.children(0).len());
    }

    #[test]
    fn builders_deterministic() {
        for shape in [
            TreeShape::Binomial,
            TreeShape::Flat,
            TreeShape::Chain,
            TreeShape::Fibonacci(3),
            TreeShape::DistanceHalving,
        ] {
            let a = shape.build(9, &ids(9), 4).unwrap();
            let b = shape.build(9, &ids(9), 4).unwrap();
            assert_eq!(a, b, "{shape:?} not deterministic");
        }
    }

    #[test]
    fn distance_halving_equals_binomial_on_powers_of_two() {
        for n in [2usize, 4, 8, 16] {
            let dh = TreeShape::DistanceHalving.build(n, &ids(n), 0).unwrap();
            let bi = TreeShape::Binomial.build(n, &ids(n), 0).unwrap();
            assert_eq!(dh, bi, "n={n}");
        }
    }

    #[test]
    fn distance_halving_spans_and_halves_distances() {
        for n in [3usize, 5, 6, 7, 9, 13, 20] {
            let t = TreeShape::DistanceHalving.build(n, &ids(n), 0).unwrap();
            t.validate(Some(&ids(n))).unwrap();
            // Root's children sit at strictly decreasing ring distances,
            // first hop spanning (at least) half the ring.
            let kids = t.children(0);
            assert!(!kids.is_empty());
            assert!(2 * kids[0] >= n, "first hop spans half the ring (n={n})");
            for w in kids.windows(2) {
                assert!(w[0] > w[1], "descending distance order (n={n})");
            }
        }
        // Non-power-of-two counts differ from the bitmask binomial.
        let dh = TreeShape::DistanceHalving.build(6, &ids(6), 0).unwrap();
        let bi = TreeShape::Binomial.build(6, &ids(6), 0).unwrap();
        assert_ne!(dh, bi);
        assert_eq!(dh.children(0), &[3, 2, 1]);
        assert_eq!(dh.children(3), &[5, 4]);
    }

    #[test]
    fn distance_halving_rotates_with_root_and_subsets() {
        let t = TreeShape::DistanceHalving.build(8, &ids(8), 3).unwrap();
        t.validate(Some(&ids(8))).unwrap();
        assert_eq!(t.root(), 3);
        // rel 4, 2, 1 => ranks (3+4)%8=7, 5, 4 — same rotation law as
        // the other shapes.
        assert_eq!(t.children(3), &[7, 5, 4]);
        let members = [2, 5, 7];
        let s = TreeShape::DistanceHalving.build(10, &members, 5).unwrap();
        s.validate(Some(&members)).unwrap();
    }

    #[test]
    fn subset_members_and_missing_root() {
        let members = [2, 5, 7];
        let t = TreeShape::Binomial.build(10, &members, 5).unwrap();
        t.validate(Some(&members)).unwrap();
        assert!(!t.contains(0));
        assert!(TreeShape::Flat.build(10, &members, 9).is_err());
    }

    #[test]
    fn singleton_member() {
        for shape in [TreeShape::Binomial, TreeShape::Flat, TreeShape::Chain] {
            let t = shape.build(4, &[2], 2).unwrap();
            assert_eq!(t.n_members(), 1);
        }
    }
}
