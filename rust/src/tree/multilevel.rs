//! The multilevel topology-aware tree builder (§2.3, §3.2) plus the
//! comparison strategies: topology-unaware MPICH binomial and the
//! MagPIe-style 2-level trees (§2.1, §2.2).
//!
//! Construction is purely a function of `(clustering, root, policy)` —
//! every process can build the identical tree independently, with no
//! communication, exactly as MPICH-G2 does at collective-call time.

use crate::error::Result;
use crate::topology::{Clustering, Communicator, Rank};
use crate::tree::shapes::TreeShape;
use crate::tree::Tree;

/// Which collective-tree strategy to use — the four curves of Fig. 8.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// MPICH default: one binomial tree over all ranks, topology-ignorant.
    Unaware,
    /// MagPIe-style 2-level, clusters = machines (Fig. 3a).
    TwoLevelMachine,
    /// MagPIe-style 2-level, clusters = level-1 (site) groups (Fig. 3b).
    TwoLevelSite,
    /// The paper's multilevel approach (Fig. 4).
    Multilevel,
}

impl Strategy {
    pub const ALL: [Strategy; 4] =
        [Strategy::Unaware, Strategy::TwoLevelMachine, Strategy::TwoLevelSite, Strategy::Multilevel];

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Unaware => "mpich-binomial",
            Strategy::TwoLevelMachine => "magpie-machine",
            Strategy::TwoLevelSite => "magpie-site",
            Strategy::Multilevel => "multilevel",
        }
    }
}

/// Per-level tree shapes for the multilevel builder.
///
/// `shape_at(l)` picks the tree used *among the representatives of the
/// level-`l` clusters* (l = 1 is the WAN level); the deepest level is the
/// intra-machine tree. The paper's choice (§3.2): flat at the WAN level,
/// binomial below.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct LevelPolicy {
    /// `shapes[l-1]` = shape among level-`l` cluster representatives;
    /// levels beyond the vector clamp to the last entry.
    pub shapes: Vec<TreeShape>,
}

impl LevelPolicy {
    /// The paper's §3.2 policy: flat across the WAN, binomial elsewhere.
    pub fn paper() -> Self {
        LevelPolicy { shapes: vec![TreeShape::Flat, TreeShape::Binomial] }
    }

    /// Binomial everywhere (what the earlier hidden-communicator prototype
    /// [19] produced).
    pub fn all_binomial() -> Self {
        LevelPolicy { shapes: vec![TreeShape::Binomial] }
    }

    /// Same shape at every level.
    pub fn uniform(shape: TreeShape) -> Self {
        LevelPolicy { shapes: vec![shape] }
    }

    pub fn shape_at(&self, level: usize) -> TreeShape {
        debug_assert!(level >= 1);
        let idx = (level - 1).min(self.shapes.len() - 1);
        self.shapes[idx]
    }
}

/// Build the multilevel topology-aware tree over all ranks of `clustering`,
/// rooted at `root` (§2.3):
///
/// - at each level, the clusters that partition the current group are
///   connected by a tree over one **representative** per cluster (the root
///   for its own cluster, the minimum member rank otherwise);
/// - recursion descends into each cluster rooted at its representative;
/// - at the deepest level the remaining ranks share a machine and are
///   spanned directly.
///
/// Consequently each level-`l` boundary inside any cluster is crossed by
/// exactly (#subclusters - 1) messages — one per non-root subcluster — the
/// minimum possible (Fig. 4: one WAN message, one LAN message).
pub fn build_multilevel(clustering: &Clustering, root: Rank, policy: &LevelPolicy) -> Result<Tree> {
    let n = clustering.n_ranks();
    let mut tree = Tree::singleton(n, root);
    let all: Vec<Rank> = (0..n).collect();
    build_rec(clustering, &all, 1, root, policy, &mut tree)?;
    tree.validate(Some(&all))?;
    Ok(tree)
}

fn build_rec(
    clustering: &Clustering,
    ranks: &[Rank],
    level: usize,
    root: Rank,
    policy: &LevelPolicy,
    tree: &mut Tree,
) -> Result<()> {
    debug_assert!(ranks.contains(&root));
    if ranks.len() == 1 {
        return Ok(());
    }
    if level >= clustering.n_levels() {
        // Deepest level: all ranks share a machine.
        return policy.shape_at(level).graft(tree, ranks, root);
    }
    let parts = clustering.partition(ranks, level);
    if parts.len() == 1 {
        return build_rec(clustering, ranks, level + 1, root, policy, tree);
    }
    // One representative per cluster; the root's cluster is led by root.
    let mut reps = Vec::with_capacity(parts.len());
    for part in &parts {
        if part.contains(&root) {
            reps.push(root);
        } else {
            reps.push(*part.iter().min().expect("non-empty part"));
        }
    }
    // Representatives tree: root's rep first (shape builders rotate to the
    // root), others in cluster order.
    policy.shape_at(level).graft(tree, &reps, root)?;
    for (part, &rep) in parts.iter().zip(&reps) {
        build_rec(clustering, part, level + 1, rep, policy, tree)?;
    }
    Ok(())
}

/// Build the tree for a `(communicator, root, strategy)` triple — the
/// single entry point the collectives use.
pub fn build_strategy_tree(
    comm: &Communicator,
    root: Rank,
    strategy: Strategy,
    policy: &LevelPolicy,
) -> Result<Tree> {
    crate::util::counters::count_tree_build();
    let clustering = comm.clustering();
    let n = comm.size();
    let all: Vec<Rank> = (0..n).collect();
    match strategy {
        Strategy::Unaware => {
            let t = TreeShape::Binomial.build(n, &all, root)?;
            Ok(t)
        }
        Strategy::TwoLevelMachine => {
            // Clusters at the deepest (machine) level; if the clustering
            // is already flat (1 level) this degrades to Unaware.
            if clustering.n_levels() < 2 {
                return build_strategy_tree(comm, root, Strategy::Unaware, policy);
            }
            let view = clustering.two_level_view(clustering.n_levels() - 1)?;
            build_multilevel(&view, root, policy)
        }
        Strategy::TwoLevelSite => {
            if clustering.n_levels() < 2 {
                return build_strategy_tree(comm, root, Strategy::Unaware, policy);
            }
            let view = clustering.two_level_view(1)?;
            build_multilevel(&view, root, policy)
        }
        Strategy::Multilevel => build_multilevel(clustering, root, policy),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::TopologySpec;

    /// Count tree edges by separation level.
    fn edges_by_sep(tree: &Tree, c: &Clustering) -> Vec<usize> {
        let mut counts = vec![0usize; c.n_levels()];
        for (p, ch) in tree.edges() {
            counts[c.sep(p, ch) - 1] += 1;
        }
        counts
    }

    #[test]
    fn fig4_multilevel_tree_crosses_each_level_once() {
        // Fig. 1/4 topology: SDSC{SP:10}, NCSA{O2Ka:5, O2Kb:5}, root on SP.
        let spec = TopologySpec::paper_fig1();
        let c = spec.clustering();
        let t = build_multilevel(&c, 0, &LevelPolicy::paper()).unwrap();
        let by_sep = edges_by_sep(&t, &c);
        assert_eq!(by_sep[0], 1, "exactly one WAN edge (Fig. 4)");
        assert_eq!(by_sep[1], 1, "exactly one LAN edge (Fig. 4)");
        assert_eq!(by_sep[2], 17, "remaining edges intra-machine");
        // The WAN edge lands on the NCSA rep = rank 10 (min of O2Ka);
        // the LAN edge goes O2Ka-rep -> O2Kb-rep (rank 15).
        assert_eq!(t.parent(10), Some(0));
        assert_eq!(t.parent(15), Some(10));
    }

    #[test]
    fn fig3a_two_level_machine_uses_two_wan_messages() {
        let spec = TopologySpec::paper_fig1();
        let comm = crate::topology::Communicator::world(&spec);
        let t =
            build_strategy_tree(&comm, 0, Strategy::TwoLevelMachine, &LevelPolicy::paper()).unwrap();
        let by_sep = edges_by_sep(&t, comm.clustering());
        // Machine-boundary clustering ignores the LAN: both O2K reps hang
        // off the SDSC root -> 2 messages over the WAN (Fig. 3a).
        assert_eq!(by_sep[0], 2);
        assert_eq!(by_sep[1], 0);
    }

    #[test]
    fn fig3b_two_level_site_uses_one_wan_but_lan_heavy() {
        let spec = TopologySpec::paper_fig1();
        let comm = crate::topology::Communicator::world(&spec);
        let t =
            build_strategy_tree(&comm, 0, Strategy::TwoLevelSite, &LevelPolicy::paper()).unwrap();
        let by_sep = edges_by_sep(&t, comm.clustering());
        // Site clustering: 1 WAN message, but the NCSA-internal binomial
        // tree is machine-unaware, so multiple LAN crossings (Fig. 3b).
        assert_eq!(by_sep[0], 1);
        assert!(by_sep[1] >= 2, "expected multiple LAN crossings, got {}", by_sep[1]);
    }

    #[test]
    fn unaware_binomial_crosses_wan_logn_times() {
        let spec = TopologySpec::paper_fig1();
        let comm = crate::topology::Communicator::world(&spec);
        let t = build_strategy_tree(&comm, 0, Strategy::Unaware, &LevelPolicy::paper()).unwrap();
        let by_sep = edges_by_sep(&t, comm.clustering());
        // Binomial over 20 ranks rooted at 0: ranks 10..20 are NCSA; many
        // edges cross the WAN.
        assert!(by_sep[0] >= 2, "binomial should cross WAN repeatedly, got {}", by_sep[0]);
    }

    #[test]
    fn multilevel_any_root_still_minimal() {
        let spec = TopologySpec::paper_experiment(); // 3 machines, 2 sites, 48 procs
        let c = spec.clustering();
        for root in [0usize, 5, 16, 31, 32, 47] {
            let t = build_multilevel(&c, root, &LevelPolicy::paper()).unwrap();
            let by_sep = edges_by_sep(&t, &c);
            assert_eq!(by_sep[0], 1, "root {root}: 1 WAN edge");
            assert_eq!(by_sep[1], 1, "root {root}: 1 LAN edge (ANL pair)");
            assert_eq!(t.root(), root);
        }
    }

    #[test]
    fn four_level_clustering_minimal_at_every_level() {
        // 2 sites x 2 LANs x 2 machines x 3 procs = 24 ranks, 4 levels.
        let spec = TopologySpec::new(
            "deep",
            crate::topology::GroupNode::group(
                "grid",
                (0..2)
                    .map(|s| {
                        crate::topology::GroupNode::group(
                            format!("site{s}"),
                            (0..2)
                                .map(|l| {
                                    crate::topology::GroupNode::group(
                                        format!("s{s}lan{l}"),
                                        (0..2)
                                            .map(|m| {
                                                crate::topology::GroupNode::machine(
                                                    format!("s{s}l{l}m{m}"),
                                                    3,
                                                )
                                            })
                                            .collect(),
                                    )
                                })
                                .collect(),
                        )
                    })
                    .collect(),
            ),
        )
        .unwrap();
        let c = spec.clustering();
        assert_eq!(c.n_levels(), 4);
        let t = build_multilevel(&c, 0, &LevelPolicy::paper()).unwrap();
        let by_sep = edges_by_sep(&t, &c);
        assert_eq!(by_sep[0], 1, "1 WAN edge between the 2 sites");
        assert_eq!(by_sep[1], 2, "1 inter-LAN edge within each site");
        assert_eq!(by_sep[2], 4, "1 inter-machine edge within each LAN");
        assert_eq!(by_sep[3], 16, "2 intra-machine edges per machine x 8");
    }

    #[test]
    fn strategy_degrades_gracefully_on_flat_clustering() {
        let comm = crate::topology::Communicator::unaware(8);
        for s in Strategy::ALL {
            let t = build_strategy_tree(&comm, 3, s, &LevelPolicy::paper()).unwrap();
            t.validate(Some(&(0..8).collect::<Vec<_>>())).unwrap();
            assert_eq!(t.root(), 3);
        }
    }

    #[test]
    fn deterministic_across_calls() {
        let spec = TopologySpec::paper_experiment();
        let comm = crate::topology::Communicator::world(&spec);
        for s in Strategy::ALL {
            let a = build_strategy_tree(&comm, 7, s, &LevelPolicy::paper()).unwrap();
            let b = build_strategy_tree(&comm, 7, s, &LevelPolicy::paper()).unwrap();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn wan_level_is_flat_in_paper_policy() {
        // 5 sites, one machine each; the inter-site tree must be flat.
        let spec = TopologySpec::uniform(5, 1, 2).unwrap();
        let c = spec.clustering();
        let t = build_multilevel(&c, 0, &LevelPolicy::paper()).unwrap();
        // Site reps are ranks 2,4,6,8 — all children of root 0.
        for rep in [2, 4, 6, 8] {
            assert_eq!(t.parent(rep), Some(0), "rep {rep} must hang off the root (flat WAN)");
        }
    }

    #[test]
    fn all_binomial_policy_differs_at_wan() {
        let spec = TopologySpec::uniform(5, 1, 2).unwrap();
        let c = spec.clustering();
        let t = build_multilevel(&c, 0, &LevelPolicy::all_binomial()).unwrap();
        // Binomial over 5 reps: root has ceil(log2(5)) = 3 children, not 4.
        let rep_children = t.children(0).iter().filter(|&&ch| c.sep(0, ch) == 1).count();
        assert_eq!(rep_children, 3);
    }
}
