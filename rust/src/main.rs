//! `gridcollect` — the L3 coordinator CLI.
//!
//! Subcommands map 1:1 onto the experiments in DESIGN.md §6:
//!
//! ```text
//! gridcollect fig8 [--sizes 1k,...,1m] [--fused] [--threads N]  # E1: the headline figure
//!                                  # (--fused adds the E13 fused-vs-separate delta table;
//!                                  #  timing points are ghost runs — no combiner involved;
//!                                  #  --threads N > 1 runs the cluster-sharded engine —
//!                                  #  identical numbers, parallel wall-clock)
//! gridcollect suite [--size 64k] [--xla]           # E8: 6 ops x 4 strategies
//! gridcollect allreduce [--size 64k] [--op sum] [--boundary 1] [--policy-file t.json] [--matrix m.csv] [--connect T] [--xla]
//! gridcollect tune-boundary [--sizes 4k,64k,1m] [--op sum] [--strategy s] [--spec fig1|experiment|SxMxP] [--matrix m.csv] [--save t.json] [--threads N]
//! gridcollect tune-composition [--sizes 4k,64k,1m] [--op sum] [--mode auto|exhaustive|beam:W] [--strategy s] [--spec ...] [--matrix m.csv] [--save t.json] [--connect T] [--threads N]
//! gridcollect discover [--matrix m.csv | --spec ... [--noise 0.1] [--seed 1]] [--probe 1k] [--out m.csv] [--emit-spec]
//! gridcollect cost-model [--size 64k]              # E2: §4 analytic vs sim
//! gridcollect ablation [--sites 8] [--size 64k]    # E9: WAN tree shapes
//! gridcollect scaling [--size 64k]                 # E10: site-count scaling
//! gridcollect roots [--size 64k]                   # E7: root sensitivity
//! gridcollect tree [--spec fig1|experiment] [--root 0]   # E3-E5: tree shapes
//! gridcollect rsl <script.rsl> [--root 0]          # E6: RSL front-end
//! gridcollect train [--steps 50] [--lr 0.1] [--strategy multilevel] [--spec fig1|experiment|SxMxP] [--matrix m.csv] [--algo rb|rsag|hybrid|comp:a,b,...] [--boundary 1] [--chunks K] [--order fifo|scf|ll] [--policy-file t.json] [--xla] [--threads N]
//! gridcollect gantt [--size 64k] [--strategy s] [--params file.net]
//! gridcollect calibrate [--out params.net]        # measure combine us/B
//! gridcollect bench-diff <baseline> <current> [--threshold 0.25]   # soft perf gate over BENCH_*.json
//! ```
//!
//! `--xla` routes reduce arithmetic through the AOT-compiled Pallas
//! combine kernels via PJRT (requires `make artifacts`); default is the
//! native combiner.
//!
//! The tuner → workload loop: `tune-boundary --save t.json` (two-regime
//! hybrids) or `tune-composition --save t.json` (the full per-level
//! assignment space — exhaustive on shallow grids, beam search on deep
//! ones) persists the winning `AlgoPolicy` per payload size (with
//! provenance); `train` / `allreduce` consume it via `--policy-file
//! t.json` and transparently run the tuned composition. All of the
//! tuners/`train`/`allreduce` default to the paper's experiment
//! topology, so the two-command loop works as-is; tune and consume with
//! the same `--spec`/`--strategy` otherwise — a provenance mismatch is a
//! hard error by design.
//!
//! `discover` closes the measurement loop: it infers the multilevel
//! clustering from a measured cost matrix (TACOS-style CSV edge list)
//! instead of a hand-written spec, and every topology-taking subcommand
//! accepts `--matrix m.csv` to run on the discovered hierarchy. On a
//! noiseless matrix the inferred clustering fingerprints identically to
//! the spec it was measured from, so tables tuned either way interoperate.
//!
//! `--connect <socket-or-host:port>` routes `allreduce` and
//! `tune-composition` through a running `gridd` daemon instead of
//! executing in-process: concurrent tuners share the daemon's plan cache
//! and policy store, identical in-flight tune requests coalesce into one
//! ghost sweep, and (with the daemon's `--policy-dir`) every verdict
//! persists across daemon restarts.

use gridcollect::cli::Args;
use gridcollect::coordinator::{experiment, timing_app, training, tuning};
use gridcollect::error::{Error, Result};
use gridcollect::model::presets;
use gridcollect::netsim::{Combiner, NativeCombiner, ReduceOp};
use gridcollect::runtime::{calibrate_us_per_byte, MlpRuntime, Runtime, XlaCombiner};
use gridcollect::service::{proto::JsonObj, Client, Target};
use gridcollect::session::{GridSession, PolicyTable};
use gridcollect::topology::{discover, rsl, Communicator, CostMatrix, TopologySpec};
use gridcollect::tree::Strategy;
use gridcollect::util::fmt;
use std::sync::Arc;

const USAGE: &str = "usage: gridcollect <fig8|suite|allreduce|tune-boundary|tune-composition|discover|cost-model|ablation|scaling|roots|tree|rsl|train|calibrate|bench-diff> [flags]
run `gridcollect help` or see rust/src/main.rs for flag details";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Open the PJRT runtime + XLA combiner when `--xla` is given.
fn maybe_xla(args: &Args) -> Result<Option<(Runtime, XlaCombiner)>> {
    if !args.has("xla") {
        return Ok(None);
    }
    let rt = Runtime::open(
        args.get("artifacts").map(Into::into).unwrap_or_else(gridcollect::runtime::artifacts::default_dir),
    )?;
    let c = XlaCombiner::open_default(&rt)?;
    Ok(Some((rt, c)))
}

/// Parse `--spec fig1|experiment|SxMxP` (shared by every
/// topology-taking subcommand).
fn parse_spec(args: &Args, default: &str) -> Result<TopologySpec> {
    match args.get_or("spec", default) {
        "fig1" => Ok(TopologySpec::paper_fig1()),
        "experiment" => Ok(TopologySpec::paper_experiment()),
        other => {
            // SxMxP, e.g. 4x2x8
            let parts: Vec<usize> = other.split('x').filter_map(|p| p.parse().ok()).collect();
            if parts.len() != 3 {
                return Err(Error::Cli(format!(
                    "--spec must be fig1|experiment|SxMxP, got '{other}'"
                )));
            }
            TopologySpec::uniform(parts[0], parts[1], parts[2])
        }
    }
}

/// Resolve the workload communicator: `--matrix m.csv` measures it —
/// the multilevel clustering is inferred from the cost matrix via
/// [`Communicator::from_matrix`] — otherwise `--spec` hand-specifies it
/// (falling back to `default`).
fn resolve_comm(args: &Args, default: &str) -> Result<Communicator> {
    match args.get("matrix") {
        Some(path) => Communicator::from_matrix(&CostMatrix::load_tacos_csv(path)?),
        None => Ok(Communicator::world(&parse_spec(args, default)?)),
    }
}

/// The `--save` consume hint: name commands whose topology actually
/// matches this table's provenance at install time. A discovered
/// clustering fingerprints structurally, so a table tuned through
/// `--matrix` also installs on the matching hand-specified `--spec`.
fn consume_hint(args: &Args, path: &str) -> String {
    if let Some(m) = args.get("matrix") {
        return format!("`gridcollect train|allreduce --matrix {m} --policy-file {path}`");
    }
    let spec_name = args.get_or("spec", "experiment");
    if spec_name == "experiment" {
        format!("`gridcollect train|allreduce --policy-file {path}`")
    } else {
        format!("`gridcollect train --spec {spec_name} --policy-file {path}`")
    }
}

/// Attach the request's topology parameters for a daemon-routed
/// command: an inline cost matrix when `--matrix` is given (the daemon
/// infers the clustering just like the in-process path), otherwise the
/// `--spec` name, plus the strategy token either way.
fn daemon_topology(args: &Args, req: JsonObj) -> Result<JsonObj> {
    let req = req.str("strategy", args.get_or("strategy", "multilevel"));
    Ok(match args.get("matrix") {
        Some(path) => {
            let csv = std::fs::read_to_string(path).map_err(|e| Error::io(path, e))?;
            req.str("matrix_csv", &csv)
        }
        None => req.str("spec", args.get_or("spec", "experiment")),
    })
}

/// `allreduce --connect`: ghost-time the collective on a running
/// `gridd` daemon. The daemon resolves the policy from its verdict
/// store (falling back to uniform reduce+bcast when the point was never
/// tuned), so a `tune-composition --connect` earlier in the session
/// changes what runs here — same loop as `--policy-file`, minus the
/// file.
fn allreduce_via_daemon(args: &Args, target: &str) -> Result<()> {
    let op = args.reduce_op(ReduceOp::Sum)?;
    let size = args.get_size("size", 65536)?;
    let req = JsonObj::new()
        .str("cmd", "allreduce")
        .str("op", op.name())
        .num_usize("bytes", size)
        .num_usize("root", args.get_usize("root", 0)?);
    let req = daemon_topology(args, req)?;
    let mut client = Client::connect(&Target::parse(target))?;
    let doc = client.request(&req.render())?;
    let policy = doc.get("policy").and_then(|v| v.as_str()).unwrap_or("?");
    let makespan = doc.get("makespan_us").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
    let wan = doc.get("wan_msgs").and_then(|v| v.as_u64()).unwrap_or(0);
    println!(
        "gridd {} allreduce ({}) of {}: policy {policy} — makespan {}, WAN msgs {wan}",
        Target::parse(target),
        op.name(),
        fmt::bytes(size),
        fmt::time_us(makespan)
    );
    Ok(())
}

/// `tune-composition --connect`: one daemon-side tune request per
/// payload size. Identical requests racing from other clients coalesce
/// into a single ghost sweep on the daemon (`source` says whether this
/// reply was tuned fresh, coalesced onto someone else's flight, or
/// served from the persistent verdict store).
fn tune_via_daemon(args: &Args, target: &str) -> Result<()> {
    let sizes = args.sizes(&[4096, 65536, 1 << 20])?;
    let op = args.reduce_op(ReduceOp::Sum)?;
    args.search_mode()?; // validate --mode locally for early errors
    let mut client = Client::connect(&Target::parse(target))?;
    println!(
        "E15 via gridd at {} — per-level composition autotuning ({}):\n",
        Target::parse(target),
        op.name()
    );
    for &bytes in &sizes {
        let req = JsonObj::new()
            .str("cmd", "tune")
            .str("kind", "composition")
            .str("op", op.name())
            .num_usize("bytes", bytes)
            .str("mode", args.get_or("mode", "auto"));
        let req = daemon_topology(args, req)?;
        let doc = client.request(&req.render())?;
        let policy = doc.get("policy").and_then(|v| v.as_str()).unwrap_or("?");
        let best_us = doc.get("best_us").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
        let probes = doc.get("probes").and_then(|v| v.as_u64()).unwrap_or(0);
        let source = doc.get("source").and_then(|v| v.as_str()).unwrap_or("?");
        println!(
            "  {:>10}: {policy} ({}) — {probes} probes [{source}]",
            fmt::bytes(bytes),
            fmt::time_us(best_us)
        );
    }
    println!("\nverdicts live in the daemon's policy store (and its --policy-dir, when set).");
    Ok(())
}

/// Read one benchkit `BENCH_*.json` back as `(case name, median_us)`
/// rows (file order preserved; written by `benchkit::save_bench_json`).
fn load_bench_cases(path: &std::path::Path) -> Result<Vec<(String, f64)>> {
    let label = path.display().to_string();
    let text = std::fs::read_to_string(path).map_err(|e| Error::io(label.clone(), e))?;
    let doc = gridcollect::util::json::parse(&text)?;
    let results = doc
        .get("results")
        .and_then(|r| r.as_array())
        .ok_or_else(|| Error::Config(format!("{label}: no \"results\" array")))?;
    let mut cases = Vec::with_capacity(results.len());
    for r in results {
        let name = r.get("name").and_then(|v| v.as_str());
        let median_us = r.get("median_us").and_then(|v| v.as_f64());
        match (name, median_us) {
            (Some(name), Some(median_us)) => cases.push((name.to_string(), median_us)),
            _ => {
                return Err(Error::Config(format!(
                    "{label}: result entries need a string \"name\" and numeric \"median_us\""
                )))
            }
        }
    }
    Ok(cases)
}

/// The `BENCH_*.json` files under `path` (sorted by file name), or
/// `path` itself when it names a single file.
fn bench_json_files(path: &str) -> Result<Vec<std::path::PathBuf>> {
    let p = std::path::Path::new(path);
    if !p.is_dir() {
        return Ok(vec![p.to_path_buf()]);
    }
    let entries = std::fs::read_dir(p).map_err(|e| Error::io(path.to_string(), e))?;
    let mut files: Vec<std::path::PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|f| {
            f.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
        })
        .collect();
    files.sort();
    Ok(files)
}

fn run(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw)?;
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "fig8" => {
            let sizes = args.sizes(&timing_app::default_sizes())?;
            let (table, _) = experiment::fig8_table_with_mode(&sizes, args.exec_mode()?)?;
            println!("E1 / Figure 8 — rotating-root MPI_Bcast on the paper grid (48 procs),");
            println!("each point one fused ghost simulation of the whole rotation:\n");
            print!("{}", table.to_markdown());
            if args.has("fused") {
                let strategy = args.strategy(Strategy::Multilevel)?;
                println!(
                    "\nE13 — fused rotation vs summed isolated makespans ({}):\n",
                    strategy.name()
                );
                print!(
                    "{}",
                    experiment::fig8_fused_vs_separate(&sizes, strategy)?.to_markdown()
                );
            }
        }
        "suite" => {
            let size = args.get_size("size", 65536)?;
            let xla = maybe_xla(&args)?;
            let (_rt, combiner): (Option<Runtime>, Arc<dyn Combiner>) = match xla {
                Some((rt, c)) => (Some(rt), Arc::new(c)),
                None => (None, experiment::native_arc()),
            };
            println!("E8 — six collectives x four strategies ({}):\n", fmt::bytes(size));
            print!("{}", experiment::collectives_suite_table(size, combiner)?.to_markdown());
        }
        "allreduce" => {
            if let Some(target) = args.get("connect") {
                return allreduce_via_daemon(&args, target);
            }
            let size = args.get_size("size", 65536)?;
            let xla = maybe_xla(&args)?;
            let (_rt, combiner): (Option<Runtime>, Arc<dyn Combiner>) = match xla {
                Some((rt, c)) => (Some(rt), Arc::new(c)),
                None => (None, experiment::native_arc()),
            };
            let op = args.reduce_op(ReduceOp::Sum)?;
            let boundary = args.get_usize("boundary", 1)?;
            println!(
                "E12 — multilevel allreduce ({}), every composition policy, every strategy ({}):\n",
                op.name(),
                fmt::bytes(size)
            );
            print!(
                "{}",
                experiment::allreduce_table(size, op, combiner.clone(), boundary)?.to_markdown()
            );
            if let Some(path) = args.get("policy-file") {
                // The tuner → workload loop: resolve this size through
                // the persisted table and run the winning policy. The
                // session honors --spec / --matrix (default: the
                // experiment grid, matching tune-boundary's default) so
                // any tuned topology — hand-written or discovered — can
                // be consumed.
                let comm = resolve_comm(&args, "experiment")?;
                let strategy = args.strategy(Strategy::Multilevel)?;
                let session = GridSession::new(&comm, presets::paper_grid(), strategy)
                    .with_combiner(combiner)
                    .with_policy_file(path)?;
                // Resolve once and run exactly that policy, so the
                // printed name is always what executed.
                let policy = session.resolve_policy(op, size)?;
                let n = comm.size();
                let elems = (size / 4).max(1);
                let contributions: Vec<Vec<f32>> = (0..n)
                    .map(|r| (0..elems).map(|i| (1 + (r + i) % 9) as f32).collect())
                    .collect();
                let out = session.allreduce_with_policy(policy, 0, op, &contributions)?;
                println!(
                    "\ntuned policy from {path} for {}: {} — makespan {}, WAN msgs {}",
                    fmt::bytes(size),
                    policy.name(),
                    fmt::time_us(out.sim.makespan_us),
                    out.sim.wan_messages()
                );
            }
        }
        "tune-boundary" => {
            let sizes = args.sizes(&[4096, 65536, 1 << 20])?;
            let op = args.reduce_op(ReduceOp::Sum)?;
            let strategy = args.strategy(Strategy::Multilevel)?;
            let comm = resolve_comm(&args, "experiment")?;
            let session = GridSession::new(&comm, presets::paper_grid(), strategy)
                .with_exec_mode(args.exec_mode()?);
            println!(
                "E14 — allreduce composition-boundary autotuning ({} strategy, {} ranks,",
                strategy.name(),
                comm.size()
            );
            println!("ghost probes: timing-only simulation, zero payload allocation):\n");
            let (table, policy_table) = session.tune_boundary(op, &sizes)?;
            print!("{}", table.to_markdown());
            println!("\nwinning policy per payload size:");
            for e in policy_table.entries() {
                println!(
                    "  {:>10}: {} ({})",
                    fmt::bytes(e.bytes),
                    e.policy.name(),
                    fmt::time_us(e.best_us)
                );
            }
            if let Some(path) = args.get("save") {
                policy_table.save(path)?;
                let consumer = consume_hint(&args, path);
                println!(
                    "\nwrote {path}: {} tuned entries (params hash {:#018x}); consume with \
                     {consumer} (same --spec/--strategy — provenance is enforced)",
                    policy_table.len(),
                    policy_table.provenance().params_hash
                );
            }
        }
        "tune-composition" => {
            if let Some(target) = args.get("connect") {
                return tune_via_daemon(&args, target);
            }
            let sizes = args.sizes(&[4096, 65536, 1 << 20])?;
            let op = args.reduce_op(ReduceOp::Sum)?;
            let strategy = args.strategy(Strategy::Multilevel)?;
            let mode = args.search_mode()?;
            let comm = resolve_comm(&args, "experiment")?;
            let session = GridSession::new(&comm, presets::paper_grid(), strategy)
                .with_exec_mode(args.exec_mode()?);
            println!(
                "E15 — per-level composition autotuning ({} strategy, {} ranks, {} levels,",
                strategy.name(),
                comm.size(),
                comm.clustering().n_levels()
            );
            println!("ghost probes: timing-only simulation, zero payload allocation):\n");
            let engine = session.engine();
            let (table, tunings) = tuning::composition_tuning_table(&engine, op, &sizes, mode)?;
            print!("{}", table.to_markdown());
            let mut policy_table = PolicyTable::new(session.provenance());
            println!("\nwinning composition per payload size:");
            for t in &tunings {
                policy_table.record(t.op, t.bytes, t.best, t.best_us);
                println!(
                    "  {:>10}: {} ({}) — {} probes into a {}-assignment structural space [{:?}]",
                    fmt::bytes(t.bytes),
                    t.best.name(),
                    fmt::time_us(t.best_us),
                    t.probes_issued,
                    t.exhaustive_space,
                    t.mode
                );
            }
            if let Some(path) = args.get("save") {
                policy_table.save(path)?;
                let consumer = consume_hint(&args, path);
                println!(
                    "\nwrote {path}: {} tuned entries (params hash {:#018x}); consume with \
                     {consumer} (same --spec/--strategy — provenance is enforced)",
                    policy_table.len(),
                    policy_table.provenance().params_hash
                );
            }
        }
        "discover" => {
            // The paper's §3.1 front half: measured pair costs →
            // inferred multilevel clustering. `--matrix m.csv` loads a
            // TACOS-style edge list; without it the matrix is
            // synthesized from `--spec` through the paper-grid cost
            // model (`--noise` relative jitter, `--seed` for
            // reproducibility) — the self-test path.
            let m = match args.get("matrix") {
                Some(path) => CostMatrix::load_tacos_csv(path)?,
                None => {
                    let spec = parse_spec(&args, "experiment")?;
                    let noise = args.get_f32("noise", 0.0)? as f64;
                    let seed = args.get_usize("seed", 1)? as u64;
                    discover::synthesize_from_spec(&spec, &presets::paper_grid(), noise, seed)
                }
            };
            if let Some(path) = args.get("out") {
                m.save_tacos_csv(path)?;
                println!("wrote {path}: {}-rank cost matrix '{}'\n", m.n_ranks(), m.name());
            }
            let probe = args.get_size("probe", discover::DEFAULT_PROBE_BYTES)?;
            let d = discover::infer_clustering(&m, probe)?;
            let c = &d.clustering;
            println!(
                "inferred hierarchy for '{}': {} ranks, {} levels ({} probes):",
                m.name(),
                c.n_ranks(),
                c.n_levels(),
                fmt::bytes(probe)
            );
            for l in 0..c.n_levels() {
                let n_clusters = c.clusters_at(l).len();
                // Bands ascend by cost (cheapest merges form the
                // deepest level), so level l was glued by band
                // n_levels - 1 - l; a 1-rank matrix has no merges.
                match d.band_mean_cost_us.get(c.n_levels() - 1 - l) {
                    Some(&cost) => println!(
                        "  level {l}: {n_clusters:>3} cluster(s), glued by links ~{}",
                        fmt::time_us(cost)
                    ),
                    None => println!("  level {l}: {n_clusters:>3} cluster(s)"),
                }
            }
            if !d.cut_costs_us.is_empty() {
                let cuts: Vec<String> = d.cut_costs_us.iter().map(|&t| fmt::time_us(t)).collect();
                println!("merge-curve cuts at: {}", cuts.join(", "));
            }
            if args.has("emit-spec") {
                let spec = discover::spec_from_clustering(m.name(), c)?;
                println!("\nround-tripped TopologySpec:");
                print!("{}", discover::render_spec_tree(&spec));
            }
        }
        "cost-model" => {
            // Latency-dominated default (the regime where the §4 closed
            // form is exact; see experiment::cost_model_table docs).
            let size = args.get_size("size", 1024)?;
            println!("E2 — §4 closed-form model vs simulator ({}):\n", fmt::bytes(size));
            print!("{}", experiment::cost_model_table(size)?.to_markdown());
        }
        "ablation" => {
            let sites = args.get_usize("sites", 8)?;
            let size = args.get_size("size", 65536)?;
            println!("E9 — WAN-level tree shape ablation ({sites} sites, {}):\n", fmt::bytes(size));
            print!("{}", experiment::wan_shape_ablation(sites, size)?.to_markdown());
        }
        "scaling" => {
            let size = args.get_size("size", 65536)?;
            println!("E10 — site-count scaling at 64 procs ({}):\n", fmt::bytes(size));
            print!("{}", experiment::site_scaling_table(size)?.to_markdown());
        }
        "roots" => {
            let size = args.get_size("size", 65536)?;
            println!("E7 — root-placement sensitivity ({}):\n", fmt::bytes(size));
            print!("{}", experiment::root_sensitivity_table(size)?.to_markdown());
        }
        "tree" => {
            let spec = parse_spec(&args, "fig1")?;
            let root = args.get_usize("root", 0)?;
            print!("{}", experiment::render_strategy_trees(&spec, root)?);
            let comm = Communicator::world(&spec);
            for s in Strategy::ALL {
                println!("--- {} message accounting (64 KiB bcast) ---", s.name());
                print!("{}", experiment::message_accounting(&comm, s, 65536)?.to_markdown());
            }
        }
        "rsl" => {
            let path = args
                .positional
                .get(1)
                .ok_or_else(|| Error::Cli("rsl: need a script path".into()))?;
            let src = std::fs::read_to_string(path).map_err(|e| Error::io(path.clone(), e))?;
            let spec = rsl::topology_from_script(&src)?;
            println!(
                "parsed RSL: {} machines, {} processes, {} levels",
                spec.machines().len(),
                spec.n_procs(),
                spec.n_levels()
            );
            let root = args.get_usize("root", 0)?;
            print!("{}", experiment::render_strategy_trees(&spec, root)?);
        }
        "train" => {
            let rt = Runtime::open(
                args.get("artifacts")
                    .map(Into::into)
                    .unwrap_or_else(gridcollect::runtime::artifacts::default_dir),
            )?;
            let mlp = MlpRuntime::open(&rt)?;
            // Default topology is the paper's experiment grid — the
            // same default as tune-boundary/fig8/suite/allreduce, so
            // `tune-boundary --save t.json && train --policy-file
            // t.json` works as-is; `--spec fig1` selects the small
            // Fig. 1 grid and `--matrix m.csv` a discovered one (tune
            // with the same topology so a `--policy-file`'s provenance
            // matches).
            let comm = resolve_comm(&args, "experiment")?;
            let strategy = args.strategy(Strategy::Multilevel)?;
            let mut session = GridSession::new(&comm, presets::paper_grid(), strategy)
                .with_exec_mode(args.exec_mode()?);
            // The native combiner is Sync, so sharded full-mode runs can
            // share it across shard workers; an --xla combiner's
            // thread-safety is unknown here, so those runs fall back to
            // the sequential engine (identical results either way).
            session = if args.has("xla") {
                session.with_combiner(Arc::new(XlaCombiner::open_default(&rt)?))
            } else {
                session.with_sync_combiner(Arc::new(NativeCombiner))
            };
            let pinned = args.algo_policy_opt()?;
            if let Some(path) = args.get("policy-file") {
                if pinned.is_some() {
                    return Err(Error::Cli(
                        "--policy-file and --algo/--boundary are mutually exclusive \
                         (the file resolves the policy)"
                            .into(),
                    ));
                }
                session = session.with_policy_file(path)?;
            }
            let cfg = training::TrainConfig {
                steps: args.get_usize("steps", 50)?,
                lr: args.get_f32("lr", 0.1)?,
                allreduce: pinned,
                seed: args.get_usize("seed", 0)? as u64,
            };
            println!(
                "E11 — data-parallel training: {} workers ({}), strategy {}, \
                 policy provider {}, combiner {}",
                comm.size(),
                comm.name(),
                strategy.name(),
                session.policy_name(),
                session.combiner().name(),
            );
            let logs = training::train(&session, &mlp, &cfg)?;
            for l in logs.iter().step_by((logs.len() / 10).max(1)) {
                println!(
                    "step {:>3}  loss {:.4}  comm {:>12} (reduce {} | bcast {})  wan_msgs {}  compute {:>10}",
                    l.step,
                    l.mean_loss,
                    fmt::time_us(l.comm_us),
                    fmt::time_us(l.reduce_us),
                    fmt::time_us(l.bcast_us),
                    l.wan_msgs,
                    fmt::time_us(l.compute_wall_us)
                );
            }
            let first = logs.first().unwrap();
            let last = logs.last().unwrap();
            println!(
                "loss {:.4} -> {:.4} over {} steps; allreduce policy {}; per-step comm {}",
                first.mean_loss,
                last.mean_loss,
                logs.len(),
                last.policy.name(),
                fmt::time_us(last.comm_us)
            );
        }
        "gantt" => {
            // Visualize one collective's simulated timeline.
            let spec = TopologySpec::paper_fig1();
            let comm = Communicator::world(&spec);
            let size = args.get_size("size", 16384)?;
            let strategy = args.strategy(Strategy::Multilevel)?;
            let params = match args.get("params") {
                Some(path) => gridcollect::config::network_params_from_file(path)?,
                None => presets::paper_grid(),
            };
            let session = GridSession::new(&comm, params, strategy).with_trace();
            let out = session.bcast(args.get_usize("root", 0)?, &vec![0.0f32; size / 4])?;
            println!(
                "{} bcast of {} on fig1 ({} ranks):",
                strategy.name(),
                fmt::bytes(size),
                comm.size()
            );
            print!("{}", gridcollect::coordinator::report::gantt(&out.sim, 100));
            println!(
                "{}",
                gridcollect::coordinator::report::level_summary(
                    &out.sim,
                    comm.clustering().n_levels()
                )
            );
        }
        "calibrate" => {
            let rt = Runtime::open(gridcollect::runtime::artifacts::default_dir())?;
            let c = XlaCombiner::open_default(&rt)?;
            let us_per_byte = calibrate_us_per_byte(&c, 50);
            println!("PJRT combine throughput: {:.6} us/byte ({:.1} MB/s)", us_per_byte, 1.0 / us_per_byte);
            println!("suggested NetworkParams::combine_us_per_byte = {us_per_byte:.6}");
            if let Some(path) = args.get("out") {
                let params = presets::paper_grid().with_combine_us_per_byte(us_per_byte);
                let text = gridcollect::config::render_network_params(&params);
                std::fs::write(path, text).map_err(|e| Error::io(path, e))?;
                println!("wrote {path} (paper_grid preset with calibrated combine cost)");
            }
        }
        "bench-diff" => {
            // The perf-trajectory gate: committed baseline snapshots
            // (bench-reports/baseline/) vs a fresh run's BENCH_*.json.
            // Soft by design — regressions are printed, the exit status
            // stays 0 — because shared-CI-runner wall-clock noise would
            // make a hard gate flaky; the log line is the signal.
            let base_root = args
                .positional
                .get(1)
                .ok_or_else(|| Error::Cli("bench-diff: need <baseline> <current> paths".into()))?;
            let new_root = args
                .positional
                .get(2)
                .ok_or_else(|| Error::Cli("bench-diff: need <baseline> <current> paths".into()))?;
            let threshold = args.get_f32("threshold", 0.25)? as f64;
            let new_files = bench_json_files(new_root)?;
            let file_name = |p: &std::path::Path| {
                p.file_name().and_then(|n| n.to_str()).unwrap_or_default().to_string()
            };
            let (mut compared, mut regressions, mut improvements) = (0usize, 0usize, 0usize);
            println!(
                "bench trajectory diff: {base_root} (baseline) vs {new_root} \
                 (threshold ±{:.0}% on median_us)\n",
                threshold * 100.0
            );
            for base_path in bench_json_files(base_root)? {
                let name = file_name(&base_path);
                let Some(new_path) = new_files.iter().find(|p| file_name(p) == name) else {
                    println!("{name}: missing from {new_root} — no current run to compare");
                    continue;
                };
                println!("{name}:");
                let base_cases = load_bench_cases(&base_path)?;
                let new_cases = load_bench_cases(new_path)?;
                for (case, base_us) in &base_cases {
                    let Some((_, new_us)) = new_cases.iter().find(|(n, _)| n == case) else {
                        println!("  {case:<44} dropped (in baseline only)");
                        continue;
                    };
                    compared += 1;
                    let delta = (new_us - base_us) / base_us.max(1e-9);
                    let marker = if delta >= threshold {
                        regressions += 1;
                        "  <-- slower than baseline"
                    } else if delta <= -threshold {
                        improvements += 1;
                        "  (faster than baseline)"
                    } else {
                        ""
                    };
                    println!(
                        "  {case:<44} {:>12} -> {:>12}  {:+6.1}%{marker}",
                        fmt::time_us(*base_us),
                        fmt::time_us(*new_us),
                        delta * 100.0
                    );
                }
                for (case, _) in &new_cases {
                    if !base_cases.iter().any(|(n, _)| n == case) {
                        println!("  {case:<44} new (no baseline; refresh the snapshots)");
                    }
                }
            }
            println!(
                "\n{compared} case(s) compared: {regressions} beyond +{:.0}%, \
                 {improvements} beyond -{:.0}% (soft gate — always exit 0)",
                threshold * 100.0,
                threshold * 100.0
            );
        }
        "help" | _ => {
            println!("{USAGE}");
        }
    }
    Ok(())
}
