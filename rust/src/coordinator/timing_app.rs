//! The paper's Figure-7 broadcast timing application.
//!
//! For each message size M: barrier, then every rank takes a turn as the
//! broadcast root, with an **ack-barrier** (every rank sends ACK to rank
//! 0; rank 0 answers each with GO, one at a time) after each broadcast to
//! kill inter-broadcast pipelining. The reported number for M is the total
//! virtual time of the root rotation — exactly what `t1 - t0` measures in
//! Fig. 7.

use crate::collectives::CollectiveEngine;
use crate::error::Result;
use crate::model::NetworkParams;
use crate::netsim::{run, Combiner, Merge, NativeCombiner, Payload, Program, SendPart, SimConfig};
use crate::topology::Communicator;
use crate::tree::Strategy;

/// One sweep point of the Fig. 8 curve.
#[derive(Clone, Debug)]
pub struct TimingPoint {
    pub bytes: usize,
    pub strategy: Strategy,
    /// Total virtual time for the full root rotation (us) — the paper's y-axis.
    pub total_us: f64,
    /// Mean per-broadcast time (us), ack-barrier excluded.
    pub mean_bcast_us: f64,
    /// WAN messages across the whole rotation (broadcasts only).
    pub wan_msgs: u64,
    /// All messages across the rotation (broadcasts only).
    pub total_msgs: u64,
}

/// The paper's hand-rolled ack-barrier (§4): flat fan-in of ACKs to rank
/// 0, then rank 0 sends GO to each rank one at a time. Deliberately *not*
/// the (reimplemented, topology-aware) MPI_Barrier, for the reason the
/// paper gives.
pub fn ack_barrier_program(n: usize, tag: u64) -> Program {
    let mut p = Program::new(n);
    for r in 1..n {
        p.send(r, 0, tag, SendPart::Empty);
    }
    for r in 1..n {
        p.recv(0, r, tag, Merge::Discard);
    }
    for r in 1..n {
        p.send(0, r, tag + 1, SendPart::Empty);
        p.recv(r, 0, tag + 1, Merge::Discard);
    }
    p
}

/// Run the Fig. 7 application for one (strategy, message size) pair.
pub fn run_point(
    comm: &Communicator,
    params: &NetworkParams,
    strategy: Strategy,
    bytes: usize,
    combiner: &dyn Combiner,
) -> Result<TimingPoint> {
    assert_eq!(bytes % 4, 0, "message size must be f32-aligned");
    let n = comm.size();
    let data = vec![1.0f32; bytes / 4];
    let engine = CollectiveEngine::new(comm, params.clone(), strategy).with_combiner(combiner);
    let ack_cfg = SimConfig::new(params.clone());

    let mut total_us = 0.0;
    let mut bcast_us_sum = 0.0;
    let mut wan_msgs = 0;
    let mut total_msgs = 0;
    for root in 0..n {
        // measurement path: no per-rank payload materialization
        let sim = engine.bcast_sim(root, &data)?;
        total_us += sim.makespan_us;
        bcast_us_sum += sim.makespan_us;
        wan_msgs += sim.wan_messages();
        total_msgs += sim.msgs_by_sep.iter().sum::<u64>();
        // ack barrier between broadcasts
        let ack = ack_barrier_program(n, 1_000_000 + root as u64 * 4);
        let sim = run(
            comm.clustering(),
            &ack,
            vec![Payload::empty(); n],
            &ack_cfg,
            &NativeCombiner,
        )?;
        total_us += sim.makespan_us;
    }
    Ok(TimingPoint {
        bytes,
        strategy,
        total_us,
        mean_bcast_us: bcast_us_sum / n as f64,
        wan_msgs,
        total_msgs,
    })
}

/// Full Fig. 8 sweep: all strategies × all message sizes.
pub fn fig8_sweep(
    comm: &Communicator,
    params: &NetworkParams,
    sizes: &[usize],
    strategies: &[Strategy],
    combiner: &dyn Combiner,
) -> Result<Vec<TimingPoint>> {
    let mut out = Vec::with_capacity(sizes.len() * strategies.len());
    for &bytes in sizes {
        for &s in strategies {
            out.push(run_point(comm, params, s, bytes, combiner)?);
        }
    }
    Ok(out)
}

/// The default Fig. 8 message-size grid: 1 KiB to 1 MiB, doubling.
pub fn default_sizes() -> Vec<usize> {
    (0..=10).map(|i| 1024usize << i).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::topology::TopologySpec;

    #[test]
    fn ack_barrier_is_balanced_and_sequential() {
        let p = ack_barrier_program(4, 100);
        p.validate().unwrap();
        // 2*(n-1) messages
        let total: usize = p.actions.iter().map(|a| a.len()).sum();
        assert_eq!(total, 4 * 3);
    }

    #[test]
    fn fig8_ordering_holds_at_64k() {
        // The paper's experiment topology; one representative size.
        let comm = Communicator::world(&TopologySpec::paper_experiment());
        let params = presets::paper_grid();
        let get = |s: Strategy| {
            run_point(&comm, &params, s, 65536, &NativeCombiner).unwrap().total_us
        };
        let unaware = get(Strategy::Unaware);
        let machine = get(Strategy::TwoLevelMachine);
        let site = get(Strategy::TwoLevelSite);
        let multi = get(Strategy::Multilevel);
        // Fig. 8 ordering: multilevel fastest; every topology-aware
        // variant beats the binomial tree.
        assert!(multi < site, "multilevel {multi} !< site {site}");
        assert!(multi < machine, "multilevel {multi} !< machine {machine}");
        assert!(site < unaware);
        assert!(machine < unaware);
    }

    #[test]
    fn multilevel_wan_messages_one_per_bcast() {
        let comm = Communicator::world(&TopologySpec::paper_experiment());
        let params = presets::paper_grid();
        let pt =
            run_point(&comm, &params, Strategy::Multilevel, 4096, &NativeCombiner).unwrap();
        // one WAN message per broadcast, one broadcast per rank
        assert_eq!(pt.wan_msgs, comm.size() as u64);
    }

    #[test]
    fn sweep_shape() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let params = presets::paper_grid();
        let pts = fig8_sweep(
            &comm,
            &params,
            &[1024, 4096],
            &[Strategy::Unaware, Strategy::Multilevel],
            &NativeCombiner,
        )
        .unwrap();
        assert_eq!(pts.len(), 4);
        // larger messages cost more, same strategy
        assert!(pts[0].total_us < pts[2].total_us);
    }
}
