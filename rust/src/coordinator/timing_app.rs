//! The paper's Figure-7 broadcast timing application.
//!
//! For each message size M: barrier, then every rank takes a turn as the
//! broadcast root, with an **ack-barrier** (every rank sends ACK to rank
//! 0; rank 0 answers each with GO, one at a time) after each broadcast to
//! kill inter-broadcast pipelining. The reported number for M is the total
//! virtual time of the root rotation — exactly what `t1 - t0` measures in
//! Fig. 7.
//!
//! Fidelity note: the paper measures `t1 - t0` over one *continuous* run
//! of the whole rotation, so [`run_point_with`] fuses the 2n phases (n
//! broadcasts, n ack-barriers) into a single [`Schedule`] and executes
//! **one** engine run per point. Summing per-phase makespans of
//! isolated simulations — the pre-fusion implementation, kept as
//! [`run_point_separate`] for A/B comparison — erases every cross-phase
//! effect: a straggler rank entering the next broadcast late, ack/GO
//! control traffic overlapping the tail of a broadcast.
//!
//! Perf note: a timing point only needs *timing*, so [`run_point_with`]
//! executes the rotation in **ghost mode**
//! ([`GridSession::run_schedule_timing`]) — bit-identical virtual
//! times, zero payload allocation — against the session's **memoized**
//! rotation schedule ([`rotation_schedule_memo`]): the schedule is
//! payload-independent, so a warm sweep point performs zero tree builds,
//! zero compiles, zero schedule assemblies, zero scratch growth and
//! exactly one engine invocation (asserted in
//! `rust/tests/fused_timing.rs`).

use crate::collectives::GhostProber;
use crate::error::Result;
use crate::model::NetworkParams;
use crate::netsim::{
    run, ExecMode, GhostPayload, Merge, Payload, Program, SendPart, SimConfig, SimResult,
};
use crate::plan::{OpKind, PlanCache, Schedule};
use crate::session::GridSession;
use crate::topology::Communicator;
use crate::tree::Strategy;
use crate::util::par;
use std::sync::Arc;

/// One sweep point of the Fig. 8 curve.
#[derive(Clone, Debug)]
pub struct TimingPoint {
    pub bytes: usize,
    pub strategy: Strategy,
    /// Total virtual time for the full root rotation (us) — the paper's y-axis.
    pub total_us: f64,
    /// Mean per-broadcast time (us), ack-barrier excluded. For the fused
    /// path this is the mean critical-path residual of the broadcast
    /// segments (overlap with the preceding ack tail already discounted).
    pub mean_bcast_us: f64,
    /// Mean ack-barrier time (us) between broadcasts.
    pub mean_ack_us: f64,
    /// WAN messages across the whole rotation (broadcasts only).
    pub wan_msgs: u64,
    /// All messages across the rotation (broadcasts only).
    pub total_msgs: u64,
}

/// The paper's hand-rolled ack-barrier (§4): flat fan-in of ACKs to rank
/// 0, then rank 0 sends GO to each rank one at a time. Deliberately *not*
/// the (reimplemented, topology-aware) MPI_Barrier, for the reason the
/// paper gives.
pub fn ack_barrier_program(n: usize, tag: u64) -> Program {
    let mut p = Program::new(n);
    for r in 1..n {
        p.send(r, 0, tag, SendPart::Empty);
    }
    for r in 1..n {
        p.recv(0, r, tag, Merge::Discard);
    }
    for r in 1..n {
        p.send(0, r, tag + 1, SendPart::Empty);
        p.recv(r, 0, tag + 1, Merge::Discard);
    }
    p
}

/// Assemble the full Fig. 7 root rotation — n × (broadcast from root r ;
/// ack-barrier) — as one fused, tag-rebased, validated [`Schedule`].
/// Even segments are broadcasts, odd segments ack-barriers. On a warm
/// plan cache assembly performs zero tree builds and zero compiles
/// (cached programs are cloned and integer-rebased).
pub fn rotation_schedule(session: &GridSession) -> Result<Schedule> {
    let n = session.comm().size();
    let mut b = session.schedule_builder();
    for root in 0..n {
        let plan = session.plan_for(root, OpKind::Bcast, 1)?;
        b.add_plan(&format!("bcast@{root}"), &plan)?;
        b.add_program(&format!("ack@{root}"), ack_barrier_program(n, 1))?;
    }
    b.build()
}

/// The session's memoized Fig. 7 rotation (built once per session via
/// [`GridSession::memo_schedule`]; the schedule depends only on the
/// session's topology/strategy, never on the payload size). Sweeps and
/// benches share this slot so a warm point re-assembles nothing.
pub fn rotation_schedule_memo(session: &GridSession) -> Result<Arc<Schedule>> {
    session.memo_schedule("fig7-rotation", || rotation_schedule(session))
}

/// Run the Fig. 7 application for one message size on `session`, as a
/// **single fused ghost simulation** of the whole rotation (the point
/// only reports timing, and ghost timing is bit-identical to the full
/// run's — `rust/tests/ghost_equivalence.rs`).
///
/// Only rank 0 (the first root) is seeded: every later root
/// re-broadcasts the register it received in an earlier phase, exactly
/// as the paper's application broadcasts same-sized buffers in turn —
/// wire bytes per phase are identical to the isolated runs.
pub fn run_point_with(session: &GridSession, bytes: usize) -> Result<TimingPoint> {
    assert_eq!(bytes % 4, 0, "message size must be f32-aligned");
    let n = session.comm().size();
    let schedule = rotation_schedule_memo(session)?;
    let mut init = vec![GhostPayload::empty(); n];
    init[0] = GhostPayload::single(0, bytes / 4);
    let sim = session.run_schedule_timing(&schedule, init)?;
    point_from_segments(&schedule, &sim, session.strategy(), bytes, n)
}

/// The ghost-run core of [`run_point_with`], driven through a
/// [`GhostProber`] so independent sweep points can fan out across worker
/// threads ([`fig8_sweep_with_mode`]): one timing-only run of the fused
/// rotation into the caller's pooled result buffer, then the per-segment
/// decomposition. Bit-identical to [`run_point_with`] on the same
/// (strategy, size) point.
fn run_point_ghost(
    prober: &GhostProber<'_>,
    schedule: &Schedule,
    strategy: Strategy,
    bytes: usize,
    sim: &mut SimResult,
) -> Result<TimingPoint> {
    assert_eq!(bytes % 4, 0, "message size must be f32-aligned");
    let n = prober.comm().size();
    let mut init = vec![GhostPayload::empty(); n];
    init[0] = GhostPayload::single(0, bytes / 4);
    prober.run_schedule_timing_into(schedule, init, sim)?;
    point_from_segments(schedule, sim, strategy, bytes, n)
}

/// Decompose one fused-rotation result into the Fig. 8 point (total,
/// per-phase means, broadcast message accounting).
fn point_from_segments(
    schedule: &Schedule,
    sim: &SimResult,
    strategy: Strategy,
    bytes: usize,
    n: usize,
) -> Result<TimingPoint> {
    let durations = schedule.segment_durations(sim)?;
    let mut bcast_us_sum = 0.0;
    let mut ack_us_sum = 0.0;
    let mut wan_msgs = 0;
    let mut total_msgs = 0;
    for (i, (seg, &d)) in schedule.segments().iter().zip(&durations).enumerate() {
        if i % 2 == 0 {
            // broadcast segment (see rotation_schedule ordering)
            bcast_us_sum += d;
            wan_msgs += seg.meta.wan_messages();
            total_msgs += seg.meta.total_messages();
        } else {
            ack_us_sum += d;
        }
    }
    Ok(TimingPoint {
        bytes,
        strategy,
        total_us: sim.makespan_us,
        mean_bcast_us: bcast_us_sum / n as f64,
        mean_ack_us: ack_us_sum / n as f64,
        wan_msgs,
        total_msgs,
    })
}

/// The pre-fusion implementation: every broadcast and every ack-barrier
/// is an isolated `netsim::run` and the point is the **sum** of 2n
/// makespans. Kept for A/B comparison (`gridcollect fig8 --fused`
/// comparison table, the `fused_schedule` bench); it overstates the
/// rotation by serializing phases that the continuous measurement
/// overlaps, and costs 2n engine invocations per point.
pub fn run_point_separate(session: &GridSession, bytes: usize) -> Result<TimingPoint> {
    assert_eq!(bytes % 4, 0, "message size must be f32-aligned");
    let comm = session.comm();
    let n = comm.size();
    let data = vec![1.0f32; bytes / 4];
    let ack_cfg = SimConfig::new(session.params().clone());
    // One engine view for the whole 2n-phase loop (per-root views would
    // re-clone the cost model and level policy 2n times per point).
    let engine = session.engine();

    let mut total_us = 0.0;
    let mut bcast_us_sum = 0.0;
    let mut ack_us_sum = 0.0;
    let mut wan_msgs = 0;
    let mut total_msgs = 0;
    for root in 0..n {
        // measurement path: no per-rank payload materialization
        let sim = engine.bcast_sim(root, &data)?;
        total_us += sim.makespan_us;
        bcast_us_sum += sim.makespan_us;
        wan_msgs += sim.wan_messages();
        total_msgs += sim.msgs_by_sep.iter().sum::<u64>();
        // ack barrier between broadcasts
        let ack = ack_barrier_program(n, 1_000_000 + root as u64 * 4);
        let sim = run(
            comm.clustering(),
            &ack,
            vec![Payload::empty(); n],
            &ack_cfg,
            session.combiner(),
        )?;
        total_us += sim.makespan_us;
        ack_us_sum += sim.makespan_us;
    }
    Ok(TimingPoint {
        bytes,
        strategy: session.strategy(),
        total_us,
        mean_bcast_us: bcast_us_sum / n as f64,
        mean_ack_us: ack_us_sum / n as f64,
        wan_msgs,
        total_msgs,
    })
}

/// Run the Fig. 7 application for one (strategy, message size) pair.
///
/// Convenience wrapper over [`run_point_with`] that opens a one-shot
/// session (cold cache). Sweeps should hold a [`GridSession`] (or share
/// a [`PlanCache`]) and call [`run_point_with`] so repeated points stay
/// warm — see [`fig8_sweep`].
pub fn run_point(
    comm: &Communicator,
    params: &NetworkParams,
    strategy: Strategy,
    bytes: usize,
) -> Result<TimingPoint> {
    let session = GridSession::new(comm, params.clone(), strategy);
    run_point_with(&session, bytes)
}

/// Full Fig. 8 sweep: all strategies × all message sizes, fused. One
/// long-lived session per strategy shares a single [`PlanCache`] and one
/// scratch arena, so only the first point per strategy builds plans —
/// every later size reuses them (plans are payload-size-independent).
pub fn fig8_sweep(
    comm: &Communicator,
    params: &NetworkParams,
    sizes: &[usize],
    strategies: &[Strategy],
) -> Result<Vec<TimingPoint>> {
    fig8_sweep_with_mode(comm, params, sizes, strategies, ExecMode::Sequential)
}

/// [`fig8_sweep`] under an explicit execution mode — the `--threads`
/// CLI flag routes here. Sweep points are independent ghost runs, so
/// `ExecMode::Sharded { threads }` fans the whole size × strategy point
/// grid across `threads` workers (each point simulated sequentially by
/// one worker through a [`GhostProber`]); results merge back in
/// size-major order, bitwise-identical to the sequential sweep.
pub fn fig8_sweep_with_mode(
    comm: &Communicator,
    params: &NetworkParams,
    sizes: &[usize],
    strategies: &[Strategy],
    mode: ExecMode,
) -> Result<Vec<TimingPoint>> {
    let cache = Arc::new(PlanCache::new());
    let scratch = Arc::new(crate::netsim::ExecScratch::new());
    let sessions: Vec<GridSession> = strategies
        .iter()
        .map(|&s| {
            GridSession::new(comm, params.clone(), s)
                .with_plan_cache(cache.clone())
                .with_scratch(scratch.clone())
                .with_exec_mode(mode)
        })
        .collect();
    let threads = match mode {
        ExecMode::Sharded { threads } => threads,
        ExecMode::Sequential => 1,
    };
    if threads <= 1 || sessions.is_empty() {
        let mut out = Vec::with_capacity(sizes.len() * strategies.len());
        for &bytes in sizes {
            for session in &sessions {
                out.push(run_point_with(session, bytes)?);
            }
        }
        return Ok(out);
    }
    // Assemble each strategy's rotation schedule serially first (plan
    // building and schedule assembly stay single-threaded and memoized),
    // then fan the embarrassingly-parallel point grid out across the
    // worker pool.
    let prepared = sessions
        .iter()
        .map(|s| Ok((s.ghost_prober(), rotation_schedule_memo(s)?)))
        .collect::<Result<Vec<_>>>()?;
    let n_points = sizes.len() * prepared.len();
    let results = par::map_pooled(threads, n_points, SimResult::default, |sim, i| {
        let bytes = sizes[i / prepared.len()];
        let (prober, schedule) = &prepared[i % prepared.len()];
        run_point_ghost(prober, schedule, strategies[i % prepared.len()], bytes, sim)
    });
    let mut out = Vec::with_capacity(n_points);
    for r in results {
        out.push(r?);
    }
    Ok(out)
}

/// The default Fig. 8 message-size grid: 1 KiB to 1 MiB, doubling.
pub fn default_sizes() -> Vec<usize> {
    (0..=10).map(|i| 1024usize << i).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::topology::TopologySpec;

    #[test]
    fn ack_barrier_is_balanced_and_sequential() {
        let p = ack_barrier_program(4, 100);
        p.validate().unwrap();
        // 2*(n-1) messages
        let total: usize = p.actions.iter().map(|a| a.len()).sum();
        assert_eq!(total, 4 * 3);
    }

    #[test]
    fn rotation_schedule_has_2n_segments_and_validates() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let s = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel);
        let sched = rotation_schedule(&s).unwrap();
        assert_eq!(sched.n_segments(), 2 * comm.size());
        sched.program().validate().unwrap();
        // even segments broadcast (one message per non-root rank), odd
        // segments ack (2(n-1) control messages)
        let n = comm.size() as u64;
        for (i, seg) in sched.segments().iter().enumerate() {
            if i % 2 == 0 {
                assert_eq!(seg.meta.total_messages(), n - 1, "segment {i}");
            } else {
                assert_eq!(seg.meta.total_messages(), 2 * (n - 1), "segment {i}");
            }
        }
    }

    #[test]
    fn fig8_ordering_holds_at_64k() {
        // The paper's experiment topology; one representative size.
        let comm = Communicator::world(&TopologySpec::paper_experiment());
        let params = presets::paper_grid();
        let get = |s: Strategy| run_point(&comm, &params, s, 65536).unwrap().total_us;
        let unaware = get(Strategy::Unaware);
        let machine = get(Strategy::TwoLevelMachine);
        let site = get(Strategy::TwoLevelSite);
        let multi = get(Strategy::Multilevel);
        // Fig. 8 ordering: multilevel fastest; every topology-aware
        // variant beats the binomial tree.
        assert!(multi < site, "multilevel {multi} !< site {site}");
        assert!(multi < machine, "multilevel {multi} !< machine {machine}");
        assert!(site < unaware);
        assert!(machine < unaware);
    }

    // NB: fused-vs-separate invariants (fused ≤ separate, identical
    // message accounting) live in rust/tests/schedule_invariants.rs.

    #[test]
    fn multilevel_wan_messages_one_per_bcast() {
        let comm = Communicator::world(&TopologySpec::paper_experiment());
        let params = presets::paper_grid();
        let pt = run_point(&comm, &params, Strategy::Multilevel, 4096).unwrap();
        // one WAN message per broadcast, one broadcast per rank
        assert_eq!(pt.wan_msgs, comm.size() as u64);
    }

    #[test]
    fn sharded_sweep_is_bitwise_identical_to_sequential() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let params = presets::paper_grid();
        let sizes = [1024usize, 8192];
        let strategies = [Strategy::Unaware, Strategy::Multilevel];
        let seq = fig8_sweep(&comm, &params, &sizes, &strategies).unwrap();
        let mode = ExecMode::Sharded { threads: 4 };
        let sh = fig8_sweep_with_mode(&comm, &params, &sizes, &strategies, mode).unwrap();
        assert_eq!(seq.len(), sh.len());
        for (a, b) in seq.iter().zip(&sh) {
            assert_eq!(a.total_us.to_bits(), b.total_us.to_bits(), "{} B", a.bytes);
            assert_eq!(a.mean_bcast_us.to_bits(), b.mean_bcast_us.to_bits());
            assert_eq!(a.mean_ack_us.to_bits(), b.mean_ack_us.to_bits());
            assert_eq!(a.wan_msgs, b.wan_msgs);
            assert_eq!(a.total_msgs, b.total_msgs);
        }
    }

    #[test]
    fn sweep_shape() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let params = presets::paper_grid();
        let pts = fig8_sweep(
            &comm,
            &params,
            &[1024, 4096],
            &[Strategy::Unaware, Strategy::Multilevel],
        )
        .unwrap();
        assert_eq!(pts.len(), 4);
        // size-major order preserved: larger messages cost more, same strategy
        assert_eq!(pts[0].bytes, 1024);
        assert_eq!(pts[2].bytes, 4096);
        assert!(pts[0].total_us < pts[2].total_us);
        // phase means decompose the rotation
        for p in &pts {
            let n = comm.size() as f64;
            let recomposed = n * (p.mean_bcast_us + p.mean_ack_us);
            assert!(
                (recomposed - p.total_us).abs() < 1e-6 * p.total_us.max(1.0),
                "segment durations must sum to the rotation total"
            );
        }
    }
}
