//! End-to-end data-parallel training over the simulated grid (E11):
//! every simulated worker computes gradients through the AOT-compiled
//! train-step (L2 JAX graph via PJRT), gradients are **all-reduced with
//! the topology-aware collectives** (payload arithmetic through the L1
//! Pallas combine kernels when the session carries an `XlaCombiner`),
//! and parameters are updated with the Pallas `axpy` SGD kernel — all
//! three layers composing on one workload.
//!
//! The driver runs on a [`GridSession`]: the allreduce composition is
//! **policy-resolved** per gradient size unless pinned in the config, so
//! a session carrying a tuned [`crate::session::PolicyTable`]
//! (`gridcollect train --policy-file t.json`) transparently executes the
//! tuner's winning policy on every step.

use crate::error::{Error, Result};
use crate::netsim::{Payload, ReduceOp};
use crate::plan::{AlgoPolicy, AllreduceAlgo};
use crate::runtime::MlpRuntime;
use crate::session::GridSession;

/// Per-step record.
#[derive(Clone, Debug)]
pub struct StepLog {
    pub step: usize,
    pub mean_loss: f32,
    /// Virtual communication time of the gradient allreduce (us).
    pub comm_us: f64,
    /// Completion time of the reduce phase within the fused allreduce
    /// schedule (us). Zero when the composition runs as a single fused
    /// plan (the chunked policies: `rs+ag`, hybrid).
    pub reduce_us: f64,
    /// Critical-path residual of the broadcast phase (`comm_us -
    /// reduce_us`). Zero for the chunked policies.
    pub bcast_us: f64,
    pub wan_msgs: u64,
    /// The composition policy this step's allreduce ran under (constant
    /// across a run; recorded so logs show what the provider resolved).
    pub policy: AlgoPolicy,
    /// Wall-clock compute time of the PJRT train steps (us).
    pub compute_wall_us: f64,
}

/// Training configuration. Topology, strategy and combiner live on the
/// [`GridSession`]; this carries only the loop parameters.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    /// Pin the per-step gradient-allreduce composition (every policy is
    /// bitwise-equivalent; see [`AlgoPolicy`]). `None` — the default —
    /// asks the session's policy provider to resolve it for the gradient
    /// payload size (the tuned path under `--policy-file`).
    pub allreduce: Option<AlgoPolicy>,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { steps: 50, lr: 0.1, allreduce: None, seed: 0 }
    }
}

/// Run synchronous data-parallel SGD: one worker per communicator rank.
///
/// Workers hold identical parameter replicas; each step computes local
/// gradients on a worker-specific synthetic batch, allreduces them over
/// the simulated grid, and applies the averaged gradient. Divergence
/// between replicas is checked every step (they must stay bitwise equal:
/// same reduced gradient, same update).
pub fn train(session: &GridSession, mlp: &MlpRuntime, cfg: &TrainConfig) -> Result<Vec<StepLog>> {
    let n = session.comm().size();
    let p0 = mlp.init_params(cfg.seed);
    // Resolve the composition once: the gradient size is fixed for the
    // whole run, so the provider's verdict is too.
    let policy = match cfg.allreduce {
        Some(p) => p,
        None => session.resolve_policy(ReduceOp::Sum, p0.len() * 4)?,
    };
    // One engine view for the whole run: the per-step allreduce plan is
    // built on step 0 and served from the session's PlanCache on every
    // subsequent step (zero tree builds / program compiles / scratch
    // growth on the hot path — the pipeline's whole point for this
    // workload).
    let engine = session.engine();
    // For the uniform reduce+bcast composition the per-step exchange
    // executes as a fused two-segment Schedule (same message structure
    // and timing as the cached Allreduce plan, plus a phase boundary
    // marker), built once here and reused every step — the program is
    // payload-independent, so the hot path stays payload setup + one
    // simulation. Chunked policies (rs+ag, hybrid) run their single
    // fused plan through the generic request path instead.
    let step_schedule = if policy == AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast) {
        Some(engine.allreduce_schedule(0, ReduceOp::Sum)?)
    } else {
        None
    };
    let mut replicas: Vec<Vec<f32>> = vec![p0; n];
    let mut logs = Vec::with_capacity(cfg.steps);

    for step in 0..cfg.steps {
        // Local gradient computation (PJRT; wall-clock measured).
        let t0 = std::time::Instant::now();
        let mut grads = Vec::with_capacity(n);
        let mut loss_sum = 0.0f32;
        for w in 0..n {
            let (x, y) = mlp.synth_batch((step * n + w) as u64);
            let (g, loss) = mlp.train_step(&replicas[w], &x, &y)?;
            loss_sum += loss;
            grads.push(g);
        }
        let compute_wall_us = t0.elapsed().as_secs_f64() * 1e6;

        // Gradient allreduce over the simulated grid.
        let (reduced, comm_us, reduce_us, bcast_us, wan_msgs) = match &step_schedule {
            Some(schedule) => {
                let init: Vec<Payload> =
                    grads.iter().map(|g| Payload::single(0, g.clone())).collect();
                let sim = engine.run_schedule(schedule, init)?;
                let t = schedule.segment_completions(&sim)?;
                let data: Vec<Vec<f32>> = (0..n)
                    .map(|r| sim.payloads[r].get_cloned(&0).unwrap_or_default())
                    .collect();
                (data, sim.makespan_us, t[0], t[1] - t[0], sim.wan_messages())
            }
            None => {
                let out = engine.allreduce_with_policy(policy, 0, ReduceOp::Sum, &grads)?;
                (out.data, out.sim.makespan_us, 0.0, 0.0, out.sim.wan_messages())
            }
        };

        // SGD update with the averaged gradient (Pallas axpy kernel).
        let lr_eff = cfg.lr / n as f32;
        for w in 0..n {
            replicas[w] = mlp.sgd_step(&replicas[w], &reduced[w], lr_eff)?;
        }

        // Replica synchronization invariant.
        for w in 1..n {
            if replicas[w] != replicas[0] {
                return Err(Error::Verify(format!(
                    "replica divergence at step {step}, worker {w}"
                )));
            }
        }

        logs.push(StepLog {
            step,
            mean_loss: loss_sum / n as f32,
            comm_us,
            reduce_us,
            bcast_us,
            wan_msgs,
            policy,
            compute_wall_us,
        });
    }
    Ok(logs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::runtime::{artifacts::default_dir, Runtime};
    use crate::topology::{Communicator, TopologySpec};
    use crate::tree::Strategy;

    #[test]
    fn training_learns_and_stays_synchronized() {
        if cfg!(not(feature = "pjrt")) {
            return; // stub PJRT backend cannot execute the train-step
        }
        let dir = default_dir();
        if !dir.join("manifest.tsv").is_file() {
            return; // artifacts not built in this environment
        }
        let rt = Runtime::open(dir).unwrap();
        let mlp = MlpRuntime::open(&rt).unwrap();
        // Small grid to keep the test quick: 2 sites x 2 machines x 2.
        let comm = Communicator::world(&TopologySpec::uniform(2, 2, 2).unwrap());
        let session = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel);
        let cfg = TrainConfig { steps: 25, lr: 0.2, ..Default::default() };
        let logs = train(&session, &mlp, &cfg).unwrap();
        assert_eq!(logs.len(), 25);
        let first = logs.first().unwrap().mean_loss;
        let last = logs.last().unwrap().mean_loss;
        assert!(last < first * 0.8, "no learning: {first} -> {last}");
        // multilevel allreduce = reduce + bcast: 2 WAN messages per step
        assert_eq!(logs[0].wan_msgs, 2);
    }
}
