//! End-to-end data-parallel training over the simulated grid (E11):
//! every simulated worker computes gradients through the AOT-compiled
//! train-step (L2 JAX graph via PJRT), gradients are **all-reduced with
//! the topology-aware collectives** (payload arithmetic through the L1
//! Pallas combine kernels when an [`XlaCombiner`] is supplied), and
//! parameters are updated with the Pallas `axpy` SGD kernel — all three
//! layers composing on one workload.

use crate::collectives::CollectiveEngine;
use crate::error::{Error, Result};
use crate::model::NetworkParams;
use crate::netsim::{Combiner, ReduceOp};
use crate::plan::AllreduceAlgo;
use crate::runtime::MlpRuntime;
use crate::topology::Communicator;
use crate::tree::Strategy;

/// Per-step record.
#[derive(Clone, Debug)]
pub struct StepLog {
    pub step: usize,
    pub mean_loss: f32,
    /// Virtual communication time of the gradient allreduce (us).
    pub comm_us: f64,
    pub wan_msgs: u64,
    /// Wall-clock compute time of the PJRT train steps (us).
    pub compute_wall_us: f64,
}

/// Training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub strategy: Strategy,
    /// How the per-step gradient allreduce is composed (both algorithms
    /// are bitwise-equivalent; see [`AllreduceAlgo`]).
    pub allreduce: AllreduceAlgo,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 50,
            lr: 0.1,
            strategy: Strategy::Multilevel,
            allreduce: AllreduceAlgo::ReduceBcast,
            seed: 0,
        }
    }
}

/// Run synchronous data-parallel SGD: one worker per communicator rank.
///
/// Workers hold identical parameter replicas; each step computes local
/// gradients on a worker-specific synthetic batch, allreduces them over
/// the simulated grid, and applies the averaged gradient. Divergence
/// between replicas is checked every step (they must stay bitwise equal:
/// same reduced gradient, same update).
pub fn train(
    comm: &Communicator,
    params_net: &NetworkParams,
    mlp: &MlpRuntime,
    combiner: &dyn Combiner,
    cfg: &TrainConfig,
) -> Result<Vec<StepLog>> {
    let n = comm.size();
    // One engine for the whole run: the per-step allreduce plan is built
    // on step 0 and served from the engine's PlanCache on every
    // subsequent step (zero tree builds / program compiles on the hot
    // path — the pipeline's whole point for this workload).
    let engine = CollectiveEngine::new(comm, params_net.clone(), cfg.strategy)
        .with_combiner(combiner)
        .with_allreduce_algo(cfg.allreduce);
    let p0 = mlp.init_params(cfg.seed);
    let mut replicas: Vec<Vec<f32>> = vec![p0; n];
    let mut logs = Vec::with_capacity(cfg.steps);

    for step in 0..cfg.steps {
        // Local gradient computation (PJRT; wall-clock measured).
        let t0 = std::time::Instant::now();
        let mut grads = Vec::with_capacity(n);
        let mut loss_sum = 0.0f32;
        for w in 0..n {
            let (x, y) = mlp.synth_batch((step * n + w) as u64);
            let (g, loss) = mlp.train_step(&replicas[w], &x, &y)?;
            loss_sum += loss;
            grads.push(g);
        }
        let compute_wall_us = t0.elapsed().as_secs_f64() * 1e6;

        // Gradient allreduce over the simulated grid.
        let out = engine.allreduce(ReduceOp::Sum, &grads)?;

        // SGD update with the averaged gradient (Pallas axpy kernel).
        let lr_eff = cfg.lr / n as f32;
        for w in 0..n {
            replicas[w] = mlp.sgd_step(&replicas[w], &out.data[w], lr_eff)?;
        }

        // Replica synchronization invariant.
        for w in 1..n {
            if replicas[w] != replicas[0] {
                return Err(Error::Verify(format!(
                    "replica divergence at step {step}, worker {w}"
                )));
            }
        }

        logs.push(StepLog {
            step,
            mean_loss: loss_sum / n as f32,
            comm_us: out.sim.makespan_us,
            wan_msgs: out.sim.wan_messages(),
            compute_wall_us,
        });
    }
    Ok(logs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::netsim::NativeCombiner;
    use crate::runtime::{artifacts::default_dir, Runtime};
    use crate::topology::TopologySpec;

    #[test]
    fn training_learns_and_stays_synchronized() {
        if cfg!(not(feature = "pjrt")) {
            return; // stub PJRT backend cannot execute the train-step
        }
        let dir = default_dir();
        if !dir.join("manifest.tsv").is_file() {
            return; // artifacts not built in this environment
        }
        let rt = Runtime::open(dir).unwrap();
        let mlp = MlpRuntime::open(&rt).unwrap();
        // Small grid to keep the test quick: 2 sites x 2 machines x 2.
        let comm = Communicator::world(&TopologySpec::uniform(2, 2, 2).unwrap());
        let cfg = TrainConfig { steps: 25, lr: 0.2, ..Default::default() };
        let logs =
            train(&comm, &presets::paper_grid(), &mlp, &NativeCombiner, &cfg).unwrap();
        assert_eq!(logs.len(), 25);
        let first = logs.first().unwrap().mean_loss;
        let last = logs.last().unwrap().mean_loss;
        assert!(last < first * 0.8, "no learning: {first} -> {last}");
        // multilevel allreduce = reduce + bcast: 2 WAN messages per step
        assert_eq!(logs[0].wan_msgs, 2);
    }
}
