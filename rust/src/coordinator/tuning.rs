//! Empirical autotuning of the allreduce composition boundary — the
//! ghost-payload engine's payoff feature.
//!
//! `AlgoPolicy` made the per-level composition a *plan-key parameter*
//! (PR 3); what was missing was a cheap way to pick it. cs/0408034
//! (*Fast Tuning of Intra-Cluster Collective Communications*) shows that
//! sweep-based tuning is practical exactly when each probe is nearly
//! free, and cs/0408033's logical-cluster construction assumes the same
//! cheap-probe loop at every topology level. Ghost-mode simulation makes
//! a probe exactly that: on a warm plan cache, one candidate costs one
//! timing-only engine run — **zero tree builds, zero program compiles,
//! zero payload allocations** (enforced by the stage counters in
//! `rust/tests/tuning_counters.rs`).
//!
//! [`tune_allreduce_boundary`] sweeps every composition candidate — both
//! uniforms plus `hybrid(b)` for every interior boundary level of the
//! communicator's clustering — for one (topology, payload size) pair and
//! returns the makespan-minimizing policy, the way
//! `CollectiveEngine::tune_bcast_segments` does for segment counts. All
//! candidates deliver bitwise-identical results (same tree, same combine
//! association), so the tuner's choice is purely a message-structure
//! trade-off and needs no re-verification.

use std::collections::HashMap;

use crate::collectives::{request, CollectiveEngine};
use crate::error::{Error, Result};
use crate::netsim::{ExecMode, ReduceOp, SimResult};
use crate::plan::{AlgoPolicy, AllreduceAlgo, ChunkOrder, LevelAlgo, MAX_COMP_LEVELS};
use crate::util::fmt::{self, Table};
use crate::util::par;

/// One candidate's ghost-probe measurement.
#[derive(Clone, Copy, Debug)]
pub struct BoundaryProbe {
    pub policy: AlgoPolicy,
    /// Simulated makespan of the allreduce under this policy (us).
    pub makespan_us: f64,
    pub wan_msgs: u64,
    pub total_msgs: u64,
}

/// The tuner's verdict for one (topology, payload size) pair.
#[derive(Clone, Debug)]
pub struct BoundaryTuning {
    pub bytes: usize,
    pub op: ReduceOp,
    /// Every candidate, in sweep order (uniforms first, then ascending
    /// boundaries).
    pub probes: Vec<BoundaryProbe>,
    /// The makespan-minimizing policy (ties break toward the earliest
    /// candidate, so the preference order is deterministic).
    pub best: AlgoPolicy,
    pub best_us: f64,
}

impl BoundaryTuning {
    /// Ghost sweeps this tuning actually ran — one per candidate probed.
    /// The `gridd` singleflight path reports it so clients can see a
    /// coalesced (or table-served) request cost zero probes.
    pub fn probes_issued(&self) -> usize {
        self.probes.len()
    }
}

/// The composition candidates for a clustering of `n_levels` separation
/// levels: both uniforms, plus `hybrid(b)` for every **interior**
/// boundary `1 <= b < n_levels`. `hybrid(0)` and `hybrid(>= n_levels)`
/// are structural aliases of the uniforms (rs+ag and reduce+bcast
/// respectively — see `AlgoPolicy::boundary`) and must never appear: a
/// flat (1-level) clustering therefore yields exactly the two uniforms,
/// and the sweep never probes the same message structure twice.
pub fn boundary_candidates(n_levels: usize) -> Vec<AlgoPolicy> {
    let mut c = vec![
        AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast),
        AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather),
    ];
    // `1..n_levels` is empty for flat (and degenerate 0-level)
    // clusterings, so no hybrid candidate can ever alias a uniform.
    c.extend((1..n_levels).map(AlgoPolicy::hybrid));
    c
}

/// Ghost-probe a batch of **independent** candidate policies and append
/// one [`BoundaryProbe`] per candidate, in candidate order.
///
/// On a [`ExecMode::Sequential`] engine this is the classic pooled
/// serial loop (one recycled [`SimResult`], exact stage-counter deltas —
/// see `rust/tests/tuning_counters.rs`). On a sharded engine the batch
/// fans out across `threads` workers via a [`CollectiveEngine::ghost_prober`]
/// — each worker simulates whole probes sequentially with its own pooled
/// result buffer, so every probe's `SimResult` is bit-identical to the
/// serial loop's and the merged output (candidate order; on failure, the
/// lowest-index error) is byte-for-byte the serial output.
fn probe_policies(
    engine: &CollectiveEngine,
    op: ReduceOp,
    elems: usize,
    policies: &[AlgoPolicy],
    sim: &mut SimResult,
    out: &mut Vec<BoundaryProbe>,
) -> Result<()> {
    let threads = match engine.exec_mode() {
        ExecMode::Sharded { threads } => threads,
        ExecMode::Sequential => 1,
    };
    out.reserve(policies.len());
    if threads <= 1 || policies.len() <= 1 {
        for &policy in policies {
            let probe = request::AllreduceProbe { root: 0, op, policy, elems };
            engine.simulate_timing_into(&probe, sim)?;
            out.push(BoundaryProbe {
                policy,
                makespan_us: sim.makespan_us,
                wan_msgs: sim.wan_messages(),
                total_msgs: sim.msgs_by_sep.iter().sum(),
            });
        }
        return Ok(());
    }
    let prober = engine.ghost_prober();
    let results = par::map_pooled(
        threads,
        policies.len(),
        SimResult::default,
        |sim, i| -> Result<BoundaryProbe> {
            let policy = policies[i];
            let probe = request::AllreduceProbe { root: 0, op, policy, elems };
            prober.simulate_timing_into(&probe, sim)?;
            Ok(BoundaryProbe {
                policy,
                makespan_us: sim.makespan_us,
                wan_msgs: sim.wan_messages(),
                total_msgs: sim.msgs_by_sep.iter().sum(),
            })
        },
    );
    for r in results {
        out.push(r?);
    }
    Ok(())
}

/// Sweep every composition candidate for an allreduce of `bytes` on
/// `engine`'s topology via ghost probes, and return the winner.
///
/// Probes run through [`CollectiveEngine::simulate_timing`] with a
/// data-free [`request::AllreduceProbe`], so a warm sweep is pure
/// timing-only execution. Plans are cached per policy: the first sweep
/// compiles each candidate once, every later sweep (any payload size —
/// plans are size-independent) compiles nothing. On a sharded engine the
/// candidates probe in parallel (see [`probe_policies`]) with an
/// unchanged verdict.
pub fn tune_allreduce_boundary(
    engine: &CollectiveEngine,
    op: ReduceOp,
    bytes: usize,
) -> Result<BoundaryTuning> {
    if bytes % 4 != 0 {
        return Err(Error::Comm(format!(
            "tune_allreduce_boundary: payload size {bytes} is not f32-aligned"
        )));
    }
    let elems = bytes / 4;
    let candidates = boundary_candidates(engine.comm().clustering().n_levels());
    let mut probes = Vec::with_capacity(candidates.len());
    // One pooled result buffer for the whole sweep: a warm sweep
    // allocates nothing for results either (inline per-separation
    // accounting for <= 4-level clusterings).
    let mut sim = SimResult::default();
    probe_policies(engine, op, elems, &candidates, &mut sim, &mut probes)?;
    let best = probes
        .iter()
        .min_by(|a, b| a.makespan_us.total_cmp(&b.makespan_us))
        .expect("candidate set is never empty (two uniforms)");
    let (best_policy, best_us) = (best.policy, best.makespan_us);
    Ok(BoundaryTuning { bytes, op, probes, best: best_policy, best_us })
}

/// How [`tune_allreduce_composition`] explores the per-level assignment
/// space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SearchMode {
    /// Exhaustive for clusterings of at most 3 separation levels
    /// (27 structural assignments), beam search with
    /// [`DEFAULT_BEAM_WIDTH`] beyond that.
    Auto,
    /// Probe every structural assignment (`|STRUCTURAL|^levels` probes).
    /// The differential oracle for small level counts.
    Exhaustive,
    /// Level-by-level beam search (BEAN/TACOS-style): keep the `width`
    /// best prefixes per level, extending each with every structural
    /// algorithm. A prefix is scored by probing its canonical completion
    /// (trailing levels repeat the last assigned algorithm — exactly
    /// [`AlgoPolicy::composition`]'s fill rule), so prefix scores are
    /// real makespans, not heuristics.
    Beam { width: usize },
}

/// Default beam width. 9 = `|STRUCTURAL|^2`, which makes the beam carry
/// every 2-level prefix — so for clusterings of <= 3 levels the beam
/// degenerates to the exhaustive sweep and the two modes provably agree.
pub const DEFAULT_BEAM_WIDTH: usize = 9;

/// The composition tuner's verdict for one (topology, payload size)
/// pair.
#[derive(Clone, Debug)]
pub struct CompositionTuning {
    pub bytes: usize,
    pub op: ReduceOp,
    /// The mode that actually ran (`Auto` resolved).
    pub mode: SearchMode,
    /// Every *distinct* policy probed, in probe order (structural sweep
    /// first, then the chunked refinement of the structural winner).
    pub probes: Vec<BoundaryProbe>,
    /// The makespan-minimizing policy over all probes (ties break by the
    /// policy's `Ord`, so the verdict is deterministic).
    pub best: AlgoPolicy,
    pub best_us: f64,
    /// Size of the full structural assignment space
    /// (`|STRUCTURAL|^levels`) the sweep draws from.
    pub exhaustive_space: usize,
    /// Ghost probes actually issued (`== probes.len()`). Bounded by the
    /// structural sweep (the full space, or the beam's strictly smaller
    /// subset on deep clusterings) plus the 6 uniform-chunk refinements
    /// plus at most `2 * levels` per-level chunk refinements; memo hits
    /// make the exact count data-dependent.
    pub probes_issued: usize,
}

/// Probe memo for one sweep: each distinct policy is simulated exactly
/// once, so `probes.len()` is the true ghost-probe count however the
/// search revisits candidates.
struct ProbeSet<'a> {
    engine: &'a CollectiveEngine<'a>,
    op: ReduceOp,
    elems: usize,
    sim: SimResult,
    probes: Vec<BoundaryProbe>,
    scores: HashMap<AlgoPolicy, f64>,
}

impl ProbeSet<'_> {
    /// Score a batch of candidates: drop duplicates (within the batch
    /// and against the memo), fan the fresh ones out through
    /// [`probe_policies`] (parallel on a sharded engine), record their
    /// probes in candidate order. Batching is what the parallel driver
    /// layer feeds on — every independent group of probes arrives here
    /// as one batch.
    fn score_batch(&mut self, candidates: &[AlgoPolicy]) -> Result<()> {
        let mut fresh = Vec::with_capacity(candidates.len());
        for &policy in candidates {
            if !self.scores.contains_key(&policy) && !fresh.contains(&policy) {
                fresh.push(policy);
            }
        }
        let start = self.probes.len();
        probe_policies(self.engine, self.op, self.elems, &fresh, &mut self.sim, &mut self.probes)?;
        for p in &self.probes[start..] {
            self.scores.insert(p.policy, p.makespan_us);
        }
        Ok(())
    }

    /// Memoized score of an already-batched candidate.
    fn cached(&self, policy: &AlgoPolicy) -> f64 {
        self.scores[policy]
    }
}

/// Tune the full per-level composition for an allreduce of `bytes`:
/// search the structural assignment space (every [`LevelAlgo`] in
/// [`LevelAlgo::STRUCTURAL`] independently per separation level), refine
/// the structural winner with the uniform chunked-pipelining knob
/// (2 and 4 chunks per level under every [`ChunkOrder`]: FIFO,
/// shortest-chunk-first, least-loaded), then coordinate-descend the
/// **per-level** chunk counts of the incumbent (each separation level
/// independently tries the other counts in {1, 2, 4}).
///
/// Probes are ghost probes exactly like [`tune_allreduce_boundary`]'s:
/// on a warm plan cache a whole sweep is timing-only execution — zero
/// tree builds, zero program compiles, zero payload allocations. On a
/// sharded engine every independent probe batch (one odometer sweep, one
/// beam depth, one refinement round) fans out in parallel with an
/// unchanged verdict (see [`probe_policies`]).
pub fn tune_allreduce_composition(
    engine: &CollectiveEngine,
    op: ReduceOp,
    bytes: usize,
    mode: SearchMode,
) -> Result<CompositionTuning> {
    if bytes % 4 != 0 {
        return Err(Error::Comm(format!(
            "tune_allreduce_composition: payload size {bytes} is not f32-aligned"
        )));
    }
    let levels = engine.comm().clustering().n_levels().clamp(1, MAX_COMP_LEVELS);
    let mode = match mode {
        SearchMode::Auto if levels <= 3 => SearchMode::Exhaustive,
        SearchMode::Auto => SearchMode::Beam { width: DEFAULT_BEAM_WIDTH },
        m => m,
    };
    let k = LevelAlgo::STRUCTURAL.len();
    let exhaustive_space = k.pow(levels as u32);
    let mut set = ProbeSet {
        engine,
        op,
        elems: bytes / 4,
        sim: SimResult::default(),
        probes: Vec::new(),
        scores: HashMap::new(),
    };
    match mode {
        SearchMode::Exhaustive => {
            // Mixed-radix odometer over the full assignment space — one
            // batch, every assignment independent.
            let mut all = Vec::with_capacity(exhaustive_space);
            for idx in 0..exhaustive_space {
                let mut rest = idx;
                let mut algos = Vec::with_capacity(levels);
                for _ in 0..levels {
                    algos.push(LevelAlgo::STRUCTURAL[rest % k]);
                    rest /= k;
                }
                all.push(AlgoPolicy::composition(&algos)?);
            }
            set.score_batch(&all)?;
        }
        SearchMode::Beam { width } => {
            let width = width.max(1);
            let mut frontier: Vec<Vec<LevelAlgo>> =
                LevelAlgo::STRUCTURAL.iter().map(|&a| vec![a]).collect();
            for depth in 1..=levels {
                // The prefixes of one depth are independent: batch them
                // (the parallel fan-out unit), then rank from the memo.
                let policies = frontier
                    .iter()
                    .map(|prefix| AlgoPolicy::composition(prefix))
                    .collect::<Result<Vec<_>>>()?;
                set.score_batch(&policies)?;
                let mut scored: Vec<(f64, AlgoPolicy, Vec<LevelAlgo>)> = policies
                    .into_iter()
                    .zip(frontier.drain(..))
                    .map(|(policy, prefix)| (set.cached(&policy), policy, prefix))
                    .collect();
                scored.sort_by(|a, b| a.0.total_cmp(&b.0).then_with(|| a.1.cmp(&b.1)));
                scored.truncate(width);
                if depth == levels {
                    break;
                }
                frontier = scored
                    .iter()
                    .flat_map(|(_, _, prefix)| {
                        LevelAlgo::STRUCTURAL.iter().map(|&a| {
                            let mut next = prefix.clone();
                            next.push(a);
                            next
                        })
                    })
                    .collect();
            }
        }
        SearchMode::Auto => unreachable!("Auto resolved above"),
    }
    let structural_best = set
        .probes
        .iter()
        .min_by(|a, b| {
            a.makespan_us.total_cmp(&b.makespan_us).then_with(|| a.policy.cmp(&b.policy))
        })
        .expect("structural sweep is never empty")
        .policy;
    // Chunked refinement of the structural winner: both modes run the
    // identical pass, so beam-vs-exhaustive agreement is decided purely
    // by the structural sweep.
    let mut refine = Vec::with_capacity(6);
    for chunks in [2usize, 4] {
        for order in ChunkOrder::ALL {
            refine.push(structural_best.with_chunks(chunks).with_chunk_order(order));
        }
    }
    set.score_batch(&refine)?;
    // Per-level chunk refinement: coordinate descent over the chunk
    // count of each separation level of the best policy so far —
    // chunking only the levels that profit (typically the WAN) beats the
    // uniform knob when level costs are skewed. Each level tries the two
    // other counts in {1, 2, 4}; the incumbent moves only on a strict
    // improvement, so the descent is deterministic and the final argmin
    // can only get better.
    if levels > 1 {
        let seed = set
            .probes
            .iter()
            .min_by(|a, b| {
                a.makespan_us.total_cmp(&b.makespan_us).then_with(|| a.policy.cmp(&b.policy))
            })
            .expect("probe set is never empty");
        let (mut best, mut best_us) = (seed.policy, seed.makespan_us);
        for level in 1..=levels {
            let profile: Vec<usize> = (1..=levels).map(|l| best.chunks_at(l)).collect();
            let cur = profile[level - 1];
            let cands: Vec<AlgoPolicy> = [1usize, 2, 4]
                .into_iter()
                .filter(|&c| c != cur)
                .map(|c| {
                    let mut prof = profile.clone();
                    prof[level - 1] = c;
                    best.with_chunk_profile(&prof)
                })
                .collect();
            set.score_batch(&cands)?;
            for p in cands {
                let us = set.cached(&p);
                if us < best_us {
                    best = p;
                    best_us = us;
                }
            }
        }
    }
    let best = set
        .probes
        .iter()
        .min_by(|a, b| {
            a.makespan_us.total_cmp(&b.makespan_us).then_with(|| a.policy.cmp(&b.policy))
        })
        .expect("probe set is never empty");
    let (best_policy, best_us) = (best.policy, best.makespan_us);
    let probes_issued = set.probes.len();
    Ok(CompositionTuning {
        bytes,
        op,
        mode,
        probes: set.probes,
        best: best_policy,
        best_us,
        exhaustive_space,
        probes_issued,
    })
}

/// The composition-tuner analogue of [`boundary_tuning_table`]: every
/// probed policy × every payload size, with the per-size winner marked.
pub fn composition_tuning_table(
    engine: &CollectiveEngine,
    op: ReduceOp,
    sizes: &[usize],
    mode: SearchMode,
) -> Result<(Table, Vec<CompositionTuning>)> {
    let mut t = Table::new(&[
        "msg size", "policy", "makespan", "WAN msgs", "total msgs", "winner",
    ]);
    let mut tunings = Vec::with_capacity(sizes.len());
    for &bytes in sizes {
        let tuning = tune_allreduce_composition(engine, op, bytes, mode)?;
        for p in &tuning.probes {
            t.row(&[
                fmt::bytes(bytes),
                p.policy.name(),
                fmt::time_us(p.makespan_us),
                p.wan_msgs.to_string(),
                p.total_msgs.to_string(),
                if p.policy == tuning.best { "<- best".into() } else { String::new() },
            ]);
        }
        tunings.push(tuning);
    }
    Ok((t, tunings))
}

/// E14 — the winning-policy table: every candidate × every payload size,
/// with the per-size winner marked. Returns the table plus the raw
/// tunings (the policy table callers would install).
pub fn boundary_tuning_table(
    engine: &CollectiveEngine,
    op: ReduceOp,
    sizes: &[usize],
) -> Result<(Table, Vec<BoundaryTuning>)> {
    let mut t = Table::new(&[
        "msg size", "policy", "makespan", "WAN msgs", "total msgs", "winner",
    ]);
    let mut tunings = Vec::with_capacity(sizes.len());
    for &bytes in sizes {
        let tuning = tune_allreduce_boundary(engine, op, bytes)?;
        for p in &tuning.probes {
            t.row(&[
                fmt::bytes(bytes),
                p.policy.name(),
                fmt::time_us(p.makespan_us),
                p.wan_msgs.to_string(),
                p.total_msgs.to_string(),
                if p.policy == tuning.best { "<- best".into() } else { String::new() },
            ]);
        }
        tunings.push(tuning);
    }
    Ok((t, tunings))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::topology::{Communicator, TopologySpec};
    use crate::tree::Strategy;

    #[test]
    fn candidates_cover_uniforms_and_interior_boundaries() {
        let c = boundary_candidates(3);
        assert_eq!(c.len(), 4, "2 uniforms + boundaries 1 and 2");
        assert_eq!(c[0], AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast));
        assert_eq!(c[1], AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather));
        assert_eq!(c[2], AlgoPolicy::hybrid(1));
        assert_eq!(c[3], AlgoPolicy::hybrid(2));
    }

    #[test]
    fn degenerate_clusterings_yield_exactly_the_two_uniforms() {
        // A flat (1-level) topology has no interior boundary: the
        // candidate set is exactly the two uniforms — in particular no
        // duplicate/invalid hybrid(0) entry (a structural alias of
        // uniform rs+ag that would probe the same message structure
        // twice and could shadow it in the argmin tie-break).
        for n_levels in [0usize, 1] {
            let c = boundary_candidates(n_levels);
            assert_eq!(c.len(), 2, "{n_levels} levels: uniforms only");
            assert_eq!(c[0], AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast));
            assert_eq!(c[1], AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather));
            assert!(
                !c.iter().any(|p| p.hybrid_boundary().is_some()),
                "no hybrid candidates on a degenerate clustering"
            );
        }
        // No candidate set ever contains duplicates or non-interior
        // hybrids (either would double-probe a structure).
        for n_levels in 1..=5 {
            let c = boundary_candidates(n_levels);
            for (i, a) in c.iter().enumerate() {
                assert!(!c[i + 1..].contains(a), "duplicate candidate {a:?}");
                if let Some(boundary_level) = a.hybrid_boundary() {
                    assert!(
                        (1..n_levels).contains(&boundary_level),
                        "hybrid({boundary_level}) is not interior for {n_levels} levels"
                    );
                }
            }
        }
        // And the tuner actually runs on a flat communicator.
        let comm = Communicator::unaware(6);
        let e = CollectiveEngine::new(&comm, presets::uniform_lan(1), Strategy::Unaware);
        let t = tune_allreduce_boundary(&e, ReduceOp::Sum, 4096).unwrap();
        assert_eq!(t.probes.len(), 2, "flat topology probes the two uniforms");
        assert!(t.best_us.is_finite());
    }

    #[test]
    fn tuner_probes_every_candidate_and_picks_the_min() {
        let comm = Communicator::world(&TopologySpec::paper_experiment());
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
        let t = tune_allreduce_boundary(&e, ReduceOp::Sum, 65536).unwrap();
        let n_levels = comm.clustering().n_levels();
        assert_eq!(t.probes.len(), boundary_candidates(n_levels).len());
        let min = t
            .probes
            .iter()
            .map(|p| p.makespan_us)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(t.best_us, min, "winner is the sweep minimum");
        assert!(t.probes.iter().any(|p| p.policy == t.best));
        // Misaligned sizes are rejected, not rounded.
        assert!(tune_allreduce_boundary(&e, ReduceOp::Sum, 1001).is_err());
    }

    /// 24 ranks over 4 separation levels (machine / LAN / site / WAN):
    /// the smallest topology where beam search actually prunes.
    fn deep_comm() -> Communicator {
        use crate::topology::GroupNode;
        let spec = TopologySpec::new(
            "deep",
            GroupNode::group(
                "grid",
                (0..2)
                    .map(|s| {
                        GroupNode::group(
                            format!("site{s}"),
                            (0..2)
                                .map(|l| {
                                    GroupNode::group(
                                        format!("s{s}lan{l}"),
                                        (0..2)
                                            .map(|m| {
                                                GroupNode::machine(format!("s{s}l{l}m{m}"), 3)
                                            })
                                            .collect(),
                                    )
                                })
                                .collect(),
                        )
                    })
                    .collect(),
            ),
        )
        .unwrap();
        Communicator::world(&spec)
    }

    #[test]
    fn composition_tuner_covers_the_space_and_refines_chunks() {
        let comm = Communicator::world(&TopologySpec::paper_experiment());
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
        let t = tune_allreduce_composition(&e, ReduceOp::Sum, 65536, SearchMode::Auto).unwrap();
        assert_eq!(t.mode, SearchMode::Exhaustive, "Auto resolves to exhaustive at 3 levels");
        assert_eq!(t.exhaustive_space, 27, "3 structural algos over 3 levels");
        assert!(
            t.probes_issued >= t.exhaustive_space + 6,
            "full space + uniform chunk refinement: {} probes",
            t.probes_issued
        );
        assert!(
            t.probes_issued <= t.exhaustive_space + 6 + 2 * 3,
            "at most 2 per-level chunk probes per level: {} probes",
            t.probes_issued
        );
        assert_eq!(t.probes.len(), t.probes_issued, "every probe is distinct");
        let min = t.probes.iter().map(|p| p.makespan_us).fold(f64::INFINITY, f64::min);
        assert_eq!(t.best_us, min, "winner is the sweep minimum");
        assert!(
            t.probes.iter().any(|p| p.policy.chunks_per_level() == 4),
            "chunk refinement probed the pipelined variants"
        );
        // The boundary tuner's candidates are a subset of the structural
        // space, so the composition winner can never be worse.
        let b = tune_allreduce_boundary(&e, ReduceOp::Sum, 65536).unwrap();
        assert!(t.best_us <= b.best_us, "{} vs boundary {}", t.best_us, b.best_us);
        // Misaligned sizes are rejected, not rounded.
        assert!(tune_allreduce_composition(&e, ReduceOp::Sum, 1001, SearchMode::Auto).is_err());
    }

    #[test]
    fn beam_matches_exhaustive_on_small_spaces() {
        let comm = Communicator::world(&TopologySpec::paper_experiment());
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
        for bytes in [4096usize, 65536, 1 << 20] {
            let ex =
                tune_allreduce_composition(&e, ReduceOp::Sum, bytes, SearchMode::Exhaustive)
                    .unwrap();
            let width = DEFAULT_BEAM_WIDTH;
            let beam =
                tune_allreduce_composition(&e, ReduceOp::Sum, bytes, SearchMode::Beam { width })
                    .unwrap();
            // Width 9 carries every 2-level prefix, so on a <= 3-level
            // clustering the beam probes the whole space and the argmin
            // must coincide with the oracle's.
            assert_eq!(beam.probes_issued, ex.probes_issued, "{bytes}B: beam == exhaustive");
            assert_eq!(beam.best, ex.best, "{bytes}B: same argmin");
            assert_eq!(beam.best_us, ex.best_us, "{bytes}B: same makespan");
        }
    }

    #[test]
    fn beam_prunes_the_deep_assignment_space() {
        let comm = deep_comm();
        assert_eq!(comm.clustering().n_levels(), 4);
        let e = CollectiveEngine::new(&comm, presets::deep_grid(), Strategy::Multilevel);
        let ex =
            tune_allreduce_composition(&e, ReduceOp::Sum, 16384, SearchMode::Exhaustive).unwrap();
        let beam = tune_allreduce_composition(&e, ReduceOp::Sum, 16384, SearchMode::Auto).unwrap();
        assert_eq!(beam.mode, SearchMode::Beam { width: DEFAULT_BEAM_WIDTH });
        assert_eq!(ex.exhaustive_space, 81, "3^4 structural assignments");
        assert!(
            (81 + 6..=81 + 6 + 8).contains(&ex.probes_issued),
            "full space + chunk refinements: {} probes",
            ex.probes_issued
        );
        assert!(
            (45 + 6..=45 + 6 + 8).contains(&beam.probes_issued),
            "3+6+18+18 structural probes + chunk refinements: {} probes",
            beam.probes_issued
        );
        assert!(beam.probes_issued < ex.probes_issued, "beam must prune on deep spaces");
        // The beam explores a subset, so it can never beat the oracle.
        assert!(beam.best_us >= ex.best_us);
    }

    #[test]
    fn parallel_probe_fanout_matches_serial() {
        use crate::netsim::ExecMode;
        // The differential oracle for the parallel driver layer: a
        // sharded engine fans each probe batch across 4 workers, yet the
        // probe sequence (policies, bitwise makespans, accounting) and
        // the argmin must be byte-identical to the serial sweep's.
        let comm = deep_comm();
        let serial = CollectiveEngine::new(&comm, presets::deep_grid(), Strategy::Multilevel);
        let par4 = CollectiveEngine::new(&comm, presets::deep_grid(), Strategy::Multilevel)
            .with_exec_mode(ExecMode::Sharded { threads: 4 });
        for mode in [SearchMode::Auto, SearchMode::Exhaustive] {
            let s = tune_allreduce_composition(&serial, ReduceOp::Sum, 16384, mode).unwrap();
            let p = tune_allreduce_composition(&par4, ReduceOp::Sum, 16384, mode).unwrap();
            assert_eq!(s.probes_issued, p.probes_issued, "{mode:?}: same probe count");
            assert_eq!(s.best, p.best, "{mode:?}: same argmin");
            assert_eq!(s.best_us.to_bits(), p.best_us.to_bits(), "{mode:?}: same makespan");
            for (a, b) in s.probes.iter().zip(&p.probes) {
                assert_eq!(a.policy, b.policy, "identical probe sequence");
                assert_eq!(a.makespan_us.to_bits(), b.makespan_us.to_bits());
                assert_eq!((a.wan_msgs, a.total_msgs), (b.wan_msgs, b.total_msgs));
            }
        }
        let s = tune_allreduce_boundary(&serial, ReduceOp::Sum, 65536).unwrap();
        let p = tune_allreduce_boundary(&par4, ReduceOp::Sum, 65536).unwrap();
        assert_eq!(s.best, p.best, "boundary tuner: same argmin");
        assert_eq!(s.best_us.to_bits(), p.best_us.to_bits());
        assert_eq!(s.probes.len(), p.probes.len());
    }

    #[test]
    fn composition_table_rows_and_winner_marks() {
        let comm = Communicator::world(&TopologySpec::paper_experiment());
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
        let sizes = [4096usize, 65536];
        let (table, tunings) =
            composition_tuning_table(&e, ReduceOp::Sum, &sizes, SearchMode::Auto).unwrap();
        assert_eq!(table.n_rows(), tunings.iter().map(|t| t.probes_issued).sum::<usize>());
        let md = table.to_markdown();
        assert_eq!(md.matches("<- best").count(), sizes.len(), "one winner per size");
    }

    #[test]
    fn tuning_table_rows_and_winner_marks() {
        let comm = Communicator::world(&TopologySpec::paper_experiment());
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
        let sizes = [4096usize, 65536];
        let (table, tunings) = boundary_tuning_table(&e, ReduceOp::Sum, &sizes).unwrap();
        let per_size = boundary_candidates(comm.clustering().n_levels()).len();
        assert_eq!(table.n_rows(), sizes.len() * per_size);
        assert_eq!(tunings.len(), sizes.len());
        let md = table.to_markdown();
        assert_eq!(md.matches("<- best").count(), sizes.len(), "one winner per size");
    }
}
