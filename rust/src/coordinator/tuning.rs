//! Empirical autotuning of the allreduce composition boundary — the
//! ghost-payload engine's payoff feature.
//!
//! `AlgoPolicy` made the per-level composition a *plan-key parameter*
//! (PR 3); what was missing was a cheap way to pick it. cs/0408034
//! (*Fast Tuning of Intra-Cluster Collective Communications*) shows that
//! sweep-based tuning is practical exactly when each probe is nearly
//! free, and cs/0408033's logical-cluster construction assumes the same
//! cheap-probe loop at every topology level. Ghost-mode simulation makes
//! a probe exactly that: on a warm plan cache, one candidate costs one
//! timing-only engine run — **zero tree builds, zero program compiles,
//! zero payload allocations** (enforced by the stage counters in
//! `rust/tests/tuning_counters.rs`).
//!
//! [`tune_allreduce_boundary`] sweeps every composition candidate — both
//! uniforms plus `hybrid(b)` for every interior boundary level of the
//! communicator's clustering — for one (topology, payload size) pair and
//! returns the makespan-minimizing policy, the way
//! `CollectiveEngine::tune_bcast_segments` does for segment counts. All
//! candidates deliver bitwise-identical results (same tree, same combine
//! association), so the tuner's choice is purely a message-structure
//! trade-off and needs no re-verification.

use crate::collectives::{request, CollectiveEngine};
use crate::error::{Error, Result};
use crate::netsim::{ReduceOp, SimResult};
use crate::plan::{AlgoPolicy, AllreduceAlgo};
use crate::util::fmt::{self, Table};

/// One candidate's ghost-probe measurement.
#[derive(Clone, Copy, Debug)]
pub struct BoundaryProbe {
    pub policy: AlgoPolicy,
    /// Simulated makespan of the allreduce under this policy (us).
    pub makespan_us: f64,
    pub wan_msgs: u64,
    pub total_msgs: u64,
}

/// The tuner's verdict for one (topology, payload size) pair.
#[derive(Clone, Debug)]
pub struct BoundaryTuning {
    pub bytes: usize,
    pub op: ReduceOp,
    /// Every candidate, in sweep order (uniforms first, then ascending
    /// boundaries).
    pub probes: Vec<BoundaryProbe>,
    /// The makespan-minimizing policy (ties break toward the earliest
    /// candidate, so the preference order is deterministic).
    pub best: AlgoPolicy,
    pub best_us: f64,
}

/// The composition candidates for a clustering of `n_levels` separation
/// levels: both uniforms, plus `hybrid(b)` for every **interior**
/// boundary `1 <= b < n_levels`. `hybrid(0)` and `hybrid(>= n_levels)`
/// are structural aliases of the uniforms (rs+ag and reduce+bcast
/// respectively — see `AlgoPolicy::boundary`) and must never appear: a
/// flat (1-level) clustering therefore yields exactly the two uniforms,
/// and the sweep never probes the same message structure twice.
pub fn boundary_candidates(n_levels: usize) -> Vec<AlgoPolicy> {
    let mut c = vec![
        AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast),
        AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather),
    ];
    // `1..n_levels` is empty for flat (and degenerate 0-level)
    // clusterings, so no hybrid candidate can ever alias a uniform.
    c.extend((1..n_levels).map(AlgoPolicy::hybrid));
    c
}

/// Sweep every composition candidate for an allreduce of `bytes` on
/// `engine`'s topology via ghost probes, and return the winner.
///
/// Probes run through [`CollectiveEngine::simulate_timing`] with a
/// data-free [`request::AllreduceProbe`], so a warm sweep is pure
/// timing-only execution. Plans are cached per policy: the first sweep
/// compiles each candidate once, every later sweep (any payload size —
/// plans are size-independent) compiles nothing.
pub fn tune_allreduce_boundary(
    engine: &CollectiveEngine,
    op: ReduceOp,
    bytes: usize,
) -> Result<BoundaryTuning> {
    if bytes % 4 != 0 {
        return Err(Error::Comm(format!(
            "tune_allreduce_boundary: payload size {bytes} is not f32-aligned"
        )));
    }
    let elems = bytes / 4;
    let candidates = boundary_candidates(engine.comm().clustering().n_levels());
    let mut probes = Vec::with_capacity(candidates.len());
    // One pooled result buffer for the whole sweep: a warm sweep
    // allocates nothing for results either (inline per-separation
    // accounting for <= 4-level clusterings).
    let mut sim = SimResult::default();
    for policy in candidates {
        let probe = request::AllreduceProbe { root: 0, op, policy, elems };
        engine.simulate_timing_into(&probe, &mut sim)?;
        probes.push(BoundaryProbe {
            policy,
            makespan_us: sim.makespan_us,
            wan_msgs: sim.wan_messages(),
            total_msgs: sim.msgs_by_sep.iter().sum(),
        });
    }
    let best = probes
        .iter()
        .min_by(|a, b| a.makespan_us.total_cmp(&b.makespan_us))
        .expect("candidate set is never empty (two uniforms)");
    let (best_policy, best_us) = (best.policy, best.makespan_us);
    Ok(BoundaryTuning { bytes, op, probes, best: best_policy, best_us })
}

/// E14 — the winning-policy table: every candidate × every payload size,
/// with the per-size winner marked. Returns the table plus the raw
/// tunings (the policy table callers would install).
pub fn boundary_tuning_table(
    engine: &CollectiveEngine,
    op: ReduceOp,
    sizes: &[usize],
) -> Result<(Table, Vec<BoundaryTuning>)> {
    let mut t = Table::new(&[
        "msg size", "policy", "makespan", "WAN msgs", "total msgs", "winner",
    ]);
    let mut tunings = Vec::with_capacity(sizes.len());
    for &bytes in sizes {
        let tuning = tune_allreduce_boundary(engine, op, bytes)?;
        for p in &tuning.probes {
            t.row(&[
                fmt::bytes(bytes),
                p.policy.name(),
                fmt::time_us(p.makespan_us),
                p.wan_msgs.to_string(),
                p.total_msgs.to_string(),
                if p.policy == tuning.best { "<- best".into() } else { String::new() },
            ]);
        }
        tunings.push(tuning);
    }
    Ok((t, tunings))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::topology::{Communicator, TopologySpec};
    use crate::tree::Strategy;

    #[test]
    fn candidates_cover_uniforms_and_interior_boundaries() {
        let c = boundary_candidates(3);
        assert_eq!(c.len(), 4, "2 uniforms + boundaries 1 and 2");
        assert_eq!(c[0], AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast));
        assert_eq!(c[1], AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather));
        assert_eq!(c[2], AlgoPolicy::hybrid(1));
        assert_eq!(c[3], AlgoPolicy::hybrid(2));
    }

    #[test]
    fn degenerate_clusterings_yield_exactly_the_two_uniforms() {
        // A flat (1-level) topology has no interior boundary: the
        // candidate set is exactly the two uniforms — in particular no
        // duplicate/invalid hybrid(0) entry (a structural alias of
        // uniform rs+ag that would probe the same message structure
        // twice and could shadow it in the argmin tie-break).
        for n_levels in [0usize, 1] {
            let c = boundary_candidates(n_levels);
            assert_eq!(c.len(), 2, "{n_levels} levels: uniforms only");
            assert_eq!(c[0], AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast));
            assert_eq!(c[1], AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather));
            assert!(
                !c.iter().any(|p| matches!(p, AlgoPolicy::Hybrid { .. })),
                "no hybrid candidates on a degenerate clustering"
            );
        }
        // No candidate set ever contains duplicates or non-interior
        // hybrids (either would double-probe a structure).
        for n_levels in 1..=5 {
            let c = boundary_candidates(n_levels);
            for (i, a) in c.iter().enumerate() {
                assert!(!c[i + 1..].contains(a), "duplicate candidate {a:?}");
                if let AlgoPolicy::Hybrid { boundary_level } = *a {
                    assert!(
                        (1..n_levels).contains(&boundary_level),
                        "hybrid({boundary_level}) is not interior for {n_levels} levels"
                    );
                }
            }
        }
        // And the tuner actually runs on a flat communicator.
        let comm = Communicator::unaware(6);
        let e = CollectiveEngine::new(&comm, presets::uniform_lan(1), Strategy::Unaware);
        let t = tune_allreduce_boundary(&e, ReduceOp::Sum, 4096).unwrap();
        assert_eq!(t.probes.len(), 2, "flat topology probes the two uniforms");
        assert!(t.best_us.is_finite());
    }

    #[test]
    fn tuner_probes_every_candidate_and_picks_the_min() {
        let comm = Communicator::world(&TopologySpec::paper_experiment());
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
        let t = tune_allreduce_boundary(&e, ReduceOp::Sum, 65536).unwrap();
        let n_levels = comm.clustering().n_levels();
        assert_eq!(t.probes.len(), boundary_candidates(n_levels).len());
        let min = t
            .probes
            .iter()
            .map(|p| p.makespan_us)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(t.best_us, min, "winner is the sweep minimum");
        assert!(t.probes.iter().any(|p| p.policy == t.best));
        // Misaligned sizes are rejected, not rounded.
        assert!(tune_allreduce_boundary(&e, ReduceOp::Sum, 1001).is_err());
    }

    #[test]
    fn tuning_table_rows_and_winner_marks() {
        let comm = Communicator::world(&TopologySpec::paper_experiment());
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
        let sizes = [4096usize, 65536];
        let (table, tunings) = boundary_tuning_table(&e, ReduceOp::Sum, &sizes).unwrap();
        let per_size = boundary_candidates(comm.clustering().n_levels()).len();
        assert_eq!(table.n_rows(), sizes.len() * per_size);
        assert_eq!(tunings.len(), sizes.len());
        let md = table.to_markdown();
        assert_eq!(md.matches("<- best").count(), sizes.len(), "one winner per size");
    }
}
