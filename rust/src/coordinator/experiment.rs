//! Experiment drivers: parameterized sweeps behind every table/figure in
//! DESIGN.md §6, shared by the benches, the examples and the CLI. All
//! drivers run through [`GridSession`] (the front door); per-strategy
//! sweeps share one plan cache and one scratch arena across sessions.

use crate::analytic::TwoTier;
use crate::collectives::verify;
use crate::coordinator::timing_app::{self, TimingPoint};
use crate::error::Result;
use crate::model::{presets, NetworkParams};
use crate::netsim::{Combiner, ExecMode, ExecScratch, NativeCombiner, ReduceOp};
use crate::plan::{AlgoPolicy, AllreduceAlgo, PlanCache};
use crate::session::GridSession;
use crate::topology::{Communicator, TopologySpec};
use crate::tree::{build_strategy_tree, LevelPolicy, Strategy, TreeShape};
use crate::util::fmt::{self, Table};
use std::sync::Arc;

/// E1 — Fig. 8: the full rotation timing for the paper's 48-process
/// grid, one row per (size, strategy). Each point is one fused **ghost**
/// simulation of the whole rotation (§4 fidelity; see
/// [`timing_app::run_point_with`]) — ghost runs never touch a combiner,
/// so the driver takes none.
pub fn fig8_table(sizes: &[usize]) -> Result<(Table, Vec<TimingPoint>)> {
    fig8_table_with_mode(sizes, ExecMode::Sequential)
}

/// [`fig8_table`] under an explicit execution mode (`--threads` routes
/// here). Sharded timing is bitwise-identical to sequential, so the
/// table contents never depend on the mode — only the wall-clock does.
pub fn fig8_table_with_mode(sizes: &[usize], mode: ExecMode) -> Result<(Table, Vec<TimingPoint>)> {
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let params = presets::paper_grid();
    let pts = timing_app::fig8_sweep_with_mode(&comm, &params, sizes, &Strategy::ALL, mode)?;
    let mut t = Table::new(&[
        "msg size", "strategy", "rotation total", "mean bcast", "mean ack", "WAN msgs",
    ]);
    for p in &pts {
        t.row(&[
            fmt::bytes(p.bytes),
            p.strategy.name().to_string(),
            fmt::time_us(p.total_us),
            fmt::time_us(p.mean_bcast_us),
            fmt::time_us(p.mean_ack_us),
            p.wan_msgs.to_string(),
        ]);
    }
    Ok((t, pts))
}

/// E13 — fused rotation vs sum-of-isolated-makespans, one strategy:
/// quantifies exactly what the pre-fusion timing app overstated (and the
/// 2n-fold engine-invocation saving is benched in `fused_schedule`).
pub fn fig8_fused_vs_separate(sizes: &[usize], strategy: Strategy) -> Result<Table> {
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let session = GridSession::new(&comm, presets::paper_grid(), strategy);
    let mut t = Table::new(&[
        "msg size", "fused rotation", "separate sum", "overlap saved", "saved %",
    ]);
    for &bytes in sizes {
        let fused = timing_app::run_point_with(&session, bytes)?;
        let sep = timing_app::run_point_separate(&session, bytes)?;
        let saved = sep.total_us - fused.total_us;
        t.row(&[
            fmt::bytes(bytes),
            fmt::time_us(fused.total_us),
            fmt::time_us(sep.total_us),
            fmt::time_us(saved),
            format!("{:.2}%", 100.0 * saved / sep.total_us),
        ]);
    }
    Ok(t)
}

/// E2 — §4 cost model: predicted vs simulated binomial/multilevel
/// broadcast times for P processes over C clusters.
///
/// The §4 closed form charges a *single* slow term for the multilevel
/// tree; that is exact in the latency-dominated postal regime the paper
/// invokes (Bar-Noy & Kipnis), i.e. small messages — use `bytes` ≲ a few
/// KiB. For bandwidth-dominated messages the flat WAN stage serializes on
/// the root's uplink and the optimal WAN shape flattens out (§6;
/// `wan_shape_ablation` quantifies exactly this).
pub fn cost_model_table(bytes: usize) -> Result<Table> {
    let params = presets::paper_grid();
    let tt = TwoTier { slow: params.per_sep[0], fast: params.per_sep[2] };
    let mut t = Table::new(&[
        "P", "C", "analytic binomial", "analytic multilevel", "sim binomial", "sim multilevel",
        "sim speedup", "asymptote log2(C)",
    ]);
    for (p, c) in [(16, 2), (32, 4), (64, 8), (128, 16)] {
        let spec = TopologySpec::uniform(c, 1, p / c)?;
        let comm = Communicator::world(&spec);
        let data = vec![0.0f32; bytes / 4];
        let sim_b = GridSession::new(&comm, params.clone(), Strategy::Unaware)
            .bcast(0, &data)?
            .sim
            .makespan_us;
        let sim_m = GridSession::new(&comm, params.clone(), Strategy::Multilevel)
            .bcast(0, &data)?
            .sim
            .makespan_us;
        t.row(&[
            p.to_string(),
            c.to_string(),
            fmt::time_us(tt.binomial_bcast_us(p, c, bytes)),
            fmt::time_us(tt.multilevel_bcast_us(p, c, bytes)),
            fmt::time_us(sim_b),
            fmt::time_us(sim_m),
            format!("{:.2}x", sim_b / sim_m),
            format!("{:.2}", tt.asymptotic_speedup(c)),
        ]);
    }
    Ok(t)
}

/// E8 — the core collectives plus allreduce under every strategy on the
/// paper grid. All sessions share one [`PlanCache`] and scratch arena
/// (keys carry the strategy, so sharing is safe and the table's second
/// run is all-warm).
pub fn collectives_suite_table(bytes: usize, combiner: Arc<dyn Combiner>) -> Result<Table> {
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let params = presets::paper_grid();
    let n = comm.size();
    let elems = bytes / 4;
    let cache = Arc::new(PlanCache::new());
    let scratch = Arc::new(ExecScratch::new());
    let mut t = Table::new(&["op", "strategy", "makespan", "WAN msgs", "total msgs"]);
    for s in Strategy::ALL {
        let session = GridSession::new(&comm, params.clone(), s)
            .with_combiner(combiner.clone())
            .with_plan_cache(cache.clone())
            .with_scratch(scratch.clone());
        let data = vec![1.0f32; elems];
        let contributions: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32; elems]).collect();
        let seg: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32; elems / n.max(1) + 1]).collect();
        let rows: Vec<(&str, crate::netsim::SimResult)> = vec![
            ("bcast", session.bcast(0, &data)?.sim),
            ("reduce", session.reduce(0, ReduceOp::Sum, &contributions)?.sim),
            ("barrier", session.barrier()?),
            ("gather", session.gather(0, &seg)?.sim),
            ("scatter", session.scatter(0, &seg)?.sim),
            ("allreduce", session.allreduce(ReduceOp::Sum, &contributions)?.sim),
        ];
        for (op, sim) in rows {
            t.row(&[
                op.to_string(),
                s.name().to_string(),
                fmt::time_us(sim.makespan_us),
                sim.wan_messages().to_string(),
                sim.msgs_by_sep.iter().sum::<u64>().to_string(),
            ]);
        }
    }
    Ok(t)
}

/// E12 — the headline new op: allreduce across every strategy and every
/// composition policy (both uniforms plus the per-level hybrid at
/// `boundary`), verified against the serial reference on every row.
pub fn allreduce_table(
    bytes: usize,
    op: ReduceOp,
    combiner: Arc<dyn Combiner>,
    boundary: usize,
) -> Result<Table> {
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let params = presets::paper_grid();
    let n = comm.size();
    let elems = (bytes / 4).max(1);
    // Small-integer contributions keep f32 arithmetic exact for every
    // operator (sums stay far below 2^24; products of values in [1, 3]
    // over 48 ranks stay finite and exact is not guaranteed for prod, so
    // prod uses a [1, 2] base), hence "verified" means bit-for-bit
    // against the reference combiner.
    let base = if op == ReduceOp::Prod { 2 } else { 9 };
    let contributions: Vec<Vec<f32>> = (0..n)
        .map(|r| (0..elems).map(|i| (1 + (r + i) % base) as f32).collect())
        .collect();
    let expect = verify::ref_reduce(&contributions, op);
    let cache = Arc::new(PlanCache::new());
    let scratch = Arc::new(ExecScratch::new());
    let policies = [
        AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast),
        AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather),
        AlgoPolicy::hybrid(boundary),
    ];
    let mut t =
        Table::new(&["strategy", "algorithm", "makespan", "WAN msgs", "total msgs", "verified"]);
    for s in Strategy::ALL {
        let session = GridSession::new(&comm, params.clone(), s)
            .with_combiner(combiner.clone())
            .with_plan_cache(cache.clone())
            .with_scratch(scratch.clone());
        for policy in policies {
            let out = session.allreduce_with_policy(policy, 0, op, &contributions)?;
            let ok = (0..n).all(|r| out.data[r] == expect);
            t.row(&[
                s.name().to_string(),
                policy.name(),
                fmt::time_us(out.sim.makespan_us),
                out.sim.wan_messages().to_string(),
                out.sim.msgs_by_sep.iter().sum::<u64>().to_string(),
                if ok { "exact".into() } else { "MISMATCH".to_string() },
            ]);
        }
    }
    Ok(t)
}

/// E9 — §6 ablation: tree shape at the WAN level (flat vs binomial vs
/// chain vs Fibonacci-λ) for a many-site grid.
pub fn wan_shape_ablation(sites: usize, bytes: usize) -> Result<Table> {
    let spec = TopologySpec::uniform(sites, 2, 4)?;
    let comm = Communicator::world(&spec);
    let params = presets::paper_grid();
    let data = vec![0.5f32; bytes / 4];
    let mut t = Table::new(&["WAN shape", "makespan", "WAN msgs"]);
    let shapes: Vec<(String, LevelPolicy)> = vec![
        ("flat (paper)".into(), LevelPolicy::paper()),
        ("binomial".into(), LevelPolicy::all_binomial()),
        (
            "chain".into(),
            LevelPolicy { shapes: vec![TreeShape::Chain, TreeShape::Binomial] },
        ),
        (
            "fibonacci λ=2".into(),
            LevelPolicy { shapes: vec![TreeShape::Fibonacci(2), TreeShape::Binomial] },
        ),
        (
            "fibonacci λ=4".into(),
            LevelPolicy { shapes: vec![TreeShape::Fibonacci(4), TreeShape::Binomial] },
        ),
        (
            "distance-halving (bine)".into(),
            LevelPolicy { shapes: vec![TreeShape::DistanceHalving, TreeShape::Binomial] },
        ),
    ];
    for (name, policy) in shapes {
        let session = GridSession::new(&comm, params.clone(), Strategy::Multilevel)
            .with_level_policy(policy);
        let out = session.bcast(0, &data)?;
        t.row(&[
            name,
            fmt::time_us(out.sim.makespan_us),
            out.sim.wan_messages().to_string(),
        ]);
    }
    Ok(t)
}

/// E10 — scaling with the number of sites at fixed total processes.
pub fn site_scaling_table(bytes: usize) -> Result<Table> {
    let params = presets::paper_grid();
    let data = vec![0.25f32; bytes / 4];
    let mut t = Table::new(&["sites", "procs", "binomial", "multilevel", "speedup"]);
    for sites in [2usize, 4, 8, 16] {
        let per = 64 / sites;
        let spec = TopologySpec::uniform(sites, 1, per)?;
        let comm = Communicator::world(&spec);
        let b = GridSession::new(&comm, params.clone(), Strategy::Unaware)
            .bcast(0, &data)?
            .sim
            .makespan_us;
        let m = GridSession::new(&comm, params.clone(), Strategy::Multilevel)
            .bcast(0, &data)?
            .sim
            .makespan_us;
        t.row(&[
            sites.to_string(),
            "64".into(),
            fmt::time_us(b),
            fmt::time_us(m),
            format!("{:.2}x", b / m),
        ]);
    }
    Ok(t)
}

/// E7/E10 — root-placement sensitivity: the binomial tree's cost varies
/// with the root's position, the multilevel tree's does not (much).
pub fn root_sensitivity_table(bytes: usize) -> Result<Table> {
    let comm = Communicator::world(&TopologySpec::paper_experiment());
    let params = presets::paper_grid();
    let data = vec![0.5f32; bytes / 4];
    let mut t = Table::new(&["strategy", "min over roots", "max over roots", "spread"]);
    for s in [Strategy::Unaware, Strategy::Multilevel] {
        // Each root appears once per sweep, so this session-private
        // cache only pays off for callers that hold a long-lived session
        // (or pass a shared PlanCache) across repeated sweeps; within
        // one call it simply builds each root's plan once.
        let session = GridSession::new(&comm, params.clone(), s);
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for root in 0..comm.size() {
            let us = session.bcast(root, &data)?.sim.makespan_us;
            lo = lo.min(us);
            hi = hi.max(us);
        }
        t.row(&[
            s.name().to_string(),
            fmt::time_us(lo),
            fmt::time_us(hi),
            format!("{:.2}x", hi / lo),
        ]);
    }
    Ok(t)
}

/// Per-link-class message/byte accounting for one broadcast (E4/E5).
pub fn message_accounting(comm: &Communicator, strategy: Strategy, bytes: usize) -> Result<Table> {
    let params = presets::paper_grid();
    let session = GridSession::new(comm, params, strategy);
    let out = session.bcast(0, &vec![0.0f32; bytes / 4])?;
    let n_levels = comm.clustering().n_levels();
    let mut t = Table::new(&["link class", "messages", "bytes"]);
    for (i, (&m, &b)) in out.sim.msgs_by_sep.iter().zip(&out.sim.bytes_by_sep).enumerate() {
        t.row(&[
            crate::model::sep_name(i + 1, n_levels).to_string(),
            m.to_string(),
            fmt::bytes(b as usize),
        ]);
    }
    Ok(t)
}

/// Render all four strategy trees for a topology (tree explorer).
pub fn render_strategy_trees(spec: &TopologySpec, root: usize) -> Result<String> {
    let comm = Communicator::world(spec);
    let machines = spec.machines();
    let label = |r: usize| {
        let m = machines
            .iter()
            .rev()
            .find(|m| m.first_rank <= r)
            .expect("rank within some machine");
        format!("r{r}[{}]", m.name)
    };
    let mut out = String::new();
    for s in Strategy::ALL {
        let t = build_strategy_tree(&comm, root, s, &LevelPolicy::paper())?;
        out.push_str(&format!("--- {} (root {root}) ---\n", s.name()));
        out.push_str(&t.render(label));
        out.push('\n');
    }
    Ok(out)
}

/// Cheap default combiner for CLI paths that don't need PJRT.
pub fn native() -> &'static NativeCombiner {
    static N: NativeCombiner = NativeCombiner;
    &N
}

/// [`native`] behind the `Arc` handle sessions take.
pub fn native_arc() -> Arc<dyn Combiner> {
    Arc::new(NativeCombiner)
}

/// Sweep helper shared by benches: build the paper-grid communicator.
pub fn paper_comm() -> Communicator {
    Communicator::world(&TopologySpec::paper_experiment())
}

/// Default parameter set for CLI paths.
pub fn paper_params() -> NetworkParams {
    presets::paper_grid()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_table_has_all_rows() {
        let (t, pts) = fig8_table(&[1024, 8192]).unwrap();
        assert_eq!(t.n_rows(), 8);
        assert_eq!(pts.len(), 8);
    }

    #[test]
    fn fused_vs_separate_table_rows() {
        let t = fig8_fused_vs_separate(&[4096], Strategy::Multilevel).unwrap();
        assert_eq!(t.n_rows(), 1);
    }

    #[test]
    fn cost_model_rows() {
        let t = cost_model_table(65536).unwrap();
        assert_eq!(t.n_rows(), 4);
    }

    #[test]
    fn suite_covers_6_ops_x_4_strategies() {
        let t = collectives_suite_table(4096, native_arc()).unwrap();
        assert_eq!(t.n_rows(), 24);
    }

    #[test]
    fn allreduce_table_verifies_every_row() {
        for op in crate::netsim::ReduceOp::ALL {
            let t = allreduce_table(4096, op, native_arc(), 1).unwrap();
            assert_eq!(t.n_rows(), 12, "4 strategies x 3 composition policies");
            let md = t.to_markdown();
            assert!(md.contains("exact"), "{op:?}");
            assert!(md.contains("hybrid(b=1)"), "{op:?}");
            assert!(!md.contains("MISMATCH"), "{op:?}");
        }
    }

    #[test]
    fn ablation_and_scaling_run() {
        assert_eq!(wan_shape_ablation(6, 16384).unwrap().n_rows(), 6);
        assert_eq!(site_scaling_table(16384).unwrap().n_rows(), 4);
        assert_eq!(root_sensitivity_table(16384).unwrap().n_rows(), 2);
    }

    #[test]
    fn accounting_rows_match_levels() {
        let comm = paper_comm();
        let t = message_accounting(&comm, Strategy::Multilevel, 4096).unwrap();
        assert_eq!(t.n_rows(), 3);
    }

    #[test]
    fn tree_rendering_contains_all_strategies() {
        let s = render_strategy_trees(&TopologySpec::paper_fig1(), 0).unwrap();
        for name in ["mpich-binomial", "magpie-machine", "magpie-site", "multilevel"] {
            assert!(s.contains(name), "missing {name}");
        }
        assert!(s.contains("r10[O2Ka]"));
    }
}
