//! Trace reporting: ASCII Gantt charts of simulated collective
//! executions and CSV trace export — the observability layer for
//! debugging tree schedules and for EXPERIMENTS.md figures.

use crate::netsim::{SimResult, TraceKind};
use crate::util::fmt;

/// Render an ASCII Gantt chart of the trace: one row per rank, time
/// bucketed into `width` columns. `S` marks a send start, `R` a receive
/// completion, `-` spans in-between activity.
pub fn gantt(sim: &SimResult, width: usize) -> String {
    let width = width.max(10);
    if sim.trace.is_empty() {
        return String::from("(no trace recorded — build the engine with .with_trace())\n");
    }
    let n = sim.finish_us.len();
    let t_max = sim.makespan_us.max(1e-9);
    let col = |t: f64| -> usize { ((t / t_max) * (width - 1) as f64).round() as usize };
    let mut rows: Vec<Vec<u8>> = vec![vec![b' '; width]; n];
    // fill activity spans: first event to finish time
    let mut first_event = vec![f64::INFINITY; n];
    for ev in &sim.trace {
        first_event[ev.rank] = first_event[ev.rank].min(ev.t_us);
    }
    for r in 0..n {
        if first_event[r].is_finite() {
            let a = col(first_event[r]);
            let b = col(sim.finish_us[r]);
            for c in a..=b.min(width - 1) {
                rows[r][c] = b'-';
            }
        }
    }
    for ev in &sim.trace {
        let c = col(ev.t_us);
        rows[ev.rank][c] = match ev.kind {
            TraceKind::SendStart => b'S',
            TraceKind::RecvDone => b'R',
        };
    }
    let mut out = String::new();
    out.push_str(&format!(
        "time 0 .. {} ({} cols; S=send start, R=recv done)\n",
        fmt::time_us(t_max),
        width
    ));
    for (r, row) in rows.iter().enumerate() {
        out.push_str(&format!("r{r:<3} |{}|\n", String::from_utf8_lossy(row)));
    }
    out
}

/// Export the trace as CSV (`t_us,rank,kind,peer,tag,bytes,sep`).
pub fn trace_csv(sim: &SimResult) -> String {
    let mut out = String::from("t_us,rank,kind,peer,tag,bytes,sep\n");
    for ev in &sim.trace {
        out.push_str(&format!(
            "{:.3},{},{},{},{},{},{}\n",
            ev.t_us,
            ev.rank,
            match ev.kind {
                TraceKind::SendStart => "send",
                TraceKind::RecvDone => "recv",
            },
            ev.peer,
            ev.tag,
            ev.bytes,
            ev.sep
        ));
    }
    out
}

/// One-line per-level summary of a simulation.
pub fn level_summary(sim: &SimResult, n_levels: usize) -> String {
    let mut parts = Vec::new();
    for (i, (&m, &b)) in sim.msgs_by_sep.iter().zip(&sim.bytes_by_sep).enumerate() {
        parts.push(format!(
            "{}: {m} msgs / {}",
            crate::model::sep_name(i + 1, n_levels),
            fmt::bytes(b as usize)
        ));
    }
    format!("makespan {} | {}", fmt::time_us(sim.makespan_us), parts.join(" | "))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::CollectiveEngine;
    use crate::model::presets;
    use crate::topology::{Communicator, TopologySpec};
    use crate::tree::Strategy;

    fn traced_sim() -> SimResult {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel)
            .with_trace();
        e.bcast(0, &[1.0f32; 512]).unwrap().sim
    }

    #[test]
    fn gantt_renders_all_ranks() {
        let sim = traced_sim();
        let g = gantt(&sim, 60);
        assert_eq!(g.lines().count(), 21); // header + 20 ranks
        assert!(g.contains('S'));
        assert!(g.contains('R'));
        // root row has sends
        assert!(g.lines().nth(1).unwrap().contains('S'));
    }

    #[test]
    fn gantt_without_trace_is_graceful() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let e = CollectiveEngine::new(&comm, presets::paper_grid(), Strategy::Multilevel);
        let sim = e.bcast(0, &[1.0f32; 16]).unwrap().sim;
        assert!(gantt(&sim, 40).contains("no trace"));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let sim = traced_sim();
        let csv = trace_csv(&sim);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "t_us,rank,kind,peer,tag,bytes,sep");
        assert_eq!(lines.len(), 1 + sim.trace.len());
        assert!(lines[1].contains("send"));
    }

    #[test]
    fn summary_mentions_all_levels() {
        let sim = traced_sim();
        let s = level_summary(&sim, 3);
        assert!(s.contains("WAN"));
        assert!(s.contains("LAN"));
        assert!(s.contains("intra-machine"));
    }
}
