//! L3 coordination: the Fig. 7 timing application, the experiment drivers
//! behind every reproduced table/figure, the allreduce-boundary
//! autotuner, and the end-to-end data-parallel training orchestrator.

pub mod experiment;
pub mod report;
pub mod timing_app;
pub mod training;
pub mod tuning;

pub use timing_app::{
    ack_barrier_program, default_sizes, fig8_sweep, rotation_schedule, rotation_schedule_memo,
    run_point, run_point_separate, run_point_with, TimingPoint,
};
pub use training::{train, StepLog, TrainConfig};
pub use tuning::{
    boundary_candidates, boundary_tuning_table, composition_tuning_table, tune_allreduce_boundary,
    tune_allreduce_composition, BoundaryProbe, BoundaryTuning, CompositionTuning, SearchMode,
    DEFAULT_BEAM_WIDTH,
};
