//! Micro-benchmark harness (no `criterion` in the offline vendor set):
//! warm-up, adaptive iteration, robust statistics, and a uniform report
//! format shared by all `rust/benches/*` targets.
//!
//! Benches are declared with `harness = false` in Cargo.toml and call
//! [`Bench::run`] / [`section`] directly; `cargo bench` executes them.

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// Configuration for one measured case.
#[derive(Clone, Debug)]
pub struct Bench {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub target: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 500,
            target: Duration::from_millis(300),
        }
    }
}

/// Result of one measured case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub median_us: f64,
    pub mad_us: f64,
    pub min_us: f64,
    pub max_us: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>10} med {:>12} mean ±{:>9} mad  ({} iters)",
            self.name,
            crate::util::fmt::time_us(self.median_us),
            crate::util::fmt::time_us(self.mean_us),
            crate::util::fmt::time_us(self.mad_us),
            self.iters
        )
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup_iters: 1, min_iters: 5, max_iters: 50, target: Duration::from_millis(100) }
    }

    /// Measure `f` (called repeatedly); returns robust timing stats.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Summary::new();
        let started = Instant::now();
        let mut iters = 0usize;
        while iters < self.min_iters
            || (started.elapsed() < self.target && iters < self.max_iters)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64() * 1e6);
            iters += 1;
        }
        let mut s = samples;
        let r = BenchResult {
            name: name.to_string(),
            iters,
            mean_us: s.mean(),
            median_us: s.median(),
            mad_us: s.mad(),
            min_us: s.min(),
            max_us: s.max(),
        };
        println!("{}", r.line());
        r
    }
}

/// Print a section header (groups cases in `cargo bench` output).
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Write a results table to `target/bench-reports/<name>.{md,csv}` so
/// EXPERIMENTS.md can reference regenerated tables.
pub fn save_report(name: &str, table: &crate::util::fmt::Table) {
    let dir = std::path::Path::new("target/bench-reports");
    if std::fs::create_dir_all(dir).is_err() {
        return;
    }
    let _ = std::fs::write(dir.join(format!("{name}.md")), table.to_markdown());
    let _ = std::fs::write(dir.join(format!("{name}.csv")), table.to_csv());
    println!("[saved target/bench-reports/{name}.{{md,csv}}]");
}

/// Write a machine-readable results file to
/// `target/bench-reports/BENCH_<name>.json` so CI can archive bench
/// output and trajectory tracking can diff runs. Hand-rolled JSON (no
/// `serde` in the offline vendor set); case names are emitted verbatim
/// and must not contain `"` or `\`.
pub fn save_bench_json(name: &str, results: &[BenchResult]) {
    let dir = std::path::Path::new("target/bench-reports");
    if std::fs::create_dir_all(dir).is_err() {
        return;
    }
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"bench\": \"{name}\",\n"));
    s.push_str("  \"results\": [\n");
    for (i, r) in results.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"iters\": {}, \"mean_us\": {:.3}, \
             \"median_us\": {:.3}, \"mad_us\": {:.3}, \"min_us\": {:.3}, \
             \"max_us\": {:.3}}}{}\n",
            r.name,
            r.iters,
            r.mean_us,
            r.median_us,
            r.mad_us,
            r.min_us,
            r.max_us,
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    let path = dir.join(format!("BENCH_{name}.json"));
    let _ = std::fs::write(&path, s);
    println!("[saved target/bench-reports/BENCH_{name}.json]");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_json_is_well_formed() {
        let r = BenchResult {
            name: "case/a".into(),
            iters: 3,
            mean_us: 1.5,
            median_us: 1.25,
            mad_us: 0.25,
            min_us: 1.0,
            max_us: 2.0,
        };
        // Exercise the formatter via a synthetic write; content checks
        // guard the hand-rolled JSON against comma/brace slips.
        save_bench_json("benchkit_selftest", &[r.clone(), r]);
        let text = std::fs::read_to_string(
            "target/bench-reports/BENCH_benchkit_selftest.json",
        );
        if let Ok(text) = text {
            // write can legitimately fail in sandboxed environments
            assert!(text.contains("\"bench\": \"benchkit_selftest\""));
            assert!(text.contains("\"median_us\": 1.250"));
            assert_eq!(text.matches("{\"name\"").count(), 2);
            assert!(text.contains("}},") || text.contains("},\n"), "comma between items");
            assert!(text.trim_end().ends_with('}'));
        }
        // Don't leave the synthetic file behind: `cargo test` runs before
        // the CI bench smoke step, and the whole bench-reports directory
        // is uploaded as the trajectory-tracking artifact.
        let _ = std::fs::remove_file("target/bench-reports/BENCH_benchkit_selftest.json");
    }

    #[test]
    fn measures_something() {
        let b = Bench::quick();
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters >= 5);
        assert!(r.mean_us >= 0.0);
        assert!(r.min_us <= r.median_us && r.median_us <= r.max_us);
    }

    #[test]
    fn respects_min_iters() {
        let b = Bench { warmup_iters: 0, min_iters: 7, max_iters: 7, target: Duration::ZERO };
        let r = b.run("bounded", || std::thread::sleep(Duration::from_micros(10)));
        assert_eq!(r.iters, 7);
    }
}
