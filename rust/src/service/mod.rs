//! `gridd` — the long-running tuning/planning service.
//!
//! [`GridSession`] answers one caller at a time; `gridd` promotes it to
//! a daemon serving **concurrent** clients over newline-delimited JSON
//! (Unix socket and/or TCP — see [`proto`] for the wire format). All
//! requests targeting the same `(topology, strategy)` route through one
//! shared [`Context`]: a sharded [`PlanCache`], a [`PolicyTable`] verdict
//! store, and — the headline mechanism — a [`Singleflight`] table that
//! coalesces `K` concurrent identical tune requests into exactly **one**
//! ghost sweep (latecomers block on the in-flight entry and share the
//! verdict; counter-enforced in `rust/tests/gridd_singleflight.rs`).
//!
//! Connections are handled by a bounded [`TaskPool`] whose workers each
//! own an [`ExecScratch`] arena for their whole lifetime, so scratch
//! reuse works exactly like the library's pooled probe loops — per
//! worker, not per request.
//!
//! With `--policy-dir` set, tuned verdicts write back to disk through
//! the atomic [`PolicyTable::save`] (merge-on-write, newest verdict
//! wins), and a restarted daemon seeds each context's store from the
//! persisted table: the second life of the daemon starts warm, serving
//! `tune` requests for already-tuned points from the table with zero
//! probes.

pub mod client;
pub mod proto;
pub mod singleflight;

pub use client::{Client, Target};
pub use singleflight::Singleflight;

use crate::collectives::request;
use crate::coordinator::tuning::{self, SearchMode, DEFAULT_BEAM_WIDTH};
use crate::error::{Error, Result};
use crate::model::{presets, NetworkParams};
use crate::netsim::{ExecScratch, ReduceOp};
use crate::plan::{AlgoPolicy, AllreduceAlgo, PlanCache};
use crate::session::{
    policy_from_token, policy_to_token, topology_fingerprint, GridSession, PolicyProvenance,
    PolicyTable,
};
use crate::topology::{discover, Communicator, CostMatrix, TopologySpec};
use crate::tree::{LevelPolicy, Strategy};
use crate::util::json::Value;
use crate::util::par::TaskPool;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// How a daemon is configured: at least one listener is required.
#[derive(Clone, Debug, Default)]
pub struct GriddConfig {
    /// Unix socket path to listen on (removed and rebound if stale).
    pub socket: Option<String>,
    /// TCP address to listen on, e.g. `127.0.0.1:0`.
    pub tcp: Option<String>,
    /// Worker threads (each owning one scratch arena); 0 means 1.
    pub threads: usize,
    /// Directory for persisted per-context policy tables; `None`
    /// disables write-back.
    pub policy_dir: Option<String>,
}

/// One tune verdict as it travels through the singleflight table —
/// cloneable so followers share the leader's copy.
#[derive(Clone, Debug)]
struct TuneVerdict {
    token: String,
    best_us: f64,
    probes: usize,
    /// Served from the policy store (zero probes) rather than tuned now.
    from_table: bool,
}

/// `(context key, op name, bytes, tuner kind, search mode)` — what
/// makes two tune requests "the same question". The context key (not
/// just the topology fingerprint) matters: the same topology under a
/// different strategy, or reached via spec vs. matrix, is a *different*
/// context with its own policy store, and coalescing across contexts
/// would hand followers a verdict their own store never recorded.
type FlightKey = (String, String, usize, String, String);

/// Shared per-`(topology, strategy)` state: every request against the
/// same context hits the same plan cache and policy store.
struct Context {
    /// The `ServerState::contexts` map key this context lives under —
    /// also the flight-key prefix, so flights never cross contexts.
    key: String,
    comm: Communicator,
    params: NetworkParams,
    strategy: Strategy,
    fingerprint: u64,
    cache: Arc<PlanCache>,
    store: Mutex<PolicyTable>,
    persist_path: Option<String>,
}

impl Context {
    /// A per-request session view over this context's shared state,
    /// executing on the calling worker's scratch arena.
    fn session(&self, scratch: &Arc<ExecScratch>) -> GridSession {
        GridSession::new(&self.comm, self.params.clone(), self.strategy)
            .with_plan_cache(Arc::clone(&self.cache))
            .with_scratch(Arc::clone(scratch))
    }

    /// Write the store back to `persist_path` (no-op without one):
    /// load-merge-save so a concurrently written file keeps its other
    /// verdicts, with this store's entries winning collisions. The save
    /// itself is atomic (temp file + rename).
    fn persist(&self) -> Result<()> {
        let Some(path) = &self.persist_path else {
            return Ok(());
        };
        let snapshot = self.store.lock().unwrap().clone();
        let merged = if std::path::Path::new(path).exists() {
            match PolicyTable::load(path) {
                Ok(mut disk) => {
                    disk.merge(&snapshot)?;
                    disk
                }
                Err(_) => snapshot,
            }
        } else {
            snapshot
        };
        merged.save(path)?;
        Ok(())
    }
}

struct ServerState {
    params: NetworkParams,
    policy_dir: Option<String>,
    contexts: Mutex<HashMap<String, Arc<Context>>>,
    flights: Singleflight<FlightKey, TuneVerdict>,
    /// One scratch arena per pool worker, indexed by worker id (also
    /// readable here so `stats` can report pool depths).
    scratches: Vec<Arc<ExecScratch>>,
    requests: AtomicU64,
    shutdown: AtomicBool,
}

impl ServerState {
    /// The shared context for the request's `spec`/`matrix_csv` +
    /// `strategy` parameters, created (and disk-seeded) on first use.
    fn context(&self, doc: &Value) -> Result<Arc<Context>> {
        let strategy = parse_strategy(proto::opt_str(doc, "strategy").unwrap_or("multilevel"))?;
        let (key, comm) = match proto::opt_str(doc, "matrix_csv") {
            Some(csv) => {
                let m = CostMatrix::from_tacos_csv("wire", csv)?;
                let comm = Communicator::from_matrix(&m)?;
                let key =
                    format!("matrix:{:016x}|{}", topology_fingerprint(&comm), strategy.name());
                (key, Some(comm))
            }
            None => {
                let spec_name = proto::opt_str(doc, "spec").unwrap_or("experiment");
                (format!("spec:{spec_name}|{}", strategy.name()), None)
            }
        };
        if let Some(ctx) = self.contexts.lock().unwrap().get(&key) {
            return Ok(Arc::clone(ctx));
        }
        // Build outside the lock (tree construction is not free); if two
        // requests race, the first insert wins and the loser's context is
        // dropped before serving anything.
        let comm = match comm {
            Some(c) => c,
            None => {
                let spec = parse_spec_text(proto::opt_str(doc, "spec").unwrap_or("experiment"))?;
                Communicator::world(&spec)
            }
        };
        let fingerprint = topology_fingerprint(&comm);
        let prov = PolicyProvenance::of(&comm, &self.params, strategy, &LevelPolicy::paper());
        let persist_path = self
            .policy_dir
            .as_ref()
            .map(|d| format!("{d}/policy_{fingerprint:016x}_{}.json", strategy.name()));
        let store = match persist_path.as_deref().filter(|p| std::path::Path::new(p).exists()) {
            Some(p) => {
                let table = PolicyTable::load(p)?;
                table.provenance().check_matches(&prov)?;
                table
            }
            None => PolicyTable::new(prov),
        };
        let ctx = Arc::new(Context {
            key: key.clone(),
            comm,
            params: self.params.clone(),
            strategy,
            fingerprint,
            cache: Arc::new(PlanCache::new()),
            store: Mutex::new(store),
            persist_path,
        });
        let mut map = self.contexts.lock().unwrap();
        Ok(Arc::clone(map.entry(key).or_insert(ctx)))
    }
}

fn parse_strategy(name: &str) -> Result<Strategy> {
    match name {
        "unaware" | "mpich-binomial" | "binomial" => Ok(Strategy::Unaware),
        "machine" | "magpie-machine" => Ok(Strategy::TwoLevelMachine),
        "site" | "magpie-site" => Ok(Strategy::TwoLevelSite),
        "multilevel" => Ok(Strategy::Multilevel),
        other => Err(Error::Service(format!(
            "unknown strategy '{other}' (use unaware|machine|site|multilevel)"
        ))),
    }
}

fn parse_spec_text(name: &str) -> Result<TopologySpec> {
    match name {
        "fig1" => Ok(TopologySpec::paper_fig1()),
        "experiment" => Ok(TopologySpec::paper_experiment()),
        other => {
            let parts: Vec<usize> = other.split('x').filter_map(|p| p.parse().ok()).collect();
            if parts.len() != 3 {
                return Err(Error::Service(format!(
                    "\"spec\" must be fig1|experiment|SxMxP, got '{other}'"
                )));
            }
            TopologySpec::uniform(parts[0], parts[1], parts[2])
        }
    }
}

fn parse_op(name: &str) -> Result<ReduceOp> {
    match name {
        "sum" => Ok(ReduceOp::Sum),
        "max" => Ok(ReduceOp::Max),
        "min" => Ok(ReduceOp::Min),
        "prod" => Ok(ReduceOp::Prod),
        other => {
            Err(Error::Service(format!("unknown reduce op '{other}' (use sum|max|min|prod)")))
        }
    }
}

fn parse_mode(name: &str) -> Result<SearchMode> {
    match name {
        "auto" => Ok(SearchMode::Auto),
        "exhaustive" | "full" => Ok(SearchMode::Exhaustive),
        "beam" => Ok(SearchMode::Beam { width: DEFAULT_BEAM_WIDTH }),
        other => match other.strip_prefix("beam:").map(str::parse::<usize>) {
            Some(Ok(w)) if w >= 1 => Ok(SearchMode::Beam { width: w }),
            _ => Err(Error::Service(format!(
                "unknown search mode '{other}' (use auto|exhaustive|beam|beam:W)"
            ))),
        },
    }
}

/// f32-aligned payload size from the request's `bytes` field.
fn want_elems(doc: &Value) -> Result<(usize, usize)> {
    let bytes = proto::want_u64(doc, "bytes")? as usize;
    if bytes == 0 || bytes % 4 != 0 {
        return Err(Error::Service(format!(
            "\"bytes\" must be a positive multiple of 4 (f32 payloads), got {bytes}"
        )));
    }
    Ok((bytes, bytes / 4))
}

// ---- request handlers ----------------------------------------------

fn handle_tune(
    state: &ServerState,
    scratch: &Arc<ExecScratch>,
    id: Option<u64>,
    doc: &Value,
) -> Result<String> {
    let ctx = state.context(doc)?;
    let op = parse_op(proto::opt_str(doc, "op").unwrap_or("sum"))?;
    let (bytes, _) = want_elems(doc)?;
    let kind = proto::opt_str(doc, "kind").unwrap_or("boundary").to_string();
    let mode = match kind.as_str() {
        "boundary" => None,
        "composition" => Some(parse_mode(proto::opt_str(doc, "mode").unwrap_or("auto"))?),
        other => {
            return Err(Error::Service(format!(
                "unknown tune kind '{other}' (use boundary|composition)"
            )))
        }
    };
    let respond = |v: &TuneVerdict, source: &str| {
        Ok(proto::ok_response(id)
            .str("cmd", "tune")
            .str("op", op.name())
            .num_usize("bytes", bytes)
            .str("kind", &kind)
            .str("policy", &v.token)
            .f64("best_us", v.best_us)
            .num_usize("probes", v.probes)
            .str("source", source)
            .str("fingerprint", &format!("{:016x}", ctx.fingerprint))
            .render())
    };
    // Warm path: an already-tuned point never flies (this is also what
    // makes a restarted daemon with a seeded store answer with zero
    // probes).
    if let Some(e) = ctx.store.lock().unwrap().exact(op, bytes) {
        let v = TuneVerdict {
            token: policy_to_token(e.policy),
            best_us: e.best_us,
            probes: 0,
            from_table: true,
        };
        return respond(&v, "table");
    }
    let mode_token = match mode {
        None => String::new(),
        Some(SearchMode::Auto) => "auto".to_string(),
        Some(SearchMode::Exhaustive) => "exhaustive".to_string(),
        Some(SearchMode::Beam { width }) => format!("beam:{width}"),
    };
    let key: FlightKey = (ctx.key.clone(), op.name().to_string(), bytes, kind.clone(), mode_token);
    let flight_ctx = Arc::clone(&ctx);
    let flight_scratch = Arc::clone(scratch);
    let (outcome, led) = state.flights.run(key, move || {
        // Double-check inside the flight: a leader that finished between
        // our store check and this flight's start already recorded the
        // verdict — serve it instead of re-sweeping.
        if let Some(e) = flight_ctx.store.lock().unwrap().exact(op, bytes) {
            return Ok(TuneVerdict {
                token: policy_to_token(e.policy),
                best_us: e.best_us,
                probes: 0,
                from_table: true,
            });
        }
        let session = flight_ctx.session(&flight_scratch);
        let engine = session.engine();
        let (best, best_us, probes) = match mode {
            None => {
                let t = tuning::tune_allreduce_boundary(&engine, op, bytes)
                    .map_err(|e| e.to_string())?;
                (t.best, t.best_us, t.probes_issued())
            }
            Some(m) => {
                let t = tuning::tune_allreduce_composition(&engine, op, bytes, m)
                    .map_err(|e| e.to_string())?;
                (t.best, t.best_us, t.probes_issued)
            }
        };
        flight_ctx.store.lock().unwrap().record(op, bytes, best, best_us);
        // The verdict is already recorded in the in-memory store — a
        // failed disk write-back must not turn a successful tune into
        // an error for the leader and every coalesced follower.
        if let Err(e) = flight_ctx.persist() {
            eprintln!(
                "gridd: policy write-back failed for context '{}': {e}",
                flight_ctx.key
            );
        }
        Ok(TuneVerdict { token: policy_to_token(best), best_us, probes, from_table: false })
    });
    let v = outcome.map_err(Error::Service)?;
    let source = if v.from_table {
        "table"
    } else if led {
        "tuned"
    } else {
        "coalesced"
    };
    respond(&v, source)
}

fn handle_resolve(state: &ServerState, id: Option<u64>, doc: &Value) -> Result<String> {
    let ctx = state.context(doc)?;
    let op = parse_op(proto::opt_str(doc, "op").unwrap_or("sum"))?;
    let (bytes, _) = want_elems(doc)?;
    let store = ctx.store.lock().unwrap();
    let Some(policy) = store.best_for(op, bytes) else {
        return Err(Error::Service(format!(
            "no tuned verdict for op '{}' on this topology — send a \"tune\" request first",
            op.name()
        )));
    };
    let exact = store.exact(op, bytes).is_some();
    drop(store);
    Ok(proto::ok_response(id)
        .str("cmd", "resolve")
        .str("op", op.name())
        .num_usize("bytes", bytes)
        .str("policy", &policy_to_token(policy))
        .bool("exact", exact)
        .str("fingerprint", &format!("{:016x}", ctx.fingerprint))
        .render())
}

/// `allreduce` (policy defaults to the store's verdict, then uniform
/// reduce+bcast) and `simulate` (explicit policy token required) share
/// one ghost-timing path.
fn handle_timing(
    state: &ServerState,
    scratch: &Arc<ExecScratch>,
    id: Option<u64>,
    cmd: &str,
    doc: &Value,
) -> Result<String> {
    let ctx = state.context(doc)?;
    let op = parse_op(proto::opt_str(doc, "op").unwrap_or("sum"))?;
    let (bytes, elems) = want_elems(doc)?;
    let root = proto::opt_u64(doc, "root").unwrap_or(0) as usize;
    if root >= ctx.comm.size() {
        return Err(Error::Service(format!(
            "root {root} out of range for a {}-rank topology",
            ctx.comm.size()
        )));
    }
    let policy = match proto::opt_str(doc, "policy") {
        Some(token) => policy_from_token(token)?,
        None if cmd == "allreduce" => ctx
            .store
            .lock()
            .unwrap()
            .best_for(op, bytes)
            .unwrap_or(AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast)),
        None => {
            return Err(Error::Service(
                "\"simulate\" needs an explicit \"policy\" token (use \"allreduce\" for \
                 store-resolved timing)"
                    .into(),
            ))
        }
    };
    let session = ctx.session(scratch);
    let sim = session.simulate_timing(&request::AllreduceProbe { root, op, policy, elems })?;
    Ok(proto::ok_response(id)
        .str("cmd", cmd)
        .str("op", op.name())
        .num_usize("bytes", bytes)
        .num_usize("root", root)
        .str("policy", &policy_to_token(policy))
        .f64("makespan_us", sim.makespan_us)
        .num_u64("wan_msgs", sim.wan_messages())
        .str("fingerprint", &format!("{:016x}", ctx.fingerprint))
        .render())
}

fn handle_discover(id: Option<u64>, doc: &Value) -> Result<String> {
    let csv = proto::want_str(doc, "matrix_csv")?;
    let m = CostMatrix::from_tacos_csv("wire", csv)?;
    let probe =
        proto::opt_u64(doc, "probe_bytes").unwrap_or(discover::DEFAULT_PROBE_BYTES as u64) as usize;
    let d = discover::infer_clustering(&m, probe)?;
    let comm = Communicator::from_matrix(&m)?;
    let c = &d.clustering;
    let clusters: Vec<String> =
        (0..c.n_levels()).map(|l| c.clusters_at(l).len().to_string()).collect();
    Ok(proto::ok_response(id)
        .str("cmd", "discover")
        .num_usize("n_ranks", c.n_ranks())
        .num_usize("n_levels", c.n_levels())
        .raw("clusters_per_level", &format!("[{}]", clusters.join(",")))
        .num_usize("probe_bytes", probe)
        .str("fingerprint", &format!("{:016x}", topology_fingerprint(&comm)))
        .render())
}

fn handle_stats(state: &ServerState, id: Option<u64>) -> Result<String> {
    let contexts = state.contexts.lock().unwrap();
    let (mut hits, mut misses, mut evictions) = (0u64, 0u64, 0u64);
    let (mut plans, mut footprint, mut entries) = (0usize, 0usize, 0usize);
    for ctx in contexts.values() {
        hits += ctx.cache.hits();
        misses += ctx.cache.misses();
        evictions += ctx.cache.evictions();
        plans += ctx.cache.len();
        footprint += ctx.cache.footprint_bytes();
        entries += ctx.store.lock().unwrap().len();
    }
    let n_contexts = contexts.len();
    drop(contexts);
    let ghost_pooled: usize = state.scratches.iter().map(|s| s.ghost_pool_size()).sum();
    Ok(proto::ok_response(id)
        .str("cmd", "stats")
        .num_u64("requests", state.requests.load(Ordering::Relaxed))
        .num_usize("contexts", n_contexts)
        .num_usize("policy_entries", entries)
        .num_u64("plan_hits", hits)
        .num_u64("plan_misses", misses)
        .num_u64("plan_evictions", evictions)
        .num_usize("plans_cached", plans)
        .num_usize("plan_footprint_bytes", footprint)
        .num_usize("shards_per_cache", crate::plan::cache::DEFAULT_SHARDS)
        .num_u64("singleflight_leaders", state.flights.leaders())
        .num_u64("singleflight_followers", state.flights.followers())
        .num_usize("threads", state.scratches.len())
        .num_usize("ghost_arenas_pooled", ghost_pooled)
        .render())
}

fn dispatch_cmd(
    state: &ServerState,
    worker: usize,
    id: Option<u64>,
    cmd: &str,
    doc: &Value,
) -> Result<String> {
    let scratch = &state.scratches[worker];
    match cmd {
        "ping" => Ok(proto::ok_response(id).str("cmd", "ping").str("service", "gridd").render()),
        "tune" => handle_tune(state, scratch, id, doc),
        "resolve" => handle_resolve(state, id, doc),
        "allreduce" | "simulate" => handle_timing(state, scratch, id, cmd, doc),
        "discover" => handle_discover(id, doc),
        "stats" => handle_stats(state, id),
        "shutdown" => {
            state.shutdown.store(true, Ordering::SeqCst);
            Ok(proto::ok_response(id).str("cmd", "shutdown").bool("stopping", true).render())
        }
        other => Err(Error::Service(format!(
            "unknown command '{other}' (use \
             ping|tune|resolve|allreduce|simulate|discover|stats|shutdown)"
        ))),
    }
}

fn handle_line(state: &ServerState, worker: usize, line: &str) -> String {
    state.requests.fetch_add(1, Ordering::Relaxed);
    match proto::parse_request(line) {
        Err(e) => proto::err_response(None, &e.to_string()),
        Ok((id, cmd, doc)) => match dispatch_cmd(state, worker, id, &cmd, &doc) {
            Ok(response) => response,
            Err(e) => proto::err_response(id, &e.to_string()),
        },
    }
}

// ---- transport ------------------------------------------------------

enum Stream {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Stream {
    fn configure(&self) -> std::io::Result<()> {
        // Accepted sockets must be blocking with a finite read timeout:
        // the per-connection loop wakes every 250ms to notice shutdown.
        match self {
            Stream::Unix(s) => {
                s.set_nonblocking(false)?;
                s.set_read_timeout(Some(Duration::from_millis(250)))
            }
            Stream::Tcp(s) => {
                s.set_nonblocking(false)?;
                s.set_read_timeout(Some(Duration::from_millis(250)))
            }
        }
    }

    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }

    fn write_line(&mut self, line: &str) -> std::io::Result<()> {
        let mut framed = Vec::with_capacity(line.len() + 1);
        framed.extend_from_slice(line.as_bytes());
        framed.push(b'\n');
        match self {
            Stream::Unix(s) => s.write_all(&framed),
            Stream::Tcp(s) => s.write_all(&framed),
        }
    }
}

/// A request line (including large inline cost matrices) may be long,
/// but a client streaming bytes with no newline must not grow the
/// connection buffer without bound.
const MAX_LINE_BYTES: usize = 4 << 20;

/// A connection with no traffic for this long is closed so long-lived
/// idle clients cannot pin pool workers and starve queued connections.
const IDLE_TIMEOUT: Duration = Duration::from_secs(300);

fn handle_conn(state: &ServerState, worker: usize, mut stream: Stream) {
    if stream.configure().is_err() {
        return;
    }
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut last_activity = std::time::Instant::now();
    loop {
        while let Some(pos) = buf.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = buf.drain(..=pos).collect();
            let text = String::from_utf8_lossy(&line[..line.len() - 1]);
            let trimmed = text.trim();
            if trimmed.is_empty() {
                continue;
            }
            let response = handle_line(state, worker, trimmed);
            if stream.write_line(&response).is_err() {
                return;
            }
        }
        if buf.len() > MAX_LINE_BYTES {
            let msg = proto::err_response(
                None,
                &format!("request line exceeds {MAX_LINE_BYTES} bytes without a newline"),
            );
            let _ = stream.write_line(&msg);
            return;
        }
        if state.shutdown.load(Ordering::Relaxed) {
            return;
        }
        if last_activity.elapsed() >= IDLE_TIMEOUT {
            return;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                last_activity = std::time::Instant::now();
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) => {}
            Err(_) => return,
        }
    }
}

/// The daemon: listeners are bound by [`Gridd::new`] (so a caller knows
/// the OS-assigned TCP port before serving), the accept loop runs in
/// [`Gridd::run`] (or on a background thread via [`Gridd::spawn`]), and
/// connections are drained by the worker pool. Dropping the daemon
/// joins the pool after every accepted connection finishes.
pub struct Gridd {
    state: Arc<ServerState>,
    unix: Option<(UnixListener, String)>,
    tcp: Option<TcpListener>,
    pool: TaskPool<usize>,
}

impl Gridd {
    pub fn new(cfg: GriddConfig) -> Result<Gridd> {
        if cfg.socket.is_none() && cfg.tcp.is_none() {
            return Err(Error::Service(
                "gridd needs at least one listener (--socket and/or --tcp)".into(),
            ));
        }
        let threads = cfg.threads.max(1);
        let state = Arc::new(ServerState {
            params: presets::paper_grid(),
            policy_dir: cfg.policy_dir,
            contexts: Mutex::new(HashMap::new()),
            flights: Singleflight::new(),
            scratches: (0..threads).map(|_| Arc::new(ExecScratch::new())).collect(),
            requests: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        });
        if let Some(dir) = &state.policy_dir {
            std::fs::create_dir_all(dir).map_err(|e| Error::io(dir.clone(), e))?;
        }
        let unix = match cfg.socket {
            Some(path) => {
                // A stale socket file from a dead daemon blocks bind.
                let _ = std::fs::remove_file(&path);
                let listener =
                    UnixListener::bind(&path).map_err(|e| Error::io(path.clone(), e))?;
                listener.set_nonblocking(true).map_err(|e| Error::io(path.clone(), e))?;
                Some((listener, path))
            }
            None => None,
        };
        let tcp = match cfg.tcp {
            Some(addr) => {
                let listener = TcpListener::bind(&addr).map_err(|e| Error::io(addr.clone(), e))?;
                listener.set_nonblocking(true).map_err(|e| Error::io(addr, e))?;
                Some(listener)
            }
            None => None,
        };
        let pool = TaskPool::new(threads, |w| w);
        Ok(Gridd { state, unix, tcp, pool })
    }

    /// The bound TCP address (e.g. to learn an OS-assigned port).
    pub fn tcp_addr(&self) -> Option<std::net::SocketAddr> {
        self.tcp.as_ref().and_then(|l| l.local_addr().ok())
    }

    /// The bound Unix socket path.
    pub fn socket_path(&self) -> Option<&str> {
        self.unix.as_ref().map(|(_, p)| p.as_str())
    }

    fn dispatch(&self, stream: Stream) {
        let state = Arc::clone(&self.state);
        self.pool.submit(move |w| handle_conn(&state, *w, stream));
    }

    /// Accept connections until a `shutdown` request lands, then drain
    /// in-flight connections and remove the socket file.
    pub fn run(self) -> Result<()> {
        loop {
            if self.state.shutdown.load(Ordering::Relaxed) {
                break;
            }
            let mut accepted = false;
            if let Some((listener, _)) = &self.unix {
                match listener.accept() {
                    Ok((stream, _)) => {
                        accepted = true;
                        self.dispatch(Stream::Unix(stream));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                    Err(_) => {}
                }
            }
            if let Some(listener) = &self.tcp {
                match listener.accept() {
                    Ok((stream, _)) => {
                        accepted = true;
                        self.dispatch(Stream::Tcp(stream));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                    Err(_) => {}
                }
            }
            if !accepted {
                std::thread::sleep(Duration::from_millis(5));
            }
        }
        if let Some((_, path)) = &self.unix {
            let _ = std::fs::remove_file(path);
        }
        // Dropping `self` closes the pool queue and joins the workers —
        // every accepted connection drains before this returns.
        Ok(())
    }

    /// Run the accept loop on a background thread (tests, benches).
    pub fn spawn(self) -> GriddHandle {
        GriddHandle { thread: std::thread::spawn(move || self.run()) }
    }
}

/// Join handle for a daemon spawned with [`Gridd::spawn`].
pub struct GriddHandle {
    thread: std::thread::JoinHandle<Result<()>>,
}

impl GriddHandle {
    pub fn join(self) -> Result<()> {
        self.thread
            .join()
            .map_err(|_| Error::Service("gridd server thread panicked".into()))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parameter_parsers_accept_the_cli_vocabulary() {
        assert_eq!(parse_strategy("multilevel").unwrap(), Strategy::Multilevel);
        assert_eq!(parse_strategy("mpich-binomial").unwrap(), Strategy::Unaware);
        assert!(parse_strategy("bogus").is_err());
        assert!(parse_spec_text("fig1").is_ok());
        assert!(parse_spec_text("2x2x2").is_ok());
        assert!(parse_spec_text("2x2").is_err());
        assert_eq!(parse_op("max").unwrap(), ReduceOp::Max);
        assert!(parse_op("bogus").is_err());
        assert_eq!(parse_mode("beam:4").unwrap(), SearchMode::Beam { width: 4 });
        assert_eq!(parse_mode("beam").unwrap(), SearchMode::Beam { width: DEFAULT_BEAM_WIDTH });
        assert!(parse_mode("beam:0").is_err());
    }

    #[test]
    fn config_requires_a_listener() {
        assert!(Gridd::new(GriddConfig::default()).is_err());
    }
}
