//! Singleflight probe deduplication: `K` concurrent callers asking the
//! same question get exactly **one** execution of the answer-producing
//! work, with the other `K - 1` blocking on the in-flight entry and
//! sharing its verdict.
//!
//! The `gridd` service keys flights by `(context key, op, bytes, tuner
//! kind, search mode)`: a burst of identical `tune` requests then costs
//! one ghost sweep total — counter-enforced in
//! `rust/tests/gridd_singleflight.rs` (`sim_runs` rises by exactly one
//! sweep's worth, not `K` of them).
//!
//! The work's outcome is `Result<V, String>` rather than the crate's
//! [`crate::error::Error`] (which is deliberately not `Clone`):
//! followers receive a cloned copy of whatever the leader produced,
//! including its failure.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// What a flight's work produced — cloneable so every waiter gets it.
pub type Outcome<V> = std::result::Result<V, String>;

struct Flight<V> {
    done: Mutex<Option<Outcome<V>>>,
    cv: Condvar,
}

/// Publishes a flight's outcome on drop — including the unwind path.
/// Without this, a panicking leader would leave `done` forever unset
/// (followers block on the condvar for good) and the inflight entry in
/// the map (every future caller joins the dead flight): one panic would
/// permanently wedge that tune key in a long-running daemon.
struct LeaderGuard<'a, K: std::hash::Hash + Eq + Clone, V: Clone> {
    table: &'a Singleflight<K, V>,
    flight: &'a Arc<Flight<V>>,
    key: &'a K,
    outcome: Option<Outcome<V>>,
}

impl<K: std::hash::Hash + Eq + Clone, V: Clone> Drop for LeaderGuard<'_, K, V> {
    fn drop(&mut self) {
        let outcome = self
            .outcome
            .take()
            .unwrap_or_else(|| Err("singleflight leader panicked mid-flight".to_string()));
        // Ignore mutex poisoning here: this drop may already be running
        // on an unwinding thread, and waiters only need the value.
        let mut done = self.flight.done.lock().unwrap_or_else(|e| e.into_inner());
        *done = Some(outcome);
        drop(done);
        self.flight.cv.notify_all();
        self.table
            .inflight
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .remove(self.key);
    }
}

/// In-flight call table: one entry per distinct key currently being
/// computed. See the module docs for semantics.
pub struct Singleflight<K, V> {
    inflight: Mutex<HashMap<K, Arc<Flight<V>>>>,
    leaders: AtomicU64,
    followers: AtomicU64,
}

impl<K: std::hash::Hash + Eq + Clone, V: Clone> Singleflight<K, V> {
    pub fn new() -> Self {
        Singleflight {
            inflight: Mutex::new(HashMap::new()),
            leaders: AtomicU64::new(0),
            followers: AtomicU64::new(0),
        }
    }

    /// Run `work` under `key`, deduplicated: the first caller for a key
    /// becomes the **leader** and executes `work`; callers arriving
    /// while that execution is in flight become **followers**, block,
    /// and receive a clone of the leader's outcome. Returns the outcome
    /// plus whether this caller led.
    ///
    /// Once a flight completes its entry is removed, so a *later* call
    /// with the same key runs the work again — memoization across
    /// flights is the caller's job (the service checks its policy store
    /// before flying, and the leader re-checks inside `work`).
    pub fn run(&self, key: K, work: impl FnOnce() -> Outcome<V>) -> (Outcome<V>, bool) {
        let (flight, leading) = {
            let mut map = self.inflight.lock().unwrap();
            if let Some(f) = map.get(&key) {
                self.followers.fetch_add(1, Ordering::Relaxed);
                (Arc::clone(f), false)
            } else {
                let f = Arc::new(Flight { done: Mutex::new(None), cv: Condvar::new() });
                map.insert(key.clone(), Arc::clone(&f));
                self.leaders.fetch_add(1, Ordering::Relaxed);
                (f, true)
            }
        };
        if !leading {
            let mut done = flight.done.lock().unwrap();
            while done.is_none() {
                done = flight.cv.wait(done).unwrap();
            }
            return (done.clone().expect("flight completed"), false);
        }
        let mut guard = LeaderGuard { table: self, flight: &flight, key: &key, outcome: None };
        let outcome = work();
        guard.outcome = Some(outcome.clone());
        drop(guard);
        (outcome, true)
    }

    /// How many calls led a flight (executed the work).
    pub fn leaders(&self) -> u64 {
        self.leaders.load(Ordering::Relaxed)
    }

    /// How many calls joined an in-flight computation instead.
    pub fn followers(&self) -> u64 {
        self.followers.load(Ordering::Relaxed)
    }
}

impl<K: std::hash::Hash + Eq + Clone, V: Clone> Default for Singleflight<K, V> {
    fn default() -> Self {
        Singleflight::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Barrier;

    #[test]
    fn concurrent_identical_keys_execute_once() {
        let sf = Arc::new(Singleflight::<&'static str, usize>::new());
        let executions = Arc::new(AtomicUsize::new(0));
        let k = 8;
        let barrier = Arc::new(Barrier::new(k));
        let handles: Vec<_> = (0..k)
            .map(|_| {
                let sf = Arc::clone(&sf);
                let executions = Arc::clone(&executions);
                let barrier = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    barrier.wait();
                    sf.run("tune", || {
                        executions.fetch_add(1, Ordering::Relaxed);
                        // Hold the flight open long enough that the
                        // other threads join it as followers.
                        std::thread::sleep(std::time::Duration::from_millis(50));
                        Ok(42usize)
                    })
                })
            })
            .collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(executions.load(Ordering::Relaxed), 1, "exactly one execution");
        assert_eq!(sf.leaders(), 1);
        assert_eq!(sf.followers(), (k - 1) as u64);
        assert_eq!(results.iter().filter(|(_, led)| *led).count(), 1);
        for (outcome, _) in results {
            assert_eq!(outcome.unwrap(), 42);
        }
    }

    #[test]
    fn sequential_calls_re_execute() {
        // No memoization across completed flights — that is the policy
        // store's job, by design.
        let sf = Singleflight::<u32, u32>::new();
        let executions = AtomicUsize::new(0);
        for _ in 0..3 {
            let (out, led) = sf.run(7, || {
                executions.fetch_add(1, Ordering::Relaxed);
                Ok(1)
            });
            assert!(led);
            assert_eq!(out.unwrap(), 1);
        }
        assert_eq!(executions.load(Ordering::Relaxed), 3);
        assert_eq!(sf.leaders(), 3);
        assert_eq!(sf.followers(), 0);
    }

    #[test]
    fn distinct_keys_fly_independently() {
        let sf = Singleflight::<u32, u32>::new();
        let (a, _) = sf.run(1, || Ok(10));
        let (b, _) = sf.run(2, || Ok(20));
        assert_eq!(a.unwrap(), 10);
        assert_eq!(b.unwrap(), 20);
        assert_eq!(sf.leaders(), 2);
    }

    #[test]
    fn panicking_leader_releases_followers_and_clears_the_key() {
        let sf = Arc::new(Singleflight::<u8, u8>::new());
        let barrier = Arc::new(Barrier::new(3));
        let leader = {
            let sf = Arc::clone(&sf);
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    sf.run(9, || {
                        barrier.wait();
                        std::thread::sleep(std::time::Duration::from_millis(30));
                        panic!("sweep blew up");
                    })
                }));
            })
        };
        let followers: Vec<_> = (0..2)
            .map(|_| {
                let sf = Arc::clone(&sf);
                let barrier = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    barrier.wait();
                    sf.run(9, || Ok(1))
                })
            })
            .collect();
        leader.join().unwrap();
        for h in followers {
            let (outcome, led) = h.join().unwrap();
            // A follower that joined the doomed flight gets the panic
            // error; one that arrived after cleanup led its own flight.
            match outcome {
                Err(msg) => assert!(msg.contains("panicked"), "got: {msg}"),
                Ok(v) => {
                    assert!(led);
                    assert_eq!(v, 1);
                }
            }
        }
        // The dead flight's entry is gone: a fresh call runs the work.
        let (out, led) = sf.run(9, || Ok(7));
        assert!(led);
        assert_eq!(out.unwrap(), 7);
    }

    #[test]
    fn leader_errors_propagate_to_followers() {
        let sf = Arc::new(Singleflight::<u8, u8>::new());
        let barrier = Arc::new(Barrier::new(4));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let sf = Arc::clone(&sf);
                let barrier = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    barrier.wait();
                    sf.run(0, || {
                        std::thread::sleep(std::time::Duration::from_millis(30));
                        Err("sweep failed".to_string())
                    })
                })
            })
            .collect();
        for h in handles {
            let (outcome, _) = h.join().unwrap();
            assert_eq!(outcome.unwrap_err(), "sweep failed");
        }
        assert_eq!(sf.leaders() + sf.followers(), 4);
    }
}
