//! The `gridd` wire protocol: newline-delimited JSON objects, one
//! request and one response per line, symmetric over Unix sockets and
//! TCP.
//!
//! Every request is `{"cmd": "<name>", ...params}` with an optional
//! numeric `"id"` the response echoes back. Every response carries
//! `"ok": true|false`; failures add `"error": "<message>"` and
//! successes the command's payload fields. Timing fields are written
//! with Rust's `{:?}` float formatting, which the in-tree JSON parser
//! round-trips **bit-exactly** — the daemon's verdicts compare bitwise
//! against the library path (`rust/tests/gridd_service.rs`).

use crate::error::{Error, Result};
use crate::util::json::{self, Value};

/// Incremental JSON-object writer shared by both wire directions: keys
/// land in insertion order, strings are escaped, floats rendered via
/// `{:?}` (non-finite values become `null` — JSON has no spelling for
/// them, and the parser must never see one).
#[derive(Default)]
pub struct JsonObj {
    body: String,
}

impl JsonObj {
    pub fn new() -> Self {
        JsonObj::default()
    }

    fn key(&mut self, k: &str) -> &mut String {
        if !self.body.is_empty() {
            self.body.push(',');
        }
        self.body.push('"');
        self.body.push_str(&json::escape(k));
        self.body.push_str("\":");
        &mut self.body
    }

    pub fn str(mut self, k: &str, v: &str) -> Self {
        let escaped = json::escape(v);
        let body = self.key(k);
        body.push('"');
        body.push_str(&escaped);
        body.push('"');
        self
    }

    pub fn num_u64(mut self, k: &str, v: u64) -> Self {
        let rendered = v.to_string();
        self.key(k).push_str(&rendered);
        self
    }

    pub fn num_usize(self, k: &str, v: usize) -> Self {
        self.num_u64(k, v as u64)
    }

    pub fn f64(mut self, k: &str, v: f64) -> Self {
        let rendered = if v.is_finite() { format!("{v:?}") } else { "null".to_string() };
        self.key(k).push_str(&rendered);
        self
    }

    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.key(k).push_str(if v { "true" } else { "false" });
        self
    }

    /// Insert pre-rendered JSON (an array or nested object) verbatim.
    pub fn raw(mut self, k: &str, rendered: &str) -> Self {
        self.key(k).push_str(rendered);
        self
    }

    pub fn render(self) -> String {
        format!("{{{}}}", self.body)
    }
}

/// Parse one request line into `(id, cmd, whole document)`.
pub fn parse_request(line: &str) -> Result<(Option<u64>, String, Value)> {
    let doc = json::parse(line)
        .map_err(|e| Error::Service(format!("request is not valid JSON: {e}")))?;
    let id = doc.get("id").and_then(|v| v.as_u64());
    let cmd = doc
        .get("cmd")
        .and_then(|v| v.as_str())
        .ok_or_else(|| Error::Service("request needs a string \"cmd\" field".into()))?
        .to_string();
    Ok((id, cmd, doc))
}

/// Required string parameter.
pub fn want_str<'a>(doc: &'a Value, key: &str) -> Result<&'a str> {
    doc.get(key)
        .and_then(|v| v.as_str())
        .ok_or_else(|| Error::Service(format!("request needs a string \"{key}\" field")))
}

/// Required integral parameter.
pub fn want_u64(doc: &Value, key: &str) -> Result<u64> {
    doc.get(key)
        .and_then(|v| v.as_u64())
        .ok_or_else(|| Error::Service(format!("request needs an integer \"{key}\" field")))
}

/// Optional string parameter.
pub fn opt_str<'a>(doc: &'a Value, key: &str) -> Option<&'a str> {
    doc.get(key).and_then(|v| v.as_str())
}

/// Optional integral parameter.
pub fn opt_u64(doc: &Value, key: &str) -> Option<u64> {
    doc.get(key).and_then(|v| v.as_u64())
}

/// Start a success response (the `id`, when present, is echoed first).
pub fn ok_response(id: Option<u64>) -> JsonObj {
    let obj = match id {
        Some(id) => JsonObj::new().num_u64("id", id),
        None => JsonObj::new(),
    };
    obj.bool("ok", true)
}

/// Render a failure response.
pub fn err_response(id: Option<u64>, message: &str) -> String {
    let obj = match id {
        Some(id) => JsonObj::new().num_u64("id", id),
        None => JsonObj::new(),
    };
    obj.bool("ok", false).str("error", message).render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn objects_render_and_round_trip() {
        let line = JsonObj::new()
            .str("cmd", "tune")
            .num_u64("id", 7)
            .f64("best_us", 1234.5678901234567)
            .bool("warm", true)
            .raw("sizes", "[1,2,3]")
            .render();
        let doc = json::parse(&line).unwrap();
        assert_eq!(doc.get("cmd").unwrap().as_str(), Some("tune"));
        assert_eq!(doc.get("id").unwrap().as_u64(), Some(7));
        assert_eq!(
            doc.get("best_us").unwrap().as_f64().unwrap().to_bits(),
            1234.5678901234567f64.to_bits(),
            "floats survive the wire bit-exactly"
        );
        assert_eq!(doc.get("warm").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("sizes").unwrap().as_array().map(<[Value]>::len), Some(3));
    }

    #[test]
    fn non_finite_floats_become_null() {
        let line = JsonObj::new().f64("x", f64::INFINITY).render();
        let doc = json::parse(&line).unwrap();
        assert_eq!(doc.get("x"), Some(&Value::Null));
    }

    #[test]
    fn strings_are_escaped() {
        let line = JsonObj::new().str("msg", "a \"quoted\"\nline").render();
        let doc = json::parse(&line).unwrap();
        assert_eq!(doc.get("msg").unwrap().as_str(), Some("a \"quoted\"\nline"));
    }

    #[test]
    fn request_parsing_and_errors() {
        let (id, cmd, doc) = parse_request(r#"{"cmd":"tune","id":3,"bytes":65536}"#).unwrap();
        assert_eq!(id, Some(3));
        assert_eq!(cmd, "tune");
        assert_eq!(want_u64(&doc, "bytes").unwrap(), 65536);
        assert!(want_str(&doc, "op").is_err());
        assert_eq!(opt_str(&doc, "op"), None);
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"id":1}"#).is_err(), "cmd is required");
        let err = err_response(Some(1), "boom");
        let doc = json::parse(&err).unwrap();
        assert_eq!(doc.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(doc.get("error").unwrap().as_str(), Some("boom"));
        let ok = ok_response(None).str("status", "ready").render();
        let doc = json::parse(&ok).unwrap();
        assert_eq!(doc.get("ok").unwrap().as_bool(), Some(true));
    }
}
