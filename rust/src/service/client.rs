//! Blocking `gridd` client: one connection, line-per-request, used by
//! the `gridcollect --connect` CLI paths, the e2e tests and the QPS
//! bench. Std-only, like the daemon.

use crate::error::{Error, Result};
use crate::service::proto;
use crate::util::json::Value;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::os::unix::net::UnixStream;

/// Where a `gridd` daemon listens. Parsed from the `--connect` flag:
/// anything with a `/` (or a `.sock` suffix) is a Unix socket path,
/// `host:port` is TCP.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Target {
    Unix(String),
    Tcp(String),
}

impl Target {
    pub fn parse(s: &str) -> Target {
        if s.contains('/') || s.ends_with(".sock") {
            Target::Unix(s.to_string())
        } else if s.contains(':') {
            Target::Tcp(s.to_string())
        } else {
            Target::Unix(s.to_string())
        }
    }
}

impl std::fmt::Display for Target {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Target::Unix(p) => write!(f, "unix:{p}"),
            Target::Tcp(a) => write!(f, "tcp:{a}"),
        }
    }
}

enum Conn {
    Unix(UnixStream),
    Tcp(TcpStream),
}

/// One open connection to a daemon. Requests are serialized on the
/// connection in order; responses to failed commands surface as
/// [`Error::Service`] carrying the daemon's message.
pub struct Client {
    conn: Conn,
    buf: Vec<u8>,
}

impl Client {
    pub fn connect(target: &Target) -> Result<Client> {
        let conn = match target {
            Target::Unix(path) => {
                Conn::Unix(UnixStream::connect(path).map_err(|e| Error::io(path, e))?)
            }
            Target::Tcp(addr) => {
                Conn::Tcp(TcpStream::connect(addr).map_err(|e| Error::io(addr, e))?)
            }
        };
        Ok(Client { conn, buf: Vec::new() })
    }

    fn write_all(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        match &mut self.conn {
            Conn::Unix(s) => s.write_all(bytes),
            Conn::Tcp(s) => s.write_all(bytes),
        }
    }

    fn read_some(&mut self, chunk: &mut [u8]) -> std::io::Result<usize> {
        match &mut self.conn {
            Conn::Unix(s) => s.read(chunk),
            Conn::Tcp(s) => s.read(chunk),
        }
    }

    /// Send one request line (no trailing newline) and block for the
    /// response. `ok: false` responses become [`Error::Service`].
    pub fn request(&mut self, line: &str) -> Result<Value> {
        let mut framed = String::with_capacity(line.len() + 1);
        framed.push_str(line);
        framed.push('\n');
        self.write_all(framed.as_bytes())
            .map_err(|e| Error::Service(format!("write failed: {e}")))?;
        let mut chunk = [0u8; 4096];
        loop {
            if let Some(pos) = self.buf.iter().position(|&b| b == b'\n') {
                let line: Vec<u8> = self.buf.drain(..=pos).collect();
                let text = std::str::from_utf8(&line[..line.len() - 1])
                    .map_err(|_| Error::Service("response is not UTF-8".into()))?;
                let doc = crate::util::json::parse(text)?;
                if doc.get("ok").and_then(|v| v.as_bool()) != Some(true) {
                    let msg = proto::opt_str(&doc, "error").unwrap_or("unspecified failure");
                    return Err(Error::Service(msg.to_string()));
                }
                return Ok(doc);
            }
            let n = self
                .read_some(&mut chunk)
                .map_err(|e| Error::Service(format!("read failed: {e}")))?;
            if n == 0 {
                return Err(Error::Service("connection closed before a response".into()));
            }
            self.buf.extend_from_slice(&chunk[..n]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn target_parsing() {
        assert_eq!(Target::parse("/tmp/gridd.sock"), Target::Unix("/tmp/gridd.sock".into()));
        assert_eq!(Target::parse("gridd.sock"), Target::Unix("gridd.sock".into()));
        assert_eq!(Target::parse("127.0.0.1:7070"), Target::Tcp("127.0.0.1:7070".into()));
        assert_eq!(Target::parse("plain"), Target::Unix("plain".into()));
        assert_eq!(Target::parse("127.0.0.1:7070").to_string(), "tcp:127.0.0.1:7070");
    }
}
