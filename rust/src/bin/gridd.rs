//! `gridd` — the long-running tuning/planning daemon plus a thin
//! admin client.
//!
//! ```text
//! gridd serve [--socket /tmp/gridd.sock] [--tcp 127.0.0.1:7070] [--threads 8] [--policy-dir D]
//! gridd ping --connect <socket-or-addr>
//! gridd stats --connect <socket-or-addr>
//! gridd shutdown --connect <socket-or-addr>
//! ```
//!
//! `serve` defaults to a Unix socket at `/tmp/gridd.sock` when neither
//! listener flag is given. With `--policy-dir`, every tuned verdict is
//! written back as an atomic provenance-stamped policy table, and a
//! restarted daemon starts warm from it. The workload-facing client
//! paths live in `gridcollect` (`allreduce --connect`,
//! `tune-composition --connect`); this binary only carries the admin
//! verbs.

use gridcollect::cli::Args;
use gridcollect::error::{Error, Result};
use gridcollect::service::{proto, Client, Gridd, GriddConfig, Target};

const USAGE: &str = "usage: gridd <serve|ping|stats|shutdown> [flags]
  serve     [--socket PATH] [--tcp HOST:PORT] [--threads N] [--policy-dir DIR]
  ping      --connect <socket-or-addr>
  stats     --connect <socket-or-addr>
  shutdown  --connect <socket-or-addr>";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn client(args: &Args) -> Result<Client> {
    let target = args
        .get("connect")
        .map(Target::parse)
        .ok_or_else(|| Error::Cli("need --connect <socket-or-addr>".into()))?;
    Client::connect(&target)
}

fn run(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw)?;
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "serve" => {
            let mut cfg = GriddConfig {
                socket: args.get("socket").map(str::to_string),
                tcp: args.get("tcp").map(str::to_string),
                threads: args.get_usize("threads", 8)?,
                policy_dir: args.get("policy-dir").map(str::to_string),
            };
            if cfg.socket.is_none() && cfg.tcp.is_none() {
                cfg.socket = Some("/tmp/gridd.sock".to_string());
            }
            let daemon = Gridd::new(cfg)?;
            if let Some(path) = daemon.socket_path() {
                println!("gridd: listening on unix:{path}");
            }
            if let Some(addr) = daemon.tcp_addr() {
                println!("gridd: listening on tcp:{addr}");
            }
            daemon.run()?;
            println!("gridd: shut down cleanly");
        }
        "ping" => {
            let doc = client(&args)?.request(&proto::JsonObj::new().str("cmd", "ping").render())?;
            println!("gridd: {}", doc.get("service").and_then(|v| v.as_str()).unwrap_or("?"));
        }
        "stats" => {
            let doc =
                client(&args)?.request(&proto::JsonObj::new().str("cmd", "stats").render())?;
            for key in [
                "requests",
                "contexts",
                "policy_entries",
                "plan_hits",
                "plan_misses",
                "plan_evictions",
                "plans_cached",
                "plan_footprint_bytes",
                "shards_per_cache",
                "singleflight_leaders",
                "singleflight_followers",
                "threads",
                "ghost_arenas_pooled",
            ] {
                if let Some(v) = doc.get(key).and_then(|v| v.as_u64()) {
                    println!("{key:>24}: {v}");
                }
            }
        }
        "shutdown" => {
            let doc =
                client(&args)?.request(&proto::JsonObj::new().str("cmd", "shutdown").render())?;
            if doc.get("stopping").and_then(|v| v.as_bool()) == Some(true) {
                println!("gridd: stopping");
            }
        }
        _ => println!("{USAGE}"),
    }
    Ok(())
}
