//! Experiment configuration files: a TOML-lite `key = value` format with
//! `[section]` headers (no external crates offline — see DESIGN.md §2),
//! used to load custom network parameter sets for the simulator so
//! deployments can run the benches against their own calibrations (e.g.
//! the output of `gridcollect calibrate` / `model::fit`).
//!
//! Format:
//!
//! ```toml
//! # paper_grid.net
//! combine_us_per_byte = 0.002
//!
//! [level.1]             # sep level 1 = WAN (slowest)
//! latency_us = 30000
//! bandwidth_mb_s = 2.0
//! send_overhead_us = 60
//! recv_overhead_us = 60
//! overlapped = false
//!
//! [level.2]
//! latency_us = 500
//! bandwidth_mb_s = 10
//! ```

use crate::error::{Error, Result};
use crate::model::{LinkParams, NetworkParams};
use std::collections::BTreeMap;

/// Parsed file: top-level keys + per-section key/value maps.
#[derive(Clone, Debug, Default)]
pub struct Ini {
    pub top: BTreeMap<String, String>,
    pub sections: BTreeMap<String, BTreeMap<String, String>>,
}

/// Parse the TOML-lite text.
pub fn parse(src: &str) -> Result<Ini> {
    let mut ini = Ini::default();
    let mut current: Option<String> = None;
    for (lineno, raw) in src.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            let name = name.trim().to_string();
            if name.is_empty() {
                return Err(Error::Config(format!("line {}: empty section name", lineno + 1)));
            }
            ini.sections.entry(name.clone()).or_default();
            current = Some(name);
        } else if let Some((k, v)) = line.split_once('=') {
            let (k, v) = (k.trim().to_string(), v.trim().trim_matches('"').to_string());
            if k.is_empty() {
                return Err(Error::Config(format!("line {}: empty key", lineno + 1)));
            }
            match &current {
                Some(sec) => {
                    ini.sections.get_mut(sec).unwrap().insert(k, v);
                }
                None => {
                    ini.top.insert(k, v);
                }
            }
        } else {
            return Err(Error::Config(format!("line {}: expected `key = value` or `[section]`, got '{line}'", lineno + 1)));
        }
    }
    Ok(ini)
}

fn get_f64(map: &BTreeMap<String, String>, key: &str, ctx: &str) -> Result<f64> {
    map.get(key)
        .ok_or_else(|| Error::Config(format!("{ctx}: missing '{key}'")))?
        .parse()
        .map_err(|_| Error::Config(format!("{ctx}: '{key}' is not a number")))
}

fn get_f64_or(map: &BTreeMap<String, String>, key: &str, default: f64, ctx: &str) -> Result<f64> {
    match map.get(key) {
        None => Ok(default),
        Some(v) => {
            v.parse().map_err(|_| Error::Config(format!("{ctx}: '{key}' is not a number")))
        }
    }
}

/// Build [`NetworkParams`] from a parsed file: `[level.N]` sections for
/// N = 1..D (must be contiguous from 1), optional top-level
/// `combine_us_per_byte`.
pub fn network_params(ini: &Ini) -> Result<NetworkParams> {
    let mut levels = Vec::new();
    for n in 1.. {
        let name = format!("level.{n}");
        let Some(sec) = ini.sections.get(&name) else { break };
        let ctx = format!("[{name}]");
        let mut lp = LinkParams::new(
            get_f64(sec, "latency_us", &ctx)?,
            get_f64(sec, "bandwidth_mb_s", &ctx)?,
        )
        .with_overheads(
            get_f64_or(sec, "send_overhead_us", 1.0, &ctx)?,
            get_f64_or(sec, "recv_overhead_us", 1.0, &ctx)?,
        );
        if sec.get("overlapped").map(String::as_str) == Some("true") {
            lp = lp.overlapped();
        }
        levels.push(lp);
    }
    if levels.is_empty() {
        return Err(Error::Config("no [level.N] sections (need at least [level.1])".into()));
    }
    // reject gaps / extra levels beyond the contiguous prefix
    for name in ini.sections.keys() {
        if let Some(idx) = name.strip_prefix("level.") {
            let idx: usize = idx
                .parse()
                .map_err(|_| Error::Config(format!("bad section [{name}]")))?;
            if idx == 0 || idx > levels.len() {
                return Err(Error::Config(format!(
                    "[{name}] out of order: levels must be contiguous from 1"
                )));
            }
        }
    }
    let mut params = NetworkParams::new(levels);
    if let Some(v) = ini.top.get("combine_us_per_byte") {
        params = params.with_combine_us_per_byte(
            v.parse().map_err(|_| Error::Config("combine_us_per_byte not a number".into()))?,
        );
    }
    Ok(params)
}

/// Load network params from a file path.
pub fn network_params_from_file(path: &str) -> Result<NetworkParams> {
    let src = std::fs::read_to_string(path).map_err(|e| Error::io(path, e))?;
    network_params(&parse(&src)?)
}

/// Serialize params back to the file format (round-trips through
/// [`network_params`]; used by `gridcollect calibrate --out`).
pub fn render_network_params(p: &NetworkParams) -> String {
    let mut out = String::new();
    out.push_str(&format!("combine_us_per_byte = {}\n", p.combine_us_per_byte));
    for (i, l) in p.per_sep.iter().enumerate() {
        out.push_str(&format!(
            "\n[level.{}]\nlatency_us = {}\nbandwidth_mb_s = {}\nsend_overhead_us = {}\nrecv_overhead_us = {}\noverlapped = {}\n",
            i + 1,
            l.latency_us,
            l.bandwidth_mb_s,
            l.send_overhead_us,
            l.recv_overhead_us,
            !l.sender_serializes
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;

    const SAMPLE: &str = r#"
        # a grid
        combine_us_per_byte = 0.01

        [level.1]
        latency_us = 30000   # WAN
        bandwidth_mb_s = 2.0
        send_overhead_us = 60
        recv_overhead_us = 60

        [level.2]
        latency_us = 500
        bandwidth_mb_s = 10
    "#;

    #[test]
    fn parses_sections_and_comments() {
        let ini = parse(SAMPLE).unwrap();
        assert_eq!(ini.top["combine_us_per_byte"], "0.01");
        assert_eq!(ini.sections["level.1"]["latency_us"], "30000");
        assert_eq!(ini.sections.len(), 2);
    }

    #[test]
    fn builds_network_params() {
        let p = network_params(&parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(p.n_levels(), 2);
        assert_eq!(p.at_sep(1).latency_us, 30000.0);
        assert_eq!(p.at_sep(1).send_overhead_us, 60.0);
        assert_eq!(p.at_sep(2).bandwidth_mb_s, 10.0);
        assert_eq!(p.at_sep(2).send_overhead_us, 1.0); // default
        assert!((p.combine_us_per_byte - 0.01).abs() < 1e-12);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("just words\n").is_err());
        assert!(parse("[]\n").is_err());
        assert!(parse("= 3\n").is_err());
        assert!(network_params(&parse("x = 1\n").unwrap()).is_err()); // no levels
        let gap = "[level.1]\nlatency_us=1\nbandwidth_mb_s=1\n[level.3]\nlatency_us=1\nbandwidth_mb_s=1\n";
        assert!(network_params(&parse(gap).unwrap()).is_err());
        let bad = "[level.1]\nlatency_us=abc\nbandwidth_mb_s=1\n";
        assert!(network_params(&parse(bad).unwrap()).is_err());
    }

    #[test]
    fn round_trips_presets() {
        for p in [presets::paper_grid(), presets::deep_grid(), presets::cluster_of_smps()] {
            let text = render_network_params(&p);
            let back = network_params(&parse(&text).unwrap()).unwrap();
            assert_eq!(back.n_levels(), p.n_levels());
            for sep in 1..=p.n_levels() {
                assert_eq!(back.at_sep(sep), p.at_sep(sep), "sep {sep}");
            }
            assert!((back.combine_us_per_byte - p.combine_us_per_byte).abs() < 1e-12);
        }
    }

    #[test]
    fn overlapped_flag_parses() {
        let src = "[level.1]\nlatency_us=1\nbandwidth_mb_s=1\noverlapped = true\n";
        let p = network_params(&parse(src).unwrap()).unwrap();
        assert!(!p.at_sep(1).sender_serializes);
    }
}
