//! Communicators carrying multilevel topology information.
//!
//! Mirrors §3.1 of the paper: the multilevel clustering is computed at
//! bootstrap, stored on the world communicator, and **propagated to every
//! derived communicator** (`split`) so that all communicators can build
//! multilevel topology-aware trees without communication.

use crate::error::{Error, Result};
use crate::topology::cluster::{Clustering, Rank};
use crate::topology::spec::TopologySpec;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Process-wide epoch allocator: every newly *constructed* communicator
/// (world/unaware/split/sub) gets a distinct epoch; clones share it.
static NEXT_EPOCH: AtomicU64 = AtomicU64::new(1);

/// An MPI-like communicator: an ordered process group plus the multilevel
/// clustering of exactly those processes.
#[derive(Clone, Debug)]
pub struct Communicator {
    /// Map from communicator rank to world rank.
    world_ranks: Arc<Vec<usize>>,
    /// Clustering over *communicator* ranks (already restricted).
    clustering: Arc<Clustering>,
    /// Human-readable name for reports.
    name: String,
    /// Cache identity: plans compiled against this communicator are keyed
    /// by this value (see [`crate::plan`]). Clones share the epoch (same
    /// group, same clustering => same plans apply); any freshly derived
    /// communicator gets its own.
    epoch: u64,
}

fn fresh_epoch() -> u64 {
    NEXT_EPOCH.fetch_add(1, Ordering::Relaxed)
}

impl Communicator {
    /// Bootstrap `MPI_COMM_WORLD` from a topology spec.
    pub fn world(spec: &TopologySpec) -> Self {
        let n = spec.n_procs();
        Communicator {
            world_ranks: Arc::new((0..n).collect()),
            clustering: Arc::new(spec.clustering()),
            name: format!("world[{}]", spec.name),
            epoch: fresh_epoch(),
        }
    }

    /// A topology-unaware communicator over `n` ranks (single level) —
    /// what a plain MPICH would see.
    pub fn unaware(n: usize) -> Self {
        Communicator {
            world_ranks: Arc::new((0..n).collect()),
            clustering: Arc::new(Clustering::flat(n)),
            name: format!("flat[{n}]"),
            epoch: fresh_epoch(),
        }
    }

    /// Bootstrap from a clustering inferred at runtime (see
    /// [`crate::topology::discover`]): same group semantics as
    /// [`Communicator::world`], but the colors table came from
    /// measurements instead of a spec. The `topology_fingerprint` in
    /// policy-table provenance covers only `(n_ranks, n_levels, colors)`,
    /// so a discovered communicator interoperates with tables tuned on
    /// the equivalent hand-written spec.
    pub fn discovered(clustering: Clustering, name: impl Into<String>) -> Self {
        let n = clustering.n_ranks();
        Communicator {
            world_ranks: Arc::new((0..n).collect()),
            clustering: Arc::new(clustering),
            name: format!("discovered[{}]", name.into()),
            epoch: fresh_epoch(),
        }
    }

    /// Infer the multilevel clustering from a measured cost matrix (at
    /// the default probe size) and wrap it as a communicator.
    pub fn from_matrix(m: &crate::topology::discover::CostMatrix) -> Result<Self> {
        let d = crate::topology::discover::infer_clustering(
            m,
            crate::topology::discover::DEFAULT_PROBE_BYTES,
        )?;
        Ok(Communicator::discovered(d.clustering, m.name()))
    }

    pub fn size(&self) -> usize {
        self.world_ranks.len()
    }

    /// Cache identity of this communicator's (group, clustering) pair.
    /// Stable across clones, unique across constructions — a
    /// [`crate::plan::PlanCache`] keyed by it never serves a plan built
    /// for a different communicator.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// World rank of communicator rank `r`.
    pub fn world_rank(&self, r: Rank) -> usize {
        self.world_ranks[r]
    }

    pub fn world_ranks(&self) -> &[usize] {
        &self.world_ranks
    }

    /// The multilevel clustering of this communicator's group.
    pub fn clustering(&self) -> &Clustering {
        &self.clustering
    }

    /// `MPI_Comm_split`: every rank supplies a `(color, key)`; ranks with
    /// equal color form a new communicator ordered by `(key, old rank)`.
    /// Color `None` (MPI_UNDEFINED) opts out. Returns the new
    /// communicators in ascending color order; each inherits the
    /// restriction of the parent's clustering (the §3.1 propagation rule).
    pub fn split<F>(&self, color_key: F) -> Result<Vec<Communicator>>
    where
        F: Fn(Rank) -> (Option<i64>, i64),
    {
        let mut by_color: std::collections::BTreeMap<i64, Vec<(i64, Rank)>> = Default::default();
        for r in 0..self.size() {
            let (color, key) = color_key(r);
            if let Some(c) = color {
                by_color.entry(c).or_default().push((key, r));
            }
        }
        let mut out = Vec::with_capacity(by_color.len());
        for (color, mut members) in by_color {
            members.sort_by_key(|&(key, r)| (key, r));
            let ranks: Vec<Rank> = members.iter().map(|&(_, r)| r).collect();
            let clustering = self.clustering.restrict(&ranks)?;
            let world_ranks: Vec<usize> = ranks.iter().map(|&r| self.world_ranks[r]).collect();
            out.push(Communicator {
                world_ranks: Arc::new(world_ranks),
                clustering: Arc::new(clustering),
                name: format!("{}/split{color}", self.name),
                epoch: fresh_epoch(),
            });
        }
        if out.is_empty() {
            return Err(Error::Comm("split produced no communicators".into()));
        }
        Ok(out)
    }

    /// Communicator over a subset of ranks (in the given order must be
    /// ascending-unique). Used by tests and the training driver.
    pub fn sub(&self, ranks: &[Rank]) -> Result<Communicator> {
        for w in ranks.windows(2) {
            if w[0] >= w[1] {
                return Err(Error::Comm("sub(): ranks must be ascending and unique".into()));
            }
        }
        if ranks.iter().any(|&r| r >= self.size()) {
            return Err(Error::Comm("sub(): rank out of range".into()));
        }
        Ok(Communicator {
            world_ranks: Arc::new(ranks.iter().map(|&r| self.world_ranks[r]).collect()),
            clustering: Arc::new(self.clustering.restrict(ranks)?),
            name: format!("{}/sub", self.name),
            epoch: fresh_epoch(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world() -> Communicator {
        Communicator::world(&TopologySpec::paper_fig1())
    }

    #[test]
    fn world_shape() {
        let w = world();
        assert_eq!(w.size(), 20);
        assert_eq!(w.world_rank(7), 7);
        assert_eq!(w.clustering().n_levels(), 3);
    }

    #[test]
    fn split_even_odd_propagates_clustering() {
        let w = world();
        let comms = w.split(|r| (Some((r % 2) as i64), r as i64)).unwrap();
        assert_eq!(comms.len(), 2);
        let even = &comms[0];
        assert_eq!(even.size(), 10);
        assert_eq!(even.world_rank(0), 0);
        assert_eq!(even.world_rank(5), 10); // world rank 10 is the 6th even
        // Clustering was restricted: even ranks 0..5 are SDSC, 5..10 NCSA.
        assert_eq!(even.clustering().sep(0, 4), 3); // both on SP
        assert_eq!(even.clustering().sep(0, 5), 1); // SP vs O2Ka: WAN
        assert_eq!(even.clustering().sep(5, 8), 2); // O2Ka vs O2Kb: LAN
    }

    #[test]
    fn split_with_undefined_color() {
        let w = world();
        // Only NCSA ranks participate.
        let comms = w.split(|r| (if r >= 10 { Some(0) } else { None }, r as i64)).unwrap();
        assert_eq!(comms.len(), 1);
        assert_eq!(comms[0].size(), 10);
        assert_eq!(comms[0].world_rank(0), 10);
    }

    #[test]
    fn split_key_reorders() {
        let w = world();
        // Reverse order within a single color.
        let comms = w.split(|r| (Some(0), -(r as i64))).unwrap();
        assert_eq!(comms[0].world_rank(0), 19);
        assert_eq!(comms[0].world_rank(19), 0);
    }

    #[test]
    fn split_all_undefined_errors() {
        let w = world();
        assert!(w.split(|_| (None, 0)).is_err());
    }

    #[test]
    fn sub_validates() {
        let w = world();
        assert!(w.sub(&[3, 3]).is_err());
        assert!(w.sub(&[5, 2]).is_err());
        assert!(w.sub(&[99]).is_err());
        let s = w.sub(&[0, 10, 15]).unwrap();
        assert_eq!(s.size(), 3);
        assert_eq!(s.clustering().sep(1, 2), 2); // O2Ka vs O2Kb
    }

    #[test]
    fn unaware_has_single_level() {
        let c = Communicator::unaware(8);
        assert_eq!(c.clustering().n_levels(), 1);
        assert_eq!(c.size(), 8);
    }

    #[test]
    fn discovered_communicator_matches_the_spec_world() {
        let spec = TopologySpec::paper_fig1();
        let m = crate::topology::discover::synthesize_from_spec(
            &spec,
            &crate::model::presets::paper_grid(),
            0.0,
            5,
        );
        let c = Communicator::from_matrix(&m).unwrap();
        assert_eq!(c.size(), 20);
        assert_eq!(c.clustering(), Communicator::world(&spec).clustering());
        assert!(c.name().starts_with("discovered["));
    }

    #[test]
    fn epochs_distinguish_constructions_but_not_clones() {
        let a = world();
        let b = world();
        assert_ne!(a.epoch(), b.epoch(), "independent worlds must not share plans");
        assert_eq!(a.epoch(), a.clone().epoch(), "clones are the same group");
        let subs = a.split(|r| (Some((r % 2) as i64), r as i64)).unwrap();
        assert_ne!(subs[0].epoch(), subs[1].epoch());
        assert_ne!(subs[0].epoch(), a.epoch());
        assert_ne!(a.sub(&[0, 1]).unwrap().epoch(), a.epoch());
    }
}
