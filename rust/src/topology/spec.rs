//! Declarative grid topology: a uniform-depth tree of named groups whose
//! leaves are machines with process counts. This is the structured form
//! behind both the RSL front-end (Fig. 5/6) and the programmatic builders
//! used by experiments.

use crate::error::{Error, Result};
use crate::topology::cluster::Clustering;

/// A node in the topology tree.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GroupNode {
    pub name: String,
    pub kind: NodeKind,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum NodeKind {
    /// Interior grouping (site, LAN, ...).
    Group(Vec<GroupNode>),
    /// A machine hosting `procs` MPI processes.
    Machine { procs: usize },
}

impl GroupNode {
    pub fn group(name: impl Into<String>, children: Vec<GroupNode>) -> Self {
        GroupNode { name: name.into(), kind: NodeKind::Group(children) }
    }

    pub fn machine(name: impl Into<String>, procs: usize) -> Self {
        GroupNode { name: name.into(), kind: NodeKind::Machine { procs } }
    }

    fn depth_range(&self) -> (usize, usize) {
        match &self.kind {
            NodeKind::Machine { .. } => (0, 0),
            NodeKind::Group(children) => {
                let mut lo = usize::MAX;
                let mut hi = 0;
                for c in children {
                    let (clo, chi) = c.depth_range();
                    lo = lo.min(clo + 1);
                    hi = hi.max(chi + 1);
                }
                if children.is_empty() {
                    (1, 1)
                } else {
                    (lo, hi)
                }
            }
        }
    }
}

/// A validated topology: uniform depth, >= 1 process.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TopologySpec {
    pub name: String,
    root: GroupNode,
    n_procs: usize,
    depth: usize, // levels below the root group, >= 1; machines sit at `depth`
}

/// Description of one machine, flattened in rank order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MachineInfo {
    pub name: String,
    /// Names of enclosing groups from outermost (site) to innermost.
    pub path: Vec<String>,
    pub first_rank: usize,
    pub procs: usize,
}

impl TopologySpec {
    /// Validate and wrap a group tree. Requirements: all machines at the
    /// same depth, at least one process, positive per-machine counts.
    pub fn new(name: impl Into<String>, root: GroupNode) -> Result<Self> {
        let (lo, hi) = root.depth_range();
        if lo != hi {
            return Err(Error::TopologySpec(format!(
                "machines at non-uniform depth ({lo} vs {hi}); pad the tree"
            )));
        }
        if lo == 0 {
            return Err(Error::TopologySpec("root cannot itself be a machine".into()));
        }
        let mut n = 0usize;
        let mut bad: Option<String> = None;
        visit_machines(&root, &mut |m, _| {
            if let NodeKind::Machine { procs } = m.kind {
                if procs == 0 {
                    bad = Some(m.name.clone());
                }
                n += procs;
            }
        });
        if let Some(b) = bad {
            return Err(Error::TopologySpec(format!("machine '{b}' has 0 processes")));
        }
        if n == 0 {
            return Err(Error::TopologySpec("topology has no processes".into()));
        }
        Ok(TopologySpec { name: name.into(), root, n_procs: n, depth: lo })
    }

    pub fn n_procs(&self) -> usize {
        self.n_procs
    }

    /// Number of clustering levels, including the world level:
    /// `depth + 1` (world, each interior tier, machines).
    pub fn n_levels(&self) -> usize {
        self.depth + 1
    }

    pub fn root(&self) -> &GroupNode {
        &self.root
    }

    /// Machines in rank order with their group paths.
    pub fn machines(&self) -> Vec<MachineInfo> {
        let mut out = Vec::new();
        let mut next_rank = 0usize;
        visit_machines(&self.root, &mut |m, path| {
            if let NodeKind::Machine { procs } = m.kind {
                out.push(MachineInfo {
                    name: m.name.clone(),
                    path: path.to_vec(),
                    first_rank: next_rank,
                    procs,
                });
                next_rank += procs;
            }
        });
        out
    }

    /// Derive the multilevel clustering (colors table). Ranks are assigned
    /// in tree (DFS) order; cluster ids per level in first-appearance order.
    pub fn clustering(&self) -> Clustering {
        let levels = self.n_levels();
        let mut colors: Vec<Vec<u32>> = vec![Vec::with_capacity(self.n_procs); levels];
        // counters[l] = next cluster id to assign at level l
        let mut counters = vec![0u32; levels];
        // `ancestors[l]` is the cluster id at level `l` of the node being
        // visited; when a Machine is reached, `ancestors` is a complete
        // column of the colors table (the machine's id at the leaf level
        // was assigned by its parent's loop).
        fn rec(
            node: &GroupNode,
            level: usize,
            colors: &mut Vec<Vec<u32>>,
            counters: &mut Vec<u32>,
            ancestors: &mut Vec<u32>,
        ) {
            match &node.kind {
                NodeKind::Machine { procs } => {
                    debug_assert_eq!(ancestors.len(), colors.len());
                    for _ in 0..*procs {
                        for (l, &c) in ancestors.iter().enumerate() {
                            colors[l].push(c);
                        }
                    }
                }
                NodeKind::Group(children) => {
                    for ch in children {
                        let id = counters[level];
                        counters[level] += 1;
                        ancestors.push(id);
                        rec(ch, level + 1, colors, counters, ancestors);
                        ancestors.pop();
                    }
                }
            }
        }
        // Level 0 (world): a single cluster with id 0 for every rank; the
        // recursion assigns fresh ids per child group at each deeper level.
        let mut ancestors = vec![0u32];
        rec(&self.root, 1, &mut colors, &mut counters, &mut ancestors);
        Clustering::new(colors).expect("spec-derived clustering is valid by construction")
    }

    // ---------------------------------------------------------------
    // Canned builders used throughout tests, examples and benchmarks.
    // ---------------------------------------------------------------

    /// `sites[s][m]` = process count of machine `m` at site `s`
    /// (3 levels: world / site / machine).
    pub fn grid(name: &str, sites: &[Vec<usize>]) -> Result<Self> {
        let site_nodes = sites
            .iter()
            .enumerate()
            .map(|(si, machines)| {
                GroupNode::group(
                    format!("site{si}"),
                    machines
                        .iter()
                        .enumerate()
                        .map(|(mi, &p)| GroupNode::machine(format!("site{si}-m{mi}"), p))
                        .collect(),
                )
            })
            .collect();
        TopologySpec::new(name, GroupNode::group("grid", site_nodes))
    }

    /// Uniform grid: `sites` sites × `machines` machines × `procs` processes.
    pub fn uniform(sites: usize, machines: usize, procs: usize) -> Result<Self> {
        TopologySpec::grid(
            &format!("uniform-{sites}x{machines}x{procs}"),
            &vec![vec![procs; machines]; sites],
        )
    }

    /// The paper's Fig. 1 example: 10 procs on the SDSC SP; 5 on each of
    /// two NCSA O2Ks (which share a LAN).
    pub fn paper_fig1() -> Self {
        TopologySpec::new(
            "fig1",
            GroupNode::group(
                "grid",
                vec![
                    GroupNode::group("SDSC", vec![GroupNode::machine("SP", 10)]),
                    GroupNode::group(
                        "NCSA",
                        vec![GroupNode::machine("O2Ka", 5), GroupNode::machine("O2Kb", 5)],
                    ),
                ],
            ),
        )
        .expect("static spec")
    }

    /// The §4 experiment: 16 procs on the SDSC SP and 16 on each of the
    /// ANL SP and ANL O2K (ANL machines share a LAN). 48 processes total.
    pub fn paper_experiment() -> Self {
        TopologySpec::new(
            "paper-experiment",
            GroupNode::group(
                "grid",
                vec![
                    GroupNode::group("SDSC", vec![GroupNode::machine("SDSC-SP", 16)]),
                    GroupNode::group(
                        "ANL",
                        vec![GroupNode::machine("ANL-SP", 16), GroupNode::machine("ANL-O2K", 16)],
                    ),
                ],
            ),
        )
        .expect("static spec")
    }
}

fn visit_machines<'a, F: FnMut(&'a GroupNode, &[String])>(node: &'a GroupNode, f: &mut F) {
    fn rec<'a, F: FnMut(&'a GroupNode, &[String])>(
        node: &'a GroupNode,
        path: &mut Vec<String>,
        f: &mut F,
    ) {
        match &node.kind {
            NodeKind::Machine { .. } => f(node, path),
            NodeKind::Group(children) => {
                for c in children {
                    path.push(node.name.clone());
                    rec(c, path, f);
                    path.pop();
                }
            }
        }
    }
    let mut path = Vec::new();
    match &node.kind {
        NodeKind::Machine { .. } => f(node, &path),
        NodeKind::Group(children) => {
            for c in children {
                rec(c, &mut path, f);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_shape() {
        let t = TopologySpec::paper_fig1();
        assert_eq!(t.n_procs(), 20);
        assert_eq!(t.n_levels(), 3);
        let ms = t.machines();
        assert_eq!(ms.len(), 3);
        assert_eq!(ms[0].name, "SP");
        assert_eq!(ms[0].first_rank, 0);
        assert_eq!(ms[1].name, "O2Ka");
        assert_eq!(ms[1].first_rank, 10);
        assert_eq!(ms[2].first_rank, 15);
        assert_eq!(ms[1].path, vec!["NCSA".to_string()]);
    }

    #[test]
    fn fig1_clustering_matches_hand_built() {
        let t = TopologySpec::paper_fig1();
        let c = t.clustering();
        assert_eq!(c.n_levels(), 3);
        assert_eq!(c.n_ranks(), 20);
        assert_eq!(c.sep(0, 9), 3); // same SP
        assert_eq!(c.sep(0, 10), 1); // WAN
        assert_eq!(c.sep(10, 15), 2); // LAN between O2Ks
        assert_eq!(c.clusters_at(1).len(), 2);
        assert_eq!(c.clusters_at(2).len(), 3);
    }

    #[test]
    fn paper_experiment_shape() {
        let t = TopologySpec::paper_experiment();
        assert_eq!(t.n_procs(), 48);
        let c = t.clustering();
        assert_eq!(c.members(1, 1).len(), 32); // ANL site
        assert_eq!(c.sep(16, 32), 2); // ANL-SP vs ANL-O2K: LAN
        assert_eq!(c.sep(0, 16), 1); // SDSC vs ANL: WAN
    }

    #[test]
    fn uniform_builder() {
        let t = TopologySpec::uniform(4, 2, 8).unwrap();
        assert_eq!(t.n_procs(), 64);
        assert_eq!(t.machines().len(), 8);
        let c = t.clustering();
        assert_eq!(c.clusters_at(1).len(), 4);
        assert_eq!(c.clusters_at(2).len(), 8);
    }

    #[test]
    fn four_level_topology() {
        // world -> site -> lan -> machine (the MPICH-G2 4-level table).
        let t = TopologySpec::new(
            "deep",
            GroupNode::group(
                "grid",
                vec![
                    GroupNode::group(
                        "siteA",
                        vec![
                            GroupNode::group(
                                "lanA1",
                                vec![GroupNode::machine("a", 2), GroupNode::machine("b", 2)],
                            ),
                            GroupNode::group("lanA2", vec![GroupNode::machine("c", 2)]),
                        ],
                    ),
                    GroupNode::group(
                        "siteB",
                        vec![GroupNode::group("lanB1", vec![GroupNode::machine("d", 2)])],
                    ),
                ],
            ),
        )
        .unwrap();
        assert_eq!(t.n_levels(), 4);
        let c = t.clustering();
        assert_eq!(c.sep(0, 2), 3); // a vs b: same lan, different machine
        assert_eq!(c.sep(0, 4), 2); // a vs c: same site, different lan
        assert_eq!(c.sep(0, 6), 1); // a vs d: WAN
    }

    #[test]
    fn rejects_non_uniform_depth() {
        let bad = GroupNode::group(
            "grid",
            vec![
                GroupNode::machine("shallow", 1),
                GroupNode::group("deep", vec![GroupNode::machine("m", 1)]),
            ],
        );
        assert!(TopologySpec::new("bad", bad).is_err());
    }

    #[test]
    fn rejects_zero_procs_and_empty() {
        let zero = GroupNode::group("g", vec![GroupNode::machine("m", 0)]);
        assert!(TopologySpec::new("z", zero).is_err());
        let machine_root = GroupNode::machine("m", 4);
        assert!(TopologySpec::new("m", machine_root).is_err());
    }
}
