//! Multilevel process clustering — the paper's replacement for "hidden
//! communicators" (§1, §3.1): per-process **integer vectors** describing, at
//! every network level, which cluster each process belongs to.
//!
//! `colors[l][r]` is the cluster id of rank `r` at level `l`. Level 0 is the
//! whole world (everyone color 0); deeper levels refine shallower ones
//! (MPICH-G2's "depths & colors" table). For the canonical 3-level grid:
//! level 0 = world, level 1 = site (WAN between sites), level 2 = machine
//! (LAN between machines of a site, vendor-MPI/shared memory within).

use crate::error::{Error, Result};

/// A communicator rank (dense `0..n`).
pub type Rank = usize;

/// Nested multilevel partition of ranks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Clustering {
    /// `colors[l][r]` = cluster id of rank `r` at level `l`; `colors[0]` all 0.
    colors: Vec<Vec<u32>>,
}

impl Clustering {
    /// Build from explicit color vectors. Validates shape and nestedness.
    pub fn new(colors: Vec<Vec<u32>>) -> Result<Self> {
        let c = Clustering { colors };
        c.validate()?;
        Ok(c)
    }

    /// The trivial clustering: one level, everyone in one cluster
    /// (a topology-unaware view of `n` ranks).
    pub fn flat(n: usize) -> Self {
        Clustering { colors: vec![vec![0; n]] }
    }

    fn validate(&self) -> Result<()> {
        if self.colors.is_empty() {
            return Err(Error::TopologySpec("clustering needs >= 1 level".into()));
        }
        let n = self.colors[0].len();
        if n == 0 {
            return Err(Error::TopologySpec("clustering needs >= 1 rank".into()));
        }
        if self.colors[0].iter().any(|&c| c != 0) {
            return Err(Error::TopologySpec("level 0 must be a single cluster (color 0)".into()));
        }
        for (l, lv) in self.colors.iter().enumerate() {
            if lv.len() != n {
                return Err(Error::TopologySpec(format!(
                    "level {l} has {} ranks, expected {n}",
                    lv.len()
                )));
            }
        }
        // Nestedness: same color at level l+1 implies same color at level
        // l. Violations name the offending rank pair — discovery emits
        // machine-generated tables, so "which ranks disagree" is the
        // actionable part of the diagnostic.
        for l in 1..self.colors.len() {
            let mut parent_of: std::collections::HashMap<u32, (u32, usize)> = Default::default();
            for r in 0..n {
                let child = self.colors[l][r];
                let parent = self.colors[l - 1][r];
                match parent_of.entry(child) {
                    std::collections::hash_map::Entry::Vacant(v) => {
                        v.insert((parent, r));
                    }
                    std::collections::hash_map::Entry::Occupied(o) => {
                        let &(prev, first) = o.get();
                        if prev != parent {
                            return Err(Error::TopologySpec(format!(
                                "non-hierarchical clustering: level-{l} cluster {child} spans \
                                 level-{} clusters {prev} and {parent} (ranks {first} and {r})",
                                l - 1
                            )));
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Number of levels `D` (>= 1).
    pub fn n_levels(&self) -> usize {
        self.colors.len()
    }

    /// Number of ranks.
    pub fn n_ranks(&self) -> usize {
        self.colors[0].len()
    }

    /// Cluster id of `r` at level `l`.
    pub fn color(&self, l: usize, r: Rank) -> u32 {
        self.colors[l][r]
    }

    /// **Separation level** of two ranks: the smallest level at which they
    /// fall in different clusters; `n_levels()` if they never differ
    /// (same machine). `sep==1` means the pair crosses the WAN;
    /// `sep==n_levels()` means intra-machine.
    pub fn sep(&self, a: Rank, b: Rank) -> usize {
        for l in 0..self.colors.len() {
            if self.colors[l][a] != self.colors[l][b] {
                return l;
            }
        }
        self.colors.len()
    }

    /// Distinct cluster ids at level `l`, in first-appearance (rank) order.
    pub fn clusters_at(&self, l: usize) -> Vec<u32> {
        let mut seen = Vec::new();
        for &c in &self.colors[l] {
            if !seen.contains(&c) {
                seen.push(c);
            }
        }
        seen
    }

    /// Member ranks of cluster `c` at level `l`, ascending.
    pub fn members(&self, l: usize, c: u32) -> Vec<Rank> {
        (0..self.n_ranks()).filter(|&r| self.colors[l][r] == c).collect()
    }

    /// Partition a *subset* of ranks by their level-`l` color, preserving
    /// first-appearance order of clusters and member order. Used by the
    /// multilevel tree builder's recursion.
    pub fn partition(&self, ranks: &[Rank], l: usize) -> Vec<Vec<Rank>> {
        let mut order: Vec<u32> = Vec::new();
        let mut groups: std::collections::HashMap<u32, Vec<Rank>> = Default::default();
        for &r in ranks {
            let c = self.colors[l][r];
            if !order.contains(&c) {
                order.push(c);
            }
            groups.entry(c).or_default().push(r);
        }
        order.into_iter().map(|c| groups.remove(&c).unwrap()).collect()
    }

    /// Restriction to a subset of ranks (the §3.1 propagation rule for
    /// `MPI_Comm_split`): new rank `i` corresponds to `ranks[i]`; colors are
    /// re-numbered densely per level (first-appearance order) and levels
    /// that have become degenerate duplicates of their parent are *kept*
    /// (MPICH-G2 keeps the full depth table), so `n_levels` is preserved.
    pub fn restrict(&self, ranks: &[Rank]) -> Result<Self> {
        if ranks.is_empty() {
            return Err(Error::TopologySpec("cannot restrict to zero ranks".into()));
        }
        for &r in ranks {
            if r >= self.n_ranks() {
                return Err(Error::TopologySpec(format!(
                    "restrict: rank {r} out of range ({} ranks)",
                    self.n_ranks()
                )));
            }
        }
        let mut colors = Vec::with_capacity(self.n_levels());
        for l in 0..self.n_levels() {
            let mut map: std::collections::HashMap<u32, u32> = Default::default();
            let mut next = 0u32;
            let lv: Vec<u32> = ranks
                .iter()
                .map(|&r| {
                    let c = self.colors[l][r];
                    *map.entry(c).or_insert_with(|| {
                        let v = next;
                        next += 1;
                        v
                    })
                })
                .collect();
            colors.push(lv);
        }
        Clustering::new(colors)
    }

    /// Per-rank "depths" vector in the MPICH-G2 sense: for rank `r`, the
    /// number of levels in which `r`'s cluster is non-trivial w.r.t. its
    /// siblings is not needed for tree building — what the builders use is
    /// the full color table. Exposed for the MPI-attribute-style API.
    pub fn depths(&self) -> Vec<usize> {
        vec![self.n_levels(); self.n_ranks()]
    }

    /// Collapse to a 2-level view at level `l` (the MagPIe comparison):
    /// level 0 = world, level 1 = the level-`l` clusters.
    pub fn two_level_view(&self, l: usize) -> Result<Clustering> {
        if l == 0 || l >= self.n_levels() {
            return Err(Error::TopologySpec(format!(
                "two_level_view: level {l} out of range 1..{}",
                self.n_levels()
            )));
        }
        Clustering::new(vec![self.colors[0].clone(), self.colors[l].clone()])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 3-level example from the paper's Fig. 1: 10 procs on the SDSC SP,
    /// 5 on each of two NCSA O2Ks sharing a LAN.
    fn fig1() -> Clustering {
        let n = 20;
        let world = vec![0u32; n];
        let mut site = vec![0u32; n];
        let mut machine = vec![0u32; n];
        for r in 0..n {
            if r < 10 {
                site[r] = 0; // SDSC
                machine[r] = 0; // SP
            } else {
                site[r] = 1; // NCSA
                machine[r] = if r < 15 { 1 } else { 2 }; // O2Ka / O2Kb
            }
        }
        Clustering::new(vec![world, site, machine]).unwrap()
    }

    #[test]
    fn fig1_separation_levels() {
        let c = fig1();
        assert_eq!(c.sep(0, 5), 3); // same machine (SP)
        assert_eq!(c.sep(10, 12), 3); // same machine (O2Ka)
        assert_eq!(c.sep(10, 17), 2); // O2Ka vs O2Kb: same site, LAN link
        assert_eq!(c.sep(0, 10), 1); // SDSC vs NCSA: WAN link
        assert_eq!(c.sep(3, 3), 3);
    }

    #[test]
    fn clusters_and_members() {
        let c = fig1();
        assert_eq!(c.clusters_at(1), vec![0, 1]);
        assert_eq!(c.clusters_at(2), vec![0, 1, 2]);
        assert_eq!(c.members(2, 1), vec![10, 11, 12, 13, 14]);
        assert_eq!(c.members(1, 0), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn partition_subset_preserves_order() {
        let c = fig1();
        let subset = [17, 3, 11, 9, 18];
        let parts = c.partition(&subset, 2);
        // first-appearance order: machine of 17 (O2Kb), of 3 (SP), of 11 (O2Ka)
        assert_eq!(parts, vec![vec![17, 18], vec![3, 9], vec![11]]);
    }

    #[test]
    fn nestedness_violation_rejected() {
        // Level-2 cluster 0 spans both level-1 clusters -> invalid.
        let world = vec![0, 0];
        let site = vec![0, 1];
        let machine = vec![0, 0];
        assert!(Clustering::new(vec![world, site, machine]).is_err());
    }

    #[test]
    fn nestedness_violation_names_the_offending_rank_pair() {
        // Ranks 1 and 3 share machine cluster 1 but sit in different
        // sites — the error must name exactly that pair.
        let world = vec![0; 4];
        let site = vec![0, 0, 1, 1];
        let machine = vec![0, 1, 2, 1];
        let err = Clustering::new(vec![world, site, machine]).unwrap_err().to_string();
        assert!(err.contains("non-hierarchical"), "got: {err}");
        assert!(err.contains("ranks 1 and 3"), "got: {err}");
        assert!(err.contains("cluster 1"), "got: {err}");
    }

    #[test]
    fn level0_must_be_single_cluster() {
        assert!(Clustering::new(vec![vec![0, 1]]).is_err());
    }

    #[test]
    fn restrict_renumbers_densely() {
        let c = fig1();
        // NCSA only: ranks 10..20.
        let sub = c.restrict(&(10..20).collect::<Vec<_>>()).unwrap();
        assert_eq!(sub.n_ranks(), 10);
        assert_eq!(sub.n_levels(), 3);
        // All in one site now (color 0 after renumbering).
        assert!((0..10).all(|r| sub.color(1, r) == 0));
        // Two machines, colors 0 and 1.
        assert_eq!(sub.clusters_at(2), vec![0, 1]);
        assert_eq!(sub.sep(0, 5), 2); // O2Ka vs O2Kb is now the deepest split
    }

    #[test]
    fn restrict_rejects_bad_ranks() {
        let c = fig1();
        assert!(c.restrict(&[25]).is_err());
        assert!(c.restrict(&[]).is_err());
    }

    #[test]
    fn two_level_views() {
        let c = fig1();
        let by_site = c.two_level_view(1).unwrap();
        assert_eq!(by_site.n_levels(), 2);
        assert_eq!(by_site.clusters_at(1).len(), 2);
        let by_machine = c.two_level_view(2).unwrap();
        assert_eq!(by_machine.clusters_at(1).len(), 3);
        assert!(c.two_level_view(0).is_err());
        assert!(c.two_level_view(3).is_err());
    }

    #[test]
    fn flat_clustering() {
        let c = Clustering::flat(4);
        assert_eq!(c.n_levels(), 1);
        assert_eq!(c.sep(0, 3), 1); // beyond the last level: "same machine"
        assert_eq!(c.clusters_at(0), vec![0]);
    }
}
