//! Grid topology: declarative specs, the RSL front-end (Fig. 5/6), the
//! multilevel clustering table (§3.1), topology-carrying communicators,
//! and measurement-driven clustering discovery.

pub mod cluster;
pub mod comm;
pub mod discover;
pub mod rsl;
pub mod spec;

pub use cluster::{Clustering, Rank};
pub use comm::Communicator;
pub use discover::{CostMatrix, Discovery};
pub use spec::{GroupNode, MachineInfo, NodeKind, TopologySpec};
