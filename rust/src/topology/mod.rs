//! Grid topology: declarative specs, the RSL front-end (Fig. 5/6), the
//! multilevel clustering table (§3.1), and topology-carrying communicators.

pub mod cluster;
pub mod comm;
pub mod rsl;
pub mod spec;

pub use cluster::{Clustering, Rank};
pub use comm::Communicator;
pub use spec::{GroupNode, MachineInfo, NodeKind, TopologySpec};
