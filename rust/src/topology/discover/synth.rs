//! Synthetic measurement generation: sample a ground-truth clustering
//! through the [`NetworkParams`] cost model to produce the matrix a real
//! N×N probe sweep would have measured on that grid.
//!
//! The generator is the test bed for inference: with `noise == 0` the
//! matrix is exactly the model's per-separation channel table, so
//! [`super::infer_clustering`] must reproduce the ground-truth clustering
//! bit-for-bit (same `topology_fingerprint`); with jitter it exercises
//! the gap heuristic's tolerance.

use crate::model::NetworkParams;
use crate::topology::cluster::Clustering;
use crate::topology::discover::matrix::CostMatrix;
use crate::topology::spec::TopologySpec;
use crate::util::rng::Rng;

/// Sample a measured matrix from a ground-truth clustering: each ordered
/// pair `(a, b)` reports the latency/bandwidth of the channel class at
/// their separation level, independently jittered by up to
/// `±noise` (relative; `0.0` is exact, `0.1` is ±10%). Deterministic in
/// `seed`.
pub fn synthesize_from_clustering(
    clustering: &Clustering,
    params: &NetworkParams,
    name: impl Into<String>,
    noise: f64,
    seed: u64,
) -> CostMatrix {
    assert!((0.0..1.0).contains(&noise), "noise must be in [0, 1), got {noise}");
    let n = clustering.n_ranks();
    let mut rng = Rng::new(seed);
    let mut latency_us = vec![0.0f64; n * n];
    let mut bandwidth_mb_s = vec![f64::INFINITY; n * n];
    for src in 0..n {
        for dst in 0..n {
            if src == dst {
                continue;
            }
            let link = params.at_sep(clustering.sep(src, dst));
            latency_us[src * n + dst] = link.latency_us * jitter(&mut rng, noise);
            bandwidth_mb_s[src * n + dst] = link.bandwidth_mb_s * jitter(&mut rng, noise);
        }
    }
    CostMatrix::new(name, n, latency_us, bandwidth_mb_s)
        .expect("synthesized matrix is valid by construction")
}

/// [`synthesize_from_clustering`] on a spec's derived clustering; the
/// matrix is named after the spec.
pub fn synthesize_from_spec(
    spec: &TopologySpec,
    params: &NetworkParams,
    noise: f64,
    seed: u64,
) -> CostMatrix {
    synthesize_from_clustering(&spec.clustering(), params, spec.name.clone(), noise, seed)
}

fn jitter(rng: &mut Rng, noise: f64) -> f64 {
    if noise == 0.0 {
        1.0
    } else {
        1.0 + (rng.f64() * 2.0 - 1.0) * noise
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;

    #[test]
    fn noiseless_matrix_is_exactly_the_model_table() {
        let spec = TopologySpec::paper_fig1();
        let params = presets::paper_grid();
        let m = synthesize_from_spec(&spec, &params, 0.0, 7);
        let c = spec.clustering();
        // Same machine (ranks 0,5): intra link, exactly.
        assert_eq!(m.latency_us(0, 5), params.at_sep(c.sep(0, 5)).latency_us);
        // WAN pair (0, 10).
        assert_eq!(m.latency_us(0, 10), 30_000.0);
        assert_eq!(m.bandwidth_mb_s(0, 10), 2.0);
    }

    #[test]
    fn noise_is_bounded_and_deterministic() {
        let spec = TopologySpec::paper_fig1();
        let params = presets::paper_grid();
        let a = synthesize_from_spec(&spec, &params, 0.1, 42);
        let b = synthesize_from_spec(&spec, &params, 0.1, 42);
        let other = synthesize_from_spec(&spec, &params, 0.1, 43);
        let mut any_differs = false;
        for src in 0..20 {
            for dst in 0..20 {
                if src == dst {
                    continue;
                }
                assert_eq!(a.latency_us(src, dst), b.latency_us(src, dst), "same seed");
                let truth = params.at_sep(spec.clustering().sep(src, dst)).latency_us;
                let rel = (a.latency_us(src, dst) - truth).abs() / truth;
                assert!(rel <= 0.1 + 1e-12, "jitter bound at ({src},{dst}): {rel}");
                any_differs |= a.latency_us(src, dst) != other.latency_us(src, dst);
            }
        }
        assert!(any_differs, "different seeds must differ somewhere");
    }
}
