//! Measured inter-process cost matrices — the ingestion side of topology
//! discovery.
//!
//! A [`CostMatrix`] holds one `(latency, bandwidth)` observation per
//! ordered rank pair, the output of an N×N probe sweep (every process
//! pings every other). The on-disk form is the TACOS-style CSV edge list:
//!
//! ```text
//! 4                                    # rank count
//! Src,Dest,Latency (ns),Bandwidth (GB/s)
//! 0,1,30000000,0.002
//! 0,2,500000,0.01
//! ...
//! ```
//!
//! Latencies are nanoseconds and bandwidths GB/s on disk (the TACOS
//! convention); in memory everything is microseconds and MB/s (== bytes
//! per microsecond), matching [`crate::model::LinkParams`]. Missing
//! reverse directions are mirrored; a pair measured in neither direction
//! is an error.

use crate::error::{Error, Result};

/// Probe payload used to collapse a `(latency, bandwidth)` measurement
/// into one scalar cost during inference: small enough to stay
/// latency-dominated (where level boundaries are sharpest), large enough
/// that bandwidth still separates links with degenerate latencies.
pub const DEFAULT_PROBE_BYTES: usize = 1024;

/// An N×N matrix of measured point-to-point channel parameters.
#[derive(Clone, Debug)]
pub struct CostMatrix {
    n: usize,
    name: String,
    /// Row-major `[src * n + dst]`, microseconds; diagonal 0.
    latency_us: Vec<f64>,
    /// Row-major, MB/s; diagonal +inf (a rank reaches itself for free).
    bandwidth_mb_s: Vec<f64>,
}

impl CostMatrix {
    /// Build from dense row-major tables. Validates shape and that every
    /// off-diagonal entry is a usable measurement.
    pub fn new(
        name: impl Into<String>,
        n: usize,
        latency_us: Vec<f64>,
        bandwidth_mb_s: Vec<f64>,
    ) -> Result<CostMatrix> {
        if n == 0 {
            return Err(Error::Config("cost matrix needs >= 1 rank".into()));
        }
        if latency_us.len() != n * n || bandwidth_mb_s.len() != n * n {
            return Err(Error::Config(format!(
                "cost matrix tables must be {n}x{n} ({} entries), got {} latencies and {} bandwidths",
                n * n,
                latency_us.len(),
                bandwidth_mb_s.len()
            )));
        }
        for src in 0..n {
            for dst in 0..n {
                if src == dst {
                    continue;
                }
                let lat = latency_us[src * n + dst];
                let bw = bandwidth_mb_s[src * n + dst];
                if !lat.is_finite() || lat < 0.0 {
                    return Err(Error::Config(format!(
                        "cost matrix ({src},{dst}): bad latency {lat}"
                    )));
                }
                if bw <= 0.0 || bw.is_nan() {
                    return Err(Error::Config(format!(
                        "cost matrix ({src},{dst}): bad bandwidth {bw}"
                    )));
                }
            }
        }
        Ok(CostMatrix { n, name: name.into(), latency_us, bandwidth_mb_s })
    }

    pub fn n_ranks(&self) -> usize {
        self.n
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn latency_us(&self, src: usize, dst: usize) -> f64 {
        self.latency_us[src * self.n + dst]
    }

    pub fn bandwidth_mb_s(&self, src: usize, dst: usize) -> f64 {
        self.bandwidth_mb_s[src * self.n + dst]
    }

    /// Directed probe cost (the `l + N/b` of §4) for a payload of
    /// `probe_bytes`.
    pub fn cost_us(&self, src: usize, dst: usize, probe_bytes: usize) -> f64 {
        self.latency_us(src, dst) + probe_bytes as f64 / self.bandwidth_mb_s(src, dst)
    }

    /// Symmetrized pair cost: the mean of the two directions (real probe
    /// sweeps are never perfectly symmetric; inference works on the
    /// undirected view).
    pub fn pair_cost_us(&self, a: usize, b: usize, probe_bytes: usize) -> f64 {
        0.5 * (self.cost_us(a, b, probe_bytes) + self.cost_us(b, a, probe_bytes))
    }

    /// Serialize as a TACOS-style CSV edge list (diagonal omitted).
    pub fn to_tacos_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.n));
        out.push_str("Src,Dest,Latency (ns),Bandwidth (GB/s)\n");
        for src in 0..self.n {
            for dst in 0..self.n {
                if src == dst {
                    continue;
                }
                let lat_ns = self.latency_us(src, dst) * 1000.0;
                let bw_gb_s = self.bandwidth_mb_s(src, dst) / 1000.0;
                out.push_str(&format!("{src},{dst},{lat_ns},{bw_gb_s}\n"));
            }
        }
        out
    }

    /// Parse a TACOS-style CSV edge list. Pairs measured in only one
    /// direction are mirrored; pairs measured in neither are an error
    /// naming the first missing one.
    pub fn from_tacos_csv(name: impl Into<String>, text: &str) -> Result<CostMatrix> {
        let mut lines = text
            .lines()
            .enumerate()
            .map(|(i, l)| (i + 1, l.trim()))
            .filter(|&(_, l)| !l.is_empty() && !l.starts_with('#'));
        let (_, first) = lines
            .next()
            .ok_or_else(|| Error::Config("matrix csv: empty file".into()))?;
        let n: usize = first
            .parse()
            .map_err(|_| Error::Config(format!("matrix csv: bad rank count '{first}'")))?;
        if n == 0 {
            return Err(Error::Config("matrix csv: rank count must be >= 1".into()));
        }
        let mut latency_us = vec![0.0f64; n * n];
        let mut bandwidth_mb_s = vec![f64::INFINITY; n * n];
        let mut seen = vec![false; n * n];
        for (lineno, line) in lines {
            // Header row(s): anything whose first field is not a rank id.
            if line.split(',').next().is_some_and(|f| f.trim().parse::<usize>().is_err()) {
                continue;
            }
            let fields: Vec<&str> = line.split(',').map(str::trim).collect();
            if fields.len() != 4 {
                return Err(Error::Config(format!(
                    "matrix csv line {lineno}: expected 'src,dest,latency_ns,bandwidth_gb_s', got '{line}'"
                )));
            }
            let src: usize = parse_field(fields[0], "src rank", lineno)?;
            let dst: usize = parse_field(fields[1], "dest rank", lineno)?;
            if src >= n || dst >= n {
                return Err(Error::Config(format!(
                    "matrix csv line {lineno}: rank pair ({src},{dst}) out of range for {n} ranks"
                )));
            }
            if src == dst {
                continue; // self-edges carry no information
            }
            let lat_ns: f64 = parse_field(fields[2], "latency", lineno)?;
            let bw_gb_s: f64 = parse_field(fields[3], "bandwidth", lineno)?;
            latency_us[src * n + dst] = lat_ns / 1000.0;
            bandwidth_mb_s[src * n + dst] = bw_gb_s * 1000.0;
            seen[src * n + dst] = true;
        }
        for a in 0..n {
            for b in 0..n {
                if a == b || seen[a * n + b] {
                    continue;
                }
                if seen[b * n + a] {
                    latency_us[a * n + b] = latency_us[b * n + a];
                    bandwidth_mb_s[a * n + b] = bandwidth_mb_s[b * n + a];
                } else {
                    return Err(Error::Config(format!(
                        "matrix csv: no measurement for rank pair ({a},{b}) in either direction"
                    )));
                }
            }
        }
        CostMatrix::new(name, n, latency_us, bandwidth_mb_s)
    }

    /// Load a TACOS-style CSV from disk; the matrix is named after the
    /// file.
    pub fn load_tacos_csv(path: &str) -> Result<CostMatrix> {
        let text = std::fs::read_to_string(path).map_err(|e| Error::io(path, e))?;
        CostMatrix::from_tacos_csv(path, &text)
    }

    /// Write the TACOS-style CSV form to disk.
    pub fn save_tacos_csv(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_tacos_csv()).map_err(|e| Error::io(path, e))
    }
}

fn parse_field<T: std::str::FromStr>(field: &str, what: &str, lineno: usize) -> Result<T> {
    field
        .parse()
        .map_err(|_| Error::Config(format!("matrix csv line {lineno}: bad {what} '{field}'")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_rank() -> CostMatrix {
        CostMatrix::new(
            "t",
            2,
            vec![0.0, 500.0, 500.0, 0.0],
            vec![f64::INFINITY, 10.0, 10.0, f64::INFINITY],
        )
        .unwrap()
    }

    #[test]
    fn costs_follow_the_postal_model() {
        let m = two_rank();
        // 500us + 1024B / 10 MB/s = 602.4us
        assert!((m.cost_us(0, 1, 1024) - 602.4).abs() < 1e-9);
        assert_eq!(m.cost_us(0, 0, 1024), 0.0, "diagonal is free");
        assert!((m.pair_cost_us(0, 1, 1024) - 602.4).abs() < 1e-9);
    }

    #[test]
    fn csv_round_trip_is_exact() {
        let m = two_rank();
        let csv = m.to_tacos_csv();
        let back = CostMatrix::from_tacos_csv("t", &csv).unwrap();
        assert_eq!(back.n_ranks(), 2);
        assert_eq!(back.latency_us(0, 1), m.latency_us(0, 1));
        assert_eq!(back.bandwidth_mb_s(1, 0), m.bandwidth_mb_s(1, 0));
    }

    #[test]
    fn csv_units_are_tacos_conventions() {
        // 30ms / 2 MB/s on the wire: 30_000_000 ns and 0.002 GB/s on disk.
        let m = CostMatrix::new(
            "t",
            2,
            vec![0.0, 30_000.0, 30_000.0, 0.0],
            vec![f64::INFINITY, 2.0, 2.0, f64::INFINITY],
        )
        .unwrap();
        let csv = m.to_tacos_csv();
        assert!(csv.contains("0,1,30000000,0.002"), "csv:\n{csv}");
    }

    #[test]
    fn one_directional_measurements_are_mirrored() {
        let csv = "2\nSrc,Dest,Latency (ns),Bandwidth (GB/s)\n0,1,1000,1\n";
        let m = CostMatrix::from_tacos_csv("t", csv).unwrap();
        assert_eq!(m.latency_us(1, 0), 1.0);
        assert_eq!(m.bandwidth_mb_s(1, 0), 1000.0);
    }

    #[test]
    fn missing_pair_is_an_error_naming_it() {
        let csv = "3\nSrc,Dest,Latency (ns),Bandwidth (GB/s)\n0,1,1000,1\n0,2,1000,1\n";
        let err = CostMatrix::from_tacos_csv("t", csv).unwrap_err().to_string();
        assert!(err.contains("(1,2)"), "got: {err}");
    }

    #[test]
    fn malformed_rows_are_rejected_with_line_numbers() {
        assert!(CostMatrix::from_tacos_csv("t", "").is_err());
        assert!(CostMatrix::from_tacos_csv("t", "x\n").is_err());
        let bad_fields = "2\nheader\n0,1,1000\n";
        assert!(CostMatrix::from_tacos_csv("t", bad_fields).is_err());
        let bad_rank = "2\nheader\n0,5,1000,1\n";
        assert!(CostMatrix::from_tacos_csv("t", bad_rank).is_err());
        let bad_bw = "2\nheader\n0,1,1000,0\n1,0,1000,1\n";
        assert!(CostMatrix::from_tacos_csv("t", bad_bw).is_err());
    }

    #[test]
    fn shape_validation() {
        assert!(CostMatrix::new("t", 0, vec![], vec![]).is_err());
        assert!(CostMatrix::new("t", 2, vec![0.0; 3], vec![1.0; 4]).is_err());
        assert!(CostMatrix::new("t", 2, vec![0.0, -1.0, 0.0, 0.0], vec![1.0; 4]).is_err());
    }
}
