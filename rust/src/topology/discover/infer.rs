//! Agglomerative clustering inference with an automatic level-count cut.
//!
//! The algorithm is the logical-homogeneous-clusters idea specialized to
//! the multilevel colors table:
//!
//! 1. Symmetrize the measured matrix into one scalar cost per unordered
//!    rank pair (probe cost at [`super::DEFAULT_PROBE_BYTES`] by default).
//! 2. Single-linkage agglomerative merge (Kruskal over ascending pair
//!    costs): the sequence of costs at which two clusters first join is
//!    the **merge-cost curve** — `n - 1` points, non-decreasing.
//! 3. Gap heuristic: every consecutive ratio `>= MIN_GAP_RATIO` on the
//!    curve is a level boundary; the cut threshold is the geometric mean
//!    of the flanking merge costs. The number of gaps picks the level
//!    count — nothing is configured up front.
//! 4. For each cut (ascending), the connected components over edges
//!    cheaper than the threshold are one level's clusters, numbered
//!    densely in first-appearance (rank) order — exactly the numbering
//!    [`TopologySpec::clustering`] emits, so noiseless recovery is
//!    bit-identical (same `topology_fingerprint`).
//!
//! Nestedness is structural: the edge sets under increasing thresholds
//! are themselves nested, so deeper levels always refine shallower ones
//! and the emitted colors table passes [`Clustering::new`] validation by
//! construction (which still checks — discovery depends on that invariant
//! being enforced, not assumed).

use crate::error::{Error, Result};
use crate::topology::cluster::{Clustering, Rank};
use crate::topology::discover::matrix::CostMatrix;
use crate::topology::spec::{GroupNode, TopologySpec};

/// Two consecutive merge costs whose ratio reaches this value mark a
/// level boundary. Within one channel class, ±10% measurement jitter
/// spreads costs by at most 1.1/0.9 ≈ 1.22×; across classes every
/// calibrated preset separates by ≥ 3× — 2.0 sits safely between.
pub const MIN_GAP_RATIO: f64 = 2.0;

/// The result of [`infer_clustering`]: the clustering plus the evidence
/// it was cut from.
#[derive(Clone, Debug)]
pub struct Discovery {
    /// The inferred multilevel clustering (validated).
    pub clustering: Clustering,
    /// Single-linkage merge-cost curve, ascending (`n - 1` points).
    pub merge_costs_us: Vec<f64>,
    /// Chosen cut thresholds, ascending; `len() == n_levels() - 1`.
    pub cut_costs_us: Vec<f64>,
    /// Mean merge cost per band, ascending (innermost level first).
    pub band_mean_cost_us: Vec<f64>,
}

/// Infer the multilevel clustering behind a measured cost matrix. The
/// scalar pair cost is the symmetrized probe cost at `probe_bytes`.
pub fn infer_clustering(m: &CostMatrix, probe_bytes: usize) -> Result<Discovery> {
    let n = m.n_ranks();
    // Symmetrized pair costs, ascending.
    let mut edges: Vec<(f64, u32, u32)> = Vec::with_capacity(n * (n - 1) / 2);
    for a in 0..n {
        for b in (a + 1)..n {
            let c = m.pair_cost_us(a, b, probe_bytes);
            if !c.is_finite() || c <= 0.0 {
                return Err(Error::TopologySpec(format!(
                    "cannot infer clustering: pair ({a},{b}) has non-positive cost {c}"
                )));
            }
            edges.push((c, a as u32, b as u32));
        }
    }
    edges.sort_by(|x, y| x.0.total_cmp(&y.0));

    // Pass 1 — the merge-cost curve (Kruskal: each edge that joins two
    // components is one agglomerative merge).
    let mut uf = UnionFind::new(n);
    let mut merge_costs_us = Vec::with_capacity(n.saturating_sub(1));
    for &(c, a, b) in &edges {
        if uf.union(a as usize, b as usize) {
            merge_costs_us.push(c);
            if merge_costs_us.len() == n - 1 {
                break;
            }
        }
    }

    // Gap heuristic: cut between consecutive merges whose ratio jumps.
    let mut cut_costs_us = Vec::new();
    for w in merge_costs_us.windows(2) {
        if w[1] / w[0] >= MIN_GAP_RATIO {
            cut_costs_us.push((w[0] * w[1]).sqrt());
        }
    }

    // Pass 2 — component snapshot per cut (ascending thresholds), then
    // reverse: the coarsest snapshot is level 1, the finest the deepest.
    let mut uf = UnionFind::new(n);
    let mut snapshots: Vec<Vec<u32>> = Vec::with_capacity(cut_costs_us.len());
    let mut next_edge = 0;
    for &t in &cut_costs_us {
        while next_edge < edges.len() && edges[next_edge].0 < t {
            let (_, a, b) = edges[next_edge];
            uf.union(a as usize, b as usize);
            next_edge += 1;
        }
        snapshots.push(uf.dense_labels());
    }
    let mut colors = vec![vec![0u32; n]];
    colors.extend(snapshots.into_iter().rev());
    let clustering = Clustering::new(colors)?;

    // Mean merge cost per band, for reporting.
    let mut band_mean_cost_us = Vec::with_capacity(cut_costs_us.len() + 1);
    let mut band: Vec<f64> = Vec::new();
    let mut cuts = cut_costs_us.iter().peekable();
    for &c in &merge_costs_us {
        if cuts.peek().is_some_and(|&&t| c > t) {
            cuts.next();
            band_mean_cost_us.push(mean(&band));
            band.clear();
        }
        band.push(c);
    }
    if !band.is_empty() {
        band_mean_cost_us.push(mean(&band));
    }

    Ok(Discovery { clustering, merge_costs_us, cut_costs_us, band_mean_cost_us })
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Round-trip a discovered clustering into a [`TopologySpec`] (`gridcollect
/// discover --emit-spec`): level-`l` clusters become nested groups, the
/// innermost level the machines. Requires every cluster to cover a
/// contiguous rank range (always true of spec-sampled matrices; a
/// permuted measurement cannot be expressed as a spec, whose DFS assigns
/// ranks contiguously). A 1-level (flat) clustering becomes a single
/// machine holding every rank — the spec form adds the machine level, so
/// only clusterings with `n_levels() >= 2` round-trip exactly.
pub fn spec_from_clustering(name: impl Into<String>, c: &Clustering) -> Result<TopologySpec> {
    let n = c.n_ranks();
    for l in 1..c.n_levels() {
        for cluster in c.clusters_at(l) {
            let members = c.members(l, cluster);
            let (first, last) = (members[0], *members.last().unwrap());
            if last - first + 1 != members.len() {
                // Name the first hole so a permuted measurement is
                // diagnosable from the message alone.
                let hole = (first..=last)
                    .find(|r| !members.contains(r))
                    .expect("non-contiguous span has a hole");
                return Err(Error::TopologySpec(format!(
                    "cannot emit a spec: cluster {cluster} at level {l} is not rank-contiguous \
                     — it spans ranks {first}..={last} but holds only {} of them (rank {hole} \
                     belongs to cluster {} at that level); a TopologySpec numbers ranks \
                     depth-first, so renumber the measurement or consume the clustering directly",
                    members.len(),
                    c.color(l, hole),
                )));
            }
        }
    }
    let all: Vec<Rank> = (0..n).collect();
    let children = if c.n_levels() == 1 {
        vec![GroupNode::machine("m0", n)]
    } else {
        group_nodes(c, 1, &all)
    };
    TopologySpec::new(name, GroupNode::group("discovered", children))
}

fn group_nodes(c: &Clustering, level: usize, members: &[Rank]) -> Vec<GroupNode> {
    c.partition(members, level)
        .into_iter()
        .map(|group| {
            let name = format!("l{level}c{}", c.color(level, group[0]));
            if level + 1 == c.n_levels() {
                GroupNode::machine(name, group.len())
            } else {
                GroupNode::group(name, group_nodes(c, level + 1, &group))
            }
        })
        .collect()
}

/// Disjoint-set forest with path halving; `dense_labels` renumbers roots
/// in first-appearance (rank) order, matching the colors-table numbering.
struct UnionFind {
    parent: Vec<u32>,
}

impl UnionFind {
    fn new(n: usize) -> Self {
        UnionFind { parent: (0..n as u32).collect() }
    }

    fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] as usize != x {
            let grand = self.parent[self.parent[x] as usize];
            self.parent[x] = grand;
            x = grand as usize;
        }
        x
    }

    /// Join the sets of `a` and `b`; true if they were distinct.
    fn union(&mut self, a: usize, b: usize) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        // Deterministic: smaller root wins (no rank balancing — the
        // labels pass renumbers anyway, and paths stay short via halving).
        let (lo, hi) = (ra.min(rb), ra.max(rb));
        self.parent[hi] = lo as u32;
        true
    }

    fn dense_labels(&mut self) -> Vec<u32> {
        let n = self.parent.len();
        let mut label: std::collections::HashMap<usize, u32> = Default::default();
        let mut out = Vec::with_capacity(n);
        for r in 0..n {
            let root = self.find(r);
            let next = label.len() as u32;
            out.push(*label.entry(root).or_insert(next));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::topology::discover::synth::synthesize_from_spec;
    use crate::topology::discover::DEFAULT_PROBE_BYTES;

    #[test]
    fn recovers_fig1_exactly_from_a_noiseless_matrix() {
        let spec = TopologySpec::paper_fig1();
        let m = synthesize_from_spec(&spec, &presets::paper_grid(), 0.0, 1);
        let d = infer_clustering(&m, DEFAULT_PROBE_BYTES).unwrap();
        assert_eq!(d.clustering, spec.clustering());
        assert_eq!(d.cut_costs_us.len(), 2, "3 levels -> 2 cuts");
        assert_eq!(d.band_mean_cost_us.len(), 3);
        assert_eq!(d.merge_costs_us.len(), 19);
    }

    #[test]
    fn uniform_costs_infer_a_flat_clustering() {
        let spec = TopologySpec::uniform(2, 2, 2).unwrap();
        // Uniform network: every pair identical -> no gaps -> one level.
        let m = synthesize_from_spec(&spec, &presets::uniform_lan(3), 0.0, 1);
        let d = infer_clustering(&m, DEFAULT_PROBE_BYTES).unwrap();
        assert_eq!(d.clustering, Clustering::flat(8));
        assert!(d.cut_costs_us.is_empty());
    }

    #[test]
    fn single_rank_matrix_is_flat() {
        let m = CostMatrix::new("one", 1, vec![0.0], vec![f64::INFINITY]).unwrap();
        let d = infer_clustering(&m, DEFAULT_PROBE_BYTES).unwrap();
        assert_eq!(d.clustering, Clustering::flat(1));
    }

    #[test]
    fn merge_curve_is_sorted_and_cuts_sit_in_gaps() {
        let spec = TopologySpec::paper_experiment();
        let m = synthesize_from_spec(&spec, &presets::paper_grid(), 0.05, 3);
        let d = infer_clustering(&m, DEFAULT_PROBE_BYTES).unwrap();
        for w in d.merge_costs_us.windows(2) {
            assert!(w[0] <= w[1]);
        }
        for &t in &d.cut_costs_us {
            assert!(d.merge_costs_us.iter().all(|&c| c != t), "cut strictly between merges");
        }
        assert_eq!(d.clustering, spec.clustering(), "±5% jitter still recovers");
    }

    #[test]
    fn spec_round_trip_preserves_the_clustering() {
        let spec = TopologySpec::paper_fig1();
        let c = spec.clustering();
        let back = spec_from_clustering("rt", &c).unwrap();
        assert_eq!(back.clustering(), c);
        assert_eq!(back.n_procs(), 20);
    }

    #[test]
    fn spec_round_trip_rejects_non_contiguous_clusters() {
        // Ranks 0 and 2 share a machine, 1 sits in another: valid
        // clustering, but no spec's DFS rank order can produce it.
        let c = Clustering::new(vec![vec![0, 0, 0], vec![0, 1, 0]]).unwrap();
        let err = spec_from_clustering("bad", &c).unwrap_err().to_string();
        assert!(err.contains("not rank-contiguous"), "got: {err}");
        assert!(err.contains("cluster 0 at level 1"), "names the offender: {err}");
        assert!(err.contains("ranks 0..=2"), "names the span: {err}");
        assert!(err.contains("rank 1 belongs to cluster 1"), "names the hole: {err}");
    }
}
