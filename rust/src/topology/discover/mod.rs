//! Automatic topology discovery — the missing front half of the paper's
//! pipeline (§3.1 promises clusterings "constructed automatically during
//! execution"; everything upstream of this module hand-specifies them).
//!
//! The flow is measurement-driven: a [`CostMatrix`] of per-pair latency /
//! bandwidth observations (loaded from a TACOS-style CSV edge list, or
//! synthesized from a ground-truth [`crate::topology::TopologySpec`]
//! through the [`crate::model::NetworkParams`] cost model) is fed to
//! [`infer_clustering`], which runs a single-linkage agglomerative merge
//! on link-cost similarity and cuts the merge-cost curve at its large
//! gaps (the automatic level-count choice). The result is a validated
//! multilevel [`crate::topology::Clustering`] that the rest of the stack
//! — tree builders, tuners, policy tables — consumes exactly as if it had
//! been hand-written: on a noiseless synthetic matrix the inferred
//! clustering fingerprints identically to the spec it was sampled from,
//! so a `PolicyTable` tuned on a discovered communicator installs on the
//! hand-specified one without a provenance mismatch.

mod infer;
mod matrix;
mod synth;

pub use infer::{infer_clustering, spec_from_clustering, Discovery, MIN_GAP_RATIO};
pub use matrix::{CostMatrix, DEFAULT_PROBE_BYTES};
pub use synth::{synthesize_from_clustering, synthesize_from_spec};

use crate::topology::spec::{GroupNode, NodeKind, TopologySpec};

/// Render a spec as an indented tree (the `gridcollect discover
/// --emit-spec` output): one line per group/machine, machines with their
/// process counts.
pub fn render_spec_tree(spec: &TopologySpec) -> String {
    fn rec(node: &GroupNode, depth: usize, out: &mut String) {
        let indent = "  ".repeat(depth);
        match &node.kind {
            NodeKind::Machine { procs } => {
                out.push_str(&format!("{indent}{} ({procs} procs)\n", node.name));
            }
            NodeKind::Group(children) => {
                out.push_str(&format!("{indent}{}/\n", node.name));
                for c in children {
                    rec(c, depth + 1, out);
                }
            }
        }
    }
    let mut out = String::new();
    rec(spec.root(), 0, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_lists_every_machine() {
        let spec = TopologySpec::paper_fig1();
        let r = render_spec_tree(&spec);
        for m in spec.machines() {
            assert!(r.contains(&m.name), "missing machine {} in:\n{r}", m.name);
        }
        assert!(r.contains("(10 procs)"));
    }
}
