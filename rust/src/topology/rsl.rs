//! Parser for the Globus **Resource Specification Language** dialect used
//! by the paper's Figures 5 and 6, and the bootstrap step that turns a
//! parsed script into a multilevel [`TopologySpec`].
//!
//! Grammar (the subset MPICH-G2 job scripts use):
//!
//! ```text
//! script   := '+'? subjob+
//! subjob   := '(' '&' relation* ')'
//! relation := '(' ident '=' value ')'
//! value    := atom
//!           | quoted-string
//!           | pairlist              // e.g. environment=(A 1)(B two)
//! pairlist := ( '(' ident atom ')' )+
//! ```
//!
//! Each subjob describes one machine (`resourceManagerContact`, `count`).
//! `GLOBUS_LAN_ID` in a subjob's `environment` merges machines into one
//! LAN/site group (the paper's only user-visible knob, §3.1);
//! `GLOBUS_DUROC_SUBJOB_INDEX` fixes subjob (and hence rank) order. As a
//! documented extension, `GLOBUS_SITE_ID` inserts a level *above* LANs,
//! producing a 4-level clustering (world / site / LAN / machine).

use crate::error::{Error, Result};
use crate::topology::spec::{GroupNode, TopologySpec};
use std::collections::BTreeMap;

/// One `(attr=value)` relation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RslValue {
    Atom(String),
    Pairs(Vec<(String, String)>),
}

/// A parsed subjob: ordered relations.
#[derive(Clone, Debug, Default)]
pub struct Subjob {
    pub relations: Vec<(String, RslValue)>,
}

impl Subjob {
    pub fn get(&self, key: &str) -> Option<&RslValue> {
        self.relations.iter().find(|(k, _)| k.eq_ignore_ascii_case(key)).map(|(_, v)| v)
    }

    pub fn atom(&self, key: &str) -> Option<&str> {
        match self.get(key) {
            Some(RslValue::Atom(s)) => Some(s),
            _ => None,
        }
    }

    pub fn env(&self, var: &str) -> Option<&str> {
        match self.get("environment") {
            Some(RslValue::Pairs(ps)) => {
                ps.iter().find(|(k, _)| k == var).map(|(_, v)| v.as_str())
            }
            _ => None,
        }
    }

    pub fn contact(&self) -> Option<&str> {
        self.atom("resourceManagerContact")
    }

    pub fn count(&self) -> Option<usize> {
        self.atom("count").and_then(|s| s.parse().ok())
    }
}

/// A parsed RSL multi-request.
#[derive(Clone, Debug, Default)]
pub struct RslScript {
    pub subjobs: Vec<Subjob>,
}

// ----------------------------------------------------------------------
// Lexer
// ----------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq, Eq)]
enum Tok {
    LParen,
    RParen,
    Amp,
    Plus,
    Eq,
    Atom(String),
}

struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
    line: usize,
    col: usize,
}

impl<'a> Lexer<'a> {
    fn new(src: &'a str) -> Self {
        Lexer { src: src.as_bytes(), pos: 0, line: 1, col: 1 }
    }

    fn err(&self, msg: impl Into<String>) -> Error {
        Error::RslParse { line: self.line, col: self.col, msg: msg.into() }
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.src.get(self.pos).copied()?;
        self.pos += 1;
        if b == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(b)
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn skip_ws_and_comments(&mut self) {
        loop {
            match self.peek() {
                Some(b) if b.is_ascii_whitespace() => {
                    self.bump();
                }
                Some(b'#') => {
                    while let Some(b) = self.bump() {
                        if b == b'\n' {
                            break;
                        }
                    }
                }
                _ => break,
            }
        }
    }

    /// Next token, or None at EOF.
    fn next_tok(&mut self) -> Result<Option<(Tok, usize, usize)>> {
        self.skip_ws_and_comments();
        let (line, col) = (self.line, self.col);
        let b = match self.peek() {
            None => return Ok(None),
            Some(b) => b,
        };
        let tok = match b {
            b'(' => {
                self.bump();
                Tok::LParen
            }
            b')' => {
                self.bump();
                Tok::RParen
            }
            b'&' => {
                self.bump();
                Tok::Amp
            }
            b'+' => {
                self.bump();
                Tok::Plus
            }
            b'=' => {
                self.bump();
                Tok::Eq
            }
            b'"' => {
                self.bump();
                let mut s = String::new();
                loop {
                    match self.bump() {
                        None => return Err(self.err("unterminated string")),
                        Some(b'"') => break,
                        Some(b'\\') => match self.bump() {
                            Some(c) => s.push(c as char),
                            None => return Err(self.err("unterminated escape")),
                        },
                        Some(c) => s.push(c as char),
                    }
                }
                Tok::Atom(s)
            }
            _ => {
                let mut s = String::new();
                while let Some(c) = self.peek() {
                    if c.is_ascii_whitespace() || matches!(c, b'(' | b')' | b'&' | b'=' | b'"') {
                        break;
                    }
                    s.push(c as char);
                    self.bump();
                }
                if s.is_empty() {
                    return Err(self.err(format!("unexpected byte {:?}", b as char)));
                }
                Tok::Atom(s)
            }
        };
        Ok(Some((tok, line, col)))
    }
}

// ----------------------------------------------------------------------
// Parser
// ----------------------------------------------------------------------

struct Parser {
    toks: Vec<(Tok, usize, usize)>,
    pos: usize,
}

impl Parser {
    fn err_at(&self, msg: impl Into<String>) -> Error {
        let (line, col) = self
            .toks
            .get(self.pos.min(self.toks.len().saturating_sub(1)))
            .map(|&(_, l, c)| (l, c))
            .unwrap_or((0, 0));
        Error::RslParse { line, col, msg: msg.into() }
    }

    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos).map(|(t, _, _)| t)
    }

    fn bump(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.pos).map(|(t, _, _)| t.clone());
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn expect(&mut self, want: &Tok) -> Result<()> {
        match self.bump() {
            Some(ref t) if t == want => Ok(()),
            Some(t) => Err(self.err_at(format!("expected {want:?}, found {t:?}"))),
            None => Err(self.err_at(format!("expected {want:?}, found EOF"))),
        }
    }

    fn subjob(&mut self) -> Result<Subjob> {
        self.expect(&Tok::LParen)?;
        self.expect(&Tok::Amp)?;
        let mut sj = Subjob::default();
        loop {
            match self.peek() {
                Some(Tok::RParen) => {
                    self.bump();
                    break;
                }
                Some(Tok::LParen) => {
                    let (k, v) = self.relation()?;
                    sj.relations.push((k, v));
                }
                Some(t) => {
                    let t = t.clone();
                    return Err(self.err_at(format!("expected relation or ')', found {t:?}")));
                }
                None => return Err(self.err_at("unterminated subjob")),
            }
        }
        Ok(sj)
    }

    fn relation(&mut self) -> Result<(String, RslValue)> {
        self.expect(&Tok::LParen)?;
        let key = match self.bump() {
            Some(Tok::Atom(s)) => s,
            other => return Err(self.err_at(format!("expected attribute name, found {other:?}"))),
        };
        self.expect(&Tok::Eq)?;
        // value: pairs, or atom(s)
        let val = match self.peek() {
            Some(Tok::LParen) => {
                let mut pairs = Vec::new();
                while matches!(self.peek(), Some(Tok::LParen)) {
                    self.bump();
                    let k = match self.bump() {
                        Some(Tok::Atom(s)) => s,
                        other => {
                            return Err(self.err_at(format!("expected env var name, found {other:?}")))
                        }
                    };
                    let v = match self.bump() {
                        Some(Tok::Atom(s)) => s,
                        // Empty value: `(VAR )`
                        Some(Tok::RParen) => {
                            pairs.push((k, String::new()));
                            continue;
                        }
                        other => {
                            return Err(self.err_at(format!("expected env value, found {other:?}")))
                        }
                    };
                    self.expect(&Tok::RParen)?;
                    pairs.push((k, v));
                }
                RslValue::Pairs(pairs)
            }
            Some(Tok::Atom(_)) => {
                let mut parts: Vec<String> = Vec::new();
                while let Some(Tok::Atom(_)) = self.peek() {
                    if let Some(Tok::Atom(s)) = self.bump() {
                        parts.push(s);
                    }
                }
                RslValue::Atom(parts.join(" "))
            }
            other => return Err(self.err_at(format!("expected value, found {other:?}"))),
        };
        self.expect(&Tok::RParen)?;
        Ok((key, val))
    }
}

/// Parse an RSL multi-request script.
pub fn parse(src: &str) -> Result<RslScript> {
    let mut lx = Lexer::new(src);
    let mut toks = Vec::new();
    while let Some(t) = lx.next_tok()? {
        toks.push(t);
    }
    let mut p = Parser { toks, pos: 0 };
    // optional leading '+' (multi-request operator)
    if matches!(p.peek(), Some(Tok::Plus)) {
        p.bump();
    }
    let mut script = RslScript::default();
    while p.peek().is_some() {
        script.subjobs.push(p.subjob()?);
    }
    if script.subjobs.is_empty() {
        return Err(Error::RslParse { line: 1, col: 1, msg: "no subjobs in script".into() });
    }
    Ok(script)
}

/// MPICH-G2 bootstrap: derive the multilevel [`TopologySpec`] from a parsed
/// script (§3.1). Subjobs are ordered by `GLOBUS_DUROC_SUBJOB_INDEX` when
/// present (script order otherwise); `GLOBUS_LAN_ID` merges machines into
/// LAN groups; machines without a LAN id form singleton groups. The
/// extension variable `GLOBUS_SITE_ID` (if present on any subjob) adds a
/// site level above the LAN level.
pub fn to_topology(script: &RslScript) -> Result<TopologySpec> {
    let mut ordered: Vec<(usize, &Subjob)> = script.subjobs.iter().enumerate().collect();
    // Sort by DUROC index when every subjob carries one.
    if script.subjobs.iter().all(|s| s.env("GLOBUS_DUROC_SUBJOB_INDEX").is_some()) {
        let mut keyed: Vec<(usize, &Subjob)> = Vec::with_capacity(ordered.len());
        for (i, sj) in ordered {
            let idx: usize = sj
                .env("GLOBUS_DUROC_SUBJOB_INDEX")
                .unwrap()
                .parse()
                .map_err(|_| Error::TopologySpec(format!("subjob {i}: bad DUROC index")))?;
            keyed.push((idx, sj));
        }
        keyed.sort_by_key(|&(idx, _)| idx);
        // Duplicate indices are a user error.
        for w in keyed.windows(2) {
            if w[0].0 == w[1].0 {
                return Err(Error::TopologySpec(format!(
                    "duplicate GLOBUS_DUROC_SUBJOB_INDEX {}",
                    w[0].0
                )));
            }
        }
        ordered = keyed;
    }

    struct M {
        name: String,
        procs: usize,
        lan: String,
        site: Option<String>,
    }
    let mut machines = Vec::new();
    for (i, sj) in &ordered {
        let contact = sj
            .contact()
            .ok_or_else(|| Error::TopologySpec(format!("subjob {i}: missing resourceManagerContact")))?;
        let count = sj
            .count()
            .ok_or_else(|| Error::TopologySpec(format!("subjob {i} ({contact}): missing/invalid count")))?;
        let lan = sj
            .env("GLOBUS_LAN_ID")
            .map(|s| s.to_string())
            .unwrap_or_else(|| format!("__solo_{contact}"));
        let site = sj.env("GLOBUS_SITE_ID").map(|s| s.to_string());
        machines.push(M { name: contact.to_string(), procs: count, lan, site });
    }

    let any_site = machines.iter().any(|m| m.site.is_some());
    // Group machines by LAN (first-appearance order).
    let mut lan_order: Vec<String> = Vec::new();
    let mut lans: BTreeMap<String, Vec<GroupNode>> = BTreeMap::new();
    let mut lan_site: BTreeMap<String, String> = BTreeMap::new();
    for m in &machines {
        if !lan_order.contains(&m.lan) {
            lan_order.push(m.lan.clone());
        }
        lans.entry(m.lan.clone()).or_default().push(GroupNode::machine(&m.name, m.procs));
        let site = m.site.clone().unwrap_or_else(|| format!("__site_{}", m.lan));
        match lan_site.get(&m.lan) {
            Some(prev) if *prev != site => {
                return Err(Error::TopologySpec(format!(
                    "LAN '{}' spans sites '{prev}' and '{site}'",
                    m.lan
                )));
            }
            None => {
                lan_site.insert(m.lan.clone(), site);
            }
            _ => {}
        }
    }

    let root = if any_site {
        // 4 levels: world / site / lan / machine
        let mut site_order: Vec<String> = Vec::new();
        let mut sites: BTreeMap<String, Vec<GroupNode>> = BTreeMap::new();
        for lan in &lan_order {
            let site = lan_site[lan].clone();
            if !site_order.contains(&site) {
                site_order.push(site.clone());
            }
            sites.entry(site).or_default().push(GroupNode::group(lan, lans[lan].clone()));
        }
        GroupNode::group(
            "grid",
            site_order
                .into_iter()
                .map(|s| {
                    let nodes = sites.remove(&s).unwrap();
                    GroupNode::group(s, nodes)
                })
                .collect(),
        )
    } else {
        // 3 levels: world / lan-as-site / machine (the paper's model:
        // site groups == GLOBUS_LAN_ID groups).
        GroupNode::group(
            "grid",
            lan_order
                .into_iter()
                .map(|lan| {
                    let nodes = lans.remove(&lan).unwrap();
                    GroupNode::group(lan, nodes)
                })
                .collect(),
        )
    };
    TopologySpec::new("rsl", root)
}

/// Convenience: parse + bootstrap in one step.
pub fn topology_from_script(src: &str) -> Result<TopologySpec> {
    to_topology(&parse(src)?)
}

/// The paper's Figure 6 script (multilevel clustering via GLOBUS_LAN_ID),
/// reproduced verbatim-modulo-whitespace; used by tests and examples.
pub const FIG6_SCRIPT: &str = r#"
( &(resourceManagerContact="sp.npaci.edu")
   (count=10)
   (jobtype=mpi)
   (label="subjob 0")
   (environment=(GLOBUS_DUROC_SUBJOB_INDEX 0))
   (directory=/homes/users/smith)
   (executable=/homes/users/smith/myapp)
)
( &(resourceManagerContact="o2ka.ncsa.uiuc.edu")
   (count=5)
   (jobtype=mpi)
   (label="subjob 1")
   (environment=(GLOBUS_DUROC_SUBJOB_INDEX 1)
                (GLOBUS_LAN_ID NCSAlan))
   (directory=/users/smith)
   (executable=/users/smith/myapp)
)
( &(resourceManagerContact="o2kb.ncsa.uiuc.edu")
   (count=5)
   (jobtype=mpi)
   (label="subjob 2")
   (environment=(GLOBUS_DUROC_SUBJOB_INDEX 2)
                (GLOBUS_LAN_ID NCSAlan))
   (directory=/users/smith)
   (executable=/users/smith/myapp)
)
"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_fig6_script() {
        let s = parse(FIG6_SCRIPT).unwrap();
        assert_eq!(s.subjobs.len(), 3);
        assert_eq!(s.subjobs[0].contact(), Some("sp.npaci.edu"));
        assert_eq!(s.subjobs[0].count(), Some(10));
        assert_eq!(s.subjobs[0].atom("label"), Some("subjob 0"));
        assert_eq!(s.subjobs[1].env("GLOBUS_LAN_ID"), Some("NCSAlan"));
        assert_eq!(s.subjobs[0].env("GLOBUS_LAN_ID"), None);
    }

    #[test]
    fn fig6_topology_matches_fig1() {
        let t = topology_from_script(FIG6_SCRIPT).unwrap();
        assert_eq!(t.n_procs(), 20);
        assert_eq!(t.n_levels(), 3);
        let c = t.clustering();
        // Same separation structure as the hand-built Fig. 1 clustering.
        assert_eq!(c.sep(0, 9), 3);
        assert_eq!(c.sep(10, 15), 2);
        assert_eq!(c.sep(0, 10), 1);
        assert_eq!(c.clusters_at(1).len(), 2); // SDSC-ish solo + NCSAlan
        assert_eq!(c.clusters_at(2).len(), 3);
    }

    #[test]
    fn fig5_no_lan_id_gives_singleton_sites() {
        // Figure 5: identical script minus the GLOBUS_LAN_ID lines: every
        // machine is its own "site" -> only machine-boundary clustering.
        let fig5 = FIG6_SCRIPT.replace("(GLOBUS_LAN_ID NCSAlan)", "");
        let t = topology_from_script(&fig5).unwrap();
        let c = t.clustering();
        assert_eq!(c.clusters_at(1).len(), 3); // three singleton groups
        assert_eq!(c.sep(10, 15), 1); // O2Ka vs O2Kb now looks like WAN
    }

    #[test]
    fn duroc_index_reorders() {
        let src = r#"
            ( &(resourceManagerContact="b") (count=2)
              (environment=(GLOBUS_DUROC_SUBJOB_INDEX 1)) )
            ( &(resourceManagerContact="a") (count=3)
              (environment=(GLOBUS_DUROC_SUBJOB_INDEX 0)) )
        "#;
        let t = topology_from_script(src).unwrap();
        let ms = t.machines();
        assert_eq!(ms[0].name, "a");
        assert_eq!(ms[0].first_rank, 0);
        assert_eq!(ms[1].name, "b");
        assert_eq!(ms[1].first_rank, 3);
    }

    #[test]
    fn duplicate_duroc_index_rejected() {
        let src = r#"
            ( &(resourceManagerContact="a") (count=1)
              (environment=(GLOBUS_DUROC_SUBJOB_INDEX 0)) )
            ( &(resourceManagerContact="b") (count=1)
              (environment=(GLOBUS_DUROC_SUBJOB_INDEX 0)) )
        "#;
        assert!(topology_from_script(src).is_err());
    }

    #[test]
    fn site_id_extension_adds_level() {
        let src = r#"
            ( &(resourceManagerContact="a") (count=2)
              (environment=(GLOBUS_LAN_ID lan1)(GLOBUS_SITE_ID east)) )
            ( &(resourceManagerContact="b") (count=2)
              (environment=(GLOBUS_LAN_ID lan2)(GLOBUS_SITE_ID east)) )
            ( &(resourceManagerContact="c") (count=2)
              (environment=(GLOBUS_LAN_ID lan3)(GLOBUS_SITE_ID west)) )
        "#;
        let t = topology_from_script(src).unwrap();
        assert_eq!(t.n_levels(), 4);
        let c = t.clustering();
        assert_eq!(c.sep(0, 2), 2); // a vs b: same site, different LAN
        assert_eq!(c.sep(0, 4), 1); // a vs c: WAN
    }

    #[test]
    fn lan_spanning_sites_rejected() {
        let src = r#"
            ( &(resourceManagerContact="a") (count=1)
              (environment=(GLOBUS_LAN_ID l)(GLOBUS_SITE_ID east)) )
            ( &(resourceManagerContact="b") (count=1)
              (environment=(GLOBUS_LAN_ID l)(GLOBUS_SITE_ID west)) )
        "#;
        assert!(topology_from_script(src).is_err());
    }

    #[test]
    fn parse_errors_have_positions() {
        match parse("( &(count=") {
            Err(Error::RslParse { line, .. }) => assert_eq!(line, 1),
            other => panic!("expected parse error, got {other:?}"),
        }
        assert!(parse("").is_err());
        assert!(parse("( &(count 5) )").is_err()); // missing '='
    }

    #[test]
    fn comments_and_plus_prefix() {
        let src = "+ # leading multirequest op\n( &(resourceManagerContact=\"x\") (count=4) )";
        let t = topology_from_script(src).unwrap();
        assert_eq!(t.n_procs(), 4);
    }

    #[test]
    fn missing_required_fields_rejected() {
        assert!(topology_from_script("( &(count=4) )").is_err());
        assert!(topology_from_script("( &(resourceManagerContact=\"x\") )").is_err());
        assert!(topology_from_script("( &(resourceManagerContact=\"x\") (count=zero) )").is_err());
    }
}
