//! # gridcollect
//!
//! A production-grade reproduction of *"A Multilevel Approach to
//! Topology-Aware Collective Operations in Computational Grids"*
//! (Karonis, de Supinski, Foster, Gropp, Lusk, Lacour — 2002): multilevel
//! topology-aware MPI collective operations, an RSL topology front-end, a
//! discrete-event grid network simulator, and an AOT-compiled JAX/Pallas
//! compute path driven from Rust via PJRT.
//!
//! Architecture (three layers, Python never on the request path):
//! - **L3** (this crate): clustering, tree builders, the collectives
//!   (compiled through the topology → plan → execute pipeline, see
//!   [`plan`]; front door: [`session::GridSession`]), the simulator,
//!   experiment drivers and CLI.
//! - **L2** (`python/compile/model.py`): JAX compute graphs, AOT-lowered to
//!   HLO text in `artifacts/`.
//! - **L1** (`python/compile/kernels/`): Pallas reduction-combine kernels
//!   called by L2.
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

pub mod analytic;
pub mod benchkit;
pub mod cli;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod model;
pub mod plan;
pub mod runtime;
pub mod service;
pub mod session;
pub mod tree;
pub mod netsim;
pub mod topology;
pub mod util;

pub use error::{Error, Result};
