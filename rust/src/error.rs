//! Unified error type for the gridcollect library.
//!
//! Hand-rolled (no `thiserror` in the offline vendor set); implements
//! `std::error::Error` + `Display` and converts from the error types of the
//! substrates (RSL parsing, config parsing, simulator, runtime).

use std::fmt;

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All error conditions surfaced by the public API.
#[derive(Debug)]
pub enum Error {
    /// RSL script could not be parsed (position, message).
    RslParse { line: usize, col: usize, msg: String },
    /// Topology specification is structurally invalid.
    TopologySpec(String),
    /// Config file / key-value parse error.
    Config(String),
    /// CLI argument error.
    Cli(String),
    /// Communicator misuse (rank out of range, bad split, ...).
    Comm(String),
    /// Tree construction or validation failure.
    Tree(String),
    /// Collective schedule construction/validation failure.
    Schedule(String),
    /// The simulator detected a deadlock: no runnable rank before completion.
    Deadlock { stuck_ranks: Vec<usize>, detail: String },
    /// Simulator invariant violation.
    Sim(String),
    /// PJRT runtime error (artifact load, compile, execute).
    Runtime(String),
    /// Artifact missing or manifest inconsistent.
    Artifact(String),
    /// I/O error with path context.
    Io { path: String, source: std::io::Error },
    /// Numeric verification failed (expected vs got summary).
    Verify(String),
    /// `gridd` service failure: a protocol violation, an `ok: false`
    /// response relayed to a client, or a transport fault.
    Service(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::RslParse { line, col, msg } => {
                write!(f, "RSL parse error at {line}:{col}: {msg}")
            }
            Error::TopologySpec(m) => write!(f, "invalid topology spec: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Cli(m) => write!(f, "CLI error: {m}"),
            Error::Comm(m) => write!(f, "communicator error: {m}"),
            Error::Tree(m) => write!(f, "tree error: {m}"),
            Error::Schedule(m) => write!(f, "schedule error: {m}"),
            Error::Deadlock { stuck_ranks, detail } => {
                write!(f, "simulation deadlock (stuck ranks {stuck_ranks:?}): {detail}")
            }
            Error::Sim(m) => write!(f, "simulator error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Io { path, source } => write!(f, "I/O error on {path}: {source}"),
            Error::Verify(m) => write!(f, "verification failure: {m}"),
            Error::Service(m) => write!(f, "gridd service error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl Error {
    /// Attach a path to an `std::io::Error`.
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::RslParse { line: 3, col: 7, msg: "unexpected ')'".into() };
        assert_eq!(e.to_string(), "RSL parse error at 3:7: unexpected ')'");
        let e = Error::Deadlock { stuck_ranks: vec![1, 2], detail: "recv never matched".into() };
        assert!(e.to_string().contains("stuck ranks [1, 2]"));
    }

    #[test]
    fn io_source_is_chained() {
        use std::error::Error as _;
        let e = Error::io("/tmp/x", std::io::Error::new(std::io::ErrorKind::NotFound, "nope"));
        assert!(e.source().is_some());
        assert!(e.to_string().contains("/tmp/x"));
    }
}
