//! The paper's §4 closed-form broadcast cost model, used to cross-check
//! the simulator and to regenerate the asymptotic comparison (experiment
//! E2 in DESIGN.md).
//!
//! For `P` processes spread evenly over `C` clusters, message of `N`
//! bytes, inter-cluster link `(l_s, b_s)` and intra-cluster link
//! `(l_f, b_f)`:
//!
//! ```text
//! binomial   : log2(C)·(l_s + N/b_s) + log2(P/C)·(l_f + N/b_f)
//! multilevel :          (l_s + N/b_s) + log2(P/C)·(l_f + N/b_f)
//! ```
//!
//! The model charges the longest dependency path, assuming inter-cluster
//! cost dominates — exactly the paper's conservative accounting.

use crate::model::LinkParams;

/// Two-tier analytic network: slow inter-cluster, fast intra-cluster.
#[derive(Clone, Copy, Debug)]
pub struct TwoTier {
    pub slow: LinkParams,
    pub fast: LinkParams,
}

impl TwoTier {
    /// Longest-path cost of the binomial-tree broadcast (§4): at least
    /// `log2 C` inter-cluster hops plus `log2 (P/C)` intra-cluster hops.
    pub fn binomial_bcast_us(&self, p: usize, c: usize, bytes: usize) -> f64 {
        assert!(p >= c && c >= 1, "need P >= C >= 1");
        let log_c = (c as f64).log2();
        let log_pc = ((p / c) as f64).log2();
        log_c * self.slow.p2p_us(bytes) + log_pc * self.fast.p2p_us(bytes)
    }

    /// Longest-path cost of the multilevel broadcast (§4): one
    /// inter-cluster hop plus `log2 (P/C)` intra-cluster hops.
    pub fn multilevel_bcast_us(&self, p: usize, c: usize, bytes: usize) -> f64 {
        assert!(p >= c && c >= 1, "need P >= C >= 1");
        let log_pc = ((p / c) as f64).log2();
        let slow = if c > 1 { self.slow.p2p_us(bytes) } else { 0.0 };
        slow + log_pc * self.fast.p2p_us(bytes)
    }

    /// Predicted speedup of multilevel over binomial.
    pub fn speedup(&self, p: usize, c: usize, bytes: usize) -> f64 {
        self.binomial_bcast_us(p, c, bytes) / self.multilevel_bcast_us(p, c, bytes)
    }

    /// The asymptotic claim of §1: when inter-cluster cost dominates, the
    /// saving approaches `log2 C`.
    pub fn asymptotic_speedup(&self, c: usize) -> f64 {
        (c as f64).log2()
    }
}

/// Message-count predictions (exact, not asymptotic) for a P-rank world
/// split evenly into C clusters, broadcast from rank 0.
pub mod counts {
    /// Inter-cluster messages used by the binomial tree. With blocks of
    /// `P/C` consecutive ranks per cluster and the MPICH relative-rank
    /// tree, an edge (parent rel `r`, child rel `r + 2^j`) crosses a
    /// cluster boundary iff the two rels fall in different blocks.
    pub fn binomial_intercluster(p: usize, c: usize) -> usize {
        assert!(c >= 1 && p % c == 0);
        let block = p / c;
        let mut count = 0;
        for r in 1..p {
            let parent = r & (r - 1);
            if parent / block != r / block {
                count += 1;
            }
        }
        count
    }

    /// The multilevel tree uses exactly `C - 1` inter-cluster messages.
    pub fn multilevel_intercluster(c: usize) -> usize {
        c - 1
    }

    /// A *flat* inter-cluster stage also uses `C - 1`, but all from the
    /// root; a binomial inter-cluster stage uses `C - 1` spread over
    /// `log2 C` rounds.
    pub fn flat_intercluster(c: usize) -> usize {
        c - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiers() -> TwoTier {
        TwoTier {
            slow: LinkParams::new(30_000.0, 2.0),
            fast: LinkParams::new(30.0, 150.0),
        }
    }

    #[test]
    fn multilevel_beats_binomial_when_slow_dominates() {
        let t = tiers();
        // P=64 over C=8 clusters, 1 KiB.
        let b = t.binomial_bcast_us(64, 8, 1024);
        let m = t.multilevel_bcast_us(64, 8, 1024);
        assert!(m < b);
        // Saving approaches log2(8)=3 because slow >> fast here.
        let s = t.speedup(64, 8, 1024);
        assert!(s > 2.5 && s <= 3.0 + 1e-9, "speedup {s}");
    }

    #[test]
    fn single_cluster_no_slow_term() {
        let t = tiers();
        assert_eq!(t.multilevel_bcast_us(16, 1, 1024), t.binomial_bcast_us(16, 1, 1024));
    }

    #[test]
    fn speedup_grows_with_cluster_count() {
        let t = tiers();
        let s2 = t.speedup(64, 2, 1024);
        let s4 = t.speedup(64, 4, 1024);
        let s8 = t.speedup(64, 8, 1024);
        assert!(s2 < s4 && s4 < s8);
    }

    #[test]
    fn binomial_intercluster_counts() {
        // P=8, C=2: blocks {0..4},{4..8}. Edges crossing: (0,4) at least,
        // and per §4 >= log2(C)=1. Exact: rels 4,5,6,7 have parents
        // 0,4,4,6 -> only (0,4) crosses. == 1? parent(5)=4 same block,
        // parent(6)=4 same, parent(7)=6 same. So 1 crossing.
        assert_eq!(counts::binomial_intercluster(8, 2), 1);
        // P=8, C=4: blocks of 2. rels: 1->0 same, 2->0 cross, 3->2 same,
        // 4->0 cross, 5->4 same, 6->4 cross, 7->6 same => 3 crossings.
        assert_eq!(counts::binomial_intercluster(8, 4), 3);
        assert_eq!(counts::multilevel_intercluster(4), 3);
    }

    #[test]
    fn binomial_crossings_at_least_log_c() {
        for (p, c) in [(16, 2), (16, 4), (64, 8), (256, 16)] {
            let cnt = counts::binomial_intercluster(p, c);
            let log_c = (c as f64).log2() as usize;
            assert!(cnt >= log_c, "P={p} C={c}: {cnt} < log2(C)={log_c}");
        }
    }
}
