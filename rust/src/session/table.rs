//! Persisted tuning tables: the versioned on-disk form of the boundary
//! autotuner's verdicts.
//!
//! "Fast Tuning of Intra-Cluster Collective Communications" (cs/0408034)
//! makes the case this module implements: tuned decision tables only pay
//! off when they **persist across runs** and are consulted transparently
//! at call time. A [`PolicyTable`] maps `(reduce op, payload bytes)` to
//! the makespan-minimizing [`AlgoPolicy`] for one (topology, network,
//! strategy) context, and carries a [`PolicyProvenance`] header so a
//! table tuned under one context can never be silently applied to
//! another: loading is cheap, but *installing* a table into a
//! [`crate::session::GridSession`] re-derives the session's provenance
//! and hard-errors on any mismatch.
//!
//! The file format is JSON (hand-rolled writer + [`crate::util::json`]
//! parser — no `serde` in the offline vendor set), versioned via
//! [`POLICY_TABLE_VERSION`]. 64-bit hashes are serialized as hex strings
//! (JSON numbers are doubles and would corrupt them).

use crate::error::{Error, Result};
use crate::model::NetworkParams;
use crate::netsim::ReduceOp;
use crate::plan::{AlgoPolicy, AllreduceAlgo, ChunkOrder, LevelAlgo, MAX_CHUNKS};
use crate::topology::Communicator;
use crate::tree::{LevelPolicy, Strategy, TreeShape};
use crate::util::json::{self, Value};

/// Current on-disk format version. Version 2 added per-level policy
/// compositions (`comp:` tokens), the vocabulary provenance field and
/// the optional `wan_shapes` section. Readers accept any version in
/// `1..=POLICY_TABLE_VERSION` (older files simply lack the newer
/// optional sections); versions from the future are hard errors
/// (tables are cheap to regenerate with `gridcollect tune-composition
/// --save <table.json>`).
pub const POLICY_TABLE_VERSION: u64 = 2;

const FORMAT_TAG: &str = "gridcollect-policy-table";

/// The policy vocabulary this build can express, rendered as a stable
/// string and stored in the provenance header: a table tuned under a
/// smaller (or different) vocabulary must not silently resolve in a
/// session whose tuner would have searched a different space.
pub fn vocabulary_string() -> String {
    let algos: Vec<&str> = LevelAlgo::ALL.iter().map(|a| a.name()).collect();
    let orders: Vec<&str> = ChunkOrder::ALL.iter().map(|o| o.name()).collect();
    format!("algos={};orders={};max_chunks={}", algos.join(","), orders.join(","), MAX_CHUNKS)
}

/// 64-bit FNV-1a. Used for the provenance hashes because it is stable
/// across Rust releases and platforms (`DefaultHasher` is neither).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Deterministic, platform-stable hash of a [`NetworkParams`] set: every
/// per-level link parameter (bit-exact) plus the combine cost.
pub fn params_hash(params: &NetworkParams) -> u64 {
    let mut bytes = Vec::with_capacity(8 + params.per_sep.len() * 33);
    bytes.extend_from_slice(&(params.per_sep.len() as u64).to_le_bytes());
    for l in &params.per_sep {
        bytes.extend_from_slice(&l.latency_us.to_bits().to_le_bytes());
        bytes.extend_from_slice(&l.bandwidth_mb_s.to_bits().to_le_bytes());
        bytes.extend_from_slice(&l.send_overhead_us.to_bits().to_le_bytes());
        bytes.extend_from_slice(&l.recv_overhead_us.to_bits().to_le_bytes());
        bytes.push(l.sender_serializes as u8);
    }
    bytes.extend_from_slice(&params.combine_us_per_byte.to_bits().to_le_bytes());
    fnv1a64(&bytes)
}

/// Structural fingerprint of a communicator's multilevel clustering:
/// rank count, level count and the full color matrix. Deliberately
/// **not** [`Communicator::epoch`] — epochs are process-local identities,
/// while two worlds bootstrapped from the same topology spec in
/// different processes must fingerprint identically (that is what makes
/// a saved table loadable tomorrow).
pub fn topology_fingerprint(comm: &Communicator) -> u64 {
    let c = comm.clustering();
    let (n, d) = (c.n_ranks(), c.n_levels());
    let mut bytes = Vec::with_capacity(16 + n * d * 4);
    bytes.extend_from_slice(&(n as u64).to_le_bytes());
    bytes.extend_from_slice(&(d as u64).to_le_bytes());
    for l in 0..d {
        for r in 0..n {
            bytes.extend_from_slice(&c.color(l, r).to_le_bytes());
        }
    }
    fnv1a64(&bytes)
}

/// Everything a tuned table's verdicts depend on. Saved alongside the
/// entries; checked (field by field, hard error on mismatch) before a
/// table is installed into a session.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PolicyProvenance {
    /// On-disk format version ([`POLICY_TABLE_VERSION`]).
    pub version: u64,
    /// [`params_hash`] of the cost model the probes ran under.
    pub params_hash: u64,
    /// [`topology_fingerprint`] of the tuned communicator.
    pub topology_fingerprint: u64,
    pub n_ranks: usize,
    pub n_levels: usize,
    /// [`Strategy::name`] of the tuned tree discipline.
    pub strategy: String,
    /// Debug rendering of the [`LevelPolicy`] (per-level tree shapes).
    pub level_policy: String,
    /// How the probes were executed (`"ghost"` for the timing engine).
    pub probe_mode: String,
    /// [`vocabulary_string`] of the policy vocabulary the tuner searched
    /// over. Version-1 files predate the field and read back as the
    /// current vocabulary (their `rb`/`rsag`/`hybrid:N` tokens mean the
    /// same compositions under it).
    pub vocabulary: String,
}

impl PolicyProvenance {
    /// The provenance of tuning performed right now under the given
    /// context (the session computes this for both saving and checking).
    pub fn of(
        comm: &Communicator,
        params: &NetworkParams,
        strategy: Strategy,
        level_policy: &LevelPolicy,
    ) -> Self {
        PolicyProvenance {
            version: POLICY_TABLE_VERSION,
            params_hash: params_hash(params),
            topology_fingerprint: topology_fingerprint(comm),
            n_ranks: comm.size(),
            n_levels: comm.clustering().n_levels(),
            strategy: strategy.name().to_string(),
            level_policy: format!("{level_policy:?}"),
            probe_mode: "ghost".to_string(),
            vocabulary: vocabulary_string(),
        }
    }

    /// Hard compatibility check: every field of `self` (a loaded table's
    /// header) must match `current` (the installing session's context).
    /// A mismatch means the table's verdicts were tuned under different
    /// conditions and silently accepting them would run the wrong
    /// policies — so it is an error, never a warning.
    pub fn check_matches(&self, current: &PolicyProvenance) -> Result<()> {
        let mismatch = |what: &str, got: &str, want: &str| {
            Err(Error::Config(format!(
                "policy table provenance mismatch: {what} was '{got}' when tuned \
                 but this session has '{want}' — retune with `gridcollect \
                 tune-composition --save <table.json>` under the current configuration"
            )))
        };
        // Older supported versions are compatible by construction (their
        // token vocabulary is a subset); only a table from the *future*
        // is a mismatch here (from_json already rejects those on read).
        if self.version > current.version {
            let (got, want) = (self.version.to_string(), current.version.to_string());
            return mismatch("format version", &got, &want);
        }
        if self.params_hash != current.params_hash {
            return mismatch(
                "NetworkParams hash",
                &format!("{:#018x}", self.params_hash),
                &format!("{:#018x}", current.params_hash),
            );
        }
        if self.topology_fingerprint != current.topology_fingerprint
            || self.n_ranks != current.n_ranks
            || self.n_levels != current.n_levels
        {
            return mismatch(
                "topology",
                &format!(
                    "{} ranks / {} levels / {:#018x}",
                    self.n_ranks, self.n_levels, self.topology_fingerprint
                ),
                &format!(
                    "{} ranks / {} levels / {:#018x}",
                    current.n_ranks, current.n_levels, current.topology_fingerprint
                ),
            );
        }
        if self.strategy != current.strategy {
            return mismatch("strategy", &self.strategy, &current.strategy);
        }
        if self.level_policy != current.level_policy {
            return mismatch("level policy", &self.level_policy, &current.level_policy);
        }
        if self.probe_mode != current.probe_mode {
            return mismatch("probe mode", &self.probe_mode, &current.probe_mode);
        }
        if self.vocabulary != current.vocabulary {
            return mismatch("policy vocabulary", &self.vocabulary, &current.vocabulary);
        }
        Ok(())
    }
}

/// One tuned verdict: the winning policy for `(op, bytes)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PolicyEntry {
    pub op: ReduceOp,
    pub bytes: usize,
    pub policy: AlgoPolicy,
    /// Simulated makespan of the winner (us) — informational.
    pub best_us: f64,
}

/// One tuned pipelined-broadcast verdict: the winning
/// `tune_bcast_segments` chunk count for a payload of `bytes`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SegmentEntry {
    pub bytes: usize,
    pub segments: usize,
    /// Simulated makespan of the winner (us) — informational.
    pub best_us: f64,
}

/// One tuned WAN tree-shape verdict: the winning root-level
/// [`TreeShape`] for a payload of `bytes` (resolved through the
/// session's policy provider like broadcast segment counts).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShapeEntry {
    pub bytes: usize,
    pub shape: TreeShape,
    /// Simulated makespan of the winner (us) — informational.
    pub best_us: f64,
}

/// A persisted tuning table: provenance header + sorted verdict entries,
/// one kind per tuned op family (allreduce composition policies,
/// pipelined-broadcast segment counts, per-size WAN tree shapes).
#[derive(Clone, Debug)]
pub struct PolicyTable {
    provenance: PolicyProvenance,
    /// Sorted by `(op, bytes)`; at most one entry per key.
    entries: Vec<PolicyEntry>,
    /// Sorted by `bytes`; at most one entry per size.
    bcast_segments: Vec<SegmentEntry>,
    /// Sorted by `bytes`; at most one entry per size.
    wan_shapes: Vec<ShapeEntry>,
}

fn op_rank(op: ReduceOp) -> u8 {
    match op {
        ReduceOp::Sum => 0,
        ReduceOp::Max => 1,
        ReduceOp::Min => 2,
        ReduceOp::Prod => 3,
    }
}

fn op_from_name(name: &str) -> Result<ReduceOp> {
    match name {
        "sum" => Ok(ReduceOp::Sum),
        "max" => Ok(ReduceOp::Max),
        "min" => Ok(ReduceOp::Min),
        "prod" => Ok(ReduceOp::Prod),
        other => Err(Error::Config(format!("policy table: unknown reduce op '{other}'"))),
    }
}

/// Compact, grep-able policy token. The three legacy shapes keep their
/// version-1 spellings (`rb`, `rsag`, `hybrid:N`) so old files and
/// grep habits survive the composition refactor; everything else gets
/// the general form `comp:a,b,c[;chunks=k1,k2,...][;order=scf|ll]` with
/// the level names of [`LevelAlgo::name`] (trailing repeats collapsed,
/// for the chunk counts too — a uniform profile keeps the version-2
/// single-count `chunks=K` spelling). Public because the `gridd` wire
/// protocol speaks the same tokens as the table files.
pub fn policy_to_token(p: AlgoPolicy) -> String {
    if p == AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast) {
        return "rb".to_string();
    }
    if p == AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather) {
        return "rsag".to_string();
    }
    if let Some(b) = p.hybrid_boundary() {
        return format!("hybrid:{b}");
    }
    let names: Vec<&str> = p.level_algos().iter().map(|a| a.name()).collect();
    let mut token = format!("comp:{}", names.join(","));
    if p.chunks_per_level() > 1 {
        let prof: Vec<String> = p.chunk_profile().iter().map(|c| c.to_string()).collect();
        token.push_str(&format!(";chunks={}", prof.join(",")));
        if p.chunk_order() != ChunkOrder::Fifo {
            token.push_str(&format!(";order={}", p.chunk_order().name()));
        }
    }
    token
}

/// Inverse of [`policy_to_token`] (strict; used by both the file reader
/// and the `gridd` wire protocol).
pub fn policy_from_token(token: &str) -> Result<AlgoPolicy> {
    let bad = || Error::Config(format!("policy table: bad policy token '{token}'"));
    match token {
        "rb" => return Ok(AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast)),
        "rsag" => return Ok(AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather)),
        _ => {}
    }
    if let Some(b) = token.strip_prefix("hybrid:") {
        return b.parse::<usize>().map(AlgoPolicy::hybrid).map_err(|_| bad());
    }
    let body = token.strip_prefix("comp:").ok_or_else(bad)?;
    let mut sections = body.split(';');
    let mut algos = Vec::new();
    for name in sections.next().ok_or_else(bad)?.split(',') {
        algos.push(LevelAlgo::from_name(name).ok_or_else(bad)?);
    }
    let (mut chunks, mut order) = (vec![1usize], ChunkOrder::Fifo);
    for section in sections {
        if let Some(k) = section.strip_prefix("chunks=") {
            // One count per level (fill-last); the version-2 single
            // count parses as the uniform profile it always meant.
            chunks.clear();
            for part in k.split(',') {
                let c: usize = part.parse().map_err(|_| bad())?;
                if c == 0 || c > MAX_CHUNKS {
                    return Err(bad());
                }
                chunks.push(c);
            }
        } else if let Some(o) = section.strip_prefix("order=") {
            order = ChunkOrder::from_name(o).ok_or_else(bad)?;
        } else {
            return Err(bad());
        }
    }
    Ok(AlgoPolicy::composition(&algos)?.with_chunk_profile(&chunks).with_chunk_order(order))
}

/// Compact WAN tree-shape token: [`TreeShape::name`] spellings with the
/// Fibonacci latency parameter rendered as `fibonacci:N`.
fn shape_to_token(s: TreeShape) -> String {
    match s {
        TreeShape::Binomial => "binomial".to_string(),
        TreeShape::Flat => "flat".to_string(),
        TreeShape::Chain => "chain".to_string(),
        TreeShape::DistanceHalving => "distance-halving".to_string(),
        TreeShape::Fibonacci(l) => format!("fibonacci:{l}"),
    }
}

fn shape_from_token(token: &str) -> Result<TreeShape> {
    let bad = || Error::Config(format!("policy table: bad tree-shape token '{token}'"));
    match token {
        "binomial" => Ok(TreeShape::Binomial),
        "flat" => Ok(TreeShape::Flat),
        "chain" => Ok(TreeShape::Chain),
        "distance-halving" => Ok(TreeShape::DistanceHalving),
        other => match other.strip_prefix("fibonacci:") {
            Some(l) => {
                let l: u32 = l.parse().map_err(|_| bad())?;
                if l == 0 {
                    return Err(bad());
                }
                Ok(TreeShape::Fibonacci(l))
            }
            None => Err(bad()),
        },
    }
}

impl PolicyTable {
    /// An empty table for the given tuning context.
    pub fn new(provenance: PolicyProvenance) -> Self {
        PolicyTable {
            provenance,
            entries: Vec::new(),
            bcast_segments: Vec::new(),
            wan_shapes: Vec::new(),
        }
    }

    pub fn provenance(&self) -> &PolicyProvenance {
        &self.provenance
    }

    /// Entries sorted by `(op, bytes)`.
    pub fn entries(&self) -> &[PolicyEntry] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Record (or replace) the verdict for `(op, bytes)`, keeping the
    /// entry list sorted — so the serialized form is deterministic.
    pub fn record(&mut self, op: ReduceOp, bytes: usize, policy: AlgoPolicy, best_us: f64) {
        let key = (op_rank(op), bytes);
        match self.entries.binary_search_by_key(&key, |e| (op_rank(e.op), e.bytes)) {
            Ok(i) => self.entries[i] = PolicyEntry { op, bytes, policy, best_us },
            Err(i) => self.entries.insert(i, PolicyEntry { op, bytes, policy, best_us }),
        }
    }

    /// The verdict stored for exactly `(op, bytes)`.
    pub fn exact(&self, op: ReduceOp, bytes: usize) -> Option<&PolicyEntry> {
        let key = (op_rank(op), bytes);
        self.entries
            .binary_search_by_key(&key, |e| (op_rank(e.op), e.bytes))
            .ok()
            .map(|i| &self.entries[i])
    }

    /// Tuned pipelined-broadcast entries, sorted by payload size.
    pub fn bcast_segment_entries(&self) -> &[SegmentEntry] {
        &self.bcast_segments
    }

    /// Record (or replace) the tuned segment count for a `bytes`-sized
    /// broadcast, keeping the entry list sorted.
    pub fn record_bcast_segments(&mut self, bytes: usize, segments: usize, best_us: f64) {
        let entry = SegmentEntry { bytes, segments, best_us };
        match self.bcast_segments.binary_search_by_key(&bytes, |e| e.bytes) {
            Ok(i) => self.bcast_segments[i] = entry,
            Err(i) => self.bcast_segments.insert(i, entry),
        }
    }

    /// The tuned segment count for a `bytes`-sized broadcast: the exact
    /// entry if present, otherwise the entry whose tuned size is nearest
    /// in log-space (ties break toward the smaller size). `None` when
    /// the table holds no broadcast verdicts at all.
    pub fn best_segments_for(&self, bytes: usize) -> Option<usize> {
        let target = (bytes.max(1) as f64).ln();
        let mut best: Option<(f64, usize)> = None;
        for e in &self.bcast_segments {
            if e.bytes == bytes {
                return Some(e.segments);
            }
            let d = (target - (e.bytes.max(1) as f64).ln()).abs();
            let closer = match best {
                Some((bd, _)) => d < bd,
                None => true,
            };
            if closer {
                best = Some((d, e.segments));
            }
        }
        best.map(|(_, s)| s)
    }

    /// Tuned per-size WAN tree-shape entries, sorted by payload size.
    pub fn wan_shape_entries(&self) -> &[ShapeEntry] {
        &self.wan_shapes
    }

    /// Record (or replace) the tuned WAN tree shape for a `bytes`-sized
    /// payload, keeping the entry list sorted.
    pub fn record_wan_shape(&mut self, bytes: usize, shape: TreeShape, best_us: f64) {
        let entry = ShapeEntry { bytes, shape, best_us };
        match self.wan_shapes.binary_search_by_key(&bytes, |e| e.bytes) {
            Ok(i) => self.wan_shapes[i] = entry,
            Err(i) => self.wan_shapes.insert(i, entry),
        }
    }

    /// The tuned WAN tree shape for a `bytes`-sized payload: the exact
    /// entry if present, otherwise the entry whose tuned size is nearest
    /// in log-space (ties break toward the smaller size). `None` when
    /// the table holds no WAN-shape verdicts at all.
    pub fn best_wan_shape_for(&self, bytes: usize) -> Option<TreeShape> {
        let target = (bytes.max(1) as f64).ln();
        let mut best: Option<(f64, TreeShape)> = None;
        for e in &self.wan_shapes {
            if e.bytes == bytes {
                return Some(e.shape);
            }
            let d = (target - (e.bytes.max(1) as f64).ln()).abs();
            let closer = match best {
                Some((bd, _)) => d < bd,
                None => true,
            };
            if closer {
                best = Some((d, e.shape));
            }
        }
        best.map(|(_, s)| s)
    }

    /// Resolve `(op, bytes)` to a policy: the exact entry if present,
    /// otherwise the entry whose tuned size is nearest in log-space
    /// (ties break toward the smaller size — deterministic). `None` only
    /// when the table holds no entry for `op` at all.
    pub fn best_for(&self, op: ReduceOp, bytes: usize) -> Option<AlgoPolicy> {
        let target = (bytes.max(1) as f64).ln();
        let mut best: Option<(f64, AlgoPolicy)> = None;
        for e in self.entries.iter().filter(|e| e.op == op) {
            if e.bytes == bytes {
                return Some(e.policy);
            }
            let d = (target - (e.bytes.max(1) as f64).ln()).abs();
            let closer = match best {
                Some((bd, _)) => d < bd,
                None => true,
            };
            if closer {
                best = Some((d, e.policy));
            }
        }
        best.map(|(_, p)| p)
    }

    /// `best_us` is informational; a non-finite makespan (a degenerate
    /// cost model) must still round-trip, and JSON has no inf/NaN — so
    /// the codec maps non-finite to `null` (read back as NaN).
    fn best_us_json(v: f64) -> String {
        if v.is_finite() {
            format!("{v:?}")
        } else {
            "null".to_string()
        }
    }

    /// Serialize to the versioned JSON format.
    pub fn to_json(&self) -> String {
        let p = &self.provenance;
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"format\": \"{FORMAT_TAG}\",\n"));
        s.push_str(&format!("  \"version\": {},\n", p.version));
        s.push_str("  \"provenance\": {\n");
        s.push_str(&format!("    \"params_hash\": \"{:#018x}\",\n", p.params_hash));
        s.push_str(&format!(
            "    \"topology_fingerprint\": \"{:#018x}\",\n",
            p.topology_fingerprint
        ));
        s.push_str(&format!("    \"n_ranks\": {},\n", p.n_ranks));
        s.push_str(&format!("    \"n_levels\": {},\n", p.n_levels));
        s.push_str(&format!("    \"strategy\": \"{}\",\n", json::escape(&p.strategy)));
        s.push_str(&format!("    \"level_policy\": \"{}\",\n", json::escape(&p.level_policy)));
        s.push_str(&format!("    \"probe_mode\": \"{}\",\n", json::escape(&p.probe_mode)));
        s.push_str(&format!("    \"vocabulary\": \"{}\"\n", json::escape(&p.vocabulary)));
        s.push_str("  },\n");
        s.push_str("  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"op\": \"{}\", \"bytes\": {}, \"policy\": \"{}\", \"best_us\": {}}}{}\n",
                e.op.name(),
                e.bytes,
                policy_to_token(e.policy),
                Self::best_us_json(e.best_us),
                if i + 1 < self.entries.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"bcast_segments\": [\n");
        for (i, e) in self.bcast_segments.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"bytes\": {}, \"segments\": {}, \"best_us\": {}}}{}\n",
                e.bytes,
                e.segments,
                Self::best_us_json(e.best_us),
                if i + 1 < self.bcast_segments.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]");
        // Optional section: omitted entirely when untuned, so files stay
        // byte-compatible with version-1 readers' expectations and the
        // common case stays small.
        if !self.wan_shapes.is_empty() {
            s.push_str(",\n  \"wan_shapes\": [\n");
            for (i, e) in self.wan_shapes.iter().enumerate() {
                s.push_str(&format!(
                    "    {{\"bytes\": {}, \"shape\": \"{}\", \"best_us\": {}}}{}\n",
                    e.bytes,
                    shape_to_token(e.shape),
                    Self::best_us_json(e.best_us),
                    if i + 1 < self.wan_shapes.len() { "," } else { "" }
                ));
            }
            s.push_str("  ]");
        }
        s.push_str("\n}\n");
        s
    }

    /// Parse the versioned JSON format (strict: unknown versions, bad
    /// tokens and malformed documents are errors).
    pub fn from_json(src: &str) -> Result<PolicyTable> {
        let doc = json::parse(src)?;
        let field = |v: &Value, key: &str| -> Result<Value> {
            v.get(key)
                .cloned()
                .ok_or_else(|| Error::Config(format!("policy table: missing field '{key}'")))
        };
        let str_field = |v: &Value, key: &str| -> Result<String> {
            field(v, key)?
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| Error::Config(format!("policy table: '{key}' must be a string")))
        };
        let u64_field = |v: &Value, key: &str| -> Result<u64> {
            field(v, key)?
                .as_u64()
                .ok_or_else(|| Error::Config(format!("policy table: '{key}' must be an integer")))
        };
        let hash_field = |v: &Value, key: &str| -> Result<u64> {
            let s = str_field(v, key)?;
            let hex = s.strip_prefix("0x").unwrap_or(&s);
            u64::from_str_radix(hex, 16)
                .map_err(|_| Error::Config(format!("policy table: '{key}' is not a hex hash")))
        };
        if str_field(&doc, "format")? != FORMAT_TAG {
            return Err(Error::Config(format!(
                "policy table: not a {FORMAT_TAG} file (format tag mismatch)"
            )));
        }
        let version = u64_field(&doc, "version")?;
        if version == 0 || version > POLICY_TABLE_VERSION {
            return Err(Error::Config(format!(
                "policy table: format version {version} is not in the supported range \
                 1..={POLICY_TABLE_VERSION} — regenerate with `gridcollect tune-composition \
                 --save <table.json>`"
            )));
        }
        let prov = field(&doc, "provenance")?;
        // Version-1 files predate the vocabulary field; their tokens are
        // a strict subset of the current vocabulary, so defaulting keeps
        // them installable.
        let vocabulary = match prov.get("vocabulary") {
            Some(v) => v.as_str().map(str::to_string).ok_or_else(|| {
                Error::Config("policy table: 'vocabulary' must be a string".into())
            })?,
            None => vocabulary_string(),
        };
        let provenance = PolicyProvenance {
            version,
            params_hash: hash_field(&prov, "params_hash")?,
            topology_fingerprint: hash_field(&prov, "topology_fingerprint")?,
            n_ranks: u64_field(&prov, "n_ranks")? as usize,
            n_levels: u64_field(&prov, "n_levels")? as usize,
            strategy: str_field(&prov, "strategy")?,
            level_policy: str_field(&prov, "level_policy")?,
            probe_mode: str_field(&prov, "probe_mode")?,
            vocabulary,
        };
        let mut table = PolicyTable::new(provenance);
        let entries = field(&doc, "entries")?;
        let items = entries
            .as_array()
            .ok_or_else(|| Error::Config("policy table: 'entries' must be an array".into()))?;
        for item in items {
            let op = op_from_name(&str_field(item, "op")?)?;
            let bytes = u64_field(item, "bytes")? as usize;
            let token = str_field(item, "policy")?;
            let policy = policy_from_token(&token)?;
            // A non-interior hybrid boundary is a structural alias of a
            // uniform policy the tuner never emits: a hand-edited table
            // claiming one would *run* a uniform composition while
            // *reporting* a hybrid — reject it rather than silently
            // misreporting what executes. The check is token-level
            // because `hybrid:0` canonicalizes away during parsing.
            if let Some(b) = token.strip_prefix("hybrid:") {
                let b: usize = b.parse().unwrap_or(0);
                if b == 0 || b >= table.provenance.n_levels {
                    return Err(Error::Config(format!(
                        "policy table: hybrid:{b} is not an interior boundary \
                         for a {}-level clustering (valid: 1..{})",
                        table.provenance.n_levels, table.provenance.n_levels
                    )));
                }
            }
            // Likewise a composition naming more explicit levels than
            // the clustering has can only come from a hand edit under a
            // different topology.
            if let Some(body) = token.strip_prefix("comp:") {
                let named = body.split(';').next().unwrap_or("").split(',').count();
                if named > table.provenance.n_levels {
                    return Err(Error::Config(format!(
                        "policy table: '{token}' names {named} levels but the \
                         clustering has only {}",
                        table.provenance.n_levels
                    )));
                }
            }
            let best_us = match field(item, "best_us")? {
                Value::Null => f64::NAN,
                v => v.as_f64().ok_or_else(|| {
                    Error::Config("policy table: 'best_us' must be a number or null".into())
                })?,
            };
            table.record(op, bytes, policy, best_us);
        }
        // Absent in tables written before bcast tuning existed — treat a
        // missing array as empty rather than rejecting old files.
        if let Some(seg) = doc.get("bcast_segments") {
            let items = seg.as_array().ok_or_else(|| {
                Error::Config("policy table: 'bcast_segments' must be an array".into())
            })?;
            for item in items {
                let bytes = u64_field(item, "bytes")? as usize;
                let segments = u64_field(item, "segments")? as usize;
                if segments == 0 {
                    return Err(Error::Config(
                        "policy table: 'segments' must be at least 1".into(),
                    ));
                }
                let best_us = match field(item, "best_us")? {
                    Value::Null => f64::NAN,
                    v => v.as_f64().ok_or_else(|| {
                        Error::Config("policy table: 'best_us' must be a number or null".into())
                    })?,
                };
                table.record_bcast_segments(bytes, segments, best_us);
            }
        }
        // Optional since version 2; earlier files (and tables with no
        // WAN-shape verdicts) simply lack it. Unknown *other* top-level
        // sections are skipped by construction — the parser keeps them
        // and this reader only consults the keys it knows, so files from
        // newer minor revisions stay loadable.
        if let Some(shapes) = doc.get("wan_shapes") {
            let items = shapes.as_array().ok_or_else(|| {
                Error::Config("policy table: 'wan_shapes' must be an array".into())
            })?;
            for item in items {
                let bytes = u64_field(item, "bytes")? as usize;
                let shape = shape_from_token(&str_field(item, "shape")?)?;
                let best_us = match field(item, "best_us")? {
                    Value::Null => f64::NAN,
                    v => v.as_f64().ok_or_else(|| {
                        Error::Config("policy table: 'best_us' must be a number or null".into())
                    })?,
                };
                table.record_wan_shape(bytes, shape, best_us);
            }
        }
        Ok(table)
    }

    /// Fold `other`'s verdicts into this table, `other` winning on key
    /// collisions (the daemon merges its in-memory verdicts — the newer
    /// tuning — over whatever an earlier run left on disk). Hard error
    /// when the two tables' provenance differs: verdicts tuned under
    /// different topologies/params/strategies must never mix in one
    /// file. Returns the number of verdicts folded in.
    pub fn merge(&mut self, other: &PolicyTable) -> Result<usize> {
        other.provenance.check_matches(&self.provenance)?;
        for e in &other.entries {
            self.record(e.op, e.bytes, e.policy, e.best_us);
        }
        for e in &other.bcast_segments {
            self.record_bcast_segments(e.bytes, e.segments, e.best_us);
        }
        for e in &other.wan_shapes {
            self.record_wan_shape(e.bytes, e.shape, e.best_us);
        }
        Ok(other.entries.len() + other.bcast_segments.len() + other.wan_shapes.len())
    }

    /// Write the table to `path` **atomically**: the JSON goes to a
    /// uniquely named temp file in the same directory (same filesystem,
    /// so rename is atomic), is fsynced, then renamed over `path`. A
    /// reader — or a concurrent writer racing this one — therefore only
    /// ever observes some complete table, never a torn prefix; a crash
    /// mid-write leaves at worst a stray `.tmp.` file next to an intact
    /// previous table.
    pub fn save(&self, path: &str) -> Result<()> {
        use std::io::Write as _;
        static SAVE_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let seq = SAVE_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let tmp = format!("{path}.tmp.{}.{seq}", std::process::id());
        let write = |tmp: &str| -> std::io::Result<()> {
            let mut f = std::fs::File::create(tmp)?;
            f.write_all(self.to_json().as_bytes())?;
            f.sync_all()?;
            std::fs::rename(tmp, path)
        };
        if let Err(e) = write(&tmp) {
            let _ = std::fs::remove_file(&tmp);
            return Err(Error::io(path, e));
        }
        // Durability of the rename itself: fsync the directory entry
        // (best effort — the atomicity guarantee above does not need it).
        if let Some(dir) = std::path::Path::new(path).parent() {
            let dir = if dir.as_os_str().is_empty() { std::path::Path::new(".") } else { dir };
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    }

    /// Load a table from `path`. Loading does **not** validate
    /// provenance — that happens when the table is installed into a
    /// session (`GridSession::with_policy_table`), where the current
    /// context is known.
    pub fn load(path: &str) -> Result<PolicyTable> {
        let src = std::fs::read_to_string(path).map_err(|e| Error::io(path, e))?;
        PolicyTable::from_json(&src)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::topology::TopologySpec;

    fn provenance() -> PolicyProvenance {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        PolicyProvenance::of(
            &comm,
            &presets::paper_grid(),
            Strategy::Multilevel,
            &LevelPolicy::paper(),
        )
    }

    #[test]
    fn hashes_are_stable_and_discriminating() {
        let a = Communicator::world(&TopologySpec::paper_fig1());
        let b = Communicator::world(&TopologySpec::paper_fig1());
        let c = Communicator::world(&TopologySpec::paper_experiment());
        // Same spec, different processes-worth of epochs: identical
        // fingerprints (the whole point — files outlive processes).
        assert_ne!(a.epoch(), b.epoch());
        assert_eq!(topology_fingerprint(&a), topology_fingerprint(&b));
        assert_ne!(topology_fingerprint(&a), topology_fingerprint(&c));
        let p = presets::paper_grid();
        assert_eq!(params_hash(&p), params_hash(&presets::paper_grid()));
        assert_ne!(params_hash(&p), params_hash(&p.clone().with_combine_us_per_byte(1.0)));
    }

    #[test]
    fn record_sorts_and_replaces() {
        let mut t = PolicyTable::new(provenance());
        t.record(ReduceOp::Sum, 65536, AlgoPolicy::hybrid(1), 10.0);
        t.record(ReduceOp::Sum, 4096, AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast), 5.0);
        t.record(ReduceOp::Max, 4096, AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast), 7.0);
        assert_eq!(t.len(), 3);
        assert_eq!(t.entries()[0].bytes, 4096, "sorted by (op, bytes)");
        assert_eq!(t.entries()[0].op, ReduceOp::Sum);
        t.record(ReduceOp::Sum, 4096, AlgoPolicy::hybrid(2), 4.0);
        assert_eq!(t.len(), 3, "replaced, not duplicated");
        assert_eq!(t.exact(ReduceOp::Sum, 4096).unwrap().policy, AlgoPolicy::hybrid(2));
    }

    #[test]
    fn best_for_is_exact_then_nearest_log_size() {
        let mut t = PolicyTable::new(provenance());
        let rb = AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast);
        let rsag = AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather);
        t.record(ReduceOp::Sum, 4096, rb, 1.0);
        t.record(ReduceOp::Sum, 1 << 20, rsag, 2.0);
        assert_eq!(t.best_for(ReduceOp::Sum, 4096), Some(rb));
        assert_eq!(t.best_for(ReduceOp::Sum, 1 << 20), Some(rsag));
        // 8 KiB is much nearer 4 KiB than 1 MiB in log-space.
        assert_eq!(t.best_for(ReduceOp::Sum, 8192), Some(rb));
        assert_eq!(t.best_for(ReduceOp::Sum, 1 << 19), Some(rsag));
        // exact log-midpoint (64 KiB between 4 KiB and 1 MiB): the
        // smaller tuned size wins the tie deterministically.
        assert_eq!(t.best_for(ReduceOp::Sum, 65536), Some(rb));
        assert_eq!(t.best_for(ReduceOp::Max, 4096), None, "no entries for op");
    }

    #[test]
    fn json_round_trip_preserves_everything() {
        let mut t = PolicyTable::new(provenance());
        t.record(ReduceOp::Sum, 4096, AlgoPolicy::hybrid(1), 123.456);
        t.record(ReduceOp::Sum, 65536, AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast), 7.5);
        t.record(
            ReduceOp::Prod,
            1 << 20,
            AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather),
            9999.25,
        );
        let back = PolicyTable::from_json(&t.to_json()).unwrap();
        assert_eq!(back.provenance(), t.provenance());
        assert_eq!(back.entries(), t.entries());
    }

    #[test]
    fn from_json_rejects_bad_documents() {
        let good = PolicyTable::new(provenance()).to_json();
        assert!(PolicyTable::from_json(&good).is_ok());
        assert!(PolicyTable::from_json("{}").is_err(), "missing format tag");
        assert!(
            PolicyTable::from_json(&good.replace(FORMAT_TAG, "other-format")).is_err(),
            "wrong format tag"
        );
        assert!(
            PolicyTable::from_json(&good.replace("\"version\": 2", "\"version\": 99")).is_err(),
            "unknown version"
        );
        let mut t = PolicyTable::new(provenance());
        t.record(ReduceOp::Sum, 4096, AlgoPolicy::hybrid(1), 1.0);
        let doc = t.to_json();
        for bad in ["hybrid:x", "comp:", "comp:bogus", "comp:rb;chunks=0", "comp:rb;order=up"] {
            let broken = doc.replace("hybrid:1", bad);
            assert!(PolicyTable::from_json(&broken).is_err(), "'{bad}' must not parse");
        }
    }

    #[test]
    fn non_finite_best_us_still_round_trips() {
        // best_us is informational; JSON has no inf/NaN, so the codec
        // maps non-finite to null and reads it back as NaN — save()
        // must never produce a file load() cannot parse.
        let mut t = PolicyTable::new(provenance());
        t.record(ReduceOp::Sum, 4096, AlgoPolicy::hybrid(1), f64::INFINITY);
        t.record(ReduceOp::Sum, 65536, AlgoPolicy::hybrid(2), f64::NAN);
        let json = t.to_json();
        assert!(json.contains("null"), "non-finite serialized as null: {json}");
        let back = PolicyTable::from_json(&json).unwrap();
        assert_eq!(back.len(), 2);
        assert!(back.entries().iter().all(|e| e.best_us.is_nan()));
        assert_eq!(back.best_for(ReduceOp::Sum, 4096), Some(AlgoPolicy::hybrid(1)));
    }

    #[test]
    fn non_interior_hybrid_tokens_are_rejected_on_load() {
        // hybrid(0) / hybrid(>= n_levels) are structural aliases of the
        // uniforms; a table claiming one would misreport what executes.
        let mut t = PolicyTable::new(provenance()); // fig1: 3 levels
        t.record(ReduceOp::Sum, 4096, AlgoPolicy::hybrid(1), 1.0);
        let good = t.to_json();
        assert!(PolicyTable::from_json(&good).is_ok());
        for bad in ["hybrid:0", "hybrid:3", "hybrid:99"] {
            let doc = good.replace("hybrid:1", bad);
            let err = PolicyTable::from_json(&doc);
            assert!(err.is_err(), "{bad} must not load");
        }
    }

    #[test]
    fn bcast_segment_entries_record_resolve_and_round_trip() {
        let mut t = PolicyTable::new(provenance());
        assert_eq!(t.best_segments_for(4096), None, "untuned table resolves nothing");
        t.record_bcast_segments(1 << 20, 16, 250.0);
        t.record_bcast_segments(4096, 2, 12.5);
        assert_eq!(t.bcast_segment_entries()[0].bytes, 4096, "sorted by bytes");
        t.record_bcast_segments(4096, 4, 10.0);
        assert_eq!(t.bcast_segment_entries().len(), 2, "replaced, not duplicated");
        // Exact, then nearest in log-space (64 KiB midpoint ties toward
        // the smaller tuned size).
        assert_eq!(t.best_segments_for(4096), Some(4));
        assert_eq!(t.best_segments_for(8192), Some(4));
        assert_eq!(t.best_segments_for(65536), Some(4));
        assert_eq!(t.best_segments_for(1 << 19), Some(16));
        let back = PolicyTable::from_json(&t.to_json()).unwrap();
        assert_eq!(back.bcast_segment_entries(), t.bcast_segment_entries());
        assert_eq!(back.best_segments_for(1 << 20), Some(16));
    }

    #[test]
    fn tables_without_bcast_segments_still_load() {
        // Files written before broadcast tuning existed lack the array;
        // they must keep loading (as "no broadcast verdicts").
        let mut t = PolicyTable::new(provenance());
        t.record(ReduceOp::Sum, 4096, AlgoPolicy::hybrid(1), 1.0);
        t.record_bcast_segments(4096, 8, 3.0);
        let json = t.to_json();
        let start = json.find(",\n  \"bcast_segments\"").unwrap();
        let end = json.rfind("  ]\n").unwrap() + 4;
        let legacy = format!("{}\n{}", &json[..start], &json[end..]);
        let back = PolicyTable::from_json(&legacy).unwrap();
        assert_eq!(back.len(), 1);
        assert!(back.bcast_segment_entries().is_empty());
        assert!(
            PolicyTable::from_json(&json.replace("\"segments\": 8", "\"segments\": 0")).is_err(),
            "zero segment count must not load"
        );
    }

    #[test]
    fn composition_tokens_round_trip_with_chunking() {
        let mut t = PolicyTable::new(provenance()); // fig1: 3 levels
        let comp = AlgoPolicy::composition(&[
            LevelAlgo::ReduceBcast,
            LevelAlgo::Halving,
            LevelAlgo::RsAgRing,
        ])
        .unwrap();
        let chunked = AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast)
            .with_chunks(4)
            .with_chunk_order(ChunkOrder::ShortestFirst);
        let balanced = AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast)
            .with_chunks(2)
            .with_chunk_order(ChunkOrder::LeastLoaded);
        let profiled = AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast).with_chunk_profile(&[4, 2]);
        t.record(ReduceOp::Sum, 4096, comp, 1.0);
        t.record(ReduceOp::Sum, 65536, chunked, 2.0);
        t.record(ReduceOp::Sum, 1 << 20, balanced, 3.0);
        t.record(ReduceOp::Sum, 1 << 22, profiled, 4.0);
        let json = t.to_json();
        assert!(json.contains("comp:rb,halving,ring"), "comp token serialized: {json}");
        assert!(json.contains("comp:rb;chunks=4;order=scf"), "chunk knobs serialized: {json}");
        assert!(json.contains("comp:rb;chunks=2;order=ll"), "LL order serialized: {json}");
        assert!(json.contains("comp:rb;chunks=4,2"), "per-level chunk profile serialized: {json}");
        let back = PolicyTable::from_json(&json).unwrap();
        assert_eq!(back.entries(), t.entries());
        assert_eq!(back.exact(ReduceOp::Sum, 4096).unwrap().policy, comp);
        assert_eq!(back.exact(ReduceOp::Sum, 65536).unwrap().policy, chunked);
        assert_eq!(back.exact(ReduceOp::Sum, 1 << 20).unwrap().policy, balanced);
        assert_eq!(back.exact(ReduceOp::Sum, 1 << 22).unwrap().policy, profiled);
        // A composition naming more explicit levels than the clustering
        // has can only come from a hand edit under a different topology.
        let too_deep = json.replace("comp:rb,halving,ring", "comp:rb,rb,halving,ring");
        assert!(PolicyTable::from_json(&too_deep).is_err(), "4 named levels on 3-level grid");
    }

    #[test]
    fn wan_shape_entries_record_resolve_and_round_trip() {
        let mut t = PolicyTable::new(provenance());
        assert_eq!(t.best_wan_shape_for(4096), None, "untuned table resolves nothing");
        let json = t.to_json();
        assert!(!json.contains("wan_shapes"), "empty section omitted: {json}");
        t.record_wan_shape(1 << 20, TreeShape::Fibonacci(3), 250.0);
        t.record_wan_shape(4096, TreeShape::Binomial, 12.5);
        assert_eq!(t.wan_shape_entries()[0].bytes, 4096, "sorted by bytes");
        t.record_wan_shape(4096, TreeShape::Flat, 10.0);
        assert_eq!(t.wan_shape_entries().len(), 2, "replaced, not duplicated");
        // Exact, then nearest in log-space (64 KiB midpoint ties toward
        // the smaller tuned size).
        assert_eq!(t.best_wan_shape_for(4096), Some(TreeShape::Flat));
        assert_eq!(t.best_wan_shape_for(8192), Some(TreeShape::Flat));
        assert_eq!(t.best_wan_shape_for(65536), Some(TreeShape::Flat));
        assert_eq!(t.best_wan_shape_for(1 << 19), Some(TreeShape::Fibonacci(3)));
        let json = t.to_json();
        assert!(json.contains("fibonacci:3"), "parametric shape token: {json}");
        let back = PolicyTable::from_json(&json).unwrap();
        assert_eq!(back.wan_shape_entries(), t.wan_shape_entries());
        for bad in ["fibonacci:0", "fibonacci:x", "star"] {
            let broken = json.replace("fibonacci:3", bad);
            assert!(PolicyTable::from_json(&broken).is_err(), "'{bad}' must not parse");
        }
    }

    #[test]
    fn version_1_documents_still_load_and_install() {
        // The version bump must not brick existing tables: a version-1
        // file (no vocabulary field, no wan_shapes) loads, defaults its
        // vocabulary, and passes the provenance check against a current
        // session.
        let mut t = PolicyTable::new(provenance());
        t.record(ReduceOp::Sum, 4096, AlgoPolicy::hybrid(1), 1.0);
        t.record_bcast_segments(4096, 8, 3.0);
        let vocab_line = format!(",\n    \"vocabulary\": \"{}\"", vocabulary_string());
        let v1 = t
            .to_json()
            .replace("\"version\": 2", "\"version\": 1")
            .replace(&vocab_line, "");
        assert!(!v1.contains("vocabulary"), "surgery removed the field: {v1}");
        let back = PolicyTable::from_json(&v1).unwrap();
        assert_eq!(back.provenance().version, 1);
        assert_eq!(back.provenance().vocabulary, vocabulary_string(), "defaulted");
        assert_eq!(back.entries(), t.entries());
        assert!(back.provenance().check_matches(&provenance()).is_ok());
    }

    #[test]
    fn unknown_optional_sections_are_skipped() {
        // Forward compatibility: a file from a newer minor revision may
        // carry sections this build has never heard of — they must be
        // skipped, not rejected (the version gate handles real breaks).
        let mut t = PolicyTable::new(provenance());
        t.record(ReduceOp::Sum, 4096, AlgoPolicy::hybrid(1), 1.0);
        let json = t.to_json().replacen(
            "  \"entries\":",
            "  \"future_section\": [{\"x\": 1}, 2, \"three\"],\n  \"entries\":",
            1,
        );
        let back = PolicyTable::from_json(&json).unwrap();
        assert_eq!(back.entries(), t.entries());
    }

    #[test]
    fn merge_folds_newer_verdicts_over_older() {
        let rb = AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast);
        let rsag = AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather);
        let mut disk = PolicyTable::new(provenance());
        disk.record(ReduceOp::Sum, 4096, rb, 5.0);
        disk.record(ReduceOp::Sum, 65536, rb, 6.0);
        disk.record_bcast_segments(4096, 2, 3.0);
        let mut fresh = PolicyTable::new(provenance());
        fresh.record(ReduceOp::Sum, 65536, rsag, 4.0); // collision: newer wins
        fresh.record(ReduceOp::Max, 4096, rb, 7.0); // new key
        fresh.record_wan_shape(4096, TreeShape::Flat, 2.0);
        assert_eq!(disk.merge(&fresh).unwrap(), 3);
        assert_eq!(disk.len(), 3);
        assert_eq!(disk.exact(ReduceOp::Sum, 4096).unwrap().policy, rb, "untouched");
        let merged = disk.exact(ReduceOp::Sum, 65536).unwrap();
        assert_eq!(merged.policy, rsag, "newer verdict won the collision");
        assert_eq!(merged.best_us, 4.0);
        assert_eq!(disk.exact(ReduceOp::Max, 4096).unwrap().policy, rb);
        assert_eq!(disk.best_segments_for(4096), Some(2), "disjoint section kept");
        assert_eq!(disk.best_wan_shape_for(4096), Some(TreeShape::Flat));
        // save -> merge -> load round trip: what a daemon restart reads
        // back is exactly the merged table.
        let path = format!(
            "{}/gridcollect_merge_{}.json",
            std::env::temp_dir().display(),
            std::process::id()
        );
        disk.save(&path).unwrap();
        let back = PolicyTable::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.entries(), disk.entries());
        assert_eq!(back.bcast_segment_entries(), disk.bcast_segment_entries());
        assert_eq!(back.wan_shape_entries(), disk.wan_shape_entries());
    }

    #[test]
    fn merge_rejects_incompatible_provenance() {
        let mut a = PolicyTable::new(provenance());
        let mut p = provenance();
        p.topology_fingerprint ^= 1;
        let b = PolicyTable::new(p);
        assert!(a.merge(&b).is_err(), "fingerprint mismatch is a hard error");
        let mut p = provenance();
        p.params_hash ^= 1;
        let c = PolicyTable::new(p);
        assert!(a.merge(&c).is_err(), "params mismatch is a hard error");
        assert_eq!(a.len(), 0, "a failed merge folds nothing in");
    }

    #[test]
    fn save_is_atomic_under_crash_window_and_concurrent_writers() {
        let path = format!(
            "{}/gridcollect_atomic_{}.json",
            std::env::temp_dir().display(),
            std::process::id()
        );
        let rb = AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast);
        let mut t = PolicyTable::new(provenance());
        t.record(ReduceOp::Sum, 4096, rb, 1.0);
        t.save(&path).unwrap();
        // Crash window: a writer that died mid-write leaves only a
        // garbage temp file; the published table must stay intact.
        let stale_tmp = format!("{path}.tmp.{}.99999", std::process::id());
        std::fs::write(&stale_tmp, "{\"torn\": tru").unwrap();
        assert_eq!(PolicyTable::load(&path).unwrap().len(), 1, "table untouched by torn temp");
        std::fs::remove_file(&stale_tmp).unwrap();
        // Concurrent writers racing distinct verdict sets: every
        // interleaving publishes via rename, so the survivor is some
        // writer's *complete* table — load() must always parse.
        std::thread::scope(|s| {
            for w in 0..4u32 {
                let path = &path;
                s.spawn(move || {
                    let mut t = PolicyTable::new(provenance());
                    t.record(ReduceOp::Sum, 4096 << w, rb, w as f64);
                    for _ in 0..8 {
                        t.save(path).unwrap();
                        let back = PolicyTable::load(path).unwrap();
                        assert_eq!(back.len(), 1, "never a torn read");
                    }
                });
            }
        });
        let survivor = PolicyTable::load(&path).unwrap();
        assert_eq!(survivor.len(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn provenance_mismatches_are_hard_errors() {
        let current = provenance();
        let mut other = current.clone();
        other.params_hash ^= 1;
        assert!(other.check_matches(&current).is_err(), "params");
        let mut other = current.clone();
        other.topology_fingerprint ^= 1;
        assert!(other.check_matches(&current).is_err(), "topology");
        let mut other = current.clone();
        other.strategy = "mpich-binomial".into();
        assert!(other.check_matches(&current).is_err(), "strategy");
        let mut other = current.clone();
        other.level_policy = "something else".into();
        assert!(other.check_matches(&current).is_err(), "level policy");
        let mut other = current.clone();
        other.probe_mode = "full".into();
        assert!(other.check_matches(&current).is_err(), "probe mode");
        let mut other = current.clone();
        other.vocabulary = "algos=rb".into();
        assert!(other.check_matches(&current).is_err(), "vocabulary");
        let mut other = current.clone();
        other.version = POLICY_TABLE_VERSION + 1;
        assert!(other.check_matches(&current).is_err(), "future version");
        let mut other = current.clone();
        other.version = 1;
        assert!(other.check_matches(&current).is_ok(), "older supported version");
        assert!(current.check_matches(&current).is_ok());
    }
}
