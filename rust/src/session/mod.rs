//! `GridSession` — the front door to the whole stack.
//!
//! The paper's promise is that multilevel topology-aware communication
//! is constructed *automatically during execution* from topology
//! information. Before this module, using the reproduction that way
//! meant hand-wiring a [`CollectiveEngine`] from a borrowed
//! communicator plus a stack of `with_*` builders, and the boundary
//! autotuner's verdicts (PR 4) were computed and then dropped — nothing
//! consumed the winning [`AlgoPolicy`] per (topology, payload size).
//!
//! A [`GridSession`] owns the whole context — [`Communicator`],
//! [`NetworkParams`], strategy and per-level tree shapes, a shared
//! [`PlanCache`], the reusable engine [`ExecScratch`] arena, the fused-
//! schedule memo — plus a pluggable [`PolicyProvider`] that resolves the
//! allreduce composition per `(op, topology, payload size)` **at call
//! time**. Tuned tables persist ([`PolicyTable`], written by
//! `gridcollect tune-boundary --save`, consumed via `--policy-file`), so
//! the tuner → workload loop closes: tune once, and every later run of
//! `train`/`allreduce` transparently executes the winning policy with
//! zero tree builds, zero compiles, zero payload allocations and zero
//! scratch growth on warm steps (counter-enforced in
//! `rust/tests/session_counters.rs`).
//!
//! The session is a *view factory* over the internal execution layer:
//! [`GridSession::engine`] hands out short-lived [`CollectiveEngine`]s
//! that share the session's caches, scratch and schedule memo, so using
//! the front door costs nothing over hand-wiring — and every
//! `SimResult` it produces is bitwise-identical to the engine path
//! (`rust/tests/policy_session.rs`).

pub mod policy;
pub mod table;

pub use policy::{AutoTune, Fixed, OnMiss, PolicyProvider, Tuned};
pub use table::{
    policy_from_token, policy_to_token, topology_fingerprint, PolicyEntry, PolicyProvenance,
    PolicyTable, SegmentEntry, ShapeEntry, POLICY_TABLE_VERSION,
};

use crate::collectives::{request, CollectiveEngine, GhostProber, OpSpec, Outcome, ScheduleMemo};
use crate::coordinator::tuning;
use crate::error::{Error, Result};
use crate::model::NetworkParams;
use crate::netsim::{
    Combiner, ExecMode, ExecScratch, GhostPayload, NativeCombiner, Payload, ReduceOp, SimResult,
};
use crate::plan::{
    AlgoPolicy, AllreduceAlgo, CollectivePlan, OpKind, PlanCache, Schedule, ScheduleBuilder,
};
use crate::topology::{Communicator, Rank};
use crate::tree::{LevelPolicy, Strategy, TreeShape};
use crate::util::fmt::Table;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// The owning front door: topology + cost model + strategy + caches +
/// policy resolution, in one value. See the module docs for the full
/// story; construction is `GridSession::new(&comm, params, strategy)`
/// plus optional `with_*` builders.
pub struct GridSession {
    comm: Communicator,
    params: NetworkParams,
    strategy: Strategy,
    level_policy: LevelPolicy,
    combiner: Arc<dyn Combiner>,
    /// The combiner again when it is known `Sync` (required to share it
    /// across shard workers in full mode); `None` after
    /// [`GridSession::with_combiner`].
    sync_combiner: Option<Arc<dyn Combiner + Sync>>,
    cache: Arc<PlanCache>,
    scratch: Arc<ExecScratch>,
    schedules: ScheduleMemo,
    provider: Box<dyn PolicyProvider>,
    trace: bool,
    exec_mode: ExecMode,
}

impl GridSession {
    /// Open a session on `comm` (cloned — clones share the communicator
    /// epoch, so plans built through this session stay valid for other
    /// holders of the same communicator).
    pub fn new(comm: &Communicator, params: NetworkParams, strategy: Strategy) -> Self {
        GridSession {
            comm: comm.clone(),
            params,
            strategy,
            level_policy: LevelPolicy::paper(),
            combiner: Arc::new(NativeCombiner),
            sync_combiner: Some(Arc::new(NativeCombiner)),
            cache: Arc::new(PlanCache::new()),
            scratch: Arc::new(ExecScratch::new()),
            schedules: Arc::new(Mutex::new(HashMap::new())),
            provider: Box::new(Fixed(AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast))),
            trace: false,
            exec_mode: ExecMode::Sequential,
        }
    }

    /// Route reduce arithmetic through a specific combiner (e.g. the
    /// PJRT-backed `XlaCombiner`). Its thread-safety is unknown here, so
    /// a sharded session's full-mode runs fall back to the sequential
    /// engine; use [`GridSession::with_sync_combiner`] when the combiner
    /// is `Sync`.
    pub fn with_combiner(mut self, combiner: Arc<dyn Combiner>) -> Self {
        self.combiner = combiner;
        self.sync_combiner = None;
        self
    }

    /// Route reduce arithmetic through a thread-safe combiner that
    /// sharded full-mode runs may share across workers.
    pub fn with_sync_combiner(mut self, combiner: Arc<dyn Combiner + Sync>) -> Self {
        self.combiner = combiner.clone();
        self.sync_combiner = Some(combiner);
        self
    }

    /// Select sequential or cluster-sharded execution for every run this
    /// session performs. Sharded results are bitwise-identical to
    /// sequential ones (see `netsim::shard`); single-cluster topologies
    /// and `threads <= 1` degenerate to the sequential fast path.
    pub fn with_exec_mode(mut self, mode: ExecMode) -> Self {
        self.exec_mode = mode;
        self
    }

    /// The session's execution mode.
    pub fn exec_mode(&self) -> ExecMode {
        self.exec_mode
    }

    /// Per-level tree shapes (default: the paper's flat-WAN policy).
    pub fn with_level_policy(mut self, policy: LevelPolicy) -> Self {
        self.level_policy = policy;
        self
    }

    /// Share a plan cache with other sessions/engines.
    pub fn with_plan_cache(mut self, cache: Arc<PlanCache>) -> Self {
        self.cache = cache;
        self
    }

    /// Share the execution scratch arenas with other sessions/engines.
    pub fn with_scratch(mut self, scratch: Arc<ExecScratch>) -> Self {
        self.scratch = scratch;
        self
    }

    /// Record per-message trace events on every run.
    pub fn with_trace(mut self) -> Self {
        self.trace = true;
        self
    }

    /// Install a policy provider (default: `Fixed(reduce+bcast)`).
    pub fn with_policy_provider(mut self, provider: Box<dyn PolicyProvider>) -> Self {
        self.provider = provider;
        self
    }

    /// Shorthand: resolve every allreduce to one fixed policy.
    pub fn with_allreduce_policy(self, policy: AlgoPolicy) -> Self {
        self.with_policy_provider(Box::new(Fixed(policy)))
    }

    /// Install a persisted tuning table as the policy provider. The
    /// table's provenance must match this session's context (topology
    /// fingerprint, `NetworkParams` hash, strategy, level policy) — a
    /// table tuned under different conditions is a **hard error**, never
    /// a silent accept.
    pub fn with_policy_table(self, table: PolicyTable) -> Result<Self> {
        table.provenance().check_matches(&self.provenance())?;
        Ok(self.with_policy_provider(Box::new(Tuned(table))))
    }

    /// [`GridSession::with_policy_table`], loading the table from a
    /// `tune-boundary --save` file first.
    pub fn with_policy_file(self, path: &str) -> Result<Self> {
        let table = PolicyTable::load(path)?;
        self.with_policy_table(table)
    }

    pub fn comm(&self) -> &Communicator {
        &self.comm
    }

    pub fn params(&self) -> &NetworkParams {
        &self.params
    }

    pub fn strategy(&self) -> Strategy {
        self.strategy
    }

    pub fn level_policy(&self) -> &LevelPolicy {
        &self.level_policy
    }

    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.cache
    }

    pub fn scratch(&self) -> &Arc<ExecScratch> {
        &self.scratch
    }

    pub fn combiner(&self) -> &dyn Combiner {
        self.combiner.as_ref()
    }

    /// Display name of the installed policy provider.
    pub fn policy_name(&self) -> String {
        self.provider.name()
    }

    /// The provenance tuning performed by this session would carry —
    /// also what a loaded table is checked against.
    pub fn provenance(&self) -> PolicyProvenance {
        PolicyProvenance::of(&self.comm, &self.params, self.strategy, &self.level_policy)
    }

    /// A short-lived engine view sharing this session's communicator,
    /// combiner, plan cache, scratch arenas and schedule memo — the
    /// escape hatch to the internal execution layer. Constructing one is
    /// a few `Arc` clones plus two small copies (the cost-model vector
    /// and the level-policy shape table — no private cache/scratch/memo
    /// is ever allocated and discarded); per-step hot loops should hold
    /// one view across steps, as [`crate::coordinator::training::train`]
    /// does. The warm-path guarantees (zero builds / compiles / payload
    /// allocs / scratch growth) hold across views either way, because
    /// all state of consequence lives in the shared `Arc`s.
    pub fn engine(&self) -> CollectiveEngine<'_> {
        CollectiveEngine::from_parts(
            &self.comm,
            self.params.clone(),
            self.strategy,
            crate::collectives::EngineParts {
                combiner: self.combiner.as_ref(),
                combiner_sync: self.sync_combiner.as_deref(),
                policy: self.level_policy.clone(),
                cache: self.cache.clone(),
                scratch: self.scratch.clone(),
                schedules: self.schedules.clone(),
                trace: self.trace,
                exec_mode: self.exec_mode,
            },
        )
    }

    /// Resolve the allreduce composition for an `op` over `bytes` via
    /// the installed [`PolicyProvider`].
    pub fn resolve_policy(&self, op: ReduceOp, bytes: usize) -> Result<AlgoPolicy> {
        self.provider.resolve(self, op, bytes)
    }

    // ---- generic request paths -------------------------------------

    /// Run a typed request: plan (cached) → encode → simulate → decode.
    pub fn run(&self, request: &dyn OpSpec) -> Result<Outcome> {
        self.engine().run(request)
    }

    /// Measurement path: identical simulation, no per-rank decode.
    pub fn run_sim(&self, request: &dyn OpSpec) -> Result<SimResult> {
        self.engine().run_sim(request)
    }

    /// Ghost (timing-only) path: bit-identical timing, zero payload
    /// allocation, recycled scratch.
    pub fn simulate_timing(&self, request: &dyn OpSpec) -> Result<SimResult> {
        self.engine().simulate_timing(request)
    }

    /// Pooled ghost probe: [`GridSession::simulate_timing`] into a
    /// caller-owned result buffer — a warm probe loop allocates nothing
    /// for results either (≤ 4-level clusterings keep the per-separation
    /// accounting inline).
    pub fn simulate_timing_into(&self, request: &dyn OpSpec, out: &mut SimResult) -> Result<()> {
        self.engine().simulate_timing_into(request, out)
    }

    /// A `Send + Sync` ghost-probing view of this session's engine for
    /// parallel driver fan-out (see [`CollectiveEngine::ghost_prober`]).
    pub fn ghost_prober(&self) -> GhostProber<'_> {
        self.engine().ghost_prober()
    }

    /// Fetch (or build once) the cached plan for `(root, op, segments)`.
    pub fn plan_for(&self, root: Rank, op: OpKind, segments: usize) -> Result<Arc<CollectivePlan>> {
        self.engine().plan_for(root, op, segments)
    }

    /// Start a fused multi-collective schedule over this session.
    pub fn schedule_builder(&self) -> ScheduleBuilder {
        ScheduleBuilder::new(&self.comm)
    }

    /// The fused reduce;bcast allreduce as a two-segment schedule.
    pub fn allreduce_schedule(&self, root: Rank, op: ReduceOp) -> Result<Schedule> {
        self.engine().allreduce_schedule(root, op)
    }

    /// Execute a fused schedule as one simulation.
    pub fn run_schedule(&self, schedule: &Schedule, init: Vec<Payload>) -> Result<SimResult> {
        self.engine().run_schedule(schedule, init)
    }

    /// Ghost-mode schedule execution (timing-only).
    pub fn run_schedule_timing(
        &self,
        schedule: &Schedule,
        init: Vec<GhostPayload>,
    ) -> Result<SimResult> {
        self.engine().run_schedule_timing(schedule, init)
    }

    /// Memoized schedule slot shared by every engine view of this
    /// session: built once per key, reused by all later calls.
    pub fn memo_schedule(
        &self,
        key: &str,
        build: impl FnOnce() -> Result<Schedule>,
    ) -> Result<Arc<Schedule>> {
        self.engine().memo_schedule(key, build)
    }

    // ---- named collectives -----------------------------------------

    /// MPI_Bcast: `data` flows from `root` to every rank.
    pub fn bcast(&self, root: Rank, data: &[f32]) -> Result<Outcome> {
        self.run(&request::Bcast { root, data })
    }

    /// MPI_Bcast, measurement path.
    pub fn bcast_sim(&self, root: Rank, data: &[f32]) -> Result<SimResult> {
        self.run_sim(&request::Bcast { root, data })
    }

    /// MPI_Reduce: elementwise `op`, result at `root`.
    pub fn reduce(&self, root: Rank, op: ReduceOp, contributions: &[Vec<f32>]) -> Result<Outcome> {
        self.run(&request::Reduce { root, op, contributions })
    }

    /// MPI_Barrier rooted at rank 0.
    pub fn barrier(&self) -> Result<SimResult> {
        self.run_sim(&request::Barrier)
    }

    /// MPI_Gather: rank `r`'s segment ends at `root`.
    pub fn gather(&self, root: Rank, contributions: &[Vec<f32>]) -> Result<Outcome> {
        self.run(&request::Gather { root, contributions })
    }

    /// MPI_Scatter: `segments[r]` travels from `root` to rank `r`.
    pub fn scatter(&self, root: Rank, segments: &[Vec<f32>]) -> Result<Outcome> {
        self.run(&request::Scatter { root, segments })
    }

    /// All-reduce, **policy-resolved**: the installed provider picks the
    /// composition for this payload size — the tuned path when a policy
    /// table is installed. Every policy is bitwise-identical in its
    /// results; the provider only chooses the message structure.
    pub fn allreduce(&self, op: ReduceOp, contributions: &[Vec<f32>]) -> Result<Outcome> {
        self.allreduce_at(0, op, contributions)
    }

    /// Policy-resolved all-reduce with an explicit internal tree root.
    pub fn allreduce_at(
        &self,
        root: Rank,
        op: ReduceOp,
        contributions: &[Vec<f32>],
    ) -> Result<Outcome> {
        let bytes = contributions.first().map(|c| c.len() * 4).unwrap_or(0);
        let policy = self.resolve_policy(op, bytes)?;
        self.allreduce_with_policy(policy, root, op, contributions)
    }

    /// All-reduce under an explicit uniform composition (bypasses the
    /// provider).
    pub fn allreduce_with(
        &self,
        algo: AllreduceAlgo,
        root: Rank,
        op: ReduceOp,
        contributions: &[Vec<f32>],
    ) -> Result<Outcome> {
        self.allreduce_with_policy(AlgoPolicy::uniform(algo), root, op, contributions)
    }

    /// All-reduce under an explicit per-level policy (bypasses the
    /// provider).
    pub fn allreduce_with_policy(
        &self,
        policy: AlgoPolicy,
        root: Rank,
        op: ReduceOp,
        contributions: &[Vec<f32>],
    ) -> Result<Outcome> {
        self.run(&request::Allreduce { root, op, policy, contributions })
    }

    /// Policy-resolved, data-free allreduce timing probe: `elems` f32
    /// per rank, ghost execution. On a warm session this is exactly one
    /// engine run — zero builds, zero compiles, zero payload
    /// allocations, zero scratch growth.
    pub fn allreduce_timing(&self, op: ReduceOp, elems: usize) -> Result<SimResult> {
        let policy = self.resolve_policy(op, elems * 4)?;
        self.simulate_timing(&request::AllreduceProbe { root: 0, op, policy, elems })
    }

    /// Allgather (§6 extension).
    pub fn allgather(&self, contributions: &[Vec<f32>]) -> Result<Outcome> {
        self.run(&request::Allgather { contributions })
    }

    /// Reduce-scatter (§6 extension).
    pub fn reduce_scatter(
        &self,
        op: ReduceOp,
        contributions: &[Vec<Vec<f32>>],
    ) -> Result<Outcome> {
        self.run(&request::ReduceScatter { op, contributions })
    }

    /// Personalized all-to-all (§6 extension).
    pub fn alltoall(&self, sends: &[Vec<Vec<f32>>]) -> Result<Outcome> {
        self.run(&request::Alltoall { sends })
    }

    /// Segmented (pipelined) broadcast.
    pub fn bcast_segmented(&self, root: Rank, data: &[f32], n_segments: usize) -> Result<Outcome> {
        self.run(&request::BcastSegmented { root, data, n_segments })
    }

    /// Segmented broadcast with the chunk count **policy-resolved**: the
    /// tuned count when the installed provider holds broadcast verdicts
    /// covering this payload size ([`GridSession::tune_bcast_table`]),
    /// otherwise one unsegmented send.
    pub fn bcast_segmented_auto(&self, root: Rank, data: &[f32]) -> Result<Outcome> {
        let segments = self.resolve_bcast_segments(data.len() * 4)?.unwrap_or(1);
        self.bcast_segmented(root, data, segments)
    }

    /// The tuned segment count the installed provider holds for a
    /// `bytes`-sized broadcast (`None` when it carries no broadcast
    /// verdicts).
    pub fn resolve_bcast_segments(&self, bytes: usize) -> Result<Option<usize>> {
        self.provider.resolve_bcast_segments(self, bytes)
    }

    /// Empirical segment-count tuning for the segmented broadcast.
    pub fn tune_bcast_segments(
        &self,
        root: Rank,
        data: &[f32],
        candidates: &[usize],
    ) -> Result<(usize, f64)> {
        self.engine().tune_bcast_segments(root, data, candidates)
    }

    /// The tuned WAN tree shape the installed provider holds for a
    /// `bytes`-sized payload (`None` when it carries no WAN-shape
    /// verdicts).
    pub fn resolve_wan_shape(&self, bytes: usize) -> Result<Option<TreeShape>> {
        self.provider.resolve_wan_shape(self, bytes)
    }

    /// The session's [`LevelPolicy`] with the provider's tuned WAN shape
    /// for `bytes` applied at the root level — `None` when no WAN-shape
    /// verdict exists. Trees depend on the level policy, so the caller
    /// applies this by opening a session with
    /// [`GridSession::with_level_policy`] (a new plan-cache context; the
    /// shapes change the plans themselves).
    pub fn wan_level_policy(&self, bytes: usize) -> Result<Option<LevelPolicy>> {
        let Some(shape) = self.resolve_wan_shape(bytes)? else {
            return Ok(None);
        };
        let mut lp = self.level_policy.clone();
        if lp.shapes.is_empty() {
            lp.shapes.push(shape);
        } else {
            lp.shapes[0] = shape;
        }
        Ok(Some(lp))
    }

    /// Snapshot the installed provider's allreduce verdicts into a
    /// provenance-stamped [`PolicyTable`] and write it to `path` — how
    /// an [`AutoTune`] provider with a persist path leaves a
    /// `--policy-file`-loadable table behind, and how any workload can
    /// persist what its provider accumulated.
    pub fn save_policy_table(&self, path: &str) -> Result<PolicyTable> {
        let mut table = PolicyTable::new(self.provenance());
        for e in self.provider.verdict_entries() {
            table.record(e.op, e.bytes, e.policy, e.best_us);
        }
        table.save(path)?;
        Ok(table)
    }

    // ---- tuning ----------------------------------------------------

    /// Sweep the composition candidates for every payload size via ghost
    /// probes and return both the E14 report table and a provenance-
    /// stamped [`PolicyTable`] ready to [`PolicyTable::save`] (or
    /// install via [`GridSession::with_policy_table`]).
    pub fn tune_boundary(&self, op: ReduceOp, sizes: &[usize]) -> Result<(Table, PolicyTable)> {
        let engine = self.engine();
        let (report, tunings) = tuning::boundary_tuning_table(&engine, op, sizes)?;
        let mut table = PolicyTable::new(self.provenance());
        for t in &tunings {
            table.record(t.op, t.bytes, t.best, t.best_us);
        }
        Ok((report, table))
    }

    /// The composition tuner's analogue of
    /// [`GridSession::tune_boundary`]: search the full per-level
    /// assignment space (exhaustively, or with beam search on deep
    /// clusterings — see [`tuning::SearchMode`]) plus the chunked
    /// refinement, and return the report table and a provenance-stamped
    /// [`PolicyTable`].
    pub fn tune_composition(
        &self,
        op: ReduceOp,
        sizes: &[usize],
        mode: tuning::SearchMode,
    ) -> Result<(Table, PolicyTable)> {
        let engine = self.engine();
        let (report, tunings) = tuning::composition_tuning_table(&engine, op, sizes, mode)?;
        let mut table = PolicyTable::new(self.provenance());
        for t in &tunings {
            table.record(t.op, t.bytes, t.best, t.best_us);
        }
        Ok((report, table))
    }

    /// Sweep candidate WAN tree shapes per payload size and return a
    /// report table plus a provenance-stamped [`PolicyTable`] carrying
    /// per-size [`ShapeEntry`] verdicts
    /// ([`GridSession::resolve_wan_shape`] consumes them once the table
    /// is installed).
    ///
    /// Unlike composition probes, a candidate shape changes the trees
    /// themselves, so each candidate runs on a **private** session (its
    /// own plan cache): the session's shared cache must never hold
    /// foreign-shape plans.
    pub fn tune_wan_shapes(
        &self,
        op: ReduceOp,
        sizes: &[usize],
        candidates: &[TreeShape],
    ) -> Result<(Table, PolicyTable)> {
        if candidates.is_empty() {
            return Err(Error::Comm("tune_wan_shapes: empty candidate set".into()));
        }
        let mut report = Table::new(&["bytes", "WAN shape", "makespan", "winner"]);
        let mut table = PolicyTable::new(self.provenance());
        for &bytes in sizes {
            if bytes % 4 != 0 {
                return Err(Error::Comm(format!(
                    "tune_wan_shapes: payload size {bytes} is not f32-aligned"
                )));
            }
            let mut probes = Vec::with_capacity(candidates.len());
            for &shape in candidates {
                let mut lp = self.level_policy.clone();
                if lp.shapes.is_empty() {
                    lp.shapes.push(shape);
                } else {
                    lp.shapes[0] = shape;
                }
                let probe = GridSession::new(&self.comm, self.params.clone(), self.strategy)
                    .with_level_policy(lp);
                let sim = probe.allreduce_timing(op, bytes / 4)?;
                probes.push((shape, sim.makespan_us));
            }
            let &(best_shape, best_us) = probes
                .iter()
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .expect("candidate set is non-empty");
            table.record_wan_shape(bytes, best_shape, best_us);
            for (shape, us) in probes {
                report.row(&[
                    crate::util::fmt::bytes(bytes),
                    shape.name(),
                    crate::util::fmt::time_us(us),
                    if shape == best_shape { "<- best".into() } else { String::new() },
                ]);
            }
        }
        Ok((report, table))
    }

    /// Sweep pipelined-broadcast segment-count candidates for every
    /// payload size via **ghost probes** (bitwise-identical timing to
    /// the data path, zero payload allocation, one pooled result buffer)
    /// and return a report table plus a provenance-stamped
    /// [`PolicyTable`] whose verdicts
    /// [`GridSession::bcast_segmented_auto`] consumes once installed.
    pub fn tune_bcast_table(
        &self,
        root: Rank,
        sizes: &[usize],
        candidates: &[usize],
    ) -> Result<(Table, PolicyTable)> {
        if candidates.is_empty() {
            return Err(Error::Comm("tune_bcast_table: empty candidate set".into()));
        }
        let mut report = Table::new(&["bytes", "best segments", "best time", "unsegmented"]);
        let mut table = PolicyTable::new(self.provenance());
        let mut probe = SimResult::default();
        for &bytes in sizes {
            let data = vec![0.0f32; bytes.div_ceil(4).max(1)];
            let mut best = (1usize, f64::INFINITY);
            let mut unsegmented = f64::INFINITY;
            for &segments in candidates {
                self.simulate_timing_into(
                    &request::BcastSegmented { root, data: &data, n_segments: segments },
                    &mut probe,
                )?;
                if segments <= 1 {
                    unsegmented = probe.makespan_us;
                }
                if probe.makespan_us < best.1 {
                    best = (segments, probe.makespan_us);
                }
            }
            table.record_bcast_segments(bytes, best.0, best.1);
            report.row(&[
                crate::util::fmt::bytes(bytes),
                best.0.to_string(),
                crate::util::fmt::time_us(best.1),
                if unsegmented.is_finite() {
                    crate::util::fmt::time_us(unsegmented)
                } else {
                    "-".into()
                },
            ]);
        }
        Ok((report, table))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::topology::TopologySpec;

    fn session() -> GridSession {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel)
    }

    #[test]
    fn session_collectives_deliver_correct_data() {
        let s = session();
        let n = s.comm().size();
        let data: Vec<f32> = (0..48).map(|i| i as f32).collect();
        let out = s.bcast(3, &data).unwrap();
        for r in 0..n {
            assert_eq!(out.data[r], data, "rank {r}");
        }
        let contributions: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0f32; 8]).collect();
        let out = s.allreduce(ReduceOp::Sum, &contributions).unwrap();
        for r in 0..n {
            assert_eq!(out.data[r], vec![n as f32; 8], "rank {r}");
        }
    }

    #[test]
    fn default_provider_is_fixed_reduce_bcast() {
        let s = session();
        assert_eq!(
            s.resolve_policy(ReduceOp::Sum, 4096).unwrap(),
            AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast)
        );
        assert!(s.policy_name().starts_with("fixed("));
        let s = s.with_allreduce_policy(AlgoPolicy::hybrid(1));
        assert_eq!(s.resolve_policy(ReduceOp::Sum, 4096).unwrap(), AlgoPolicy::hybrid(1));
    }

    #[test]
    fn engine_views_share_caches_and_memo() {
        let s = session();
        let data = vec![1.0f32; 16];
        s.bcast(0, &data).unwrap();
        s.bcast(0, &data).unwrap();
        // Two separate engine views, one shared cache: second call hit.
        assert_eq!(s.plan_cache().misses(), 1);
        assert_eq!(s.plan_cache().hits(), 1);
        let a = s.memo_schedule("x", || s.allreduce_schedule(0, ReduceOp::Sum)).unwrap();
        let b = s.memo_schedule("x", || panic!("memo must not rebuild")).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "schedule memo shared across views");
    }

    #[test]
    fn autotune_provider_memoizes_per_size_verdicts() {
        let comm = Communicator::world(&TopologySpec::paper_experiment());
        let s = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel)
            .with_policy_provider(Box::new(AutoTune::new()));
        let p1 = s.resolve_policy(ReduceOp::Sum, 65536).unwrap();
        let (_, table) = s.tune_boundary(ReduceOp::Sum, &[65536]).unwrap();
        assert_eq!(Some(p1), table.best_for(ReduceOp::Sum, 65536), "autotune == tuner verdict");
        // Second resolve is a memo hit: the session-local plan cache
        // sees no further traffic (cache-local stats are race-free).
        let (hits, misses) = (s.plan_cache().hits(), s.plan_cache().misses());
        let p2 = s.resolve_policy(ReduceOp::Sum, 65536).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(s.plan_cache().hits(), hits, "memoized verdict resolves without probing");
        assert_eq!(s.plan_cache().misses(), misses);
        // Fallback mode never probes: the cache stays untouched.
        let s = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel)
            .with_policy_provider(Box::new(AutoTune::with_on_miss(OnMiss::Fallback(
                AlgoPolicy::hybrid(2),
            ))));
        assert_eq!(s.resolve_policy(ReduceOp::Max, 4096).unwrap(), AlgoPolicy::hybrid(2));
        assert_eq!(s.plan_cache().hits() + s.plan_cache().misses(), 0);
    }

    #[test]
    fn policy_table_install_validates_provenance() {
        let comm = Communicator::world(&TopologySpec::paper_experiment());
        let s = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel);
        let (_, table) = s.tune_boundary(ReduceOp::Sum, &[4096, 65536]).unwrap();
        // Same context: installs fine and resolves to the tuned argmin.
        let tuned = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel)
            .with_policy_table(table.clone())
            .unwrap();
        assert_eq!(
            tuned.resolve_policy(ReduceOp::Sum, 65536).unwrap(),
            table.best_for(ReduceOp::Sum, 65536).unwrap()
        );
        // Untuned op: hard error, not a silent fallback.
        assert!(tuned.resolve_policy(ReduceOp::Prod, 65536).is_err());
        // Different topology: hard error on install.
        let other = Communicator::world(&TopologySpec::paper_fig1());
        let err = GridSession::new(&other, presets::paper_grid(), Strategy::Multilevel)
            .with_policy_table(table.clone());
        assert!(err.is_err(), "topology mismatch must not install");
        // Different params: hard error on install.
        let err = GridSession::new(
            &comm,
            presets::paper_grid().with_combine_us_per_byte(123.0),
            Strategy::Multilevel,
        )
        .with_policy_table(table.clone());
        assert!(err.is_err(), "params mismatch must not install");
        // Different strategy: hard error on install.
        let err = GridSession::new(&comm, presets::paper_grid(), Strategy::Unaware)
            .with_policy_table(table);
        assert!(err.is_err(), "strategy mismatch must not install");
    }

    #[test]
    fn autotune_persists_verdicts_through_save_policy_table() {
        let path = std::env::temp_dir()
            .join(format!("gridcollect_autotune_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let comm = Communicator::world(&TopologySpec::paper_experiment());
        let s = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel)
            .with_policy_provider(Box::new(AutoTune::new().with_persist_path(&path)));
        let p1 = s.resolve_policy(ReduceOp::Sum, 65536).unwrap();
        let p2 = s.resolve_policy(ReduceOp::Sum, 4096).unwrap();
        // Every miss rewrote the full table: the file now holds both
        // verdicts under this session's provenance, so a fresh session
        // can install it as its policy file.
        let loaded = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel)
            .with_policy_file(&path)
            .unwrap();
        assert_eq!(loaded.resolve_policy(ReduceOp::Sum, 65536).unwrap(), p1);
        assert_eq!(loaded.resolve_policy(ReduceOp::Sum, 4096).unwrap(), p2);
        // Explicit save of the same provider state is identical.
        let table = s.save_policy_table(&path).unwrap();
        assert_eq!(table.len(), 2);
        assert_eq!(table.best_for(ReduceOp::Sum, 65536), Some(p1));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn composition_tuning_closes_the_session_loop() {
        let comm = Communicator::world(&TopologySpec::paper_experiment());
        let s = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel);
        let (report, table) =
            s.tune_composition(ReduceOp::Sum, &[4096, 65536], tuning::SearchMode::Auto).unwrap();
        assert!(report.n_rows() > 0);
        assert_eq!(table.len(), 2);
        // The tuned table installs and resolves to the tuner's argmin.
        let tuned = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel)
            .with_policy_table(table.clone())
            .unwrap();
        let mode = tuning::SearchMode::Auto;
        let want =
            tuning::tune_allreduce_composition(&s.engine(), ReduceOp::Sum, 65536, mode).unwrap();
        assert_eq!(tuned.resolve_policy(ReduceOp::Sum, 65536).unwrap(), want.best);
        // And the resolved composition actually runs through the session.
        let n = s.comm().size();
        let contributions: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0f32; 16]).collect();
        let out = tuned.allreduce(ReduceOp::Sum, &contributions).unwrap();
        for r in 0..n {
            assert_eq!(out.data[r], vec![n as f32; 16], "rank {r}");
        }
    }

    #[test]
    fn wan_shape_table_resolves_like_bcast_segments() {
        let s = session();
        assert_eq!(s.resolve_wan_shape(4096).unwrap(), None, "default: no verdicts");
        assert!(s.tune_wan_shapes(ReduceOp::Sum, &[4096], &[]).is_err(), "empty candidates");
        let candidates =
            [TreeShape::Flat, TreeShape::Binomial, TreeShape::Chain, TreeShape::Fibonacci(2)];
        let (report, table) =
            s.tune_wan_shapes(ReduceOp::Sum, &[4096, 65536], &candidates).unwrap();
        assert_eq!(report.n_rows(), 2 * candidates.len());
        assert_eq!(table.wan_shape_entries().len(), 2);
        // Install and resolve through the provider, like bcast segments.
        let comm = s.comm().clone();
        let tuned = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel)
            .with_policy_table(table.clone())
            .unwrap();
        let best = table.best_wan_shape_for(65536).unwrap();
        assert_eq!(tuned.resolve_wan_shape(65536).unwrap(), Some(best));
        // The applied level policy carries the winner at the WAN slot.
        let lp = tuned.wan_level_policy(65536).unwrap().unwrap();
        assert_eq!(lp.shape_at(1), best);
        assert_eq!(lp.shape_at(2), s.level_policy().shape_at(2), "deeper levels untouched");
        // The shape table survives the JSON round trip with everything
        // else in place.
        let back = PolicyTable::from_json(&table.to_json()).unwrap();
        assert_eq!(back.wan_shape_entries(), table.wan_shape_entries());
    }

    #[test]
    fn bcast_segment_table_closes_the_bcast_tuning_loop() {
        let s = session();
        assert_eq!(s.resolve_bcast_segments(1 << 16).unwrap(), None, "default: no verdicts");
        assert!(s.tune_bcast_table(0, &[4096], &[]).is_err(), "empty candidate set");
        let (report, table) = s.tune_bcast_table(0, &[1 << 12, 1 << 16], &[1, 2, 4, 8]).unwrap();
        assert_eq!(report.n_rows(), 2);
        assert_eq!(table.bcast_segment_entries().len(), 2);
        // The ghost verdict agrees bitwise with the engine's full-data
        // sweep for the same candidates.
        let data = vec![0.0f32; (1 << 16) / 4];
        let (best, best_us) = s.tune_bcast_segments(0, &data, &[1, 2, 4, 8]).unwrap();
        assert_eq!(table.best_segments_for(1 << 16), Some(best));
        let entry = *table.bcast_segment_entries().iter().find(|e| e.bytes == 1 << 16).unwrap();
        assert_eq!(entry.best_us.to_bits(), best_us.to_bits(), "ghost == full timing");
        // Install and route: the auto path resolves the tuned count and
        // delivers exactly what the explicit call delivers.
        let comm = s.comm().clone();
        let tuned = GridSession::new(&comm, presets::paper_grid(), Strategy::Multilevel)
            .with_policy_table(table)
            .unwrap();
        assert_eq!(tuned.resolve_bcast_segments(1 << 16).unwrap(), Some(best));
        let payload: Vec<f32> = (0..(1 << 16) / 4).map(|i| i as f32).collect();
        let auto = tuned.bcast_segmented_auto(0, &payload).unwrap();
        let explicit = tuned.bcast_segmented(0, &payload, best).unwrap();
        assert_eq!(auto.sim.finish_us, explicit.sim.finish_us);
        assert_eq!(auto.data, explicit.data);
    }
}
