//! Pluggable allreduce-policy resolution: who decides *which* per-level
//! composition a session runs, per `(op, payload size)`, at call time.
//!
//! The [`PolicyProvider`] trait is the session's decision hook. Three
//! providers ship in-tree:
//!
//! - [`Fixed`] — one [`AlgoPolicy`] for everything (the pre-session
//!   behavior, and the default: uniform reduce+bcast);
//! - [`Tuned`] — consult a persisted [`PolicyTable`] (exact size hit,
//!   else nearest tuned size in log-space); how `--policy-file` closes
//!   the tuner → workload loop;
//! - [`AutoTune`] — consult an in-memory table and, on a miss, run the
//!   ghost-probe boundary tuner right there and memoize the verdict
//!   (configurable via [`OnMiss`]).
//!
//! Resolution happens on the session's engine, so an auto-tune miss
//! shares the session's plan cache and scratch arenas: the probes that
//! decide the policy warm the very caches the chosen policy then runs
//! on.

use crate::coordinator::tuning;
use crate::error::{Error, Result};
use crate::netsim::ReduceOp;
use crate::plan::AlgoPolicy;
use crate::session::table::{PolicyEntry, PolicyTable};
use crate::session::GridSession;
use crate::tree::TreeShape;
use std::sync::Mutex;

/// Resolves the allreduce composition for one call. Implementations may
/// consult the session (topology, engine, caches) — [`AutoTune`] runs
/// ghost probes through it. `Send + Sync` so sessions can be shared by
/// the `gridd` service's worker threads (all in-tree providers already
/// were: [`Fixed`] is `Copy`, [`Tuned`] owns its table, [`AutoTune`]
/// locks its verdicts).
pub trait PolicyProvider: Send + Sync {
    /// The policy to run for an allreduce of `bytes` under `op` on this
    /// session's (topology, network, strategy).
    fn resolve(&self, session: &GridSession, op: ReduceOp, bytes: usize) -> Result<AlgoPolicy>;

    /// The tuned segment count for a pipelined broadcast of `bytes`, or
    /// `None` when this provider holds no broadcast verdicts (the
    /// session then falls back to an unsegmented send). Default: no
    /// verdicts — only [`Tuned`] tables carry per-op broadcast entries.
    fn resolve_bcast_segments(
        &self,
        _session: &GridSession,
        _bytes: usize,
    ) -> Result<Option<usize>> {
        Ok(None)
    }

    /// The tuned WAN tree shape for a `bytes`-sized payload, or `None`
    /// when this provider holds no WAN-shape verdicts (the session then
    /// keeps its configured [`crate::tree::LevelPolicy`]). Default: no
    /// verdicts — only [`Tuned`] tables carry per-size shape entries.
    fn resolve_wan_shape(
        &self,
        _session: &GridSession,
        _bytes: usize,
    ) -> Result<Option<TreeShape>> {
        Ok(None)
    }

    /// Snapshot of the allreduce verdicts this provider holds, for
    /// persisting via [`GridSession::save_policy_table`]. Default: none
    /// (a [`Fixed`] provider has nothing worth writing back).
    fn verdict_entries(&self) -> Vec<PolicyEntry> {
        Vec::new()
    }

    /// Display name for logs and reports.
    fn name(&self) -> String;
}

/// Always the same policy — the expert override and the default
/// (uniform reduce+bcast, matching the engine's historical default).
#[derive(Clone, Copy, Debug)]
pub struct Fixed(pub AlgoPolicy);

impl PolicyProvider for Fixed {
    fn resolve(&self, _session: &GridSession, _op: ReduceOp, _bytes: usize) -> Result<AlgoPolicy> {
        Ok(self.0)
    }

    fn name(&self) -> String {
        format!("fixed({})", self.0.name())
    }
}

/// Consult a persisted [`PolicyTable`]. The table's provenance is
/// validated against the session when the provider is installed
/// ([`GridSession::with_policy_table`]); resolution itself is a pure
/// lookup — exact `(op, bytes)` hit, else the nearest tuned size in
/// log-space. An op the table was never tuned for is a hard error (a
/// silent fallback would defeat the point of loading the table).
#[derive(Clone, Debug)]
pub struct Tuned(pub PolicyTable);

impl PolicyProvider for Tuned {
    fn resolve(&self, _session: &GridSession, op: ReduceOp, bytes: usize) -> Result<AlgoPolicy> {
        self.0.best_for(op, bytes).ok_or_else(|| {
            Error::Config(format!(
                "policy table has no entry for op '{}' — retune with \
                 `gridcollect tune-boundary --op {} --save <table.json>`",
                op.name(),
                op.name()
            ))
        })
    }

    fn resolve_bcast_segments(
        &self,
        _session: &GridSession,
        bytes: usize,
    ) -> Result<Option<usize>> {
        Ok(self.0.best_segments_for(bytes))
    }

    fn resolve_wan_shape(
        &self,
        _session: &GridSession,
        bytes: usize,
    ) -> Result<Option<TreeShape>> {
        Ok(self.0.best_wan_shape_for(bytes))
    }

    fn verdict_entries(&self) -> Vec<PolicyEntry> {
        self.0.entries().to_vec()
    }

    fn name(&self) -> String {
        format!("tuned({} entries)", self.0.len())
    }
}

/// What an [`AutoTune`] provider does when `(op, bytes)` has no memoized
/// verdict yet.
#[derive(Clone, Copy, Debug)]
pub enum OnMiss {
    /// Run the ghost-probe boundary tuner for the missing point and
    /// memoize its verdict (the default). First call per point pays one
    /// candidate sweep; every later call is a lookup.
    Tune,
    /// Use a fixed fallback policy without tuning (bounded-latency mode:
    /// nothing is ever probed on the request path).
    Fallback(AlgoPolicy),
}

/// Tune-on-miss provider: an in-memory verdict table that fills itself
/// via [`tuning::tune_allreduce_boundary`] as sizes are first seen.
/// With a persist path installed, every *newly tuned* verdict is also
/// written back to the session's policy file
/// ([`GridSession::save_policy_table`]) — so a workload that warmed the
/// autotuner leaves a `--policy-file`-loadable table behind.
pub struct AutoTune {
    verdicts: Mutex<Vec<PolicyEntry>>,
    on_miss: OnMiss,
    persist_path: Option<String>,
}

impl AutoTune {
    /// Empty table, [`OnMiss::Tune`] on miss.
    pub fn new() -> Self {
        AutoTune { verdicts: Mutex::new(Vec::new()), on_miss: OnMiss::Tune, persist_path: None }
    }

    /// Empty table with an explicit miss behavior.
    pub fn with_on_miss(on_miss: OnMiss) -> Self {
        AutoTune { verdicts: Mutex::new(Vec::new()), on_miss, persist_path: None }
    }

    /// Seed the in-memory table with a saved table's entries (provenance
    /// is the caller's concern — typically `GridSession::with_policy_table`
    /// already validated the file this came from).
    pub fn seeded(table: &PolicyTable, on_miss: OnMiss) -> Self {
        AutoTune { verdicts: Mutex::new(table.entries().to_vec()), on_miss, persist_path: None }
    }

    /// Write every newly tuned verdict back to `path` as a provenance-
    /// stamped policy table (the full verdict set is rewritten on each
    /// miss — the file is always a complete, loadable table).
    pub fn with_persist_path(mut self, path: impl Into<String>) -> Self {
        self.persist_path = Some(path.into());
        self
    }

    /// Snapshot the memoized verdicts (e.g. to persist what a workload
    /// auto-tuned, via [`PolicyTable::record`]).
    pub fn verdicts(&self) -> Vec<PolicyEntry> {
        self.verdicts.lock().unwrap().clone()
    }
}

impl Default for AutoTune {
    fn default() -> Self {
        AutoTune::new()
    }
}

impl PolicyProvider for AutoTune {
    fn resolve(&self, session: &GridSession, op: ReduceOp, bytes: usize) -> Result<AlgoPolicy> {
        if let Some(e) =
            self.verdicts.lock().unwrap().iter().find(|e| e.op == op && e.bytes == bytes)
        {
            return Ok(e.policy);
        }
        match self.on_miss {
            OnMiss::Fallback(policy) => Ok(policy),
            OnMiss::Tune => {
                // Probe outside the lock: the sweep takes engine runs,
                // and a concurrent resolver at worst repeats the work
                // (verdicts are deterministic, so both agree).
                let tuning = tuning::tune_allreduce_boundary(&session.engine(), op, bytes)?;
                let entry = PolicyEntry { op, bytes, policy: tuning.best, best_us: tuning.best_us };
                {
                    let mut verdicts = self.verdicts.lock().unwrap();
                    if !verdicts.iter().any(|e| e.op == op && e.bytes == bytes) {
                        verdicts.push(entry);
                    }
                }
                // Write-back outside the lock (save_policy_table reads
                // the verdicts through `verdict_entries`, which locks).
                if let Some(path) = &self.persist_path {
                    session.save_policy_table(path)?;
                }
                Ok(tuning.best)
            }
        }
    }

    fn verdict_entries(&self) -> Vec<PolicyEntry> {
        self.verdicts.lock().unwrap().clone()
    }

    fn name(&self) -> String {
        let n = self.verdicts.lock().unwrap().len();
        match self.on_miss {
            OnMiss::Tune => format!("autotune({n} memoized)"),
            OnMiss::Fallback(p) => format!("autotune({n} memoized, fallback {})", p.name()),
        }
    }
}
