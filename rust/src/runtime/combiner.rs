//! The PJRT-backed reduction combiner: executes the L1 Pallas combine
//! kernels (AOT-compiled to `combine2_{op}_{n}.hlo.txt`) for the payload
//! arithmetic of simulated `MPI_Reduce` trees.
//!
//! Arbitrary payload lengths are handled by chunking to the artifact's
//! fixed `n` and padding the tail chunk with the operator's identity
//! element. A calibration helper measures effective combine throughput so
//! the simulator's `combine_us_per_byte` can be set from reality.

use crate::error::Result;
use crate::netsim::{Combiner, ReduceOp};
use crate::runtime::pjrt::{Executable, Runtime};
use std::sync::Arc;

/// Chunked, padded PJRT combiner. Implements [`Combiner`] so it can be
/// plugged straight into the simulation engine.
pub struct XlaCombiner {
    n: usize,
    exes: [Arc<Executable>; 4], // indexed by op_index
    /// Scratch is per-call allocated; kept simple because PJRT owns its
    /// own buffers anyway.
    pub calls: std::cell::Cell<u64>,
}

fn op_index(op: ReduceOp) -> usize {
    match op {
        ReduceOp::Sum => 0,
        ReduceOp::Max => 1,
        ReduceOp::Min => 2,
        ReduceOp::Prod => 3,
    }
}

impl XlaCombiner {
    /// Load the four combine artifacts of width `n` from `runtime`.
    pub fn new(runtime: &Runtime, n: usize) -> Result<Self> {
        let load = |op: &str| runtime.load(&format!("combine2_{op}_{n}"));
        Ok(XlaCombiner {
            n,
            exes: [load("sum")?, load("max")?, load("min")?, load("prod")?],
            calls: std::cell::Cell::new(0),
        })
    }

    /// Default artifact width (matches `python/compile/aot.py::COMBINE_N`).
    pub const DEFAULT_N: usize = 16384;

    pub fn open_default(runtime: &Runtime) -> Result<Self> {
        Self::new(runtime, Self::DEFAULT_N)
    }

    pub fn chunk_len(&self) -> usize {
        self.n
    }

    /// Combine one padded chunk through PJRT.
    fn combine_chunk(&self, op: ReduceOp, acc: &[f32], src: &[f32]) -> Vec<f32> {
        debug_assert_eq!(acc.len(), self.n);
        debug_assert_eq!(src.len(), self.n);
        let exe = &self.exes[op_index(op)];
        self.calls.set(self.calls.get() + 1);
        let out = exe
            .run_f32(&[(acc, &[self.n as i64]), (src, &[self.n as i64])])
            .expect("combine artifact execution failed");
        out.into_iter().next().expect("combine artifact returned no output")
    }
}

impl Combiner for XlaCombiner {
    fn combine(&self, op: ReduceOp, acc: &mut [f32], src: &[f32]) {
        assert_eq!(acc.len(), src.len(), "combine length mismatch");
        let id = op.identity();
        let mut off = 0;
        while off < acc.len() {
            let take = (acc.len() - off).min(self.n);
            if take == self.n {
                let out = self.combine_chunk(op, &acc[off..off + take], &src[off..off + take]);
                acc[off..off + take].copy_from_slice(&out);
            } else {
                // Tail chunk: pad with the identity so op(pad, pad) = pad.
                let mut a = vec![id; self.n];
                let mut b = vec![id; self.n];
                a[..take].copy_from_slice(&acc[off..off + take]);
                b[..take].copy_from_slice(&src[off..off + take]);
                let out = self.combine_chunk(op, &a, &b);
                acc[off..off + take].copy_from_slice(&out[..take]);
            }
            off += take;
        }
    }

    fn name(&self) -> &'static str {
        "xla-pjrt"
    }
}

/// Measure effective combine throughput (us per byte) over `iters`
/// full-chunk combines — used to calibrate the simulator's
/// `combine_us_per_byte` from measured reality.
pub fn calibrate_us_per_byte(c: &XlaCombiner, iters: usize) -> f64 {
    let n = c.chunk_len();
    let mut acc = vec![1.0f32; n];
    let src = vec![2.0f32; n];
    // warm-up
    c.combine(ReduceOp::Sum, &mut acc, &src);
    let start = std::time::Instant::now();
    for _ in 0..iters {
        c.combine(ReduceOp::Sum, &mut acc, &src);
    }
    let us = start.elapsed().as_secs_f64() * 1e6;
    us / (iters as f64 * (n * 4) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::NativeCombiner;
    use crate::runtime::artifacts::default_dir;
    use crate::util::rng::Rng;

    fn combiner() -> Option<(Runtime, XlaCombiner)> {
        if cfg!(not(feature = "pjrt")) {
            return None; // stub backend cannot execute artifacts
        }
        let dir = default_dir();
        if !dir.join("manifest.tsv").is_file() {
            return None;
        }
        let rt = Runtime::open(dir).unwrap();
        let c = XlaCombiner::open_default(&rt).unwrap();
        Some((rt, c))
    }

    #[test]
    fn matches_native_on_exact_chunks() {
        let Some((_rt, c)) = combiner() else { return };
        let n = XlaCombiner::DEFAULT_N;
        let mut rng = Rng::new(42);
        for op in ReduceOp::ALL {
            let mut acc: Vec<f32> = (0..n).map(|_| rng.f32_in(0.5, 2.0)).collect();
            let src: Vec<f32> = (0..n).map(|_| rng.f32_in(0.5, 2.0)).collect();
            let mut expect = acc.clone();
            NativeCombiner.combine(op, &mut expect, &src);
            c.combine(op, &mut acc, &src);
            assert_eq!(acc, expect, "{op:?}"); // bitwise: same fp ops
        }
    }

    #[test]
    fn chunking_and_padding_arbitrary_lengths() {
        let Some((_rt, c)) = combiner() else { return };
        let mut rng = Rng::new(7);
        for len in [1usize, 100, 16384, 16385, 40000] {
            for op in [ReduceOp::Sum, ReduceOp::Min] {
                let mut acc: Vec<f32> = (0..len).map(|_| rng.f32_in(-3.0, 3.0)).collect();
                let src: Vec<f32> = (0..len).map(|_| rng.f32_in(-3.0, 3.0)).collect();
                let mut expect = acc.clone();
                NativeCombiner.combine(op, &mut expect, &src);
                c.combine(op, &mut acc, &src);
                assert_eq!(acc, expect, "len={len} {op:?}");
            }
        }
    }

    #[test]
    fn call_counting() {
        let Some((_rt, c)) = combiner() else { return };
        let before = c.calls.get();
        let mut acc = vec![0.0f32; XlaCombiner::DEFAULT_N * 2 + 5];
        let src = acc.clone();
        c.combine(ReduceOp::Sum, &mut acc, &src);
        assert_eq!(c.calls.get() - before, 3, "2 full + 1 padded chunk");
    }
}
