//! PJRT runtime: loads the AOT-compiled artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts`) and executes them from the request
//! path — the combine kernels for `MPI_Reduce` arithmetic and the MLP
//! train/update steps for the end-to-end training example.

pub mod artifacts;
pub mod combiner;
pub mod mlp;
pub mod pjrt;

pub use artifacts::{ArtifactInfo, Manifest};
pub use combiner::{calibrate_us_per_byte, XlaCombiner};
pub use mlp::{MlpDims, MlpRuntime};
pub use pjrt::{Executable, Runtime};
