//! Executors for the MLP training artifacts (`mlp_train_step`,
//! `mlp_sgd_step`) used by the end-to-end data-parallel training example:
//! gradients are computed per simulated worker through PJRT, allreduced
//! through the topology-aware collectives, and applied with the Pallas
//! `axpy` kernel — all from Rust.

use crate::error::{Error, Result};
use crate::runtime::pjrt::{Executable, Runtime};
use crate::util::rng::Rng;
use std::sync::Arc;

/// Dimensions baked into the artifacts (mirrors `model.MLP_SIZES` etc.).
#[derive(Clone, Copy, Debug)]
pub struct MlpDims {
    pub params: usize,
    pub batch: usize,
    pub d_in: usize,
    pub d_h: usize,
    pub d_out: usize,
}

/// The training-step + SGD-step executable pair.
pub struct MlpRuntime {
    train: Arc<Executable>,
    sgd: Arc<Executable>,
    pub dims: MlpDims,
}

impl MlpRuntime {
    pub fn open(runtime: &Runtime) -> Result<Self> {
        let info = runtime.manifest.get("mlp_train_step")?;
        let dims = MlpDims {
            params: info.meta_usize("params")?,
            batch: info.meta_usize("batch")?,
            d_in: info.meta_usize("d_in")?,
            d_h: info.meta_usize("d_h")?,
            d_out: info.meta_usize("d_out")?,
        };
        Ok(MlpRuntime {
            train: runtime.load("mlp_train_step")?,
            sgd: runtime.load("mlp_sgd_step")?,
            dims,
        })
    }

    /// Forward+backward: returns (grads, loss).
    /// `x`: `[batch * d_in]` row-major, `y_onehot`: `[batch * d_out]`.
    pub fn train_step(&self, params: &[f32], x: &[f32], y_onehot: &[f32]) -> Result<(Vec<f32>, f32)> {
        let d = &self.dims;
        if params.len() != d.params || x.len() != d.batch * d.d_in || y_onehot.len() != d.batch * d.d_out
        {
            return Err(Error::Runtime(format!(
                "train_step shape mismatch: params {} (want {}), x {} (want {}), y {} (want {})",
                params.len(),
                d.params,
                x.len(),
                d.batch * d.d_in,
                y_onehot.len(),
                d.batch * d.d_out
            )));
        }
        let out = self.train.run_f32(&[
            (params, &[d.params as i64]),
            (x, &[d.batch as i64, d.d_in as i64]),
            (y_onehot, &[d.batch as i64, d.d_out as i64]),
        ])?;
        if out.len() != 2 {
            return Err(Error::Runtime(format!("train_step returned {} outputs", out.len())));
        }
        let mut it = out.into_iter();
        let grads = it.next().unwrap();
        let loss = it.next().unwrap();
        Ok((grads, loss[0]))
    }

    /// Parameter update via the Pallas axpy kernel: `p - lr * g`.
    pub fn sgd_step(&self, params: &[f32], grads: &[f32], lr: f32) -> Result<Vec<f32>> {
        let d = &self.dims;
        if params.len() != d.params || grads.len() != d.params {
            return Err(Error::Runtime("sgd_step shape mismatch".into()));
        }
        let out = self.sgd.run_f32(&[
            (params, &[d.params as i64]),
            (grads, &[d.params as i64]),
            (&[lr], &[]),
        ])?;
        Ok(out.into_iter().next().ok_or_else(|| Error::Runtime("sgd_step: no output".into()))?)
    }

    /// Deterministic Glorot-style init matching `model.mlp_init`'s scheme
    /// (not bitwise — different RNG — but the same scaling).
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let d = &self.dims;
        let mut rng = Rng::new(seed);
        let mut flat = vec![0.0f32; d.params];
        // Layout per model._unflatten:
        // W1 [d_in, d_h], b1 [d_h], W2 [d_h, d_out], b2 [d_out], padding.
        let hidden = d.d_h;
        let w1_scale = (2.0 / d.d_in as f32).sqrt();
        let w2_scale = (2.0 / hidden as f32).sqrt();
        let mut i = 0;
        for _ in 0..d.d_in * hidden {
            flat[i] = gauss(&mut rng) * w1_scale;
            i += 1;
        }
        i += hidden; // b1 = 0
        for _ in 0..hidden * d.d_out {
            flat[i] = gauss(&mut rng) * w2_scale;
            i += 1;
        }
        flat
    }

    /// Synthetic classification batch (same construction as the Python
    /// tests): label = argmax of a fixed random projection.
    pub fn synth_batch(&self, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let d = &self.dims;
        let mut proj_rng = Rng::new(123);
        let proj: Vec<f32> =
            (0..d.d_in * d.d_out).map(|_| gauss(&mut proj_rng)).collect();
        let mut rng = Rng::new(seed ^ 0xBA7C4);
        let mut x = vec![0.0f32; d.batch * d.d_in];
        for v in x.iter_mut() {
            *v = gauss(&mut rng);
        }
        let mut y = vec![0.0f32; d.batch * d.d_out];
        for b in 0..d.batch {
            let mut best = f32::NEG_INFINITY;
            let mut arg = 0;
            for c in 0..d.d_out {
                let mut dot = 0.0;
                for j in 0..d.d_in {
                    dot += x[b * d.d_in + j] * proj[j * d.d_out + c];
                }
                if dot > best {
                    best = dot;
                    arg = c;
                }
            }
            y[b * d.d_out + arg] = 1.0;
        }
        (x, y)
    }
}

/// Box–Muller standard normal from the deterministic RNG.
fn gauss(rng: &mut Rng) -> f32 {
    let u1 = rng.f64().max(1e-12);
    let u2 = rng.f64();
    ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::default_dir;

    fn mlp() -> Option<(Runtime, MlpRuntime)> {
        if cfg!(not(feature = "pjrt")) {
            return None; // stub backend cannot execute artifacts
        }
        let dir = default_dir();
        if !dir.join("manifest.tsv").is_file() {
            return None;
        }
        let rt = Runtime::open(dir).unwrap();
        let m = MlpRuntime::open(&rt).unwrap();
        Some((rt, m))
    }

    #[test]
    fn dims_from_manifest() {
        let Some((_rt, m)) = mlp() else { return };
        assert_eq!(m.dims.d_in, 64);
        assert_eq!(m.dims.d_out, 10);
        assert_eq!(m.dims.batch, 32);
        assert_eq!(m.dims.params % 1024, 0);
        assert_eq!(m.dims.d_h, 256);
        // padded params cover the unpadded layout
        let unpadded =
            m.dims.d_in * m.dims.d_h + m.dims.d_h + m.dims.d_h * m.dims.d_out + m.dims.d_out;
        assert!(m.dims.params >= unpadded);
    }

    #[test]
    fn train_step_runs_and_loss_reasonable() {
        let Some((_rt, m)) = mlp() else { return };
        let p = m.init_params(0);
        let (x, y) = m.synth_batch(0);
        let (grads, loss) = m.train_step(&p, &x, &y).unwrap();
        assert_eq!(grads.len(), m.dims.params);
        assert!(loss.is_finite());
        assert!((loss - (10.0f32).ln()).abs() < 1.0, "loss {loss} far from ln(10)");
        assert!(grads.iter().any(|&g| g != 0.0));
    }

    #[test]
    fn sgd_matches_manual() {
        let Some((_rt, m)) = mlp() else { return };
        let p = m.init_params(1);
        let (x, y) = m.synth_batch(1);
        let (grads, _) = m.train_step(&p, &x, &y).unwrap();
        let updated = m.sgd_step(&p, &grads, 0.05).unwrap();
        for i in (0..m.dims.params).step_by(997) {
            let want = p[i] - 0.05 * grads[i];
            assert!((updated[i] - want).abs() < 1e-6, "i={i}");
        }
    }

    #[test]
    fn loss_decreases_over_steps() {
        let Some((_rt, m)) = mlp() else { return };
        let mut p = m.init_params(0);
        let mut first = None;
        let mut last = 0.0;
        for step in 0..30 {
            let (x, y) = m.synth_batch(step % 4);
            let (grads, loss) = m.train_step(&p, &x, &y).unwrap();
            p = m.sgd_step(&p, &grads, 0.1).unwrap();
            if first.is_none() {
                first = Some(loss);
            }
            last = loss;
        }
        assert!(last < first.unwrap() * 0.8, "no learning: {first:?} -> {last}");
    }
}
