//! Artifact manifest: discovery and metadata for the AOT-compiled HLO
//! modules produced by `python/compile/aot.py`.
//!
//! Format (`artifacts/manifest.tsv`, tab-separated, `#` comments):
//!
//! ```text
//! name  file  kind  meta(k=v;k=v)  inputs(f32[AxB],...)  outputs(...)
//! ```

use crate::error::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One artifact's manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub meta: HashMap<String, String>,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

impl ArtifactInfo {
    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| Error::Artifact(format!("{}: missing/invalid meta '{key}'", self.name)))
    }
}

/// The parsed manifest plus the artifact directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 6 {
                return Err(Error::Artifact(format!(
                    "manifest line {}: expected 6 columns, got {}",
                    lineno + 1,
                    cols.len()
                )));
            }
            let mut meta = HashMap::new();
            for kv in cols[3].split(';').filter(|s| !s.is_empty()) {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| Error::Artifact(format!("bad meta entry '{kv}'")))?;
                meta.insert(k.to_string(), v.to_string());
            }
            artifacts.push(ArtifactInfo {
                name: cols[0].to_string(),
                file: dir.join(cols[1]),
                kind: cols[2].to_string(),
                meta,
                inputs: cols[4].split(',').map(str::to_string).collect(),
                outputs: cols[5].split(',').map(str::to_string).collect(),
            });
        }
        if artifacts.is_empty() {
            return Err(Error::Artifact(format!("{}: no artifacts listed", path.display())));
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| Error::Artifact(format!("artifact '{name}' not in manifest")))
    }

    /// All artifacts of a given kind.
    pub fn by_kind(&self, kind: &str) -> Vec<&ArtifactInfo> {
        self.artifacts.iter().filter(|a| a.kind == kind).collect()
    }

    /// Verify every listed file exists.
    pub fn check_files(&self) -> Result<()> {
        for a in &self.artifacts {
            if !a.file.is_file() {
                return Err(Error::Artifact(format!(
                    "artifact file missing: {} (run `make artifacts`)",
                    a.file.display()
                )));
            }
        }
        Ok(())
    }
}

/// Default artifact directory: `$GRIDCOLLECT_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("GRIDCOLLECT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        let mut f = std::fs::File::create(dir.join("manifest.tsv")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("gc_manifest_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn parses_well_formed_manifest() {
        let d = tmpdir("ok");
        write_manifest(
            &d,
            "# comment\n\
             c2\tc2.hlo.txt\tcombine2\tn=128;op=sum\tf32[128],f32[128]\tf32[128]\n",
        );
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.get("c2").unwrap();
        assert_eq!(a.kind, "combine2");
        assert_eq!(a.meta_usize("n").unwrap(), 128);
        assert_eq!(a.meta["op"], "sum");
        assert_eq!(a.inputs.len(), 2);
        assert!(m.get("nope").is_err());
        assert_eq!(m.by_kind("combine2").len(), 1);
        // file missing on disk
        assert!(m.check_files().is_err());
    }

    #[test]
    fn rejects_malformed_lines() {
        let d = tmpdir("bad");
        write_manifest(&d, "only\tthree\tcols\n");
        assert!(Manifest::load(&d).is_err());
        write_manifest(&d, "");
        assert!(Manifest::load(&d).is_err());
        write_manifest(&d, "a\tf\tk\tbadmeta\tf32[1]\tf32[1]\n");
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // When `make artifacts` has run, validate the real manifest.
        let dir = default_dir();
        if dir.join("manifest.tsv").is_file() {
            let m = Manifest::load(&dir).unwrap();
            m.check_files().unwrap();
            assert!(m.by_kind("combine2").len() >= 4, "sum/max/min/prod combiners");
            m.get("mlp_train_step").unwrap();
            m.get("mlp_sgd_step").unwrap();
        }
    }
}
