//! PJRT runtime: loads the AOT-compiled HLO-text artifacts and executes
//! them on the CPU PJRT client from the request path.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO **text** is the interchange format —
//! the bundled xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id protos.
//!
//! ## Feature gating
//!
//! The real backend needs the external `xla` crate (a PJRT binding that
//! is **not** vendored in this repository and not on the offline
//! registry). It is therefore gated behind the `pjrt` cargo feature: the
//! default build compiles a stub with the identical public surface whose
//! `load`/`run_f32` return a descriptive `Error::Runtime`, so the whole
//! crate (CLI, engines, simulator, tests) builds and runs everywhere,
//! and only `--xla` code paths degrade. To enable the real backend,
//! vendor the `xla` crate, add it as a dependency, and build with
//! `--features pjrt`.

use crate::error::{Error, Result};
use crate::runtime::artifacts::Manifest;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

#[cfg(feature = "pjrt")]
mod backend {
    use super::*;
    use crate::runtime::artifacts::ArtifactInfo;

    /// A compiled, executable artifact.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl Executable {
        /// Execute with f32 buffers; returns the tuple elements as f32
        /// vectors. `inputs` are (data, dims) pairs; a rank-0 scalar is
        /// `(&[v], &[])`.
        pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, dims) in inputs {
                let lit = xla::Literal::vec1(data);
                let lit = if dims.is_empty() {
                    lit.reshape(&[]).map_err(wrap)?
                } else {
                    lit.reshape(dims).map_err(wrap)?
                };
                literals.push(lit);
            }
            let result = self.exe.execute::<xla::Literal>(&literals).map_err(wrap)?;
            let first = result
                .first()
                .and_then(|r| r.first())
                .ok_or_else(|| Error::Runtime("empty execution result".into()))?;
            let tuple = first.to_literal_sync().map_err(wrap)?;
            // aot.py lowers with return_tuple=True.
            let parts = tuple.to_tuple().map_err(wrap)?;
            let mut out = Vec::with_capacity(parts.len());
            for p in parts {
                out.push(p.to_vec::<f32>().map_err(wrap)?);
            }
            Ok(out)
        }
    }

    fn wrap(e: xla::Error) -> Error {
        Error::Runtime(e.to_string())
    }

    /// PJRT client + compiled-executable cache over an artifact manifest.
    pub struct Runtime {
        client: xla::PjRtClient,
        pub manifest: Manifest,
        cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
    }

    impl Runtime {
        /// Open the artifact directory (validates the manifest and files)
        /// and bring up the CPU PJRT client.
        pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
            let manifest = Manifest::load(dir)?;
            manifest.check_files()?;
            let client = xla::PjRtClient::cpu().map_err(wrap)?;
            Ok(Runtime { client, manifest, cache: Mutex::new(HashMap::new()) })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch from cache) an artifact by manifest name.
        pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
            if let Some(e) = self.cache.lock().unwrap().get(name) {
                return Ok(e.clone());
            }
            let info: &ArtifactInfo = self.manifest.get(name)?;
            let path = info.file.to_string_lossy().to_string();
            let proto = xla::HloModuleProto::from_text_file(&path).map_err(wrap)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(wrap)?;
            let exe = std::sync::Arc::new(Executable { exe, name: name.to_string() });
            self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
            Ok(exe)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    use super::*;

    fn unavailable(what: &str) -> Error {
        Error::Runtime(format!(
            "{what}: PJRT backend not compiled in — vendor the `xla` crate and build with \
             `--features pjrt` (the native combiner and all simulator paths work without it)"
        ))
    }

    /// Stub executable (the `pjrt` feature is disabled).
    pub struct Executable {
        pub name: String,
    }

    impl Executable {
        pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
            Err(unavailable(&format!("execute '{}'", self.name)))
        }
    }

    /// Stub runtime: the manifest still loads and validates (so artifact
    /// tooling works), but compiling/executing artifacts errors.
    pub struct Runtime {
        pub manifest: Manifest,
        #[allow(dead_code)]
        cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
    }

    impl Runtime {
        pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
            let manifest = Manifest::load(dir)?;
            manifest.check_files()?;
            Ok(Runtime { manifest, cache: Mutex::new(HashMap::new()) })
        }

        pub fn platform(&self) -> String {
            "stub (pjrt feature disabled)".to_string()
        }

        pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
            Err(unavailable(&format!("load '{name}'")))
        }
    }
}

pub use backend::{Executable, Runtime};

impl Runtime {
    /// Open the default artifact directory (`$GRIDCOLLECT_ARTIFACTS` or
    /// `./artifacts`).
    pub fn open_default() -> Result<Self> {
        Self::open(crate::runtime::artifacts::default_dir())
    }

    /// Pre-compile every artifact (startup warm-up so the request path
    /// never compiles).
    pub fn warm_up(&self) -> Result<usize> {
        let names: Vec<String> = self.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        for n in &names {
            self.load(n)?;
        }
        Ok(names.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::default_dir;

    fn runtime() -> Option<Runtime> {
        // Skip silently when artifacts have not been built yet (pure
        // `cargo test` before `make artifacts`) or when the pjrt feature
        // is disabled; integration tests in rust/tests/runtime_artifacts.rs
        // require both.
        if cfg!(not(feature = "pjrt")) {
            return None;
        }
        let dir = default_dir();
        if dir.join("manifest.tsv").is_file() {
            Some(Runtime::open(dir).expect("runtime open"))
        } else {
            None
        }
    }

    #[test]
    fn combine2_sum_roundtrip() {
        let Some(rt) = runtime() else { return };
        let exe = rt.load("combine2_sum_16384").unwrap();
        let n = 16384;
        let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let y: Vec<f32> = (0..n).map(|i| 2.0 * i as f32).collect();
        let out = exe.run_f32(&[(&x, &[n as i64]), (&y, &[n as i64])]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), n);
        assert_eq!(out[0][10], 30.0);
        assert_eq!(out[0][n - 1], 3.0 * (n - 1) as f32);
    }

    #[test]
    fn cache_returns_same_executable() {
        let Some(rt) = runtime() else { return };
        let a = rt.load("combine2_sum_16384").unwrap();
        let b = rt.load("combine2_sum_16384").unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn missing_artifact_errors() {
        let Some(rt) = runtime() else { return };
        assert!(rt.load("not_a_real_artifact").is_err());
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn stub_errors_are_descriptive() {
        let exe = Executable { name: "x".into() };
        let err = exe.run_f32(&[]).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
