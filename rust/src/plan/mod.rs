//! Stage 2 of the collective pipeline: **topology → plan → execute**.
//!
//! The paper's §3.2 requires every rank to derive the collective tree
//! deterministically at call time; the seed code took that literally and
//! re-ran tree construction *and* program compilation on every call, even
//! though the result is a pure function of
//! `(communicator, strategy, policy, root, op, segmentation)`. This module
//! makes that function explicit and memoizable:
//!
//! - **topology** (stage 1, unchanged): [`Communicator`] + [`Strategy`] +
//!   [`LevelPolicy`] describe *where* processes sit;
//! - **plan** (this module): a [`CollectivePlan`] is the compiled,
//!   immutable artifact — the built [`Tree`], the compiled simulator
//!   [`Program`], and static [`PlanMeta`] (message counts per separation
//!   level, per-level fan-out) — produced once per [`PlanKey`] and stored
//!   in a [`PlanCache`];
//! - **execute** (stage 3): `netsim::run` is invoked against the cached
//!   plan with per-call initial payloads. Programs are compiled at a
//!   fixed base tag; every `run` gets a fresh mailbox, so cached tags can
//!   be reused verbatim across calls, and *composition* of cached
//!   programs (allreduce = cached reduce ; cached bcast) uses
//!   [`Program::rebase_tags`] instead of recompiling.
//!
//! A warm [`PlanCache`] hit therefore performs **zero tree builds and
//! zero program compiles** (asserted in tests via
//! [`crate::util::counters`]) — the hot path of an iterative workload
//! (e.g. the training loop's per-step allreduce) reduces to payload
//! setup + simulation.

pub mod cache;
pub mod schedule;

pub use cache::PlanCache;
pub use schedule::{Schedule, ScheduleBuilder, Segment};

use crate::collectives::{extended, programs};
use crate::error::Result;
use crate::netsim::{Action, ChannelIndex, Program, ReduceOp, SendPart, ShardMap};
use crate::topology::{Clustering, Rank};
use crate::tree::{LevelPolicy, Strategy, Tree};

/// How `allreduce` is composed from tree phases — selectable per call
/// (both algorithms produce bitwise-identical results; they differ in
/// message structure and pipelining).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AllreduceAlgo {
    /// Reduce to the root, then broadcast back down over the same cached
    /// tree pair — 2 messages per tree edge, the MPICH-G2 composition.
    ReduceBcast,
    /// Reduce-scatter + allgather over one tree: the reduced vector is
    /// chunked per rank; the down-traffic is split into a subtree-chunks
    /// message and a complement message (3 messages per edge, same total
    /// bytes), letting interior nodes forward early (pipelining).
    ReduceScatterAllgather,
}

impl AllreduceAlgo {
    pub const ALL: [AllreduceAlgo; 2] =
        [AllreduceAlgo::ReduceBcast, AllreduceAlgo::ReduceScatterAllgather];

    pub fn name(&self) -> &'static str {
        match self {
            AllreduceAlgo::ReduceBcast => "reduce+bcast",
            AllreduceAlgo::ReduceScatterAllgather => "rs+ag",
        }
    }
}

/// Per-separation-level allreduce composition — the algorithmic analogue
/// of [`LevelPolicy`]'s per-level shape table. A policy participates in
/// [`PlanKey`], so each distinct policy compiles (once) to its own cached
/// plan.
///
/// [`AlgoPolicy::Hybrid`] is the paper-§6 "exploit the network at every
/// level" composition the uniform algorithms cannot express: reduce+bcast
/// message structure across the slow (WAN-side) tree edges — two full-
/// payload messages per edge — while edges below the boundary pipeline
/// their delivery rs+ag style (split subtree/complement messages). All
/// compositions are bitwise-identical in their results (same tree, same
/// combine association); they differ only in message structure.
///
/// ```
/// use gridcollect::plan::{AlgoPolicy, AllreduceAlgo};
/// let p = AlgoPolicy::hybrid(1);
/// // level 1 = WAN: reduce+bcast; deeper levels: rs+ag.
/// assert_eq!(p.algo_at(1), AllreduceAlgo::ReduceBcast);
/// assert_eq!(p.algo_at(3), AllreduceAlgo::ReduceScatterAllgather);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AlgoPolicy {
    /// One composition for every tree edge.
    Uniform(AllreduceAlgo),
    /// Reduce+bcast (full-payload) delivery on edges at separation level
    /// `<= boundary_level`; rs+ag (split, pipelined) delivery on deeper
    /// edges. `hybrid(0)` degrades to uniform rs+ag, `hybrid(>= levels)`
    /// to uniform reduce+bcast.
    Hybrid { boundary_level: usize },
}

impl AlgoPolicy {
    /// The same composition at every level.
    pub fn uniform(algo: AllreduceAlgo) -> Self {
        AlgoPolicy::Uniform(algo)
    }

    /// Reduce+bcast across levels `1..=boundary_level`, rs+ag below.
    pub fn hybrid(boundary_level: usize) -> Self {
        AlgoPolicy::Hybrid { boundary_level }
    }

    /// Which composition handles a tree edge at separation `level`
    /// (level 1 = WAN) — mirrors [`LevelPolicy::shape_at`].
    pub fn algo_at(&self, level: usize) -> AllreduceAlgo {
        debug_assert!(level >= 1);
        match *self {
            AlgoPolicy::Uniform(algo) => algo,
            AlgoPolicy::Hybrid { boundary_level } => {
                if level <= boundary_level {
                    AllreduceAlgo::ReduceBcast
                } else {
                    AllreduceAlgo::ReduceScatterAllgather
                }
            }
        }
    }

    /// Effective boundary for the down-phase compiler: edges at
    /// separation `<= boundary()` carry a single full-map message, deeper
    /// edges the split subtree/complement pair.
    pub fn boundary(&self) -> usize {
        match *self {
            AlgoPolicy::Uniform(AllreduceAlgo::ReduceBcast) => usize::MAX,
            AlgoPolicy::Uniform(AllreduceAlgo::ReduceScatterAllgather) => 0,
            AlgoPolicy::Hybrid { boundary_level } => boundary_level,
        }
    }

    /// Whether calls under this policy move rank-chunked payload maps
    /// (rs+ag convention) rather than a single key-0 vector. Uniform
    /// reduce+bcast is the only single-vector policy.
    pub fn is_chunked(&self) -> bool {
        !matches!(self, AlgoPolicy::Uniform(AllreduceAlgo::ReduceBcast))
    }

    pub fn name(&self) -> String {
        match *self {
            AlgoPolicy::Uniform(algo) => algo.name().to_string(),
            AlgoPolicy::Hybrid { boundary_level } => format!("hybrid(b={boundary_level})"),
        }
    }
}

/// Which collective a plan implements. Carries everything that changes
/// the compiled program (reduction operator, allreduce composition);
/// message segmentation lives in [`PlanKey::segments`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    Bcast,
    Reduce(ReduceOp),
    Barrier,
    Gather,
    Scatter,
    Allreduce(ReduceOp, AlgoPolicy),
    Allgather,
    ReduceScatter(ReduceOp),
    Alltoall,
    /// Segmented (pipelined) broadcast; chunk count = `PlanKey::segments`.
    BcastSegmented,
}

impl OpKind {
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Bcast => "bcast",
            OpKind::Reduce(_) => "reduce",
            OpKind::Barrier => "barrier",
            OpKind::Gather => "gather",
            OpKind::Scatter => "scatter",
            OpKind::Allreduce(..) => "allreduce",
            OpKind::Allgather => "allgather",
            OpKind::ReduceScatter(_) => "reduce_scatter",
            OpKind::Alltoall => "alltoall",
            OpKind::BcastSegmented => "bcast_segmented",
        }
    }

    /// Compile this op's program over `tree` — the single, total dispatch
    /// every path (plan cache cold builds, `OpSpec::compile`) goes
    /// through. `clustering` classifies edges for per-level compositions
    /// (the hybrid allreduce); `segments` is the pipelining chunk count.
    pub fn compile(
        &self,
        clustering: &Clustering,
        tree: &Tree,
        segments: usize,
        tag: u64,
    ) -> Result<Program> {
        match *self {
            OpKind::Bcast => programs::bcast(tree, tag),
            OpKind::Reduce(op) => programs::reduce(tree, op, tag),
            OpKind::Barrier => programs::barrier(tree, tag),
            OpKind::Gather => programs::gather(tree, tag),
            OpKind::Scatter => programs::scatter(tree, tag),
            OpKind::Allreduce(op, policy) => {
                programs::allreduce(tree, clustering, op, policy, tag)
            }
            OpKind::Allgather => extended::allgather(tree, tag),
            OpKind::ReduceScatter(op) => extended::reduce_scatter(tree, op, tag),
            OpKind::Alltoall => extended::alltoall(tree, tag),
            OpKind::BcastSegmented => extended::bcast_segmented(tree, segments.max(1), tag),
        }
    }

    /// Static byte-prediction model for this op (see [`BytesModel`]).
    pub fn bytes_model(&self) -> BytesModel {
        match self {
            OpKind::Bcast
            | OpKind::Reduce(_)
            | OpKind::Allreduce(_, AlgoPolicy::Uniform(AllreduceAlgo::ReduceBcast)) => {
                BytesModel::FullPayloadPerSend
            }
            OpKind::Barrier => BytesModel::Zero,
            _ => BytesModel::Routed,
        }
    }
}

/// Complete cache key for a compiled plan. Two calls with equal keys are
/// guaranteed to need byte-identical programs:
/// [`Communicator::epoch`](crate::topology::Communicator::epoch)
/// pins the process group + clustering, and tree construction is a pure
/// function of the remaining fields (§3.2 determinism).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub comm_epoch: u64,
    pub strategy: Strategy,
    pub policy: LevelPolicy,
    pub root: Rank,
    pub op: OpKind,
    /// Pipelining chunk count (1 = unsegmented). Only `BcastSegmented`
    /// uses values > 1.
    pub segments: usize,
}

/// How a plan's wire bytes relate to the caller's payload size — lets
/// [`PlanMeta::expected_bytes_by_sep`] predict traffic statically where
/// that is well-defined.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BytesModel {
    /// Every message carries the full input payload (bcast, reduce,
    /// allreduce/reduce+bcast).
    FullPayloadPerSend,
    /// Control messages only (barrier).
    Zero,
    /// Per-message bytes depend on segment routing (gather, scatter,
    /// the extended ops, segmented/chunked compositions).
    Routed,
}

/// Static, payload-independent facts about a compiled plan.
#[derive(Clone, Debug)]
pub struct PlanMeta {
    /// Messages the program will put on the wire, by separation level
    /// (index `sep-1`; index 0 = WAN). Exact: `SimResult::msgs_by_sep`
    /// equals this for every execution of the plan.
    pub msgs_by_sep: Vec<u64>,
    /// Tree edges by separation level (the Fig. 4 boundary-crossing
    /// structure: multilevel trees have exactly `#subclusters - 1` edges
    /// per boundary).
    pub tree_edges_by_sep: Vec<usize>,
    /// Largest child count of any tree node (root serialization width).
    pub max_fanout: usize,
    /// Tree height in hops.
    pub tree_height: usize,
    /// Byte-prediction model for this op.
    pub bytes_model: BytesModel,
}

impl PlanMeta {
    /// Exact message counts per separation level for any program's sends.
    fn msgs_by_sep(clustering: &Clustering, program: &Program) -> Vec<u64> {
        let mut msgs = vec![0u64; clustering.n_levels()];
        for (from, list) in program.actions.iter().enumerate() {
            for a in list {
                if let Action::Send { to, .. } = a {
                    msgs[clustering.sep(from, *to) - 1] += 1;
                }
            }
        }
        msgs
    }

    /// Metadata for an ad-hoc (tree-less) program — e.g. a schedule's
    /// ack-barrier segment. Message counts are exact; tree facts are
    /// zero; control-only programs get the [`BytesModel::Zero`] model so
    /// byte predictions stay available, anything else is `Routed`.
    pub fn of_program(clustering: &Clustering, program: &Program) -> PlanMeta {
        let msgs_by_sep = Self::msgs_by_sep(clustering, program);
        let control_only = program.actions.iter().flatten().all(|a| {
            !matches!(a, Action::Send { part, .. } if *part != SendPart::Empty)
        });
        PlanMeta {
            msgs_by_sep,
            tree_edges_by_sep: vec![0; clustering.n_levels()],
            max_fanout: 0,
            tree_height: 0,
            bytes_model: if control_only { BytesModel::Zero } else { BytesModel::Routed },
        }
    }

    fn compute(clustering: &Clustering, tree: &Tree, program: &Program, op: OpKind) -> PlanMeta {
        let n_levels = clustering.n_levels();
        let msgs_by_sep = Self::msgs_by_sep(clustering, program);
        let mut tree_edges_by_sep = vec![0usize; n_levels];
        for (p, c) in tree.edges() {
            tree_edges_by_sep[clustering.sep(p, c) - 1] += 1;
        }
        let max_fanout = (0..tree.capacity())
            .filter(|&r| tree.contains(r))
            .map(|r| tree.children(r).len())
            .max()
            .unwrap_or(0);
        let bytes_model = op.bytes_model();
        PlanMeta {
            msgs_by_sep,
            tree_edges_by_sep,
            max_fanout,
            tree_height: tree.height(),
            bytes_model,
        }
    }

    /// Static WAN message count — defined to agree with
    /// `SimResult::wan_messages()` for every execution of the plan.
    pub fn wan_messages(&self) -> u64 {
        self.msgs_by_sep.first().copied().unwrap_or(0)
    }

    /// Total messages across all levels.
    pub fn total_messages(&self) -> u64 {
        self.msgs_by_sep.iter().sum()
    }

    /// Predicted bytes per separation level for a call whose full input
    /// payload is `payload_bytes`. `None` when the op's per-message bytes
    /// are routing-dependent ([`BytesModel::Routed`]).
    pub fn expected_bytes_by_sep(&self, payload_bytes: usize) -> Option<Vec<u64>> {
        match self.bytes_model {
            BytesModel::FullPayloadPerSend => {
                Some(self.msgs_by_sep.iter().map(|&m| m * payload_bytes as u64).collect())
            }
            BytesModel::Zero => Some(vec![0; self.msgs_by_sep.len()]),
            BytesModel::Routed => None,
        }
    }
}

/// A compiled, immutable collective plan: the stage-2 artifact.
///
/// The program is compiled at a fixed base tag (every `netsim::run` gets
/// an isolated mailbox, so identical tags across calls never collide);
/// callers composing several plans into one run must rebase —
/// see [`Program::rebase_tags`].
#[derive(Clone, Debug)]
pub struct CollectivePlan {
    pub key: PlanKey,
    pub tree: Tree,
    pub program: Program,
    pub meta: PlanMeta,
    /// Dense channel resolution of `program`, precomputed at build time
    /// so warm executions (`CollectiveEngine::run_sim` /
    /// `simulate_timing`) index a flat mailbox instead of hashing
    /// `(from, to, tag)` per message.
    pub channels: ChannelIndex,
    /// Cluster partition of `channels`, precomputed like the index so the
    /// sharded engine ([`crate::netsim::ExecMode::Sharded`]) routes warm
    /// executions without rebuilding the rank/channel ownership tables.
    pub shards: ShardMap,
}

impl CollectivePlan {
    /// Approximate resident size of this plan, used as the eviction
    /// weight for capacity-bounded [`PlanCache`]s. Dominated by the
    /// per-rank action lists and any scatter rank-lists they carry; the
    /// tree and metadata vectors contribute their element storage.
    pub fn footprint_bytes(&self) -> usize {
        let mut bytes = std::mem::size_of::<CollectivePlan>();
        for list in &self.program.actions {
            bytes += std::mem::size_of::<Vec<Action>>();
            bytes += list.len() * std::mem::size_of::<Action>();
            for a in list {
                match a {
                    Action::Send { part: SendPart::Ranks(rs), .. } => {
                        bytes += rs.len() * std::mem::size_of::<Rank>();
                    }
                    Action::Send { part: SendPart::Ranges(rs), .. } => {
                        bytes += rs.len() * std::mem::size_of::<(Rank, Rank)>();
                    }
                    _ => {}
                }
            }
        }
        bytes += self.tree.capacity() * 2 * std::mem::size_of::<usize>();
        bytes += self.meta.msgs_by_sep.len() * std::mem::size_of::<u64>();
        bytes += self.meta.tree_edges_by_sep.len() * std::mem::size_of::<usize>();
        bytes += self.channels.approx_bytes();
        bytes += self.shards.approx_bytes();
        bytes
    }
}

/// Base tag plans are compiled at. Arbitrary but fixed: documented so
/// composition deltas are predictable.
pub const PLAN_BASE_TAG: u64 = 1;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::netsim::{NativeCombiner, SimConfig};
    use crate::topology::{Communicator, TopologySpec};

    fn key(comm: &Communicator, op: OpKind, root: Rank) -> PlanKey {
        PlanKey {
            comm_epoch: comm.epoch(),
            strategy: Strategy::Multilevel,
            policy: LevelPolicy::paper(),
            root,
            op,
            segments: 1,
        }
    }

    #[test]
    fn meta_predicts_simulated_message_and_byte_counts() {
        let comm = Communicator::world(&TopologySpec::paper_experiment());
        let cache = PlanCache::new();
        let plan = cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 0)).unwrap();
        // Fig. 4 structure: one WAN edge, one LAN edge.
        assert_eq!(plan.meta.wan_messages(), 1);
        assert_eq!(plan.meta.tree_edges_by_sep[0], 1);
        assert_eq!(plan.meta.total_messages(), comm.size() as u64 - 1);

        let data = vec![1.0f32; 256];
        let mut init = vec![crate::netsim::Payload::empty(); comm.size()];
        init[0] = crate::netsim::Payload::single(0, data.clone());
        let cfg = SimConfig::new(presets::paper_grid());
        let sim = crate::netsim::run(
            comm.clustering(),
            &plan.program,
            init,
            &cfg,
            &NativeCombiner,
        )
        .unwrap();
        assert_eq!(sim.msgs_by_sep, plan.meta.msgs_by_sep);
        assert_eq!(
            sim.bytes_by_sep,
            plan.meta.expected_bytes_by_sep(data.len() * 4).unwrap()
        );
        assert_eq!(sim.wan_messages(), plan.meta.wan_messages());
    }

    #[test]
    fn meta_models_match_ops() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        let barrier = cache.get_or_build(&comm, key(&comm, OpKind::Barrier, 0)).unwrap();
        assert_eq!(barrier.meta.bytes_model, BytesModel::Zero);
        assert_eq!(
            barrier.meta.expected_bytes_by_sep(4096).unwrap().iter().sum::<u64>(),
            0
        );
        let scatter = cache.get_or_build(&comm, key(&comm, OpKind::Scatter, 0)).unwrap();
        assert_eq!(scatter.meta.bytes_model, BytesModel::Routed);
        assert!(scatter.meta.expected_bytes_by_sep(4096).is_none());
        let ar = cache
            .get_or_build(
                &comm,
                key(
                    &comm,
                    OpKind::Allreduce(
                        ReduceOp::Sum,
                        AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast),
                    ),
                    0,
                ),
            )
            .unwrap();
        // reduce up + bcast down: every tree edge carries two messages.
        assert_eq!(ar.meta.total_messages(), 2 * (comm.size() as u64 - 1));
        assert_eq!(ar.meta.wan_messages(), 2);
    }

    #[test]
    fn algo_policy_levels_and_boundaries() {
        let rb = AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast);
        let rsag = AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather);
        for l in 1..=4 {
            assert_eq!(rb.algo_at(l), AllreduceAlgo::ReduceBcast);
            assert_eq!(rsag.algo_at(l), AllreduceAlgo::ReduceScatterAllgather);
        }
        let h = AlgoPolicy::hybrid(2);
        assert_eq!(h.algo_at(1), AllreduceAlgo::ReduceBcast);
        assert_eq!(h.algo_at(2), AllreduceAlgo::ReduceBcast);
        assert_eq!(h.algo_at(3), AllreduceAlgo::ReduceScatterAllgather);
        assert_eq!(h.boundary(), 2);
        assert_eq!(rb.boundary(), usize::MAX);
        assert_eq!(rsag.boundary(), 0);
        assert!(!rb.is_chunked());
        assert!(rsag.is_chunked());
        assert!(h.is_chunked());
        assert_eq!(h.name(), "hybrid(b=2)");
        assert_eq!(rb.name(), "reduce+bcast");
    }

    #[test]
    fn footprint_tracks_program_size() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        let bc = cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 0)).unwrap();
        let ar = cache
            .get_or_build(
                &comm,
                key(
                    &comm,
                    OpKind::Allreduce(
                        ReduceOp::Sum,
                        AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast),
                    ),
                    0,
                ),
            )
            .unwrap();
        assert!(bc.footprint_bytes() > 0);
        assert!(
            ar.footprint_bytes() > bc.footprint_bytes(),
            "allreduce carries strictly more actions than one of its phases"
        );
    }
}
