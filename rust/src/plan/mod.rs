//! Stage 2 of the collective pipeline: **topology → plan → execute**.
//!
//! The paper's §3.2 requires every rank to derive the collective tree
//! deterministically at call time; the seed code took that literally and
//! re-ran tree construction *and* program compilation on every call, even
//! though the result is a pure function of
//! `(communicator, strategy, policy, root, op, segmentation)`. This module
//! makes that function explicit and memoizable:
//!
//! - **topology** (stage 1, unchanged): [`Communicator`] + [`Strategy`] +
//!   [`LevelPolicy`] describe *where* processes sit;
//! - **plan** (this module): a [`CollectivePlan`] is the compiled,
//!   immutable artifact — the built [`Tree`], the compiled simulator
//!   [`Program`], and static [`PlanMeta`] (message counts per separation
//!   level, per-level fan-out) — produced once per [`PlanKey`] and stored
//!   in a [`PlanCache`];
//! - **execute** (stage 3): `netsim::run` is invoked against the cached
//!   plan with per-call initial payloads. Programs are compiled at a
//!   fixed base tag; every `run` gets a fresh mailbox, so cached tags can
//!   be reused verbatim across calls, and *composition* of cached
//!   programs (allreduce = cached reduce ; cached bcast) uses
//!   [`Program::rebase_tags`] instead of recompiling.
//!
//! A warm [`PlanCache`] hit therefore performs **zero tree builds and
//! zero program compiles** (asserted in tests via
//! [`crate::util::counters`]) — the hot path of an iterative workload
//! (e.g. the training loop's per-step allreduce) reduces to payload
//! setup + simulation.

pub mod cache;
pub mod schedule;

pub use cache::PlanCache;
pub use schedule::{Schedule, ScheduleBuilder, Segment};

use crate::collectives::{extended, programs};
use crate::error::{Error, Result};
use crate::netsim::{Action, ChannelIndex, Program, ReduceOp, SendPart, ShardMap};
use crate::topology::{Clustering, Rank};
use crate::tree::{LevelPolicy, Strategy, Tree};

/// How `allreduce` is composed from tree phases — selectable per call
/// (both algorithms produce bitwise-identical results; they differ in
/// message structure and pipelining).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AllreduceAlgo {
    /// Reduce to the root, then broadcast back down over the same cached
    /// tree pair — 2 messages per tree edge, the MPICH-G2 composition.
    ReduceBcast,
    /// Reduce-scatter + allgather over one tree: the reduced vector is
    /// chunked per rank; the down-traffic is split into a subtree-chunks
    /// message and a complement message (3 messages per edge, same total
    /// bytes), letting interior nodes forward early (pipelining).
    ReduceScatterAllgather,
}

impl AllreduceAlgo {
    pub const ALL: [AllreduceAlgo; 2] =
        [AllreduceAlgo::ReduceBcast, AllreduceAlgo::ReduceScatterAllgather];

    pub fn name(&self) -> &'static str {
        match self {
            AllreduceAlgo::ReduceBcast => "reduce+bcast",
            AllreduceAlgo::ReduceScatterAllgather => "rs+ag",
        }
    }
}

/// Maximum number of separation levels an [`AlgoPolicy`] stores
/// explicitly. Deeper levels clamp to the last slot, mirroring
/// [`LevelPolicy::shape_at`]'s clamp-to-last rule; no grid clustering in
/// this repo exceeds 4 levels, so 8 is pure headroom.
pub const MAX_COMP_LEVELS: usize = 8;

/// Upper bound for [`AlgoPolicy::with_chunks`].
pub const MAX_CHUNKS: usize = 32;

/// One entry of the per-level algorithm vocabulary: how allreduce
/// traffic crossing a tree edge at one separation level is structured.
///
/// [`LevelAlgo::ReduceBcast`], [`LevelAlgo::Binomial`] and
/// [`LevelAlgo::Flat`] are *full-structure* algorithms — one full-payload
/// message per edge and phase. The tree *shape* itself is
/// [`LevelPolicy`]'s axis, so the latter two are named aliases kept for
/// vocabulary parity with astra-sim-style composition strings; they
/// compile identically to `ReduceBcast`. [`LevelAlgo::RsAgRing`] splits
/// delivery into subtree/complement interval messages (rs+ag ring);
/// [`LevelAlgo::Halving`] delivers in recursive-halving pieces
/// (Bine/Swing-style distance halving: at least two pipelined pieces per
/// edge).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LevelAlgo {
    /// Single full-payload message per edge (the MPICH-G2 composition).
    ReduceBcast,
    /// Split subtree/complement interval delivery (rs+ag ring style).
    RsAgRing,
    /// Recursive-halving piece delivery (Bine/Swing distance halving).
    Halving,
    /// Full-structure alias of `ReduceBcast` (binomial is a tree shape).
    Binomial,
    /// Full-structure alias of `ReduceBcast` (flat/direct delivery).
    Flat,
}

impl LevelAlgo {
    /// Every vocabulary entry.
    pub const ALL: [LevelAlgo; 5] = [
        LevelAlgo::ReduceBcast,
        LevelAlgo::RsAgRing,
        LevelAlgo::Halving,
        LevelAlgo::Binomial,
        LevelAlgo::Flat,
    ];

    /// The structurally distinct entries — the tuner's per-level search
    /// space. `Binomial`/`Flat` compile identically to `ReduceBcast`
    /// (shape is [`LevelPolicy`]'s axis), so probing them would
    /// re-measure the same program.
    pub const STRUCTURAL: [LevelAlgo; 3] =
        [LevelAlgo::ReduceBcast, LevelAlgo::RsAgRing, LevelAlgo::Halving];

    pub fn name(&self) -> &'static str {
        match self {
            LevelAlgo::ReduceBcast => "rb",
            LevelAlgo::RsAgRing => "ring",
            LevelAlgo::Halving => "halving",
            LevelAlgo::Binomial => "binomial",
            LevelAlgo::Flat => "flat",
        }
    }

    /// Parse a vocabulary token (CLI `--algo comp:...`, policy-table
    /// entries). Accepts the canonical names plus the aliases the
    /// literature uses.
    pub fn from_name(s: &str) -> Option<LevelAlgo> {
        match s {
            "rb" | "reduce+bcast" | "reduce-bcast" => Some(LevelAlgo::ReduceBcast),
            "ring" | "rsag" | "rs+ag" => Some(LevelAlgo::RsAgRing),
            "halving" | "bine" | "swing" | "distance-halving" => Some(LevelAlgo::Halving),
            "binomial" => Some(LevelAlgo::Binomial),
            "flat" | "direct" => Some(LevelAlgo::Flat),
            _ => None,
        }
    }

    /// Full-structure algorithms deliver one full-payload message per
    /// edge and phase (no interval splitting).
    pub fn is_full_structure(&self) -> bool {
        matches!(self, LevelAlgo::ReduceBcast | LevelAlgo::Binomial | LevelAlgo::Flat)
    }
}

/// Order in which a pipelined edge's chunk pieces are scheduled.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ChunkOrder {
    /// Pieces go out in index order (chunk 0 first), child by child.
    Fifo,
    /// Shortest piece first (SCF): fewest chunk keys first, index order
    /// breaking ties — small pieces clear the wire before long ones.
    ShortestFirst,
    /// Least-loaded interleave (LL): each piece is sent in index order
    /// per child, but a parent with several piece children alternates
    /// between them, always serving the child that has received the
    /// fewest chunk keys so far (ties by child order) — no sibling
    /// starves behind another's full piece train.
    LeastLoaded,
}

impl ChunkOrder {
    pub const ALL: [ChunkOrder; 3] =
        [ChunkOrder::Fifo, ChunkOrder::ShortestFirst, ChunkOrder::LeastLoaded];

    pub fn name(&self) -> &'static str {
        match self {
            ChunkOrder::Fifo => "fifo",
            ChunkOrder::ShortestFirst => "scf",
            ChunkOrder::LeastLoaded => "ll",
        }
    }

    pub fn from_name(s: &str) -> Option<ChunkOrder> {
        match s {
            "fifo" => Some(ChunkOrder::Fifo),
            "scf" | "shortest" | "shortest-first" => Some(ChunkOrder::ShortestFirst),
            "ll" | "least-loaded" | "least_loaded" => Some(ChunkOrder::LeastLoaded),
            _ => None,
        }
    }
}

/// Per-separation-level allreduce composition — the algorithmic analogue
/// of [`LevelPolicy`]'s per-level shape table. A policy participates in
/// [`PlanKey`], so each distinct policy compiles (once) to its own cached
/// plan, and ghost probing, sharded execution, and schedule fusion treat
/// it like any other plan input.
///
/// A policy is a dense per-level assignment: slot `i` (0-based) holds the
/// [`LevelAlgo`] for separation level `i + 1` (level 1 = WAN), with
/// levels beyond [`MAX_COMP_LEVELS`] clamping to the last slot — the same
/// clamp rule as [`LevelPolicy::shape_at`]. On top of the structural
/// assignment sits a chunked-pipelining knob: [`AlgoPolicy::with_chunks`]
/// splits full-structure deliveries into `k` interval pieces per edge,
/// scheduled FIFO, shortest-first, or least-loaded
/// ([`AlgoPolicy::with_chunk_order`]).
///
/// The legacy two-regime policies survive as constructors over this
/// type: [`AlgoPolicy::uniform`] and [`AlgoPolicy::hybrid`] build the
/// corresponding compositions, compare equal to them, and keep their
/// historical `name()`s, so tuned tables and call sites keep meaning.
/// All compositions are bitwise-identical in their results (same tree,
/// same combine association); they differ only in message structure.
///
/// ```
/// use gridcollect::plan::{AlgoPolicy, AllreduceAlgo, LevelAlgo};
/// let p = AlgoPolicy::hybrid(1);
/// // level 1 = WAN: reduce+bcast; deeper levels: rs+ag.
/// assert_eq!(p.algo_at(1), AllreduceAlgo::ReduceBcast);
/// assert_eq!(p.algo_at(3), AllreduceAlgo::ReduceScatterAllgather);
/// // Arbitrary per-level compositions with chunked pipelining:
/// let c = AlgoPolicy::composition(&[LevelAlgo::ReduceBcast, LevelAlgo::Halving])
///     .unwrap()
///     .with_chunks(4);
/// assert_eq!(c.level_algo_at(1), LevelAlgo::ReduceBcast);
/// assert_eq!(c.level_algo_at(5), LevelAlgo::Halving); // clamps to last
/// assert_eq!(c.chunks_per_level(), 4);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AlgoPolicy {
    /// Slot `i` = separation level `i + 1`; deeper levels clamp to the
    /// last slot.
    algos: [LevelAlgo; MAX_COMP_LEVELS],
    /// Pieces a full-structure delivery at separation level `i + 1` is
    /// split into (1 = off); deeper levels clamp to the last slot, like
    /// `algos`. [`AlgoPolicy::with_chunks`] sets every slot (the uniform
    /// knob); [`AlgoPolicy::with_chunk_profile`] sets them per level.
    chunks: [u8; MAX_COMP_LEVELS],
    /// Scheduling order for the pieces (canonically FIFO when no level
    /// pipelines, so equal-behavior policies compare equal).
    order: ChunkOrder,
}

impl AlgoPolicy {
    /// The same composition at every level.
    pub fn uniform(algo: AllreduceAlgo) -> Self {
        match algo {
            AllreduceAlgo::ReduceBcast => Self::uniform_level(LevelAlgo::ReduceBcast),
            AllreduceAlgo::ReduceScatterAllgather => Self::uniform_level(LevelAlgo::RsAgRing),
        }
    }

    /// The same vocabulary entry at every level.
    pub fn uniform_level(algo: LevelAlgo) -> Self {
        AlgoPolicy {
            algos: [algo; MAX_COMP_LEVELS],
            chunks: [1; MAX_COMP_LEVELS],
            order: ChunkOrder::Fifo,
        }
    }

    /// Reduce+bcast across levels `1..=boundary_level`, rs+ag below —
    /// the historical two-regime hybrid. `hybrid(0)` is (and compares
    /// equal to) uniform rs+ag; `hybrid(>= MAX_COMP_LEVELS)` uniform
    /// reduce+bcast.
    pub fn hybrid(boundary_level: usize) -> Self {
        let mut algos = [LevelAlgo::RsAgRing; MAX_COMP_LEVELS];
        for slot in algos.iter_mut().take(boundary_level.min(MAX_COMP_LEVELS)) {
            *slot = LevelAlgo::ReduceBcast;
        }
        AlgoPolicy { algos, chunks: [1; MAX_COMP_LEVELS], order: ChunkOrder::Fifo }
    }

    /// An explicit per-level assignment: `algos[i]` handles separation
    /// level `i + 1`; levels beyond the slice repeat its last entry.
    /// Errors on an empty slice or more than [`MAX_COMP_LEVELS`]
    /// entries.
    pub fn composition(algos: &[LevelAlgo]) -> Result<Self> {
        if algos.is_empty() {
            return Err(Error::Config("composition needs at least one level algorithm".into()));
        }
        if algos.len() > MAX_COMP_LEVELS {
            return Err(Error::Config(format!(
                "composition has {} levels; max is {MAX_COMP_LEVELS}",
                algos.len()
            )));
        }
        let mut slots = [*algos.last().expect("non-empty"); MAX_COMP_LEVELS];
        slots[..algos.len()].copy_from_slice(algos);
        Ok(AlgoPolicy { algos: slots, chunks: [1; MAX_COMP_LEVELS], order: ChunkOrder::Fifo })
    }

    /// Split every full-structure delivery into `chunks` pipelined
    /// interval pieces per edge (clamped to `1..=MAX_CHUNKS`), at every
    /// separation level uniformly. `1` switches pipelining off; the
    /// chunk order canonicalizes to FIFO then, so behaviorally identical
    /// policies compare (and cache) equal.
    pub fn with_chunks(self, chunks: usize) -> Self {
        let k = chunks.clamp(1, MAX_CHUNKS) as u8;
        let order = if k <= 1 { ChunkOrder::Fifo } else { self.order };
        AlgoPolicy { chunks: [k; MAX_COMP_LEVELS], order, ..self }
    }

    /// An explicit **per-level** chunk profile: `profile[i]` pipelines
    /// deliveries at separation level `i + 1` (each entry clamped to
    /// `1..=MAX_CHUNKS`); levels beyond the slice repeat its last entry
    /// — the same fill rule as [`AlgoPolicy::composition`] — and an
    /// empty slice switches pipelining off everywhere. The chunk order
    /// canonicalizes to FIFO when no level pipelines.
    pub fn with_chunk_profile(self, profile: &[usize]) -> Self {
        let mut chunks = [1u8; MAX_COMP_LEVELS];
        if !profile.is_empty() {
            for (i, slot) in chunks.iter_mut().enumerate() {
                *slot = profile[i.min(profile.len() - 1)].clamp(1, MAX_CHUNKS) as u8;
            }
        }
        let order = if chunks.iter().all(|&c| c <= 1) { ChunkOrder::Fifo } else { self.order };
        AlgoPolicy { chunks, order, ..self }
    }

    /// Scheduling order for pipelined pieces. No effect (canonicalized
    /// to FIFO) while `chunks_per_level() <= 1` — set chunks first.
    pub fn with_chunk_order(self, order: ChunkOrder) -> Self {
        let order = if self.chunks_per_level() <= 1 { ChunkOrder::Fifo } else { order };
        AlgoPolicy { order, ..self }
    }

    /// The vocabulary entry handling tree edges at separation `level`
    /// (level 1 = WAN) — mirrors [`LevelPolicy::shape_at`]'s clamp.
    pub fn level_algo_at(&self, level: usize) -> LevelAlgo {
        debug_assert!(level >= 1);
        self.algos[level.saturating_sub(1).min(MAX_COMP_LEVELS - 1)]
    }

    /// Legacy two-regime view of [`AlgoPolicy::level_algo_at`]:
    /// full-structure entries read as reduce+bcast, splitting entries as
    /// rs+ag.
    pub fn algo_at(&self, level: usize) -> AllreduceAlgo {
        if self.level_algo_at(level).is_full_structure() {
            AllreduceAlgo::ReduceBcast
        } else {
            AllreduceAlgo::ReduceScatterAllgather
        }
    }

    /// The explicit per-level assignment with trailing repeats collapsed
    /// (never empty; the last entry repeats for all deeper levels).
    pub fn level_algos(&self) -> &[LevelAlgo] {
        let mut len = MAX_COMP_LEVELS;
        while len > 1 && self.algos[len - 1] == self.algos[len - 2] {
            len -= 1;
        }
        &self.algos[..len]
    }

    /// The largest per-level chunk count (1 = pipelining off
    /// everywhere). Uniform policies — the [`AlgoPolicy::with_chunks`]
    /// knob — read this as *the* chunk count.
    pub fn chunks_per_level(&self) -> usize {
        *self.chunks.iter().max().expect("MAX_COMP_LEVELS > 0") as usize
    }

    /// Pieces a delivery at separation `level` (level 1 = WAN) is
    /// pipelined into — mirrors [`AlgoPolicy::level_algo_at`]'s clamp.
    pub fn chunks_at(&self, level: usize) -> usize {
        debug_assert!(level >= 1);
        self.chunks[level.saturating_sub(1).min(MAX_COMP_LEVELS - 1)] as usize
    }

    /// The explicit per-level chunk counts with trailing repeats
    /// collapsed (never empty; the last entry repeats for all deeper
    /// levels) — the chunk analogue of [`AlgoPolicy::level_algos`].
    pub fn chunk_profile(&self) -> &[u8] {
        let mut len = MAX_COMP_LEVELS;
        while len > 1 && self.chunks[len - 1] == self.chunks[len - 2] {
            len -= 1;
        }
        &self.chunks[..len]
    }

    pub fn chunk_order(&self) -> ChunkOrder {
        self.order
    }

    /// Whether every delivery is a single full-payload message — the
    /// only case where the plain cached reduce;bcast composition and the
    /// [`BytesModel::FullPayloadPerSend`] model apply.
    pub fn is_plain_full(&self) -> bool {
        self.chunks_per_level() <= 1 && self.algos.iter().all(|a| a.is_full_structure())
    }

    /// Effective boundary for the down-phase compiler: the leading run
    /// of full-structure levels (`usize::MAX` when every delivery is a
    /// single full-payload message).
    pub fn boundary(&self) -> usize {
        if self.is_plain_full() {
            usize::MAX
        } else {
            self.algos.iter().take_while(|a| a.is_full_structure()).count()
        }
    }

    /// `Some(b)` iff this is exactly the historical `hybrid(b)` with an
    /// interior boundary: an unchunked ReduceBcast prefix over a
    /// RsAgRing suffix.
    pub fn hybrid_boundary(&self) -> Option<usize> {
        if self.chunks_per_level() > 1 {
            return None;
        }
        let b = self.algos.iter().take_while(|a| **a == LevelAlgo::ReduceBcast).count();
        if b == 0 || b == MAX_COMP_LEVELS {
            return None;
        }
        if self.algos[b..].iter().all(|a| *a == LevelAlgo::RsAgRing) {
            Some(b)
        } else {
            None
        }
    }

    /// Whether calls under this policy move rank-chunked payload maps
    /// (interval convention) rather than a single key-0 vector. Plain
    /// full-structure policies are the only single-vector case.
    pub fn is_chunked(&self) -> bool {
        !self.is_plain_full()
    }

    pub fn name(&self) -> String {
        if self.chunks_per_level() <= 1 {
            if self.algos == [LevelAlgo::ReduceBcast; MAX_COMP_LEVELS] {
                return AllreduceAlgo::ReduceBcast.name().to_string();
            }
            if self.algos == [LevelAlgo::RsAgRing; MAX_COMP_LEVELS] {
                return AllreduceAlgo::ReduceScatterAllgather.name().to_string();
            }
            if let Some(b) = self.hybrid_boundary() {
                return format!("hybrid(b={b})");
            }
        }
        let slots: Vec<&str> = self.level_algos().iter().map(|a| a.name()).collect();
        let mut s = format!("comp:{}", slots.join(","));
        if self.chunks_per_level() > 1 {
            // Uniform profiles collapse to the historical single-count
            // spelling; per-level profiles list one count per level
            // (trailing repeats collapsed, like the algo list).
            let prof: Vec<String> = self.chunk_profile().iter().map(|c| c.to_string()).collect();
            s.push_str(&format!(";chunks={}", prof.join(",")));
            if self.order != ChunkOrder::Fifo {
                s.push_str(&format!(";order={}", self.order.name()));
            }
        }
        s
    }
}

/// Which collective a plan implements. Carries everything that changes
/// the compiled program (reduction operator, allreduce composition);
/// message segmentation lives in [`PlanKey::segments`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    Bcast,
    Reduce(ReduceOp),
    Barrier,
    Gather,
    Scatter,
    Allreduce(ReduceOp, AlgoPolicy),
    Allgather,
    ReduceScatter(ReduceOp),
    Alltoall,
    /// Segmented (pipelined) broadcast; chunk count = `PlanKey::segments`.
    BcastSegmented,
}

impl OpKind {
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Bcast => "bcast",
            OpKind::Reduce(_) => "reduce",
            OpKind::Barrier => "barrier",
            OpKind::Gather => "gather",
            OpKind::Scatter => "scatter",
            OpKind::Allreduce(..) => "allreduce",
            OpKind::Allgather => "allgather",
            OpKind::ReduceScatter(_) => "reduce_scatter",
            OpKind::Alltoall => "alltoall",
            OpKind::BcastSegmented => "bcast_segmented",
        }
    }

    /// Compile this op's program over `tree` — the single, total dispatch
    /// every path (plan cache cold builds, `OpSpec::compile`) goes
    /// through. `clustering` classifies edges for per-level compositions
    /// (the hybrid allreduce); `segments` is the pipelining chunk count.
    pub fn compile(
        &self,
        clustering: &Clustering,
        tree: &Tree,
        segments: usize,
        tag: u64,
    ) -> Result<Program> {
        match *self {
            OpKind::Bcast => programs::bcast(tree, tag),
            OpKind::Reduce(op) => programs::reduce(tree, op, tag),
            OpKind::Barrier => programs::barrier(tree, tag),
            OpKind::Gather => programs::gather(tree, tag),
            OpKind::Scatter => programs::scatter(tree, tag),
            OpKind::Allreduce(op, policy) => {
                programs::allreduce(tree, clustering, op, policy, tag)
            }
            OpKind::Allgather => extended::allgather(tree, tag),
            OpKind::ReduceScatter(op) => extended::reduce_scatter(tree, op, tag),
            OpKind::Alltoall => extended::alltoall(tree, tag),
            OpKind::BcastSegmented => extended::bcast_segmented(tree, segments.max(1), tag),
        }
    }

    /// Static byte-prediction model for this op (see [`BytesModel`]).
    pub fn bytes_model(&self) -> BytesModel {
        match self {
            OpKind::Bcast | OpKind::Reduce(_) => BytesModel::FullPayloadPerSend,
            OpKind::Allreduce(_, policy) if policy.is_plain_full() => {
                BytesModel::FullPayloadPerSend
            }
            OpKind::Barrier => BytesModel::Zero,
            _ => BytesModel::Routed,
        }
    }
}

/// Complete cache key for a compiled plan. Two calls with equal keys are
/// guaranteed to need byte-identical programs:
/// [`Communicator::epoch`](crate::topology::Communicator::epoch)
/// pins the process group + clustering, and tree construction is a pure
/// function of the remaining fields (§3.2 determinism).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub comm_epoch: u64,
    pub strategy: Strategy,
    pub policy: LevelPolicy,
    pub root: Rank,
    pub op: OpKind,
    /// Pipelining chunk count (1 = unsegmented). Only `BcastSegmented`
    /// uses values > 1.
    pub segments: usize,
}

/// How a plan's wire bytes relate to the caller's payload size — lets
/// [`PlanMeta::expected_bytes_by_sep`] predict traffic statically where
/// that is well-defined.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BytesModel {
    /// Every message carries the full input payload (bcast, reduce,
    /// allreduce/reduce+bcast).
    FullPayloadPerSend,
    /// Control messages only (barrier).
    Zero,
    /// Per-message bytes depend on segment routing (gather, scatter,
    /// the extended ops, segmented/chunked compositions).
    Routed,
}

/// Static, payload-independent facts about a compiled plan.
#[derive(Clone, Debug)]
pub struct PlanMeta {
    /// Messages the program will put on the wire, by separation level
    /// (index `sep-1`; index 0 = WAN). Exact: `SimResult::msgs_by_sep`
    /// equals this for every execution of the plan.
    pub msgs_by_sep: Vec<u64>,
    /// Tree edges by separation level (the Fig. 4 boundary-crossing
    /// structure: multilevel trees have exactly `#subclusters - 1` edges
    /// per boundary).
    pub tree_edges_by_sep: Vec<usize>,
    /// Largest child count of any tree node (root serialization width).
    pub max_fanout: usize,
    /// Tree height in hops.
    pub tree_height: usize,
    /// Byte-prediction model for this op.
    pub bytes_model: BytesModel,
}

impl PlanMeta {
    /// Exact message counts per separation level for any program's sends.
    fn msgs_by_sep(clustering: &Clustering, program: &Program) -> Vec<u64> {
        let mut msgs = vec![0u64; clustering.n_levels()];
        for (from, list) in program.actions.iter().enumerate() {
            for a in list {
                if let Action::Send { to, .. } = a {
                    msgs[clustering.sep(from, *to) - 1] += 1;
                }
            }
        }
        msgs
    }

    /// Metadata for an ad-hoc (tree-less) program — e.g. a schedule's
    /// ack-barrier segment. Message counts are exact; tree facts are
    /// zero; control-only programs get the [`BytesModel::Zero`] model so
    /// byte predictions stay available, anything else is `Routed`.
    pub fn of_program(clustering: &Clustering, program: &Program) -> PlanMeta {
        let msgs_by_sep = Self::msgs_by_sep(clustering, program);
        let control_only = program.actions.iter().flatten().all(|a| {
            !matches!(a, Action::Send { part, .. } if *part != SendPart::Empty)
        });
        PlanMeta {
            msgs_by_sep,
            tree_edges_by_sep: vec![0; clustering.n_levels()],
            max_fanout: 0,
            tree_height: 0,
            bytes_model: if control_only { BytesModel::Zero } else { BytesModel::Routed },
        }
    }

    fn compute(clustering: &Clustering, tree: &Tree, program: &Program, op: OpKind) -> PlanMeta {
        let n_levels = clustering.n_levels();
        let msgs_by_sep = Self::msgs_by_sep(clustering, program);
        let mut tree_edges_by_sep = vec![0usize; n_levels];
        for (p, c) in tree.edges() {
            tree_edges_by_sep[clustering.sep(p, c) - 1] += 1;
        }
        let max_fanout = (0..tree.capacity())
            .filter(|&r| tree.contains(r))
            .map(|r| tree.children(r).len())
            .max()
            .unwrap_or(0);
        let bytes_model = op.bytes_model();
        PlanMeta {
            msgs_by_sep,
            tree_edges_by_sep,
            max_fanout,
            tree_height: tree.height(),
            bytes_model,
        }
    }

    /// Static WAN message count — defined to agree with
    /// `SimResult::wan_messages()` for every execution of the plan.
    pub fn wan_messages(&self) -> u64 {
        self.msgs_by_sep.first().copied().unwrap_or(0)
    }

    /// Total messages across all levels.
    pub fn total_messages(&self) -> u64 {
        self.msgs_by_sep.iter().sum()
    }

    /// Predicted bytes per separation level for a call whose full input
    /// payload is `payload_bytes`. `None` when the op's per-message bytes
    /// are routing-dependent ([`BytesModel::Routed`]).
    pub fn expected_bytes_by_sep(&self, payload_bytes: usize) -> Option<Vec<u64>> {
        match self.bytes_model {
            BytesModel::FullPayloadPerSend => {
                Some(self.msgs_by_sep.iter().map(|&m| m * payload_bytes as u64).collect())
            }
            BytesModel::Zero => Some(vec![0; self.msgs_by_sep.len()]),
            BytesModel::Routed => None,
        }
    }
}

/// A compiled, immutable collective plan: the stage-2 artifact.
///
/// The program is compiled at a fixed base tag (every `netsim::run` gets
/// an isolated mailbox, so identical tags across calls never collide);
/// callers composing several plans into one run must rebase —
/// see [`Program::rebase_tags`].
#[derive(Clone, Debug)]
pub struct CollectivePlan {
    pub key: PlanKey,
    pub tree: Tree,
    pub program: Program,
    pub meta: PlanMeta,
    /// Dense channel resolution of `program`, precomputed at build time
    /// so warm executions (`CollectiveEngine::run_sim` /
    /// `simulate_timing`) index a flat mailbox instead of hashing
    /// `(from, to, tag)` per message.
    pub channels: ChannelIndex,
    /// Cluster partition of `channels`, precomputed like the index so the
    /// sharded engine ([`crate::netsim::ExecMode::Sharded`]) routes warm
    /// executions without rebuilding the rank/channel ownership tables.
    pub shards: ShardMap,
}

impl CollectivePlan {
    /// Approximate resident size of this plan, used as the eviction
    /// weight for capacity-bounded [`PlanCache`]s. Dominated by the
    /// per-rank action lists and any scatter rank-lists they carry; the
    /// tree and metadata vectors contribute their element storage.
    pub fn footprint_bytes(&self) -> usize {
        let mut bytes = std::mem::size_of::<CollectivePlan>();
        for list in &self.program.actions {
            bytes += std::mem::size_of::<Vec<Action>>();
            bytes += list.len() * std::mem::size_of::<Action>();
            for a in list {
                match a {
                    Action::Send { part: SendPart::Ranks(rs), .. } => {
                        bytes += rs.len() * std::mem::size_of::<Rank>();
                    }
                    Action::Send { part: SendPart::Ranges(rs), .. } => {
                        bytes += rs.len() * std::mem::size_of::<(Rank, Rank)>();
                    }
                    _ => {}
                }
            }
        }
        bytes += self.tree.capacity() * 2 * std::mem::size_of::<usize>();
        bytes += self.meta.msgs_by_sep.len() * std::mem::size_of::<u64>();
        bytes += self.meta.tree_edges_by_sep.len() * std::mem::size_of::<usize>();
        bytes += self.channels.approx_bytes();
        bytes += self.shards.approx_bytes();
        bytes
    }
}

/// Base tag plans are compiled at. Arbitrary but fixed: documented so
/// composition deltas are predictable.
pub const PLAN_BASE_TAG: u64 = 1;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::netsim::{NativeCombiner, SimConfig};
    use crate::topology::{Communicator, TopologySpec};

    fn key(comm: &Communicator, op: OpKind, root: Rank) -> PlanKey {
        PlanKey {
            comm_epoch: comm.epoch(),
            strategy: Strategy::Multilevel,
            policy: LevelPolicy::paper(),
            root,
            op,
            segments: 1,
        }
    }

    #[test]
    fn meta_predicts_simulated_message_and_byte_counts() {
        let comm = Communicator::world(&TopologySpec::paper_experiment());
        let cache = PlanCache::new();
        let plan = cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 0)).unwrap();
        // Fig. 4 structure: one WAN edge, one LAN edge.
        assert_eq!(plan.meta.wan_messages(), 1);
        assert_eq!(plan.meta.tree_edges_by_sep[0], 1);
        assert_eq!(plan.meta.total_messages(), comm.size() as u64 - 1);

        let data = vec![1.0f32; 256];
        let mut init = vec![crate::netsim::Payload::empty(); comm.size()];
        init[0] = crate::netsim::Payload::single(0, data.clone());
        let cfg = SimConfig::new(presets::paper_grid());
        let sim = crate::netsim::run(
            comm.clustering(),
            &plan.program,
            init,
            &cfg,
            &NativeCombiner,
        )
        .unwrap();
        assert_eq!(sim.msgs_by_sep, plan.meta.msgs_by_sep);
        assert_eq!(
            sim.bytes_by_sep,
            plan.meta.expected_bytes_by_sep(data.len() * 4).unwrap()
        );
        assert_eq!(sim.wan_messages(), plan.meta.wan_messages());
    }

    #[test]
    fn meta_models_match_ops() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        let barrier = cache.get_or_build(&comm, key(&comm, OpKind::Barrier, 0)).unwrap();
        assert_eq!(barrier.meta.bytes_model, BytesModel::Zero);
        assert_eq!(
            barrier.meta.expected_bytes_by_sep(4096).unwrap().iter().sum::<u64>(),
            0
        );
        let scatter = cache.get_or_build(&comm, key(&comm, OpKind::Scatter, 0)).unwrap();
        assert_eq!(scatter.meta.bytes_model, BytesModel::Routed);
        assert!(scatter.meta.expected_bytes_by_sep(4096).is_none());
        let ar = cache
            .get_or_build(
                &comm,
                key(
                    &comm,
                    OpKind::Allreduce(
                        ReduceOp::Sum,
                        AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast),
                    ),
                    0,
                ),
            )
            .unwrap();
        // reduce up + bcast down: every tree edge carries two messages.
        assert_eq!(ar.meta.total_messages(), 2 * (comm.size() as u64 - 1));
        assert_eq!(ar.meta.wan_messages(), 2);
    }

    #[test]
    fn algo_policy_levels_and_boundaries() {
        let rb = AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast);
        let rsag = AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather);
        for l in 1..=4 {
            assert_eq!(rb.algo_at(l), AllreduceAlgo::ReduceBcast);
            assert_eq!(rsag.algo_at(l), AllreduceAlgo::ReduceScatterAllgather);
        }
        let h = AlgoPolicy::hybrid(2);
        assert_eq!(h.algo_at(1), AllreduceAlgo::ReduceBcast);
        assert_eq!(h.algo_at(2), AllreduceAlgo::ReduceBcast);
        assert_eq!(h.algo_at(3), AllreduceAlgo::ReduceScatterAllgather);
        assert_eq!(h.boundary(), 2);
        assert_eq!(rb.boundary(), usize::MAX);
        assert_eq!(rsag.boundary(), 0);
        assert!(!rb.is_chunked());
        assert!(rsag.is_chunked());
        assert!(h.is_chunked());
        assert_eq!(h.name(), "hybrid(b=2)");
        assert_eq!(rb.name(), "reduce+bcast");
    }

    #[test]
    fn compositions_generalize_the_legacy_policies() {
        // Legacy constructors are canonical compositions: extremes
        // compare equal to the uniforms they degrade to.
        assert_eq!(
            AlgoPolicy::hybrid(0),
            AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather)
        );
        assert_eq!(AlgoPolicy::hybrid(99), AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast));
        assert_eq!(
            AlgoPolicy::composition(&[LevelAlgo::ReduceBcast]).unwrap(),
            AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast)
        );
        assert_eq!(
            AlgoPolicy::composition(&[LevelAlgo::ReduceBcast, LevelAlgo::RsAgRing]).unwrap(),
            AlgoPolicy::hybrid(1)
        );
        // hybrid_boundary is the exact inverse of hybrid() on interior b.
        for b in 1..MAX_COMP_LEVELS {
            assert_eq!(AlgoPolicy::hybrid(b).hybrid_boundary(), Some(b));
        }
        assert_eq!(AlgoPolicy::hybrid(0).hybrid_boundary(), None);
        assert_eq!(AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast).hybrid_boundary(), None);

        let comp = AlgoPolicy::composition(&[
            LevelAlgo::ReduceBcast,
            LevelAlgo::Halving,
            LevelAlgo::RsAgRing,
        ])
        .unwrap();
        assert_eq!(comp.level_algo_at(1), LevelAlgo::ReduceBcast);
        assert_eq!(comp.level_algo_at(2), LevelAlgo::Halving);
        // Deeper levels clamp to the last explicit entry.
        assert_eq!(comp.level_algo_at(7), LevelAlgo::RsAgRing);
        assert_eq!(
            comp.level_algos(),
            &[LevelAlgo::ReduceBcast, LevelAlgo::Halving, LevelAlgo::RsAgRing]
        );
        assert_eq!(comp.name(), "comp:rb,halving,ring");
        assert!(comp.is_chunked());
        assert!(!comp.is_plain_full());
        assert_eq!(comp.boundary(), 1);
        assert_eq!(comp.hybrid_boundary(), None);

        // Binomial/Flat are full-structure aliases: plain-full but not
        // the canonical reduce+bcast composition.
        let binom = AlgoPolicy::uniform_level(LevelAlgo::Binomial);
        assert!(binom.is_plain_full());
        assert_eq!(binom.boundary(), usize::MAX);
        assert_eq!(binom.name(), "comp:binomial");

        // Errors: empty and oversized assignments.
        assert!(AlgoPolicy::composition(&[]).is_err());
        assert!(AlgoPolicy::composition(&[LevelAlgo::Flat; MAX_COMP_LEVELS + 1]).is_err());
    }

    #[test]
    fn chunking_knob_canonicalizes_and_names() {
        let rb = AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast);
        let rb4 = rb.with_chunks(4);
        assert_eq!(rb4.chunks_per_level(), 4);
        assert!(rb4.is_chunked());
        assert!(!rb4.is_plain_full());
        assert_eq!(rb4.name(), "comp:rb;chunks=4");
        let scf = rb4.with_chunk_order(ChunkOrder::ShortestFirst);
        assert_eq!(scf.chunk_order(), ChunkOrder::ShortestFirst);
        assert_eq!(scf.name(), "comp:rb;chunks=4;order=scf");
        let ll = rb4.with_chunk_order(ChunkOrder::LeastLoaded);
        assert_eq!(ll.chunk_order(), ChunkOrder::LeastLoaded);
        assert_eq!(ll.name(), "comp:rb;chunks=4;order=ll");
        assert_eq!(ll.with_chunks(1), rb, "LL canonicalizes away without chunks");
        // chunks=1 switches pipelining off and canonicalizes the order,
        // so behaviorally identical policies compare (and cache) equal.
        assert_eq!(scf.with_chunks(1), rb);
        assert_eq!(rb.with_chunk_order(ChunkOrder::ShortestFirst), rb);
        assert_eq!(rb.with_chunks(0), rb);
        assert_eq!(rb.with_chunks(MAX_CHUNKS + 10).chunks_per_level(), MAX_CHUNKS);
        // Vocabulary tokens round-trip.
        for a in LevelAlgo::ALL {
            assert_eq!(LevelAlgo::from_name(a.name()), Some(a));
        }
        for o in ChunkOrder::ALL {
            assert_eq!(ChunkOrder::from_name(o.name()), Some(o));
        }
    }

    #[test]
    fn per_level_chunk_profiles() {
        let rb = AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast);
        // Fill-last: the slice's last entry repeats for deeper levels.
        let p = rb.with_chunk_profile(&[4, 2]);
        assert_eq!(p.chunks_at(1), 4, "level 1 = WAN");
        assert_eq!(p.chunks_at(2), 2);
        assert_eq!(p.chunks_at(7), 2, "deeper levels repeat the last entry");
        assert_eq!(p.chunks_per_level(), 4, "the uniform view reads the max");
        assert_eq!(p.chunk_profile(), &[4, 2]);
        assert_eq!(p.name(), "comp:rb;chunks=4,2");
        assert!(p.is_chunked() && !p.is_plain_full());
        // A uniform profile is exactly the with_chunks knob.
        assert_eq!(rb.with_chunk_profile(&[4]), rb.with_chunks(4));
        assert_eq!(rb.with_chunks(4).chunk_profile(), &[4]);
        // Empty / all-ones profiles switch pipelining off and
        // canonicalize the order away.
        assert_eq!(rb.with_chunks(4).with_chunk_profile(&[]), rb);
        let scf = p.with_chunk_order(ChunkOrder::ShortestFirst);
        assert_eq!(scf.name(), "comp:rb;chunks=4,2;order=scf");
        assert_eq!(scf.with_chunk_profile(&[1, 1]), rb);
        // Entries clamp like the uniform knob.
        let clamped = rb.with_chunk_profile(&[0, MAX_CHUNKS + 9]);
        assert_eq!(clamped.chunk_profile(), &[1, MAX_CHUNKS as u8]);
        // Only the pipelined level pays pieces: chunks=1 at a level is
        // full-structure delivery there.
        assert_eq!(rb.with_chunk_profile(&[2, 1]).chunks_at(3), 1);
    }

    #[test]
    fn footprint_tracks_program_size() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        let bc = cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 0)).unwrap();
        let ar = cache
            .get_or_build(
                &comm,
                key(
                    &comm,
                    OpKind::Allreduce(
                        ReduceOp::Sum,
                        AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast),
                    ),
                    0,
                ),
            )
            .unwrap();
        assert!(bc.footprint_bytes() > 0);
        assert!(
            ar.footprint_bytes() > bc.footprint_bytes(),
            "allreduce carries strictly more actions than one of its phases"
        );
    }
}
