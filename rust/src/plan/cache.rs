//! The memoizing plan store: `(topology, op)` → compiled
//! [`CollectivePlan`], built once, shared thereafter.
//!
//! Thread-safe (`Mutex` + `Arc` values) so one cache can back several
//! engines — e.g. every strategy row of an experiment table, or every
//! step of a training loop. The build path runs *outside* the lock: plan
//! construction may itself consult the cache (the reduce+bcast allreduce
//! composes its two cached phases), and an uncontended rebuild race at
//! worst wastes one build — first insert wins, so `Arc` identity stays
//! stable.

use super::{AllreduceAlgo, CollectivePlan, OpKind, PlanKey, PlanMeta, PLAN_BASE_TAG};
use crate::collectives::{extended, programs};
use crate::error::{Error, Result};
use crate::netsim::Program;
use crate::topology::Communicator;
use crate::tree::{build_strategy_tree, Tree};
use crate::util::counters;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Memoizing store of compiled collective plans.
#[derive(Debug, Default)]
pub struct PlanCache {
    plans: Mutex<HashMap<PlanKey, Arc<CollectivePlan>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    pub fn new() -> Self {
        PlanCache::default()
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        self.plans.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached plan (counters keep running).
    pub fn clear(&self) {
        self.plans.lock().unwrap().clear();
    }

    /// Warm-path lookups served without building, over this cache's
    /// lifetime.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cold-path lookups that had to build a plan.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Fetch the plan for `key`, building (tree + program + meta) only on
    /// the first request. `key.comm_epoch` must match `comm` — plans are
    /// never valid across communicators.
    pub fn get_or_build(
        &self,
        comm: &Communicator,
        key: PlanKey,
    ) -> Result<Arc<CollectivePlan>> {
        if key.comm_epoch != comm.epoch() {
            return Err(Error::Comm(format!(
                "plan key epoch {} does not match communicator epoch {}",
                key.comm_epoch,
                comm.epoch()
            )));
        }
        if key.root >= comm.size() {
            return Err(Error::Comm(format!(
                "root {} out of range for {}-rank communicator",
                key.root,
                comm.size()
            )));
        }
        if let Some(plan) = self.plans.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            counters::count_plan_hit();
            return Ok(plan.clone());
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        counters::count_plan_miss();
        let plan = Arc::new(self.build(comm, key.clone())?);
        let mut plans = self.plans.lock().unwrap();
        // First insert wins so concurrent builders agree on Arc identity.
        Ok(plans.entry(key).or_insert(plan).clone())
    }

    /// Cold path: construct tree, compile program, derive metadata.
    fn build(&self, comm: &Communicator, key: PlanKey) -> Result<CollectivePlan> {
        let tag = PLAN_BASE_TAG;
        let (tree, program) = match key.op {
            OpKind::Allreduce(op, AllreduceAlgo::ReduceBcast) => {
                // Compose the two cached phases instead of recompiling:
                // the reduce and bcast plans share one tree build, and the
                // bcast program is tag-rebased past the reduce's tags.
                let red = self.get_or_build(
                    comm,
                    PlanKey { op: OpKind::Reduce(op), ..key.clone() },
                )?;
                let bc =
                    self.get_or_build(comm, PlanKey { op: OpKind::Bcast, ..key.clone() })?;
                let mut program = red.program.clone();
                program.then(bc.program.rebased(red.program.max_tag() + 1))?;
                program.validate()?;
                (red.tree.clone(), program)
            }
            _ => {
                let tree = build_strategy_tree(comm, key.root, key.strategy, &key.policy)?;
                let program = Self::compile(&tree, &key, tag)?;
                (tree, program)
            }
        };
        let meta = PlanMeta::compute(comm.clustering(), &tree, &program, key.op);
        Ok(CollectivePlan { key, tree, program, meta })
    }

    fn compile(tree: &Tree, key: &PlanKey, tag: u64) -> Result<Program> {
        match key.op {
            OpKind::Bcast => programs::bcast(tree, tag),
            OpKind::Reduce(op) => programs::reduce(tree, op, tag),
            OpKind::Barrier => programs::barrier(tree, tag),
            OpKind::Gather => programs::gather(tree, tag),
            OpKind::Scatter => programs::scatter(tree, tag),
            OpKind::Allreduce(op, AllreduceAlgo::ReduceScatterAllgather) => {
                programs::allreduce_rsag(tree, op, tag)
            }
            OpKind::Allreduce(_, AllreduceAlgo::ReduceBcast) => {
                unreachable!("composed in build()")
            }
            OpKind::Allgather => extended::allgather(tree, tag),
            OpKind::ReduceScatter(op) => extended::reduce_scatter(tree, op, tag),
            OpKind::Alltoall => extended::alltoall(tree, tag),
            OpKind::BcastSegmented => extended::bcast_segmented(tree, key.segments.max(1), tag),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::ReduceOp;
    use crate::topology::TopologySpec;
    use crate::tree::{LevelPolicy, Strategy};

    fn key(comm: &Communicator, op: OpKind, root: usize) -> PlanKey {
        PlanKey {
            comm_epoch: comm.epoch(),
            strategy: Strategy::Multilevel,
            policy: LevelPolicy::paper(),
            root,
            op,
            segments: 1,
        }
    }

    #[test]
    fn warm_hit_builds_nothing() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        let k = key(&comm, OpKind::Bcast, 3);
        let cold = cache.get_or_build(&comm, k.clone()).unwrap();
        let before = counters::snapshot();
        let warm = cache.get_or_build(&comm, k).unwrap();
        let delta = counters::snapshot().since(&before);
        assert!(Arc::ptr_eq(&cold, &warm), "same plan instance");
        // NOTE: other tests run in this process; these counters are only
        // meaningful because a hit takes the early-return path — but the
        // Arc identity plus cache hit count pin the behavior:
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert!(delta.plan_cache_hits >= 1);
    }

    #[test]
    fn distinct_keys_build_distinct_plans() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 0)).unwrap();
        cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 1)).unwrap();
        cache.get_or_build(&comm, key(&comm, OpKind::Reduce(ReduceOp::Sum), 0)).unwrap();
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.misses(), 3);
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn allreduce_rb_reuses_cached_phases() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        // Pre-warm the two phases.
        cache.get_or_build(&comm, key(&comm, OpKind::Reduce(ReduceOp::Sum), 0)).unwrap();
        cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 0)).unwrap();
        let before = counters::snapshot();
        let ar = cache
            .get_or_build(
                &comm,
                key(&comm, OpKind::Allreduce(ReduceOp::Sum, AllreduceAlgo::ReduceBcast), 0),
            )
            .unwrap();
        let delta = counters::snapshot().since(&before);
        // Composition is rebase + concatenation: no new tree build and no
        // new compile happen *in this thread's* build. (Parallel tests can
        // inflate global counters, so assert via cache-local stats too.)
        assert_eq!(cache.misses(), 3, "allreduce itself was the only new miss");
        assert_eq!(cache.hits(), 2, "both phases served warm");
        assert!(delta.plan_cache_misses >= 1);
        // Tags of the two phases must not collide inside one run.
        ar.program.validate().unwrap();
    }

    #[test]
    fn epoch_mismatch_rejected() {
        let a = Communicator::world(&TopologySpec::paper_fig1());
        let b = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        let k = key(&a, OpKind::Bcast, 0);
        assert!(cache.get_or_build(&b, k).is_err());
    }

    #[test]
    fn out_of_range_root_rejected() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        assert!(cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 99)).is_err());
    }
}
