//! The memoizing plan store: `(topology, op)` → compiled
//! [`CollectivePlan`], built once, shared thereafter.
//!
//! Thread-safe (`Mutex` + `Arc` values) so one cache can back several
//! engines — e.g. every strategy row of an experiment table, or every
//! step of a training loop. The build path runs *outside* the lock: plan
//! construction may itself consult the cache (the reduce+bcast allreduce
//! composes its two cached phases), and an uncontended rebuild race at
//! worst wastes one build — first insert wins, so `Arc` identity stays
//! stable, and **the miss is counted on the actual insert**: a racer
//! that loses the insert records a hit (it was served the winner's
//! plan), so `misses() == len()` holds for any race-free, eviction-free
//! key set and `hits() + misses()` always equals the lookup count.
//!
//! Capacity: by default the cache grows without bound (one plan per
//! `(root, op)` — a root-rotation sweep on a 512-rank communicator
//! caches 512 plans). [`PlanCache::with_capacity`] bounds the resident
//! set by **plan footprint bytes**
//! ([`CollectivePlan::footprint_bytes`]); inserting past the budget
//! evicts least-recently-used plans until the total fits (the newest
//! plan is only evicted if it alone exceeds the budget — it is the MRU,
//! so it always survives while anything older can be dropped first).
//! Evicted plans stay alive for holders of their `Arc`; `evictions()`
//! reports how many were dropped.
//!
//! Sharding: an unbounded cache spreads its map over
//! [`DEFAULT_SHARDS`] independently locked shards (key-hash addressed)
//! so a daemon's worker threads don't serialize on one mutex. Every
//! invariant above is per-key, and a key always maps to the same shard,
//! so first-insert-wins identity and the counter identities
//! (`hits() + misses()` == lookups, `misses() == len()` race-free
//! eviction-free) hold globally — the hit/miss/eviction counters stay
//! cache-global atomics. A *bounded* cache uses a single shard: LRU
//! eviction needs one recency order over the whole resident set, and
//! capacity-bounded caches are sized for sweeps, not daemon QPS.

use super::{CollectivePlan, OpKind, PlanKey, PlanMeta, PLAN_BASE_TAG};
use crate::collectives::programs;
use crate::error::{Error, Result};
use crate::topology::Communicator;
use crate::tree::build_strategy_tree;
use crate::util::counters;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Shard count for unbounded caches (power of two, modest: plans are
/// few and large, contention comes from lookups, not resident count).
pub const DEFAULT_SHARDS: usize = 8;

#[derive(Debug)]
struct Entry {
    plan: Arc<CollectivePlan>,
    /// Monotone recency stamp (from `Inner::tick`) of the last lookup.
    last_used: u64,
    /// Cached `plan.footprint_bytes()` so eviction never re-walks plans.
    footprint: usize,
}

#[derive(Debug, Default)]
struct Inner {
    map: HashMap<PlanKey, Entry>,
    /// Lookup counter driving LRU recency.
    tick: u64,
    /// Sum of resident entries' footprints.
    footprint: usize,
}

/// Memoizing store of compiled collective plans.
#[derive(Debug)]
pub struct PlanCache {
    /// Key-hash-addressed shards; bounded caches always hold exactly one
    /// (global LRU needs a single recency order).
    shards: Box<[Mutex<Inner>]>,
    /// Footprint budget in bytes; `None` = unbounded.
    capacity: Option<usize>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::new()
    }
}

impl PlanCache {
    pub fn new() -> Self {
        PlanCache::sharded(DEFAULT_SHARDS, None)
    }

    /// A cache bounded to `capacity_bytes` of plan footprint, evicting
    /// least-recently-used plans on overflow. Single-sharded: eviction
    /// ranks recency across the entire resident set.
    pub fn with_capacity(capacity_bytes: usize) -> Self {
        PlanCache::sharded(1, Some(capacity_bytes))
    }

    fn sharded(n_shards: usize, capacity: Option<usize>) -> Self {
        let shards =
            (0..n_shards.max(1)).map(|_| Mutex::new(Inner::default())).collect::<Vec<_>>();
        PlanCache {
            shards: shards.into_boxed_slice(),
            capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// The footprint budget (`None` = unbounded).
    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    /// Number of independently locked shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The shard owning `key` — stable for the cache's lifetime, so all
    /// racers for one key serialize on the same lock.
    fn shard(&self, key: &PlanKey) -> &Mutex<Inner> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    /// Number of cached plans (summed over shards).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current resident footprint in bytes (summed over shards).
    pub fn footprint_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().footprint).sum()
    }

    /// Drop every cached plan (counters keep running).
    pub fn clear(&self) {
        for shard in self.shards.iter() {
            let mut inner = shard.lock().unwrap();
            inner.map.clear();
            inner.footprint = 0;
        }
    }

    /// Warm-path lookups served without building, over this cache's
    /// lifetime.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups whose build was actually inserted (cold path). Equals
    /// `len()` for a race-free key set with no evictions.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Plans dropped by LRU capacity eviction, over this cache's
    /// lifetime.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Fetch the plan for `key`, building (tree + program + meta) only on
    /// the first request. `key.comm_epoch` must match `comm` — plans are
    /// never valid across communicators.
    pub fn get_or_build(
        &self,
        comm: &Communicator,
        key: PlanKey,
    ) -> Result<Arc<CollectivePlan>> {
        if key.comm_epoch != comm.epoch() {
            return Err(Error::Comm(format!(
                "plan key epoch {} does not match communicator epoch {}",
                key.comm_epoch,
                comm.epoch()
            )));
        }
        if key.root >= comm.size() {
            return Err(Error::Comm(format!(
                "root {} out of range for {}-rank communicator",
                key.root,
                comm.size()
            )));
        }
        if let Some(plan) = self.lookup(&key) {
            return Ok(plan);
        }
        // Build outside the lock: construction may recursively consult
        // this cache (reduce+bcast allreduce composes its cached phases).
        let plan = Arc::new(self.build(comm, key.clone())?);
        Ok(self.insert_or_adopt(key, plan))
    }

    /// Warm path: bump recency and hit counters under the shard lock.
    fn lookup(&self, key: &PlanKey) -> Option<Arc<CollectivePlan>> {
        let mut inner = self.shard(key).lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let entry = inner.map.get_mut(key)?;
        entry.last_used = tick;
        let plan = entry.plan.clone();
        drop(inner);
        self.hits.fetch_add(1, Ordering::Relaxed);
        counters::count_plan_hit();
        Some(plan)
    }

    /// Cold path tail: insert the freshly built plan unless a racing
    /// builder got there first. The miss is counted only when the insert
    /// lands; the losing racer records a hit instead.
    fn insert_or_adopt(
        &self,
        key: PlanKey,
        plan: Arc<CollectivePlan>,
    ) -> Arc<CollectivePlan> {
        let footprint = plan.footprint_bytes();
        let mut inner = self.shard(&key).lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(existing) = inner.map.get_mut(&key) {
            // Lost a build race: first insert wins so concurrent builders
            // agree on Arc identity; the winner counted the miss.
            existing.last_used = tick;
            let winner = existing.plan.clone();
            drop(inner);
            self.hits.fetch_add(1, Ordering::Relaxed);
            counters::count_plan_hit();
            return winner;
        }
        inner.footprint += footprint;
        inner.map.insert(key, Entry { plan: plan.clone(), last_used: tick, footprint });
        if let Some(cap) = self.capacity {
            self.evict_lru(&mut inner, cap);
        }
        drop(inner);
        self.misses.fetch_add(1, Ordering::Relaxed);
        counters::count_plan_miss();
        plan
    }

    /// Evict least-recently-used entries until the footprint fits `cap`.
    /// Never empties the cache: the just-inserted plan is the MRU, so it
    /// survives even when it alone exceeds the budget.
    fn evict_lru(&self, inner: &mut Inner, cap: usize) {
        while inner.footprint > cap && inner.map.len() > 1 {
            let victim = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
                .expect("non-empty map");
            if let Some(evicted) = inner.map.remove(&victim) {
                inner.footprint -= evicted.footprint;
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Cold path: construct tree, compile program, derive metadata.
    fn build(&self, comm: &Communicator, key: PlanKey) -> Result<CollectivePlan> {
        let tag = PLAN_BASE_TAG;
        let (tree, program) = match key.op {
            OpKind::Allreduce(op, policy) if policy.is_plain_full() => {
                // Compose the two cached phases instead of recompiling:
                // the reduce and bcast plans share one tree build, and the
                // bcast program is tag-rebased past the reduce's tags.
                let red = self.get_or_build(
                    comm,
                    PlanKey { op: OpKind::Reduce(op), ..key.clone() },
                )?;
                let bc =
                    self.get_or_build(comm, PlanKey { op: OpKind::Bcast, ..key.clone() })?;
                let mut program = red.program.clone();
                program.then(bc.program.rebased(red.program.max_tag() + 1))?;
                program.validate()?;
                (red.tree.clone(), program)
            }
            OpKind::Allreduce(op, policy) => {
                // Every other composition (hybrid, ring, halving, chunked
                // pipelining): the up phase IS the cached reduce plan
                // (same tree, same program — the combine dataflow is
                // payload-representation-agnostic); only the per-level
                // delivery phase is compiled, then tag-rebased past it.
                // Zero extra tree builds on this path.
                let red = self.get_or_build(
                    comm,
                    PlanKey { op: OpKind::Reduce(op), ..key.clone() },
                )?;
                let down =
                    programs::allreduce_down(&red.tree, comm.clustering(), policy, tag)?;
                let mut program = red.program.clone();
                program.then(down.rebased(red.program.max_tag() + 1))?;
                program.validate()?;
                (red.tree.clone(), program)
            }
            _ => {
                let tree = build_strategy_tree(comm, key.root, key.strategy, &key.policy)?;
                let program = key.op.compile(comm.clustering(), &tree, key.segments, tag)?;
                (tree, program)
            }
        };
        let meta = PlanMeta::compute(comm.clustering(), &tree, &program, key.op);
        // Resolve mailbox channels once, here on the cold path, so every
        // warm execution of this plan is hash-free — and partition them
        // by cluster so sharded execution is table-lookup-only too.
        let channels = crate::netsim::ChannelIndex::build(&program);
        let shards = crate::netsim::ShardMap::build(comm.clustering(), &channels);
        Ok(CollectivePlan { key, tree, program, meta, channels, shards })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::ReduceOp;
    use crate::plan::{AlgoPolicy, AllreduceAlgo};
    use crate::topology::TopologySpec;
    use crate::tree::{LevelPolicy, Strategy};

    fn key(comm: &Communicator, op: OpKind, root: usize) -> PlanKey {
        PlanKey {
            comm_epoch: comm.epoch(),
            strategy: Strategy::Multilevel,
            policy: LevelPolicy::paper(),
            root,
            op,
            segments: 1,
        }
    }

    #[test]
    fn warm_hit_builds_nothing() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        let k = key(&comm, OpKind::Bcast, 3);
        let cold = cache.get_or_build(&comm, k.clone()).unwrap();
        let before = counters::snapshot();
        let warm = cache.get_or_build(&comm, k).unwrap();
        let delta = counters::snapshot().since(&before);
        // The behavior is pinned by cache-local stats and Arc identity —
        // both immune to other tests running in this process.
        assert!(Arc::ptr_eq(&cold, &warm), "same plan instance");
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.misses() as usize, cache.len(), "misses() == len()");
        // Global counters are process-wide, so only a >= smoke bound.
        assert!(delta.plan_cache_hits >= 1);
    }

    #[test]
    fn distinct_keys_build_distinct_plans() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 0)).unwrap();
        cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 1)).unwrap();
        cache.get_or_build(&comm, key(&comm, OpKind::Reduce(ReduceOp::Sum), 0)).unwrap();
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.misses(), 3);
        assert!(cache.footprint_bytes() > 0);
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.footprint_bytes(), 0);
    }

    #[test]
    fn allreduce_rb_reuses_cached_phases() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        // Pre-warm the two phases.
        let red = cache.get_or_build(&comm, key(&comm, OpKind::Reduce(ReduceOp::Sum), 0)).unwrap();
        let bc = cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 0)).unwrap();
        let before = counters::snapshot();
        let ar = cache
            .get_or_build(
                &comm,
                key(
                    &comm,
                    OpKind::Allreduce(
                        ReduceOp::Sum,
                        AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast),
                    ),
                    0,
                ),
            )
            .unwrap();
        let delta = counters::snapshot().since(&before);
        // Composition is rebase + concatenation. Pinned via cache-local
        // stats and Arc identity only (parallel tests perturb the global
        // counters, which therefore get >= smoke bounds, never equality).
        assert_eq!(cache.misses(), 3, "allreduce itself was the only new miss");
        assert_eq!(cache.hits(), 2, "both phases served warm");
        assert!(
            Arc::ptr_eq(&red, &cache.get_or_build(&comm, red.key.clone()).unwrap()),
            "reduce phase still resident"
        );
        assert!(
            Arc::ptr_eq(&bc, &cache.get_or_build(&comm, bc.key.clone()).unwrap()),
            "bcast phase still resident"
        );
        assert!(delta.plan_cache_misses >= 1);
        // Tags of the two phases must not collide inside one run.
        ar.program.validate().unwrap();
    }

    #[test]
    fn allreduce_hybrid_reuses_cached_reduce_tree() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        let red = cache.get_or_build(&comm, key(&comm, OpKind::Reduce(ReduceOp::Sum), 0)).unwrap();
        let hybrid = cache
            .get_or_build(
                &comm,
                key(&comm, OpKind::Allreduce(ReduceOp::Sum, AlgoPolicy::hybrid(1)), 0),
            )
            .unwrap();
        // Composition: the reduce phase was served warm, only the hybrid
        // plan itself missed (cache-local stats are race-free).
        assert_eq!(cache.misses(), 2, "reduce + hybrid");
        assert_eq!(cache.hits(), 1, "reduce phase served warm");
        assert_eq!(hybrid.tree, red.tree, "one tree shared by both phases");
        hybrid.program.validate().unwrap();
        // Distinct boundaries are distinct plans.
        cache
            .get_or_build(
                &comm,
                key(&comm, OpKind::Allreduce(ReduceOp::Sum, AlgoPolicy::hybrid(2)), 0),
            )
            .unwrap();
        assert_eq!(cache.misses(), 3);
    }

    #[test]
    fn allreduce_compositions_share_the_cached_reduce_plan() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        let red = cache.get_or_build(&comm, key(&comm, OpKind::Reduce(ReduceOp::Sum), 0)).unwrap();
        let chunked = AlgoPolicy::uniform(AllreduceAlgo::ReduceBcast).with_chunks(4);
        let plan = cache
            .get_or_build(&comm, key(&comm, OpKind::Allreduce(ReduceOp::Sum, chunked), 0))
            .unwrap();
        assert_eq!(cache.misses(), 2, "reduce + composition");
        assert_eq!(cache.hits(), 1, "reduce phase served warm");
        assert_eq!(plan.tree, red.tree, "one tree shared by both phases");
        plan.program.validate().unwrap();
        // Uniform rs+ag rides the same shared-reduce path.
        cache
            .get_or_build(
                &comm,
                key(
                    &comm,
                    OpKind::Allreduce(
                        ReduceOp::Sum,
                        AlgoPolicy::uniform(AllreduceAlgo::ReduceScatterAllgather),
                    ),
                    0,
                ),
            )
            .unwrap();
        assert_eq!(cache.misses(), 3);
        assert_eq!(cache.hits(), 2);
    }

    #[test]
    fn racing_builders_count_one_miss_and_share_identity() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        let k = key(&comm, OpKind::Bcast, 0);
        let plans: Vec<Arc<CollectivePlan>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let k = k.clone();
                    let cache = &cache;
                    let comm = &comm;
                    s.spawn(move || cache.get_or_build(comm, k).unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for p in &plans[1..] {
            assert!(Arc::ptr_eq(&plans[0], p), "all racers share one plan");
        }
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.misses(), 1, "exactly one insert counted a miss");
        assert_eq!(cache.hits(), 3, "losing racers and warm lookups count hits");
        assert_eq!(cache.evictions(), 0);
    }

    #[test]
    fn lru_eviction_caps_footprint_and_keeps_hot_plans() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        // Budget for roughly three bcast plans: measure one first.
        let probe = PlanCache::new();
        let one = probe
            .get_or_build(&comm, key(&comm, OpKind::Bcast, 0))
            .unwrap()
            .footprint_bytes();
        let cache = PlanCache::with_capacity(3 * one + one / 2);
        assert_eq!(cache.capacity(), Some(3 * one + one / 2));
        // A root-rotation-style sweep: many single-use plans.
        for root in 0..comm.size() {
            // Keep root 0 hot so LRU retains it over older-but-colder peers.
            cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 0)).unwrap();
            cache.get_or_build(&comm, key(&comm, OpKind::Bcast, root)).unwrap();
        }
        assert!(
            cache.footprint_bytes() <= cache.capacity().unwrap(),
            "footprint {} over budget {}",
            cache.footprint_bytes(),
            cache.capacity().unwrap()
        );
        assert!(cache.len() <= 3, "at most three plans fit, got {}", cache.len());
        assert!(cache.evictions() > 0, "the sweep must have evicted");
        // The hot plan survived every eviction round.
        let before_hits = cache.hits();
        cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 0)).unwrap();
        assert_eq!(cache.hits(), before_hits + 1, "hot root-0 plan still resident");
    }

    #[test]
    fn oversized_single_plan_still_cached() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::with_capacity(1); // absurdly small budget
        cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 0)).unwrap();
        assert_eq!(cache.len(), 1, "the MRU plan is never evicted");
        let before_hits = cache.hits();
        cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 0)).unwrap();
        assert_eq!(cache.hits(), before_hits + 1);
        // A second key displaces the first (single-slot behavior).
        cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 1)).unwrap();
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.evictions(), 1);
    }

    #[test]
    fn sharding_defaults_and_aggregate_views() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        assert_eq!(cache.n_shards(), DEFAULT_SHARDS);
        assert_eq!(PlanCache::with_capacity(1024).n_shards(), 1, "bounded => global LRU");
        // Populate enough distinct keys to land in more than one shard;
        // len()/footprint_bytes() must aggregate across all of them.
        for root in 0..comm.size() {
            cache.get_or_build(&comm, key(&comm, OpKind::Bcast, root)).unwrap();
        }
        assert_eq!(cache.len(), comm.size());
        assert_eq!(cache.misses() as usize, cache.len(), "misses() == len() across shards");
        assert!(cache.footprint_bytes() > 0);
        cache.clear();
        assert!(cache.is_empty());
        assert_eq!(cache.footprint_bytes(), 0);
    }

    #[test]
    fn epoch_mismatch_rejected() {
        let a = Communicator::world(&TopologySpec::paper_fig1());
        let b = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        let k = key(&a, OpKind::Bcast, 0);
        assert!(cache.get_or_build(&b, k).is_err());
    }

    #[test]
    fn out_of_range_root_rejected() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        assert!(cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 99)).is_err());
    }
}
