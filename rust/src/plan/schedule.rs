//! Fused multi-collective schedules (stage 2½ of the pipeline).
//!
//! The paper's §4 timing application measures `t1 - t0` over one
//! *continuous* run of a whole operation sequence (broadcast, ack-barrier,
//! next root, …). Simulating each operation as its own `netsim::run` and
//! summing makespans erases every cross-phase effect — a straggler rank
//! entering the next phase late, ack/GO control traffic overlapping the
//! tail of a broadcast — and costs one engine invocation per phase.
//!
//! A [`Schedule`] concatenates cached [`CollectivePlan`] programs and
//! ad-hoc programs (e.g. the hand-rolled ack-barrier) into **one**
//! validated [`Program`]:
//!
//! - **automatic tag allocation** — each appended segment is tag-rebased
//!   past every tag already allocated ([`Program::rebase_tags`]), so
//!   channels of different segments never collide; the fused program is
//!   re-validated on [`ScheduleBuilder::build`];
//! - **per-segment boundary markers** — an [`crate::netsim::Action::Mark`]
//!   is appended at every rank after each segment, so a single `netsim::run` yields
//!   the cumulative completion timestamp of every segment
//!   ([`Schedule::segment_completions`]);
//! - **aggregated [`PlanMeta`]** — static message counts per separation
//!   level sum over segments and stay exact for the fused run.
//!
//! Assembly is cheap by design: cloning cached programs plus an
//! O(actions) integer rebase — **zero tree builds, zero compiles** on a
//! warm [`super::PlanCache`]. `Action::Mark` is not a synchronization
//! point; ranks pass markers independently, so fusion never slows a
//! sequence down (the engine's timing is monotone max-plus: fused
//! makespan ≤ sum of isolated makespans).

use super::{CollectivePlan, PlanMeta};
use crate::error::{Error, Result};
use crate::netsim::{ChannelIndex, Program, ShardMap, SimResult};
use crate::topology::{Clustering, Communicator};
use crate::util::counters;

/// One appended segment of a fused schedule: label + static metadata +
/// the tag budget it was rebased into.
#[derive(Clone, Debug)]
pub struct Segment {
    /// Caller-supplied label (e.g. `"bcast@7"`, `"ack@7"`).
    pub label: String,
    /// Static per-segment metadata; `msgs_by_sep` stays exact for the
    /// fused run (marker actions send nothing).
    pub meta: PlanMeta,
    /// Half-open tag interval `[lo, hi)` allocated to this segment.
    /// Intervals of consecutive segments are disjoint by construction.
    pub tags: (u64, u64),
    /// Total actions contributed (excluding the boundary markers).
    pub actions: usize,
}

/// Incrementally composes segments into a fused program.
///
/// Created via [`ScheduleBuilder::new`]; finished with
/// [`ScheduleBuilder::build`], which validates the fused program.
#[derive(Clone, Debug)]
pub struct ScheduleBuilder {
    clustering: Clustering,
    comm_epoch: u64,
    program: Program,
    segments: Vec<Segment>,
    next_tag: u64,
}

impl ScheduleBuilder {
    /// Start an empty schedule over `comm`'s process group. The
    /// clustering is captured for per-segment metadata; the epoch pins
    /// which cached plans may be appended.
    pub fn new(comm: &Communicator) -> Self {
        ScheduleBuilder {
            clustering: comm.clustering().clone(),
            comm_epoch: comm.epoch(),
            program: Program::new(comm.size()),
            segments: Vec::new(),
            next_tag: 0,
        }
    }

    /// Number of segments appended so far.
    pub fn n_segments(&self) -> usize {
        self.segments.len()
    }

    /// Append a cached plan as the next segment. Rejects plans built for
    /// another communicator epoch. Returns the segment index (also the
    /// boundary-marker id).
    pub fn add_plan(&mut self, label: &str, plan: &CollectivePlan) -> Result<usize> {
        if plan.key.comm_epoch != self.comm_epoch {
            return Err(Error::Schedule(format!(
                "segment '{label}': plan epoch {} does not match schedule epoch {}",
                plan.key.comm_epoch, self.comm_epoch
            )));
        }
        self.append(label, plan.program.clone(), plan.meta.clone())
    }

    /// Append an ad-hoc program (e.g. the §4 ack-barrier) as the next
    /// segment. The program is validated in isolation first; its metadata
    /// is derived from its send actions (no tree ⇒ zero tree edges).
    pub fn add_program(&mut self, label: &str, program: Program) -> Result<usize> {
        program.validate().map_err(|e| {
            Error::Schedule(format!("segment '{label}' is invalid in isolation: {e}"))
        })?;
        let meta = PlanMeta::of_program(&self.clustering, &program);
        self.append(label, program, meta)
    }

    fn append(&mut self, label: &str, mut program: Program, meta: PlanMeta) -> Result<usize> {
        if program.n_ranks() != self.program.n_ranks() {
            return Err(Error::Schedule(format!(
                "segment '{label}' spans {} ranks, schedule spans {}",
                program.n_ranks(),
                self.program.n_ranks()
            )));
        }
        // Marker ids are the schedule's namespace: a stray Mark inside a
        // segment would collide with (or fall outside) the boundary ids
        // and corrupt per-segment timing silently.
        if program
            .actions
            .iter()
            .flatten()
            .any(|a| matches!(a, crate::netsim::Action::Mark { .. }))
        {
            return Err(Error::Schedule(format!(
                "segment '{label}' contains Mark actions; boundary markers \
                 are inserted by the schedule itself"
            )));
        }
        let id = self.segments.len();
        let actions = program.total_actions();
        // Automatic tag allocation: shift the segment past every tag
        // already spoken for, then reserve its (shifted) range.
        let delta = self.next_tag;
        program.rebase_tags(delta);
        // `max(delta)` keeps the allocator monotone for empty segments
        // (an action-free program reports max_tag() == 0).
        let tag_end = (program.max_tag() + 1).max(delta);
        self.next_tag = tag_end;
        self.program.then(program)?;
        // Boundary marker: every rank stamps its local clock when it
        // finishes this segment; the engine keeps the max.
        self.program.mark_all(id as u64);
        self.segments.push(Segment {
            label: label.to_string(),
            meta,
            tags: (delta, tag_end),
            actions,
        });
        Ok(id)
    }

    /// Validate the fused program and freeze the schedule. Also resolves
    /// the fused program's [`ChannelIndex`] so every execution of the
    /// schedule is hash-free, and bumps the schedule-build stage counter
    /// (warm sweeps over a memoized schedule must not re-assemble it —
    /// see `CollectiveEngine::memo_schedule`).
    pub fn build(self) -> Result<Schedule> {
        self.program.validate().map_err(|e| {
            Error::Schedule(format!("fused schedule failed validation: {e}"))
        })?;
        counters::count_schedule_build();
        let meta = aggregate_meta(self.clustering.n_levels(), &self.segments);
        let channels = ChannelIndex::build(&self.program);
        let shards = ShardMap::build(&self.clustering, &channels);
        Ok(Schedule {
            comm_epoch: self.comm_epoch,
            program: self.program,
            segments: self.segments,
            meta,
            channels,
            shards,
        })
    }
}

/// Sum the per-segment static facts. Counts add exactly; shape facts
/// (fan-out, height) take the max; byte prediction is answered per
/// segment by [`Schedule::expected_bytes_by_sep`], so the aggregate
/// carries the conservative `Routed` model.
fn aggregate_meta(n_levels: usize, segments: &[Segment]) -> PlanMeta {
    let mut msgs_by_sep = vec![0u64; n_levels];
    let mut tree_edges_by_sep = vec![0usize; n_levels];
    let mut max_fanout = 0usize;
    let mut tree_height = 0usize;
    for s in segments {
        for (acc, &m) in msgs_by_sep.iter_mut().zip(&s.meta.msgs_by_sep) {
            *acc += m;
        }
        for (acc, &e) in tree_edges_by_sep.iter_mut().zip(&s.meta.tree_edges_by_sep) {
            *acc += e;
        }
        max_fanout = max_fanout.max(s.meta.max_fanout);
        tree_height = tree_height.max(s.meta.tree_height);
    }
    PlanMeta {
        msgs_by_sep,
        tree_edges_by_sep,
        max_fanout,
        tree_height,
        bytes_model: super::BytesModel::Routed,
    }
}

/// A validated, tag-rebased fusion of collective plans and ad-hoc
/// programs: one program, one `netsim::run`, per-segment timings.
#[derive(Clone, Debug)]
pub struct Schedule {
    comm_epoch: u64,
    program: Program,
    segments: Vec<Segment>,
    meta: PlanMeta,
    channels: ChannelIndex,
    shards: ShardMap,
}

impl Schedule {
    /// The fused program (run it with `netsim::run` or
    /// `CollectiveEngine::run_schedule`).
    pub fn program(&self) -> &Program {
        &self.program
    }

    /// The fused program's precomputed channel resolution (pass to the
    /// engine's `*_indexed` entry points).
    pub fn channels(&self) -> &ChannelIndex {
        &self.channels
    }

    /// The fused program's cluster partition, for sharded execution
    /// ([`crate::netsim::ExecMode::Sharded`]).
    pub fn shards(&self) -> &ShardMap {
        &self.shards
    }

    /// The appended segments, in execution order.
    pub fn segments(&self) -> &[Segment] {
        &self.segments
    }

    pub fn n_segments(&self) -> usize {
        self.segments.len()
    }

    /// Communicator epoch the schedule was assembled against.
    pub fn comm_epoch(&self) -> u64 {
        self.comm_epoch
    }

    /// Aggregated static metadata: `msgs_by_sep` is the exact message
    /// count of the fused run (sum over segments).
    pub fn meta(&self) -> &PlanMeta {
        &self.meta
    }

    /// Predicted wire bytes per separation level for a run whose data
    /// payload is `payload_bytes`, summed over segments. `None` as soon
    /// as any segment's per-message bytes are routing-dependent.
    pub fn expected_bytes_by_sep(&self, payload_bytes: usize) -> Option<Vec<u64>> {
        let mut total = vec![0u64; self.meta.msgs_by_sep.len()];
        for s in &self.segments {
            let per = s.meta.expected_bytes_by_sep(payload_bytes)?;
            for (acc, b) in total.iter_mut().zip(per) {
                *acc += b;
            }
        }
        Some(total)
    }

    /// Cumulative completion timestamp of every segment, extracted from a
    /// fused run's boundary markers. Monotone non-decreasing; the last
    /// entry equals the run's makespan.
    pub fn segment_completions(&self, sim: &SimResult) -> Result<Vec<f64>> {
        let mut out = vec![f64::NAN; self.segments.len()];
        let mut seen = 0usize;
        for &(id, t) in &sim.mark_times_us {
            let idx = id as usize;
            if idx >= out.len() {
                return Err(Error::Schedule(format!(
                    "run recorded marker {id}, schedule has {} segments",
                    self.segments.len()
                )));
            }
            out[idx] = t;
            seen += 1;
        }
        if seen != self.segments.len() {
            return Err(Error::Schedule(format!(
                "run recorded {seen} markers, schedule has {} segments \
                 (was the schedule's own program executed?)",
                self.segments.len()
            )));
        }
        Ok(out)
    }

    /// Per-segment durations `d[i] = t[i] - t[i-1]` (with `t[-1] = 0`)
    /// from a fused run. Because markers are not synchronization points,
    /// `d[i]` is the *critical-path* residual of segment `i` given the
    /// overlap with its predecessors — exactly the per-phase share of the
    /// continuous `t1 - t0` measurement.
    pub fn segment_durations(&self, sim: &SimResult) -> Result<Vec<f64>> {
        let t = self.segment_completions(sim)?;
        let mut prev = 0.0;
        Ok(t.into_iter()
            .map(|ti| {
                let d = ti - prev;
                prev = ti;
                d
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use crate::netsim::{run, Merge, NativeCombiner, Payload, SendPart, SimConfig};
    use crate::plan::{OpKind, PlanCache, PlanKey};
    use crate::topology::{Communicator, TopologySpec};
    use crate::tree::{LevelPolicy, Strategy};

    fn key(comm: &Communicator, op: OpKind, root: usize) -> PlanKey {
        PlanKey {
            comm_epoch: comm.epoch(),
            strategy: Strategy::Multilevel,
            policy: LevelPolicy::paper(),
            root,
            op,
            segments: 1,
        }
    }

    #[test]
    fn tag_budgets_are_disjoint_and_fused_program_validates() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        let mut b = ScheduleBuilder::new(&comm);
        for root in 0..4 {
            let plan = cache.get_or_build(&comm, key(&comm, OpKind::Bcast, root)).unwrap();
            b.add_plan(&format!("bcast@{root}"), &plan).unwrap();
        }
        assert_eq!(b.n_segments(), 4);
        let s = b.build().unwrap();
        s.program().validate().unwrap();
        for w in s.segments().windows(2) {
            assert!(w[0].tags.1 <= w[1].tags.0, "tag budgets overlap");
        }
        assert_eq!(s.n_segments(), 4);
    }

    #[test]
    fn aggregated_meta_sums_segments() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        let mut b = ScheduleBuilder::new(&comm);
        let p0 = cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 0)).unwrap();
        let p1 = cache.get_or_build(&comm, key(&comm, OpKind::Bcast, 1)).unwrap();
        b.add_plan("a", &p0).unwrap();
        b.add_plan("b", &p1).unwrap();
        let s = b.build().unwrap();
        let expect: Vec<u64> = p0
            .meta
            .msgs_by_sep
            .iter()
            .zip(&p1.meta.msgs_by_sep)
            .map(|(a, b)| a + b)
            .collect();
        assert_eq!(s.meta().msgs_by_sep, expect);
        assert_eq!(s.meta().total_messages(), 2 * (comm.size() as u64 - 1));
    }

    #[test]
    fn fused_run_yields_monotone_segment_timestamps() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        let mut b = ScheduleBuilder::new(&comm);
        for root in [0usize, 5, 11] {
            let plan = cache.get_or_build(&comm, key(&comm, OpKind::Bcast, root)).unwrap();
            b.add_plan(&format!("bcast@{root}"), &plan).unwrap();
        }
        let s = b.build().unwrap();
        let data = vec![1.0f32; 64];
        let mut init = vec![Payload::empty(); comm.size()];
        init[0] = Payload::single(0, data.clone());
        let cfg = SimConfig::new(presets::paper_grid());
        let sim =
            run(comm.clustering(), s.program(), init, &cfg, &NativeCombiner).unwrap();
        let t = s.segment_completions(&sim).unwrap();
        assert_eq!(t.len(), 3);
        assert!(t.windows(2).all(|w| w[0] <= w[1]), "monotone: {t:?}");
        assert!((t[2] - sim.makespan_us).abs() < 1e-9, "last marker == makespan");
        let d = s.segment_durations(&sim).unwrap();
        assert!(d.iter().all(|&x| x >= 0.0));
        assert!((d.iter().sum::<f64>() - sim.makespan_us).abs() < 1e-6);
        // static meta stays exact for the fused run
        assert_eq!(sim.msgs_by_sep, s.meta().msgs_by_sep);
        assert_eq!(
            sim.bytes_by_sep,
            s.expected_bytes_by_sep(data.len() * 4).unwrap()
        );
    }

    #[test]
    fn ad_hoc_program_segment_gets_derived_meta() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let n = comm.size();
        let mut ack = Program::new(n);
        for r in 1..n {
            ack.send(r, 0, 1, SendPart::Empty);
        }
        for r in 1..n {
            ack.recv(0, r, 1, Merge::Discard);
        }
        let mut b = ScheduleBuilder::new(&comm);
        b.add_program("ack", ack).unwrap();
        let s = b.build().unwrap();
        assert_eq!(s.meta().total_messages(), n as u64 - 1);
        assert_eq!(s.segments()[0].meta.tree_edges_by_sep.iter().sum::<usize>(), 0);
        // control traffic: zero predicted bytes
        assert_eq!(
            s.expected_bytes_by_sep(4096).unwrap().iter().sum::<u64>(),
            0
        );
    }

    #[test]
    fn mismatched_segments_rejected() {
        let comm = Communicator::world(&TopologySpec::paper_fig1());
        let other = Communicator::world(&TopologySpec::paper_fig1());
        let cache = PlanCache::new();
        let plan = cache.get_or_build(&other, key(&other, OpKind::Bcast, 0)).unwrap();
        let mut b = ScheduleBuilder::new(&comm);
        // same shape, different epoch: cached plans must not cross
        assert!(b.add_plan("x", &plan).is_err());
        // wrong rank count
        assert!(b.add_program("y", Program::new(3)).is_err());
        // invalid in isolation (unbalanced send)
        let mut bad = Program::new(comm.size());
        bad.send(0, 1, 1, SendPart::Empty);
        assert!(b.add_program("z", bad).is_err());
        // stray markers would collide with the schedule's boundary ids
        let mut marked = Program::new(comm.size());
        marked.mark_all(0);
        assert!(b.add_program("w", marked).is_err());
    }
}
