//! Payloads carried by simulated messages, and the reduction combiner
//! abstraction.
//!
//! A payload is a rank-keyed map of f32 segments. This one representation
//! serves all five collectives: broadcast/reduce move a single segment,
//! gather/scatter move per-rank segments, barrier moves empty payloads.
//! Real bytes flow through the simulator so collective *semantics* are
//! verified, not just timing; the combine arithmetic is pluggable so the
//! PJRT-backed combiner (L1 Pallas kernel, AOT-compiled) can execute it.

use std::collections::BTreeMap;

pub type Rank = usize;

/// MPI reduction operators supported by the combine kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReduceOp {
    Sum,
    Max,
    Min,
    Prod,
}

impl ReduceOp {
    pub const ALL: [ReduceOp; 4] = [ReduceOp::Sum, ReduceOp::Max, ReduceOp::Min, ReduceOp::Prod];

    pub fn name(&self) -> &'static str {
        match self {
            ReduceOp::Sum => "sum",
            ReduceOp::Max => "max",
            ReduceOp::Min => "min",
            ReduceOp::Prod => "prod",
        }
    }

    #[inline]
    pub fn apply(&self, a: f32, b: f32) -> f32 {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Max => a.max(b),
            ReduceOp::Min => a.min(b),
            ReduceOp::Prod => a * b,
        }
    }

    /// Identity element (for empty folds).
    pub fn identity(&self) -> f32 {
        match self {
            ReduceOp::Sum => 0.0,
            ReduceOp::Max => f32::NEG_INFINITY,
            ReduceOp::Min => f32::INFINITY,
            ReduceOp::Prod => 1.0,
        }
    }
}

/// Executes the elementwise combine `acc[i] = op(acc[i], src[i])`.
///
/// `NativeCombiner` is the pure-Rust fallback; `runtime::XlaCombiner` runs
/// the AOT-compiled Pallas kernel through PJRT.
pub trait Combiner {
    fn combine(&self, op: ReduceOp, acc: &mut [f32], src: &[f32]);

    /// Name for reports.
    fn name(&self) -> &'static str {
        "combiner"
    }
}

/// Scalar-loop reference combiner.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeCombiner;

impl Combiner for NativeCombiner {
    fn combine(&self, op: ReduceOp, acc: &mut [f32], src: &[f32]) {
        assert_eq!(acc.len(), src.len(), "combine length mismatch");
        match op {
            // Specialized loops: the generic `op.apply` closure defeats
            // autovectorization; these compile to packed SIMD.
            ReduceOp::Sum => {
                for (a, b) in acc.iter_mut().zip(src) {
                    *a += *b;
                }
            }
            ReduceOp::Max => {
                for (a, b) in acc.iter_mut().zip(src) {
                    *a = a.max(*b);
                }
            }
            ReduceOp::Min => {
                for (a, b) in acc.iter_mut().zip(src) {
                    *a = a.min(*b);
                }
            }
            ReduceOp::Prod => {
                for (a, b) in acc.iter_mut().zip(src) {
                    *a *= *b;
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Rank-keyed f32 segments.
///
/// Segments are reference-counted (`Arc`) so that forwarding a payload
/// down a tree — the inner loop of every simulated broadcast — is a
/// refcount bump instead of a deep copy; `combine` uses copy-on-write
/// (`Arc::make_mut`). This is the §Perf L3 optimization recorded in
/// EXPERIMENTS.md.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Payload {
    segments: BTreeMap<Rank, std::sync::Arc<Vec<f32>>>,
}

impl Payload {
    pub fn empty() -> Self {
        Payload::default()
    }

    /// Single segment keyed by `owner`.
    pub fn single(owner: Rank, data: Vec<f32>) -> Self {
        let mut segments = BTreeMap::new();
        segments.insert(owner, std::sync::Arc::new(data));
        Payload { segments }
    }

    /// Number of segments.
    pub fn len(&self) -> usize {
        self.segments.len()
    }

    pub fn is_empty(&self) -> bool {
        self.segments.is_empty()
    }

    /// Borrow the segment keyed `k`, if present.
    pub fn get(&self, k: &Rank) -> Option<&[f32]> {
        self.segments.get(k).map(|v| v.as_slice())
    }

    /// Clone out the segment keyed `k` (for result extraction).
    pub fn get_cloned(&self, k: &Rank) -> Option<Vec<f32>> {
        self.segments.get(k).map(|v| v.as_ref().clone())
    }

    /// Whether a segment with key `k` exists.
    pub fn contains_key(&self, k: &Rank) -> bool {
        self.segments.contains_key(k)
    }

    /// Iterate `(key, segment)` in key order.
    pub fn iter(&self) -> impl Iterator<Item = (Rank, &[f32])> {
        self.segments.iter().map(|(&k, v)| (k, v.as_slice()))
    }

    /// Keys in order.
    pub fn keys(&self) -> impl Iterator<Item = Rank> + '_ {
        self.segments.keys().copied()
    }

    pub fn n_bytes(&self) -> usize {
        self.segments.values().map(|v| v.len() * 4).sum()
    }

    pub fn n_elems(&self) -> usize {
        self.segments.values().map(|v| v.len()).sum()
    }

    /// Subset containing only the given ranks' segments (cheap: shares
    /// the underlying segment storage).
    pub fn select(&self, ranks: &[Rank]) -> Payload {
        let mut segments = BTreeMap::new();
        for &r in ranks {
            if let Some(v) = self.segments.get(&r) {
                segments.insert(r, v.clone());
            }
        }
        Payload { segments }
    }

    /// Subset containing the segments whose keys fall in one of the
    /// half-open `[lo, hi)` intervals. Like [`Payload::select`] this
    /// shares segment storage; unlike it, the walk is O(runs + hits)
    /// via the ordered map's range queries, never O(n) in the rank count.
    pub fn select_ranges(&self, ranges: &[(Rank, Rank)]) -> Payload {
        let mut segments = BTreeMap::new();
        for &(lo, hi) in ranges {
            for (&k, v) in self.segments.range(lo..hi) {
                segments.insert(k, v.clone());
            }
        }
        Payload { segments }
    }

    /// Union-merge (gather): disjoint keys required.
    pub fn union(&mut self, other: Payload) -> Result<(), String> {
        for (k, v) in other.segments {
            if self.segments.insert(k, v).is_some() {
                return Err(format!("duplicate segment for rank {k} in union"));
            }
        }
        Ok(())
    }

    /// Elementwise combine (reduce): keys and lengths must align.
    /// Copy-on-write: the accumulator segment is cloned only if shared.
    pub fn combine(&mut self, other: &Payload, op: ReduceOp, c: &dyn Combiner) -> Result<(), String> {
        if self.segments.len() != other.segments.len() {
            return Err(format!(
                "combine key-count mismatch: {} vs {}",
                self.segments.len(),
                other.segments.len()
            ));
        }
        for (k, src) in &other.segments {
            let acc = self
                .segments
                .get_mut(k)
                .ok_or_else(|| format!("combine missing segment {k}"))?;
            if acc.len() != src.len() {
                return Err(format!("combine length mismatch on segment {k}"));
            }
            c.combine(op, std::sync::Arc::make_mut(acc).as_mut_slice(), src.as_slice());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_apply_and_identity() {
        assert_eq!(ReduceOp::Sum.apply(2.0, 3.0), 5.0);
        assert_eq!(ReduceOp::Max.apply(2.0, 3.0), 3.0);
        assert_eq!(ReduceOp::Min.apply(2.0, 3.0), 2.0);
        assert_eq!(ReduceOp::Prod.apply(2.0, 3.0), 6.0);
        for op in ReduceOp::ALL {
            assert_eq!(op.apply(op.identity(), 7.0), 7.0);
        }
    }

    #[test]
    fn payload_sizes() {
        let p = Payload::single(3, vec![1.0; 10]);
        assert_eq!(p.n_bytes(), 40);
        assert_eq!(p.n_elems(), 10);
        assert_eq!(Payload::empty().n_bytes(), 0);
    }

    #[test]
    fn select_subsets() {
        let mut p = Payload::single(0, vec![1.0]);
        p.union(Payload::single(1, vec![2.0, 2.0])).unwrap();
        p.union(Payload::single(2, vec![3.0])).unwrap();
        let s = p.select(&[1, 2]);
        assert_eq!(s.segments.len(), 2);
        assert!(s.segments.contains_key(&1));
        assert!(!s.segments.contains_key(&0));
        // selecting a missing rank is silently empty for that key
        assert_eq!(p.select(&[9]).segments.len(), 0);
    }

    #[test]
    fn select_ranges_matches_select() {
        let mut p = Payload::empty();
        for k in [0usize, 1, 2, 5, 6, 9] {
            p.union(Payload::single(k, vec![k as f32])).unwrap();
        }
        let by_ranges = p.select_ranges(&[(0, 3), (5, 7)]);
        let by_ranks = p.select(&[0, 1, 2, 5, 6]);
        assert_eq!(by_ranges, by_ranks);
        // intervals spanning absent keys select only what exists
        assert_eq!(p.select_ranges(&[(3, 5)]).len(), 0);
        assert_eq!(p.select_ranges(&[(0, 10)]).len(), 6);
    }

    #[test]
    fn union_rejects_duplicates() {
        let mut p = Payload::single(0, vec![1.0]);
        assert!(p.union(Payload::single(0, vec![2.0])).is_err());
    }

    #[test]
    fn combine_native_all_ops() {
        let c = NativeCombiner;
        for (op, expect) in [
            (ReduceOp::Sum, vec![5.0, 7.0]),
            (ReduceOp::Max, vec![4.0, 5.0]),
            (ReduceOp::Min, vec![1.0, 2.0]),
            (ReduceOp::Prod, vec![4.0, 10.0]),
        ] {
            let mut acc = Payload::single(0, vec![1.0, 5.0]);
            let src = Payload::single(0, vec![4.0, 2.0]);
            acc.combine(&src, op, &c).unwrap();
            assert_eq!(acc.get(&0).unwrap(), expect.as_slice(), "{op:?}");
        }
    }

    #[test]
    fn combine_shape_mismatches_rejected() {
        let c = NativeCombiner;
        let mut a = Payload::single(0, vec![1.0]);
        let b = Payload::single(1, vec![1.0]);
        assert!(a.combine(&b, ReduceOp::Sum, &c).is_err());
        let b2 = Payload::single(0, vec![1.0, 2.0]);
        assert!(a.combine(&b2, ReduceOp::Sum, &c).is_err());
    }
}
