//! Payloads carried by simulated messages, and the reduction combiner
//! abstraction.
//!
//! A payload is a rank-keyed map of f32 segments. This one representation
//! serves all five collectives: broadcast/reduce move a single segment,
//! gather/scatter move per-rank segments, barrier moves empty payloads.
//! Real bytes flow through the simulator so collective *semantics* are
//! verified, not just timing; the combine arithmetic is pluggable so the
//! PJRT-backed combiner (L1 Pallas kernel, AOT-compiled) can execute it.
//!
//! The engine itself only ever *prices* payloads (`n_bytes`), so a
//! second register type exists for timing-only runs: [`GhostPayload`]
//! carries per-key element counts as coalesced key runs and implements
//! the same algebra with pure integer arithmetic. The shared contract is
//! the [`Register`] trait; `netsim::run` executes full payloads,
//! `netsim::run_timing` executes ghosts, and both produce bit-identical
//! timing (see `rust/tests/ghost_equivalence.rs`).

use crate::util::counters;
use std::collections::BTreeMap;

pub type Rank = usize;

/// MPI reduction operators supported by the combine kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReduceOp {
    Sum,
    Max,
    Min,
    Prod,
}

impl ReduceOp {
    pub const ALL: [ReduceOp; 4] = [ReduceOp::Sum, ReduceOp::Max, ReduceOp::Min, ReduceOp::Prod];

    pub fn name(&self) -> &'static str {
        match self {
            ReduceOp::Sum => "sum",
            ReduceOp::Max => "max",
            ReduceOp::Min => "min",
            ReduceOp::Prod => "prod",
        }
    }

    #[inline]
    pub fn apply(&self, a: f32, b: f32) -> f32 {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Max => a.max(b),
            ReduceOp::Min => a.min(b),
            ReduceOp::Prod => a * b,
        }
    }

    /// Identity element (for empty folds).
    pub fn identity(&self) -> f32 {
        match self {
            ReduceOp::Sum => 0.0,
            ReduceOp::Max => f32::NEG_INFINITY,
            ReduceOp::Min => f32::INFINITY,
            ReduceOp::Prod => 1.0,
        }
    }
}

/// Executes the elementwise combine `acc[i] = op(acc[i], src[i])`.
///
/// `NativeCombiner` is the pure-Rust fallback; `runtime::XlaCombiner` runs
/// the AOT-compiled Pallas kernel through PJRT.
pub trait Combiner {
    fn combine(&self, op: ReduceOp, acc: &mut [f32], src: &[f32]);

    /// Name for reports.
    fn name(&self) -> &'static str {
        "combiner"
    }
}

/// Scalar-loop reference combiner.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeCombiner;

impl Combiner for NativeCombiner {
    fn combine(&self, op: ReduceOp, acc: &mut [f32], src: &[f32]) {
        assert_eq!(acc.len(), src.len(), "combine length mismatch");
        match op {
            // Specialized loops: the generic `op.apply` closure defeats
            // autovectorization; these compile to packed SIMD.
            ReduceOp::Sum => {
                for (a, b) in acc.iter_mut().zip(src) {
                    *a += *b;
                }
            }
            ReduceOp::Max => {
                for (a, b) in acc.iter_mut().zip(src) {
                    *a = a.max(*b);
                }
            }
            ReduceOp::Min => {
                for (a, b) in acc.iter_mut().zip(src) {
                    *a = a.min(*b);
                }
            }
            ReduceOp::Prod => {
                for (a, b) in acc.iter_mut().zip(src) {
                    *a *= *b;
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Rank-keyed f32 segments.
///
/// Segments are reference-counted (`Arc`) so that forwarding a payload
/// down a tree — the inner loop of every simulated broadcast — is a
/// refcount bump instead of a deep copy; `combine` uses copy-on-write
/// (`Arc::make_mut`). This is the §Perf L3 optimization recorded in
/// EXPERIMENTS.md.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Payload {
    segments: BTreeMap<Rank, std::sync::Arc<Vec<f32>>>,
}

impl Payload {
    pub fn empty() -> Self {
        Payload::default()
    }

    /// Single segment keyed by `owner`.
    ///
    /// This is the one constructor through which payload *data* enters
    /// the simulator (every other operation shares or moves existing
    /// segment storage), so it is the counting site for the
    /// "ghost probes allocate no payload data" stage counter
    /// ([`counters::count_payload_alloc`]).
    pub fn single(owner: Rank, data: Vec<f32>) -> Self {
        counters::count_payload_alloc();
        let mut segments = BTreeMap::new();
        segments.insert(owner, std::sync::Arc::new(data));
        Payload { segments }
    }

    /// Number of segments.
    pub fn len(&self) -> usize {
        self.segments.len()
    }

    pub fn is_empty(&self) -> bool {
        self.segments.is_empty()
    }

    /// Borrow the segment keyed `k`, if present.
    pub fn get(&self, k: &Rank) -> Option<&[f32]> {
        self.segments.get(k).map(|v| v.as_slice())
    }

    /// Clone out the segment keyed `k` (for result extraction).
    pub fn get_cloned(&self, k: &Rank) -> Option<Vec<f32>> {
        self.segments.get(k).map(|v| v.as_ref().clone())
    }

    /// Whether a segment with key `k` exists.
    pub fn contains_key(&self, k: &Rank) -> bool {
        self.segments.contains_key(k)
    }

    /// Iterate `(key, segment)` in key order.
    pub fn iter(&self) -> impl Iterator<Item = (Rank, &[f32])> {
        self.segments.iter().map(|(&k, v)| (k, v.as_slice()))
    }

    /// Keys in order.
    pub fn keys(&self) -> impl Iterator<Item = Rank> + '_ {
        self.segments.keys().copied()
    }

    pub fn n_bytes(&self) -> usize {
        self.segments.values().map(|v| v.len() * 4).sum()
    }

    pub fn n_elems(&self) -> usize {
        self.segments.values().map(|v| v.len()).sum()
    }

    /// Subset containing only the given ranks' segments (cheap: shares
    /// the underlying segment storage).
    pub fn select(&self, ranks: &[Rank]) -> Payload {
        let mut segments = BTreeMap::new();
        for &r in ranks {
            if let Some(v) = self.segments.get(&r) {
                segments.insert(r, v.clone());
            }
        }
        Payload { segments }
    }

    /// Subset containing the segments whose keys fall in one of the
    /// half-open `[lo, hi)` intervals. Like [`Payload::select`] this
    /// shares segment storage; unlike it, the walk is O(runs + hits)
    /// via the ordered map's range queries, never O(n) in the rank count.
    pub fn select_ranges(&self, ranges: &[(Rank, Rank)]) -> Payload {
        let mut segments = BTreeMap::new();
        for &(lo, hi) in ranges {
            for (&k, v) in self.segments.range(lo..hi) {
                segments.insert(k, v.clone());
            }
        }
        Payload { segments }
    }

    /// Union-merge (gather): disjoint keys required.
    pub fn union(&mut self, other: Payload) -> Result<(), String> {
        for (k, v) in other.segments {
            if self.segments.insert(k, v).is_some() {
                return Err(format!("duplicate segment for rank {k} in union"));
            }
        }
        Ok(())
    }

    /// Elementwise combine (reduce): keys and lengths must align.
    /// Copy-on-write: the accumulator segment is cloned only if shared.
    pub fn combine(&mut self, other: &Payload, op: ReduceOp, c: &dyn Combiner) -> Result<(), String> {
        if self.segments.len() != other.segments.len() {
            return Err(format!(
                "combine key-count mismatch: {} vs {}",
                self.segments.len(),
                other.segments.len()
            ));
        }
        for (k, src) in &other.segments {
            let acc = self
                .segments
                .get_mut(k)
                .ok_or_else(|| format!("combine missing segment {k}"))?;
            if acc.len() != src.len() {
                return Err(format!("combine length mismatch on segment {k}"));
            }
            c.combine(op, std::sync::Arc::make_mut(acc).as_mut_slice(), src.as_slice());
        }
        Ok(())
    }
}

/// The payload-register algebra the execution engine is generic over.
///
/// Two implementations exist: [`Payload`] (real f32 segments — full
/// semantic execution) and [`GhostPayload`] (per-key lengths only —
/// timing execution). The engine prices messages exclusively through
/// [`Register::n_bytes`], so any two registers that agree on key→length
/// maps produce bit-identical timing.
pub trait Register: Clone {
    /// The empty register (zero segments).
    fn empty() -> Self;

    /// Wire size of this register's segments, in bytes.
    fn n_bytes(&self) -> usize;

    /// Subset containing only the given ranks' segments.
    fn select(&self, ranks: &[Rank]) -> Self;

    /// Subset of the segments whose keys fall in one of the sorted,
    /// disjoint half-open `[lo, hi)` intervals.
    fn select_ranges(&self, ranges: &[(Rank, Rank)]) -> Self;

    /// Disjoint-union merge (gather); duplicate keys are an error.
    fn union(&mut self, other: Self) -> std::result::Result<(), String>;

    /// Elementwise combine (reduce): keys and lengths must align. The
    /// ghost implementation validates shapes and skips the arithmetic.
    fn combine(
        &mut self,
        other: &Self,
        op: ReduceOp,
        c: &dyn Combiner,
    ) -> std::result::Result<(), String>;
}

impl Register for Payload {
    fn empty() -> Self {
        Payload::default()
    }

    fn n_bytes(&self) -> usize {
        Payload::n_bytes(self)
    }

    fn select(&self, ranks: &[Rank]) -> Self {
        Payload::select(self, ranks)
    }

    fn select_ranges(&self, ranges: &[(Rank, Rank)]) -> Self {
        Payload::select_ranges(self, ranges)
    }

    fn union(&mut self, other: Self) -> std::result::Result<(), String> {
        Payload::union(self, other)
    }

    fn combine(
        &mut self,
        other: &Self,
        op: ReduceOp,
        c: &dyn Combiner,
    ) -> std::result::Result<(), String> {
        Payload::combine(self, other, op, c)
    }
}

/// A maximal run of consecutive segment keys `[lo, hi)`, each key
/// carrying `elems` f32 elements.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GhostRun {
    pub lo: Rank,
    pub hi: Rank,
    pub elems: usize,
}

/// Runs stored inline before spilling to the heap. Sized for the worst
/// payloads the compiled collectives move (chunked allreduce maps
/// coalesce to ≤ 3 runs; broadcast/reduce payloads are 1), so the hot
/// paths — clone-per-send, interval select — never allocate.
const GHOST_INLINE_RUNS: usize = 4;

/// Timing-only payload register: the key→length *shape* of a [`Payload`]
/// as coalesced [`GhostRun`]s, without the f32 data.
///
/// All operations are integer arithmetic on the run list; cloning a
/// ghost (the per-send cost of `SendPart::All`) is a small `memcpy` with
/// no allocation as long as the register stays within
/// `GHOST_INLINE_RUNS` runs. Invariant: runs are sorted by `lo`,
/// non-empty (`lo < hi`), pairwise disjoint, and adjacent runs with
/// equal `elems` are merged. Keys with `elems == 0` are real segments
/// (present key, zero bytes), exactly as in [`Payload`].
#[derive(Clone, Debug, Default)]
pub struct GhostPayload {
    inline: [GhostRun; GHOST_INLINE_RUNS],
    n_inline: usize,
    /// Overflow runs; non-empty only past the inline capacity.
    spill: Vec<GhostRun>,
}

impl PartialEq for GhostPayload {
    fn eq(&self, other: &Self) -> bool {
        // Canonical form makes run-sequence equality segment equality;
        // the derived impl would compare stale inline slots.
        self.n_runs() == other.n_runs()
            && (0..self.n_runs()).all(|i| self.run_at(i) == other.run_at(i))
    }
}

impl Eq for GhostPayload {}

impl GhostPayload {
    pub fn empty() -> Self {
        GhostPayload::default()
    }

    /// Single segment of `elems` elements keyed by `owner`.
    pub fn single(owner: Rank, elems: usize) -> Self {
        let mut g = GhostPayload::empty();
        g.push_run(GhostRun { lo: owner, hi: owner + 1, elems });
        g
    }

    /// The shape of a full payload: same keys, same per-key lengths.
    pub fn of(p: &Payload) -> Self {
        let mut g = GhostPayload::empty();
        for (k, seg) in p.iter() {
            g.push_run(GhostRun { lo: k, hi: k + 1, elems: seg.len() });
        }
        g
    }

    fn n_runs(&self) -> usize {
        self.n_inline + self.spill.len()
    }

    fn run_at(&self, i: usize) -> GhostRun {
        if i < self.n_inline {
            self.inline[i]
        } else {
            self.spill[i - self.n_inline]
        }
    }

    /// The coalesced runs, in key order.
    pub fn runs(&self) -> impl Iterator<Item = GhostRun> + '_ {
        (0..self.n_runs()).map(|i| self.run_at(i))
    }

    /// Append a run at the high end. Runs must arrive in strictly
    /// ascending, disjoint key order; contiguous equal-length runs are
    /// coalesced in place.
    fn push_run(&mut self, r: GhostRun) {
        if r.lo >= r.hi {
            return;
        }
        if self.n_runs() > 0 {
            let in_spill = !self.spill.is_empty();
            let last = if in_spill {
                self.spill.last_mut().expect("non-empty spill")
            } else {
                &mut self.inline[self.n_inline - 1]
            };
            debug_assert!(r.lo >= last.hi, "ghost runs must be appended in key order");
            if last.hi == r.lo && last.elems == r.elems {
                last.hi = r.hi;
                return;
            }
        }
        if self.n_inline < GHOST_INLINE_RUNS && self.spill.is_empty() {
            self.inline[self.n_inline] = r;
            self.n_inline += 1;
        } else {
            self.spill.push(r);
        }
    }

    /// Append one segment; keys must arrive in strictly ascending order
    /// (the encode-path builder, mirroring `Payload` construction via
    /// ordered `union`s).
    pub fn push_segment(&mut self, key: Rank, elems: usize) {
        self.push_run(GhostRun { lo: key, hi: key + 1, elems });
    }

    /// Number of segments (keys), matching [`Payload::len`].
    pub fn len(&self) -> usize {
        self.runs().map(|r| r.hi - r.lo).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.n_runs() == 0
    }

    pub fn n_bytes(&self) -> usize {
        self.runs().map(|r| (r.hi - r.lo) * r.elems * 4).sum()
    }

    pub fn n_elems(&self) -> usize {
        self.runs().map(|r| (r.hi - r.lo) * r.elems).sum()
    }

    /// Element count of the segment keyed `k`, if present.
    pub fn elems_at(&self, k: Rank) -> Option<usize> {
        self.runs().find(|r| r.lo <= k && k < r.hi).map(|r| r.elems)
    }

    pub fn contains_key(&self, k: Rank) -> bool {
        self.elems_at(k).is_some()
    }

    /// Subset containing only the given ranks' segments (missing ranks
    /// are silently skipped, duplicates collapse — [`Payload::select`]
    /// semantics).
    pub fn select(&self, ranks: &[Rank]) -> GhostPayload {
        if ranks.windows(2).all(|w| w[0] < w[1]) {
            return self.select_sorted(ranks.iter().copied());
        }
        let mut v: Vec<Rank> = ranks.to_vec();
        v.sort_unstable();
        v.dedup();
        self.select_sorted(v.into_iter())
    }

    fn select_sorted<I: Iterator<Item = Rank>>(&self, ranks: I) -> GhostPayload {
        let mut out = GhostPayload::empty();
        let n = self.n_runs();
        let mut i = 0;
        for k in ranks {
            while i < n && self.run_at(i).hi <= k {
                i += 1;
            }
            if i < n {
                let r = self.run_at(i);
                if r.lo <= k {
                    out.push_run(GhostRun { lo: k, hi: k + 1, elems: r.elems });
                }
            }
        }
        out
    }

    /// Subset of the segments whose keys fall in one of the sorted,
    /// disjoint half-open `[lo, hi)` intervals — O(runs + hits) interval
    /// intersection, the ghost counterpart of [`Payload::select_ranges`].
    pub fn select_ranges(&self, ranges: &[(Rank, Rank)]) -> GhostPayload {
        let mut out = GhostPayload::empty();
        let n = self.n_runs();
        let mut i = 0;
        for &(lo, hi) in ranges {
            while i < n && self.run_at(i).hi <= lo {
                i += 1;
            }
            while i < n {
                let r = self.run_at(i);
                if r.lo >= hi {
                    break;
                }
                let s = r.lo.max(lo);
                let e = r.hi.min(hi);
                if s < e {
                    out.push_run(GhostRun { lo: s, hi: e, elems: r.elems });
                }
                if r.hi <= hi {
                    i += 1;
                } else {
                    break; // run extends past this interval; revisit it
                }
            }
        }
        out
    }

    /// Union-merge (gather): disjoint keys required, [`Payload::union`]
    /// semantics (the reported duplicate is the smallest shared key).
    pub fn union(&mut self, other: GhostPayload) -> std::result::Result<(), String> {
        if other.is_empty() {
            return Ok(());
        }
        if self.is_empty() {
            *self = other;
            return Ok(());
        }
        let mut out = GhostPayload::empty();
        let (an, bn) = (self.n_runs(), other.n_runs());
        let (mut i, mut j) = (0, 0);
        while i < an || j < bn {
            let take_a = j >= bn || (i < an && self.run_at(i).lo <= other.run_at(j).lo);
            let (x, rest) = if take_a {
                (self.run_at(i), if j < bn { Some(other.run_at(j)) } else { None })
            } else {
                (other.run_at(j), if i < an { Some(self.run_at(i)) } else { None })
            };
            if let Some(y) = rest {
                if y.lo < x.hi {
                    return Err(format!("duplicate segment for rank {} in union", y.lo));
                }
            }
            out.push_run(x);
            if take_a {
                i += 1;
            } else {
                j += 1;
            }
        }
        *self = out;
        Ok(())
    }

    /// Shape validation of an elementwise combine: every key of `other`
    /// must exist here with an equal element count. Pure run arithmetic;
    /// error messages mirror [`Payload::combine`].
    pub fn combine_shapes(&self, other: &GhostPayload) -> std::result::Result<(), String> {
        if self.len() != other.len() {
            return Err(format!(
                "combine key-count mismatch: {} vs {}",
                self.len(),
                other.len()
            ));
        }
        let sn = self.n_runs();
        let mut i = 0;
        for o in other.runs() {
            let mut k = o.lo;
            while k < o.hi {
                while i < sn && self.run_at(i).hi <= k {
                    i += 1;
                }
                if i >= sn || self.run_at(i).lo > k {
                    return Err(format!("combine missing segment {k}"));
                }
                let s = self.run_at(i);
                if s.elems != o.elems {
                    return Err(format!("combine length mismatch on segment {k}"));
                }
                k = s.hi.min(o.hi);
            }
        }
        Ok(())
    }
}

impl Register for GhostPayload {
    fn empty() -> Self {
        GhostPayload::default()
    }

    fn n_bytes(&self) -> usize {
        GhostPayload::n_bytes(self)
    }

    fn select(&self, ranks: &[Rank]) -> Self {
        GhostPayload::select(self, ranks)
    }

    fn select_ranges(&self, ranges: &[(Rank, Rank)]) -> Self {
        GhostPayload::select_ranges(self, ranges)
    }

    fn union(&mut self, other: Self) -> std::result::Result<(), String> {
        GhostPayload::union(self, other)
    }

    fn combine(
        &mut self,
        other: &Self,
        _op: ReduceOp,
        _c: &dyn Combiner,
    ) -> std::result::Result<(), String> {
        // The accumulator's shape is unchanged by a valid combine, so
        // shape validation is the whole operation.
        self.combine_shapes(other)
    }
}

// The sharded engine moves registers between `std::thread` workers and
// shares the native combiner across them; pin those auto traits at
// compile time so a future `Rc`/`Cell` field fails here, not in a
// distant `thread::scope` bound.
const fn _assert_send_sync<T: Send + Sync>() {}
const _: () = {
    _assert_send_sync::<Payload>();
    _assert_send_sync::<GhostPayload>();
    _assert_send_sync::<NativeCombiner>();
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_apply_and_identity() {
        assert_eq!(ReduceOp::Sum.apply(2.0, 3.0), 5.0);
        assert_eq!(ReduceOp::Max.apply(2.0, 3.0), 3.0);
        assert_eq!(ReduceOp::Min.apply(2.0, 3.0), 2.0);
        assert_eq!(ReduceOp::Prod.apply(2.0, 3.0), 6.0);
        for op in ReduceOp::ALL {
            assert_eq!(op.apply(op.identity(), 7.0), 7.0);
        }
    }

    #[test]
    fn payload_sizes() {
        let p = Payload::single(3, vec![1.0; 10]);
        assert_eq!(p.n_bytes(), 40);
        assert_eq!(p.n_elems(), 10);
        assert_eq!(Payload::empty().n_bytes(), 0);
    }

    #[test]
    fn select_subsets() {
        let mut p = Payload::single(0, vec![1.0]);
        p.union(Payload::single(1, vec![2.0, 2.0])).unwrap();
        p.union(Payload::single(2, vec![3.0])).unwrap();
        let s = p.select(&[1, 2]);
        assert_eq!(s.segments.len(), 2);
        assert!(s.segments.contains_key(&1));
        assert!(!s.segments.contains_key(&0));
        // selecting a missing rank is silently empty for that key
        assert_eq!(p.select(&[9]).segments.len(), 0);
    }

    #[test]
    fn select_ranges_matches_select() {
        let mut p = Payload::empty();
        for k in [0usize, 1, 2, 5, 6, 9] {
            p.union(Payload::single(k, vec![k as f32])).unwrap();
        }
        let by_ranges = p.select_ranges(&[(0, 3), (5, 7)]);
        let by_ranks = p.select(&[0, 1, 2, 5, 6]);
        assert_eq!(by_ranges, by_ranks);
        // intervals spanning absent keys select only what exists
        assert_eq!(p.select_ranges(&[(3, 5)]).len(), 0);
        assert_eq!(p.select_ranges(&[(0, 10)]).len(), 6);
    }

    #[test]
    fn union_rejects_duplicates() {
        let mut p = Payload::single(0, vec![1.0]);
        assert!(p.union(Payload::single(0, vec![2.0])).is_err());
    }

    #[test]
    fn combine_native_all_ops() {
        let c = NativeCombiner;
        for (op, expect) in [
            (ReduceOp::Sum, vec![5.0, 7.0]),
            (ReduceOp::Max, vec![4.0, 5.0]),
            (ReduceOp::Min, vec![1.0, 2.0]),
            (ReduceOp::Prod, vec![4.0, 10.0]),
        ] {
            let mut acc = Payload::single(0, vec![1.0, 5.0]);
            let src = Payload::single(0, vec![4.0, 2.0]);
            acc.combine(&src, op, &c).unwrap();
            assert_eq!(acc.get(&0).unwrap(), expect.as_slice(), "{op:?}");
        }
    }

    #[test]
    fn combine_shape_mismatches_rejected() {
        let c = NativeCombiner;
        let mut a = Payload::single(0, vec![1.0]);
        let b = Payload::single(1, vec![1.0]);
        assert!(a.combine(&b, ReduceOp::Sum, &c).is_err());
        let b2 = Payload::single(0, vec![1.0, 2.0]);
        assert!(a.combine(&b2, ReduceOp::Sum, &c).is_err());
    }

    /// `{0: n, 1: n, ..., k-1: n}` — the chunk-map shape.
    fn ghost_uniform(keys: usize, elems: usize) -> GhostPayload {
        let mut g = GhostPayload::empty();
        for k in 0..keys {
            g.push_segment(k, elems);
        }
        g
    }

    #[test]
    fn ghost_of_payload_preserves_shape() {
        let mut p = Payload::single(0, vec![1.0; 3]);
        p.union(Payload::single(1, vec![2.0; 3])).unwrap();
        p.union(Payload::single(5, vec![3.0; 7])).unwrap();
        p.union(Payload::single(6, vec![0.0; 0])).unwrap();
        let g = GhostPayload::of(&p);
        assert_eq!(g.len(), p.len());
        assert_eq!(g.n_bytes(), p.n_bytes());
        assert_eq!(g.n_elems(), p.n_elems());
        assert_eq!(g.elems_at(0), Some(3));
        assert_eq!(g.elems_at(5), Some(7));
        assert_eq!(g.elems_at(6), Some(0), "zero-length segments are real keys");
        assert_eq!(g.elems_at(4), None);
        // runs 0..2 coalesce; 5 and 6 differ in length and stay separate
        assert_eq!(g.runs().count(), 3);
    }

    #[test]
    fn ghost_select_matches_payload_select() {
        let mut p = Payload::empty();
        for k in [0usize, 1, 2, 5, 6, 9] {
            p.union(Payload::single(k, vec![k as f32; k + 1])).unwrap();
        }
        let g = GhostPayload::of(&p);
        for ranks in [
            vec![0usize, 1, 2],
            vec![9, 5, 0],
            vec![3, 4],
            vec![2, 2, 5],
            vec![],
        ] {
            let full = p.select(&ranks);
            let ghost = g.select(&ranks);
            assert_eq!(ghost, GhostPayload::of(&full), "{ranks:?}");
        }
        for ranges in [vec![(0usize, 3usize), (5, 7)], vec![(3, 5)], vec![(0, 10)]] {
            let full = p.select_ranges(&ranges);
            let ghost = g.select_ranges(&ranges);
            assert_eq!(ghost, GhostPayload::of(&full), "{ranges:?}");
        }
    }

    #[test]
    fn ghost_union_merges_and_rejects_duplicates() {
        let mut a = ghost_uniform(3, 4); // keys 0..3
        let b = GhostPayload::single(5, 4);
        a.union(b).unwrap();
        assert_eq!(a.len(), 4);
        assert_eq!(a.n_bytes(), 4 * 4 * 4);
        let dup = GhostPayload::single(1, 4);
        let err = a.union(dup).unwrap_err();
        assert!(err.contains("duplicate segment for rank 1"), "{err}");
        // interleave: {0,2} ∪ {1} coalesces to one run
        let mut x = GhostPayload::single(0, 2);
        x.union(GhostPayload::single(2, 2)).unwrap();
        x.union(GhostPayload::single(1, 2)).unwrap();
        assert_eq!(x.runs().count(), 1);
        assert_eq!(x.len(), 3);
    }

    #[test]
    fn ghost_combine_shape_checks_mirror_payload() {
        let a = ghost_uniform(4, 8);
        assert!(a.combine_shapes(&ghost_uniform(4, 8)).is_ok());
        let err = a.combine_shapes(&ghost_uniform(3, 8)).unwrap_err();
        assert!(err.contains("key-count mismatch"), "{err}");
        let err = a.combine_shapes(&ghost_uniform(4, 9)).unwrap_err();
        assert!(err.contains("length mismatch"), "{err}");
        let mut shifted = GhostPayload::empty();
        for k in 1..5 {
            shifted.push_segment(k, 8);
        }
        let err = a.combine_shapes(&shifted).unwrap_err();
        assert!(err.contains("missing segment 4"), "{err}");
    }

    #[test]
    fn ghost_spills_past_inline_capacity() {
        // Alternating lengths defeat coalescing: every key is its own run.
        let mut g = GhostPayload::empty();
        for k in 0..10 {
            g.push_segment(k, k % 2);
        }
        assert_eq!(g.runs().count(), 10);
        assert_eq!(g.len(), 10);
        assert_eq!(g.elems_at(9), Some(1));
        let h = g.clone();
        assert_eq!(g, h);
        assert_eq!(g.select_ranges(&[(2, 7)]).len(), 5);
    }
}
