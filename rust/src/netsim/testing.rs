//! Differential-testing support: the retired pre-ready-queue scheduler.
//!
//! [`run_rescan`] is the engine the ready-queue rewrite replaced — a
//! deterministic worklist fixpoint that rescans every rank (including
//! blocked ones) until quiescence. It is deliberately kept as a second,
//! independent implementation of the execution semantics so the
//! equivalence suite (`rust/tests/ghost_equivalence.rs`) and the
//! `engine_throughput` bench can pin the production scheduler against
//! it bit-for-bit; it is **not** part of the supported API surface and
//! is not tuned (full-payload mode only, hash-map mailboxes, O(n_ranks)
//! scheduling steps).
//!
//! This lives in a `#[doc(hidden)]` module rather than `#[cfg(test)]`
//! because integration tests and benches link against the public crate:
//! a `cfg(test)` item would be invisible to them.

use crate::error::{Error, Result};
use crate::netsim::engine::{SimConfig, SimResult, TraceEvent, TraceKind};
use crate::netsim::payload::{Combiner, Payload, Rank};
use crate::netsim::program::{Action, Merge, Program, SendPart};
use crate::topology::Clustering;
use crate::util::counters;
use std::collections::{BTreeMap, HashMap, VecDeque};

struct RankState {
    idx: usize,
    clock: f64,
    payload: Payload,
}

/// The pre-ready-queue scheduler, retained as a differential oracle:
/// results must be bit-identical to `netsim::run`'s.
pub fn run_rescan(
    clustering: &Clustering,
    prog: &Program,
    initial: Vec<Payload>,
    cfg: &SimConfig,
    combiner: &dyn Combiner,
) -> Result<SimResult> {
    let n = prog.n_ranks();
    if clustering.n_ranks() != n {
        return Err(Error::Sim(format!(
            "clustering has {} ranks, program has {n}",
            clustering.n_ranks()
        )));
    }
    if initial.len() != n {
        return Err(Error::Sim(format!("initial payloads: {} != {n}", initial.len())));
    }
    counters::count_sim_run();
    let n_levels = clustering.n_levels();
    let mut states: Vec<RankState> = initial
        .into_iter()
        .map(|payload| RankState { idx: 0, clock: 0.0, payload })
        .collect();
    // In-flight messages: (from, to, tag) -> FIFO of (arrival_time, payload).
    let mut mailbox: HashMap<(Rank, Rank, u64), VecDeque<(f64, Payload)>> = HashMap::new();
    let mut msgs_by_sep = vec![0u64; n_levels];
    let mut bytes_by_sep = vec![0u64; n_levels];
    let mut combines = 0u64;
    let mut trace = Vec::new();
    let mut mark_times: BTreeMap<u64, f64> = BTreeMap::new();

    loop {
        let mut progressed = false;
        let mut all_done = true;
        for r in 0..n {
            // Advance rank r as far as possible.
            loop {
                let action = match prog.actions[r].get(states[r].idx) {
                    None => break,
                    Some(a) => a,
                };
                match *action {
                    Action::Send { to, tag, ref part } => {
                        let st = &mut states[r];
                        let out = match part {
                            SendPart::All => st.payload.clone(),
                            SendPart::Ranks(rs) => st.payload.select(rs),
                            SendPart::Ranges(rs) => st.payload.select_ranges(rs),
                            SendPart::Empty => Payload::empty(),
                        };
                        let bytes = out.n_bytes();
                        let sep = clustering.sep(r, to);
                        let link = cfg.params.at_sep(sep);
                        let start = st.clock;
                        let arrival = start + link.arrival_delay_us(bytes);
                        st.clock = start + link.sender_busy_us(bytes);
                        st.idx += 1;
                        msgs_by_sep[sep - 1] += 1;
                        bytes_by_sep[sep - 1] += bytes as u64;
                        if cfg.trace {
                            trace.push(TraceEvent {
                                t_us: start,
                                rank: r,
                                kind: TraceKind::SendStart,
                                peer: to,
                                tag,
                                bytes,
                                sep,
                            });
                        }
                        mailbox.entry((r, to, tag)).or_default().push_back((arrival, out));
                        progressed = true;
                    }
                    Action::Recv { from, tag, merge } => {
                        let key = (from, r, tag);
                        let msg = mailbox.get_mut(&key).and_then(|q| q.pop_front());
                        let (arrival, incoming) = match msg {
                            Some(m) => m,
                            None => break, // blocked; try other ranks
                        };
                        let sep = clustering.sep(from, r);
                        let link = cfg.params.at_sep(sep);
                        let bytes = incoming.n_bytes();
                        let st = &mut states[r];
                        st.clock = st.clock.max(arrival) + link.recv_overhead_us;
                        match merge {
                            Merge::Replace => st.payload = incoming,
                            Merge::Discard => {}
                            Merge::Union => {
                                st.payload.union(incoming).map_err(Error::Sim)?
                            }
                            Merge::Combine(op) => {
                                st.clock += cfg.params.combine_us(bytes);
                                combines += 1;
                                st.payload
                                    .combine(&incoming, op, combiner)
                                    .map_err(Error::Sim)?;
                            }
                        }
                        st.idx += 1;
                        if cfg.trace {
                            trace.push(TraceEvent {
                                t_us: states[r].clock,
                                rank: r,
                                kind: TraceKind::RecvDone,
                                peer: from,
                                tag,
                                bytes,
                                sep,
                            });
                        }
                        progressed = true;
                    }
                    Action::Mark { id } => {
                        let t = states[r].clock;
                        states[r].idx += 1;
                        let slot = mark_times.entry(id).or_insert(t);
                        if t > *slot {
                            *slot = t;
                        }
                        progressed = true;
                    }
                }
            }
            if states[r].idx < prog.actions[r].len() {
                all_done = false;
            }
        }
        if all_done {
            break;
        }
        if !progressed {
            let stuck: Vec<usize> =
                (0..n).filter(|&r| states[r].idx < prog.actions[r].len()).collect();
            let detail = stuck
                .iter()
                .take(4)
                .map(|&r| format!("rank {r} at action {:?}", prog.actions[r][states[r].idx]))
                .collect::<Vec<_>>()
                .join("; ");
            return Err(Error::Deadlock { stuck_ranks: stuck, detail });
        }
    }

    // Deterministic undelivered-message report (sorted by channel key).
    let mut undelivered: Vec<((Rank, Rank, u64), usize)> = mailbox
        .iter()
        .filter(|(_, q)| !q.is_empty())
        .map(|(&k, q)| (k, q.len()))
        .collect();
    undelivered.sort_unstable();
    if let Some(&((f, t, tag), count)) = undelivered.first() {
        return Err(Error::Sim(format!(
            "{count} undelivered message(s) on channel {f}->{t} tag {tag}"
        )));
    }

    let finish_us: Vec<f64> = states.iter().map(|s| s.clock).collect();
    let makespan_us = finish_us.iter().fold(0.0f64, |a, &b| a.max(b));
    trace.sort_by(|a, b| a.t_us.total_cmp(&b.t_us));
    Ok(SimResult {
        finish_us,
        makespan_us,
        msgs_by_sep,
        bytes_by_sep,
        combines,
        payloads: states.into_iter().map(|s| s.payload).collect(),
        mark_times_us: mark_times.into_iter().collect(),
        trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LinkParams, NetworkParams};
    use crate::netsim::payload::NativeCombiner;
    use crate::netsim::run;

    fn simple_params() -> NetworkParams {
        NetworkParams::new(vec![LinkParams::new(100.0, 1.0).with_overheads(10.0, 5.0)])
            .with_combine_us_per_byte(0.0)
    }

    #[test]
    fn rescan_oracle_agrees_with_ready_queue() {
        // A program with cross-rank blocking: 0 -> 1 -> 2 -> 0 ring.
        let mut p = Program::new(3);
        p.send(0, 1, 1, SendPart::All);
        p.recv(1, 0, 1, Merge::Replace);
        p.send(1, 2, 2, SendPart::All);
        p.recv(2, 1, 2, Merge::Replace);
        p.send(2, 0, 3, SendPart::All);
        p.recv(0, 2, 3, Merge::Replace);
        let init =
            vec![Payload::single(0, vec![7.0; 8]), Payload::empty(), Payload::empty()];
        let cfg = SimConfig::new(simple_params());
        let a = run(&Clustering::flat(3), &p, init.clone(), &cfg, &NativeCombiner).unwrap();
        let b = run_rescan(&Clustering::flat(3), &p, init, &cfg, &NativeCombiner).unwrap();
        assert_eq!(a.finish_us, b.finish_us);
        assert_eq!(a.msgs_by_sep, b.msgs_by_sep);
        assert_eq!(a.bytes_by_sep, b.bytes_by_sep);
        assert_eq!(a.payloads, b.payloads);
    }
}
