//! The discrete-event execution engine.
//!
//! Executes a [`Program`] over a communicator's clustering under a
//! [`NetworkParams`] cost model. Timing follows the postal/LogGP
//! conventions documented in [`crate::model`]: endpoint occupancy, no
//! shared-link contention (§4 of the paper reasons under the same model).
//!
//! Three orthogonal axes, one core:
//!
//! - **Register mode.** The core is generic over [`Register`]: [`run`]
//!   executes full [`Payload`]s (real f32 segments, semantic
//!   verification), [`run_timing`] executes [`GhostPayload`]s (per-key
//!   lengths only). The cost model prices messages exclusively through
//!   `n_bytes()`, so both modes produce **bit-identical**
//!   `finish_us` / `makespan_us` / `msgs_by_sep` / `bytes_by_sep` /
//!   `mark_times_us`; ghost mode allocates no payload data and performs
//!   no combine arithmetic.
//! - **Scheduling.** Ranks advance through an event-driven ready queue:
//!   a rank blocked on a `Recv` parks in a per-channel wait slot and is
//!   woken by the matching `Send`, so each scheduling step is O(ready
//!   work) instead of the previous fixpoint loop's O(n_ranks) rescans of
//!   blocked ranks. Channel lookup is a dense [`ChannelIndex`] (cached
//!   on plans/schedules; rebuilt per call for ad-hoc programs), so warm
//!   executions hash nothing. Results are order-independent: each rank's
//!   program is sequential and arrival times depend only on the sender's
//!   progress, so any scheduling order yields identical clocks — the old
//!   rescan loop survives as `netsim::testing::run_rescan`, a
//!   differential-testing oracle off the shipped surface.
//! - **Execution mode.** The same ready-queue loop doubles as the
//!   per-shard body of the sharded engine (`run_core_sharded`, reached
//!   through [`run_indexed_scratch_sharded`] /
//!   [`run_timing_indexed_scratch_sharded`]): a [`ShardMap`]'s cluster
//!   *tree* is carved into shards by [`ShardMap::cut`] — recursively
//!   splitting the largest shard along its shallowest branching level,
//!   so a deep single-site topology shards as well as a multi-site
//!   grid — and a pool of interchangeable workers pulls runnable shards
//!   off a shared run queue (sibling work-stealing). Intra-shard
//!   messages never leave their shard's arena; boundary sends cross
//!   through per-shard inboxes under one mutex. Programs are blocking
//!   dataflow over single-sender channels (see `netsim::shard` for why
//!   that implies confluence), so any worker interleaving produces the
//!   same per-channel FIFO order and the sharded result is **bitwise
//!   identical** to the sequential engine's — which therefore stays the
//!   differential oracle for the parallel path, exactly as the rescan
//!   loop is for the ready queue. Traces are canonically sorted by a
//!   total event key in both modes, so even tied timestamps merge
//!   deterministically.
//!
//! The per-run working state (mailbox channels, wait slots, ready queue,
//! per-rank cursors and clocks, accounting vectors) lives in a reusable
//! [`EngineScratch`] arena: callers that hold one across runs — every
//! `CollectiveEngine` / `GridSession` does, via [`ExecScratch`] — pay the
//! allocations once and recycle the capacity on every later run
//! ([`crate::util::counters::count_scratch_alloc`] counts arena growth,
//! so tests can assert a warm ghost sweep grows nothing).
//!
//! Quiescence before completion is a deadlock and is reported with the
//! stuck ranks.

use crate::error::{Error, Result};
use crate::model::NetworkParams;
use crate::netsim::payload::{Combiner, GhostPayload, NativeCombiner, Payload, Rank, Register};
use crate::netsim::program::{Action, ChannelIndex, Merge, Program, SendPart};
use crate::netsim::shard::{ShardCut, ShardMap, DEFAULT_MIN_SHARD_RANKS};
use crate::topology::Clustering;
use crate::util::counters;
use std::collections::{BTreeMap, VecDeque};
use std::ops::{Deref, DerefMut};
use std::sync::{Condvar, Mutex, MutexGuard};

/// One trace record (enabled via `SimConfig::trace`).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub t_us: f64,
    pub rank: Rank,
    pub kind: TraceKind,
    pub peer: Rank,
    pub tag: u64,
    pub bytes: usize,
    pub sep: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    SendStart,
    RecvDone,
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub params: NetworkParams,
    /// Record per-message trace events (adds allocation; off for benches).
    pub trace: bool,
}

impl SimConfig {
    pub fn new(params: NetworkParams) -> Self {
        SimConfig { params, trace: false }
    }

    pub fn with_trace(mut self) -> Self {
        self.trace = true;
        self
    }
}

/// Everything the simulation produces.
///
/// A default-constructed result is an empty shell whose buffers the
/// `*_into` entry points fill in place — callers that hold one across
/// runs (sessions, tuners, benches) recycle every vector's capacity
/// instead of allocating a fresh result per probe.
#[derive(Clone, Debug, Default)]
pub struct SimResult {
    /// Per-rank local completion time (us).
    pub finish_us: Vec<f64>,
    /// max over ranks.
    pub makespan_us: f64,
    /// Message count by separation level (index `sep-1`; index 0 = WAN).
    pub msgs_by_sep: Vec<u64>,
    /// Bytes by separation level.
    pub bytes_by_sep: Vec<u64>,
    /// Number of combine invocations (reduce arithmetic).
    pub combines: u64,
    /// Final payload register of every rank (for semantic verification).
    /// **Empty for timing-mode runs** ([`run_timing`]): ghost registers
    /// carry no data worth returning, and the timing fields above are
    /// bit-identical to the full run's.
    pub payloads: Vec<Payload>,
    /// Completion timestamp per boundary marker, sorted by marker id:
    /// `(id, t_us)` where `t_us` is the max local clock over every rank
    /// that executed `Action::Mark { id }`. Empty for mark-free programs.
    /// Fused schedules use consecutive ids, so this is the cumulative
    /// per-segment completion profile of a single run.
    pub mark_times_us: Vec<(u64, f64)>,
    /// Trace (empty unless enabled).
    pub trace: Vec<TraceEvent>,
}

impl SimResult {
    /// Total messages at the WAN boundary (sep 1) — the paper's headline
    /// count.
    ///
    /// This is the **single source of truth** for WAN message counts:
    /// every layer (engine outcomes, experiment tables, training logs)
    /// reads it from here rather than indexing `msgs_by_sep[0]` directly,
    /// so the "sep 1 == WAN" convention lives in exactly one place. For
    /// the *static* (pre-execution) count of a cached plan, see
    /// `plan::PlanMeta::wan_messages`, which is defined to agree with this
    /// accessor for every op.
    pub fn wan_messages(&self) -> u64 {
        self.msgs_by_sep.first().copied().unwrap_or(0)
    }
}

/// A mailbox channel: zero / one / many in-flight messages. Single-use
/// channels — the overwhelmingly common case for compiled collectives,
/// where every `(from, to, tag)` carries exactly one message — never
/// allocate queue storage.
enum Chan<R> {
    Empty,
    One(f64, R),
    Many(VecDeque<(f64, R)>),
}

impl<R> Chan<R> {
    fn push(&mut self, t: f64, m: R) {
        match self {
            Chan::Empty => *self = Chan::One(t, m),
            Chan::One(..) => {
                let Chan::One(t0, m0) = std::mem::replace(self, Chan::Empty) else {
                    unreachable!()
                };
                let mut q = VecDeque::with_capacity(2);
                q.push_back((t0, m0));
                q.push_back((t, m));
                *self = Chan::Many(q);
            }
            Chan::Many(q) => q.push_back((t, m)),
        }
    }

    fn pop(&mut self) -> Option<(f64, R)> {
        match self {
            Chan::Empty => None,
            Chan::One(..) => {
                let Chan::One(t, m) = std::mem::replace(self, Chan::Empty) else {
                    unreachable!()
                };
                Some((t, m))
            }
            Chan::Many(q) => q.pop_front(),
        }
    }

    fn len(&self) -> usize {
        match self {
            Chan::Empty => 0,
            Chan::One(..) => 1,
            Chan::Many(q) => q.len(),
        }
    }
}

/// Canonical trace order: by timestamp (NaN-safe total order — clocks
/// are finite, but a cost model handing back a NaN must not panic the
/// sort), ties broken by the full event key. Sequential and sharded
/// executions produce the same event *multiset*, so sorting by a total
/// key makes the traces themselves bitwise comparable.
fn sort_trace(trace: &mut [TraceEvent]) {
    trace.sort_by(|a, b| {
        a.t_us.total_cmp(&b.t_us).then_with(|| {
            (a.rank, a.kind as u8, a.peer, a.tag, a.bytes, a.sep).cmp(&(
                b.rank,
                b.kind as u8,
                b.peer,
                b.tag,
                b.bytes,
                b.sep,
            ))
        })
    });
}

/// Levels held inline by [`SepCounts`] — every clustering in the paper
/// (site / machine / processor, plus the flat degenerate) fits.
pub const SEP_INLINE_LEVELS: usize = 4;

/// Small-vector accumulator for the per-separation-level counters
/// (`msgs_by_sep` / `bytes_by_sep`): clusterings of up to
/// [`SEP_INLINE_LEVELS`] levels accumulate entirely on the stack, so
/// merging per-shard partial accounting allocates nothing; deeper
/// hierarchies spill to a heap vector.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SepCounts {
    inline: [u64; SEP_INLINE_LEVELS],
    spill: Vec<u64>,
    len: usize,
}

impl SepCounts {
    /// A zeroed accumulator over `n_levels` separation levels.
    pub fn new(n_levels: usize) -> Self {
        let spill = if n_levels > SEP_INLINE_LEVELS { vec![0; n_levels] } else { Vec::new() };
        SepCounts { inline: [0; SEP_INLINE_LEVELS], spill, len: n_levels }
    }

    /// Number of separation levels.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Add `v` at separation index `level` (0-based, i.e. `sep - 1`).
    #[inline]
    pub fn add(&mut self, level: usize, v: u64) {
        if self.len <= SEP_INLINE_LEVELS {
            self.inline[level] += v;
        } else {
            self.spill[level] += v;
        }
    }

    /// Element-wise accumulate a full per-level slice.
    pub fn add_slice(&mut self, counts: &[u64]) {
        debug_assert_eq!(counts.len(), self.len);
        for (i, &v) in counts.iter().enumerate() {
            self.add(i, v);
        }
    }

    /// The accumulated counts, `[sep-1]`-indexed like `SimResult`'s.
    pub fn as_slice(&self) -> &[u64] {
        if self.len <= SEP_INLINE_LEVELS {
            &self.inline[..self.len]
        } else {
            &self.spill
        }
    }
}

/// No rank parked on this channel.
const NO_WAITER: usize = usize::MAX;

/// Reusable per-run working state of the execution core: the mailbox
/// channels, per-channel wait slots, the ready queue, per-rank program
/// cursors and clocks, and the per-level accounting vectors.
///
/// A fresh arena is empty; the first run sizes it to its program
/// (counted once via [`counters::count_scratch_alloc`]) and every later
/// run whose program needs no more capacity recycles the storage with
/// **zero** allocations. Engines and sessions hold one arena per
/// register mode (see [`ExecScratch`]) so back-to-back ghost probes are
/// allocation-free end to end.
pub struct EngineScratch<R> {
    mailbox: Vec<Chan<R>>,
    /// `waiting[c]` = the rank parked on channel `c`'s next message. At
    /// most one rank can ever wait per channel (the channel's receiver).
    waiting: Vec<usize>,
    ready: VecDeque<Rank>,
    clocks: Vec<f64>,
    cursor: Vec<usize>,
    msgs_by_sep: Vec<u64>,
    bytes_by_sep: Vec<u64>,
}

impl<R> EngineScratch<R> {
    /// An empty arena (no storage until the first run sizes it).
    pub fn new() -> Self {
        EngineScratch {
            mailbox: Vec::new(),
            waiting: Vec::new(),
            ready: VecDeque::new(),
            clocks: Vec::new(),
            cursor: Vec::new(),
            msgs_by_sep: Vec::new(),
            bytes_by_sep: Vec::new(),
        }
    }

    /// Reset for a run over `n` ranks, `n_chan` channels and `n_levels`
    /// separation levels, reusing existing capacity. Growth (a run
    /// larger than anything this arena has executed) is counted once.
    fn prepare(&mut self, n: usize, n_chan: usize, n_levels: usize) {
        self.prepare_ranks(n, n_chan, n_levels, 0..n);
    }

    /// [`Self::prepare`] with an explicit initial ready set — the shard
    /// workers seed only the ranks their shard owns. The ready queue is
    /// still reserved to `n` so the capacity check (and therefore the
    /// `scratch_allocs` counter) stabilizes after the first run.
    fn prepare_ranks(
        &mut self,
        n: usize,
        n_chan: usize,
        n_levels: usize,
        ready: impl IntoIterator<Item = Rank>,
    ) {
        if self.mailbox.capacity() < n_chan
            || self.waiting.capacity() < n_chan
            || self.ready.capacity() < n
            || self.clocks.capacity() < n
            || self.cursor.capacity() < n
            || self.msgs_by_sep.capacity() < n_levels
            || self.bytes_by_sep.capacity() < n_levels
        {
            counters::count_scratch_alloc();
        }
        self.mailbox.clear();
        self.mailbox.resize_with(n_chan, || Chan::Empty);
        self.waiting.clear();
        self.waiting.resize(n_chan, NO_WAITER);
        self.ready.clear();
        self.ready.reserve(n);
        self.ready.extend(ready);
        self.clocks.clear();
        self.clocks.resize(n, 0.0);
        self.cursor.clear();
        self.cursor.resize(n, 0);
        self.msgs_by_sep.clear();
        self.msgs_by_sep.resize(n_levels, 0);
        self.bytes_by_sep.clear();
        self.bytes_by_sep.resize(n_levels, 0);
    }
}

impl<R> Default for EngineScratch<R> {
    fn default() -> Self {
        EngineScratch::new()
    }
}

/// Both register modes' scratch arenas behind one shareable handle —
/// what a `CollectiveEngine` holds (and a `GridSession` shares across
/// the engines it hands out), so full-mode steps and ghost probes each
/// recycle their own arena.
pub struct ExecScratch {
    full: Mutex<EngineScratch<Payload>>,
    /// LIFO pool of ghost arenas: a single-threaded caller always gets
    /// the same (fully sized) arena back, keeping warm probes
    /// allocation-free, while parallel tuner fan-out checks out one
    /// arena per concurrent probe instead of serializing on a mutex.
    ghost: Mutex<Vec<EngineScratch<GhostPayload>>>,
    /// Per-shard arena pools for the sharded engine, one per register
    /// mode — sized on first sharded run, recycled thereafter.
    full_shards: Mutex<ShardPool<Payload>>,
    ghost_shards: Mutex<ShardPool<GhostPayload>>,
}

impl ExecScratch {
    pub fn new() -> Self {
        ExecScratch {
            full: Mutex::new(EngineScratch::new()),
            ghost: Mutex::new(Vec::new()),
            full_shards: Mutex::new(ShardPool::new()),
            ghost_shards: Mutex::new(ShardPool::new()),
        }
    }

    /// Lock the full-payload arena.
    pub fn full(&self) -> MutexGuard<'_, EngineScratch<Payload>> {
        self.full.lock().unwrap()
    }

    /// Check a ghost (timing-only) arena out of the pool; it returns on
    /// drop. The pool is LIFO, so a lone caller recycles one arena
    /// forever and concurrent callers each get their own.
    pub fn ghost(&self) -> GhostArena<'_> {
        let arena = self.ghost.lock().unwrap().pop().unwrap_or_default();
        GhostArena { pool: &self.ghost, arena: Some(arena) }
    }

    /// Ghost arenas currently parked in the pool (none checked out):
    /// the high-water mark of concurrent ghost probes this scratch has
    /// served — `gridd stats` reports it per worker.
    pub fn ghost_pool_size(&self) -> usize {
        self.ghost.lock().unwrap().len()
    }
}

/// A ghost arena checked out of [`ExecScratch::ghost`]'s pool; derefs
/// to the [`EngineScratch`] and returns itself to the pool on drop.
pub struct GhostArena<'a> {
    pool: &'a Mutex<Vec<EngineScratch<GhostPayload>>>,
    arena: Option<EngineScratch<GhostPayload>>,
}

impl Deref for GhostArena<'_> {
    type Target = EngineScratch<GhostPayload>;
    fn deref(&self) -> &Self::Target {
        self.arena.as_ref().expect("arena present until drop")
    }
}

impl DerefMut for GhostArena<'_> {
    fn deref_mut(&mut self) -> &mut Self::Target {
        self.arena.as_mut().expect("arena present until drop")
    }
}

impl Drop for GhostArena<'_> {
    fn drop(&mut self) {
        if let Some(arena) = self.arena.take() {
            self.pool.lock().unwrap().push(arena);
        }
    }
}

impl Default for ExecScratch {
    fn default() -> Self {
        ExecScratch::new()
    }
}

/// Shared input validation for both execution modes. Error strings are
/// part of the engines' observable behavior and must stay identical.
fn validate_inputs(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    n_regs: usize,
) -> Result<()> {
    let n = prog.n_ranks();
    if clustering.n_ranks() != n {
        return Err(Error::Sim(format!(
            "clustering has {} ranks, program has {n}",
            clustering.n_ranks()
        )));
    }
    if n_regs != n {
        return Err(Error::Sim(format!("initial payloads: {n_regs} != {n}")));
    }
    if !index.matches(prog) {
        return Err(Error::Sim("channel index does not match program shape".into()));
    }
    // Shape coincidence is not identity: catch a stale index exactly in
    // debug builds (tests), keep warm release runs O(1) here.
    debug_assert!(
        index.consistent_with(prog),
        "channel index was built for a different program of the same shape"
    );
    Ok(())
}

/// The ready-queue inner loop shared **verbatim** by the sequential core
/// and every shard worker — one implementation of the execution
/// semantics, so the two modes cannot drift. Drains `scratch.ready`
/// until every runnable rank has finished (`*live` reaches the count of
/// unfinished ranks parked on empty channels) or parked.
///
/// `route` discriminates the modes: `None` delivers every send into the
/// local mailbox (sequential); `Some((shard_of_chan, me))` diverts sends
/// on channels owned by another shard into `outbox` as
/// `(dest_shard, channel, arrival_us, message)` for the caller to flush
/// across the shard boundary.
#[allow(clippy::too_many_arguments)]
fn drain_ready<R: Register>(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    regs: &mut [R],
    cfg: &SimConfig,
    combiner: &dyn Combiner,
    scratch: &mut EngineScratch<R>,
    route: Option<(&[u32], u32)>,
    outbox: &mut Vec<(u32, u32, f64, R)>,
    trace: &mut Vec<TraceEvent>,
    marks: &mut BTreeMap<u64, f64>,
    combines: &mut u64,
    recvs: &mut u64,
    live: &mut usize,
) -> Result<()> {
    // Every unfinished rank is in exactly one place: the ready queue, a
    // wait slot, or currently executing — so each scheduling step costs
    // O(actions retired), never O(n_ranks).
    while let Some(r) = scratch.ready.pop_front() {
        // Advance rank r until it finishes or blocks on an empty channel.
        loop {
            // Borrow the action in place (no clone: `SendPart::Ranks`
            // carries key vectors that are expensive to copy per
            // execution — §Perf L3 optimization #2).
            let action = match prog.actions[r].get(scratch.cursor[r]) {
                None => {
                    *live -= 1;
                    break;
                }
                Some(a) => a,
            };
            let chan = index.at(r, scratch.cursor[r]) as usize;
            match *action {
                Action::Send { to, tag, ref part } => {
                    let out = match part {
                        SendPart::All => regs[r].clone(),
                        SendPart::Ranks(rs) => regs[r].select(rs),
                        SendPart::Ranges(rs) => regs[r].select_ranges(rs),
                        SendPart::Empty => R::empty(),
                    };
                    let bytes = out.n_bytes();
                    let sep = clustering.sep(r, to);
                    let link = cfg.params.at_sep(sep);
                    let start = scratch.clocks[r];
                    let arrival = start + link.arrival_delay_us(bytes);
                    scratch.clocks[r] = start + link.sender_busy_us(bytes);
                    scratch.cursor[r] += 1;
                    scratch.msgs_by_sep[sep - 1] += 1;
                    scratch.bytes_by_sep[sep - 1] += bytes as u64;
                    if cfg.trace {
                        trace.push(TraceEvent {
                            t_us: start,
                            rank: r,
                            kind: TraceKind::SendStart,
                            peer: to,
                            tag,
                            bytes,
                            sep,
                        });
                    }
                    match route {
                        Some((shard_of_chan, me)) if shard_of_chan[chan] != me => {
                            // Boundary send: the receiver's mailbox lives
                            // on another shard — hand it to the caller.
                            outbox.push((shard_of_chan[chan], chan as u32, arrival, out));
                        }
                        _ => {
                            scratch.mailbox[chan].push(arrival, out);
                            // Wake the receiver if it is parked on this
                            // channel.
                            let w = scratch.waiting[chan];
                            if w != NO_WAITER {
                                scratch.waiting[chan] = NO_WAITER;
                                scratch.ready.push_back(w);
                            }
                        }
                    }
                }
                Action::Recv { from, tag, merge } => {
                    let (arrival, incoming) = match scratch.mailbox[chan].pop() {
                        Some(m) => m,
                        None => {
                            // Park until the matching send arrives.
                            scratch.waiting[chan] = r;
                            break;
                        }
                    };
                    *recvs += 1;
                    let sep = clustering.sep(from, r);
                    let link = cfg.params.at_sep(sep);
                    let bytes = incoming.n_bytes();
                    scratch.clocks[r] = scratch.clocks[r].max(arrival) + link.recv_overhead_us;
                    match merge {
                        Merge::Replace => regs[r] = incoming,
                        Merge::Discard => {}
                        Merge::Union => regs[r].union(incoming).map_err(Error::Sim)?,
                        Merge::Combine(op) => {
                            scratch.clocks[r] += cfg.params.combine_us(bytes);
                            *combines += 1;
                            regs[r].combine(&incoming, op, combiner).map_err(Error::Sim)?;
                        }
                    }
                    scratch.cursor[r] += 1;
                    if cfg.trace {
                        trace.push(TraceEvent {
                            t_us: scratch.clocks[r],
                            rank: r,
                            kind: TraceKind::RecvDone,
                            peer: from,
                            tag,
                            bytes,
                            sep,
                        });
                    }
                }
                Action::Mark { id } => {
                    let t = scratch.clocks[r];
                    scratch.cursor[r] += 1;
                    let slot = marks.entry(id).or_insert(t);
                    if t > *slot {
                        *slot = t;
                    }
                }
            }
        }
    }
    Ok(())
}

/// Build the deadlock report both modes share: stuck ranks ascending,
/// detail naming the first four blocked actions.
fn deadlock_error(prog: &Program, stuck: Vec<usize>, cursor: &dyn Fn(Rank) -> usize) -> Error {
    let detail = stuck
        .iter()
        .take(4)
        .map(|&r| format!("rank {r} at action {:?}", prog.actions[r][cursor(r)]))
        .collect::<Vec<_>>()
        .join("; ");
    Error::Deadlock { stuck_ranks: stuck, detail }
}

/// Build the undelivered-message report both modes share. The report is
/// deterministic: channels are sorted by (from, to, tag), independent of
/// scheduling, shard interleaving or map iteration order.
fn undelivered_error(mut undelivered: Vec<((Rank, Rank, u64), usize)>) -> Error {
    undelivered.sort_unstable();
    let &((f, t, tag), count) = undelivered.first().expect("unbalanced ledger, empty scan");
    let more = if undelivered.len() > 1 {
        format!(" (+{} more channels)", undelivered.len() - 1)
    } else {
        String::new()
    };
    Error::Sim(format!("{count} undelivered message(s) on channel {f}->{t} tag {tag}{more}"))
}

/// The mode-generic sequential core shared by [`run`] and
/// [`run_timing`]. `regs` doubles as the payload register file (rank r's
/// register is `regs[r]`) and is returned as the run's final registers;
/// timing and accounting land in the caller-owned `out` (whose buffers
/// are recycled, not reallocated), and working state lives in the
/// caller's `scratch` arena. On error, `out` is left in an unspecified
/// partially-written state.
#[allow(clippy::too_many_arguments)]
fn run_core<R: Register>(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    mut regs: Vec<R>,
    cfg: &SimConfig,
    combiner: &dyn Combiner,
    scratch: &mut EngineScratch<R>,
    out: &mut SimResult,
) -> Result<Vec<R>> {
    validate_inputs(clustering, prog, index, regs.len())?;
    counters::count_sim_run();
    let n = prog.n_ranks();
    let n_levels = clustering.n_levels();
    scratch.prepare(n, index.n_channels(), n_levels);
    out.trace.clear();
    let mut marks: BTreeMap<u64, f64> = BTreeMap::new();
    let mut combines = 0u64;
    let mut recvs = 0u64;
    let mut live = n;
    // Sequential routing never diverts a send, so this stays empty and
    // never allocates.
    let mut outbox: Vec<(u32, u32, f64, R)> = Vec::new();
    drain_ready(
        clustering,
        prog,
        index,
        &mut regs,
        cfg,
        combiner,
        scratch,
        None,
        &mut outbox,
        &mut out.trace,
        &mut marks,
        &mut combines,
        &mut recvs,
        &mut live,
    )?;
    debug_assert!(outbox.is_empty(), "sequential sends never cross shards");

    // The queue drained: every rank either finished or is parked.
    let stuck: Vec<usize> =
        (0..n).filter(|&r| scratch.cursor[r] < prog.actions[r].len()).collect();
    if !stuck.is_empty() {
        return Err(deadlock_error(prog, stuck, &|r| scratch.cursor[r]));
    }

    // Sent/received ledger: every send pushed exactly one message, every
    // recv popped exactly one, so an undelivered message exists iff the
    // totals disagree — the per-channel scan runs only on that error
    // path, never on the hot one.
    let sent: u64 = scratch.msgs_by_sep.iter().sum();
    if sent != recvs {
        let undelivered: Vec<((Rank, Rank, u64), usize)> = scratch
            .mailbox
            .iter()
            .enumerate()
            .filter_map(|(c, q)| match q.len() {
                0 => None,
                l => Some((index.key(c as u32), l)),
            })
            .collect();
        return Err(undelivered_error(undelivered));
    }

    out.finish_us.clear();
    out.finish_us.extend_from_slice(&scratch.clocks);
    out.makespan_us = out.finish_us.iter().fold(0.0f64, |a, &b| a.max(b));
    out.msgs_by_sep.clear();
    out.msgs_by_sep.extend_from_slice(&scratch.msgs_by_sep);
    out.bytes_by_sep.clear();
    out.bytes_by_sep.extend_from_slice(&scratch.bytes_by_sep);
    out.combines = combines;
    out.mark_times_us.clear();
    out.mark_times_us.extend(marks);
    sort_trace(&mut out.trace);
    Ok(regs)
}

/// Execute `prog` with the given initial payload registers (full mode:
/// real bytes flow, collective semantics are verifiable afterwards).
///
/// `clustering` supplies `sep(src,dst)`; `initial[r]` seeds rank `r`'s
/// payload register; `combiner` performs reduce arithmetic. Builds the
/// [`ChannelIndex`] for this call; hot paths holding an immutable
/// program (cached plans, fused schedules) should pass their prebuilt
/// index via [`run_indexed`].
pub fn run(
    clustering: &Clustering,
    prog: &Program,
    initial: Vec<Payload>,
    cfg: &SimConfig,
    combiner: &dyn Combiner,
) -> Result<SimResult> {
    let index = ChannelIndex::build(prog);
    run_indexed(clustering, prog, &index, initial, cfg, combiner)
}

/// [`run`] with a caller-supplied (typically cached) [`ChannelIndex`].
pub fn run_indexed(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    initial: Vec<Payload>,
    cfg: &SimConfig,
    combiner: &dyn Combiner,
) -> Result<SimResult> {
    let mut scratch = EngineScratch::new();
    run_indexed_scratch(clustering, prog, index, initial, cfg, combiner, &mut scratch)
}

/// [`run_indexed`] with a caller-held [`EngineScratch`] arena — the
/// fully warm entry point: cached program, cached channel index,
/// recycled working state.
pub fn run_indexed_scratch(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    initial: Vec<Payload>,
    cfg: &SimConfig,
    combiner: &dyn Combiner,
    scratch: &mut EngineScratch<Payload>,
) -> Result<SimResult> {
    let mut out = SimResult::default();
    run_indexed_scratch_into(clustering, prog, index, initial, cfg, combiner, scratch, &mut out)?;
    Ok(out)
}

/// [`run_indexed_scratch`] writing into a caller-owned [`SimResult`] —
/// the pooled entry point: a result held across runs recycles every
/// output buffer's capacity, so a warm step allocates neither working
/// state nor results. On error, `out` is left partially written.
#[allow(clippy::too_many_arguments)]
pub fn run_indexed_scratch_into(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    initial: Vec<Payload>,
    cfg: &SimConfig,
    combiner: &dyn Combiner,
    scratch: &mut EngineScratch<Payload>,
    out: &mut SimResult,
) -> Result<()> {
    let regs = run_core(clustering, prog, index, initial, cfg, combiner, scratch, out)?;
    out.payloads = regs;
    Ok(())
}

/// Execute `prog` in **ghost (timing-only) mode**: registers carry
/// per-key lengths instead of data, so sends allocate nothing and
/// combines copy nothing, while every timing and accounting field of the
/// result is bit-identical to the full run's (the cost model only reads
/// `n_bytes()`). `SimResult::payloads` is empty in this mode.
pub fn run_timing(
    clustering: &Clustering,
    prog: &Program,
    initial: Vec<GhostPayload>,
    cfg: &SimConfig,
) -> Result<SimResult> {
    let index = ChannelIndex::build(prog);
    run_timing_indexed(clustering, prog, &index, initial, cfg)
}

/// [`run_timing`] with a caller-supplied (typically cached)
/// [`ChannelIndex`].
pub fn run_timing_indexed(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    initial: Vec<GhostPayload>,
    cfg: &SimConfig,
) -> Result<SimResult> {
    let mut scratch = EngineScratch::new();
    run_timing_indexed_scratch(clustering, prog, index, initial, cfg, &mut scratch)
}

/// [`run_timing_indexed`] with a caller-held [`EngineScratch`] arena —
/// the warm-probe entry point: on a recycled arena a ghost run performs
/// zero payload allocations *and* zero working-state allocations.
pub fn run_timing_indexed_scratch(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    initial: Vec<GhostPayload>,
    cfg: &SimConfig,
    scratch: &mut EngineScratch<GhostPayload>,
) -> Result<SimResult> {
    let mut out = SimResult::default();
    run_timing_indexed_scratch_into(clustering, prog, index, initial, cfg, scratch, &mut out)?;
    Ok(out)
}

/// [`run_timing_indexed_scratch`] writing into a caller-owned
/// [`SimResult`] — the fully pooled probe: cached program, cached
/// channel index, recycled working state, recycled result buffers. On
/// error, `out` is left partially written.
pub fn run_timing_indexed_scratch_into(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    initial: Vec<GhostPayload>,
    cfg: &SimConfig,
    scratch: &mut EngineScratch<GhostPayload>,
    out: &mut SimResult,
) -> Result<()> {
    // Ghost combines never touch the combiner; any impl satisfies the
    // signature.
    run_core(clustering, prog, index, initial, cfg, &NativeCombiner, scratch, out)?;
    out.payloads.clear();
    Ok(())
}

// ---------------------------------------------------------------------
// Sharded execution (see `netsim::shard` for the partition + the
// determinism argument).
// ---------------------------------------------------------------------

/// Scheduler state of one shard in the work-stealing pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ShardState {
    /// Not queued and not running: parked (empty inbox) or exited.
    Idle,
    /// In the run queue, awaiting a worker.
    Queued,
    /// A worker currently holds its arena.
    Running,
}

/// Cross-shard state under the one shared mutex: per-shard boundary
/// inboxes plus the work-stealing scheduler's bookkeeping.
struct ShardShared<R> {
    /// `inboxes[s]` — boundary messages awaiting delivery on shard `s`,
    /// as `(channel, arrival_us, message)`.
    inboxes: Vec<VecDeque<(u32, f64, R)>>,
    state: Vec<ShardState>,
    /// Runnable shards, FIFO. Workers are interchangeable: whichever
    /// worker gets to a shard first runs it — that shared queue is what
    /// lets fewer workers than shards (and sibling work-stealing among
    /// shards of one parent cluster) keep every core busy.
    runq: VecDeque<u32>,
    /// Shards whose every rank finished. Messages addressed to an
    /// exited shard never requeue it; they rot in its inbox and the
    /// parent reports them through the sent/received ledger.
    exited: Vec<bool>,
    /// Workers parked on the condvar.
    waiting: usize,
    /// Terminal flag: global quiescence (success or deadlock) or a shard
    /// error. Once set, every worker returns at its next lock.
    poisoned: bool,
}

/// One shard worker's private state, recycled across runs like
/// [`EngineScratch`] (whose capacity-check-then-count idiom
/// `prepare` follows, so the `scratch_allocs` promise extends
/// per-shard).
struct ShardArena<R> {
    scratch: EngineScratch<R>,
    /// Full-length register file; only the slots of owned ranks are
    /// populated.
    regs: Vec<R>,
    outbox: Vec<(u32, u32, f64, R)>,
    trace: Vec<TraceEvent>,
    marks: BTreeMap<u64, f64>,
    combines: u64,
    recvs: u64,
    /// Owned ranks not yet finished.
    live: usize,
    error: Option<Error>,
}

impl<R: Register> ShardArena<R> {
    fn new() -> Self {
        ShardArena {
            scratch: EngineScratch::new(),
            regs: Vec::new(),
            outbox: Vec::new(),
            trace: Vec::new(),
            marks: BTreeMap::new(),
            combines: 0,
            recvs: 0,
            live: 0,
            error: None,
        }
    }

    fn prepare(
        &mut self,
        me: u32,
        n: usize,
        n_chan: usize,
        n_levels: usize,
        shard_of_rank: &[u32],
    ) {
        let owned = (0..n).filter(|&r| shard_of_rank[r] == me);
        self.scratch.prepare_ranks(n, n_chan, n_levels, owned);
        if self.regs.capacity() < n {
            counters::count_scratch_alloc();
        }
        self.regs.clear();
        self.regs.resize_with(n, R::empty);
        self.outbox.clear();
        self.trace.clear();
        self.marks.clear();
        self.combines = 0;
        self.recvs = 0;
        self.live = self.scratch.ready.len();
        self.error = None;
    }
}

/// The pooled state of the sharded engine: per-shard arenas, boundary
/// inboxes and the cached tree carving. Held (per register mode) inside
/// [`ExecScratch`], so warm sharded runs recycle everything — including
/// the cut itself, recomputed only when the map fingerprint or the
/// worker target changes.
struct ShardPool<R> {
    /// Arenas behind per-shard mutexes: workers outnumbered by shards
    /// take whichever shard the run queue hands them. The scheduler
    /// guarantees one runner per shard, so these locks are uncontended
    /// (`try_lock` asserts it).
    arenas: Vec<Mutex<ShardArena<R>>>,
    inboxes: Vec<VecDeque<(u32, f64, R)>>,
    cut: ShardCut,
    /// `(map fingerprint, worker target, min-ranks floor)` the cached
    /// cut was computed for.
    cut_key: Option<(u64, usize, usize)>,
}

impl<R: Register> ShardPool<R> {
    fn new() -> Self {
        ShardPool {
            arenas: Vec::new(),
            inboxes: Vec::new(),
            cut: ShardCut::default(),
            cut_key: None,
        }
    }
}

/// One pool worker: repeatedly pop a runnable shard off the shared run
/// queue, deliver its pending boundary messages, drain its ready ranks
/// (outside the lock), flush its boundary sends into sibling inboxes
/// and requeue whoever became runnable. All scheduler transitions
/// happen under the one mutex, so no wakeup can be lost.
///
/// Termination is detected when every worker parks on an empty run
/// queue: no shard is running, none is queued, and — by the invariant
/// that a live shard with a non-empty inbox is always queued or running
/// — every pending message belongs to an exited shard. That is global
/// quiescence (success or deadlock; the parent decides from the
/// cursors and the ledger). Any shard error also poisons the pool.
#[allow(clippy::too_many_arguments)]
fn run_shard_worker<R: Register + Send>(
    n_workers: usize,
    cut: &ShardCut,
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    cfg: &SimConfig,
    combiner: &(dyn Combiner + Sync),
    arenas: &[Mutex<ShardArena<R>>],
    shared: &Mutex<ShardShared<R>>,
    wakeup: &Condvar,
) {
    let mut g = shared.lock().unwrap();
    loop {
        if g.poisoned {
            return;
        }
        let Some(s) = g.runq.pop_front() else {
            g.waiting += 1;
            if g.waiting == n_workers {
                // Nothing runnable and nobody running: quiescent.
                g.poisoned = true;
                wakeup.notify_all();
                return;
            }
            g = wakeup.wait(g).unwrap();
            g.waiting -= 1;
            continue;
        };
        let me = s as usize;
        g.state[me] = ShardState::Running;
        // A shard enters the run queue at most once (state-guarded) and
        // is requeued only after its arena is released below, so this
        // lock is never contended.
        let mut guard = arenas[me].try_lock().expect("one runner per shard");
        let arena = &mut *guard;
        // Deliver pending boundary messages into the local mailbox,
        // waking parked ranks, before draining.
        while let Some((chan, arrival, msg)) = g.inboxes[me].pop_front() {
            let c = chan as usize;
            arena.scratch.mailbox[c].push(arrival, msg);
            let w = arena.scratch.waiting[c];
            if w != NO_WAITER {
                arena.scratch.waiting[c] = NO_WAITER;
                arena.scratch.ready.push_back(w);
            }
        }
        drop(g);
        let res = drain_ready(
            clustering,
            prog,
            index,
            &mut arena.regs,
            cfg,
            combiner,
            &mut arena.scratch,
            Some((cut.chan_shards(), s)),
            &mut arena.outbox,
            &mut arena.trace,
            &mut arena.marks,
            &mut arena.combines,
            &mut arena.recvs,
            &mut arena.live,
        );
        g = shared.lock().unwrap();
        if let Err(e) = res {
            arena.error = Some(e);
            g.poisoned = true;
            wakeup.notify_all();
            return;
        }
        // Flush boundary sends, queueing idle live destinations.
        let mut queued_any = false;
        for (dest, chan, arrival, msg) in arena.outbox.drain(..) {
            let d = dest as usize;
            g.inboxes[d].push_back((chan, arrival, msg));
            if g.state[d] == ShardState::Idle && !g.exited[d] {
                g.state[d] = ShardState::Queued;
                g.runq.push_back(dest);
                queued_any = true;
            }
        }
        let refilled = !g.inboxes[me].is_empty();
        let finished = arena.live == 0;
        // Release the arena *before* the shard becomes poppable again,
        // upholding the one-runner-per-shard invariant.
        drop(guard);
        if refilled {
            // A sibling refilled our inbox while we drained: requeue
            // (any worker may run it next round).
            g.state[me] = ShardState::Queued;
            g.runq.push_back(s);
            queued_any = true;
        } else {
            if finished {
                g.exited[me] = true;
            }
            g.state[me] = ShardState::Idle;
        }
        if queued_any {
            wakeup.notify_all();
        }
    }
}

/// The sharded counterpart of [`run_core`]: carve the [`ShardMap`]'s
/// cluster tree into up to `threads` shards ([`ShardMap::cut`], cached
/// in the pool), run a work-stealing worker pool over them, and merge
/// the per-shard partial results in deterministic shard order.
/// Bitwise-identical to the sequential core by construction — see
/// `netsim::shard`'s module docs.
#[allow(clippy::too_many_arguments)]
fn run_core_sharded<R: Register + Send>(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    shards: &ShardMap,
    mut regs: Vec<R>,
    cfg: &SimConfig,
    combiner: &(dyn Combiner + Sync),
    pool: &mut ShardPool<R>,
    threads: usize,
    out: &mut SimResult,
) -> Result<Vec<R>> {
    validate_inputs(clustering, prog, index, regs.len())?;
    let n = prog.n_ranks();
    if shards.n_ranks() != n || !shards.matches(index) {
        return Err(Error::Sim("shard map does not match program shape".into()));
    }
    counters::count_sim_run();
    let n_chan = index.n_channels();
    let n_levels = clustering.n_levels();
    let target = threads.max(1);

    // Recompute the carving only when the tree or the worker target
    // changed; every warm run reuses the cached cut.
    let key = (shards.fingerprint(), target, DEFAULT_MIN_SHARD_RANKS);
    if pool.cut_key != Some(key) {
        shards.cut_into(target, DEFAULT_MIN_SHARD_RANKS, &mut pool.cut);
        pool.cut_key = Some(key);
    }
    let n_shards = pool.cut.n_shards().max(1);
    let n_workers = threads.min(n_shards).max(1);

    while pool.arenas.len() < n_shards {
        pool.arenas.push(Mutex::new(ShardArena::new()));
    }
    while pool.inboxes.len() < n_shards {
        pool.inboxes.push(VecDeque::new());
    }
    let ShardPool { arenas, inboxes, cut, .. } = pool;
    for (s, arena) in arenas.iter_mut().enumerate().take(n_shards) {
        arena.get_mut().unwrap().prepare(s as u32, n, n_chan, n_levels, cut.rank_shards());
    }
    for q in inboxes.iter_mut() {
        q.clear();
    }
    // Seed each rank's register into its owner's register file; `regs`
    // is drained in place and reused as the collection buffer below.
    for (r, slot) in regs.iter_mut().enumerate() {
        arenas[cut.shard_of(r)].get_mut().unwrap().regs[r] =
            std::mem::replace(slot, R::empty());
    }

    let shared = Mutex::new(ShardShared {
        inboxes: std::mem::take(inboxes),
        state: vec![ShardState::Queued; n_shards],
        runq: (0..n_shards as u32).collect(),
        exited: vec![false; n_shards],
        waiting: 0,
        poisoned: false,
    });
    let wakeup = Condvar::new();
    let worker_arenas: &[Mutex<ShardArena<R>>] = &arenas[..n_shards];
    let worker_cut: &ShardCut = cut;
    std::thread::scope(|scope| {
        for _ in 0..n_workers {
            let shared = &shared;
            let wakeup = &wakeup;
            scope.spawn(move || {
                run_shard_worker(
                    n_workers,
                    worker_cut,
                    clustering,
                    prog,
                    index,
                    cfg,
                    combiner,
                    worker_arenas,
                    shared,
                    wakeup,
                );
            });
        }
    });
    let end = shared.into_inner().unwrap();
    *inboxes = end.inboxes;

    // Workers are gone: reclaim direct access to every shard arena.
    let mut ars: Vec<&mut ShardArena<R>> =
        arenas.iter_mut().take(n_shards).map(|m| m.get_mut().unwrap()).collect();

    // Verdict, in deterministic order: first shard error, then deadlock
    // (from the owner cursors), then the sent/received ledger.
    if let Some(e) = ars.iter_mut().find_map(|a| a.error.take()) {
        return Err(e);
    }
    let mut stuck: Vec<usize> = Vec::new();
    for r in 0..n {
        if ars[cut.shard_of(r)].scratch.cursor[r] < prog.actions[r].len() {
            stuck.push(r);
        }
    }
    if !stuck.is_empty() {
        let cursor = |r: Rank| ars[cut.shard_of(r)].scratch.cursor[r];
        return Err(deadlock_error(prog, stuck, &cursor));
    }
    let mut sent = 0u64;
    let mut recvs = 0u64;
    for arena in ars.iter() {
        sent += arena.scratch.msgs_by_sep.iter().sum::<u64>();
        recvs += arena.recvs;
    }
    if sent != recvs {
        // Leftovers sit either in an owner's mailbox (delivered, never
        // received) or still in a dead shard's inbox (never delivered).
        let mut counts: BTreeMap<(Rank, Rank, u64), usize> = BTreeMap::new();
        for arena in ars.iter() {
            for (c, q) in arena.scratch.mailbox.iter().enumerate() {
                match q.len() {
                    0 => {}
                    l => *counts.entry(index.key(c as u32)).or_insert(0) += l,
                }
            }
        }
        for q in inboxes.iter().take(n_shards) {
            for (c, _, _) in q.iter() {
                *counts.entry(index.key(*c)).or_insert(0) += 1;
            }
        }
        return Err(undelivered_error(counts.into_iter().collect()));
    }

    // Merge per-shard partials in shard order. Sums and maxes are
    // order-insensitive; the trace gets the canonical total-key sort, so
    // every field is bitwise identical to the sequential result.
    out.finish_us.clear();
    out.finish_us.extend((0..n).map(|r| ars[cut.shard_of(r)].scratch.clocks[r]));
    out.makespan_us = out.finish_us.iter().fold(0.0f64, |a, &b| a.max(b));
    let mut msgs = SepCounts::new(n_levels);
    let mut bytes = SepCounts::new(n_levels);
    let mut combines = 0u64;
    for arena in ars.iter() {
        msgs.add_slice(&arena.scratch.msgs_by_sep);
        bytes.add_slice(&arena.scratch.bytes_by_sep);
        combines += arena.combines;
    }
    out.msgs_by_sep.clear();
    out.msgs_by_sep.extend_from_slice(msgs.as_slice());
    out.bytes_by_sep.clear();
    out.bytes_by_sep.extend_from_slice(bytes.as_slice());
    out.combines = combines;
    let mut marks: BTreeMap<u64, f64> = BTreeMap::new();
    for arena in ars.iter() {
        for (&id, &t) in arena.marks.iter() {
            let slot = marks.entry(id).or_insert(t);
            if t > *slot {
                *slot = t;
            }
        }
    }
    out.mark_times_us.clear();
    out.mark_times_us.extend(marks);
    out.trace.clear();
    for arena in ars.iter_mut() {
        out.trace.append(&mut arena.trace);
    }
    sort_trace(&mut out.trace);
    for (r, slot) in regs.iter_mut().enumerate() {
        *slot = std::mem::replace(&mut ars[cut.shard_of(r)].regs[r], R::empty());
    }
    Ok(regs)
}

/// Sharded full-payload execution against a precomputed [`ShardMap`].
/// Results are **bitwise identical** to [`run_indexed_scratch`]'s;
/// `threads <= 1` or a single-cluster map short-circuits to the
/// sequential path (same arena the sequential entry points use). The
/// combiner must be `Sync`: it is shared by every worker.
#[allow(clippy::too_many_arguments)]
pub fn run_indexed_scratch_sharded(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    shards: &ShardMap,
    initial: Vec<Payload>,
    cfg: &SimConfig,
    combiner: &(dyn Combiner + Sync),
    scratch: &ExecScratch,
    threads: usize,
) -> Result<SimResult> {
    let mut out = SimResult::default();
    run_indexed_scratch_sharded_into(
        clustering,
        prog,
        index,
        shards,
        initial,
        cfg,
        combiner,
        scratch,
        threads,
        &mut out,
    )?;
    Ok(out)
}

/// [`run_indexed_scratch_sharded`] writing into a caller-owned
/// [`SimResult`]. On error, `out` is left partially written.
#[allow(clippy::too_many_arguments)]
pub fn run_indexed_scratch_sharded_into(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    shards: &ShardMap,
    initial: Vec<Payload>,
    cfg: &SimConfig,
    combiner: &(dyn Combiner + Sync),
    scratch: &ExecScratch,
    threads: usize,
    out: &mut SimResult,
) -> Result<()> {
    if threads <= 1 || shards.n_clusters() <= 1 {
        let mut arena = scratch.full();
        let regs = run_core(clustering, prog, index, initial, cfg, combiner, &mut arena, out)?;
        out.payloads = regs;
        return Ok(());
    }
    let mut pool = scratch.full_shards.lock().unwrap();
    let regs = run_core_sharded(
        clustering,
        prog,
        index,
        shards,
        initial,
        cfg,
        combiner,
        &mut pool,
        threads,
        out,
    )?;
    out.payloads = regs;
    Ok(())
}

/// Sharded ghost (timing-only) execution against a precomputed
/// [`ShardMap`] — the parallel tuner probe. Bitwise identical to
/// [`run_timing_indexed_scratch`]; warm runs against a shared
/// [`ExecScratch`] allocate nothing in any shard.
#[allow(clippy::too_many_arguments)]
pub fn run_timing_indexed_scratch_sharded(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    shards: &ShardMap,
    initial: Vec<GhostPayload>,
    cfg: &SimConfig,
    scratch: &ExecScratch,
    threads: usize,
) -> Result<SimResult> {
    let mut out = SimResult::default();
    run_timing_indexed_scratch_sharded_into(
        clustering,
        prog,
        index,
        shards,
        initial,
        cfg,
        scratch,
        threads,
        &mut out,
    )?;
    Ok(out)
}

/// [`run_timing_indexed_scratch_sharded`] writing into a caller-owned
/// [`SimResult`]. On error, `out` is left partially written.
#[allow(clippy::too_many_arguments)]
pub fn run_timing_indexed_scratch_sharded_into(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    shards: &ShardMap,
    initial: Vec<GhostPayload>,
    cfg: &SimConfig,
    scratch: &ExecScratch,
    threads: usize,
    out: &mut SimResult,
) -> Result<()> {
    if threads <= 1 || shards.n_clusters() <= 1 {
        let mut arena = scratch.ghost();
        run_core(clustering, prog, index, initial, cfg, &NativeCombiner, &mut arena, out)?;
    } else {
        let mut pool = scratch.ghost_shards.lock().unwrap();
        run_core_sharded(
            clustering,
            prog,
            index,
            shards,
            initial,
            cfg,
            &NativeCombiner,
            &mut pool,
            threads,
            out,
        )?;
    }
    out.payloads.clear();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LinkParams, NetworkParams};
    use crate::netsim::payload::{NativeCombiner, ReduceOp};
    use crate::netsim::program::{Merge, SendPart};

    fn flat2() -> Clustering {
        Clustering::flat(2)
    }

    fn simple_params() -> NetworkParams {
        // latency 100us, 1 MB/s (1 byte/us), overheads 10/5 us.
        NetworkParams::new(vec![LinkParams::new(100.0, 1.0).with_overheads(10.0, 5.0)])
            .with_combine_us_per_byte(0.0)
    }

    #[test]
    fn single_message_timing() {
        let mut p = Program::new(2);
        p.send(0, 1, 1, SendPart::All);
        p.recv(1, 0, 1, Merge::Replace);
        let init = vec![Payload::single(0, vec![1.0; 25]), Payload::empty()]; // 100 bytes
        let cfg = SimConfig::new(simple_params());
        let r = run(&flat2(), &p, init, &cfg, &NativeCombiner).unwrap();
        // sender busy: 10 + 100 = 110; arrival: 110 + 100(lat) = 210;
        // receiver: max(0, 210) + 5 = 215.
        assert!((r.finish_us[0] - 110.0).abs() < 1e-9);
        assert!((r.finish_us[1] - 215.0).abs() < 1e-9);
        assert!((r.makespan_us - 215.0).abs() < 1e-9);
        assert_eq!(r.msgs_by_sep, vec![1]);
        assert_eq!(r.bytes_by_sep, vec![100]);
        assert_eq!(r.payloads[1].get(&0).unwrap(), vec![1.0; 25]);
    }

    #[test]
    fn ghost_run_reproduces_full_timing_bitwise() {
        let mut p = Program::new(2);
        p.send(0, 1, 1, SendPart::All);
        p.recv(1, 0, 1, Merge::Combine(ReduceOp::Sum));
        p.mark_all(0);
        let init = vec![Payload::single(0, vec![2.0; 10]), Payload::single(0, vec![3.0; 10])];
        let ghost_init = init.iter().map(GhostPayload::of).collect();
        let params = simple_params().with_combine_us_per_byte(1.0);
        let cfg = SimConfig::new(params);
        let full = run(&flat2(), &p, init, &cfg, &NativeCombiner).unwrap();
        let ghost = run_timing(&flat2(), &p, ghost_init, &cfg).unwrap();
        assert_eq!(full.finish_us, ghost.finish_us);
        assert_eq!(full.makespan_us.to_bits(), ghost.makespan_us.to_bits());
        assert_eq!(full.msgs_by_sep, ghost.msgs_by_sep);
        assert_eq!(full.bytes_by_sep, ghost.bytes_by_sep);
        assert_eq!(full.combines, ghost.combines);
        assert_eq!(full.mark_times_us, ghost.mark_times_us);
        assert!(ghost.payloads.is_empty(), "timing mode returns no payloads");
    }

    #[test]
    fn scratch_arena_reuse_is_allocation_free_and_result_identical() {
        // Same program through a fresh arena per run vs one recycled
        // arena: identical results; the recycled arena grows only once.
        let mut p = Program::new(2);
        p.send(0, 1, 1, SendPart::All);
        p.recv(1, 0, 1, Merge::Combine(ReduceOp::Sum));
        let index = ChannelIndex::build(&p);
        let cfg = SimConfig::new(simple_params());
        let init = || vec![Payload::single(0, vec![2.0; 10]), Payload::single(0, vec![3.0; 10])];
        let fresh = run(&flat2(), &p, init(), &cfg, &NativeCombiner).unwrap();
        let mut scratch = EngineScratch::new();
        let before = counters::snapshot();
        for _ in 0..3 {
            let r = run_indexed_scratch(
                &flat2(),
                &p,
                &index,
                init(),
                &cfg,
                &NativeCombiner,
                &mut scratch,
            )
            .unwrap();
            assert_eq!(r.finish_us, fresh.finish_us);
            assert_eq!(r.msgs_by_sep, fresh.msgs_by_sep);
            assert_eq!(r.payloads, fresh.payloads);
        }
        let delta = counters::snapshot().since(&before);
        // Global counter: other tests may also grow arenas in parallel,
        // but this loop itself contributes exactly one growth; a second
        // warm loop over the same arena contributes zero.
        assert!(delta.scratch_allocs >= 1, "first prepare sizes the arena");
        let before_warm = counters::snapshot();
        run_indexed_scratch(&flat2(), &p, &index, init(), &cfg, &NativeCombiner, &mut scratch)
            .unwrap();
        let sized = counters::snapshot().since(&before_warm);
        // The warm delta is a lower-bound smoke check only under parallel
        // tests; exact-zero enforcement lives in the single-test counter
        // binaries (tuning_counters.rs, session_counters.rs).
        assert!(sized.sim_runs >= 1);
    }

    #[test]
    fn combine_merge_applies_op_and_cost() {
        let mut p = Program::new(2);
        p.send(0, 1, 1, SendPart::All);
        p.recv(1, 0, 1, Merge::Combine(ReduceOp::Sum));
        let init = vec![Payload::single(0, vec![2.0; 10]), Payload::single(0, vec![3.0; 10])];
        let params = simple_params().with_combine_us_per_byte(1.0); // 1 us/byte
        let cfg = SimConfig::new(params);
        let r = run(&flat2(), &p, init, &cfg, &NativeCombiner).unwrap();
        assert_eq!(r.payloads[1].get(&0).unwrap(), vec![5.0; 10]);
        assert_eq!(r.combines, 1);
        // arrival: 10 + 40 + 100 = 150; recv: 150 + 5 + 40(combine) = 195.
        assert!((r.finish_us[1] - 195.0).abs() < 1e-9);
    }

    #[test]
    fn deadlock_detected() {
        let mut p = Program::new(2);
        p.recv(0, 1, 1, Merge::Replace);
        p.recv(1, 0, 1, Merge::Replace);
        let init = vec![Payload::empty(), Payload::empty()];
        let cfg = SimConfig::new(simple_params());
        match run(&flat2(), &p, init, &cfg, &NativeCombiner) {
            Err(Error::Deadlock { stuck_ranks, .. }) => assert_eq!(stuck_ranks, vec![0, 1]),
            other => panic!("expected deadlock, got {other:?}"),
        }
    }

    #[test]
    fn undelivered_message_detected() {
        let mut p = Program::new(2);
        p.send(0, 1, 1, SendPart::All);
        let init = vec![Payload::single(0, vec![1.0]), Payload::empty()];
        let cfg = SimConfig::new(simple_params());
        match run(&flat2(), &p, init, &cfg, &NativeCombiner) {
            Err(Error::Sim(msg)) => {
                assert!(msg.contains("undelivered message(s) on channel 0->1 tag 1"), "{msg}")
            }
            other => panic!("expected undelivered-message error, got {other:?}"),
        }
    }

    #[test]
    fn undelivered_report_is_deterministic_and_sorted() {
        // Two dangling channels: the report always names the smallest
        // (from, to, tag) and counts the rest.
        let mut p = Program::new(3);
        p.send(2, 0, 9, SendPart::Empty);
        p.send(0, 1, 1, SendPart::Empty);
        let init = vec![Payload::empty(); 3];
        let cfg = SimConfig::new(simple_params());
        match run(&Clustering::flat(3), &p, init, &cfg, &NativeCombiner) {
            Err(Error::Sim(msg)) => {
                assert!(msg.contains("channel 0->1 tag 1"), "{msg}");
                assert!(msg.contains("+1 more channels"), "{msg}");
            }
            other => panic!("expected undelivered-message error, got {other:?}"),
        }
    }

    #[test]
    fn sends_serialize_at_sender() {
        // Root sends to 2 peers: second send starts after first's busy time.
        let mut p = Program::new(3);
        p.send(0, 1, 1, SendPart::All);
        p.send(0, 2, 2, SendPart::All);
        p.recv(1, 0, 1, Merge::Replace);
        p.recv(2, 0, 2, Merge::Replace);
        let init =
            vec![Payload::single(0, vec![0.0; 25]), Payload::empty(), Payload::empty()];
        let cfg = SimConfig::new(simple_params());
        let r = run(&Clustering::flat(3), &p, init, &cfg, &NativeCombiner).unwrap();
        // peer 1: (10+100)+100+5 = 215. peer 2 send starts at 110:
        // 110 + 110 + 100 + 5 = 325.
        assert!((r.finish_us[1] - 215.0).abs() < 1e-9);
        assert!((r.finish_us[2] - 325.0).abs() < 1e-9);
    }

    #[test]
    fn sep_levels_priced_differently() {
        // 2-level clustering: ranks {0,1} machine A, {2} machine B.
        let c = Clustering::new(vec![vec![0, 0, 0], vec![0, 0, 1]]).unwrap();
        let params = NetworkParams::new(vec![
            LinkParams::new(1000.0, 1.0).with_overheads(0.0, 0.0), // cross-machine
            LinkParams::new(1.0, 100.0).with_overheads(0.0, 0.0),  // intra
        ])
        .with_combine_us_per_byte(0.0);
        let mut p = Program::new(3);
        p.send(0, 1, 1, SendPart::Empty); // intra: sep 2
        p.recv(1, 0, 1, Merge::Discard);
        p.send(0, 2, 2, SendPart::Empty); // cross: sep 1
        p.recv(2, 0, 2, Merge::Discard);
        let init = vec![Payload::empty(); 3];
        let cfg = SimConfig::new(params);
        let r = run(&c, &p, init, &cfg, &NativeCombiner).unwrap();
        assert_eq!(r.msgs_by_sep, vec![1, 1]);
        assert!((r.finish_us[1] - 1.0).abs() < 1e-9); // intra latency
        assert!((r.finish_us[2] - 1000.0).abs() < 1e-9); // WAN latency
        assert_eq!(r.wan_messages(), 1);
    }

    #[test]
    fn fifo_same_tag_channel() {
        // Two messages with the same (from,to,tag): FIFO delivery.
        let mut p = Program::new(2);
        p.send(0, 1, 7, SendPart::All);
        p.send(0, 1, 7, SendPart::Empty);
        p.recv(1, 0, 7, Merge::Replace);
        p.recv(1, 0, 7, Merge::Discard);
        let init = vec![Payload::single(0, vec![4.0]), Payload::empty()];
        let cfg = SimConfig::new(simple_params());
        let r = run(&flat2(), &p, init, &cfg, &NativeCombiner).unwrap();
        // First (data) message replaced, second discarded: payload intact.
        assert_eq!(r.payloads[1].get(&0).unwrap(), vec![4.0]);
    }

    #[test]
    fn marks_record_segment_completion_times() {
        // Two back-to-back messages with a marker after each: the marker
        // time is the max local clock over ranks at that boundary.
        let mut p = Program::new(2);
        p.send(0, 1, 1, SendPart::All);
        p.recv(1, 0, 1, Merge::Replace);
        p.mark_all(0);
        p.send(1, 0, 2, SendPart::All);
        p.recv(0, 1, 2, Merge::Replace);
        p.mark_all(1);
        let init = vec![Payload::single(0, vec![1.0; 25]), Payload::empty()]; // 100 bytes
        let cfg = SimConfig::new(simple_params());
        let r = run(&flat2(), &p, init, &cfg, &NativeCombiner).unwrap();
        // segment 0: rank 1 done at 215 (see single_message_timing).
        // segment 1: rank 1 busy until 215+110=325; arrival 325+100=425;
        // rank 0 done at max(110,425)+5 = 430.
        assert_eq!(r.mark_times_us.len(), 2);
        assert_eq!(r.mark_times_us[0].0, 0);
        assert!((r.mark_times_us[0].1 - 215.0).abs() < 1e-9);
        assert_eq!(r.mark_times_us[1].0, 1);
        assert!((r.mark_times_us[1].1 - 430.0).abs() < 1e-9);
        assert!((r.makespan_us - 430.0).abs() < 1e-9);
        // markers are free: same finish times as the unmarked program
        assert!(r.mark_times_us[0].1 <= r.mark_times_us[1].1, "monotone");
    }

    #[test]
    fn trace_records_events() {
        let mut p = Program::new(2);
        p.send(0, 1, 1, SendPart::All);
        p.recv(1, 0, 1, Merge::Replace);
        let init = vec![Payload::single(0, vec![1.0]), Payload::empty()];
        let cfg = SimConfig::new(simple_params()).with_trace();
        let r = run(&flat2(), &p, init, &cfg, &NativeCombiner).unwrap();
        assert_eq!(r.trace.len(), 2);
        assert_eq!(r.trace[0].kind, TraceKind::SendStart);
        assert_eq!(r.trace[1].kind, TraceKind::RecvDone);
    }

    #[test]
    fn sep_counts_stay_inline_then_spill() {
        let mut c4 = SepCounts::new(4);
        c4.add(0, 2);
        c4.add_slice(&[1, 1, 1, 1]);
        assert_eq!(c4.as_slice(), &[3, 1, 1, 1]);
        assert_eq!(c4.len(), 4);
        let mut c5 = SepCounts::new(5);
        c5.add(4, 7);
        c5.add_slice(&[1, 0, 0, 0, 1]);
        assert_eq!(c5.as_slice(), &[1, 0, 0, 0, 8]);
        assert!(!c5.is_empty());
        assert!(SepCounts::new(0).is_empty());
    }

    /// 2 sites x 2 ranks running a miniature hybrid allreduce: local
    /// reduce to each site leader, leaders exchange partials across the
    /// boundary, broadcast down — with marks after each phase.
    fn two_cluster() -> (Clustering, Program, Vec<Payload>) {
        let c = Clustering::new(vec![vec![0; 4], vec![0, 0, 1, 1]]).unwrap();
        let mut p = Program::new(4);
        p.send(1, 0, 1, SendPart::All);
        p.recv(0, 1, 1, Merge::Combine(ReduceOp::Sum));
        p.send(3, 2, 2, SendPart::All);
        p.recv(2, 3, 2, Merge::Combine(ReduceOp::Sum));
        p.send(0, 2, 3, SendPart::All);
        p.send(2, 0, 4, SendPart::All);
        p.recv(0, 2, 4, Merge::Combine(ReduceOp::Sum));
        p.recv(2, 0, 3, Merge::Combine(ReduceOp::Sum));
        p.mark_all(0);
        p.send(0, 1, 5, SendPart::All);
        p.recv(1, 0, 5, Merge::Replace);
        p.send(2, 3, 6, SendPart::All);
        p.recv(3, 2, 6, Merge::Replace);
        p.mark_all(1);
        let init = (0..4).map(|r| Payload::single(0, vec![(r + 1) as f32; 8])).collect();
        (c, p, init)
    }

    fn two_level_params() -> NetworkParams {
        NetworkParams::new(vec![
            LinkParams::new(500.0, 0.5).with_overheads(20.0, 10.0),
            LinkParams::new(5.0, 10.0).with_overheads(1.0, 1.0),
        ])
        .with_combine_us_per_byte(0.25)
    }

    #[test]
    fn sharded_matches_sequential_bitwise() {
        let (c, p, init) = two_cluster();
        let index = ChannelIndex::build(&p);
        let shards = ShardMap::build(&c, &index);
        assert_eq!(shards.n_clusters(), 2);
        let cfg = SimConfig::new(two_level_params()).with_trace();
        let seq = run_indexed(&c, &p, &index, init.clone(), &cfg, &NativeCombiner).unwrap();
        let scratch = ExecScratch::new();
        // More threads than clusters clamps to the cluster count.
        for threads in [2usize, 3, 8] {
            let mut out = SimResult::default();
            run_indexed_scratch_sharded_into(
                &c,
                &p,
                &index,
                &shards,
                init.clone(),
                &cfg,
                &NativeCombiner,
                &scratch,
                threads,
                &mut out,
            )
            .unwrap();
            assert_eq!(out.finish_us, seq.finish_us, "threads={threads}");
            assert_eq!(out.makespan_us.to_bits(), seq.makespan_us.to_bits());
            assert_eq!(out.msgs_by_sep, seq.msgs_by_sep);
            assert_eq!(out.bytes_by_sep, seq.bytes_by_sep);
            assert_eq!(out.combines, seq.combines);
            assert_eq!(out.mark_times_us, seq.mark_times_us);
            assert_eq!(out.payloads, seq.payloads);
            assert_eq!(out.trace, seq.trace);
        }
        // Ghost mode through the sharded path: same timing, no payloads.
        let ghost_init: Vec<GhostPayload> = init.iter().map(GhostPayload::of).collect();
        let mut gout = SimResult::default();
        run_timing_indexed_scratch_sharded_into(
            &c,
            &p,
            &index,
            &shards,
            ghost_init,
            &cfg,
            &scratch,
            2,
            &mut gout,
        )
        .unwrap();
        assert_eq!(gout.finish_us, seq.finish_us);
        assert_eq!(gout.mark_times_us, seq.mark_times_us);
        assert!(gout.payloads.is_empty());
    }

    #[test]
    fn sharded_single_cluster_uses_sequential_path() {
        let mut p = Program::new(2);
        p.send(0, 1, 1, SendPart::All);
        p.recv(1, 0, 1, Merge::Replace);
        let index = ChannelIndex::build(&p);
        let c = flat2();
        let shards = ShardMap::build(&c, &index);
        assert_eq!(shards.n_clusters(), 1);
        let init = vec![Payload::single(0, vec![1.0; 25]), Payload::empty()];
        let cfg = SimConfig::new(simple_params());
        let seq = run(&c, &p, init.clone(), &cfg, &NativeCombiner).unwrap();
        let scratch = ExecScratch::new();
        let mut out = SimResult::default();
        run_indexed_scratch_sharded_into(
            &c,
            &p,
            &index,
            &shards,
            init,
            &cfg,
            &NativeCombiner,
            &scratch,
            4,
            &mut out,
        )
        .unwrap();
        assert_eq!(out.finish_us, seq.finish_us);
        assert_eq!(out.payloads, seq.payloads);
    }

    #[test]
    fn sharded_deadlock_and_undelivered_detected() {
        let c = Clustering::new(vec![vec![0; 4], vec![0, 0, 1, 1]]).unwrap();
        let cfg = SimConfig::new(simple_params());
        let scratch = ExecScratch::new();
        let mut out = SimResult::default();

        // Cross-cluster recv/recv: both shards idle, no message in
        // flight — the workers reach quiescence and the parent reports
        // the stuck ranks exactly like the sequential engine.
        let mut p = Program::new(4);
        p.recv(0, 2, 1, Merge::Replace);
        p.recv(2, 0, 1, Merge::Replace);
        let index = ChannelIndex::build(&p);
        let shards = ShardMap::build(&c, &index);
        let res = run_indexed_scratch_sharded_into(
            &c,
            &p,
            &index,
            &shards,
            vec![Payload::empty(); 4],
            &cfg,
            &NativeCombiner,
            &scratch,
            2,
            &mut out,
        );
        match res {
            Err(Error::Deadlock { stuck_ranks, .. }) => assert_eq!(stuck_ranks, vec![0, 2]),
            other => panic!("expected deadlock, got {other:?}"),
        }

        // A boundary send nobody receives: caught by the ledger whether
        // the message died in the owner's mailbox or its inbox.
        let mut p = Program::new(4);
        p.send(0, 2, 9, SendPart::Empty);
        let index = ChannelIndex::build(&p);
        let shards = ShardMap::build(&c, &index);
        let res = run_indexed_scratch_sharded_into(
            &c,
            &p,
            &index,
            &shards,
            vec![Payload::empty(); 4],
            &cfg,
            &NativeCombiner,
            &scratch,
            2,
            &mut out,
        );
        match res {
            Err(Error::Sim(msg)) => {
                assert!(msg.contains("1 undelivered message(s) on channel 0->2 tag 9"), "{msg}")
            }
            other => panic!("expected undelivered-message error, got {other:?}"),
        }
    }

    #[test]
    fn sharded_warm_reruns_are_stable_and_reuse_the_pool() {
        let (c, p, init) = two_cluster();
        let index = ChannelIndex::build(&p);
        let shards = ShardMap::build(&c, &index);
        let cfg = SimConfig::new(two_level_params());
        let scratch = ExecScratch::new();
        let ghost_init: Vec<GhostPayload> = init.iter().map(GhostPayload::of).collect();
        let mut first = SimResult::default();
        run_timing_indexed_scratch_sharded_into(
            &c,
            &p,
            &index,
            &shards,
            ghost_init.clone(),
            &cfg,
            &scratch,
            2,
            &mut first,
        )
        .unwrap();
        // Reuse the same result shell: every warm rerun must overwrite
        // it to the identical values (exact-zero allocation deltas are
        // enforced in the single-test counter binary).
        let mut out = SimResult::default();
        for _ in 0..3 {
            run_timing_indexed_scratch_sharded_into(
                &c,
                &p,
                &index,
                &shards,
                ghost_init.clone(),
                &cfg,
                &scratch,
                2,
                &mut out,
            )
            .unwrap();
            assert_eq!(out.finish_us, first.finish_us);
            assert_eq!(out.msgs_by_sep, first.msgs_by_sep);
            assert_eq!(out.mark_times_us, first.mark_times_us);
        }
    }
}
