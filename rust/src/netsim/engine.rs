//! The discrete-event execution engine.
//!
//! Executes a [`Program`] over a communicator's clustering under a
//! [`NetworkParams`] cost model. Timing follows the postal/LogGP
//! conventions documented in [`crate::model`]: endpoint occupancy, no
//! shared-link contention (§4 of the paper reasons under the same model).
//!
//! Two orthogonal axes, one core:
//!
//! - **Register mode.** The core is generic over [`Register`]: [`run`]
//!   executes full [`Payload`]s (real f32 segments, semantic
//!   verification), [`run_timing`] executes [`GhostPayload`]s (per-key
//!   lengths only). The cost model prices messages exclusively through
//!   `n_bytes()`, so both modes produce **bit-identical**
//!   `finish_us` / `makespan_us` / `msgs_by_sep` / `bytes_by_sep` /
//!   `mark_times_us`; ghost mode allocates no payload data and performs
//!   no combine arithmetic.
//! - **Scheduling.** Ranks advance through an event-driven ready queue:
//!   a rank blocked on a `Recv` parks in a per-channel wait slot and is
//!   woken by the matching `Send`, so each scheduling step is O(ready
//!   work) instead of the previous fixpoint loop's O(n_ranks) rescans of
//!   blocked ranks. Channel lookup is a dense [`ChannelIndex`] (cached
//!   on plans/schedules; rebuilt per call for ad-hoc programs), so warm
//!   executions hash nothing. Results are order-independent: each rank's
//!   program is sequential and arrival times depend only on the sender's
//!   progress, so any scheduling order yields identical clocks — the old
//!   rescan loop survives as `netsim::testing::run_rescan`, a
//!   differential-testing oracle off the shipped surface.
//!
//! The per-run working state (mailbox channels, wait slots, ready queue,
//! per-rank cursors and clocks, accounting vectors) lives in a reusable
//! [`EngineScratch`] arena: callers that hold one across runs — every
//! `CollectiveEngine` / `GridSession` does, via [`ExecScratch`] — pay the
//! allocations once and recycle the capacity on every later run
//! ([`crate::util::counters::count_scratch_alloc`] counts arena growth,
//! so tests can assert a warm ghost sweep grows nothing).
//!
//! Quiescence before completion is a deadlock and is reported with the
//! stuck ranks.

use crate::error::{Error, Result};
use crate::model::NetworkParams;
use crate::netsim::payload::{Combiner, GhostPayload, NativeCombiner, Payload, Rank, Register};
use crate::netsim::program::{Action, ChannelIndex, Merge, Program, SendPart};
use crate::topology::Clustering;
use crate::util::counters;
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Mutex, MutexGuard};

/// One trace record (enabled via `SimConfig::trace`).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub t_us: f64,
    pub rank: Rank,
    pub kind: TraceKind,
    pub peer: Rank,
    pub tag: u64,
    pub bytes: usize,
    pub sep: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    SendStart,
    RecvDone,
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub params: NetworkParams,
    /// Record per-message trace events (adds allocation; off for benches).
    pub trace: bool,
}

impl SimConfig {
    pub fn new(params: NetworkParams) -> Self {
        SimConfig { params, trace: false }
    }

    pub fn with_trace(mut self) -> Self {
        self.trace = true;
        self
    }
}

/// Everything the simulation produces.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Per-rank local completion time (us).
    pub finish_us: Vec<f64>,
    /// max over ranks.
    pub makespan_us: f64,
    /// Message count by separation level (index `sep-1`; index 0 = WAN).
    pub msgs_by_sep: Vec<u64>,
    /// Bytes by separation level.
    pub bytes_by_sep: Vec<u64>,
    /// Number of combine invocations (reduce arithmetic).
    pub combines: u64,
    /// Final payload register of every rank (for semantic verification).
    /// **Empty for timing-mode runs** ([`run_timing`]): ghost registers
    /// carry no data worth returning, and the timing fields above are
    /// bit-identical to the full run's.
    pub payloads: Vec<Payload>,
    /// Completion timestamp per boundary marker, sorted by marker id:
    /// `(id, t_us)` where `t_us` is the max local clock over every rank
    /// that executed `Action::Mark { id }`. Empty for mark-free programs.
    /// Fused schedules use consecutive ids, so this is the cumulative
    /// per-segment completion profile of a single run.
    pub mark_times_us: Vec<(u64, f64)>,
    /// Trace (empty unless enabled).
    pub trace: Vec<TraceEvent>,
}

impl SimResult {
    /// Total messages at the WAN boundary (sep 1) — the paper's headline
    /// count.
    ///
    /// This is the **single source of truth** for WAN message counts:
    /// every layer (engine outcomes, experiment tables, training logs)
    /// reads it from here rather than indexing `msgs_by_sep[0]` directly,
    /// so the "sep 1 == WAN" convention lives in exactly one place. For
    /// the *static* (pre-execution) count of a cached plan, see
    /// `plan::PlanMeta::wan_messages`, which is defined to agree with this
    /// accessor for every op.
    pub fn wan_messages(&self) -> u64 {
        self.msgs_by_sep.first().copied().unwrap_or(0)
    }
}

/// A mailbox channel: zero / one / many in-flight messages. Single-use
/// channels — the overwhelmingly common case for compiled collectives,
/// where every `(from, to, tag)` carries exactly one message — never
/// allocate queue storage.
enum Chan<R> {
    Empty,
    One(f64, R),
    Many(VecDeque<(f64, R)>),
}

impl<R> Chan<R> {
    fn push(&mut self, t: f64, m: R) {
        match self {
            Chan::Empty => *self = Chan::One(t, m),
            Chan::One(..) => {
                let Chan::One(t0, m0) = std::mem::replace(self, Chan::Empty) else {
                    unreachable!()
                };
                let mut q = VecDeque::with_capacity(2);
                q.push_back((t0, m0));
                q.push_back((t, m));
                *self = Chan::Many(q);
            }
            Chan::Many(q) => q.push_back((t, m)),
        }
    }

    fn pop(&mut self) -> Option<(f64, R)> {
        match self {
            Chan::Empty => None,
            Chan::One(..) => {
                let Chan::One(t, m) = std::mem::replace(self, Chan::Empty) else {
                    unreachable!()
                };
                Some((t, m))
            }
            Chan::Many(q) => q.pop_front(),
        }
    }

    fn len(&self) -> usize {
        match self {
            Chan::Empty => 0,
            Chan::One(..) => 1,
            Chan::Many(q) => q.len(),
        }
    }
}

/// Everything the generic core produces; mode-specific wrappers shape it
/// into a [`SimResult`].
struct RunOutput<R> {
    finish_us: Vec<f64>,
    makespan_us: f64,
    msgs_by_sep: Vec<u64>,
    bytes_by_sep: Vec<u64>,
    combines: u64,
    registers: Vec<R>,
    mark_times_us: Vec<(u64, f64)>,
    trace: Vec<TraceEvent>,
}

/// No rank parked on this channel.
const NO_WAITER: usize = usize::MAX;

/// Reusable per-run working state of the execution core: the mailbox
/// channels, per-channel wait slots, the ready queue, per-rank program
/// cursors and clocks, and the per-level accounting vectors.
///
/// A fresh arena is empty; the first run sizes it to its program
/// (counted once via [`counters::count_scratch_alloc`]) and every later
/// run whose program needs no more capacity recycles the storage with
/// **zero** allocations. Engines and sessions hold one arena per
/// register mode (see [`ExecScratch`]) so back-to-back ghost probes are
/// allocation-free end to end.
pub struct EngineScratch<R> {
    mailbox: Vec<Chan<R>>,
    /// `waiting[c]` = the rank parked on channel `c`'s next message. At
    /// most one rank can ever wait per channel (the channel's receiver).
    waiting: Vec<usize>,
    ready: VecDeque<Rank>,
    clocks: Vec<f64>,
    cursor: Vec<usize>,
    msgs_by_sep: Vec<u64>,
    bytes_by_sep: Vec<u64>,
}

impl<R> EngineScratch<R> {
    /// An empty arena (no storage until the first run sizes it).
    pub fn new() -> Self {
        EngineScratch {
            mailbox: Vec::new(),
            waiting: Vec::new(),
            ready: VecDeque::new(),
            clocks: Vec::new(),
            cursor: Vec::new(),
            msgs_by_sep: Vec::new(),
            bytes_by_sep: Vec::new(),
        }
    }

    /// Reset for a run over `n` ranks, `n_chan` channels and `n_levels`
    /// separation levels, reusing existing capacity. Growth (a run
    /// larger than anything this arena has executed) is counted once.
    fn prepare(&mut self, n: usize, n_chan: usize, n_levels: usize) {
        if self.mailbox.capacity() < n_chan
            || self.waiting.capacity() < n_chan
            || self.ready.capacity() < n
            || self.clocks.capacity() < n
            || self.cursor.capacity() < n
            || self.msgs_by_sep.capacity() < n_levels
            || self.bytes_by_sep.capacity() < n_levels
        {
            counters::count_scratch_alloc();
        }
        self.mailbox.clear();
        self.mailbox.resize_with(n_chan, || Chan::Empty);
        self.waiting.clear();
        self.waiting.resize(n_chan, NO_WAITER);
        self.ready.clear();
        self.ready.extend(0..n);
        self.clocks.clear();
        self.clocks.resize(n, 0.0);
        self.cursor.clear();
        self.cursor.resize(n, 0);
        self.msgs_by_sep.clear();
        self.msgs_by_sep.resize(n_levels, 0);
        self.bytes_by_sep.clear();
        self.bytes_by_sep.resize(n_levels, 0);
    }
}

impl<R> Default for EngineScratch<R> {
    fn default() -> Self {
        EngineScratch::new()
    }
}

/// Both register modes' scratch arenas behind one shareable handle —
/// what a `CollectiveEngine` holds (and a `GridSession` shares across
/// the engines it hands out), so full-mode steps and ghost probes each
/// recycle their own arena.
pub struct ExecScratch {
    full: Mutex<EngineScratch<Payload>>,
    ghost: Mutex<EngineScratch<GhostPayload>>,
}

impl ExecScratch {
    pub fn new() -> Self {
        ExecScratch {
            full: Mutex::new(EngineScratch::new()),
            ghost: Mutex::new(EngineScratch::new()),
        }
    }

    /// Lock the full-payload arena.
    pub fn full(&self) -> MutexGuard<'_, EngineScratch<Payload>> {
        self.full.lock().unwrap()
    }

    /// Lock the ghost (timing-only) arena.
    pub fn ghost(&self) -> MutexGuard<'_, EngineScratch<GhostPayload>> {
        self.ghost.lock().unwrap()
    }
}

impl Default for ExecScratch {
    fn default() -> Self {
        ExecScratch::new()
    }
}

/// The mode-generic ready-queue core shared by [`run`] and
/// [`run_timing`]. `regs` doubles as the payload register file (rank r's
/// register is `regs[r]`) and is returned as the run's final registers;
/// everything else lives in the caller's `scratch` arena.
fn run_core<R: Register>(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    mut regs: Vec<R>,
    cfg: &SimConfig,
    combiner: &dyn Combiner,
    scratch: &mut EngineScratch<R>,
) -> Result<RunOutput<R>> {
    let n = prog.n_ranks();
    if clustering.n_ranks() != n {
        return Err(Error::Sim(format!(
            "clustering has {} ranks, program has {n}",
            clustering.n_ranks()
        )));
    }
    if regs.len() != n {
        return Err(Error::Sim(format!("initial payloads: {} != {n}", regs.len())));
    }
    if !index.matches(prog) {
        return Err(Error::Sim("channel index does not match program shape".into()));
    }
    // Shape coincidence is not identity: catch a stale index exactly in
    // debug builds (tests), keep warm release runs O(1) here.
    debug_assert!(
        index.consistent_with(prog),
        "channel index was built for a different program of the same shape"
    );
    counters::count_sim_run();
    let n_levels = clustering.n_levels();
    scratch.prepare(n, index.n_channels(), n_levels);
    let mut combines = 0u64;
    let mut trace = Vec::new();
    let mut mark_times: BTreeMap<u64, f64> = BTreeMap::new();

    // Every unfinished rank is in exactly one place: the ready queue, a
    // wait slot, or currently executing — so each scheduling step costs
    // O(actions retired), never O(n_ranks).
    while let Some(r) = scratch.ready.pop_front() {
        // Advance rank r until it finishes or blocks on an empty channel.
        loop {
            // Borrow the action in place (no clone: `SendPart::Ranks`
            // carries key vectors that are expensive to copy per
            // execution — §Perf L3 optimization #2).
            let action = match prog.actions[r].get(scratch.cursor[r]) {
                None => break,
                Some(a) => a,
            };
            let chan = index.at(r, scratch.cursor[r]) as usize;
            match *action {
                Action::Send { to, tag, ref part } => {
                    let out = match part {
                        SendPart::All => regs[r].clone(),
                        SendPart::Ranks(rs) => regs[r].select(rs),
                        SendPart::Ranges(rs) => regs[r].select_ranges(rs),
                        SendPart::Empty => R::empty(),
                    };
                    let bytes = out.n_bytes();
                    let sep = clustering.sep(r, to);
                    let link = cfg.params.at_sep(sep);
                    let start = scratch.clocks[r];
                    let arrival = start + link.arrival_delay_us(bytes);
                    scratch.clocks[r] = start + link.sender_busy_us(bytes);
                    scratch.cursor[r] += 1;
                    scratch.msgs_by_sep[sep - 1] += 1;
                    scratch.bytes_by_sep[sep - 1] += bytes as u64;
                    if cfg.trace {
                        trace.push(TraceEvent {
                            t_us: start,
                            rank: r,
                            kind: TraceKind::SendStart,
                            peer: to,
                            tag,
                            bytes,
                            sep,
                        });
                    }
                    scratch.mailbox[chan].push(arrival, out);
                    // Wake the receiver if it is parked on this channel.
                    let w = scratch.waiting[chan];
                    if w != NO_WAITER {
                        scratch.waiting[chan] = NO_WAITER;
                        scratch.ready.push_back(w);
                    }
                }
                Action::Recv { from, tag, merge } => {
                    let (arrival, incoming) = match scratch.mailbox[chan].pop() {
                        Some(m) => m,
                        None => {
                            // Park until the matching send arrives.
                            scratch.waiting[chan] = r;
                            break;
                        }
                    };
                    let sep = clustering.sep(from, r);
                    let link = cfg.params.at_sep(sep);
                    let bytes = incoming.n_bytes();
                    scratch.clocks[r] = scratch.clocks[r].max(arrival) + link.recv_overhead_us;
                    match merge {
                        Merge::Replace => regs[r] = incoming,
                        Merge::Discard => {}
                        Merge::Union => regs[r].union(incoming).map_err(Error::Sim)?,
                        Merge::Combine(op) => {
                            scratch.clocks[r] += cfg.params.combine_us(bytes);
                            combines += 1;
                            regs[r].combine(&incoming, op, combiner).map_err(Error::Sim)?;
                        }
                    }
                    scratch.cursor[r] += 1;
                    if cfg.trace {
                        trace.push(TraceEvent {
                            t_us: scratch.clocks[r],
                            rank: r,
                            kind: TraceKind::RecvDone,
                            peer: from,
                            tag,
                            bytes,
                            sep,
                        });
                    }
                }
                Action::Mark { id } => {
                    let t = scratch.clocks[r];
                    scratch.cursor[r] += 1;
                    let slot = mark_times.entry(id).or_insert(t);
                    if t > *slot {
                        *slot = t;
                    }
                }
            }
        }
    }

    // The queue drained: every rank either finished or is parked.
    let stuck: Vec<usize> =
        (0..n).filter(|&r| scratch.cursor[r] < prog.actions[r].len()).collect();
    if !stuck.is_empty() {
        let detail = stuck
            .iter()
            .take(4)
            .map(|&r| format!("rank {r} at action {:?}", prog.actions[r][scratch.cursor[r]]))
            .collect::<Vec<_>>()
            .join("; ");
        return Err(Error::Deadlock { stuck_ranks: stuck, detail });
    }

    // Undelivered messages indicate a send with no matching recv. The
    // report is deterministic: channels are sorted by (from, to, tag),
    // independent of scheduling or map iteration order.
    let mut undelivered: Vec<((Rank, Rank, u64), usize)> = scratch
        .mailbox
        .iter()
        .enumerate()
        .filter_map(|(c, q)| match q.len() {
            0 => None,
            l => Some((index.key(c as u32), l)),
        })
        .collect();
    undelivered.sort_unstable();
    if let Some(&((f, t, tag), count)) = undelivered.first() {
        let more = if undelivered.len() > 1 {
            format!(" (+{} more channels)", undelivered.len() - 1)
        } else {
            String::new()
        };
        return Err(Error::Sim(format!(
            "{count} undelivered message(s) on channel {f}->{t} tag {tag}{more}"
        )));
    }

    let finish_us: Vec<f64> = scratch.clocks.clone();
    let makespan_us = finish_us.iter().fold(0.0f64, |a, &b| a.max(b));
    // NaN-safe total order; clocks are finite, but a cost model handing
    // back a NaN must not panic the sort.
    trace.sort_by(|a, b| a.t_us.total_cmp(&b.t_us));
    Ok(RunOutput {
        finish_us,
        makespan_us,
        msgs_by_sep: scratch.msgs_by_sep.clone(),
        bytes_by_sep: scratch.bytes_by_sep.clone(),
        combines,
        registers: regs,
        mark_times_us: mark_times.into_iter().collect(),
        trace,
    })
}

/// Execute `prog` with the given initial payload registers (full mode:
/// real bytes flow, collective semantics are verifiable afterwards).
///
/// `clustering` supplies `sep(src,dst)`; `initial[r]` seeds rank `r`'s
/// payload register; `combiner` performs reduce arithmetic. Builds the
/// [`ChannelIndex`] for this call; hot paths holding an immutable
/// program (cached plans, fused schedules) should pass their prebuilt
/// index via [`run_indexed`].
pub fn run(
    clustering: &Clustering,
    prog: &Program,
    initial: Vec<Payload>,
    cfg: &SimConfig,
    combiner: &dyn Combiner,
) -> Result<SimResult> {
    let index = ChannelIndex::build(prog);
    run_indexed(clustering, prog, &index, initial, cfg, combiner)
}

/// [`run`] with a caller-supplied (typically cached) [`ChannelIndex`].
pub fn run_indexed(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    initial: Vec<Payload>,
    cfg: &SimConfig,
    combiner: &dyn Combiner,
) -> Result<SimResult> {
    let mut scratch = EngineScratch::new();
    run_indexed_scratch(clustering, prog, index, initial, cfg, combiner, &mut scratch)
}

/// [`run_indexed`] with a caller-held [`EngineScratch`] arena — the
/// fully warm entry point: cached program, cached channel index,
/// recycled working state.
pub fn run_indexed_scratch(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    initial: Vec<Payload>,
    cfg: &SimConfig,
    combiner: &dyn Combiner,
    scratch: &mut EngineScratch<Payload>,
) -> Result<SimResult> {
    let out = run_core(clustering, prog, index, initial, cfg, combiner, scratch)?;
    Ok(SimResult {
        finish_us: out.finish_us,
        makespan_us: out.makespan_us,
        msgs_by_sep: out.msgs_by_sep,
        bytes_by_sep: out.bytes_by_sep,
        combines: out.combines,
        payloads: out.registers,
        mark_times_us: out.mark_times_us,
        trace: out.trace,
    })
}

/// Execute `prog` in **ghost (timing-only) mode**: registers carry
/// per-key lengths instead of data, so sends allocate nothing and
/// combines copy nothing, while every timing and accounting field of the
/// result is bit-identical to the full run's (the cost model only reads
/// `n_bytes()`). `SimResult::payloads` is empty in this mode.
pub fn run_timing(
    clustering: &Clustering,
    prog: &Program,
    initial: Vec<GhostPayload>,
    cfg: &SimConfig,
) -> Result<SimResult> {
    let index = ChannelIndex::build(prog);
    run_timing_indexed(clustering, prog, &index, initial, cfg)
}

/// [`run_timing`] with a caller-supplied (typically cached)
/// [`ChannelIndex`].
pub fn run_timing_indexed(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    initial: Vec<GhostPayload>,
    cfg: &SimConfig,
) -> Result<SimResult> {
    let mut scratch = EngineScratch::new();
    run_timing_indexed_scratch(clustering, prog, index, initial, cfg, &mut scratch)
}

/// [`run_timing_indexed`] with a caller-held [`EngineScratch`] arena —
/// the warm-probe entry point: on a recycled arena a ghost run performs
/// zero payload allocations *and* zero working-state allocations.
pub fn run_timing_indexed_scratch(
    clustering: &Clustering,
    prog: &Program,
    index: &ChannelIndex,
    initial: Vec<GhostPayload>,
    cfg: &SimConfig,
    scratch: &mut EngineScratch<GhostPayload>,
) -> Result<SimResult> {
    // Ghost combines never touch the combiner; any impl satisfies the
    // signature.
    let out = run_core(clustering, prog, index, initial, cfg, &NativeCombiner, scratch)?;
    Ok(SimResult {
        finish_us: out.finish_us,
        makespan_us: out.makespan_us,
        msgs_by_sep: out.msgs_by_sep,
        bytes_by_sep: out.bytes_by_sep,
        combines: out.combines,
        payloads: Vec::new(),
        mark_times_us: out.mark_times_us,
        trace: out.trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LinkParams, NetworkParams};
    use crate::netsim::payload::{NativeCombiner, ReduceOp};
    use crate::netsim::program::{Merge, SendPart};

    fn flat2() -> Clustering {
        Clustering::flat(2)
    }

    fn simple_params() -> NetworkParams {
        // latency 100us, 1 MB/s (1 byte/us), overheads 10/5 us.
        NetworkParams::new(vec![LinkParams::new(100.0, 1.0).with_overheads(10.0, 5.0)])
            .with_combine_us_per_byte(0.0)
    }

    #[test]
    fn single_message_timing() {
        let mut p = Program::new(2);
        p.send(0, 1, 1, SendPart::All);
        p.recv(1, 0, 1, Merge::Replace);
        let init = vec![Payload::single(0, vec![1.0; 25]), Payload::empty()]; // 100 bytes
        let cfg = SimConfig::new(simple_params());
        let r = run(&flat2(), &p, init, &cfg, &NativeCombiner).unwrap();
        // sender busy: 10 + 100 = 110; arrival: 110 + 100(lat) = 210;
        // receiver: max(0, 210) + 5 = 215.
        assert!((r.finish_us[0] - 110.0).abs() < 1e-9);
        assert!((r.finish_us[1] - 215.0).abs() < 1e-9);
        assert!((r.makespan_us - 215.0).abs() < 1e-9);
        assert_eq!(r.msgs_by_sep, vec![1]);
        assert_eq!(r.bytes_by_sep, vec![100]);
        assert_eq!(r.payloads[1].get(&0).unwrap(), vec![1.0; 25]);
    }

    #[test]
    fn ghost_run_reproduces_full_timing_bitwise() {
        let mut p = Program::new(2);
        p.send(0, 1, 1, SendPart::All);
        p.recv(1, 0, 1, Merge::Combine(ReduceOp::Sum));
        p.mark_all(0);
        let init = vec![Payload::single(0, vec![2.0; 10]), Payload::single(0, vec![3.0; 10])];
        let ghost_init = init.iter().map(GhostPayload::of).collect();
        let params = simple_params().with_combine_us_per_byte(1.0);
        let cfg = SimConfig::new(params);
        let full = run(&flat2(), &p, init, &cfg, &NativeCombiner).unwrap();
        let ghost = run_timing(&flat2(), &p, ghost_init, &cfg).unwrap();
        assert_eq!(full.finish_us, ghost.finish_us);
        assert_eq!(full.makespan_us.to_bits(), ghost.makespan_us.to_bits());
        assert_eq!(full.msgs_by_sep, ghost.msgs_by_sep);
        assert_eq!(full.bytes_by_sep, ghost.bytes_by_sep);
        assert_eq!(full.combines, ghost.combines);
        assert_eq!(full.mark_times_us, ghost.mark_times_us);
        assert!(ghost.payloads.is_empty(), "timing mode returns no payloads");
    }

    #[test]
    fn scratch_arena_reuse_is_allocation_free_and_result_identical() {
        // Same program through a fresh arena per run vs one recycled
        // arena: identical results; the recycled arena grows only once.
        let mut p = Program::new(2);
        p.send(0, 1, 1, SendPart::All);
        p.recv(1, 0, 1, Merge::Combine(ReduceOp::Sum));
        let index = ChannelIndex::build(&p);
        let cfg = SimConfig::new(simple_params());
        let init = || vec![Payload::single(0, vec![2.0; 10]), Payload::single(0, vec![3.0; 10])];
        let fresh = run(&flat2(), &p, init(), &cfg, &NativeCombiner).unwrap();
        let mut scratch = EngineScratch::new();
        let before = counters::snapshot();
        for _ in 0..3 {
            let r = run_indexed_scratch(
                &flat2(),
                &p,
                &index,
                init(),
                &cfg,
                &NativeCombiner,
                &mut scratch,
            )
            .unwrap();
            assert_eq!(r.finish_us, fresh.finish_us);
            assert_eq!(r.msgs_by_sep, fresh.msgs_by_sep);
            assert_eq!(r.payloads, fresh.payloads);
        }
        let delta = counters::snapshot().since(&before);
        // Global counter: other tests may also grow arenas in parallel,
        // but this loop itself contributes exactly one growth; a second
        // warm loop over the same arena contributes zero.
        assert!(delta.scratch_allocs >= 1, "first prepare sizes the arena");
        let before_warm = counters::snapshot();
        run_indexed_scratch(&flat2(), &p, &index, init(), &cfg, &NativeCombiner, &mut scratch)
            .unwrap();
        let sized = counters::snapshot().since(&before_warm);
        // The warm delta is a lower-bound smoke check only under parallel
        // tests; exact-zero enforcement lives in the single-test counter
        // binaries (tuning_counters.rs, session_counters.rs).
        assert!(sized.sim_runs >= 1);
    }

    #[test]
    fn combine_merge_applies_op_and_cost() {
        let mut p = Program::new(2);
        p.send(0, 1, 1, SendPart::All);
        p.recv(1, 0, 1, Merge::Combine(ReduceOp::Sum));
        let init = vec![Payload::single(0, vec![2.0; 10]), Payload::single(0, vec![3.0; 10])];
        let params = simple_params().with_combine_us_per_byte(1.0); // 1 us/byte
        let cfg = SimConfig::new(params);
        let r = run(&flat2(), &p, init, &cfg, &NativeCombiner).unwrap();
        assert_eq!(r.payloads[1].get(&0).unwrap(), vec![5.0; 10]);
        assert_eq!(r.combines, 1);
        // arrival: 10 + 40 + 100 = 150; recv: 150 + 5 + 40(combine) = 195.
        assert!((r.finish_us[1] - 195.0).abs() < 1e-9);
    }

    #[test]
    fn deadlock_detected() {
        let mut p = Program::new(2);
        p.recv(0, 1, 1, Merge::Replace);
        p.recv(1, 0, 1, Merge::Replace);
        let init = vec![Payload::empty(), Payload::empty()];
        let cfg = SimConfig::new(simple_params());
        match run(&flat2(), &p, init, &cfg, &NativeCombiner) {
            Err(Error::Deadlock { stuck_ranks, .. }) => assert_eq!(stuck_ranks, vec![0, 1]),
            other => panic!("expected deadlock, got {other:?}"),
        }
    }

    #[test]
    fn undelivered_message_detected() {
        let mut p = Program::new(2);
        p.send(0, 1, 1, SendPart::All);
        let init = vec![Payload::single(0, vec![1.0]), Payload::empty()];
        let cfg = SimConfig::new(simple_params());
        match run(&flat2(), &p, init, &cfg, &NativeCombiner) {
            Err(Error::Sim(msg)) => {
                assert!(msg.contains("undelivered message(s) on channel 0->1 tag 1"), "{msg}")
            }
            other => panic!("expected undelivered-message error, got {other:?}"),
        }
    }

    #[test]
    fn undelivered_report_is_deterministic_and_sorted() {
        // Two dangling channels: the report always names the smallest
        // (from, to, tag) and counts the rest.
        let mut p = Program::new(3);
        p.send(2, 0, 9, SendPart::Empty);
        p.send(0, 1, 1, SendPart::Empty);
        let init = vec![Payload::empty(); 3];
        let cfg = SimConfig::new(simple_params());
        match run(&Clustering::flat(3), &p, init, &cfg, &NativeCombiner) {
            Err(Error::Sim(msg)) => {
                assert!(msg.contains("channel 0->1 tag 1"), "{msg}");
                assert!(msg.contains("+1 more channels"), "{msg}");
            }
            other => panic!("expected undelivered-message error, got {other:?}"),
        }
    }

    #[test]
    fn sends_serialize_at_sender() {
        // Root sends to 2 peers: second send starts after first's busy time.
        let mut p = Program::new(3);
        p.send(0, 1, 1, SendPart::All);
        p.send(0, 2, 2, SendPart::All);
        p.recv(1, 0, 1, Merge::Replace);
        p.recv(2, 0, 2, Merge::Replace);
        let init =
            vec![Payload::single(0, vec![0.0; 25]), Payload::empty(), Payload::empty()];
        let cfg = SimConfig::new(simple_params());
        let r = run(&Clustering::flat(3), &p, init, &cfg, &NativeCombiner).unwrap();
        // peer 1: (10+100)+100+5 = 215. peer 2 send starts at 110:
        // 110 + 110 + 100 + 5 = 325.
        assert!((r.finish_us[1] - 215.0).abs() < 1e-9);
        assert!((r.finish_us[2] - 325.0).abs() < 1e-9);
    }

    #[test]
    fn sep_levels_priced_differently() {
        // 2-level clustering: ranks {0,1} machine A, {2} machine B.
        let c = Clustering::new(vec![vec![0, 0, 0], vec![0, 0, 1]]).unwrap();
        let params = NetworkParams::new(vec![
            LinkParams::new(1000.0, 1.0).with_overheads(0.0, 0.0), // cross-machine
            LinkParams::new(1.0, 100.0).with_overheads(0.0, 0.0),  // intra
        ])
        .with_combine_us_per_byte(0.0);
        let mut p = Program::new(3);
        p.send(0, 1, 1, SendPart::Empty); // intra: sep 2
        p.recv(1, 0, 1, Merge::Discard);
        p.send(0, 2, 2, SendPart::Empty); // cross: sep 1
        p.recv(2, 0, 2, Merge::Discard);
        let init = vec![Payload::empty(); 3];
        let cfg = SimConfig::new(params);
        let r = run(&c, &p, init, &cfg, &NativeCombiner).unwrap();
        assert_eq!(r.msgs_by_sep, vec![1, 1]);
        assert!((r.finish_us[1] - 1.0).abs() < 1e-9); // intra latency
        assert!((r.finish_us[2] - 1000.0).abs() < 1e-9); // WAN latency
        assert_eq!(r.wan_messages(), 1);
    }

    #[test]
    fn fifo_same_tag_channel() {
        // Two messages with the same (from,to,tag): FIFO delivery.
        let mut p = Program::new(2);
        p.send(0, 1, 7, SendPart::All);
        p.send(0, 1, 7, SendPart::Empty);
        p.recv(1, 0, 7, Merge::Replace);
        p.recv(1, 0, 7, Merge::Discard);
        let init = vec![Payload::single(0, vec![4.0]), Payload::empty()];
        let cfg = SimConfig::new(simple_params());
        let r = run(&flat2(), &p, init, &cfg, &NativeCombiner).unwrap();
        // First (data) message replaced, second discarded: payload intact.
        assert_eq!(r.payloads[1].get(&0).unwrap(), vec![4.0]);
    }

    #[test]
    fn marks_record_segment_completion_times() {
        // Two back-to-back messages with a marker after each: the marker
        // time is the max local clock over ranks at that boundary.
        let mut p = Program::new(2);
        p.send(0, 1, 1, SendPart::All);
        p.recv(1, 0, 1, Merge::Replace);
        p.mark_all(0);
        p.send(1, 0, 2, SendPart::All);
        p.recv(0, 1, 2, Merge::Replace);
        p.mark_all(1);
        let init = vec![Payload::single(0, vec![1.0; 25]), Payload::empty()]; // 100 bytes
        let cfg = SimConfig::new(simple_params());
        let r = run(&flat2(), &p, init, &cfg, &NativeCombiner).unwrap();
        // segment 0: rank 1 done at 215 (see single_message_timing).
        // segment 1: rank 1 busy until 215+110=325; arrival 325+100=425;
        // rank 0 done at max(110,425)+5 = 430.
        assert_eq!(r.mark_times_us.len(), 2);
        assert_eq!(r.mark_times_us[0].0, 0);
        assert!((r.mark_times_us[0].1 - 215.0).abs() < 1e-9);
        assert_eq!(r.mark_times_us[1].0, 1);
        assert!((r.mark_times_us[1].1 - 430.0).abs() < 1e-9);
        assert!((r.makespan_us - 430.0).abs() < 1e-9);
        // markers are free: same finish times as the unmarked program
        assert!(r.mark_times_us[0].1 <= r.mark_times_us[1].1, "monotone");
    }

    #[test]
    fn trace_records_events() {
        let mut p = Program::new(2);
        p.send(0, 1, 1, SendPart::All);
        p.recv(1, 0, 1, Merge::Replace);
        let init = vec![Payload::single(0, vec![1.0]), Payload::empty()];
        let cfg = SimConfig::new(simple_params()).with_trace();
        let r = run(&flat2(), &p, init, &cfg, &NativeCombiner).unwrap();
        assert_eq!(r.trace.len(), 2);
        assert_eq!(r.trace[0].kind, TraceKind::SendStart);
        assert_eq!(r.trace[1].kind, TraceKind::RecvDone);
    }
}
