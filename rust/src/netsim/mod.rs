//! Discrete-event grid network simulator: payload model, program IR, and
//! the deterministic execution engine. See DESIGN.md §2 for why this
//! substitutes for the paper's physical testbed.

pub mod engine;
pub mod payload;
pub mod program;

pub use engine::{run, SimConfig, SimResult, TraceEvent, TraceKind};
pub use payload::{Combiner, NativeCombiner, Payload, ReduceOp};
pub use program::{Action, Merge, Program, SendPart};
