//! Discrete-event grid network simulator: payload model, program IR, and
//! the deterministic execution engine. See DESIGN.md §2 for why this
//! substitutes for the paper's physical testbed.
//!
//! The engine runs in two register modes over one generic core: **full**
//! ([`run`] — real f32 payloads, semantic verification) and **ghost**
//! ([`run_timing`] — per-key lengths only, bit-identical timing with
//! zero payload allocation). See [`payload::Register`]. Orthogonally, an
//! [`ExecMode`] selects single-threaded execution or the cluster-sharded
//! parallel engine (see [`shard`]) — both produce bitwise-identical
//! [`SimResult`]s.

pub mod engine;
pub mod payload;
pub mod program;
pub mod shard;
#[doc(hidden)]
pub mod testing;

pub use engine::{
    run, run_indexed, run_indexed_scratch, run_indexed_scratch_into, run_indexed_scratch_sharded,
    run_indexed_scratch_sharded_into, run_timing, run_timing_indexed, run_timing_indexed_scratch,
    run_timing_indexed_scratch_into, run_timing_indexed_scratch_sharded,
    run_timing_indexed_scratch_sharded_into, EngineScratch, ExecScratch, SepCounts, SimConfig,
    SimResult, TraceEvent, TraceKind,
};
pub use payload::{Combiner, GhostPayload, GhostRun, NativeCombiner, Payload, ReduceOp, Register};
pub use program::{Action, ChannelIndex, Merge, Program, SendPart};
pub use shard::{ExecMode, ShardCut, ShardMap, DEFAULT_MIN_SHARD_RANKS};
