//! Topology-keyed hierarchical sharding of the execution engine.
//!
//! The paper's multilevel hierarchy is also a parallel-simulation
//! opportunity: ranks of one cluster exchange the overwhelming majority
//! of a collective's messages among themselves, and only tree edges
//! that cross a separation boundary couple two clusters. The
//! [`ShardMap`] precomputes the *whole* cluster tree for a compiled
//! program — the dense cluster id of every rank at every level, the
//! parent links between levels, the receiver of every [`ChannelIndex`]
//! channel and its separation level — so the sharded engine
//! (`netsim::engine::run_core_sharded`) can carve the tree into any
//! number of shards ([`ShardMap::cut`]) and route every intra-shard
//! message without cross-thread coordination.
//!
//! Unlike the PR-6 map, which partitioned by the *top-level* cluster
//! only (capping a 2-site grid at 2 workers), the cut recursively
//! splits the largest shard along its shallowest branching level until
//! the worker target (or a min-ranks-per-shard floor) is met: a deep
//! single-site topology now yields as many shards as its deepest level
//! has clusters. Like the channel index, map and cut are pure functions
//! of immutable inputs (clustering + program + target), so plans and
//! schedules build the map once and every warm run reuses the cut.
//!
//! ## Synchronization and determinism
//!
//! The classical conservative bound for a partition is its lookahead
//! horizon: a shard may safely advance its local clock to
//! `min(neighbor clocks) + L`, where `L` is the minimum latency of any
//! link crossing the shard boundary. With hierarchical cuts that bound
//! is *per tree edge* ([`ShardMap::lookahead_at`] keyed by a channel's
//! separation level, [`ShardMap::chan_sep`]) — siblings separated only
//! at a deep level have a much smaller horizon than WAN-separated
//! shards. The engine's programs are *blocking dataflow* (each rank is
//! a sequential action list; a `Recv` waits for exactly one channel),
//! which admits an even stronger rule: a shard can run arbitrarily far
//! ahead and simply *block* on the first receive whose boundary channel
//! is still empty. Every cross-shard dependency is an explicit message,
//! never a clock comparison, so the blocking rule subsumes every
//! lookahead horizon and is exact rather than conservative — and
//! because every channel has a single sender whose sends occur in
//! program order, per-channel FIFO delivery is deterministic regardless
//! of worker interleaving or how the tree was cut. That is what makes
//! sharded results **bitwise identical** to the sequential engine's for
//! *any* cut.

use crate::model::NetworkParams;
use crate::netsim::payload::Rank;
use crate::netsim::program::ChannelIndex;
use crate::topology::Clustering;

/// How an engine executes a program.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// Single-threaded ready-queue loop (the differential oracle).
    #[default]
    Sequential,
    /// Cut the cluster tree into up to `threads` shards and run them on
    /// `std::thread` workers with sibling work-stealing. Results are
    /// bitwise identical to [`ExecMode::Sequential`]; `threads <= 1`
    /// (or a topology whose tree never branches) falls back to the
    /// sequential path.
    Sharded { threads: usize },
}

impl ExecMode {
    /// Short human-readable label for reports.
    pub fn name(&self) -> String {
        match self {
            ExecMode::Sequential => "sequential".into(),
            ExecMode::Sharded { threads } => format!("sharded:{threads}"),
        }
    }
}

/// Default floor on ranks per shard for [`ShardMap::cut`]: by default
/// the cut is limited only by the tree's branching. Raise it (e.g. to
/// a few thousand) when per-shard fixed costs dominate tiny shards.
pub const DEFAULT_MIN_SHARD_RANKS: usize = 1;

const NONE: u32 = u32::MAX;

/// The cluster tree of a compiled program: dense per-level cluster ids
/// for every rank, parent links between levels, and per-channel
/// receiver + separation level. Built once per plan/schedule alongside
/// the [`ChannelIndex`]; carved into worker shards by [`ShardMap::cut`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardMap {
    /// `level_of_rank[t][r]` — dense (first-appearance order) cluster id
    /// of rank `r` at clustering level `t + 1`. Level 0 (the world) is
    /// implicit: every rank is in cluster 0.
    level_of_rank: Vec<Vec<u32>>,
    /// Clusters per tree level (same indexing as `level_of_rank`).
    n_clusters: Vec<usize>,
    /// `parent[t][c]` — dense id at level `t - 1` containing cluster `c`
    /// of level `t`; `parent[0][*] == 0` (the world root).
    parent: Vec<Vec<u32>>,
    /// `size[t][c]` — ranks inside cluster `c` of tree level `t`.
    size: Vec<Vec<u32>>,
    /// Receiver rank of every channel (its mailbox's home shard).
    recv_of_chan: Vec<u32>,
    /// Separation level of every channel's endpoint pair.
    sep_of_chan: Vec<u8>,
    n_ranks: usize,
    /// FNV-1a digest of the tree + channel shape, for cut caching.
    fingerprint: u64,
}

/// One concrete carving of a [`ShardMap`] into worker shards: the dense
/// shard id of every rank and of every channel (its receiver's). Owned
/// by the engine's shard pool and recomputed only when the map
/// fingerprint or the worker target changes.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShardCut {
    shard_of_rank: Vec<u32>,
    shard_of_chan: Vec<u32>,
    n_shards: usize,
}

impl ShardCut {
    /// Number of shards in this cut (>= 1 once computed).
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Dense shard id of rank `r`.
    #[inline]
    pub fn shard_of(&self, r: Rank) -> usize {
        self.shard_of_rank[r] as usize
    }

    /// Owning shard (the receiver's) of channel `c`.
    #[inline]
    pub fn chan_shard(&self, c: u32) -> usize {
        self.shard_of_chan[c as usize] as usize
    }

    /// Per-rank shard table, for the engine's routing hot path.
    pub fn rank_shards(&self) -> &[u32] {
        &self.shard_of_rank
    }

    /// Per-channel shard table, for the engine's routing hot path.
    pub fn chan_shards(&self) -> &[u32] {
        &self.shard_of_chan
    }
}

impl ShardMap {
    /// Build the cluster tree of `clustering` over `index`'s channels.
    /// Single-level clusterings (topology-unaware communicators) yield a
    /// depth-0 tree — the sharded engine then degenerates to the
    /// sequential fast path.
    pub fn build(clustering: &Clustering, index: &ChannelIndex) -> ShardMap {
        let n = clustering.n_ranks();
        let depth = clustering.n_levels().saturating_sub(1);
        let mut level_of_rank: Vec<Vec<u32>> = Vec::with_capacity(depth);
        let mut n_clusters: Vec<usize> = Vec::with_capacity(depth);
        let mut parent: Vec<Vec<u32>> = Vec::with_capacity(depth);
        let mut size: Vec<Vec<u32>> = Vec::with_capacity(depth);
        for t in 0..depth {
            // Dense renumbering in first-appearance order: raw color ids
            // are arbitrary, tree ids must be `0..n_clusters[t]`.
            let mut dense: std::collections::HashMap<u32, u32> = Default::default();
            let mut row = Vec::with_capacity(n);
            let mut par: Vec<u32> = Vec::new();
            let mut sz: Vec<u32> = Vec::new();
            for r in 0..n {
                let c = clustering.color(t + 1, r);
                let next = par.len() as u32;
                let id = *dense.entry(c).or_insert_with(|| {
                    // Hierarchy validity (enforced by `Clustering::new`)
                    // makes the first member's parent *the* parent.
                    par.push(if t == 0 { 0 } else { level_of_rank[t - 1][r] });
                    sz.push(0);
                    next
                });
                sz[id as usize] += 1;
                row.push(id);
            }
            n_clusters.push(par.len());
            level_of_rank.push(row);
            parent.push(par);
            size.push(sz);
        }
        let n_chan = index.n_channels();
        let mut recv_of_chan = Vec::with_capacity(n_chan);
        let mut sep_of_chan = Vec::with_capacity(n_chan);
        for ch in 0..n_chan {
            let (from, to, _tag) = index.key(ch as u32);
            recv_of_chan.push(to as u32);
            sep_of_chan.push(clustering.sep(from, to).min(u8::MAX as usize) as u8);
        }
        let fingerprint =
            Self::digest(n, &n_clusters, &level_of_rank, &recv_of_chan);
        ShardMap {
            level_of_rank,
            n_clusters,
            parent,
            size,
            recv_of_chan,
            sep_of_chan,
            n_ranks: n,
            fingerprint,
        }
    }

    fn digest(
        n: usize,
        n_clusters: &[usize],
        level_of_rank: &[Vec<u32>],
        recv_of_chan: &[u32],
    ) -> u64 {
        fn fnv(mut h: u64, v: u64) -> u64 {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100_0000_01b3);
            }
            h
        }
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        h = fnv(h, n as u64);
        h = fnv(h, level_of_rank.len() as u64);
        for (t, row) in level_of_rank.iter().enumerate() {
            h = fnv(h, n_clusters[t] as u64);
            for &id in row {
                h = fnv(h, id as u64);
            }
        }
        h = fnv(h, recv_of_chan.len() as u64);
        for &r in recv_of_chan {
            h = fnv(h, r as u64);
        }
        h
    }

    /// Number of clusters at the *deepest* level (= maximum useful shard
    /// count of any cut).
    pub fn n_clusters(&self) -> usize {
        self.n_clusters.last().copied().unwrap_or(1).max(1)
    }

    /// Tree depth: clustering levels below the world root.
    pub fn depth(&self) -> usize {
        self.level_of_rank.len()
    }

    /// Number of ranks this map was built for.
    pub fn n_ranks(&self) -> usize {
        self.n_ranks
    }

    /// Number of channels this map covers.
    pub fn n_channels(&self) -> usize {
        self.recv_of_chan.len()
    }

    /// Cheap shape guard, mirroring `ChannelIndex::matches`: was this
    /// map built for an index with the same channel count?
    pub fn matches(&self, index: &ChannelIndex) -> bool {
        self.recv_of_chan.len() == index.n_channels()
    }

    /// Separation level of channel `c`'s endpoint pair (1 = WAN,
    /// `n_levels` = same deepest cluster).
    #[inline]
    pub fn chan_sep(&self, c: u32) -> usize {
        self.sep_of_chan[c as usize] as usize
    }

    /// FNV-1a digest of the tree + channel shape; two maps with equal
    /// fingerprints produce identical cuts, so the engine keys its
    /// cached [`ShardCut`] on it.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// The conservative lookahead horizon for a *top-level* partition:
    /// the minimum latency of any inter-cluster (separation-1) link. A
    /// shard whose neighbors' clocks are at `t` can never observe a
    /// boundary arrival before `t + lookahead`. The blocking-dataflow
    /// engine (see the module docs) subsumes this bound exactly, but the
    /// horizon remains the quantity that makes cluster-keyed sharding
    /// profitable: WAN latency dwarfs intra-cluster event spacing.
    pub fn lookahead_us(&self, params: &NetworkParams) -> f64 {
        params.at_sep(1).latency_us
    }

    /// Per-tree-edge lookahead: the horizon of a boundary at separation
    /// level `sep`. Shards split at a deep level have a much smaller
    /// horizon than WAN-separated shards — pair with [`Self::chan_sep`]
    /// for optimistic per-channel bounds.
    pub fn lookahead_at(&self, params: &NetworkParams, sep: usize) -> f64 {
        params.at_sep(sep).latency_us
    }

    /// Approximate resident size (for plan footprint accounting).
    pub fn approx_bytes(&self) -> usize {
        self.level_of_rank.iter().map(|row| row.len() * 4).sum::<usize>()
            + self.parent.iter().map(|p| p.len() * 4).sum::<usize>()
            + self.size.iter().map(|s| s.len() * 4).sum::<usize>()
            + self.recv_of_chan.len() * 4
            + self.sep_of_chan.len()
            + std::mem::size_of::<ShardMap>()
    }

    /// Ranks inside tree node `(lvl, c)`; node-level 0 is the world.
    fn node_size(&self, nd: (u32, u32)) -> u64 {
        let (lvl, c) = nd;
        if lvl == 0 {
            self.n_ranks as u64
        } else {
            self.size[lvl as usize - 1][c as usize] as u64
        }
    }

    /// The shallowest branching refinement of a shard: its own nodes
    /// when it already holds several, otherwise the children found by
    /// descending the single node through non-branching levels. `None`
    /// when the shard bottoms out at the deepest level without ever
    /// branching — such a shard can never be split.
    fn split_candidates(&self, nodes: &[(u32, u32)]) -> Option<Vec<(u32, u32)>> {
        if nodes.len() > 1 {
            return Some(nodes.to_vec());
        }
        let (mut lvl, mut c) = nodes[0];
        let depth = self.level_of_rank.len() as u32;
        loop {
            if lvl >= depth {
                return None;
            }
            let kids: Vec<(u32, u32)> = (0..self.n_clusters[lvl as usize] as u32)
                .filter(|&d| self.parent[lvl as usize][d as usize] == c)
                .map(|d| (lvl + 1, d))
                .collect();
            match kids.len() {
                0 => return None,
                1 => {
                    lvl += 1;
                    c = kids[0].1;
                }
                _ => return Some(kids),
            }
        }
    }

    /// Carve the tree into up to `target` shards, never cutting a shard
    /// below `min_ranks` ranks (pass [`DEFAULT_MIN_SHARD_RANKS`] for
    /// branching-limited cuts). See [`Self::cut_into`].
    pub fn cut(&self, target: usize, min_ranks: usize) -> ShardCut {
        let mut out = ShardCut::default();
        self.cut_into(target, min_ranks, &mut out);
        out
    }

    /// [`Self::cut`] into a caller-owned buffer (the engine's pooled
    /// cut), reusing its allocations.
    ///
    /// The cut grows a shard forest from the world root: repeatedly pick
    /// the largest still-splittable shard, refine it at its shallowest
    /// branching level, and LPT-pack the child clusters (largest first,
    /// each into the lightest bucket) into as many buckets as the
    /// remaining worker budget and the `min_ranks` floor allow. A pure
    /// function of `(tree, target, min_ranks)` — deterministic no
    /// matter how many workers later run the shards.
    pub fn cut_into(&self, target: usize, min_ranks: usize, out: &mut ShardCut) {
        let n = self.n_ranks;
        let depth = self.level_of_rank.len();
        let target = target.max(1);
        let mr = min_ranks.max(1);

        // Node = (node-level, cluster id): node-level 0 is the world
        // root, node-level k >= 1 indexes the tree arrays at k - 1.
        let mut shards: Vec<Vec<(u32, u32)>> = vec![vec![(0, 0)]];
        let mut open: Vec<bool> = vec![true];
        while shards.len() < target {
            // Largest open shard; strict `>` keeps the first on ties.
            let mut pick: Option<(usize, u64)> = None;
            for (i, nodes) in shards.iter().enumerate() {
                if !open[i] {
                    continue;
                }
                let total: u64 = nodes.iter().map(|&nd| self.node_size(nd)).sum();
                if total < 2 * mr as u64 {
                    open[i] = false;
                    continue;
                }
                match pick {
                    Some((_, best)) if total <= best => {}
                    _ => pick = Some((i, total)),
                }
            }
            let Some((i, total)) = pick else { break };
            let mut cands = match self.split_candidates(&shards[i]) {
                Some(c) => c,
                None => {
                    open[i] = false;
                    continue;
                }
            };
            let groups = cands
                .len()
                .min(target - shards.len() + 1)
                .min((total as usize / mr).max(1));
            if groups < 2 {
                open[i] = false;
                continue;
            }
            // LPT packing: largest candidate first into the lightest
            // bucket; ties break toward the lower node / bucket index.
            cands.sort_by(|&a, &b| {
                self.node_size(b).cmp(&self.node_size(a)).then(a.cmp(&b))
            });
            let mut buckets: Vec<(u64, Vec<(u32, u32)>)> = vec![(0, Vec::new()); groups];
            for nd in cands {
                let mut j = 0;
                for k in 1..groups {
                    if buckets[k].0 < buckets[j].0 {
                        j = k;
                    }
                }
                buckets[j].0 += self.node_size(nd);
                buckets[j].1.push(nd);
            }
            let mut it = buckets.into_iter();
            shards[i] = it.next().expect("groups >= 2").1;
            for (_, nodes) in it {
                shards.push(nodes);
                open.push(true);
            }
        }

        // Materialize: per-level assignment tables, then walk each rank
        // shallow -> deep. The shards' nodes partition the world (every
        // split replaces a node set by a refinement), so each rank has
        // exactly one assigned ancestor.
        let mut assign: Vec<Vec<u32>> =
            self.n_clusters.iter().map(|&k| vec![NONE; k]).collect();
        let mut root_shard = NONE;
        for (s, nodes) in shards.iter().enumerate() {
            for &(lvl, c) in nodes {
                if lvl == 0 {
                    root_shard = s as u32;
                } else {
                    assign[lvl as usize - 1][c as usize] = s as u32;
                }
            }
        }
        out.shard_of_rank.clear();
        out.shard_of_rank.reserve(n);
        // Dense shard ids in first-appearance order over ranks, so the
        // numbering is canonical regardless of split order.
        let mut remap: Vec<u32> = vec![NONE; shards.len()];
        let mut n_shards = 0usize;
        for r in 0..n {
            let mut s = root_shard;
            for (t, row) in assign.iter().enumerate().take(depth) {
                let a = row[self.level_of_rank[t][r] as usize];
                if a != NONE {
                    s = a;
                    break;
                }
            }
            debug_assert_ne!(s, NONE, "rank {r} has no assigned ancestor");
            let m = &mut remap[s as usize];
            if *m == NONE {
                *m = n_shards as u32;
                n_shards += 1;
            }
            out.shard_of_rank.push(*m);
        }
        out.n_shards = n_shards.max(1);
        out.shard_of_chan.clear();
        out.shard_of_chan
            .extend(self.recv_of_chan.iter().map(|&r| out.shard_of_rank[r as usize]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{presets, LinkParams};
    use crate::netsim::program::{Merge, Program, SendPart};

    /// 2 sites x 2 ranks; channels: intra site 0, intra site 1, WAN.
    fn two_site() -> (Clustering, Program) {
        let c = Clustering::new(vec![vec![0; 4], vec![0, 0, 1, 1]]).unwrap();
        let mut p = Program::new(4);
        p.send(0, 1, 1, SendPart::Empty); // intra cluster 0
        p.recv(1, 0, 1, Merge::Discard);
        p.send(2, 3, 1, SendPart::Empty); // intra cluster 1
        p.recv(3, 2, 1, Merge::Discard);
        p.send(0, 2, 2, SendPart::Empty); // boundary
        p.recv(2, 0, 2, Merge::Discard);
        (c, p)
    }

    /// 1 site, 2 LANs x 2 machines x 2 ranks: the deep single-site
    /// topology the old top-level split could not parallelize at all.
    fn deep_single_site() -> (Clustering, Program) {
        let c = Clustering::new(vec![
            vec![0; 8],
            vec![0; 8],
            vec![0, 0, 0, 0, 1, 1, 1, 1],
            vec![0, 0, 1, 1, 2, 2, 3, 3],
        ])
        .unwrap();
        let mut p = Program::new(8);
        p.send(0, 4, 1, SendPart::Empty);
        p.recv(4, 0, 1, Merge::Discard);
        (c, p)
    }

    #[test]
    fn partitions_ranks_and_channels() {
        let (c, p) = two_site();
        let index = ChannelIndex::build(&p);
        let map = ShardMap::build(&c, &index);
        assert_eq!(map.n_clusters(), 2);
        assert_eq!(map.depth(), 1);
        assert_eq!(map.n_ranks(), 4);
        assert_eq!(map.n_channels(), 3);
        assert!(map.matches(&index));
        // Channel separations follow the clustering: exactly one WAN
        // channel, the rest intra-site.
        let wan: Vec<u32> = (0..3u32).filter(|&ch| map.chan_sep(ch) == 1).collect();
        assert_eq!(wan.len(), 1);
        assert_eq!(index.key(wan[0]), (0, 2, 2));
        assert!((0..3u32).filter(|&ch| ch != wan[0]).all(|ch| map.chan_sep(ch) == 2));
    }

    #[test]
    fn cut_splits_along_the_top_level() {
        let (c, p) = two_site();
        let index = ChannelIndex::build(&p);
        let map = ShardMap::build(&c, &index);
        let cut = map.cut(2, DEFAULT_MIN_SHARD_RANKS);
        assert_eq!(cut.n_shards(), 2);
        assert_eq!(cut.shard_of(0), 0);
        assert_eq!(cut.shard_of(1), 0);
        assert_eq!(cut.shard_of(2), 1);
        assert_eq!(cut.shard_of(3), 1);
        // Channel shards follow the receiver.
        for ch in 0..3u32 {
            let (_, to, _) = index.key(ch);
            assert_eq!(cut.chan_shard(ch), cut.shard_of(to));
        }
        // A 1-shard cut keeps everything together.
        assert_eq!(map.cut(1, DEFAULT_MIN_SHARD_RANKS).n_shards(), 1);
    }

    #[test]
    fn flat_clustering_is_one_cluster() {
        let c = Clustering::flat(6);
        let mut p = Program::new(6);
        p.send(0, 5, 1, SendPart::Empty);
        p.recv(5, 0, 1, Merge::Discard);
        let map = ShardMap::build(&c, &ChannelIndex::build(&p));
        assert_eq!(map.n_clusters(), 1);
        assert_eq!(map.depth(), 0);
        let cut = map.cut(8, DEFAULT_MIN_SHARD_RANKS);
        assert_eq!(cut.n_shards(), 1);
        assert!((0..6).all(|r| cut.shard_of(r) == 0));
    }

    #[test]
    fn deep_single_site_splits_below_the_top_level() {
        let (c, p) = deep_single_site();
        let map = ShardMap::build(&c, &ChannelIndex::build(&p));
        // The top level has a single cluster, but the deepest has 4:
        // the cut descends the non-branching site level and keeps
        // splitting down the LAN and machine levels.
        assert_eq!(map.n_clusters(), 4);
        assert_eq!(map.depth(), 3);
        assert_eq!(map.cut(2, 1).n_shards(), 2);
        assert_eq!(map.cut(4, 1).n_shards(), 4);
        // The deepest level has 4 machines: the cut saturates there.
        assert_eq!(map.cut(8, 1).n_shards(), 4);
        // Every shard of the 4-way cut is one machine (2 ranks).
        let cut = map.cut(4, 1);
        let mut per = vec![0usize; cut.n_shards()];
        for r in 0..map.n_ranks() {
            per[cut.shard_of(r)] += 1;
        }
        assert_eq!(per, vec![2, 2, 2, 2]);
    }

    #[test]
    fn min_ranks_floor_caps_the_cut() {
        let (c, p) = deep_single_site();
        let map = ShardMap::build(&c, &ChannelIndex::build(&p));
        // 8 ranks with a floor of 4: at most 2 shards, each >= 4 ranks.
        let cut = map.cut(8, 4);
        assert_eq!(cut.n_shards(), 2);
        let mut per = vec![0usize; cut.n_shards()];
        for r in 0..map.n_ranks() {
            per[cut.shard_of(r)] += 1;
        }
        assert!(per.iter().all(|&k| k >= 4));
        // A floor above half the ranks forbids any split.
        assert_eq!(map.cut(8, 5).n_shards(), 1);
    }

    #[test]
    fn lpt_grouping_balances_uneven_clusters() {
        // Clusters of 4, 2, 2 ranks into two shards: LPT packs the two
        // small clusters together, balancing 4 + 4.
        let c =
            Clustering::new(vec![vec![0; 8], vec![0, 0, 0, 0, 1, 1, 2, 2]]).unwrap();
        let mut p = Program::new(8);
        p.send(0, 7, 1, SendPart::Empty);
        p.recv(7, 0, 1, Merge::Discard);
        let map = ShardMap::build(&c, &ChannelIndex::build(&p));
        let cut = map.cut(2, 1);
        assert_eq!(cut.n_shards(), 2);
        let mut per = vec![0usize; 2];
        for r in 0..8 {
            per[cut.shard_of(r)] += 1;
        }
        assert_eq!(per, vec![4, 4]);
    }

    #[test]
    fn cuts_are_deterministic_and_fingerprinted() {
        let (c, p) = deep_single_site();
        let index = ChannelIndex::build(&p);
        let a = ShardMap::build(&c, &index);
        let b = ShardMap::build(&c, &index);
        assert_eq!(a, b);
        assert_eq!(a.fingerprint(), b.fingerprint());
        // The cut is a pure function of (tree, target, floor): repeated
        // cuts are identical, whatever worker count later runs them.
        for target in [1usize, 2, 3, 4, 8, 16] {
            assert_eq!(a.cut(target, 1), b.cut(target, 1));
            assert_eq!(a.cut(target, 1), a.cut(target, 1));
        }
        let (c2, p2) = two_site();
        let other = ShardMap::build(&c2, &ChannelIndex::build(&p2));
        assert_ne!(a.fingerprint(), other.fingerprint());
    }

    #[test]
    fn lookahead_is_the_wan_latency() {
        let (c, p) = two_site();
        let map = ShardMap::build(&c, &ChannelIndex::build(&p));
        let params = presets::paper_grid();
        assert_eq!(map.lookahead_us(&params), params.at_sep(1).latency_us);
        assert_eq!(map.lookahead_at(&params, 1), map.lookahead_us(&params));
        assert_eq!(map.lookahead_at(&params, 2), params.at_sep(2).latency_us);
        let uniform =
            crate::model::NetworkParams::new(vec![LinkParams::new(42.0, 1.0)]);
        assert_eq!(map.lookahead_us(&uniform), 42.0);
    }

    #[test]
    fn exec_mode_labels() {
        assert_eq!(ExecMode::default(), ExecMode::Sequential);
        assert_eq!(ExecMode::Sequential.name(), "sequential");
        assert_eq!(ExecMode::Sharded { threads: 4 }.name(), "sharded:4");
    }
}
