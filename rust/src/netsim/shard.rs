//! Topology-keyed sharding of the execution engine.
//!
//! The paper's multilevel hierarchy is also a parallel-simulation
//! opportunity: ranks of one top-level (level-1) cluster exchange the
//! overwhelming majority of a collective's messages among themselves,
//! and only tree edges that cross the WAN couple two clusters. The
//! [`ShardMap`] precomputes that partition for a compiled program —
//! which cluster owns each rank, which cluster owns each
//! [`ChannelIndex`] channel (the receiver's), and which channels are
//! **boundary** channels (sender and receiver in different clusters) —
//! so the sharded engine (`netsim::engine::run_core_sharded`) can route
//! every intra-cluster message without cross-thread coordination.
//!
//! Like the channel index, the map is a pure function of immutable
//! inputs (clustering + program), so plans and schedules build it once
//! and every warm run reuses it.
//!
//! ## Synchronization and determinism
//!
//! The classical conservative bound for this partition is the
//! inter-cluster lookahead ([`ShardMap::lookahead_us`]): a shard may
//! safely advance its local clock to `min(neighbor clocks) + L`, where
//! `L` is the minimum inter-cluster link latency from
//! [`NetworkParams`] — no cross-cluster message can arrive earlier than
//! its sender's clock plus the WAN latency. The engine's programs are
//! *blocking dataflow* (each rank is a sequential action list; a `Recv`
//! waits for exactly one channel), which admits an even stronger rule:
//! a shard can run arbitrarily far ahead and simply *block* on the
//! first receive whose boundary channel is still empty. Every
//! cross-shard dependency is an explicit message, never a clock
//! comparison, so the blocking rule subsumes the lookahead horizon and
//! is exact rather than conservative — and because every channel has a
//! single sender whose sends occur in program order, per-channel FIFO
//! delivery is deterministic regardless of worker interleaving. That is
//! what makes sharded results **bitwise identical** to the sequential
//! engine's.

use crate::model::NetworkParams;
use crate::netsim::payload::Rank;
use crate::netsim::program::ChannelIndex;
use crate::topology::Clustering;

/// How an engine executes a program.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// Single-threaded ready-queue loop (the differential oracle).
    #[default]
    Sequential,
    /// Partition ranks by top-level cluster and run up to `threads`
    /// shard workers on `std::thread`s. Results are bitwise identical
    /// to [`ExecMode::Sequential`]; `threads <= 1` (or a single-cluster
    /// topology) falls back to the sequential path.
    Sharded { threads: usize },
}

impl ExecMode {
    /// Short human-readable label for reports.
    pub fn name(&self) -> String {
        match self {
            ExecMode::Sequential => "sequential".into(),
            ExecMode::Sharded { threads } => format!("sharded:{threads}"),
        }
    }
}

/// The cluster partition of a compiled program: per-rank owner cluster,
/// per-channel owner cluster (the receiver's), and the boundary-channel
/// set. Built once per plan/schedule alongside the [`ChannelIndex`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardMap {
    /// Dense level-1 cluster id of every rank (first-appearance order).
    cluster_of_rank: Vec<u32>,
    /// Owning cluster of every channel: the *receiver's* cluster, since
    /// the receiver's mailbox slot and wait slot live on its shard.
    owner_of_chan: Vec<u32>,
    /// `boundary[c]` — sender and receiver clusters differ, so a send on
    /// `c` must cross shards through the boundary mailboxes.
    boundary: Vec<bool>,
    n_clusters: usize,
    n_boundary: usize,
}

impl ShardMap {
    /// Partition `index`'s channels by `clustering`'s level-1 clusters.
    /// Single-level clusterings (topology-unaware communicators) yield
    /// one cluster — the sharded engine then degenerates to the
    /// sequential fast path.
    pub fn build(clustering: &Clustering, index: &ChannelIndex) -> ShardMap {
        let n = clustering.n_ranks();
        let mut cluster_of_rank = Vec::with_capacity(n);
        let mut n_clusters = 0usize;
        if clustering.n_levels() > 1 {
            // Dense renumbering in first-appearance order: level-1 color
            // ids are arbitrary, shard ids must be `0..n_clusters`.
            let mut dense: std::collections::HashMap<u32, u32> = Default::default();
            for r in 0..n {
                let c = clustering.color(1, r);
                let id = *dense.entry(c).or_insert_with(|| {
                    let id = n_clusters as u32;
                    n_clusters += 1;
                    id
                });
                cluster_of_rank.push(id);
            }
        } else {
            cluster_of_rank.resize(n, 0);
            n_clusters = 1;
        }
        let n_chan = index.n_channels();
        let mut owner_of_chan = Vec::with_capacity(n_chan);
        let mut boundary = Vec::with_capacity(n_chan);
        let mut n_boundary = 0usize;
        for c in 0..n_chan {
            let (from, to, _tag) = index.key(c as u32);
            let cross = cluster_of_rank[from] != cluster_of_rank[to];
            owner_of_chan.push(cluster_of_rank[to]);
            boundary.push(cross);
            n_boundary += cross as usize;
        }
        ShardMap { cluster_of_rank, owner_of_chan, boundary, n_clusters, n_boundary }
    }

    /// Number of level-1 clusters (= maximum useful shard count).
    pub fn n_clusters(&self) -> usize {
        self.n_clusters
    }

    /// Number of ranks this map was built for.
    pub fn n_ranks(&self) -> usize {
        self.cluster_of_rank.len()
    }

    /// Dense cluster id of rank `r`.
    #[inline]
    pub fn cluster_of(&self, r: Rank) -> usize {
        self.cluster_of_rank[r] as usize
    }

    /// Owning cluster (the receiver's) of channel `c`.
    #[inline]
    pub fn chan_owner(&self, c: u32) -> usize {
        self.owner_of_chan[c as usize] as usize
    }

    /// Whether channel `c` crosses clusters.
    #[inline]
    pub fn is_boundary(&self, c: u32) -> bool {
        self.boundary[c as usize]
    }

    /// Number of boundary (cross-cluster) channels.
    pub fn n_boundary(&self) -> usize {
        self.n_boundary
    }

    /// Number of channels this map covers.
    pub fn n_channels(&self) -> usize {
        self.owner_of_chan.len()
    }

    /// Cheap shape guard, mirroring `ChannelIndex::matches`: was this
    /// map built for an index with the same channel count?
    pub fn matches(&self, index: &ChannelIndex) -> bool {
        self.owner_of_chan.len() == index.n_channels()
    }

    /// The conservative lookahead horizon for this partition: the
    /// minimum latency of any inter-cluster (separation-1) link. A shard
    /// whose neighbors' clocks are at `t` can never observe a boundary
    /// arrival before `t + lookahead`. The blocking-dataflow engine
    /// (see the module docs) subsumes this bound exactly, but the
    /// horizon remains the quantity that makes cluster-keyed sharding
    /// profitable: WAN latency dwarfs intra-cluster event spacing.
    pub fn lookahead_us(&self, params: &NetworkParams) -> f64 {
        params.at_sep(1).latency_us
    }

    /// Approximate resident size (for plan footprint accounting).
    pub fn approx_bytes(&self) -> usize {
        self.cluster_of_rank.len() * 4
            + self.owner_of_chan.len() * 4
            + self.boundary.len()
            + std::mem::size_of::<ShardMap>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{presets, LinkParams};
    use crate::netsim::program::{Merge, Program, SendPart};

    /// 2 sites x 2 ranks; channels: intra site 0, intra site 1, WAN.
    fn two_site() -> (Clustering, Program) {
        let c = Clustering::new(vec![vec![0; 4], vec![0, 0, 1, 1]]).unwrap();
        let mut p = Program::new(4);
        p.send(0, 1, 1, SendPart::Empty); // intra cluster 0
        p.recv(1, 0, 1, Merge::Discard);
        p.send(2, 3, 1, SendPart::Empty); // intra cluster 1
        p.recv(3, 2, 1, Merge::Discard);
        p.send(0, 2, 2, SendPart::Empty); // boundary
        p.recv(2, 0, 2, Merge::Discard);
        (c, p)
    }

    #[test]
    fn partitions_ranks_and_channels() {
        let (c, p) = two_site();
        let index = ChannelIndex::build(&p);
        let map = ShardMap::build(&c, &index);
        assert_eq!(map.n_clusters(), 2);
        assert_eq!(map.n_ranks(), 4);
        assert_eq!(map.cluster_of(0), 0);
        assert_eq!(map.cluster_of(3), 1);
        assert_eq!(map.n_channels(), 3);
        assert!(map.matches(&index));
        // Channel owners follow the receiver.
        for ch in 0..3u32 {
            let (_, to, _) = index.key(ch);
            assert_eq!(map.chan_owner(ch), map.cluster_of(to));
        }
        assert_eq!(map.n_boundary(), 1);
        let wan: Vec<u32> = (0..3u32).filter(|&ch| map.is_boundary(ch)).collect();
        assert_eq!(wan.len(), 1);
        assert_eq!(index.key(wan[0]), (0, 2, 2));
    }

    #[test]
    fn flat_clustering_is_one_cluster() {
        let c = Clustering::flat(6);
        let mut p = Program::new(6);
        p.send(0, 5, 1, SendPart::Empty);
        p.recv(5, 0, 1, Merge::Discard);
        let map = ShardMap::build(&c, &ChannelIndex::build(&p));
        assert_eq!(map.n_clusters(), 1);
        assert_eq!(map.n_boundary(), 0);
        assert!((0..6).all(|r| map.cluster_of(r) == 0));
    }

    #[test]
    fn lookahead_is_the_wan_latency() {
        let (c, p) = two_site();
        let map = ShardMap::build(&c, &ChannelIndex::build(&p));
        let params = presets::paper_grid();
        assert_eq!(map.lookahead_us(&params), params.at_sep(1).latency_us);
        let uniform =
            crate::model::NetworkParams::new(vec![LinkParams::new(42.0, 1.0)]);
        assert_eq!(map.lookahead_us(&uniform), 42.0);
    }

    #[test]
    fn exec_mode_labels() {
        assert_eq!(ExecMode::default(), ExecMode::Sequential);
        assert_eq!(ExecMode::Sequential.name(), "sequential");
        assert_eq!(ExecMode::Sharded { threads: 4 }.name(), "sharded:4");
    }
}
